"""Layer-2 JAX compute graphs for the PERKS reproduction.

Each public function returns a *jittable* function plus its example
arguments, ready for AOT lowering by `aot.py`. Two execution models per
solver, mirroring Fig 3 of the paper:

* `*_step`  — ONE time step / iteration. The rust coordinator re-invokes
              the lowered executable N times (host-loop model); every
              invocation round-trips the state through device memory.
* `*_perks` — N steps fused into one executable via `lax.fori_loop`
              around the persistent Pallas kernel; state stays on-chip.

Python here is build-time only: these graphs are lowered once to HLO text
and never imported at runtime.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels import cg_step as cg_kernels
from compile.kernels import stencil2d, stencil3d
from compile.stencils import spec as stencil_spec


# --------------------------------------------------------------------------
# Stencils
# --------------------------------------------------------------------------

def padded_shape(name: str, interior):
    r = stencil_spec(name).radius
    return tuple(s + 2 * r for s in interior)


def stencil_step_fn(name: str, interior, dtype=jnp.float32):
    """One stencil step: padded domain -> padded domain (tuple of 1)."""
    s = stencil_spec(name)
    mod = stencil2d if s.dims == 2 else stencil3d
    shape = padded_shape(name, interior)

    def fn(x_pad):
        return (mod.step(x_pad, name),)

    return fn, (jax.ShapeDtypeStruct(shape, dtype),)


def stencil_perks_fn(name: str, interior, steps: int, dtype=jnp.float32):
    """`steps` stencil steps in one executable (PERKS execution model).

    The time loop is *inside* the Pallas kernel, so the domain stays in
    VMEM across steps; the fori_loop dependence is the device-wide barrier.
    """
    s = stencil_spec(name)
    mod = stencil2d if s.dims == 2 else stencil3d
    shape = padded_shape(name, interior)

    def fn(x_pad):
        return (mod.persistent(x_pad, name, steps),)

    return fn, (jax.ShapeDtypeStruct(shape, dtype),)


# --------------------------------------------------------------------------
# Conjugate gradient
# --------------------------------------------------------------------------

def spmv(data, cols, rows, x, n: int):
    """L2 SpMV graph: COO-with-row-ids gather + segment add.

    The rust substrate implements merge-based SpMV for the CPU hot path;
    this graph is its XLA-side counterpart feeding the fused Pallas update.
    """
    return jnp.zeros((n,), dtype=x.dtype).at[rows].add(data * x[cols])


def cg_step_fn(n: int, nnz: int, dtype=jnp.float32):
    """One CG iteration: (data, cols, rows, x, r, p, rr) -> (x, r, p, rr)."""

    def fn(data, cols, rows, x, r, p, rr):
        ap = spmv(data, cols, rows, p, n)
        x2, r2, p2, rr2 = cg_kernels.cg_vector_update(x, r, p, ap, rr)
        return (x2, r2, p2, rr2)

    args = (
        jax.ShapeDtypeStruct((nnz,), dtype),
        jax.ShapeDtypeStruct((nnz,), jnp.int32),
        jax.ShapeDtypeStruct((nnz,), jnp.int32),
        jax.ShapeDtypeStruct((n,), dtype),
        jax.ShapeDtypeStruct((n,), dtype),
        jax.ShapeDtypeStruct((n,), dtype),
        jax.ShapeDtypeStruct((1,), dtype),
    )
    return fn, args


def cg_perks_fn(n: int, nnz: int, iters: int, dtype=jnp.float32):
    """`iters` CG iterations fused into one executable.

    Matrix data (the paper's cached A) and the vectors (cached r/p/x) are
    loop-invariant resp. loop-carried: XLA keeps them device-resident for
    the whole batch of iterations — the PERKS model for Krylov solvers.
    """

    def fn(data, cols, rows, x, r, p, rr):
        def body(_, state):
            x, r, p, rr = state
            ap = spmv(data, cols, rows, p, n)
            return cg_kernels.cg_vector_update(x, r, p, ap, rr)

        x2, r2, p2, rr2 = jax.lax.fori_loop(0, iters, body, (x, r, p, rr))
        return (x2, r2, p2, rr2)

    _, args = cg_step_fn(n, nnz, dtype)
    return fn, args


# --------------------------------------------------------------------------
# Residual helper (used by the e2e example to verify convergence on-device)
# --------------------------------------------------------------------------

def residual_fn(n: int, nnz: int, dtype=jnp.float32):
    """||b - Ax||^2 for convergence checking: returns a (1,) array."""

    def fn(data, cols, rows, x, b):
        ax = spmv(data, cols, rows, x, n)
        d = b - ax
        return (jnp.sum(d * d).reshape((1,)),)

    args = (
        jax.ShapeDtypeStruct((nnz,), dtype),
        jax.ShapeDtypeStruct((nnz,), jnp.int32),
        jax.ShapeDtypeStruct((nnz,), jnp.int32),
        jax.ShapeDtypeStruct((n,), dtype),
        jax.ShapeDtypeStruct((n,), dtype),
    )
    return fn, args
