"""Pure-jnp oracles for every Pallas kernel in this package.

These are the CORE correctness references: deliberately naive, no Pallas,
no clever slicing — just weighted shifted adds on the padded array. pytest
asserts the Pallas kernels (and transitively the AOT HLO executed from
rust) match these to float tolerance.
"""

from __future__ import annotations

import jax.numpy as jnp

from compile.stencils import spec as stencil_spec


def stencil_step_2d(x_pad, name: str):
    """One Jacobi step of the named 2D stencil on a padded array.

    `x_pad` has shape (H + 2r, W + 2r); the boundary ring of width r is a
    Dirichlet boundary (left untouched); only the interior is updated.
    """
    s = stencil_spec(name)
    r = s.radius
    h = x_pad.shape[0] - 2 * r
    w = x_pad.shape[1] - 2 * r
    acc = jnp.zeros((h, w), dtype=x_pad.dtype)
    for (dy, dx), wt in zip(s.offsets, s.weights()):
        acc = acc + jnp.asarray(wt, dtype=x_pad.dtype) * x_pad[
            r + dy : r + dy + h, r + dx : r + dx + w
        ]
    return x_pad.at[r : r + h, r : r + w].set(acc)


def stencil_step_3d(x_pad, name: str):
    """One Jacobi step of the named 3D stencil on a padded array."""
    s = stencil_spec(name)
    r = s.radius
    d = x_pad.shape[0] - 2 * r
    h = x_pad.shape[1] - 2 * r
    w = x_pad.shape[2] - 2 * r
    acc = jnp.zeros((d, h, w), dtype=x_pad.dtype)
    for (dz, dy, dx), wt in zip(s.offsets, s.weights()):
        acc = acc + jnp.asarray(wt, dtype=x_pad.dtype) * x_pad[
            r + dz : r + dz + d, r + dy : r + dy + h, r + dx : r + dx + w
        ]
    return x_pad.at[r : r + d, r : r + h, r : r + w].set(acc)


def stencil_multi_step(x_pad, name: str, steps: int):
    """`steps` applications of the single-step oracle (any dims)."""
    s = stencil_spec(name)
    step = stencil_step_2d if s.dims == 2 else stencil_step_3d
    for _ in range(steps):
        x_pad = step(x_pad, name)
    return x_pad


def spmv_coo(data, cols, rows, x, n: int):
    """Sparse matrix-vector product in COO-with-row-ids form.

    This is the oracle for the L2 spmv graph: y[rows[k]] += data[k] * x[cols[k]].
    """
    return jnp.zeros((n,), dtype=x.dtype).at[rows].add(data * x[cols])


def cg_vector_update(x, r, p, ap, rr_old):
    """One fused CG vector update (everything after SpMV in a CG iteration).

    alpha = rr_old / (p . Ap); x += alpha p; r -= alpha Ap;
    rr_new = r . r; beta = rr_new / rr_old; p = r + beta p.
    Returns (x', r', p', rr_new) with rr_new shaped (1,).
    """
    pap = jnp.sum(p * ap)
    alpha = rr_old[0] / pap
    x_new = x + alpha * p
    r_new = r - alpha * ap
    rr_new = jnp.sum(r_new * r_new)
    beta = rr_new / rr_old[0]
    p_new = r_new + beta * p
    return x_new, r_new, p_new, rr_new.reshape((1,))


def cg_iteration(data, cols, rows, x, r, p, rr, n: int):
    """One full CG iteration: SpMV + fused vector update."""
    ap = spmv_coo(data, cols, rows, p, n)
    return cg_vector_update(x, r, p, ap, rr)
