"""Layer-1 Pallas kernel: fused conjugate-gradient vector update.

A CG iteration is SpMV (irregular; done at L2 with a gather/segment-add
graph) followed by a chain of BLAS-1 ops: 2 dots, 2 axpys, 1 xpay. In the
host-loop model each of those ops streams the vectors from device memory.
This kernel fuses them into one pass with the vectors resident in VMEM —
the CG analog of the paper's caching of the residual vector r (§III-B-2:
cache priority r > A).

Inputs:  x, r, p, ap : f[n]   rr_old : f[1]  (r.r from the previous step)
Outputs: x', r', p'  : f[n]   rr_new : f[1]
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cg_update_kernel(x_ref, r_ref, p_ref, ap_ref, rr_ref, xo_ref, ro_ref, po_ref, rro_ref):
    x = x_ref[...]
    r = r_ref[...]
    p = p_ref[...]
    ap = ap_ref[...]
    rr_old = rr_ref[0]

    pap = jnp.sum(p * ap)
    alpha = rr_old / pap
    x_new = x + alpha * p
    r_new = r - alpha * ap
    rr_new = jnp.sum(r_new * r_new)
    beta = rr_new / rr_old
    p_new = r_new + beta * p

    xo_ref[...] = x_new
    ro_ref[...] = r_new
    po_ref[...] = p_new
    rro_ref[...] = rr_new.reshape((1,))


def cg_vector_update(x, r, p, ap, rr_old):
    """Fused CG vector update; returns (x', r', p', rr_new)."""
    n = x.shape[0]
    dt = x.dtype
    return pl.pallas_call(
        _cg_update_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((n,), dt),
            jax.ShapeDtypeStruct((n,), dt),
            jax.ShapeDtypeStruct((n,), dt),
            jax.ShapeDtypeStruct((1,), dt),
        ),
        interpret=True,
    )(x, r, p, ap, rr_old)
