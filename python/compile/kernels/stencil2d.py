"""Layer-1 Pallas kernels for 2D iterative stencils.

Three kernels, mirroring the paper's execution models:

* `step`      — one Jacobi step, whole padded domain as a single VMEM
                block. This is the *baseline* building block: the host
                (rust L3) re-invokes the lowered executable once per time
                step, paying the device-memory round trip in between —
                exactly the host-loop model of Fig 3 (left).
* `persistent`— the PERKS kernel: the time loop lives *inside* the kernel
                and the domain stays resident in VMEM across steps (the
                register/shared-memory cache of Fig 3 right). The
                loop-carried dependence of `lax.fori_loop` plays the role
                of `grid.sync()`.
* `tiled_step`— one Jacobi step with an explicit BlockSpec tiling: the
                output is partitioned into (tile x tile) VMEM blocks and
                each grid instance reads its tile + halo from the padded
                input. This expresses the HBM<->VMEM schedule that the CUDA
                code expressed with thread blocks + shared memory.

All kernels use interpret=True: real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute (see DESIGN.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile.stencils import spec as stencil_spec


def _apply_2d(buf, name: str, h: int, w: int):
    """Weighted shifted-adds over the interior of a padded 2D buffer."""
    s = stencil_spec(name)
    r = s.radius
    acc = None
    for (dy, dx), wt in zip(s.offsets, s.weights()):
        term = jnp.asarray(wt, dtype=buf.dtype) * jax.lax.slice(
            buf, (r + dy, r + dx), (r + dy + h, r + dx + w)
        )
        acc = term if acc is None else acc + term
    return acc


def _step_kernel(x_ref, o_ref, *, name: str):
    r = stencil_spec(name).radius
    h = x_ref.shape[0] - 2 * r
    w = x_ref.shape[1] - 2 * r
    buf = x_ref[...]
    core = _apply_2d(buf, name, h, w)
    o_ref[...] = jax.lax.dynamic_update_slice(buf, core, (r, r))


def step(x_pad, name: str):
    """One Jacobi step of the named 2D stencil (padded domain in, out)."""
    return pl.pallas_call(
        functools.partial(_step_kernel, name=name),
        out_shape=jax.ShapeDtypeStruct(x_pad.shape, x_pad.dtype),
        interpret=True,
    )(x_pad)


def _persistent_kernel(x_ref, o_ref, *, name: str, steps: int):
    r = stencil_spec(name).radius
    h = x_ref.shape[0] - 2 * r
    w = x_ref.shape[1] - 2 * r
    # Load once from HBM-analog; the fori_loop carries the domain through
    # VMEM for all `steps` — this is the PERKS cache residency.
    buf = x_ref[...]

    def body(_, b):
        core = _apply_2d(b, name, h, w)
        return jax.lax.dynamic_update_slice(b, core, (r, r))

    o_ref[...] = jax.lax.fori_loop(0, steps, body, buf)


def persistent(x_pad, name: str, steps: int):
    """`steps` Jacobi steps inside ONE kernel (the PERKS execution model)."""
    return pl.pallas_call(
        functools.partial(_persistent_kernel, name=name, steps=steps),
        out_shape=jax.ShapeDtypeStruct(x_pad.shape, x_pad.dtype),
        interpret=True,
    )(x_pad)


def _tiled_kernel(x_ref, o_ref, *, name: str, tile: int):
    s = stencil_spec(name)
    r = s.radius
    ti = pl.program_id(0)
    tj = pl.program_id(1)
    # Read this tile plus its halo ring from the full padded input. The
    # load is the HBM->VMEM transfer the CUDA kernel did into shared mem.
    blk = x_ref[pl.dslice(ti * tile, tile + 2 * r), pl.dslice(tj * tile, tile + 2 * r)]
    o_ref[...] = _apply_2d(blk, name, tile, tile)


def tiled_step(x_pad, name: str, tile: int):
    """One step with explicit (tile x tile) output blocking.

    Returns the *interior* (H x W) array; the caller re-pads. Interior
    dimensions must be divisible by `tile`.
    """
    s = stencil_spec(name)
    r = s.radius
    h = x_pad.shape[0] - 2 * r
    w = x_pad.shape[1] - 2 * r
    assert h % tile == 0 and w % tile == 0, (h, w, tile)
    grid = (h // tile, w // tile)
    return pl.pallas_call(
        functools.partial(_tiled_kernel, name=name, tile=tile),
        grid=grid,
        in_specs=[pl.BlockSpec(x_pad.shape, lambda i, j: (0, 0))],
        out_specs=pl.BlockSpec((tile, tile), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((h, w), x_pad.dtype),
        interpret=True,
    )(x_pad)
