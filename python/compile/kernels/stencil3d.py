"""Layer-1 Pallas kernels for 3D iterative stencils.

Same structure as stencil2d: `step` (baseline, one step per kernel
invocation) and `persistent` (PERKS: in-kernel time loop, domain resident
in VMEM). 3D domains are small in the executed path (the simulator covers
paper-scale 256^3 domains); see DESIGN.md §2.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile.stencils import spec as stencil_spec


def _apply_3d(buf, name: str, d: int, h: int, w: int):
    s = stencil_spec(name)
    r = s.radius
    acc = None
    for (dz, dy, dx), wt in zip(s.offsets, s.weights()):
        term = jnp.asarray(wt, dtype=buf.dtype) * jax.lax.slice(
            buf, (r + dz, r + dy, r + dx), (r + dz + d, r + dy + h, r + dx + w)
        )
        acc = term if acc is None else acc + term
    return acc


def _interior(x_ref):
    return tuple(s for s in x_ref.shape)


def _step_kernel(x_ref, o_ref, *, name: str):
    r = stencil_spec(name).radius
    d, h, w = (s - 2 * r for s in x_ref.shape)
    buf = x_ref[...]
    core = _apply_3d(buf, name, d, h, w)
    o_ref[...] = jax.lax.dynamic_update_slice(buf, core, (r, r, r))


def step(x_pad, name: str):
    """One Jacobi step of the named 3D stencil (padded domain in, out)."""
    return pl.pallas_call(
        functools.partial(_step_kernel, name=name),
        out_shape=jax.ShapeDtypeStruct(x_pad.shape, x_pad.dtype),
        interpret=True,
    )(x_pad)


def _persistent_kernel(x_ref, o_ref, *, name: str, steps: int):
    r = stencil_spec(name).radius
    d, h, w = (s - 2 * r for s in x_ref.shape)
    buf = x_ref[...]

    def body(_, b):
        core = _apply_3d(b, name, d, h, w)
        return jax.lax.dynamic_update_slice(b, core, (r, r, r))

    o_ref[...] = jax.lax.fori_loop(0, steps, body, buf)


def persistent(x_pad, name: str, steps: int):
    """`steps` Jacobi steps inside ONE kernel (the PERKS execution model)."""
    return pl.pallas_call(
        functools.partial(_persistent_kernel, name=name, steps=steps),
        out_shape=jax.ShapeDtypeStruct(x_pad.shape, x_pad.dtype),
        interpret=True,
    )(x_pad)
