"""Canonical stencil benchmark catalog (Table III of the PERKS paper).

This module is the single source of truth on the python side for the 13
stencil benchmarks: their dimensionality, neighbourhood pattern, radius and
— critically — the exact (offset, weight) list. The rust substrate
(`rust/src/stencil/shape.rs`) mirrors the same construction so that the jnp
oracle, the Pallas kernels, the AOT-lowered HLO and the rust CPU gold
executor all compute bit-identical Jacobi updates.

Weight rule (deterministic, language-independent): offsets are sorted
lexicographically; weight_i = (i + 1) / sum_j (j + 1). Weights sum to 1 so
repeated Jacobi application stays bounded (convex combination).
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass


@dataclass(frozen=True)
class StencilSpec:
    name: str
    dims: int  # 2 or 3
    radius: int
    # list of integer offset tuples, sorted lexicographically; len == points
    offsets: tuple
    flops_per_cell: int  # as reported in Table III

    @property
    def points(self) -> int:
        return len(self.offsets)

    def weights(self) -> list:
        n = len(self.offsets)
        total = n * (n + 1) // 2
        return [(i + 1) / total for i in range(n)]


def _star2d(radius: int):
    offs = {(0, 0)}
    for r in range(1, radius + 1):
        offs |= {(r, 0), (-r, 0), (0, r), (0, -r)}
    return tuple(sorted(offs))


def _box2d(radius: int):
    offs = set(itertools.product(range(-radius, radius + 1), repeat=2))
    return tuple(sorted(offs))


def _star3d(radius: int):
    offs = {(0, 0, 0)}
    for r in range(1, radius + 1):
        offs |= {(r, 0, 0), (-r, 0, 0), (0, r, 0), (0, -r, 0), (0, 0, r), (0, 0, -r)}
    return tuple(sorted(offs))


def _box3d(radius: int):
    offs = set(itertools.product(range(-radius, radius + 1), repeat=3))
    return tuple(sorted(offs))


def _faces_edges3d():
    """19-point 3D Poisson stencil: center + 6 faces + 12 edges."""
    offs = set()
    for o in itertools.product((-1, 0, 1), repeat=3):
        if sum(abs(v) for v in o) <= 2:
            offs.add(o)
    return tuple(sorted(offs))


def _pt17_3d():
    """17-point order-1 3D stencil: center + 6 faces + 8 corners + (0,0,+-2).

    The literature (Rawat et al.) is not prescriptive about the exact
    17-point neighbourhood; we fix a symmetric definition with 2*17=34
    flops/cell to match Table III and document it here. DESIGN.md records
    this as a (benign) substitution.
    """
    offs = {(0, 0, 0), (0, 0, 2), (0, 0, -2)}
    for o in itertools.product((-1, 1), repeat=3):
        offs.add(o)
    for o in ((1, 0, 0), (-1, 0, 0), (0, 1, 0), (0, -1, 0), (0, 0, 1), (0, 0, -1)):
        offs.add(o)
    return tuple(sorted(offs))


CATALOG: dict = {}


def _reg(name, dims, radius, offsets, flops):
    CATALOG[name] = StencilSpec(name, dims, radius, offsets, flops)


_reg("2d5pt", 2, 1, _star2d(1), 10)
_reg("2ds9pt", 2, 2, _star2d(2), 18)
_reg("2d13pt", 2, 3, _star2d(3), 26)
_reg("2d17pt", 2, 4, _star2d(4), 34)
_reg("2d21pt", 2, 5, _star2d(5), 42)
_reg("2ds25pt", 2, 6, _star2d(6), 59)
_reg("2d9pt", 2, 1, _box2d(1), 18)
_reg("2d25pt", 2, 2, _box2d(2), 50)
_reg("3d7pt", 3, 1, _star3d(1), 14)
_reg("3d13pt", 3, 2, _star3d(2), 26)
_reg("3d17pt", 3, 2, _pt17_3d(), 34)
_reg("3d27pt", 3, 1, _box3d(1), 54)
_reg("poisson", 3, 1, _faces_edges3d(), 38)


def spec(name: str) -> StencilSpec:
    return CATALOG[name]


def names_2d():
    return [n for n, s in CATALOG.items() if s.dims == 2]


def names_3d():
    return [n for n, s in CATALOG.items() if s.dims == 3]
