"""AOT compile path: lower every model variant to HLO text artifacts.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs:
  artifacts/<name>.hlo.txt  — one per variant
  artifacts/manifest.txt    — one line per artifact: `key=value` pairs with
                              input/output signatures the rust runtime
                              parses (runtime/artifact.rs).

Run once via `make artifacts`; python never executes at request time.
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.stencils import spec as stencil_spec

jax.config.update("jax_enable_x64", True)


def to_hlo_text(lowered, return_tuple: bool = True) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=return_tuple
    )
    return comp.as_hlo_text()


def _sig(shapes) -> str:
    if not isinstance(shapes, (tuple, list)):
        shapes = (shapes,)

    def one(s):
        dt = {"float32": "f32", "float64": "f64", "int32": "i32"}[str(jnp.dtype(s.dtype))]
        return f"{dt}[{','.join(str(d) for d in s.shape)}]"

    return ",".join(one(s) for s in shapes)


def poisson2d_nnz(g: int) -> int:
    """NNZ of the 5-point Laplacian on a g x g grid (deterministic; the
    rust generator sparse::gen::poisson2d produces the same structure)."""
    return 5 * g * g - 4 * g


class Builder:
    def __init__(self, outdir: str):
        self.outdir = outdir
        self.lines = []

    def emit(self, name: str, fn, args, return_tuple: bool = True, **meta):
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered, return_tuple=return_tuple)
        path = os.path.join(self.outdir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        out_shapes = jax.eval_shape(fn, *args)
        kv = {
            "name": name,
            "in": _sig(args),
            "out": _sig(out_shapes),
            "tuple": "1" if return_tuple else "0",
        }
        kv.update({k: str(v) for k, v in meta.items()})
        self.lines.append(" ".join(f"{k}={v}" for k, v in kv.items()))
        print(f"  {name}: {len(text)} chars, in={kv['in']}")

    def finish(self):
        with open(os.path.join(self.outdir, "manifest.txt"), "w") as f:
            f.write("\n".join(self.lines) + "\n")
        print(f"wrote {len(self.lines)} artifacts + manifest to {self.outdir}")


# Stencil artifact set executed by the rust runtime. interior sizes are
# CPU-scale (paper-scale domains are covered by simgpu); `steps` is the
# fused time-step count of the PERKS executable.
STENCIL_SET = [
    # (bench, interior, dtype, perks_steps)
    ("2d5pt", (128, 128), "f32", 16),
    # row-partitioned shard for the multi-device halo-exchange runtime
    # (coordinator::multidev): two 64-row shards compose a 128x128 domain
    ("2d5pt", (64, 128), "f32", 16),
    ("2d9pt", (128, 128), "f32", 16),
    ("2ds9pt", (128, 128), "f32", 16),
    ("2d5pt", (64, 64), "f64", 16),
    ("3d7pt", (32, 32, 32), "f32", 8),
    ("3d27pt", (32, 32, 32), "f32", 8),
]

CG_GRID = 32  # poisson2d grid side: n = 1024
CG_PERKS_ITERS = 8


def build(outdir: str) -> None:
    os.makedirs(outdir, exist_ok=True)
    b = Builder(outdir)
    dtypes = {"f32": jnp.float32, "f64": jnp.float64}

    for bench, interior, dt, steps in STENCIL_SET:
        dtype = dtypes[dt]
        dims = "x".join(str(d) for d in interior)
        fn, args = model.stencil_step_fn(bench, interior, dtype)
        b.emit(
            f"stencil_{bench}_{dims}_{dt}_step", fn, args,
            kind="stencil_step", bench=bench, interior=dims, dtype=dt, steps=1,
            radius=stencil_spec(bench).radius,
        )
        fn, args = model.stencil_perks_fn(bench, interior, steps, dtype)
        b.emit(
            f"stencil_{bench}_{dims}_{dt}_perks{steps}", fn, args,
            kind="stencil_perks", bench=bench, interior=dims, dtype=dt, steps=steps,
            radius=stencil_spec(bench).radius,
        )
        # Untupled ("raw") variants: single array output, so the rust
        # host-loop can chain device buffers via execute_b without a host
        # round trip — the fair non-PERKS baseline (launch overhead only).
        def unwrap(f):
            return lambda x: f(x)[0]

        fn1, args1 = model.stencil_step_fn(bench, interior, dtype)
        b.emit(
            f"stencil_{bench}_{dims}_{dt}_step_raw", unwrap(fn1), args1,
            return_tuple=False,
            kind="stencil_step", bench=bench, interior=dims, dtype=dt, steps=1,
            radius=stencil_spec(bench).radius,
        )
        fnk, argsk = model.stencil_perks_fn(bench, interior, steps, dtype)
        b.emit(
            f"stencil_{bench}_{dims}_{dt}_perks{steps}_raw", unwrap(fnk), argsk,
            return_tuple=False,
            kind="stencil_perks", bench=bench, interior=dims, dtype=dt, steps=steps,
            radius=stencil_spec(bench).radius,
        )

    n = CG_GRID * CG_GRID
    nnz = poisson2d_nnz(CG_GRID)
    fn, args = model.cg_step_fn(n, nnz)
    b.emit(f"cg_step_n{n}", fn, args, kind="cg_step", n=n, nnz=nnz, dtype="f32", iters=1)
    fn, args = model.cg_perks_fn(n, nnz, CG_PERKS_ITERS)
    b.emit(
        f"cg_perks{CG_PERKS_ITERS}_n{n}", fn, args,
        kind="cg_perks", n=n, nnz=nnz, dtype="f32", iters=CG_PERKS_ITERS,
    )
    fn, args = model.residual_fn(n, nnz)
    b.emit(f"cg_residual_n{n}", fn, args, kind="cg_residual", n=n, nnz=nnz, dtype="f32")

    b.finish()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifact output dir")
    args = ap.parse_args()
    build(args.out)


if __name__ == "__main__":
    main()
