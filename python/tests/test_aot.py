"""AOT pipeline: lowering produces parseable HLO text + a sane manifest."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


def test_to_hlo_text_smoke():
    fn, args = model.stencil_step_fn("2d5pt", (8, 8))
    lowered = jax.jit(fn).lower(*args)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[10,10]" in text  # padded shape appears in the signature


def test_hlo_text_is_plain_ops_no_custom_call():
    """interpret=True must lower to plain HLO the CPU PJRT client can run —
    no Mosaic custom-calls."""
    fn, args = model.stencil_perks_fn("2d9pt", (8, 8), steps=4)
    text = aot.to_hlo_text(jax.jit(fn).lower(*args))
    assert "mosaic" not in text.lower()


def test_perks_artifact_contains_loop():
    fn, args = model.stencil_perks_fn("2d5pt", (8, 8), steps=4)
    text = aot.to_hlo_text(jax.jit(fn).lower(*args))
    assert "while" in text.lower()  # fused time loop is a While in HLO


def test_sig_format():
    s = jax.ShapeDtypeStruct((3, 4), jnp.float32)
    t = jax.ShapeDtypeStruct((7,), jnp.int32)
    assert aot._sig((s, t)) == "f32[3,4],i32[7]"


def test_poisson2d_nnz_formula():
    assert aot.poisson2d_nnz(4) == 5 * 16 - 16
    assert aot.poisson2d_nnz(32) == 5 * 1024 - 128


def test_build_writes_manifest(tmp_path):
    """Full (small) build into a temp dir — only run when explicitly asked,
    it lowers every artifact (~minutes)."""
    if not os.environ.get("PERKS_TEST_FULL_AOT"):
        pytest.skip("set PERKS_TEST_FULL_AOT=1 to run the full AOT build test")
    aot.build(str(tmp_path))
    manifest = (tmp_path / "manifest.txt").read_text().strip().splitlines()
    assert len(manifest) == len(list(tmp_path.glob("*.hlo.txt")))
    for line in manifest:
        kv = dict(p.split("=", 1) for p in line.split())
        assert {"name", "in", "out", "kind"} <= set(kv)
        assert (tmp_path / f"{kv['name']}.hlo.txt").exists()


def test_stencil_step_fn_shapes():
    fn, args = model.stencil_step_fn("2d25pt", (16, 16))  # radius 2
    assert args[0].shape == (20, 20)
    out = jax.eval_shape(fn, *args)
    assert out[0].shape == (20, 20)


def test_cg_fns_shapes():
    fn, args = model.cg_step_fn(64, 300)
    out = jax.eval_shape(fn, *args)
    assert [o.shape for o in out] == [(64,), (64,), (64,), (1,)]
    fnp, argsp = model.cg_perks_fn(64, 300, 5)
    outp = jax.eval_shape(fnp, *argsp)
    assert [o.shape for o in outp] == [(64,), (64,), (64,), (1,)]
