"""Catalog invariants: the 13 benchmarks of Table III."""

import pytest

from compile import stencils


def test_catalog_has_all_13_benchmarks():
    assert len(stencils.CATALOG) == 13


@pytest.mark.parametrize("name", list(stencils.CATALOG))
def test_offsets_sorted_and_unique(name):
    s = stencils.spec(name)
    assert list(s.offsets) == sorted(set(s.offsets))


@pytest.mark.parametrize("name", list(stencils.CATALOG))
def test_offsets_within_radius(name):
    s = stencils.spec(name)
    for off in s.offsets:
        assert len(off) == s.dims
        assert all(abs(d) <= s.radius for d in off), (name, off)


@pytest.mark.parametrize("name", list(stencils.CATALOG))
def test_center_included(name):
    s = stencils.spec(name)
    assert tuple([0] * s.dims) in s.offsets


@pytest.mark.parametrize("name", list(stencils.CATALOG))
def test_weights_convex(name):
    s = stencils.spec(name)
    w = s.weights()
    assert len(w) == s.points
    assert abs(sum(w) - 1.0) < 1e-12
    assert all(x > 0 for x in w)


@pytest.mark.parametrize(
    "name,points",
    [
        ("2d5pt", 5), ("2ds9pt", 9), ("2d13pt", 13), ("2d17pt", 17),
        ("2d21pt", 21), ("2ds25pt", 25), ("2d9pt", 9), ("2d25pt", 25),
        ("3d7pt", 7), ("3d13pt", 13), ("3d17pt", 17), ("3d27pt", 27),
        ("poisson", 19),
    ],
)
def test_point_counts_match_names(name, points):
    assert stencils.spec(name).points == points


@pytest.mark.parametrize("name", list(stencils.CATALOG))
def test_flops_match_table_iii(name):
    # Table III reports FLOPs/cell; for all but 2ds25pt (59) and 3d27pt (54)
    # and 2d9pt-family that's 2*points (one fma pair per point).
    table = {
        "2d5pt": 10, "2ds9pt": 18, "2d13pt": 26, "2d17pt": 34, "2d21pt": 42,
        "2ds25pt": 59, "2d9pt": 18, "2d25pt": 50, "3d7pt": 14, "3d13pt": 26,
        "3d17pt": 34, "3d27pt": 54, "poisson": 38,
    }
    assert stencils.spec(name).flops_per_cell == table[name]
