"""Pallas 3D stencil kernels vs the pure-jnp oracle."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import stencils
from compile.kernels import ref, stencil3d

BENCH_3D = stencils.names_3d()


def _domain(name, d, h, w, dtype, seed=0):
    r = stencils.spec(name).radius
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        rng.standard_normal((d + 2 * r, h + 2 * r, w + 2 * r)), dtype=dtype
    )


@pytest.mark.parametrize("name", BENCH_3D)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_step_matches_ref(name, dtype):
    x = _domain(name, 8, 10, 6, dtype)
    got = stencil3d.step(x, name)
    want = ref.stencil_step_3d(x, name)
    tol = 1e-6 if dtype == jnp.float32 else 1e-12
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


@pytest.mark.parametrize("name", BENCH_3D)
def test_step_preserves_boundary(name):
    x = _domain(name, 6, 6, 6, jnp.float32)
    r = stencils.spec(name).radius
    got = np.asarray(stencil3d.step(x, name))
    xn = np.asarray(x)
    np.testing.assert_array_equal(got[:r], xn[:r])
    np.testing.assert_array_equal(got[-r:], xn[-r:])
    np.testing.assert_array_equal(got[:, :r, :], xn[:, :r, :])
    np.testing.assert_array_equal(got[:, :, -r:], xn[:, :, -r:])


@pytest.mark.parametrize("name", BENCH_3D)
@pytest.mark.parametrize("steps", [1, 3])
def test_persistent_equals_iterated_step(name, steps):
    x = _domain(name, 6, 8, 6, jnp.float64)
    got = stencil3d.persistent(x, name, steps)
    want = ref.stencil_multi_step(x, name, steps)
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


@settings(max_examples=15, deadline=None)
@given(
    name=st.sampled_from(BENCH_3D),
    d=st.integers(min_value=1, max_value=8),
    h=st.integers(min_value=1, max_value=8),
    w=st.integers(min_value=1, max_value=8),
)
def test_step_property(name, d, h, w):
    x = _domain(name, d, h, w, jnp.float32, seed=d * 64 + h * 8 + w)
    got = stencil3d.step(x, name)
    want = ref.stencil_step_3d(x, name)
    np.testing.assert_allclose(got, want, rtol=2e-6, atol=2e-6)


def test_constant_field_invariant():
    x = jnp.full((10, 10, 10), -1.5, dtype=jnp.float32)
    got = stencil3d.persistent(x, "3d7pt", 5)
    np.testing.assert_allclose(got, x, rtol=1e-6)
