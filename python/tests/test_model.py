"""L2 model graphs: execution-model equivalence at the JAX level."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model, stencils
from compile.kernels import ref


@pytest.mark.parametrize("name", ["2d5pt", "2d9pt", "2ds25pt"])
def test_stencil_perks_equals_iterated_step(name):
    steps = 5
    fn_step, (spec_in,) = model.stencil_step_fn(name, (12, 16))
    fn_perks, _ = model.stencil_perks_fn(name, (12, 16), steps)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(spec_in.shape), jnp.float32)
    want = x
    for _ in range(steps):
        (want,) = fn_step(want)
    (got,) = fn_perks(x)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("name", ["3d7pt", "poisson"])
def test_stencil_3d_model_matches_oracle(name):
    fn, (spec_in,) = model.stencil_perks_fn(name, (6, 6, 6), 3)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal(spec_in.shape), jnp.float32)
    (got,) = fn(x)
    want = ref.stencil_multi_step(x, name, 3)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_padded_shape_accounts_radius():
    assert model.padded_shape("2ds25pt", (10, 10)) == (22, 22)  # radius 6
    assert model.padded_shape("3d7pt", (4, 4, 4)) == (6, 6, 6)


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(min_value=4, max_value=64),
    seed=st.integers(min_value=0, max_value=1000),
)
def test_spmv_property_random_coo(n, seed):
    rng = np.random.default_rng(seed)
    nnz = 3 * n
    rows = jnp.asarray(np.sort(rng.integers(0, n, nnz)).astype(np.int32))
    cols = jnp.asarray(rng.integers(0, n, nnz).astype(np.int32))
    data = jnp.asarray(rng.standard_normal(nnz), jnp.float32)
    x = jnp.asarray(rng.standard_normal(n), jnp.float32)
    got = model.spmv(data, cols, rows, x, n)
    dense = np.zeros((n, n), np.float64)
    for r, c, v in zip(np.asarray(rows), np.asarray(cols), np.asarray(data)):
        dense[r, c] += v
    np.testing.assert_allclose(got, dense @ np.asarray(x, np.float64), rtol=3e-4, atol=3e-4)


def test_jit_compile_all_graph_kinds():
    """Every graph kind used by aot.py must trace + jit cleanly."""
    for fn, args in [
        model.stencil_step_fn("2d5pt", (8, 8)),
        model.stencil_perks_fn("2d9pt", (8, 8), 4),
        model.cg_step_fn(64, 256),
        model.cg_perks_fn(64, 256, 4),
        model.residual_fn(64, 256),
    ]:
        jax.jit(fn).lower(*args)  # lowering implies successful trace
