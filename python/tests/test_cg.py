"""Fused CG Pallas kernel + L2 CG graphs vs oracle, and actual convergence."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import cg_step, ref


def _poisson2d(g, dtype=np.float32):
    """5-point Laplacian on a g x g grid in COO-with-row-ids form.

    Row-major rows; within a row, entries sorted by column. This layout is
    mirrored exactly by rust sparse::gen::poisson2d.
    """
    n = g * g
    rows, cols, data = [], [], []
    for i in range(g):
        for j in range(g):
            row = i * g + j
            ent = [(row, 4.0)]
            if i > 0:
                ent.append((row - g, -1.0))
            if i < g - 1:
                ent.append((row + g, -1.0))
            if j > 0:
                ent.append((row - 1, -1.0))
            if j < g - 1:
                ent.append((row + 1, -1.0))
            for c, v in sorted(ent):
                rows.append(row)
                cols.append(c)
                data.append(v)
    return (
        jnp.asarray(np.array(data, dtype=dtype)),
        jnp.asarray(np.array(cols, dtype=np.int32)),
        jnp.asarray(np.array(rows, dtype=np.int32)),
        n,
    )


def test_poisson2d_nnz_matches_aot_formula():
    from compile.aot import poisson2d_nnz

    for g in (4, 8, 16, 32):
        data, _, _, _ = _poisson2d(g)
        assert data.shape[0] == poisson2d_nnz(g)


@pytest.mark.parametrize("n", [8, 64, 257])
def test_cg_vector_update_matches_ref(n):
    rng = np.random.default_rng(n)
    x, r, p, ap = (jnp.asarray(rng.standard_normal(n), jnp.float32) for _ in range(4))
    rr = jnp.asarray([float(jnp.sum(r * r))], jnp.float32)
    got = cg_step.cg_vector_update(x, r, p, ap, rr)
    want = ref.cg_vector_update(x, r, p, ap, rr)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(min_value=2, max_value=128),
    seed=st.integers(min_value=0, max_value=10_000),
)
def test_cg_vector_update_property(n, seed):
    rng = np.random.default_rng(seed)
    x, r, p = (jnp.asarray(rng.standard_normal(n), jnp.float64) for _ in range(3))
    ap = jnp.asarray(rng.standard_normal(n) + 2.0, jnp.float64)  # keep p.ap != 0
    rr = jnp.asarray([float(jnp.sum(r * r)) + 1e-3], jnp.float64)
    got = cg_step.cg_vector_update(x, r, p, ap, rr)
    want = ref.cg_vector_update(x, r, p, ap, rr)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=1e-10, atol=1e-10)


def test_spmv_matches_dense():
    g = 8
    data, cols, rows, n = _poisson2d(g)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal(n), jnp.float32)
    got = model.spmv(data, cols, rows, x, n)
    dense = np.zeros((n, n), dtype=np.float32)
    dense[np.asarray(rows), np.asarray(cols)] = np.asarray(data)
    np.testing.assert_allclose(got, dense @ np.asarray(x), rtol=1e-5, atol=1e-5)


def _run_cg(step_fn, data, cols, rows, b, n, iters):
    x = jnp.zeros((n,), jnp.float32)
    r = b
    p = b
    rr = jnp.sum(r * r).reshape((1,))
    for _ in range(iters):
        x, r, p, rr = step_fn(data, cols, rows, x, r, p, rr)
    return x, rr


def test_cg_step_graph_converges_on_poisson():
    g = 8
    data, cols, rows, n = _poisson2d(g)
    rng = np.random.default_rng(7)
    b = jnp.asarray(rng.standard_normal(n), jnp.float32)
    fn, _ = model.cg_step_fn(n, int(data.shape[0]))
    # NOTE: exact convergence (rr -> 0) makes alpha = 0/0 = nan, so stop
    # well before the n-iteration exact-arithmetic bound (the rust driver
    # checks rr against a threshold each outer step for the same reason).
    x, rr = _run_cg(fn, data, cols, rows, b, n, 25)
    assert float(rr[0]) < 1e-4 * float(jnp.sum(b * b))


def test_cg_perks_equals_iterated_steps():
    """The fused k-iteration executable must equal k host-loop steps —
    the two execution models are numerically interchangeable."""
    g = 8
    data, cols, rows, n = _poisson2d(g)
    rng = np.random.default_rng(3)
    b = jnp.asarray(rng.standard_normal(n), jnp.float32)
    k = 6
    step_fn, _ = model.cg_step_fn(n, int(data.shape[0]))
    perks_fn, _ = model.cg_perks_fn(n, int(data.shape[0]), k)

    x0 = jnp.zeros((n,), jnp.float32)
    rr0 = jnp.sum(b * b).reshape((1,))
    want = (x0, b, b, rr0)
    for _ in range(k):
        want = step_fn(data, cols, rows, *want)
    got = perks_fn(data, cols, rows, x0, b, b, rr0)
    for gg, ww in zip(got, want):
        np.testing.assert_allclose(gg, ww, rtol=2e-4, atol=2e-5)


def test_residual_fn_zero_for_exact_solution():
    g = 6
    data, cols, rows, n = _poisson2d(g)
    rng = np.random.default_rng(11)
    xstar = jnp.asarray(rng.standard_normal(n), jnp.float32)
    b = model.spmv(data, cols, rows, xstar, n)
    fn, _ = model.residual_fn(n, int(data.shape[0]))
    (res,) = fn(data, cols, rows, xstar, b)
    assert float(res[0]) < 1e-8
