"""Pallas 2D stencil kernels vs the pure-jnp oracle (ref.py).

hypothesis sweeps shapes, dtypes and benchmarks; fixed-seed numpy data.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import stencils
from compile.kernels import ref, stencil2d

BENCH_2D = stencils.names_2d()


def _domain(name, h, w, dtype, seed=0):
    r = stencils.spec(name).radius
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((h + 2 * r, w + 2 * r)), dtype=dtype)


@pytest.mark.parametrize("name", BENCH_2D)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_step_matches_ref(name, dtype):
    x = _domain(name, 24, 20, dtype)
    got = stencil2d.step(x, name)
    want = ref.stencil_step_2d(x, name)
    tol = 1e-6 if dtype == jnp.float32 else 1e-12
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


@pytest.mark.parametrize("name", BENCH_2D)
def test_step_preserves_boundary(name):
    x = _domain(name, 16, 16, jnp.float32)
    r = stencils.spec(name).radius
    got = np.asarray(stencil2d.step(x, name))
    xn = np.asarray(x)
    # Dirichlet ring untouched
    np.testing.assert_array_equal(got[:r, :], xn[:r, :])
    np.testing.assert_array_equal(got[-r:, :], xn[-r:, :])
    np.testing.assert_array_equal(got[:, :r], xn[:, :r])
    np.testing.assert_array_equal(got[:, -r:], xn[:, -r:])


@pytest.mark.parametrize("name", BENCH_2D)
@pytest.mark.parametrize("steps", [1, 2, 5])
def test_persistent_equals_iterated_step(name, steps):
    """The PERKS kernel (in-kernel time loop) must equal `steps` baseline
    invocations — the execution models are numerically interchangeable."""
    x = _domain(name, 16, 12, jnp.float32)
    got = stencil2d.persistent(x, name, steps)
    want = x
    for _ in range(steps):
        want = stencil2d.step(want, name)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("name", BENCH_2D)
@pytest.mark.parametrize("steps", [3])
def test_persistent_matches_ref_multi(name, steps):
    x = _domain(name, 12, 16, jnp.float64)
    got = stencil2d.persistent(x, name, steps)
    want = ref.stencil_multi_step(x, name, steps)
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("name", ["2d5pt", "2d9pt", "2ds9pt"])
@pytest.mark.parametrize("tile", [4, 8])
def test_tiled_step_matches_ref_interior(name, tile):
    r = stencils.spec(name).radius
    x = _domain(name, 16, 24, jnp.float32)
    got = stencil2d.tiled_step(x, name, tile)
    want = ref.stencil_step_2d(x, name)[r:-r, r:-r]
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    name=st.sampled_from(BENCH_2D),
    h=st.integers(min_value=1, max_value=20),
    w=st.integers(min_value=1, max_value=20),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_step_matches_ref_property(name, h, w, seed):
    x = _domain(name, h, w, jnp.float32, seed)
    got = stencil2d.step(x, name)
    want = ref.stencil_step_2d(x, name)
    np.testing.assert_allclose(got, want, rtol=2e-6, atol=2e-6)


@settings(max_examples=10, deadline=None)
@given(
    name=st.sampled_from(["2d5pt", "2d9pt", "2d25pt"]),
    steps=st.integers(min_value=1, max_value=8),
)
def test_persistent_property(name, steps):
    x = _domain(name, 10, 10, jnp.float64, seed=steps)
    got = stencil2d.persistent(x, name, steps)
    want = ref.stencil_multi_step(x, name, steps)
    np.testing.assert_allclose(got, want, rtol=1e-11, atol=1e-11)


def test_jacobi_weights_contract_to_fixed_point():
    """Convex weights => repeated application converges toward constant
    fields' fixed point: a constant domain is exactly invariant."""
    x = jnp.full((18, 18), 3.25, dtype=jnp.float32)
    got = stencil2d.persistent(x, "2d5pt", 10)
    np.testing.assert_allclose(got, x, rtol=1e-6)
