//! Domain example: solve a 2D Poisson problem and three Table V analogs
//! with the CPU CG backend of `perks::session`, advancing each solver in
//! chunks until converged — the paper's Fig 7 workload at library level,
//! without the PJRT path (see e2e_full_stack for that).
//!
//! ```bash
//! cargo run --release --example cg_poisson
//! ```

use perks::session::{Backend, ExecMode, Session, SessionBuilder};
use perks::sparse::{datasets, gen};
use perks::util::fmt::{secs, Table};

/// Advance `session` in 32-iteration slabs until rr <= tol^2 * rr0.
fn solve(session: &mut Session, rr0: f64, tol: f64, max_iters: usize) -> perks::Result<usize> {
    session.prepare()?;
    let threshold = tol * tol * rr0;
    loop {
        let rep = session.report();
        let rr = rep.residual.expect("cg workloads report rr");
        if rr <= threshold || rep.steps >= max_iters {
            return Ok(rep.steps);
        }
        session.advance(32.min(max_iters - rep.steps))?;
    }
}

fn main() -> perks::Result<()> {
    println!("CG on synthetic SuiteSparse analogs (tol 1e-8), session API\n");
    let mut t = Table::new(&[
        "matrix",
        "rows",
        "nnz",
        "iters",
        "host-loop",
        "persistent",
        "speedup",
    ]);
    // a pure Poisson system plus three Table V analogs
    let mut cases: Vec<(String, perks::sparse::Csr)> =
        vec![("poisson2d 64".into(), gen::poisson2d(64))];
    for code in ["D1", "D3", "D8"] {
        let ds = datasets::by_code(code).unwrap();
        cases.push((format!("{} ({})", code, ds.name), ds.generate(8)?));
    }
    for (name, a) in cases {
        let b = gen::rhs(a.n_rows, 42);
        let rr0: f64 = b.iter().map(|v| v * v).sum();
        let mut stats = Vec::new();
        for mode in [ExecMode::HostLoop, ExecMode::Persistent] {
            let mut session = SessionBuilder::cg_system(a.clone(), b.clone())
                .parts(32)
                .backend(Backend::cpu(1))
                .mode(mode)
                .build()?;
            let iters = solve(&mut session, rr0, 1e-8, 3000)?;
            let rep = session.report();
            let rr = rep.residual.unwrap();
            assert!(rr <= 1e-16 * rr0, "{name}: CG must converge (rr {rr:.3e})");
            // verify the actual solution, not just the recurrence
            let err = session.true_residual()?.unwrap().sqrt();
            assert!(err < 1e-5 * (rr0.sqrt() + 1.0), "{name}: true residual {err}");
            stats.push((iters, rep.wall_seconds));
        }
        let (hi, hw) = stats[0];
        let (pi, pw) = stats[1];
        assert_eq!(hi, pi, "{name}: models must take identical iterations");
        t.row(&[
            name,
            a.n_rows.to_string(),
            a.nnz().to_string(),
            pi.to_string(),
            secs(hw),
            secs(pw),
            format!("{:.2}x", hw / pw),
        ]);
    }
    print!("{}", t.render());
    println!("\npersistent CG caches the merge-path plan once and fuses the vector");
    println!("passes (2 instead of 5 sweeps/iter) — the paper's CG caching policies.");
    Ok(())
}
