//! Domain example: solve a 2D Poisson problem with the rust-native CG
//! solver (merge-based SpMV substrate) under both execution models, on a
//! sweep of Table V dataset analogs — the paper's Fig 7 workload at
//! library level, without the PJRT path (see e2e_full_stack for that).
//!
//! ```bash
//! cargo run --release --example cg_poisson
//! ```

use perks::cg::{solve_host_loop, solve_persistent, CgOptions};
use perks::sparse::{datasets, gen};
use perks::util::fmt::{secs, Table};

fn main() -> perks::Result<()> {
    println!("CG on synthetic SuiteSparse analogs (tol 1e-8)\n");
    let mut t = Table::new(&[
        "matrix",
        "rows",
        "nnz",
        "iters",
        "host-loop",
        "persistent",
        "speedup",
        "plan searches h/p",
    ]);
    // a pure Poisson system plus three Table V analogs
    let mut cases: Vec<(String, perks::sparse::Csr)> =
        vec![("poisson2d 64".into(), gen::poisson2d(64))];
    for code in ["D1", "D3", "D8"] {
        let ds = datasets::by_code(code).unwrap();
        cases.push((format!("{} ({})", code, ds.name), ds.generate(8)?));
    }
    for (name, a) in cases {
        let b = gen::rhs(a.n_rows, 42);
        let opts = CgOptions { max_iters: 3000, tol: 1e-8, parts: 32, threaded: false };
        let h = solve_host_loop(&a, &b, &opts)?;
        let p = solve_persistent(&a, &b, &opts)?;
        assert!(h.converged && p.converged, "{name}: CG must converge");
        assert_eq!(h.iters, p.iters, "{name}: models must take identical iterations");
        // verify the actual solution
        let mut ax = vec![0.0; a.n_rows];
        a.spmv_gold(&p.x, &mut ax);
        let err: f64 = ax.iter().zip(&b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>().sqrt();
        assert!(err < 1e-5 * (h.rr0.sqrt() + 1.0), "{name}: true residual {err}");
        t.row(&[
            name,
            a.n_rows.to_string(),
            a.nnz().to_string(),
            p.iters.to_string(),
            secs(h.wall_seconds),
            secs(p.wall_seconds),
            format!("{:.2}x", h.wall_seconds / p.wall_seconds),
            format!("{}/{}", h.plan_searches, p.plan_searches),
        ]);
    }
    print!("{}", t.render());
    println!("\npersistent CG caches the merge-path plan once and fuses the vector");
    println!("passes (2 instead of 5 sweeps/iter) — the paper's CG caching policies.");
    Ok(())
}
