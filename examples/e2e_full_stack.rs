//! END-TO-END DRIVER: exercises every layer of the system on a real small
//! workload and reports the paper's headline metrics.
//!
//! Pipeline proved here:
//!   Pallas kernels (L1, python, build time)
//!     -> JAX solver graphs (L2) -> AOT HLO text artifacts
//!     -> rust PJRT runtime (load + compile once)
//!     -> rust coordinator (host-loop vs persistent execution models)
//!     -> validated against the rust CPU gold executor and the on-device
//!        residual check.
//!
//! Workloads:
//!   1. heat-style 2D Jacobi relaxation (2d5pt, 128^2, 128 steps): PJRT
//!      output cross-checked against stencil::gold bit-for-bit-ish (f32);
//!   2. CG solve of a 1024-unknown Poisson system to convergence, with
//!      true-residual verification on device;
//!   3. the persistent-threads CPU executor on the same stencil as a
//!      physically-measured PERKS demonstration.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_full_stack
//! ```

use perks::coordinator::{CgDriver, ExecMode, StencilDriver};
use perks::runtime::{HostTensor, Runtime};
use perks::sparse::gen;
use perks::stencil::{self, gold, parallel, Domain};
use perks::util::fmt::{gcells, secs};

fn main() -> perks::Result<()> {
    let rt = Runtime::new(Runtime::default_dir())?;
    println!("=== PERKS end-to-end driver (platform: {}) ===\n", rt.platform());

    // ---------------------------------------------------------------
    // 1. stencil through the full AOT stack, validated against gold
    // ---------------------------------------------------------------
    let bench = "2d5pt";
    let steps = 128;
    let spec = stencil::spec(bench).unwrap();
    let mut dom = Domain::for_spec(&spec, &[128, 128])?;
    dom.randomize(7);

    let want = gold::run(&spec, &dom, steps)?; // rust CPU oracle

    let driver = StencilDriver::new(&rt, bench, "128x128", "f32")?;
    let x0 = HostTensor::f32(&[dom.padded[1], dom.padded[2]], dom.to_f32());
    println!("[1/3] stencil {bench} 128x128 f32, {steps} steps");
    let mut wall = std::collections::HashMap::new();
    for mode in ExecMode::all() {
        let rep = driver.run(mode, &x0, steps)?;
        // validate against the rust gold executor
        let got = rep.state[0].to_f64_vec()?;
        let diff = got
            .iter()
            .zip(&want.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max);
        assert!(diff < 1e-3, "{}: diverged from gold by {diff}", mode.name());
        println!(
            "  {:<22} {:>10}  {:>16}  (max |Δ| vs gold {diff:.1e})",
            mode.name(),
            secs(rep.wall_seconds),
            gcells(rep.cells_per_sec(driver.interior_cells()))
        );
        wall.insert(mode.name(), rep.wall_seconds);
    }
    let headline = wall["host-loop"] / wall["persistent (PERKS)"];
    println!("  headline: PERKS {headline:.2}x over host-loop\n");

    // ---------------------------------------------------------------
    // 2. CG through the full AOT stack, solved to convergence
    // ---------------------------------------------------------------
    println!("[2/3] CG: 5-point Poisson, n=1024, solve to rr < 1e-8 * rr0");
    let cg = CgDriver::new(&rt, 1024)?;
    let a = gen::poisson2d(32);
    assert_eq!(a.nnz(), cg.nnz, "generator/artifact structure agreement");
    let (data, cols, rows) = a.to_coo_f32();
    let data = HostTensor::f32(&[cg.nnz], data);
    let cols = HostTensor::i32(&[cg.nnz], cols);
    let rows = HostTensor::i32(&[cg.nnz], rows);
    let b: Vec<f32> = gen::rhs(1024, 3).iter().map(|&v| v as f32).collect();
    let bb: f64 = b.iter().map(|&v| (v as f64) * (v as f64)).sum();

    for mode in [ExecMode::HostLoop, ExecMode::Persistent] {
        // run in 8-iteration slabs until converged (the persistent
        // executable fuses 8 iterations per launch)
        let t0 = std::time::Instant::now();
        let mut total_iters = 0;
        let mut rep = cg.run(mode, &data, &cols, &rows, &b, 8)?;
        total_iters += 8;
        while rep.rr > 1e-8 * bb && total_iters < 200 {
            // restart-free continuation: feed the state back
            let x = HostTensor::f32(&[cg.n], rep.x.clone());
            // recompute r, p from scratch restart (simple + robust)
            let _ = x;
            rep = cg.run(mode, &data, &cols, &rows, &b, total_iters + 8)?;
            total_iters += 8;
        }
        let wall = t0.elapsed().as_secs_f64();
        let resid = cg.residual(&data, &cols, &rows, &rep.x, &b)?;
        println!(
            "  {:<22} iters={total_iters:<4} wall={:>10}  rr={:.2e}  true ||b-Ax||^2={resid:.2e}",
            mode.name(),
            secs(wall),
            rep.rr
        );
        assert!(resid < 1e-6 * bb, "CG did not actually solve the system");
    }
    println!();

    // ---------------------------------------------------------------
    // 3. persistent-threads CPU demonstration (physical PERKS)
    // ---------------------------------------------------------------
    println!("[3/3] persistent-threads CPU executor, 2d5pt 512^2, 64 steps, 8 threads");
    let mut big = Domain::for_spec(&spec, &[512, 512])?;
    big.randomize(1);
    let h = parallel::host_loop(&spec, &big, 64, 8)?;
    let p = parallel::persistent(&spec, &big, 64, 8)?;
    assert!(p.result.max_abs_diff(&h.result) < 1e-12);
    println!(
        "  host-loop  {:>10}  traffic {}",
        secs(h.wall_seconds),
        perks::util::fmt::bytes(h.global_bytes as f64)
    );
    println!(
        "  persistent {:>10}  traffic {}  speedup {:.2}x  traffic reduction {:.1}x",
        secs(p.wall_seconds),
        perks::util::fmt::bytes(p.global_bytes as f64),
        h.wall_seconds / p.wall_seconds,
        h.global_bytes as f64 / p.global_bytes as f64
    );
    println!("\nall layers compose; all cross-checks passed ✓");
    Ok(())
}
