//! END-TO-END DRIVER: exercises every layer of the system on a real small
//! workload and reports the paper's headline metrics — all through the
//! unified `perks::session` API.
//!
//! Pipeline proved here:
//!   Pallas kernels (L1, python, build time)
//!     -> JAX solver graphs (L2) -> AOT HLO text artifacts
//!     -> rust PJRT runtime (load + compile once)
//!     -> rust session layer (host-loop vs persistent, PJRT + CPU
//!        backends behind one Solver trait)
//!     -> validated against the rust CPU gold executor and the on-device
//!        residual check.
//!
//! Workloads:
//!   1. heat-style 2D Jacobi relaxation (2d5pt, 128^2, 128 steps): PJRT
//!      output cross-checked against stencil::gold bit-for-bit-ish (f32);
//!   2. CG solve of a 1024-unknown Poisson system to convergence, with
//!      true-residual verification on device;
//!   3. the persistent-threads CPU backend on the same stencil as a
//!      physically-measured PERKS demonstration.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_full_stack
//! ```

use std::rc::Rc;

use perks::runtime::Runtime;
use perks::session::{Backend, ExecMode, SessionBuilder};
use perks::stencil::{self, gold, Domain};
use perks::util::fmt::{gcells, secs};

fn main() -> perks::Result<()> {
    let rt = Rc::new(Runtime::new(Runtime::default_dir())?);
    println!("=== PERKS end-to-end driver (platform: {}) ===\n", rt.platform());

    // ---------------------------------------------------------------
    // 1. stencil through the full AOT stack, validated against gold
    // ---------------------------------------------------------------
    let bench = "2d5pt";
    let seed = 7;

    // build all sessions first: one chunk-aligned step count serves every
    // mode AND the gold oracle, so the states stay comparable
    let mut sessions = Vec::new();
    // pipelined is CG-only — the stencil sweep runs the other three models
    for mode in ExecMode::all().into_iter().filter(|m| *m != ExecMode::Pipelined) {
        let session = SessionBuilder::stencil(bench, "128x128", "f32")
            .backend(Backend::pjrt(rt.clone()))
            .mode(mode)
            .seed(seed)
            .build()?;
        sessions.push(session);
    }
    let steps = sessions.iter().map(|s| s.aligned_steps(128)).max().unwrap();

    let spec = stencil::spec(bench).unwrap();
    let mut dom = Domain::for_spec(&spec, &[128, 128])?;
    dom.randomize(seed);
    let want = gold::run(&spec, &dom, steps)?; // rust CPU oracle

    println!("[1/3] stencil {bench} 128x128 f32, {steps} steps");
    let mut wall = std::collections::HashMap::new();
    for session in &mut sessions {
        let mode = session.mode();
        let rep = session.run(steps)?;
        // validate against the rust gold executor
        let got = session.state_f64()?;
        let diff = got
            .iter()
            .zip(&want.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max);
        assert!(diff < 1e-3, "{}: diverged from gold by {diff}", mode.name());
        println!(
            "  {:<22} {:>10}  {:>16}  (max |Δ| vs gold {diff:.1e})",
            mode.name(),
            secs(rep.wall_seconds),
            gcells(rep.fom)
        );
        wall.insert(mode.name(), rep.wall_seconds);
    }
    let headline = wall["host-loop"] / wall["persistent (PERKS)"];
    println!("  headline: PERKS {headline:.2}x over host-loop\n");

    // ---------------------------------------------------------------
    // 2. CG through the full AOT stack, solved to convergence by
    //    advancing the session in fused-chunk slabs
    // ---------------------------------------------------------------
    println!("[2/3] CG: 5-point Poisson, n=1024, solve to rr < 1e-8 * rr0");
    for mode in [ExecMode::HostLoop, ExecMode::Persistent] {
        let mut session = SessionBuilder::cg(1024)
            .backend(Backend::pjrt(rt.clone()))
            .mode(mode)
            .seed(3)
            .build()?;
        let chunk = session.aligned_steps(8);
        session.prepare()?;
        let rr0 = session.report().residual.expect("cg reports rr");
        while session.report().residual.unwrap() > 1e-8 * rr0 && session.report().steps < 200 {
            session.advance(chunk)?;
        }
        let rep = session.report();
        let resid = session.true_residual()?.unwrap();
        println!(
            "  {:<22} iters={:<4} wall={:>10}  rr={:.2e}  true ||b-Ax||^2={resid:.2e}",
            mode.name(),
            rep.steps,
            secs(rep.wall_seconds),
            rep.residual.unwrap()
        );
        assert!(resid < 1e-6 * rr0, "CG did not actually solve the system");
    }
    println!();

    // ---------------------------------------------------------------
    // 3. persistent-threads CPU backend (physical PERKS), same API
    // ---------------------------------------------------------------
    println!("[3/3] CPU persistent-threads backend, 2d5pt 512^2, 64 steps, 8 threads");
    let mut reports = Vec::new();
    let mut states = Vec::new();
    for mode in [ExecMode::HostLoop, ExecMode::Persistent] {
        let mut session = SessionBuilder::stencil("2d5pt", "512x512", "f64")
            .backend(Backend::cpu(8))
            .mode(mode)
            .seed(1)
            .build()?;
        let rep = session.run(64)?;
        states.push(session.state_f64()?);
        reports.push(rep);
    }
    let diff = states[0]
        .iter()
        .zip(&states[1])
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f64::max);
    assert!(diff < 1e-12);
    let (h, p) = (&reports[0], &reports[1]);
    println!(
        "  host-loop  {:>10}  traffic {}",
        secs(h.wall_seconds),
        perks::util::fmt::bytes(h.host_bytes as f64)
    );
    println!(
        "  persistent {:>10}  traffic {}  speedup {:.2}x  traffic reduction {:.1}x",
        secs(p.wall_seconds),
        perks::util::fmt::bytes(p.host_bytes as f64),
        h.wall_seconds / p.wall_seconds,
        h.host_bytes as f64 / p.host_bytes as f64
    );
    println!("\nall layers compose; all cross-checks passed ✓");
    Ok(())
}
