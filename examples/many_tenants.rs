//! Quickstart: serving many concurrent solver sessions from one
//! multi-tenant `SolverFarm`.
//!
//! One farm spawns its OS workers exactly once; every session —
//! here three stencil tenants at mixed temporal degrees plus a CG
//! tenant — is *admitted* onto those resident workers (zero thread
//! spawns per admission, asserted below), enqueues its advances into the
//! farm's submission queue, and keeps its slab/vector state resident
//! between commands. Results are bit-identical to solo-pool sessions,
//! which the example verifies before printing the farm's
//! throughput/queue-latency/fairness metrics.
//!
//! The second half drives tenants through the *async submission plane*:
//! one `LocalExecutor` on one OS thread multiplexes dozens of in-flight
//! sessions via completion futures, and each tenant submits its whole
//! schedule as a single batched `CommandGraph` — one scheduler-lock
//! acquisition per tenant, asserted from the farm's plane counters.
//!
//! The third section injects deterministic faults (a worker panic and
//! NaN poisoning) into one tenant of a "chaos" farm and shows the
//! supervisor recovering both from epoch-boundary checkpoints to a
//! bit-identical final state, while an unconfigured peer tenant runs
//! undisturbed.
//!
//! The final section survives *process death*: the example re-executes
//! itself as a child whose multi-tenant farm (a stencil session and a
//! CG session, both built with `SessionBuilder::durable`) is killed by
//! `FaultKind::Kill` — a hard `process::abort` mid-`advance`. The
//! parent then rebuilds both tenants from the snapshot directory alone
//! (the frames are self-describing), finishes the interrupted work, and
//! verifies both final states are bit-identical to uninterrupted runs.
//! See `docs/RECOVERY.md` and the `perks_recover` binary for the same
//! drill as an operator workflow.
//!
//! ```bash
//! cargo run --release --example many_tenants            # full demo
//! cargo run --release --example many_tenants -- --quick # CI smoke
//! ```

use std::path::Path;
use std::sync::Arc;

use perks::runtime::farm::SolverFarm;
use perks::runtime::plane::{CommandGraph, LocalExecutor};
use perks::runtime::{FaultPlan, FaultSpec, ResilienceConfig, SnapshotStore, WorkloadMeta};
use perks::session::{Backend, ExecMode, SessionBuilder};
use perks::sparse::gen;
use perks::spmv::merge::MergePlan;
use perks::stencil::{self, Domain};
use perks::util::counters;
use perks::util::fmt::Table;

// ---- durable-restart drill parameters (shared by parent and child) ----
const DUR_INTERIOR: &str = "20x20";
const DUR_BT: usize = 2;
const DUR_SEED: u64 = 21;
const DUR_S1: usize = 8; // clean first command (steps)
const DUR_S2: usize = 8; // the command the kill interrupts
const DUR_CG_N: usize = 256;
const DUR_CG_SEED: u64 = 5;
const DUR_CG_S1: usize = 8; // CG iterations committed before the crash
const DUR_CG_S2: usize = 8; // CG iterations finished by the parent
const DUR_CADENCE: u64 = 2;
const DUR_KILL_EPOCH: u64 = 6; // lifetime epoch inside stencil command 2

fn main() -> perks::Result<()> {
    // Hidden child mode for the durable-restart drill: the parent below
    // re-executes this binary with `--crash-child <dir>`, and this run
    // dies by a hard abort mid-advance. Nothing after this block runs.
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.first().map(String::as_str) == Some("--crash-child") {
        let dir = argv.get(1).expect("--crash-child needs a snapshot directory");
        return durable_crash_child(Path::new(dir));
    }

    let quick = std::env::args().any(|a| a == "--quick");
    let steps = if quick { 8 } else { 48 };
    let cg_iters = if quick { 10 } else { 40 };
    let workers = if quick { 2 } else { 8 };

    // one farm for the whole process: the only thread creation here
    let farm = SolverFarm::spawn(workers)?;
    let spawns_before = counters::thread_spawns();

    let stencil = |interior: &str, seed: u64, bt: usize| {
        SessionBuilder::stencil("2d5pt", interior, "f64")
            .temporal(bt)
            .backend(Backend::cpu(2))
            .mode(ExecMode::Persistent)
            .seed(seed)
            .farm(&farm)
            .build()
    };
    let mut tenants = vec![
        ("2d5pt 32x32 bt=1", stencil("32x32", 1, 1)?),
        ("2d5pt 48x32 bt=2", stencil("48x32", 2, 2)?),
        ("2d5pt 24x64 bt=4", stencil("24x64", 3, 4)?),
    ];
    let mut cg = SessionBuilder::cg(256)
        .backend(Backend::cpu(2))
        .mode(ExecMode::Persistent)
        .seed(4)
        .farm(&farm)
        .build()?;

    // drive everything: resumed advances on every tenant, interleaved
    for _ in 0..2 {
        for (_, s) in tenants.iter_mut() {
            s.advance(steps / 2)?;
        }
        cg.advance(cg_iters / 2)?;
    }
    assert_eq!(
        counters::thread_spawns(),
        spawns_before,
        "admissions and advances must not spawn threads"
    );

    // bit-identity spot check: tenant 0 vs its solo-pool build
    let mut solo = SessionBuilder::stencil("2d5pt", "32x32", "f64")
        .backend(Backend::cpu(2))
        .mode(ExecMode::Persistent)
        .seed(1)
        .build()?;
    solo.advance(steps)?;
    assert_eq!(
        tenants[0].1.state_f64()?,
        solo.state_f64()?,
        "farm tenant diverged from its solo run"
    );

    // ---- the async plane: one front-end thread, many in-flight tenants ----
    //
    // The blocking `advance` calls above are wrappers over completion
    // futures; here we use the futures directly. A single LocalExecutor
    // multiplexes every async tenant, and each tenant submits its whole
    // schedule as ONE batched command graph — one scheduler-lock
    // acquisition per tenant, asserted from the farm's counters below.
    let async_tenants: usize = if quick { 8 } else { 64 };
    let spec = stencil::spec("2d5pt").expect("built-in benchmark");
    let graph = CommandGraph::schedule(steps, (steps / 4).max(1), None)?;
    let handle = farm.handle();
    let m0 = farm.metrics();
    let mut async_sessions = Vec::with_capacity(async_tenants);
    for t in 0..async_tenants {
        let mut d = Domain::for_spec(&spec, &[24, 24])?;
        d.randomize(1000 + t as u64);
        async_sessions.push(handle.admit_stencil(&spec, &d, 1, 1)?);
    }
    let ex = LocalExecutor::new();
    let state0 = ex
        .run(async {
            let mut joins = Vec::with_capacity(async_tenants);
            for (t, mut s) in async_sessions.into_iter().enumerate() {
                let graph = graph.clone();
                joins.push(ex.spawn(async move {
                    s.advance_graph_async(&graph).await?;
                    if t == 0 { s.state().map(Some) } else { Ok(None) }
                }));
            }
            let mut first = None;
            for j in joins {
                if let Some(st) = j.await? {
                    first = Some(st);
                }
            }
            Ok::<_, perks::Error>(first)
        })?
        .expect("tenant 0 returns its state");
    let m1 = farm.metrics();
    assert_eq!(
        m1.plane_batches - m0.plane_batches,
        async_tenants as u64,
        "one graph batch per async tenant"
    );
    assert_eq!(
        m1.sched_lock_acquisitions - m0.sched_lock_acquisitions,
        async_tenants as u64,
        "graph segments must chain without re-acquiring the scheduler lock"
    );
    // and the plane is bit-invisible too: tenant 0 vs its solo-pool run
    let mut d0 = Domain::for_spec(&spec, &[24, 24])?;
    d0.randomize(1000);
    let mut solo_async = stencil::pool::StencilPool::spawn(&spec, &d0, 1)?;
    solo_async.run(steps, None)?;
    assert_eq!(state0, solo_async.state(), "async-plane tenant diverged from solo run");

    // ---- supervised recovery: inject faults, replay from checkpoints ----
    //
    // A separate farm gets a deterministic fault plan: tenant 0 is hit
    // by a worker panic at epoch 2 and NaN poisoning at epoch 5. With a
    // retry policy + checkpoint cadence configured, both faults are
    // recovered by replaying from the last epoch-boundary checkpoint —
    // bit-identically, which we verify against the clean gold run. The
    // unconfigured peer tenant never notices. (`PERKS_FAULT_PLAN` can
    // inject the same way into any farm with zero code.)
    let chaos = SolverFarm::spawn(2)?;
    chaos.install_faults(
        FaultPlan::new()
            .inject(FaultSpec::panic_at(2).tenant(0))
            .inject(FaultSpec::nan_at(5).tenant(0)),
    );
    let fsteps = 10;
    let mut dv = Domain::for_spec(&spec, &[20, 20])?;
    dv.randomize(77);
    let want = stencil::gold::run(&spec, &dv, fsteps)?.data;
    let ch = chaos.handle();
    let mut victim = ch.admit_stencil(&spec, &dv, 2, 1)?;
    victim.configure_resilience(ResilienceConfig::recovering(3).every(4))?;
    let mut peer = ch.admit_stencil(&spec, &dv, 2, 1)?;
    // a negative tolerance is never met: it just keeps the residual fold
    // live, which is where NaN poisoning gets detected
    let vrun = victim.advance(fsteps, Some(-1.0))?;
    let prun = peer.advance(fsteps, None)?;
    assert_eq!(victim.state()?, want, "recovered tenant diverged from gold");
    assert_eq!(peer.state()?, want, "peer tenant was disturbed by the faults");
    assert_eq!(prun.recoveries, 0);
    let cm = chaos.metrics();
    println!(
        "chaos farm: {} faults injected -> {} recoveries, {} epochs replayed, \
         {:.1} KiB checkpoint traffic; final state bit-identical to the clean run\n",
        cm.faults_injected,
        vrun.recoveries,
        vrun.replayed_epochs,
        vrun.checkpoint_bytes as f64 / 1024.0
    );

    // ---- durable restart: survive process death, resume bit-identical ----
    //
    // Everything above recovers from faults *inside* a live process.
    // This section kills the whole process: a child re-execution of this
    // binary runs a stencil tenant and a CG tenant with
    // `SessionBuilder::durable` (every cadence checkpoint also committed
    // crash-consistently to disk, off the scheduler lock) and dies by
    // `FaultKind::Kill` mid-advance. The parent restores both tenants
    // from the directory the corpse left behind and finishes their work.
    durable_restart_demo()?;

    println!("{} tenants served by {} resident workers\n", tenants.len() + 1, workers);
    let mut t = Table::new(&["tenant", "steps", "wall s", "queue wait s", "launches"]);
    for (name, s) in tenants.iter() {
        let rep = s.report();
        t.row(&[
            name.to_string(),
            rep.steps.to_string(),
            format!("{:.6}", rep.wall_seconds),
            format!("{:.6}", rep.queue_wait_seconds.unwrap_or(0.0)),
            rep.invocations.to_string(),
        ]);
    }
    let rep = cg.report();
    t.row(&[
        "cg poisson 256".to_string(),
        rep.steps.to_string(),
        format!("{:.6}", rep.wall_seconds),
        format!("{:.6}", rep.queue_wait_seconds.unwrap_or(0.0)),
        rep.invocations.to_string(),
    ]);
    print!("{}", t.render());

    let m = farm.metrics();
    println!(
        "\nfarm: {} admissions, {} commands, {} tasks, {} epochs on {} workers ({} spawns total)",
        m.admissions, m.commands, m.tasks, m.epochs, m.workers, m.threads_spawned
    );
    println!(
        "queue wait p50/p99/max: {:.3}/{:.3}/{:.3} ms   fairness (max/mean): {:.2}",
        m.queue_wait_p50 * 1e3,
        m.queue_wait_p99 * 1e3,
        m.queue_wait_max * 1e3,
        m.fairness()
    );
    println!(
        "plane: {} batches / {} scheduler locks (1:1), {} sheds, {} timeouts, peak {} in flight",
        m.plane_batches,
        m.sched_lock_acquisitions,
        m.plane_sheds,
        m.plane_timeouts,
        m.plane_inflight_peak
    );
    println!(
        "async section: {async_tenants} tenants multiplexed on ONE front-end thread,\n\
         each schedule one batched command graph (one lock acquisition per tenant)."
    );
    println!("\nevery tenant's iterates are bit-identical to its solo-pool session;");
    println!("the farm batches small solves onto one resident worker set instead of");
    println!("building (and tearing down) a pool per session.");
    Ok(())
}

/// The child half of the durable-restart drill: a two-tenant durable
/// farm (stencil `t0`, CG `t1`) with a pinned kill fault. Runs one clean
/// command per tenant, waits until both have a durable frame on disk
/// (the write-out is off the scheduler lock), then issues the command
/// the kill aborts. This function never returns `Ok`.
fn durable_crash_child(dir: &Path) -> perks::Result<()> {
    let farm = SolverFarm::spawn(2)?;
    farm.install_faults(FaultPlan::new().inject(FaultSpec::kill_at(DUR_KILL_EPOCH).tenant(0)));
    let mut st = SessionBuilder::stencil("2d5pt", DUR_INTERIOR, "f64")
        .temporal(DUR_BT)
        .backend(Backend::cpu(2))
        .mode(ExecMode::Persistent)
        .seed(DUR_SEED)
        .farm(&farm)
        .checkpoint_every(DUR_CADENCE)
        .durable(dir)
        .build()?;
    let mut cg = SessionBuilder::cg(DUR_CG_N)
        .backend(Backend::cpu(2))
        .mode(ExecMode::Persistent)
        .seed(DUR_CG_SEED)
        .farm(&farm)
        .checkpoint_every(DUR_CADENCE)
        .durable(dir)
        .build()?;
    st.advance(DUR_S1)?;
    cg.advance(DUR_CG_S1)?;
    let store = SnapshotStore::open(dir)?;
    let t0 = std::time::Instant::now();
    while !["t0", "t1"]
        .iter()
        .all(|t| store.entries(t).map(|e| !e.is_empty()).unwrap_or(false))
    {
        if t0.elapsed() > std::time::Duration::from_secs(10) {
            return Err(perks::Error::Snapshot(
                "no durable frames appeared within 10s of the clean commands".into(),
            ));
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    st.advance(DUR_S2)?; // FaultKind::Kill aborts the process here
    Err(perks::Error::Solver("crash child survived its kill fault".into()))
}

/// The parent half: compute uninterrupted references, crash a child,
/// rebuild both tenants from the snapshot directory alone, finish their
/// interrupted work, and require the reference bits.
fn durable_restart_demo() -> perks::Result<()> {
    // references: the same two sessions, never interrupted
    let clean = SolverFarm::spawn(2)?;
    clean.install_faults(FaultPlan::new());
    let mut st = SessionBuilder::stencil("2d5pt", DUR_INTERIOR, "f64")
        .temporal(DUR_BT)
        .backend(Backend::cpu(2))
        .mode(ExecMode::Persistent)
        .seed(DUR_SEED)
        .farm(&clean)
        .build()?;
    st.advance(DUR_S1 + DUR_S2)?;
    let want_st = st.state_f64()?;
    let mut cg = SessionBuilder::cg(DUR_CG_N)
        .backend(Backend::cpu(2))
        .mode(ExecMode::Persistent)
        .seed(DUR_CG_SEED)
        .farm(&clean)
        .build()?;
    cg.advance(DUR_CG_S1 + DUR_CG_S2)?;
    let want_cg = cg.state_f64()?;
    drop(st);
    drop(cg);

    // crash the child; it must die abnormally, not exit
    let dir =
        std::env::temp_dir().join(format!("perks-many-tenants-durable-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let exe = std::env::current_exe()
        .map_err(|e| perks::Error::Solver(format!("cannot locate own executable: {e}")))?;
    let status = std::process::Command::new(&exe)
        .arg("--crash-child")
        .arg(&dir)
        .status()
        .map_err(|e| perks::Error::Solver(format!("spawning crash child: {e}")))?;
    assert!(!status.success(), "the crash child must die by its kill fault");

    // restore both tenants from disk; the frames are self-describing
    let store = SnapshotStore::open(&dir)?;
    let farm = SolverFarm::spawn(2)?;
    farm.install_faults(FaultPlan::new());

    let r0 = store.restore("t0")?;
    let WorkloadMeta::Stencil { bench, dims, bt, shards } = &r0.meta else {
        return Err(perks::Error::Snapshot("t0 should be the stencil tenant".into()));
    };
    let sp = stencil::spec(bench).expect("persisted bench is built in");
    let d = Domain::for_spec(&sp, dims)?;
    let mut t = farm.handle().admit_stencil(&sp, &d, *shards, *bt)?;
    t.restore_from(&r0.checkpoint)?;
    let st_done = r0.checkpoint.epoch as usize * bt;
    t.advance(DUR_S1 + DUR_S2 - st_done, None)?;
    assert_eq!(t.state()?, want_st, "resumed stencil tenant diverged from the clean run");

    let r1 = store.restore("t1")?;
    let WorkloadMeta::Cg { n, shards } = &r1.meta else {
        return Err(perks::Error::Snapshot("t1 should be the CG tenant".into()));
    };
    let g = (*n as f64).sqrt().round() as usize;
    let a = Arc::new(gen::poisson2d(g));
    let plan = MergePlan::new(&a, *shards);
    let mut tcg = farm.handle().admit_cg(a, plan)?;
    let (mut x, mut r, mut p, rr, _) =
        r1.checkpoint.cg_state().expect("CG tenant persists a CG payload");
    let cg_done = r1.checkpoint.epoch as usize;
    let run = tcg.run(&mut x, &mut r, &mut p, rr, 0.0, DUR_CG_S1 + DUR_CG_S2 - cg_done)?;
    assert!(run.error.is_none(), "resumed CG run errored: {:?}", run.error);
    assert_eq!(x, want_cg, "resumed CG tenant diverged from the clean run");

    println!(
        "durable restart: child killed at epoch {DUR_KILL_EPOCH} -> stencil restored gen {} \
         (epoch {}, {} fallback(s)), CG restored gen {} (epoch {}) -> both resumed to the \
         clean run's exact bits\n",
        r0.generation, r0.checkpoint.epoch, r0.fallbacks, r1.generation, r1.checkpoint.epoch,
    );
    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}
