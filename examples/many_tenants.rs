//! Quickstart: serving many concurrent solver sessions from one
//! multi-tenant `SolverFarm`.
//!
//! One farm spawns its OS workers exactly once; every session —
//! here three stencil tenants at mixed temporal degrees plus a CG
//! tenant — is *admitted* onto those resident workers (zero thread
//! spawns per admission, asserted below), enqueues its advances into the
//! farm's submission queue, and keeps its slab/vector state resident
//! between commands. Results are bit-identical to solo-pool sessions,
//! which the example verifies before printing the farm's
//! throughput/queue-latency/fairness metrics.
//!
//! The second half drives tenants through the *async submission plane*:
//! one `LocalExecutor` on one OS thread multiplexes dozens of in-flight
//! sessions via completion futures, and each tenant submits its whole
//! schedule as a single batched `CommandGraph` — one scheduler-lock
//! acquisition per tenant, asserted from the farm's plane counters.
//!
//! The final section injects deterministic faults (a worker panic and
//! NaN poisoning) into one tenant of a "chaos" farm and shows the
//! supervisor recovering both from epoch-boundary checkpoints to a
//! bit-identical final state, while an unconfigured peer tenant runs
//! undisturbed.
//!
//! ```bash
//! cargo run --release --example many_tenants            # full demo
//! cargo run --release --example many_tenants -- --quick # CI smoke
//! ```

use perks::runtime::farm::SolverFarm;
use perks::runtime::plane::{CommandGraph, LocalExecutor};
use perks::runtime::{FaultPlan, FaultSpec, ResilienceConfig};
use perks::session::{Backend, ExecMode, SessionBuilder, Workload};
use perks::stencil::{self, Domain};
use perks::util::counters;
use perks::util::fmt::Table;

fn main() -> perks::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let steps = if quick { 8 } else { 48 };
    let cg_iters = if quick { 10 } else { 40 };
    let workers = if quick { 2 } else { 8 };

    // one farm for the whole process: the only thread creation here
    let farm = SolverFarm::spawn(workers)?;
    let spawns_before = counters::thread_spawns();

    let stencil = |interior: &str, seed: u64, bt: usize| {
        SessionBuilder::new()
            .backend(Backend::cpu(2))
            .workload(Workload::stencil("2d5pt", interior, "f64"))
            .mode(ExecMode::Persistent)
            .temporal(bt)
            .seed(seed)
            .farm(&farm)
            .build()
    };
    let mut tenants = vec![
        ("2d5pt 32x32 bt=1", stencil("32x32", 1, 1)?),
        ("2d5pt 48x32 bt=2", stencil("48x32", 2, 2)?),
        ("2d5pt 24x64 bt=4", stencil("24x64", 3, 4)?),
    ];
    let mut cg = SessionBuilder::new()
        .backend(Backend::cpu(2))
        .workload(Workload::cg(256))
        .mode(ExecMode::Persistent)
        .seed(4)
        .farm(&farm)
        .build()?;

    // drive everything: resumed advances on every tenant, interleaved
    for _ in 0..2 {
        for (_, s) in tenants.iter_mut() {
            s.advance(steps / 2)?;
        }
        cg.advance(cg_iters / 2)?;
    }
    assert_eq!(
        counters::thread_spawns(),
        spawns_before,
        "admissions and advances must not spawn threads"
    );

    // bit-identity spot check: tenant 0 vs its solo-pool build
    let mut solo = SessionBuilder::new()
        .backend(Backend::cpu(2))
        .workload(Workload::stencil("2d5pt", "32x32", "f64"))
        .mode(ExecMode::Persistent)
        .seed(1)
        .build()?;
    solo.advance(steps)?;
    assert_eq!(
        tenants[0].1.state_f64()?,
        solo.state_f64()?,
        "farm tenant diverged from its solo run"
    );

    // ---- the async plane: one front-end thread, many in-flight tenants ----
    //
    // The blocking `advance` calls above are wrappers over completion
    // futures; here we use the futures directly. A single LocalExecutor
    // multiplexes every async tenant, and each tenant submits its whole
    // schedule as ONE batched command graph — one scheduler-lock
    // acquisition per tenant, asserted from the farm's counters below.
    let async_tenants: usize = if quick { 8 } else { 64 };
    let spec = stencil::spec("2d5pt").expect("built-in benchmark");
    let graph = CommandGraph::schedule(steps, (steps / 4).max(1), None)?;
    let handle = farm.handle();
    let m0 = farm.metrics();
    let mut async_sessions = Vec::with_capacity(async_tenants);
    for t in 0..async_tenants {
        let mut d = Domain::for_spec(&spec, &[24, 24])?;
        d.randomize(1000 + t as u64);
        async_sessions.push(handle.admit_stencil(&spec, &d, 1, 1)?);
    }
    let ex = LocalExecutor::new();
    let state0 = ex
        .run(async {
            let mut joins = Vec::with_capacity(async_tenants);
            for (t, mut s) in async_sessions.into_iter().enumerate() {
                let graph = graph.clone();
                joins.push(ex.spawn(async move {
                    s.advance_graph_async(&graph).await?;
                    if t == 0 { s.state().map(Some) } else { Ok(None) }
                }));
            }
            let mut first = None;
            for j in joins {
                if let Some(st) = j.await? {
                    first = Some(st);
                }
            }
            Ok::<_, perks::Error>(first)
        })?
        .expect("tenant 0 returns its state");
    let m1 = farm.metrics();
    assert_eq!(
        m1.plane_batches - m0.plane_batches,
        async_tenants as u64,
        "one graph batch per async tenant"
    );
    assert_eq!(
        m1.sched_lock_acquisitions - m0.sched_lock_acquisitions,
        async_tenants as u64,
        "graph segments must chain without re-acquiring the scheduler lock"
    );
    // and the plane is bit-invisible too: tenant 0 vs its solo-pool run
    let mut d0 = Domain::for_spec(&spec, &[24, 24])?;
    d0.randomize(1000);
    let mut solo_async = stencil::pool::StencilPool::spawn(&spec, &d0, 1)?;
    solo_async.run(steps, None)?;
    assert_eq!(state0, solo_async.state(), "async-plane tenant diverged from solo run");

    // ---- supervised recovery: inject faults, replay from checkpoints ----
    //
    // A separate farm gets a deterministic fault plan: tenant 0 is hit
    // by a worker panic at epoch 2 and NaN poisoning at epoch 5. With a
    // retry policy + checkpoint cadence configured, both faults are
    // recovered by replaying from the last epoch-boundary checkpoint —
    // bit-identically, which we verify against the clean gold run. The
    // unconfigured peer tenant never notices. (`PERKS_FAULT_PLAN` can
    // inject the same way into any farm with zero code.)
    let chaos = SolverFarm::spawn(2)?;
    chaos.install_faults(
        FaultPlan::new()
            .inject(FaultSpec::panic_at(2).tenant(0))
            .inject(FaultSpec::nan_at(5).tenant(0)),
    );
    let fsteps = 10;
    let mut dv = Domain::for_spec(&spec, &[20, 20])?;
    dv.randomize(77);
    let want = stencil::gold::run(&spec, &dv, fsteps)?.data;
    let ch = chaos.handle();
    let mut victim = ch.admit_stencil(&spec, &dv, 2, 1)?;
    victim.configure_resilience(ResilienceConfig::recovering(3).every(4))?;
    let mut peer = ch.admit_stencil(&spec, &dv, 2, 1)?;
    // a negative tolerance is never met: it just keeps the residual fold
    // live, which is where NaN poisoning gets detected
    let vrun = victim.advance(fsteps, Some(-1.0))?;
    let prun = peer.advance(fsteps, None)?;
    assert_eq!(victim.state()?, want, "recovered tenant diverged from gold");
    assert_eq!(peer.state()?, want, "peer tenant was disturbed by the faults");
    assert_eq!(prun.recoveries, 0);
    let cm = chaos.metrics();
    println!(
        "chaos farm: {} faults injected -> {} recoveries, {} epochs replayed, \
         {:.1} KiB checkpoint traffic; final state bit-identical to the clean run\n",
        cm.faults_injected,
        vrun.recoveries,
        vrun.replayed_epochs,
        vrun.checkpoint_bytes as f64 / 1024.0
    );

    println!("{} tenants served by {} resident workers\n", tenants.len() + 1, workers);
    let mut t = Table::new(&["tenant", "steps", "wall s", "queue wait s", "launches"]);
    for (name, s) in tenants.iter() {
        let rep = s.report();
        t.row(&[
            name.to_string(),
            rep.steps.to_string(),
            format!("{:.6}", rep.wall_seconds),
            format!("{:.6}", rep.queue_wait_seconds.unwrap_or(0.0)),
            rep.invocations.to_string(),
        ]);
    }
    let rep = cg.report();
    t.row(&[
        "cg poisson 256".to_string(),
        rep.steps.to_string(),
        format!("{:.6}", rep.wall_seconds),
        format!("{:.6}", rep.queue_wait_seconds.unwrap_or(0.0)),
        rep.invocations.to_string(),
    ]);
    print!("{}", t.render());

    let m = farm.metrics();
    println!(
        "\nfarm: {} admissions, {} commands, {} tasks, {} epochs on {} workers ({} spawns total)",
        m.admissions, m.commands, m.tasks, m.epochs, m.workers, m.threads_spawned
    );
    println!(
        "queue wait p50/p99/max: {:.3}/{:.3}/{:.3} ms   fairness (max/mean): {:.2}",
        m.queue_wait_p50 * 1e3,
        m.queue_wait_p99 * 1e3,
        m.queue_wait_max * 1e3,
        m.fairness()
    );
    println!(
        "plane: {} batches / {} scheduler locks (1:1), {} sheds, {} timeouts, peak {} in flight",
        m.plane_batches,
        m.sched_lock_acquisitions,
        m.plane_sheds,
        m.plane_timeouts,
        m.plane_inflight_peak
    );
    println!(
        "async section: {async_tenants} tenants multiplexed on ONE front-end thread,\n\
         each schedule one batched command graph (one lock acquisition per tenant)."
    );
    println!("\nevery tenant's iterates are bit-identical to its solo-pool session;");
    println!("the farm batches small solves onto one resident worker set instead of");
    println!("building (and tearing down) a pool per session.");
    Ok(())
}
