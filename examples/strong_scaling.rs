//! Domain example: the strong-scaling story of Fig 6. As the per-node
//! domain shrinks (more nodes, same global problem), the domain fits in
//! on-chip/cache memory and the PERKS win grows. Demonstrated two ways:
//!
//! 1. *measured* on the persistent-threads CPU executor (thread-local
//!    slabs fit in core caches as the domain shrinks);
//! 2. *simulated* with the paper's performance model on A100/V100.
//!
//! ```bash
//! cargo run --release --example strong_scaling
//! ```

use perks::harness::stencil_exp::{speedup_row, StencilExperiment};
use perks::simgpu::device::{a100, v100};
use perks::simgpu::perfmodel;
use perks::stencil::{parallel, shape, Domain};
use perks::util::fmt::{secs, Table};
use perks::util::stats::{median, time_n};

fn main() -> perks::Result<()> {
    // -------- measured: CPU persistent threads --------
    let s = shape::spec("2d5pt").unwrap();
    let steps = 48;
    let threads = 8;
    println!("measured (CPU persistent threads, 2d5pt, {steps} steps, {threads} threads):\n");
    let mut t = Table::new(&["per-node domain", "host-loop", "persistent", "PERKS speedup"]);
    for size in [2048usize, 1024, 512, 256] {
        let mut d = Domain::for_spec(&s, &[size, size])?;
        d.randomize(9);
        let th = median(&time_n(3, || {
            parallel::host_loop(&s, &d, steps, threads).unwrap();
        }));
        let tp = median(&time_n(3, || {
            parallel::persistent(&s, &d, steps, threads).unwrap();
        }));
        t.row(&[
            format!("{size}x{size}"),
            secs(th),
            secs(tp),
            format!("{:.2}x", th / tp),
        ]);
    }
    print!("{}", t.render());

    // -------- simulated: the paper's model --------
    println!("\nsimulated (paper's model, 2d5pt dp, 1000 steps):\n");
    let mut t2 = Table::new(&["device", "large domain", "speedup", "small domain", "speedup"]);
    for dev in [a100(), v100()] {
        let large = StencilExperiment::large(&dev, "2d5pt", 8, 1000);
        let small = StencilExperiment::small(&dev, "2d5pt", 8, 1000);
        let rl = speedup_row(&dev, &large, perfmodel::EFF_PERKS_LARGE);
        let rs = speedup_row(&dev, &small, perfmodel::EFF_PERKS_SMALL);
        let fmt_dom =
            |d: &[usize]| d.iter().map(|x| x.to_string()).collect::<Vec<_>>().join("x");
        t2.row(&[
            dev.name.to_string(),
            fmt_dom(&rl.domain),
            format!("{:.2}x", rl.speedup),
            fmt_dom(&rs.domain),
            format!("{:.2}x", rs.speedup),
        ]);
    }
    print!("{}", t2.render());
    println!("\nsmaller per-node domains -> full on-chip residency -> larger PERKS win,");
    println!("exactly the strong-scaling argument of the paper's Fig 6.");
    Ok(())
}
