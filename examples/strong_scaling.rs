//! Domain example: the strong-scaling story of Fig 6. As the per-node
//! domain shrinks (more nodes, same global problem), the domain fits in
//! on-chip/cache memory and the PERKS win grows. Demonstrated two ways
//! through the one `perks::session` API:
//!
//! 1. *measured* on the CPU persistent-threads backend (thread-local
//!    slabs fit in core caches as the domain shrinks);
//! 2. *simulated* on the A100/V100 backend with the paper's performance
//!    model.
//!
//! ```bash
//! cargo run --release --example strong_scaling
//! ```

use perks::session::{Backend, ExecMode, SessionBuilder, Workload};
use perks::simgpu::device::{a100, v100};
use perks::util::fmt::{secs, Table};
use perks::util::stats::{median, time_n};

fn main() -> perks::Result<()> {
    // -------- measured: CPU persistent threads --------
    let steps = 48;
    let threads = 8;
    println!("measured (CPU persistent threads, 2d5pt, {steps} steps, {threads} threads):\n");
    let mut t = Table::new(&["per-node domain", "host-loop", "persistent", "PERKS speedup"]);
    for size in [2048usize, 1024, 512, 256] {
        let interior = format!("{size}x{size}");
        let mut walls = Vec::new();
        for mode in [ExecMode::HostLoop, ExecMode::Persistent] {
            let mut session = SessionBuilder::new()
                .backend(Backend::cpu(threads))
                .workload(Workload::stencil("2d5pt", &interior, "f64"))
                .mode(mode)
                .seed(9)
                .build()?;
            let times = time_n(3, || {
                session.run(steps).unwrap();
            });
            walls.push(median(&times));
        }
        t.row(&[
            interior,
            secs(walls[0]),
            secs(walls[1]),
            format!("{:.2}x", walls[0] / walls[1]),
        ]);
    }
    print!("{}", t.render());

    // -------- simulated: the paper's model, same API --------
    println!("\nsimulated (paper's model, 2d5pt dp, 1000 steps, session backend):\n");
    let mut t2 = Table::new(&["device", "domain", "host-loop", "persistent", "speedup"]);
    for dev in [a100(), v100()] {
        // a saturating large domain vs an on-chip-sized small one
        for interior in ["3072x3072", "1024x768"] {
            let mut walls = Vec::new();
            for mode in [ExecMode::HostLoop, ExecMode::Persistent] {
                let mut session = SessionBuilder::new()
                    .backend(Backend::simulated(dev.clone()))
                    .workload(Workload::stencil("2d5pt", interior, "f64"))
                    .mode(mode)
                    .build()?;
                walls.push(session.run(1000)?.wall_seconds);
            }
            t2.row(&[
                dev.name.to_string(),
                interior.to_string(),
                secs(walls[0]),
                secs(walls[1]),
                format!("{:.2}x", walls[0] / walls[1]),
            ]);
        }
    }
    print!("{}", t2.render());
    println!("\nsmaller per-node domains -> full on-chip residency -> larger PERKS win,");
    println!("exactly the strong-scaling argument of the paper's Fig 6.");
    Ok(())
}
