//! Domain example: the strong-scaling story of Fig 6. As the per-node
//! domain shrinks (more nodes, same global problem), the domain fits in
//! on-chip/cache memory and the PERKS win grows. Demonstrated two ways
//! through the one `perks::session` API:
//!
//! 1. *measured* on the CPU persistent-threads backend, riding the
//!    spawn-once `stencil::pool` runtime: the pool is spawned once at
//!    `prepare`, every timed `advance` is spawn-free (asserted via the
//!    spawn counter), and the thread-local slabs stay resident in core
//!    caches across advances as the domain shrinks;
//! 2. *simulated* on the A100/V100 backend with the paper's performance
//!    model.
//!
//! ```bash
//! cargo run --release --example strong_scaling            # full sweep
//! cargo run --release --example strong_scaling -- --quick # CI smoke
//! ```

use perks::session::{Backend, ExecMode, SessionBuilder};
use perks::simgpu::device::{a100, v100};
use perks::util::counters;
use perks::util::fmt::{secs, Table};
use perks::util::stats::{median, time_n};

fn main() -> perks::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    // -------- measured: CPU persistent threads (pooled) --------
    let steps = if quick { 8 } else { 48 };
    let reps = if quick { 1 } else { 3 };
    let threads = if quick { 2 } else { 8 };
    let sizes: &[usize] = if quick { &[256, 128] } else { &[2048, 1024, 512, 256] };
    println!("measured (CPU stencil pool, 2d5pt, {steps} steps/advance, {threads} threads):\n");
    let mut t = Table::new(&[
        "per-node domain",
        "host-loop",
        "persistent (pooled)",
        "PERKS speedup",
        "pooled advance spawns",
    ]);
    for &size in sizes {
        let interior = format!("{size}x{size}");
        let mut walls = Vec::new();
        let mut pooled_spawns = 0u64;
        for mode in [ExecMode::HostLoop, ExecMode::Persistent] {
            let mut session = SessionBuilder::stencil("2d5pt", &interior, "f64")
                .backend(Backend::cpu(threads))
                .mode(mode)
                .seed(9)
                .build()?;
            // build() already prepared the session — the pool (persistent
            // mode) spawned its workers there; the timed advances below
            // are what the models differ on
            let spawns0 = counters::thread_spawns();
            let times = time_n(reps, || {
                session.advance(steps).unwrap();
            });
            if mode == ExecMode::Persistent {
                pooled_spawns = counters::thread_spawns() - spawns0;
                // the smoke-tested invariant, enforced: pooled advances
                // must not create threads (workers spawned at prepare)
                assert_eq!(pooled_spawns, 0, "pooled advance spawned threads");
            }
            walls.push(median(&times));
        }
        t.row(&[
            interior,
            secs(walls[0]),
            secs(walls[1]),
            format!("{:.2}x", walls[0] / walls[1]),
            pooled_spawns.to_string(),
        ]);
    }
    print!("{}", t.render());
    println!("(pooled advance spawns must read 0: workers spawn once at prepare)");

    // -------- simulated: the paper's model, same API --------
    let sim_steps = if quick { 100 } else { 1000 };
    println!("\nsimulated (paper's model, 2d5pt dp, {sim_steps} steps, session backend):\n");
    let mut t2 = Table::new(&["device", "domain", "host-loop", "persistent", "speedup"]);
    for dev in [a100(), v100()] {
        // a saturating large domain vs an on-chip-sized small one
        for interior in ["3072x3072", "1024x768"] {
            let mut walls = Vec::new();
            for mode in [ExecMode::HostLoop, ExecMode::Persistent] {
                let mut session = SessionBuilder::stencil("2d5pt", interior, "f64")
                    .backend(Backend::simulated(dev.clone()))
                    .mode(mode)
                    .build()?;
                walls.push(session.run(sim_steps)?.wall_seconds);
            }
            t2.row(&[
                dev.name.to_string(),
                interior.to_string(),
                secs(walls[0]),
                secs(walls[1]),
                format!("{:.2}x", walls[0] / walls[1]),
            ]);
        }
    }
    print!("{}", t2.render());
    println!("\nsmaller per-node domains -> full on-chip residency -> larger PERKS win,");
    println!("exactly the strong-scaling argument of the paper's Fig 6.");
    Ok(())
}
