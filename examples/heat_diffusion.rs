//! Domain example: 2D heat diffusion with a hot edge, run through the
//! AOT 2d9pt artifact (a 9-point box Jacobi operator is a reasonable
//! discrete diffusion smoother). Demonstrates feeding a custom initial
//! field into a `perks::session` (`initial_domain`) and tracking a
//! physical observable (heat front progression) across execution models.
//!
//! ```bash
//! make artifacts && cargo run --release --example heat_diffusion
//! ```

use std::rc::Rc;

use perks::runtime::Runtime;
use perks::session::{Backend, ExecMode, SessionBuilder};
use perks::util::fmt::secs;

const N: usize = 128; // interior matches the lowered artifact

fn initial_field() -> Vec<f64> {
    // padded (N+2)^2: top edge held at 100.0 (Dirichlet), interior cold
    let p = N + 2;
    let mut f = vec![0.0f64; p * p];
    for x in 0..p {
        f[x] = 100.0;
    }
    f
}

/// Mean temperature of interior row `y` (1-based in padded coords).
fn row_mean(field: &[f64], y: usize) -> f64 {
    let p = N + 2;
    let row = &field[y * p + 1..y * p + 1 + N];
    row.iter().sum::<f64>() / N as f64
}

fn main() -> perks::Result<()> {
    let rt = Rc::new(Runtime::new(Runtime::default_dir())?);
    let steps = 128;

    println!("2D heat diffusion, hot top edge (T=100), {steps} steps, {N}x{N} grid\n");
    let mut fronts = Vec::new();
    for mode in [ExecMode::HostLoop, ExecMode::Persistent] {
        let mut session = SessionBuilder::stencil("2d9pt", "128x128", "f32")
            .initial_domain(initial_field())
            .backend(Backend::pjrt(rt.clone()))
            .mode(mode)
            .build()?;
        let rep = session.run(session.aligned_steps(steps))?;
        let field = session.state_f64()?;
        // heat front: deepest row whose mean temperature exceeds 1.0
        let front = (1..=N).rev().find(|&y| row_mean(&field, y) > 1.0).unwrap_or(0);
        println!(
            "{:<22} wall {:>10}   row means: y=2 {:>6.2}  y=8 {:>6.2}  y=32 {:>8.4}   front depth {}",
            rep.mode.name(),
            secs(rep.wall_seconds),
            row_mean(&field, 2),
            row_mean(&field, 8),
            row_mean(&field, 32),
            front
        );
        fronts.push(front);
    }
    assert_eq!(fronts[0], fronts[1], "execution models must agree on the physics");
    println!("\nheat front agrees across execution models ✓");
    println!("(the boundary ring is Dirichlet: the hot edge keeps feeding the domain)");
    Ok(())
}
