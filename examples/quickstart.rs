//! Quickstart for the `perks::session` API: one builder, three execution
//! models, one unified report. Runs the 2d5pt AOT stencil artifact under
//! every model, verifies they agree, and prints the PERKS speedup.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use std::rc::Rc;

use perks::runtime::Runtime;
use perks::session::{Backend, ExecMode, SessionBuilder};
use perks::util::fmt::{gcells, secs};

fn main() -> perks::Result<()> {
    // 1. open the artifact registry (built once by `make artifacts`);
    //    one Rc-shared runtime serves all three sessions below
    let rt = Rc::new(Runtime::new(Runtime::default_dir())?);
    println!("PJRT platform: {}", rt.platform());

    // 2. run 64 steps of the 2d5pt family at 128x128 f32 under each model;
    //    build all sessions first so one chunk-aligned step count serves
    //    every mode and the states stay comparable
    let mut sessions = Vec::new();
    // pipelined is CG-only — the stencil loop runs the other three models
    for mode in ExecMode::all().into_iter().filter(|m| *m != ExecMode::Pipelined) {
        let session = SessionBuilder::stencil("2d5pt", "128x128", "f32")
            .backend(Backend::pjrt(rt.clone()))
            .mode(mode)
            .seed(2026)
            .build()?;
        sessions.push(session);
    }
    let steps = sessions.iter().map(|s| s.aligned_steps(64)).max().unwrap();
    let mut reports = Vec::new();
    let mut states = Vec::new();
    for session in &mut sessions {
        let rep = session.run(steps)?;
        println!(
            "{:<22} {:>10}  {:>16}  launches={}",
            rep.mode.name(),
            secs(rep.wall_seconds),
            gcells(rep.fom),
            rep.invocations
        );
        states.push(session.state_f64()?);
        reports.push(rep);
    }

    // 3. all three must agree numerically (the execution models are
    //    interchangeable — only the memory behaviour differs)
    let a = &states[0];
    for b in &states[1..] {
        let diff = a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max);
        assert!(diff < 1e-4, "models diverged: {diff}");
    }
    println!(
        "\nPERKS speedup vs host-loop: {:.2}x   vs device-resident loop: {:.2}x",
        reports[0].wall_seconds / reports[2].wall_seconds,
        reports[1].wall_seconds / reports[2].wall_seconds
    );
    Ok(())
}
