//! Quickstart: load an AOT stencil artifact, run it under the three
//! execution models, verify they agree, and print the speedup.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use perks::coordinator::{ExecMode, StencilDriver};
use perks::runtime::{HostTensor, Runtime};
use perks::stencil::{self, Domain};
use perks::util::fmt::{gcells, secs};

fn main() -> perks::Result<()> {
    // 1. open the artifact registry (built once by `make artifacts`)
    let rt = Runtime::new(Runtime::default_dir())?;
    println!("PJRT platform: {}", rt.platform());

    // 2. pick the 2d5pt stencil family at 128x128 f32
    let driver = StencilDriver::new(&rt, "2d5pt", "128x128", "f32")?;
    println!("fused steps per persistent launch: {}", driver.fused_steps);

    // 3. build a deterministic initial domain
    let spec = stencil::spec("2d5pt").unwrap();
    let mut dom = Domain::for_spec(&spec, &[128, 128])?;
    dom.randomize(2026);
    let x0 = HostTensor::f32(&[dom.padded[1], dom.padded[2]], dom.to_f32());

    // 4. advance 64 time steps under each model
    let steps = 64;
    let mut results = Vec::new();
    for mode in ExecMode::all() {
        let rep = driver.run(mode, &x0, steps)?;
        println!(
            "{:<22} {:>10}  {:>16}  launches={}",
            mode.name(),
            secs(rep.wall_seconds),
            gcells(rep.cells_per_sec(driver.interior_cells())),
            rep.invocations
        );
        results.push(rep);
    }

    // 5. all three must agree numerically (the execution models are
    //    interchangeable — only the memory behaviour differs)
    let a = results[0].state[0].to_f64_vec()?;
    for r in &results[1..] {
        let b = r.state[0].to_f64_vec()?;
        let diff = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max);
        assert!(diff < 1e-4, "models diverged: {diff}");
    }
    println!(
        "\nPERKS speedup vs host-loop: {:.2}x   vs device-resident loop: {:.2}x",
        results[0].wall_seconds / results[2].wall_seconds,
        results[1].wall_seconds / results[2].wall_seconds
    );
    Ok(())
}
