//! perks-lint regression suite: the tree must be clean, and every
//! checked-in known-bad fixture must fire its rule — both through the
//! library API and through the `perks_lint` binary CI actually runs.

use std::path::Path;
use std::process::Command;

use perks::lint::{self, FileCtx};

fn lint_fixture(name: &str) -> Vec<lint::Violation> {
    let path = Path::new("tests/lint_fixtures").join(name);
    let ctx = FileCtx::load(&path).unwrap_or_else(|e| panic!("read {}: {e}", path.display()));
    lint::lint_file(&ctx)
}

fn rules_of(v: &[lint::Violation]) -> Vec<&str> {
    v.iter().map(|v| v.rule).collect()
}

// ------------------------------------------------------------------
// the tree itself is clean
// ------------------------------------------------------------------

#[test]
fn source_tree_is_lint_clean() {
    let v = lint::lint_root(Path::new("src")).expect("lint src tree");
    assert!(
        v.is_empty(),
        "rust/src must be perks-lint clean; fix or `lint: allow(..) -- why`:\n{}",
        v.iter().map(|v| v.to_string()).collect::<Vec<_>>().join("\n"),
    );
}

// ------------------------------------------------------------------
// every rule fires on its fixture
// ------------------------------------------------------------------

#[test]
fn fixture_condvar_shutdown_fires() {
    let v = lint_fixture("bad_condvar.rs");
    let hits = v.iter().filter(|v| v.rule == "condvar-shutdown").count();
    assert_eq!(hits, 2, "epoch-only loop + un-looped wait: {v:?}");
}

#[test]
fn fixture_lock_order_fires() {
    let v = lint_fixture("bad_lock_order.rs");
    let msgs: Vec<_> =
        v.iter().filter(|v| v.rule == "lock-order").map(|v| v.msg.clone()).collect();
    assert_eq!(msgs.len(), 2, "inversion + reentrant acquisition: {v:?}");
    assert!(msgs.iter().any(|m| m.contains("inverts")), "{msgs:?}");
    assert!(msgs.iter().any(|m| m.contains("self-deadlock")), "{msgs:?}");
}

#[test]
fn fixture_hot_path_alloc_fires() {
    let v = lint_fixture("bad_hot_path.rs");
    let hits = v.iter().filter(|v| v.rule == "hot-path-alloc").count();
    assert_eq!(hits, 4, "Vec::new, clone, format!, unclosed fence: {v:?}");
}

#[test]
fn fixture_unsafe_safety_fires() {
    let v = lint_fixture("bad_unsafe.rs");
    let hits = v.iter().filter(|v| v.rule == "unsafe-safety").count();
    assert_eq!(hits, 2, "bare unsafe impl + bare unsafe block (the commented one passes): {v:?}");
}

#[test]
fn fixture_no_panic_fires() {
    let v = lint_fixture("runtime/bad_panic.rs");
    let hits = v.iter().filter(|v| v.rule == "no-panic").count();
    assert_eq!(hits, 3, "unwrap + expect + panic!, test module exempt: {v:?}");
}

#[test]
fn fixture_unjustified_allow_fires() {
    let v = lint_fixture("bad_allow.rs");
    assert_eq!(rules_of(&v), vec!["lint-allow"], "allow silences the rule but owes a reason");
}

#[test]
fn fixture_counter_coverage_fires() {
    let v = lint::lint_root(Path::new("tests/lint_fixtures/counter_tree")).expect("lint fixture");
    let orphaned: Vec<_> = v.iter().filter(|v| v.rule == "counter-coverage").collect();
    assert_eq!(orphaned.len(), 2, "orphan never incremented + never asserted: {v:?}");
    assert!(orphaned.iter().all(|v| v.msg.contains("orphan_counter")), "{orphaned:?}");
}

// ------------------------------------------------------------------
// the binary CI runs agrees with the library
// ------------------------------------------------------------------

#[test]
fn binary_exits_zero_on_tree_nonzero_on_fixtures() {
    let bin = env!("CARGO_BIN_EXE_perks_lint");
    let clean = Command::new(bin).output().expect("run perks_lint");
    assert!(
        clean.status.success(),
        "perks_lint must exit 0 on the tree:\n{}",
        String::from_utf8_lossy(&clean.stdout),
    );
    for fixture in [
        "tests/lint_fixtures/bad_condvar.rs",
        "tests/lint_fixtures/bad_lock_order.rs",
        "tests/lint_fixtures/bad_hot_path.rs",
        "tests/lint_fixtures/bad_unsafe.rs",
        "tests/lint_fixtures/runtime/bad_panic.rs",
        "tests/lint_fixtures/bad_allow.rs",
    ] {
        let out = Command::new(bin).arg(fixture).output().expect("run perks_lint");
        assert_eq!(out.status.code(), Some(1), "{fixture} must fail the lint");
    }
    let counters = Command::new(bin)
        .args(["--root", "tests/lint_fixtures/counter_tree"])
        .output()
        .expect("run perks_lint");
    assert_eq!(counters.status.code(), Some(1), "counter fixture tree must fail the lint");
    let listing = Command::new(bin).arg("--list-rules").output().expect("run perks_lint");
    assert!(listing.status.success());
    let text = String::from_utf8_lossy(&listing.stdout).to_string();
    for (name, _) in lint::RULES {
        assert!(text.contains(name), "--list-rules must mention {name}");
    }
}
