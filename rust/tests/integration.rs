//! Integration tests over the full AOT -> PJRT -> session stack.
//!
//! These close the cross-language gold chain: the jnp oracle validated the
//! Pallas kernels (pytest), the Pallas kernels were lowered to the HLO
//! artifacts, and here the artifacts executed through PJRT (behind the
//! `perks::session` API) are checked against the *independent* rust CPU
//! gold executor.
//!
//! Requires `make artifacts`; every test skips cleanly if the artifact
//! directory is missing (e.g. fresh checkout without python).

use std::rc::Rc;

use perks::runtime::Runtime;
use perks::session::{Backend, ExecMode, SessionBuilder};
use perks::sparse::gen;
use perks::stencil::{self, gold, Domain};

fn runtime() -> Option<Rc<Runtime>> {
    let dir = Runtime::default_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping: {} has no manifest (run `make artifacts`)", dir.display());
        return None;
    }
    Some(Rc::new(Runtime::new(dir).expect("runtime")))
}

#[test]
fn all_artifacts_load_and_compile() {
    let Some(rt) = runtime() else { return };
    assert!(rt.manifest.artifacts.len() >= 15, "artifact inventory too small");
    for meta in rt.manifest.artifacts.clone() {
        let exe = rt.load(&meta.name).unwrap_or_else(|e| panic!("{}: {e}", meta.name));
        assert_eq!(exe.meta.name, meta.name);
    }
    // compile-once cache: second load hits the cache
    let before = rt.metrics().compilations;
    rt.load(&rt.manifest.artifacts[0].name.clone()).unwrap();
    assert_eq!(rt.metrics().compilations, before);
}

fn check_stencil_family(
    rt: &Rc<Runtime>,
    bench: &str,
    interior: &str,
    dtype: &str,
    steps: usize,
) {
    let seed = 4242;
    let spec = stencil::spec(bench).unwrap();
    let dims: Vec<usize> = interior.split('x').map(|d| d.parse().unwrap()).collect();
    let mut dom = Domain::for_spec(&spec, &dims).unwrap();
    dom.randomize(seed);

    // the independent rust oracle
    let want = gold::run(&spec, &dom, steps).unwrap();

    let tol = if dtype == "f64" { 1e-11 } else { 2e-4 };
    let mut first: Option<Vec<f64>> = None;
    // pipelined is a CG-only execution model; stencils reject it
    for mode in ExecMode::all().into_iter().filter(|m| *m != ExecMode::Pipelined) {
        let mut session = SessionBuilder::stencil(bench, interior, dtype)
            .backend(Backend::pjrt(rt.clone()))
            .mode(mode)
            .seed(seed)
            .build()
            .expect(mode.name());
        let rep = session.run(steps).expect(mode.name());
        assert_eq!(rep.steps, steps);
        assert!(rep.fom.is_finite(), "{bench} {}: FOM must be finite", mode.name());
        let got = session.state_f64().unwrap();
        let diff = got
            .iter()
            .zip(&want.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max);
        assert!(
            diff < tol,
            "{bench} {dtype} {}: diverged from rust gold by {diff}",
            mode.name()
        );
        match &first {
            None => first = Some(got),
            Some(f) => {
                // execution models must agree with each other even tighter
                let d = f.iter().zip(&got).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
                assert!(d < tol, "{bench} {}: inter-mode diff {d}", mode.name());
            }
        }
    }
}

#[test]
fn pjrt_stencils_match_rust_gold_2d() {
    let Some(rt) = runtime() else { return };
    check_stencil_family(&rt, "2d5pt", "128x128", "f32", 32);
    check_stencil_family(&rt, "2d9pt", "128x128", "f32", 32);
    check_stencil_family(&rt, "2ds9pt", "128x128", "f32", 32);
}

#[test]
fn pjrt_stencils_match_rust_gold_3d() {
    let Some(rt) = runtime() else { return };
    check_stencil_family(&rt, "3d7pt", "32x32x32", "f32", 16);
    check_stencil_family(&rt, "3d27pt", "32x32x32", "f32", 16);
}

#[test]
fn pjrt_stencil_f64_matches_gold_tightly() {
    let Some(rt) = runtime() else { return };
    check_stencil_family(&rt, "2d5pt", "64x64", "f64", 32);
}

#[test]
fn impulse_response_reveals_correct_weights() {
    // cross-language weight agreement: a unit impulse at the center maps,
    // after one step, to exactly the (offset, weight) catalog entries.
    // Uses the session's initial_domain hook.
    let Some(rt) = runtime() else { return };
    let spec = stencil::spec("2d5pt").unwrap();
    let p = 130usize;
    let mut field = vec![0.0f64; p * p];
    let (cy, cx) = (65usize, 65usize);
    field[cy * p + cx] = 1.0;
    let mut session = SessionBuilder::stencil("2d5pt", "128x128", "f32")
        .initial_domain(field)
        .backend(Backend::pjrt(rt.clone()))
        .mode(ExecMode::HostLoop)
        .build()
        .unwrap();
    session.run(1).unwrap();
    let out = session.state_f64().unwrap();
    for ((_, dy, dx), w) in spec.offsets.iter().zip(spec.weights()) {
        // impulse spreads to the *opposite* offset positions
        let y = (cy as i64 - *dy as i64) as usize;
        let x = (cx as i64 - *dx as i64) as usize;
        let got = out[y * p + x];
        assert!(
            (got - w).abs() < 1e-6,
            "offset ({dy},{dx}): got {got}, want weight {w}"
        );
    }
}

#[test]
fn cg_session_modes_agree_and_converge() {
    let Some(rt) = runtime() else { return };
    let build = |mode: ExecMode| {
        SessionBuilder::cg(1024)
            .backend(Backend::pjrt(rt.clone()))
            .mode(mode)
            .seed(5)
            .build()
            .unwrap()
    };
    let mut h = build(ExecMode::HostLoop);
    let mut p = build(ExecMode::Persistent);
    let hr = h.run(64).unwrap();
    let pr = p.run(64).unwrap();
    assert_eq!(hr.invocations, 64);
    assert_eq!(pr.invocations, 8); // fused by 8
    let hx = h.state_f64().unwrap();
    let px = p.state_f64().unwrap();
    let dx = hx.iter().zip(&px).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
    assert!(dx < 1e-3, "host-loop vs persistent iterates differ by {dx}");
    // converged well below the rhs norm after 64 iterations
    let rr0: f64 = gen::rhs(1024, 5)
        .iter()
        .map(|&v| (v as f32 as f64) * (v as f32 as f64))
        .sum();
    let rr = hr.residual.unwrap();
    assert!(rr < 1e-4 * rr0, "rr {rr} vs rr0 {rr0}");
    // true residual on device agrees with the recurrence
    let resid = p.true_residual().unwrap().unwrap();
    let prr = pr.residual.unwrap();
    assert!((resid - prr).abs() < 1e-2 * (resid + prr + 1e-9), "{resid} vs {prr}");
}

#[test]
fn cg_session_matches_rust_native_solver() {
    // the PJRT CG (pallas fused update + jnp spmv) and the rust-native CG
    // (merge spmv + fused passes) must walk the same iterates
    let Some(rt) = runtime() else { return };
    let mut session = SessionBuilder::cg(1024)
        .backend(Backend::pjrt(rt.clone()))
        .mode(ExecMode::Persistent)
        .seed(5)
        .build()
        .unwrap();
    session.run(24).unwrap();
    let pjrt_x = session.state_f64().unwrap();

    let a = gen::poisson2d(32);
    let b64 = gen::rhs(1024, 5);
    let opts = perks::cg::CgOptions { max_iters: 24, tol: 0.0, ..Default::default() };
    let native = perks::cg::solve_persistent(&a, &b64, &opts).unwrap();
    let dx = pjrt_x
        .iter()
        .zip(&native.x)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f64::max);
    let scale = native.x.iter().map(|v| v.abs()).fold(0.0, f64::max);
    assert!(dx < 1e-3 * (1.0 + scale), "PJRT vs native iterates differ by {dx}");
}

#[test]
fn runtime_metrics_track_traffic() {
    let Some(rt) = runtime() else { return };
    let mut session = SessionBuilder::stencil("2d5pt", "128x128", "f32")
        .backend(Backend::pjrt(rt.clone()))
        .mode(ExecMode::HostLoop)
        .seed(1)
        .build()
        .unwrap();
    rt.reset_metrics();
    session.run(16).unwrap();
    let m = rt.metrics();
    assert_eq!(m.invocations, 16);
    // 16 uploads + 16 downloads of the padded f32 domain
    let tensor_bytes = (130 * 130 * 4) as u64;
    assert_eq!(m.bytes_in, 16 * tensor_bytes);
    assert_eq!(m.bytes_out, 16 * tensor_bytes);
}

#[test]
fn pjrt_backend_rejects_pipelined_cg() {
    // no pipelined artifact family exists: the typed builder surfaces the
    // driver's rejection instead of silently falling back to classic CG
    let Some(rt) = runtime() else { return };
    let err = SessionBuilder::cg(1024)
        .pipelined(true)
        .backend(Backend::pjrt(rt.clone()))
        .seed(5)
        .build();
    let msg = format!("{}", err.err().expect("pjrt pipelined CG must be rejected"));
    assert!(msg.contains("pipelined"), "unexpected rejection text: {msg}");
}

#[test]
fn multidev_sharded_matches_single_domain_gold() {
    // §III-A distributed PERKS: two 64-row shards + coordinator halo
    // exchange must equal the single 128x128 domain advanced by gold
    let Some(rt) = runtime() else { return };
    let md = perks::coordinator::multidev::MultiDevStencil::new(&rt, "2d5pt", "64x128", "f32", 2)
        .unwrap();
    assert_eq!(md.global_rows(), 128);
    let spec = stencil::spec("2d5pt").unwrap();
    let mut dom = Domain::for_spec(&spec, &[128, 128]).unwrap();
    dom.randomize(77);
    let steps = 12;
    let want = gold::run(&spec, &dom, steps).unwrap();
    let (got, exchanged) = md.step_exchange(&rt, &dom.to_f32(), steps).unwrap();
    assert!(exchanged > 0);
    let diff = got
        .iter()
        .zip(&want.data)
        .map(|(a, b)| (*a as f64 - b).abs())
        .fold(0.0, f64::max);
    assert!(diff < 1e-4, "sharded run diverged from gold by {diff}");
}

#[test]
fn manifest_inventory_complete() {
    let Some(rt) = runtime() else { return };
    // the artifact families the benches/examples rely on
    for kind in ["stencil_step", "stencil_perks", "cg_step", "cg_perks", "cg_residual"] {
        assert!(
            !rt.manifest.by_kind(kind).is_empty(),
            "no artifacts of kind {kind}"
        );
    }
    // raw (untupled) variants exist for buffer chaining
    assert!(rt.manifest.artifacts.iter().any(|a| a.name.ends_with("_raw") && !a.tupled));
}
