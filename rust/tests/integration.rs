//! Integration tests over the full AOT -> PJRT -> coordinator stack.
//!
//! These close the cross-language gold chain: the jnp oracle validated the
//! Pallas kernels (pytest), the Pallas kernels were lowered to the HLO
//! artifacts, and here the artifacts executed through PJRT are checked
//! against the *independent* rust CPU gold executor.
//!
//! Requires `make artifacts`; every test skips cleanly if the artifact
//! directory is missing (e.g. fresh checkout without python).

use perks::coordinator::{CgDriver, ExecMode, StencilDriver};
use perks::runtime::{HostTensor, Runtime};
use perks::sparse::gen;
use perks::stencil::{self, gold, Domain};

fn runtime() -> Option<Runtime> {
    let dir = Runtime::default_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping: {} has no manifest (run `make artifacts`)", dir.display());
        return None;
    }
    Some(Runtime::new(dir).expect("runtime"))
}

#[test]
fn all_artifacts_load_and_compile() {
    let Some(rt) = runtime() else { return };
    assert!(rt.manifest.artifacts.len() >= 15, "artifact inventory too small");
    for meta in rt.manifest.artifacts.clone() {
        let exe = rt.load(&meta.name).unwrap_or_else(|e| panic!("{}: {e}", meta.name));
        assert_eq!(exe.meta.name, meta.name);
    }
    // compile-once cache: second load hits the cache
    let before = rt.metrics().compilations;
    rt.load(&rt.manifest.artifacts[0].name.clone()).unwrap();
    assert_eq!(rt.metrics().compilations, before);
}

fn check_stencil_family(rt: &Runtime, bench: &str, interior: &str, dtype: &str, steps: usize) {
    let driver = StencilDriver::new(rt, bench, interior, dtype).expect("driver");
    let spec = stencil::spec(bench).unwrap();
    let dims: Vec<usize> = interior.split('x').map(|d| d.parse().unwrap()).collect();
    let mut dom = Domain::for_spec(&spec, &dims).unwrap();
    dom.randomize(4242);

    // the independent rust oracle
    let want = gold::run(&spec, &dom, steps).unwrap();

    let padded: Vec<usize> = if spec.dims == 2 {
        vec![dom.padded[1], dom.padded[2]]
    } else {
        dom.padded.to_vec()
    };
    let x0 = match dtype {
        "f64" => HostTensor::f64(&padded, dom.data.clone()),
        _ => HostTensor::f32(&padded, dom.to_f32()),
    };
    let tol = if dtype == "f64" { 1e-11 } else { 2e-4 };
    let mut first: Option<Vec<f64>> = None;
    for mode in ExecMode::all() {
        let rep = driver.run(mode, &x0, steps).expect(mode.name());
        assert_eq!(rep.steps, steps);
        let got = rep.state[0].to_f64_vec().unwrap();
        let diff = got
            .iter()
            .zip(&want.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max);
        assert!(
            diff < tol,
            "{bench} {dtype} {}: diverged from rust gold by {diff}",
            mode.name()
        );
        match &first {
            None => first = Some(got),
            Some(f) => {
                // execution models must agree with each other even tighter
                let d = f.iter().zip(&got).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
                assert!(d < tol, "{bench} {}: inter-mode diff {d}", mode.name());
            }
        }
    }
}

#[test]
fn pjrt_stencils_match_rust_gold_2d() {
    let Some(rt) = runtime() else { return };
    check_stencil_family(&rt, "2d5pt", "128x128", "f32", 32);
    check_stencil_family(&rt, "2d9pt", "128x128", "f32", 32);
    check_stencil_family(&rt, "2ds9pt", "128x128", "f32", 32);
}

#[test]
fn pjrt_stencils_match_rust_gold_3d() {
    let Some(rt) = runtime() else { return };
    check_stencil_family(&rt, "3d7pt", "32x32x32", "f32", 16);
    check_stencil_family(&rt, "3d27pt", "32x32x32", "f32", 16);
}

#[test]
fn pjrt_stencil_f64_matches_gold_tightly() {
    let Some(rt) = runtime() else { return };
    check_stencil_family(&rt, "2d5pt", "64x64", "f64", 32);
}

#[test]
fn impulse_response_reveals_correct_weights() {
    // cross-language weight agreement: a unit impulse at the center maps,
    // after one step, to exactly the (offset, weight) catalog entries
    let Some(rt) = runtime() else { return };
    let driver = StencilDriver::new(&rt, "2d5pt", "128x128", "f32").unwrap();
    let spec = stencil::spec("2d5pt").unwrap();
    let p = 130usize;
    let mut field = vec![0.0f32; p * p];
    let (cy, cx) = (65usize, 65usize);
    field[cy * p + cx] = 1.0;
    let x0 = HostTensor::f32(&[p, p], field);
    let rep = driver.run(ExecMode::HostLoop, &x0, 1).unwrap();
    let out = rep.state[0].as_f32().unwrap();
    for ((_, dy, dx), w) in spec.offsets.iter().zip(spec.weights()) {
        // impulse spreads to the *opposite* offset positions
        let y = (cy as i64 - *dy as i64) as usize;
        let x = (cx as i64 - *dx as i64) as usize;
        let got = out[y * p + x] as f64;
        assert!(
            (got - w).abs() < 1e-6,
            "offset ({dy},{dx}): got {got}, want weight {w}"
        );
    }
}

#[test]
fn cg_artifact_modes_agree_and_converge() {
    let Some(rt) = runtime() else { return };
    let driver = CgDriver::new(&rt, 1024).unwrap();
    let a = gen::poisson2d(32);
    assert_eq!(a.nnz(), driver.nnz);
    let (data, cols, rows) = a.to_coo_f32();
    let data = HostTensor::f32(&[driver.nnz], data);
    let cols = HostTensor::i32(&[driver.nnz], cols);
    let rows = HostTensor::i32(&[driver.nnz], rows);
    let b: Vec<f32> = gen::rhs(1024, 5).iter().map(|&v| v as f32).collect();
    let bb: f64 = b.iter().map(|&v| (v as f64) * (v as f64)).sum();

    let h = driver.run(ExecMode::HostLoop, &data, &cols, &rows, &b, 64).unwrap();
    let p = driver.run(ExecMode::Persistent, &data, &cols, &rows, &b, 64).unwrap();
    assert_eq!(h.invocations, 64);
    assert_eq!(p.invocations, 8); // fused by 8
    let dx = h
        .x
        .iter()
        .zip(&p.x)
        .map(|(a, b)| (a - b).abs() as f64)
        .fold(0.0, f64::max);
    assert!(dx < 1e-3, "host-loop vs persistent iterates differ by {dx}");
    // converged well below the rhs norm after 64 iterations
    assert!(h.rr < 1e-4 * bb, "rr {} vs bb {bb}", h.rr);
    // true residual on device agrees with the recurrence
    let resid = driver.residual(&data, &cols, &rows, &p.x, &b).unwrap();
    assert!((resid - p.rr).abs() < 1e-2 * (resid + p.rr + 1e-9), "{resid} vs {}", p.rr);
}

#[test]
fn cg_artifact_matches_rust_native_solver() {
    // the PJRT CG (pallas fused update + jnp spmv) and the rust-native CG
    // (merge spmv + fused passes) must walk the same iterates
    let Some(rt) = runtime() else { return };
    let driver = CgDriver::new(&rt, 1024).unwrap();
    let a = gen::poisson2d(32);
    let (data, cols, rows) = a.to_coo_f32();
    let data = HostTensor::f32(&[driver.nnz], data);
    let cols = HostTensor::i32(&[driver.nnz], cols);
    let rows = HostTensor::i32(&[driver.nnz], rows);
    let b64 = gen::rhs(1024, 5);
    let b: Vec<f32> = b64.iter().map(|&v| v as f32).collect();

    let pjrt = driver.run(ExecMode::Persistent, &data, &cols, &rows, &b, 24).unwrap();
    let opts = perks::cg::CgOptions { max_iters: 24, tol: 0.0, parts: 8, threaded: false };
    let native = perks::cg::solve_persistent(&a, &b64, &opts).unwrap();
    let dx = pjrt
        .x
        .iter()
        .zip(&native.x)
        .map(|(a, b)| (*a as f64 - b).abs())
        .fold(0.0, f64::max);
    let scale = native.x.iter().map(|v| v.abs()).fold(0.0, f64::max);
    assert!(dx < 1e-3 * (1.0 + scale), "PJRT vs native iterates differ by {dx}");
}

#[test]
fn runtime_metrics_track_traffic() {
    let Some(rt) = runtime() else { return };
    rt.reset_metrics();
    let driver = StencilDriver::new(&rt, "2d5pt", "128x128", "f32").unwrap();
    let dom = {
        let spec = stencil::spec("2d5pt").unwrap();
        let mut d = Domain::for_spec(&spec, &[128, 128]).unwrap();
        d.randomize(1);
        d
    };
    let x0 = HostTensor::f32(&[130, 130], dom.to_f32());
    rt.reset_metrics();
    driver.run(ExecMode::HostLoop, &x0, 16).unwrap();
    let m = rt.metrics();
    assert_eq!(m.invocations, 16);
    // 16 uploads + 16 downloads of the padded f32 domain
    let tensor_bytes = (130 * 130 * 4) as u64;
    assert_eq!(m.bytes_in, 16 * tensor_bytes);
    assert_eq!(m.bytes_out, 16 * tensor_bytes);
}

#[test]
fn multidev_sharded_matches_single_domain_gold() {
    // §III-A distributed PERKS: two 64-row shards + coordinator halo
    // exchange must equal the single 128x128 domain advanced by gold
    let Some(rt) = runtime() else { return };
    let md = perks::coordinator::multidev::MultiDevStencil::new(&rt, "2d5pt", "64x128", "f32", 2)
        .unwrap();
    assert_eq!(md.global_rows(), 128);
    let spec = stencil::spec("2d5pt").unwrap();
    let mut dom = Domain::for_spec(&spec, &[128, 128]).unwrap();
    dom.randomize(77);
    let steps = 12;
    let want = gold::run(&spec, &dom, steps).unwrap();
    let (got, exchanged) = md.step_exchange(&rt, &dom.to_f32(), steps).unwrap();
    assert!(exchanged > 0);
    let diff = got
        .iter()
        .zip(&want.data)
        .map(|(a, b)| (*a as f64 - b).abs())
        .fold(0.0, f64::max);
    assert!(diff < 1e-4, "sharded run diverged from gold by {diff}");
}

#[test]
fn manifest_inventory_complete() {
    let Some(rt) = runtime() else { return };
    // the artifact families the benches/examples rely on
    for kind in ["stencil_step", "stencil_perks", "cg_step", "cg_perks", "cg_residual"] {
        assert!(
            !rt.manifest.by_kind(kind).is_empty(),
            "no artifacts of kind {kind}"
        );
    }
    // raw (untupled) variants exist for buffer chaining
    assert!(rt.manifest.artifacts.iter().any(|a| a.name.ends_with("_raw") && !a.tupled));
}
