//! Durable snapshot integration suite: the crash-consistency contract
//! end to end, on real farms and real directories.
//!
//! * durable farm runs commit frames (metrics + process counters agree,
//!   `perks_recover verify` passes on what they wrote);
//! * a clean shutdown + disk restore resumes **bit-identically** at
//!   every worker count (the worker-count invariance the farm already
//!   guarantees, now through the persistence layer);
//! * the real thing: `perks_recover crash-demo` re-runs each workload
//!   in a child process that dies by `FaultKind::Kill` (a hard
//!   `process::abort` mid-`advance`) and must resume bit-identically
//!   from the directory the corpse left behind, across workers
//!   {1, 2, 3, 8};
//! * corrupt, truncated, unmanifested, and stale-tmp frames fall back a
//!   generation or surface a structured [`Error::Snapshot`] — never a
//!   panic;
//! * `restore_from` rejects mismatched checkpoints structurally.
//!
//! Every farm installs an empty fault plan so the suite stays hermetic
//! under the CI fault-matrix (`PERKS_FAULT_PLAN` / seed sweeps).

use std::path::{Path, PathBuf};
use std::sync::Arc;

use perks::runtime::farm::SolverFarm;
use perks::runtime::{FaultPlan, ResilienceConfig, SnapshotStore};
use perks::sparse::gen;
use perks::spmv::merge::MergePlan;
use perks::stencil::{spec, Domain};
use perks::util::counters;
use perks::Error;

/// Fresh per-test scratch directory (unique per test name and process so
/// parallel test threads and reruns never collide).
fn scratch(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("perks-snapshot-it-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn farm(workers: usize) -> SolverFarm {
    let f = SolverFarm::spawn(workers).expect("spawn farm");
    f.install_faults(FaultPlan::new()); // hermetic under the CI fault matrix
    f
}

fn bits_eq(a: &[f64], b: &[f64]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// Run `steps` of a seeded stencil on a clean farm — the bit-level
/// reference every restored run is compared against.
fn stencil_reference(
    bench: &str,
    interior: &[usize],
    bt: usize,
    shards: usize,
    seed: u64,
    steps: usize,
    workers: usize,
) -> Vec<f64> {
    let f = farm(workers);
    let s = spec(bench).expect("bench");
    let mut d = Domain::for_spec(&s, interior).expect("domain");
    d.randomize(seed);
    let mut t = f.handle().admit_stencil(&s, &d, shards, bt).expect("admit");
    t.advance(steps, None).expect("advance");
    t.state().expect("state")
}

/// Run `s1` steps durably (cadence `cadence`, snapshots under `dir`),
/// shut the farm down (draining the off-lock write-out), and return the
/// farm's durable metrics.
fn stencil_durable_run(
    bench: &str,
    interior: &[usize],
    bt: usize,
    shards: usize,
    seed: u64,
    s1: usize,
    cadence: u64,
    dir: &Path,
    workers: usize,
) -> (u64, u64) {
    let mut f = farm(workers);
    let s = spec(bench).expect("bench");
    let mut d = Domain::for_spec(&s, interior).expect("domain");
    d.randomize(seed);
    let mut t = f.handle().admit_stencil(&s, &d, shards, bt).expect("admit");
    t.configure_resilience(ResilienceConfig::disabled().every(cadence).durable(dir))
        .expect("configure durable");
    t.advance(s1, None).expect("advance");
    drop(t);
    // metrics only after shutdown: durable write-out happens off the
    // scheduler lock and can outlive the command's completion signal
    f.shutdown();
    let m = f.metrics();
    (m.durable_frames, m.durable_bytes)
}

#[test]
fn durable_runs_commit_verifiable_frames_and_counters_advance() {
    let dir = scratch("frames");
    let frames_before = counters::durable_frames();
    let bytes_before = counters::durable_bytes();

    let (frames, bytes) =
        stencil_durable_run("2d5pt", &[12, 12], 2, 3, 11, 8, 2, &dir, 2);
    assert!(frames > 0, "cadence 2 over 4 epochs must commit frames");
    assert!(bytes > 0, "committed frames carry payload bytes");

    // the process-wide counters are monotone and shared across parallel
    // tests, so assert the delta covers at least this run's writes
    assert!(
        counters::durable_frames() >= frames_before + frames,
        "util::counters::durable_frames must mirror the farm metric"
    );
    assert!(
        counters::durable_bytes() >= bytes_before + bytes,
        "util::counters::durable_bytes must mirror the farm metric"
    );

    // what landed on disk is a well-formed store: one tenant, a
    // non-empty manifest, every frame passing checksum verification
    let store = SnapshotStore::open(&dir).expect("open store");
    assert_eq!(store.tenants().expect("tenants"), vec!["t0".to_string()]);
    let entries = store.entries("t0").expect("entries");
    assert!(!entries.is_empty());
    for st in store.verify("t0").expect("verify") {
        assert!(st.problem.is_none(), "gen {}: {:?}", st.generation, st.problem);
    }

    // cadence 0 + no retry writes exactly nothing (the bench_check
    // `durable-cadence-zero-writes-nothing` invariant, in miniature)
    let dir0 = scratch("frames-cad0");
    let (frames0, bytes0) =
        stencil_durable_run("2d5pt", &[12, 12], 2, 3, 11, 8, 0, &dir0, 2);
    assert_eq!((frames0, bytes0), (0, 0));
    assert!(
        SnapshotStore::open(&dir0).expect("open").tenants().expect("tenants").is_empty(),
        "cadence-0 store must stay empty"
    );

    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&dir0);
}

/// Clean-shutdown disk round trip: persist during command 1, kill
/// nothing, restore into a *fresh* farm, finish the remaining steps, and
/// require the bits of the uninterrupted run — at 1, 2, 3, and 8 workers
/// (restore feeds the same worker-count-invariant execution the farm
/// guarantees for clean runs).
#[test]
fn disk_restore_resumes_bit_identically_across_worker_counts() {
    let (bench, interior, bt, shards, seed) = ("2d5pt", &[14usize, 14][..], 2usize, 3usize, 5u64);
    let (s1, s2) = (8usize, 6usize);
    let total = s1 + s2;
    let restores_before = counters::restores();

    for &workers in &[1usize, 2, 3, 8] {
        let want = stencil_reference(bench, interior, bt, shards, seed, total, workers);

        let dir = scratch(&format!("roundtrip-w{workers}"));
        stencil_durable_run(bench, interior, bt, shards, seed, s1, 2, &dir, workers);

        let restored = SnapshotStore::open(&dir).expect("open").restore("t0").expect("restore");
        assert_eq!(restored.fallbacks, 0, "clean frames need no fallback");
        let done = restored.checkpoint.epoch as usize * bt;
        assert!(done > 0 && done <= s1, "epoch {} out of range", restored.checkpoint.epoch);

        let f = farm(workers);
        let s = spec(bench).expect("bench");
        let d = Domain::for_spec(&s, interior).expect("domain");
        let mut t = f.handle().admit_stencil(&s, &d, shards, bt).expect("admit");
        t.restore_from(&restored.checkpoint).expect("restore_from");
        t.advance(total - done, None).expect("resume");
        let got = t.state().expect("state");
        assert!(bits_eq(&got, &want), "workers={workers}: resumed state diverged");

        let _ = std::fs::remove_dir_all(&dir);
    }
    assert!(
        counters::restores() >= restores_before + 4,
        "each round trip performs one snapshot restore"
    );
}

/// CG twin of the round trip: the restored (x, r, p, rr) recurrence
/// state must continue to the reference bits.
#[test]
fn cg_disk_restore_resumes_bit_identically() {
    let (grid, shards, seed) = (10usize, 3usize, 7u64);
    let (s1, s2) = (9usize, 6usize);
    let a = Arc::new(gen::poisson2d(grid));
    let b = gen::rhs(a.n_rows, seed);
    let rr0: f64 = b.iter().map(|v| v * v).sum();

    for &workers in &[1usize, 8] {
        // reference: one uninterrupted run
        let f = farm(workers);
        let mut t = f.handle().admit_cg(a.clone(), MergePlan::new(&a, shards)).expect("admit");
        let (mut wx, mut wr, mut wp) = (vec![0.0; a.n_rows], b.clone(), b.clone());
        let run = t.run(&mut wx, &mut wr, &mut wp, rr0, 0.0, s1 + s2).expect("run");
        assert!(run.error.is_none());
        drop(t);
        drop(f);

        // durable first leg
        let dir = scratch(&format!("cg-roundtrip-w{workers}"));
        let mut f1 = farm(workers);
        let mut t1 = f1.handle().admit_cg(a.clone(), MergePlan::new(&a, shards)).expect("admit");
        t1.configure_resilience(ResilienceConfig::disabled().every(3).durable(&dir))
            .expect("configure durable");
        let (mut x, mut r, mut p) = (vec![0.0; a.n_rows], b.clone(), b.clone());
        let run1 = t1.run(&mut x, &mut r, &mut p, rr0, 0.0, s1).expect("run");
        assert!(run1.error.is_none());
        drop(t1);
        f1.shutdown();

        // restore into a fresh farm and finish
        let restored = SnapshotStore::open(&dir).expect("open").restore("t0").expect("restore");
        let done = restored.checkpoint.epoch as usize;
        assert!(done > 0 && done <= s1);
        let (mut gx, mut gr, mut gp, grr, _) =
            restored.checkpoint.cg_state().expect("cg payload");
        let f2 = farm(workers);
        let mut t2 = f2.handle().admit_cg(a.clone(), MergePlan::new(&a, shards)).expect("admit");
        let run2 = t2.run(&mut gx, &mut gr, &mut gp, grr, 0.0, s1 + s2 - done).expect("run");
        assert!(run2.error.is_none());
        assert!(bits_eq(&gx, &wx), "workers={workers}: resumed CG iterate diverged");

        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// The acceptance drill: a child process killed mid-`advance` by
/// `FaultKind::Kill` (hard abort — the SIGKILL stand-in), restarted from
/// the snapshot directory alone, must land on the uninterrupted bits.
/// Runs the real `perks_recover crash-demo` binary over all three
/// workload cases (2D stencil bt=2, 3D stencil bt=2, CG) at every
/// acceptance worker count.
#[test]
fn process_kill_and_resume_is_bit_identical_across_workers() {
    let exe = env!("CARGO_BIN_EXE_perks_recover");
    for &workers in &[1usize, 2, 3, 8] {
        let dir = scratch(&format!("crash-w{workers}"));
        let out = std::process::Command::new(exe)
            .arg("crash-demo")
            .arg(&dir)
            .arg("--workers")
            .arg(workers.to_string())
            .arg("--case")
            .arg("all")
            .output()
            .expect("run perks_recover crash-demo");
        assert!(
            out.status.success(),
            "crash-demo --workers {workers} failed:\nstdout:\n{}\nstderr:\n{}",
            String::from_utf8_lossy(&out.stdout),
            String::from_utf8_lossy(&out.stderr)
        );
        let text = String::from_utf8_lossy(&out.stdout).to_string();
        for case in ["stencil2d", "stencil3d", "cg"] {
            assert!(
                text.contains(&format!("{case}: killed at epoch")),
                "crash-demo output missing the {case} drill:\n{text}"
            );
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// Corruption ladder on frames a real farm wrote: garbage the store
/// never committed is invisible, a torn newest frame falls back one
/// generation, and only when nothing verifies does a structured
/// [`Error::Snapshot`] surface. No step panics.
#[test]
fn corrupt_frames_fall_back_and_exhaustion_is_a_structured_error() {
    let dir = scratch("corrupt");
    // cadence 1 over 4 epochs -> generations at every epoch, DEFAULT_KEEP
    // retains the last two
    stencil_durable_run("2d5pt", &[12, 12], 2, 3, 3, 8, 1, &dir, 2);
    let store = SnapshotStore::open(&dir).expect("open");
    let tdir = dir.join("t0");

    let clean = store.restore("t0").expect("restore");
    assert_eq!(clean.fallbacks, 0);
    let entries = store.entries("t0").expect("entries");
    assert!(entries.len() >= 2, "need a fallback generation, got {entries:?}");
    let newest = entries.iter().map(|e| e.generation).max().unwrap();
    let older = entries.iter().map(|e| e.generation).filter(|&g| g != newest).max().unwrap();

    // stale tmp + unmanifested frame: restore walks the manifest only
    std::fs::write(tdir.join("gen-99.frame.tmp"), b"writer died here").unwrap();
    std::fs::write(tdir.join("gen-98.frame"), b"never manifested").unwrap();
    let got = store.restore("t0").expect("restore ignores garbage");
    assert_eq!((got.generation, got.fallbacks), (clean.generation, 0));

    // flip one payload byte of the newest frame: checksum fails, restore
    // falls back exactly one generation
    let newest_path = tdir.join(format!("gen-{newest}.frame"));
    let mut bytes = std::fs::read(&newest_path).unwrap();
    let last = bytes.len() - 1;
    bytes[last] ^= 0xff;
    std::fs::write(&newest_path, &bytes).unwrap();
    let fell = store.restore("t0").expect("fallback generation still verifies");
    assert_eq!((fell.generation, fell.fallbacks), (older, 1));
    assert!(fell.checkpoint.epoch < clean.checkpoint.epoch);
    // verify() reports the torn frame without panicking
    let statuses = store.verify("t0").expect("verify");
    assert!(statuses.iter().any(|s| s.generation == newest && s.problem.is_some()));
    assert!(statuses.iter().any(|s| s.generation == older && s.problem.is_none()));

    // truncate the fallback too: every manifested generation is now bad
    let older_path = tdir.join(format!("gen-{older}.frame"));
    let blen = std::fs::read(&older_path).unwrap().len();
    let f = std::fs::OpenOptions::new().write(true).open(&older_path).unwrap();
    f.set_len(blen as u64 / 2).unwrap();
    drop(f);
    let err = store.restore("t0").expect_err("no generation verifies");
    assert!(matches!(err, Error::Snapshot(_)), "{err}");

    // and a missing manifest is the same structured story
    std::fs::remove_file(tdir.join("MANIFEST")).unwrap();
    let err = store.restore("t0").expect_err("manifest gone");
    assert!(matches!(err, Error::Snapshot(_)), "{err}");

    let _ = std::fs::remove_dir_all(&dir);
}

/// `restore_from` validates the checkpoint against the tenant it is fed
/// into: wrong payload kind and wrong geometry are structured errors.
#[test]
fn restore_from_rejects_mismatched_checkpoints() {
    // a real stencil checkpoint off disk
    let sdir = scratch("mismatch-stencil");
    stencil_durable_run("2d5pt", &[12, 12], 2, 3, 9, 8, 2, &sdir, 2);
    let stencil_ck =
        SnapshotStore::open(&sdir).expect("open").restore("t0").expect("restore").checkpoint;
    assert!(stencil_ck.cg_state().is_none(), "stencil payload has no CG state");

    // a real CG checkpoint off disk
    let cdir = scratch("mismatch-cg");
    let a = Arc::new(gen::poisson2d(8));
    let b = gen::rhs(a.n_rows, 13);
    let rr0: f64 = b.iter().map(|v| v * v).sum();
    let mut f = farm(2);
    let mut t = f.handle().admit_cg(a.clone(), MergePlan::new(&a, 3)).expect("admit");
    t.configure_resilience(ResilienceConfig::disabled().every(2).durable(&cdir))
        .expect("configure durable");
    let (mut x, mut r, mut p) = (vec![0.0; a.n_rows], b.clone(), b);
    t.run(&mut x, &mut r, &mut p, rr0, 0.0, 6).expect("run");
    drop(t);
    f.shutdown();
    let cg_ck = SnapshotStore::open(&cdir).expect("open").restore("t0").expect("restore").checkpoint;

    let f2 = farm(2);
    let s = spec("2d5pt").expect("bench");
    // wrong geometry: a 16x16 tenant fed a 12x12 snapshot
    let d = Domain::for_spec(&s, &[16, 16]).expect("domain");
    let mut wrong_dims = f2.handle().admit_stencil(&s, &d, 3, 2).expect("admit");
    let err = wrong_dims.restore_from(&stencil_ck).expect_err("geometry mismatch");
    assert!(matches!(err, Error::Snapshot(_)), "{err}");
    assert!(err.to_string().contains("cells"), "{err}");
    // wrong payload kind: a stencil tenant fed a CG snapshot
    let err = wrong_dims.restore_from(&cg_ck).expect_err("payload kind mismatch");
    assert!(matches!(err, Error::Snapshot(_)), "{err}");

    let _ = std::fs::remove_dir_all(&sdir);
    let _ = std::fs::remove_dir_all(&cdir);
}

/// An unopenable durable directory fails at `configure_resilience` time
/// (the store opens eagerly, off the scheduler lock) — not mid-run.
#[test]
fn unopenable_durable_directory_fails_at_configure_time() {
    let dir = scratch("notdir");
    std::fs::create_dir_all(&dir).unwrap();
    let file = dir.join("occupied");
    std::fs::write(&file, b"a file, not a directory").unwrap();

    let f = farm(1);
    let s = spec("2d5pt").expect("bench");
    let d = Domain::for_spec(&s, &[8, 8]).expect("domain");
    let mut t = f.handle().admit_stencil(&s, &d, 2, 1).expect("admit");
    let err = t
        .configure_resilience(
            ResilienceConfig::disabled().every(1).durable(file.join("sub")),
        )
        .expect_err("snapshot root under a regular file cannot open");
    let msg = err.to_string();
    assert!(!msg.is_empty());

    let _ = std::fs::remove_dir_all(&dir);
}
