//! Fault-injection / recovery integration tests for the farm runtime:
//! seeded and pinned faults (panic / NaN / stall) recover from the last
//! epoch-boundary checkpoint and land bit-identically on the clean run's
//! state; retry-disabled tenants surface structured, retryable errors;
//! the watchdog deadline turns silent hangs into `Error::Stuck`.
//!
//! CI runs this suite three ways (see `.github/workflows/ci.yml`):
//! plain, under a `PERKS_FAULT_SEED` matrix (drives the property test's
//! base seed), and once more with `PERKS_FAULT_PLAN` set so the
//! env-driven test actually executes. Clean-arm farms install an empty
//! plan explicitly so a stray `PERKS_FAULT_PLAN` in the environment
//! cannot poison reference runs.

use std::sync::Arc;
use std::time::Duration;

use perks::runtime::farm::{P_COMPUTE, P_LOAD, P_SPMV};
use perks::runtime::{FaultPlan, FaultSpec, ResilienceConfig, SolverFarm};
use perks::sparse::gen;
use perks::spmv::merge::MergePlan;
use perks::stencil::{gold, spec, Domain};
use perks::util::check::{forall, Prop};
use perks::util::counters;
use perks::Error;

/// Residual tolerance that residuals (always >= 0, NaN excepted) can
/// never meet: forces the per-epoch residual fold — the stencil engine's
/// NaN detector — without ever triggering an early stop.
const TRACK: Option<f64> = Some(-1.0);

/// Clean farm CG reference run: x=0, r=p=b, fixed iteration count.
fn cg_reference(
    grid: usize,
    iters: usize,
    parts: usize,
    workers: usize,
    seed: u64,
) -> (Vec<f64>, Vec<f64>, Vec<f64>, f64) {
    let a = Arc::new(gen::poisson2d(grid));
    let b = gen::rhs(a.n_rows, seed);
    let plan = MergePlan::new(&a, parts);
    let rr0: f64 = b.iter().map(|v| v * v).sum();
    let farm = SolverFarm::spawn(workers).unwrap();
    farm.install_faults(FaultPlan::new()); // hermetic: override any env plan
    let mut t = farm.handle().admit_cg(a, plan).unwrap();
    let (mut x, mut r, mut p) = (vec![0.0; b.len()], b.clone(), b.clone());
    let run = t.run(&mut x, &mut r, &mut p, rr0, 0.0, iters).unwrap();
    assert!(run.error.is_none(), "clean CG reference errored: {:?}", run.error);
    assert_eq!(run.iters, iters);
    (x, r, p, run.rr)
}

/// An injected panic on one tenant is recovered from the checkpoint and
/// replayed to a bit-identical final state, while an unconfigured peer
/// tenant on the same farm never notices. Counters account for exactly
/// what happened.
#[test]
fn injected_panic_recovers_bit_identically_with_peer_tenants() {
    let s = spec("2d9pt").unwrap();
    let mut d = Domain::for_spec(&s, &[14, 14]).unwrap();
    d.randomize(21);
    let want = gold::run(&s, &d, 12).unwrap().data;

    let base_faults = counters::faults_injected();
    let base_recov = counters::farm_recoveries();
    let base_replay = counters::replayed_epochs();
    let base_ckpt = counters::checkpoint_bytes();

    let farm = SolverFarm::spawn(3).unwrap();
    // tenant slot 0 is the first admission in a fresh farm
    farm.install_faults(FaultPlan::new().inject(FaultSpec::panic_at(2).tenant(0)));
    let h = farm.handle();
    let mut victim = h.admit_stencil(&s, &d, 3, 1).unwrap();
    victim.configure_resilience(ResilienceConfig::recovering(2).every(3)).unwrap();
    let mut peer = h.admit_stencil(&s, &d, 3, 1).unwrap();

    let vr = victim.advance(12, None).unwrap();
    let pr = peer.advance(12, None).unwrap();

    assert!(vr.recoveries >= 1, "the injected panic was never recovered");
    assert!(vr.replayed_epochs >= 1, "recovery replayed no epochs");
    assert!(vr.checkpoint_bytes > 0, "recovery ran without any checkpoint traffic");
    assert_eq!(pr.recoveries, 0, "the fault leaked to the peer tenant");
    assert_eq!(victim.state().unwrap(), want, "recovered state diverged from gold");
    assert_eq!(peer.state().unwrap(), want, "peer state diverged from gold");

    let m = farm.metrics();
    assert_eq!(m.faults_injected, 1);
    assert!(m.recoveries >= 1);
    assert!(m.checkpoint_bytes > 0);
    assert!(counters::faults_injected() >= base_faults + 1);
    assert!(counters::farm_recoveries() >= base_recov + 1);
    assert!(counters::replayed_epochs() >= base_replay + 1);
    assert!(counters::checkpoint_bytes() > base_ckpt);
}

/// The tentpole acceptance bar: a run that panics at epoch 1 and NaNs at
/// epoch 3 recovers to the exact gold bits at every tested worker count
/// (deterministic slot-order folds make replay worker-count invariant).
#[test]
fn recovered_state_is_bit_identical_at_every_worker_count() {
    let s = spec("2d9pt").unwrap();
    let mut d = Domain::for_spec(&s, &[14, 14]).unwrap();
    d.randomize(5);
    let want = gold::run(&s, &d, 10).unwrap().data;
    for workers in [1usize, 2, 3, 8] {
        let farm = SolverFarm::spawn(workers).unwrap();
        farm.install_faults(
            FaultPlan::new().inject(FaultSpec::panic_at(1)).inject(FaultSpec::nan_at(3)),
        );
        let mut t = farm.handle().admit_stencil(&s, &d, 3, 2).unwrap();
        t.configure_resilience(ResilienceConfig::recovering(3).every(2)).unwrap();
        // TRACK forces the residual fold, which is where NaN is detected
        let run = t.advance(10, TRACK).unwrap();
        assert_eq!(run.recoveries, 2, "workers={workers}: expected both faults recovered");
        assert_eq!(farm.metrics().faults_injected, 2, "workers={workers}");
        assert_eq!(t.state().unwrap(), want, "workers={workers}: recovered state vs gold");
    }
}

/// CG: NaN poisoning of the residual vector is caught at the next r·r
/// fold and recovered to bit-identical iterates; without a retry policy
/// the same fault surfaces in-band as a structured solver error with the
/// completed iteration count intact.
#[test]
fn nan_poisoning_is_detected_and_recovered_for_cg() {
    let (grid, iters, parts, workers) = (12usize, 15usize, 4usize, 2usize);
    let (want_x, want_r, want_p, want_rr) = cg_reference(grid, iters, parts, workers, 9);

    let a = Arc::new(gen::poisson2d(grid));
    let b = gen::rhs(a.n_rows, 9);
    let plan = MergePlan::new(&a, parts);
    let rr0: f64 = b.iter().map(|v| v * v).sum();

    // recovered arm
    let farm = SolverFarm::spawn(workers).unwrap();
    farm.install_faults(FaultPlan::new().inject(FaultSpec::nan_at(4)));
    let mut t = farm.handle().admit_cg(a.clone(), plan.clone()).unwrap();
    t.configure_resilience(ResilienceConfig::recovering(2).every(3)).unwrap();
    let (mut x, mut r, mut p) = (vec![0.0; b.len()], b.clone(), b.clone());
    let run = t.run(&mut x, &mut r, &mut p, rr0, 0.0, iters).unwrap();
    assert!(run.error.is_none(), "recovered run still errored: {:?}", run.error);
    assert!(run.recoveries >= 1, "the injected NaN was never recovered");
    assert_eq!(farm.metrics().faults_injected, 1);
    for (got, want, name) in [(&x, &want_x, "x"), (&r, &want_r, "r"), (&p, &want_p, "p")] {
        let same = got.iter().zip(want).all(|(a, b)| a.to_bits() == b.to_bits());
        assert!(same, "recovered CG {name} diverged from the clean run");
    }
    assert_eq!(run.rr.to_bits(), want_rr.to_bits(), "recovered rr diverged");

    // retry-disabled arm: the NaN fired at SPMV@2 is detected at the r·r
    // fold of the same iteration — two iterations complete, then the
    // error surfaces in-band
    let farm2 = SolverFarm::spawn(workers).unwrap();
    farm2.install_faults(FaultPlan::new().inject(FaultSpec::nan_at(2)));
    let mut t2 = farm2.handle().admit_cg(a, plan).unwrap();
    let (mut x2, mut r2, mut p2) = (vec![0.0; b.len()], b.clone(), b.clone());
    let run2 = t2.run(&mut x2, &mut r2, &mut p2, rr0, 0.0, iters).unwrap();
    let err = run2.error.expect("unrecovered NaN must surface in-band");
    assert!(err.contains("non-finite"), "unexpected error text: {err}");
    assert_eq!(run2.iters, 2, "iterations completed before the poisoned fold");
    assert_eq!(run2.recoveries, 0);
}

/// Without a retry policy a worker panic surfaces as the structured
/// `Error::Fault` carrying the exact (phase, shard, epoch) coordinate,
/// classified retryable — and the farm keeps serving fresh tenants.
#[test]
fn retry_disabled_panic_surfaces_structured_fault() {
    let s = spec("2d5pt").unwrap();
    let mut d = Domain::for_spec(&s, &[12, 12]).unwrap();
    d.randomize(17);
    let want = gold::run(&s, &d, 8).unwrap().data;

    let farm = SolverFarm::spawn(2).unwrap();
    farm.install_faults(
        FaultPlan::new().inject(FaultSpec::panic_at(2).phase(P_COMPUTE).shard(0)),
    );
    let mut t = farm.handle().admit_stencil(&s, &d, 2, 1).unwrap();
    match t.advance(8, None) {
        Err(e) => {
            assert!(e.is_retryable(), "a panicked shard must classify retryable");
            match e {
                Error::Fault { phase, shard, epoch } => {
                    assert_eq!(phase, P_COMPUTE as usize);
                    assert_eq!(shard, 0);
                    assert_eq!(epoch, 2);
                }
                other => panic!("expected Error::Fault, got {other:?}"),
            }
        }
        Ok(run) => panic!("expected Error::Fault, got {run:?}"),
    }
    drop(t);

    // the farm survives the fault: a fresh tenant runs clean to gold
    let mut fresh = farm.handle().admit_stencil(&s, &d, 2, 1).unwrap();
    fresh.advance(8, None).unwrap();
    assert_eq!(fresh.state().unwrap(), want, "farm corrupted after a tenant fault");

    // CG panics surface the same structured error from the blocking run
    let a = Arc::new(gen::poisson2d(10));
    let b = gen::rhs(a.n_rows, 3);
    let plan = MergePlan::new(&a, 3);
    let rr0: f64 = b.iter().map(|v| v * v).sum();
    let farm2 = SolverFarm::spawn(2).unwrap();
    farm2.install_faults(FaultPlan::new().inject(FaultSpec::panic_at(1).phase(P_SPMV)));
    let mut c = farm2.handle().admit_cg(a.clone(), plan.clone()).unwrap();
    let (mut x, mut r, mut p) = (vec![0.0; b.len()], b.clone(), b.clone());
    match c.run(&mut x, &mut r, &mut p, rr0, 0.0, 8) {
        Err(Error::Fault { phase, epoch, .. }) => {
            assert_eq!(phase, P_SPMV as usize);
            assert_eq!(epoch, 1);
        }
        other => panic!("expected Error::Fault from CG run, got {other:?}"),
    }
    drop(c);
    let mut c2 = farm2.handle().admit_cg(a, plan).unwrap();
    let (mut x2, mut r2, mut p2) = (vec![0.0; b.len()], b.clone(), b.clone());
    let run = c2.run(&mut x2, &mut r2, &mut p2, rr0, 0.0, 8).unwrap();
    assert!(run.error.is_none());
    assert_eq!(run.iters, 8);
}

/// A stalled worker trips the blocking wait's watchdog into
/// `Error::Stuck` instead of hanging; the command keeps draining and a
/// later wait harvests the full, correct result.
#[test]
fn watchdog_deadline_surfaces_stuck_then_command_drains() {
    let s = spec("2d5pt").unwrap();
    let mut d = Domain::for_spec(&s, &[12, 12]).unwrap();
    d.randomize(29);

    let farm = SolverFarm::spawn(2).unwrap();
    farm.install_faults(
        FaultPlan::new().inject(FaultSpec::stall_at(0, Duration::from_millis(150)).phase(P_LOAD)),
    );
    let mut t = farm.handle().admit_stencil(&s, &d, 2, 1).unwrap();
    t.configure_resilience(ResilienceConfig::disabled().with_deadline(Duration::from_millis(10)))
        .unwrap();
    match t.advance(4, None) {
        Err(e) => {
            assert!(e.is_retryable(), "a stuck command must classify retryable");
            match e {
                Error::Stuck { waited_ms, .. } => {
                    assert!(waited_ms >= 10, "watchdog fired before its deadline: {waited_ms} ms")
                }
                other => panic!("expected Error::Stuck, got {other:?}"),
            }
        }
        Ok(run) => panic!("expected Error::Stuck, got {run:?}"),
    }
    // the command is still draining: re-waiting re-arms the deadline and
    // eventually harvests the completed run
    let mut run = None;
    for _ in 0..400 {
        match t.wait() {
            Ok(r) => {
                run = Some(r);
                break;
            }
            Err(Error::Stuck { .. }) => continue,
            Err(other) => panic!("unexpected error while draining: {other:?}"),
        }
    }
    let run = run.expect("stalled command never drained");
    assert_eq!(run.steps, 4);
    assert_eq!(t.state().unwrap(), gold::run(&s, &d, 4).unwrap().data);
    // the tenant is fully reusable after the stall (deadline cleared so
    // a loaded CI machine cannot trip the watchdog on the clean run)
    t.configure_resilience(ResilienceConfig::disabled()).unwrap();
    t.advance(2, None).unwrap();
    assert_eq!(t.state().unwrap(), gold::run(&s, &d, 6).unwrap().data);
}

/// `PERKS_FAULT_PLAN` drives injection with zero code: a farm spawned
/// with the variable set picks the plan up itself. Skips (loudly) when
/// the variable is unset — CI's fault-matrix job sets it and runs this
/// test alone with `--exact`, so the rest of the suite stays hermetic.
#[test]
fn env_fault_plan_drives_recovery_when_set() {
    let Some(raw) = std::env::var("PERKS_FAULT_PLAN").ok().filter(|v| !v.trim().is_empty()) else {
        eprintln!("skipping: PERKS_FAULT_PLAN not set (CI fault-matrix sets it)");
        return;
    };
    let plan = match FaultPlan::from_env() {
        Ok(Some(plan)) => plan,
        Ok(None) => panic!("PERKS_FAULT_PLAN is set ({raw:?}) but parsed to no plan"),
        Err(e) => panic!("PERKS_FAULT_PLAN is set ({raw:?}) but was rejected: {e}"),
    };
    assert!(!plan.is_empty());

    let s = spec("2d5pt").unwrap();
    let mut d = Domain::for_spec(&s, &[12, 12]).unwrap();
    d.randomize(41);
    let want = gold::run(&s, &d, 10).unwrap().data;

    // no install_faults: the farm reads the env plan at spawn
    let farm = SolverFarm::spawn(3).unwrap();
    let mut t = farm.handle().admit_stencil(&s, &d, 3, 1).unwrap();
    t.configure_resilience(ResilienceConfig::recovering(3).every(2)).unwrap();
    let run = t.advance(10, TRACK).unwrap();
    assert_eq!(t.state().unwrap(), want, "env-injected run diverged from gold");
    let injected = farm.metrics().faults_injected;
    // stall faults delay without failing; only panic/NaN plans must recover
    if injected > 0 && (raw.contains("panic") || raw.contains("nan")) {
        assert!(run.recoveries >= 1, "env plan injected {injected} faults, none recovered");
    }
}

/// A malformed fault plan is a **hard error naming the offending
/// token**, not a silently empty plan — a typo'd CI matrix entry must
/// fail the run instead of executing the workload fault-free and
/// reporting a vacuous pass. (`SolverFarm::spawn` surfaces the same
/// error when `PERKS_FAULT_PLAN` itself is malformed, via
/// `FaultPlan::from_env`.)
#[test]
fn malformed_fault_plans_fail_loudly_with_the_offending_token() {
    for (bad, token) in [
        ("meteor@epoch=1", "meteor"),              // unknown kind
        ("panic@epoch=1,zz=2", "zz"),              // unknown key
        ("panic@epoch=x", "x"),                    // non-numeric value
        ("panic@phase=1", "panic@phase=1"),        // missing epoch
        ("kill@epoch", "epoch"),                   // key without value
        ("stall@epoch=1", "stall@epoch=1"),        // stall without ms
    ] {
        let err = FaultPlan::parse(bad).expect_err("malformed plan must not parse");
        let msg = err.to_string();
        assert!(
            msg.contains(token),
            "error for {bad:?} must name the offending token {token:?}, got: {msg}"
        );
    }
    // `kill` is a first-class kind: it parses and round-trips coordinates
    let plan = FaultPlan::parse("kill@epoch=5,tenant=1").unwrap();
    assert_eq!(plan.len(), 1);
}

#[derive(Debug)]
struct FaultCase {
    seed: u64,
    workers: usize,
    kind: u64,
    cadence: u64,
}

/// Property: for random (seed, worker count, workload, checkpoint
/// cadence), a run with one seeded panic-or-NaN fault recovers to the
/// exact bits of the clean run — stencils in 2D and 3D at bt ∈ {1, 2}
/// and CG. `PERKS_FAULT_SEED` (CI matrix) rotates the case stream.
#[test]
fn seeded_faults_recover_bit_identically_property() {
    let base = std::env::var("PERKS_FAULT_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(7u64);
    forall(
        base,
        10,
        |rng| FaultCase {
            seed: rng.next_u64(),
            workers: 1 + rng.index(4),
            kind: rng.below(5),
            cadence: rng.below(5),
        },
        |case| {
            let cfg = ResilienceConfig::recovering(2).every(case.cadence);
            if case.kind == 4 {
                // CG over the 2D Poisson operator
                let (grid, iters, parts) = (10usize, 12usize, 5usize);
                let (want_x, _, _, want_rr) = cg_reference(grid, iters, parts, case.workers, 13);
                let a = Arc::new(gen::poisson2d(grid));
                let b = gen::rhs(a.n_rows, 13);
                let rr0: f64 = b.iter().map(|v| v * v).sum();
                let farm = SolverFarm::spawn(case.workers).unwrap();
                farm.install_faults(FaultPlan::seeded(case.seed, iters as u64, parts));
                let mut t =
                    farm.handle().admit_cg(a.clone(), MergePlan::new(&a, parts)).unwrap();
                t.configure_resilience(cfg).unwrap();
                let (mut x, mut r, mut p) = (vec![0.0; b.len()], b.clone(), b.clone());
                let run = match t.run(&mut x, &mut r, &mut p, rr0, 0.0, iters) {
                    Ok(run) => run,
                    Err(e) => return Prop::Fail(format!("faulted CG run failed: {e}")),
                };
                if let Some(e) = run.error {
                    return Prop::Fail(format!("faulted CG run errored in-band: {e}"));
                }
                if farm.metrics().faults_injected != 1 {
                    return Prop::Fail("seeded fault never fired".into());
                }
                let same = x.iter().zip(&want_x).all(|(a, b)| a.to_bits() == b.to_bits());
                Prop::check(
                    same && run.rr.to_bits() == want_rr.to_bits(),
                    "recovered CG diverged from the clean run",
                )
            } else {
                let (name, interior, steps, bt): (&str, &[usize], usize, usize) = match case.kind {
                    0 => ("2d5pt", &[10, 12], 8, 1),
                    1 => ("2d5pt", &[10, 12], 8, 2),
                    2 => ("3d13pt", &[6, 6, 6], 6, 1),
                    _ => ("3d13pt", &[6, 6, 6], 6, 2),
                };
                let s = spec(name).unwrap();
                let mut d = Domain::for_spec(&s, interior).unwrap();
                d.randomize(case.seed ^ 0x5eed);
                let want = gold::run(&s, &d, steps).unwrap().data;
                let shards = 3usize;
                let epochs = steps.div_ceil(bt) as u64;
                let farm = SolverFarm::spawn(case.workers).unwrap();
                farm.install_faults(FaultPlan::seeded(case.seed, epochs, shards));
                let mut t = farm.handle().admit_stencil(&s, &d, shards, bt).unwrap();
                t.configure_resilience(cfg).unwrap();
                // TRACK forces the residual fold that detects NaN faults
                let run = match t.advance(steps, TRACK) {
                    Ok(run) => run,
                    Err(e) => return Prop::Fail(format!("faulted stencil run failed: {e}")),
                };
                if farm.metrics().faults_injected != 1 {
                    return Prop::Fail("seeded fault never fired".into());
                }
                if run.recoveries < 1 {
                    return Prop::Fail("fault fired but no recovery was counted".into());
                }
                Prop::check(
                    t.state().unwrap() == want,
                    "recovered stencil state diverged from gold",
                )
            }
        },
    );
}
