//! Integration tests of the async submission plane: completion futures
//! and the `LocalExecutor` must be observably identical to the blocking
//! wrappers (same bits at every worker count), batched command graphs
//! must be bit-identical to monolithic submits while taking exactly one
//! scheduler-lock acquisition per batch, and admission control must
//! shed/timeout/block deterministically — including the zombie paths
//! (dropped futures, released tenants, shutdown mid-flight).

use std::sync::Arc;
use std::time::Duration;

use perks::runtime::farm::SolverFarm;
use perks::runtime::plane::{
    block_on, AdmissionPolicy, CommandGraph, LocalExecutor, PlaneConfig,
};
use perks::sparse::gen;
use perks::spmv::merge::MergePlan;
use perks::stencil::{gold, spec, Domain};
use perks::util::counters;

fn domain(seed: u64, dims: &[usize]) -> Domain {
    let s = spec("2d5pt").unwrap();
    let mut d = Domain::for_spec(&s, dims).unwrap();
    d.randomize(seed);
    d
}

/// The async acceptance bar: futures + executor walk the blocking path's
/// bits (which walk gold) at farm worker counts {1, 2, 8}, for both
/// plain submits and batched graphs, stencil and CG.
#[test]
fn async_paths_are_bit_identical_to_blocking_at_every_worker_count() {
    let s = spec("2d5pt").unwrap();
    let d = domain(11, &[12, 12]);
    let want = gold::run(&s, &d, 10).unwrap();
    let a = gen::poisson2d(12);
    let b = gen::rhs(a.n_rows, 5);
    let rr0: f64 = b.iter().map(|v| v * v).sum();

    for workers in [1usize, 2, 8] {
        let farm = SolverFarm::spawn(workers).unwrap();
        let h = farm.handle();

        // blocking reference tenants
        let mut blocking = h.admit_stencil(&s, &d, 2, 1).unwrap();
        blocking.advance(10, None).unwrap();
        let n = a.n_rows;
        let mut cg_blocking = h.admit_cg(Arc::new(a.clone()), MergePlan::new(&a, 4)).unwrap();
        let (mut bx, mut br, mut bp) = (vec![0.0; n], b.clone(), b.clone());
        let brun = cg_blocking.run(&mut bx, &mut br, &mut bp, rr0, 0.0, 12).unwrap();

        // async twins, driven by block_on (single future) ...
        let mut t1 = h.admit_stencil(&s, &d, 2, 1).unwrap();
        let run1 = block_on(async { t1.advance_async(10, None).await }).unwrap();
        assert_eq!(run1.steps, 10);
        assert_eq!(t1.state().unwrap(), blocking.state().unwrap(), "workers={workers}");
        assert_eq!(t1.state().unwrap(), want.data, "workers={workers}: async vs gold");

        // ... by the executor (graph submit) ...
        let mut t2 = h.admit_stencil(&s, &d, 2, 1).unwrap();
        let graph = CommandGraph::schedule(10, 4, None).unwrap();
        let ex = LocalExecutor::new();
        let run2 = ex.run(async { t2.advance_graph_async(&graph).await }).unwrap();
        assert_eq!(run2.steps, 10);
        assert_eq!(t2.state().unwrap(), want.data, "workers={workers}: graph async vs gold");

        // ... and the CG async twin
        let mut cg = h.admit_cg(Arc::new(a.clone()), MergePlan::new(&a, 4)).unwrap();
        let (mut x, mut r, mut p) = (vec![0.0; n], b.clone(), b.clone());
        let arun =
            block_on(async { cg.run_async(&mut x, &mut r, &mut p, rr0, 0.0, 12).await }).unwrap();
        assert_eq!(arun.iters, brun.iters);
        assert_eq!(arun.rr.to_bits(), brun.rr.to_bits(), "workers={workers}");
        assert_eq!(x, bx, "workers={workers}: async CG x diverged");
    }
}

/// A batched graph is bit-identical to one monolithic submit — same
/// state, same step count, same slow-tier traffic — and the whole chain
/// costs exactly one scheduler-lock acquisition.
#[test]
fn graph_run_matches_monolithic_including_traffic_and_lock_accounting() {
    let s = spec("2d5pt").unwrap();
    let d = domain(3, &[14, 10]);
    let farm = SolverFarm::spawn(2).unwrap();
    let h = farm.handle();

    let mut mono = h.admit_stencil(&s, &d, 2, 2).unwrap();
    let mrun = mono.advance(12, None).unwrap();

    let m0 = farm.metrics();
    // the process-global counters must move with the per-farm metrics
    // (deltas with >=: other tests' farms bump them concurrently)
    let c_batches = counters::plane_batches();
    let c_locks = counters::sched_lock_acquisitions();
    let mut batched = h.admit_stencil(&s, &d, 2, 2).unwrap();
    let graph = CommandGraph::schedule(12, 5, None).unwrap(); // 5 + 5 + 2
    assert_eq!(graph.segments(), &[5, 5, 2]);
    let grun = batched.advance_graph(&graph).unwrap();
    let m1 = farm.metrics();

    assert_eq!(grun.steps, mrun.steps);
    assert_eq!(grun.global_bytes, mrun.global_bytes, "graph changed traffic accounting");
    assert_eq!(grun.computed_cells, mrun.computed_cells);
    assert_eq!(batched.state().unwrap(), mono.state().unwrap());
    // the tentpole counter invariant: 3 segments, ONE batch, ONE lock
    assert_eq!(m1.plane_batches - m0.plane_batches, 1);
    assert_eq!(
        m1.sched_lock_acquisitions - m0.sched_lock_acquisitions,
        1,
        "graph segments must chain inside completion transitions"
    );
    assert_eq!(m1.sched_lock_acquisitions, m1.plane_batches);
    assert!(counters::plane_batches() >= c_batches + 1);
    assert!(counters::sched_lock_acquisitions() >= c_locks + 1);
}

/// Satellite: double submit is a contract error on the stencil path too
/// (the CG twin lives in the farm unit tests) — and it must error even
/// under a full queue + Block policy, never self-deadlock.
#[test]
fn stencil_double_submit_is_an_error_not_a_deadlock() {
    let s = spec("2d5pt").unwrap();
    let d = domain(9, &[10, 10]);
    let farm = SolverFarm::spawn_with(1, PlaneConfig::bounded(1)).unwrap();
    let mut t = farm.handle().admit_stencil(&s, &d, 1, 1).unwrap();
    t.submit(2_000, None).unwrap();
    let err = t.submit(1, None).unwrap_err();
    assert!(format!("{err}").contains("in flight"), "{err}");
    let run = t.wait().unwrap();
    assert_eq!(run.steps, 2_000);
    assert_eq!(farm.metrics().plane_inflight_peak, 1);
    // tenant stays usable
    t.advance(1, None).unwrap();
}

/// A graph tolerance stop clears the remaining segments: the command
/// ends early, later segments never run, and the tenant stays usable.
#[test]
fn graph_tolerance_stop_clears_remaining_segments() {
    let s = spec("2d5pt").unwrap();
    let d = domain(21, &[12, 12]);
    let farm = SolverFarm::spawn(2).unwrap();
    let mut t = farm.handle().admit_stencil(&s, &d, 1, 1).unwrap();
    // a tolerance every epoch satisfies: converges inside segment one
    let graph =
        CommandGraph::builder().segment(4).segment(4).segment(4).tolerance(1e300).build().unwrap();
    let run = t.advance_graph(&graph).unwrap();
    assert!(run.steps < graph.total(), "tolerance stop must drop the remaining segments");
    assert!(run.residual.is_some());
    // chained segments are gone: the next command starts fresh
    let again = t.advance(3, None).unwrap();
    assert_eq!(again.steps, 3);
}

/// Resubmission replays the stored schedule when the target is reached
/// unconverged: total steps = (1 + resubmits) * schedule total.
#[test]
fn graph_resubmission_replays_the_schedule_until_exhausted() {
    let s = spec("2d5pt").unwrap();
    let d = domain(22, &[12, 12]);
    let farm = SolverFarm::spawn(2).unwrap();
    let mut t = farm.handle().admit_stencil(&s, &d, 1, 1).unwrap();
    // an unreachable tolerance: every replay runs to its step target
    let graph =
        CommandGraph::builder().segments(&[3, 3]).tolerance(1e-300).resubmit(2).build().unwrap();
    let run = t.advance_graph(&graph).unwrap();
    assert_eq!(run.steps, 6 * 3, "2 resubmits = 3 full schedules");
    // still one batch, one lock acquisition for the whole replayed chain
    let m = farm.metrics();
    assert_eq!(m.sched_lock_acquisitions, m.plane_batches);
}

/// A batch larger than the plane's caps can never be admitted: it is
/// shed immediately regardless of policy (Block would deadlock forever).
#[test]
fn oversized_batches_are_shed_immediately_even_under_block_policy() {
    let s = spec("2d5pt").unwrap();
    let d = domain(4, &[10, 10]);
    // queue cap 2, blocking policy: a 3-segment graph can never fit
    let farm =
        SolverFarm::spawn_with(1, PlaneConfig::bounded(2).policy(AdmissionPolicy::Block)).unwrap();
    let mut t = farm.handle().admit_stencil(&s, &d, 1, 1).unwrap();
    let graph = CommandGraph::schedule(6, 2, None).unwrap();
    match t.submit_graph(&graph) {
        Err(perks::Error::Shed(msg)) => assert!(msg.contains("capacity"), "{msg}"),
        other => panic!("expected Shed, got {other:?}"),
    }
    // per-tenant cap triggers the same immediate shed on an open queue
    let farm2 = SolverFarm::spawn_with(1, PlaneConfig::unbounded().per_tenant(2)).unwrap();
    let mut t2 = farm2.handle().admit_stencil(&s, &d, 1, 1).unwrap();
    assert!(matches!(t2.submit_graph(&graph), Err(perks::Error::Shed(_))));
    assert_eq!(farm2.metrics().plane_sheds, 1);
    // both tenants remain usable after the rejection
    t.advance(1, None).unwrap();
    t2.advance(1, None).unwrap();
}

/// Shed policy: a full queue rejects instantly; harvesting the holder
/// frees the slot and the rejected tenant's resubmission goes through.
#[test]
fn shed_policy_rejects_on_a_full_queue_then_recovers() {
    let s = spec("2d5pt").unwrap();
    let da = domain(5, &[10, 10]);
    let db = domain(6, &[10, 10]);
    let farm =
        SolverFarm::spawn_with(1, PlaneConfig::bounded(1).policy(AdmissionPolicy::Shed)).unwrap();
    let h = farm.handle();
    let mut a = h.admit_stencil(&s, &da, 1, 1).unwrap();
    let mut b = h.admit_stencil(&s, &db, 1, 1).unwrap();
    let c_sheds = counters::plane_sheds();
    a.submit(4, None).unwrap(); // holds the only slot until harvested
    match b.submit(1, None) {
        Err(perks::Error::Shed(msg)) => assert!(msg.contains("full"), "{msg}"),
        other => panic!("expected Shed, got {other:?}"),
    }
    assert_eq!(farm.metrics().plane_sheds, 1);
    assert!(counters::plane_sheds() >= c_sheds + 1, "global shed counter must move too");
    a.wait().unwrap(); // harvest releases the slot
    let run = b.advance(1, None).unwrap();
    assert_eq!(run.steps, 1);
    assert_eq!(farm.metrics().plane_sheds, 1, "recovered submit sheds nothing");
}

/// Timeout policy: a submission that cannot get a slot within the bound
/// fails with `Error::Timeout`; after the holder harvests, it succeeds.
#[test]
fn timeout_policy_expires_then_recovers_after_harvest() {
    let s = spec("2d5pt").unwrap();
    let da = domain(7, &[10, 10]);
    let db = domain(8, &[10, 10]);
    let cfg = PlaneConfig::bounded(1).policy(AdmissionPolicy::Timeout(Duration::from_millis(30)));
    let farm = SolverFarm::spawn_with(1, cfg).unwrap();
    let h = farm.handle();
    let mut a = h.admit_stencil(&s, &da, 1, 1).unwrap();
    let mut b = h.admit_stencil(&s, &db, 1, 1).unwrap();
    let c_timeouts = counters::plane_timeouts();
    a.submit(4, None).unwrap();
    match b.submit(1, None) {
        Err(perks::Error::Timeout(msg)) => assert!(msg.contains("slot"), "{msg}"),
        other => panic!("expected Timeout, got {other:?}"),
    }
    assert_eq!(farm.metrics().plane_timeouts, 1);
    assert!(counters::plane_timeouts() >= c_timeouts + 1, "global timeout counter must move too");
    a.wait().unwrap();
    b.advance(1, None).unwrap();
    assert_eq!(farm.metrics().plane_timeouts, 1);
}

/// Block policy: a submission parks until the holder harvests, then
/// proceeds — cross-thread, no error, no spin.
#[test]
fn block_policy_parks_until_a_slot_frees() {
    let s = spec("2d5pt").unwrap();
    let da = domain(13, &[10, 10]);
    let db = domain(14, &[10, 10]);
    let farm =
        SolverFarm::spawn_with(1, PlaneConfig::bounded(1).policy(AdmissionPolicy::Block)).unwrap();
    let h = farm.handle();
    let mut a = h.admit_stencil(&s, &da, 1, 1).unwrap();
    let mut b = h.admit_stencil(&s, &db, 1, 1).unwrap();
    a.submit(4, None).unwrap();
    std::thread::scope(|scope| {
        let blocked = scope.spawn(move || b.advance(2, None).map(|r| r.steps));
        // give the blocked submitter time to park on the gate, then free it
        std::thread::sleep(Duration::from_millis(20));
        a.wait().unwrap();
        assert_eq!(blocked.join().unwrap().unwrap(), 2);
    });
    let m = farm.metrics();
    assert_eq!(m.plane_sheds, 0);
    assert_eq!(m.plane_timeouts, 0);
    assert_eq!(m.plane_inflight_peak, 1, "cap 1 was never exceeded");
}

/// Dropping an unresolved completion future releases its plane slots
/// (the command keeps running); the tenant can still harvest later.
#[test]
fn dropping_a_completion_future_releases_its_slots() {
    let s = spec("2d5pt").unwrap();
    let da = domain(15, &[10, 10]);
    let db = domain(16, &[10, 10]);
    let farm =
        SolverFarm::spawn_with(1, PlaneConfig::bounded(1).policy(AdmissionPolicy::Shed)).unwrap();
    let h = farm.handle();
    let mut a = h.admit_stencil(&s, &da, 1, 1).unwrap();
    let mut b = h.admit_stencil(&s, &db, 1, 1).unwrap();
    let fut = a.submit_async(6, None).unwrap();
    drop(fut); // zombie future: slot must come back without a harvest
    let run = b.advance(1, None).unwrap(); // would be Shed if the slot leaked
    assert_eq!(run.steps, 1);
    // the abandoned command still completes and can be harvested late
    let arun = a.wait().unwrap();
    assert_eq!(arun.steps, 6);
    assert_eq!(farm.metrics().plane_sheds, 0);
}

/// Releasing a tenant with a command in flight (the zombie tenant path)
/// frees its plane slots for everyone else.
#[test]
fn releasing_a_tenant_mid_flight_frees_its_slots() {
    let s = spec("2d5pt").unwrap();
    let da = domain(17, &[10, 10]);
    let db = domain(18, &[10, 10]);
    let farm =
        SolverFarm::spawn_with(1, PlaneConfig::bounded(1).policy(AdmissionPolicy::Shed)).unwrap();
    let h = farm.handle();
    let mut a = h.admit_stencil(&s, &da, 1, 1).unwrap();
    let mut b = h.admit_stencil(&s, &db, 1, 1).unwrap();
    a.submit(2_000, None).unwrap();
    drop(a); // release with the command still in flight
    let run = b.advance(1, None).unwrap();
    assert_eq!(run.steps, 1);
    assert_eq!(farm.metrics().plane_sheds, 0, "zombie tenant leaked its slot");
}

/// Shutdown with a command in flight resolves the async waiter with an
/// error instead of hanging the executor.
#[test]
fn shutdown_mid_flight_errors_the_async_waiter() {
    let s = spec("2d5pt").unwrap();
    let d = domain(19, &[32, 32]);
    let mut farm = SolverFarm::spawn(1).unwrap();
    let mut t = farm.handle().admit_stencil(&s, &d, 1, 1).unwrap();
    // far too long to complete before the shutdown flag lands
    t.submit(5_000_000, None).unwrap();
    farm.shutdown();
    let err = block_on(async { t.completion().await }).unwrap_err();
    assert!(format!("{err}").contains("shut down"), "{err}");
    // and a fresh submit reports shutdown synchronously
    let err2 = t.submit(1, None).unwrap_err();
    assert!(format!("{err2}").contains("shut down"), "{err2}");
}

/// Hundreds of async tenants multiplex on ONE executor thread: all
/// complete, bits match gold, and the lock/batch accounting stays 1:1.
#[test]
fn hundreds_of_tenants_multiplex_on_one_executor() {
    let s = spec("2d5pt").unwrap();
    let tenants = 256usize;
    let rounds = 2usize;
    let farm = SolverFarm::spawn(4).unwrap();
    let h = farm.handle();
    let graph = CommandGraph::schedule(4, 2, None).unwrap();
    let mut sessions = Vec::with_capacity(tenants);
    for t in 0..tenants {
        let d = domain(900 + t as u64, &[8, 8]);
        sessions.push(h.admit_stencil(&s, &d, 1, 1).unwrap());
    }
    let ex = LocalExecutor::new();
    let states: Vec<Vec<f64>> = ex.run(async {
        let mut joins = Vec::with_capacity(tenants);
        for mut sess in sessions {
            let graph = graph.clone();
            joins.push(ex.spawn(async move {
                for _ in 0..rounds {
                    sess.advance_graph_async(&graph).await.unwrap();
                }
                sess.state().unwrap()
            }));
        }
        let mut out = Vec::with_capacity(tenants);
        for j in joins {
            out.push(j.await);
        }
        out
    });
    assert_eq!(states.len(), tenants);
    // spot-check the first and last tenants against gold
    for t in [0usize, tenants - 1] {
        let d = domain(900 + t as u64, &[8, 8]);
        let want = gold::run(&s, &d, 4 * rounds).unwrap();
        assert_eq!(states[t], want.data, "tenant {t} diverged under multiplexing");
    }
    let m = farm.metrics();
    assert_eq!(m.plane_batches, (tenants * rounds) as u64);
    assert_eq!(m.sched_lock_acquisitions, m.plane_batches);
    assert_eq!(m.plane_sheds, 0);
    assert_eq!(m.plane_timeouts, 0);
}

/// The session layer rides the plane too: `batch_epochs` turns every
/// advance into one graph batch, keeps the bits, and surfaces the plane
/// counters through `Report`.
#[test]
fn session_batch_epochs_keeps_bits_and_reports_plane_counters() {
    use perks::session::{Backend, ExecMode, SessionBuilder};
    let build = |farm: Option<&SolverFarm>, batch: usize| {
        let mut b = SessionBuilder::stencil("2d5pt", "16x16", "f64")
            .temporal(2)
            .backend(Backend::cpu(2))
            .mode(ExecMode::Persistent)
            .seed(42);
        if let Some(f) = farm {
            b = b.farm(f);
        }
        b.batch_epochs(batch).build()
    };
    let mut solo = build(None, 0).unwrap();
    solo.advance(12).unwrap();

    let farm = SolverFarm::spawn(2).unwrap();
    let mut batched = build(Some(&farm), 3).unwrap();
    batched.advance(12).unwrap();
    assert_eq!(batched.state_f64().unwrap(), solo.state_f64().unwrap());
    let rep = batched.report();
    assert_eq!(rep.plane_batches, Some(1), "one advance = one graph batch");
    assert_eq!(rep.plane_sheds, Some(0));
    assert_eq!(rep.plane_timeouts, Some(0));
    // solo sessions don't fabricate plane numbers
    assert_eq!(solo.report().plane_batches, None);
    // batching without a farm is a build-time contract error
    assert!(build(None, 3).is_err());
}
