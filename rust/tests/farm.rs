//! Integration tests of the multi-tenant serving path: sessions built
//! with `SessionBuilder::farm(&farm)` must be observably identical to
//! their solo-pool builds — same bits, same stop epochs, same Report
//! accounting shape — at every farm worker count, including mixed
//! stencil + CG tenant populations and resumed advances.

use perks::runtime::farm::SolverFarm;
use perks::session::{Backend, ExecMode, SessionBuilder};
use perks::util::counters;

fn solo_stencil(interior: &str, seed: u64, bt: usize) -> perks::Session {
    SessionBuilder::stencil("2d5pt", interior, "f64")
        .temporal(bt)
        .backend(Backend::cpu(3))
        .mode(ExecMode::Persistent)
        .seed(seed)
        .build()
        .unwrap()
}

fn farm_stencil(farm: &SolverFarm, interior: &str, seed: u64, bt: usize) -> perks::Session {
    SessionBuilder::stencil("2d5pt", interior, "f64")
        .temporal(bt)
        .backend(Backend::cpu(3))
        .mode(ExecMode::Persistent)
        .seed(seed)
        .farm(farm)
        .build()
        .unwrap()
}

/// The acceptance bar: farm sessions walk their solo-pool bits at farm
/// worker counts {1, 2, 3, 8}, across resumed advances, at bt ∈ {1, 2}.
#[test]
fn farm_sessions_are_bit_identical_to_solo_sessions_across_worker_counts() {
    // process-global monotonic counters: other tests run concurrently, so
    // assert deltas with >=, never ==
    let base_admissions = counters::farm_admissions();
    let base_commands = counters::farm_commands();
    let base_tasks = counters::farm_tasks();
    for bt in [1usize, 2] {
        let mut solo = solo_stencil("16x16", 7, bt);
        solo.advance(5).unwrap();
        solo.advance(6).unwrap();
        let want = solo.state_f64().unwrap();
        for workers in [1usize, 2, 3, 8] {
            let farm = SolverFarm::spawn(workers).unwrap();
            let mut s = farm_stencil(&farm, "16x16", 7, bt);
            assert_eq!(s.mode(), ExecMode::Persistent);
            assert_eq!(s.temporal_degree(), bt);
            s.advance(5).unwrap();
            s.advance(6).unwrap();
            assert_eq!(
                s.state_f64().unwrap(),
                want,
                "bt={bt} workers={workers}: farm session diverged from solo"
            );
            let rep = s.report();
            assert_eq!(rep.steps, 11);
            assert_eq!(rep.invocations, 2, "one farm command per advance");
            assert!(rep.queue_wait_seconds.is_some(), "farm sessions report queue wait");
            // admission + advances reused the startup worker set
            assert_eq!(farm.spawn_count(), workers as u64);
        }
    }
    // 2 bt values x 4 worker counts: 8 admissions, 2 commands each, and
    // every command fans out into at least one worker task
    assert!(counters::farm_admissions() >= base_admissions + 8);
    assert!(counters::farm_commands() >= base_commands + 16);
    assert!(counters::farm_tasks() >= base_tasks + 16);
}

/// Mixed stencil + CG tenants sharing one farm, driven through the
/// session API, each bit-identical to its solo build.
#[test]
fn mixed_stencil_and_cg_sessions_share_one_farm_bit_identically() {
    // solo references
    let mut solo_st = solo_stencil("14x14", 3, 1);
    solo_st.advance(8).unwrap();
    let want_st = solo_st.state_f64().unwrap();
    let mut solo_cg = SessionBuilder::cg(144)
        .backend(Backend::cpu(2))
        .mode(ExecMode::Persistent)
        .seed(5)
        .build()
        .unwrap();
    solo_cg.advance(12).unwrap();
    let want_cg = solo_cg.state_f64().unwrap();
    let want_rr = solo_cg.report().residual.unwrap();

    let farm = SolverFarm::spawn(3).unwrap();
    let mut st = farm_stencil(&farm, "14x14", 3, 1);
    let mut cg = SessionBuilder::cg(144)
        .backend(Backend::cpu(2))
        .mode(ExecMode::Persistent)
        .seed(5)
        .farm(&farm)
        .build()
        .unwrap();
    // interleaved advances on the shared workers
    st.advance(3).unwrap();
    cg.advance(7).unwrap();
    st.advance(5).unwrap();
    cg.advance(5).unwrap();
    assert_eq!(st.state_f64().unwrap(), want_st, "stencil tenant vs solo");
    assert_eq!(cg.state_f64().unwrap(), want_cg, "cg tenant vs solo");
    assert_eq!(
        cg.report().residual.unwrap().to_bits(),
        want_rr.to_bits(),
        "cg recurrence bits"
    );
    let m = farm.metrics();
    assert_eq!(m.admissions, 2);
    assert!(m.commands >= 4);
    assert_eq!(farm.spawn_count(), 3, "mixed tenants spawned nothing");
}

/// `advance_until` through a farm stops on the same epoch with the same
/// residual bits as the solo session, at every farm worker count.
#[test]
fn farm_advance_until_stops_on_the_solo_epoch() {
    let (tol, max) = (1e-8, 20_000);
    let mut solo = solo_stencil("8x8", 21, 1);
    let want_steps = solo.advance_until(tol, max).unwrap();
    assert!(want_steps > 0 && want_steps < max, "solo did not converge");
    let want_res = solo.report().residual.unwrap();
    let want_state = solo.state_f64().unwrap();
    for workers in [1usize, 2, 8] {
        let farm = SolverFarm::spawn(workers).unwrap();
        let mut s = farm_stencil(&farm, "8x8", 21, 1);
        let steps = s.advance_until(tol, max).unwrap();
        assert_eq!(steps, want_steps, "workers={workers}: stop step");
        let rep = s.report();
        assert_eq!(
            rep.residual.unwrap().to_bits(),
            want_res.to_bits(),
            "workers={workers}: residual bits"
        );
        assert_eq!(rep.steps, steps);
        assert_eq!(s.state_f64().unwrap(), want_state, "workers={workers}: state bits");
    }
    // CG convergence path: same iterate count and recurrence bits
    let mut solo_cg = SessionBuilder::cg(100)
        .backend(Backend::cpu(2))
        .mode(ExecMode::Persistent)
        .seed(6)
        .build()
        .unwrap();
    let solo_iters = solo_cg.advance_until(1e-10, 10_000).unwrap();
    assert!(solo_iters < 10_000);
    let farm = SolverFarm::spawn(2).unwrap();
    let mut cg = SessionBuilder::cg(100)
        .backend(Backend::cpu(2))
        .mode(ExecMode::Persistent)
        .seed(6)
        .farm(&farm)
        .build()
        .unwrap();
    let iters = cg.advance_until(1e-10, 10_000).unwrap();
    assert_eq!(iters, solo_iters);
    assert_eq!(
        cg.report().residual.unwrap().to_bits(),
        solo_cg.report().residual.unwrap().to_bits()
    );
    assert_eq!(cg.state_f64().unwrap(), solo_cg.state_f64().unwrap());
}

/// `prepare()` re-entry on a farm session releases the old tenant,
/// admits a fresh one, and restarts from x0 — without spawning.
#[test]
fn farm_session_prepare_reentry_readmits_cleanly() {
    let farm = SolverFarm::spawn(2).unwrap();
    let mut s = farm_stencil(&farm, "12x12", 4, 1);
    s.advance(6).unwrap();
    s.prepare().unwrap();
    s.advance(2).unwrap();
    let mut solo = solo_stencil("12x12", 4, 1);
    solo.advance(2).unwrap();
    assert_eq!(s.state_f64().unwrap(), solo.state_f64().unwrap(), "restart runs from x0");
    assert_eq!(s.report().steps, 2, "metrics reset on re-entry");
    assert_eq!(farm.spawn_count(), 2, "re-admission spawned nothing");
    assert!(farm.metrics().admissions >= 2);
}

/// A farm outliving its sessions and sessions outliving the farm both
/// degrade safely: shutdown turns subsequent advances into errors.
#[test]
fn sessions_surviving_farm_shutdown_error_instead_of_hanging() {
    let mut farm = SolverFarm::spawn(2).unwrap();
    let mut s = farm_stencil(&farm, "8x8", 2, 1);
    s.advance(2).unwrap();
    farm.shutdown();
    let err = s.advance(1).unwrap_err();
    assert!(format!("{err}").contains("shut down"), "{err}");
}
