//! Known-bad fixture for `no-panic` (lives under a `runtime/` path, so
//! the rule is in scope). A panic here strands a countdown or poisons a
//! pool instead of surfacing a structured, recoverable `Error::Fault`.

fn harvest(g: &mut FarmState, tid: usize) -> Run {
    // BAD: released-tenant race becomes an abort, not an error
    let t = g.tenants[tid].as_mut().unwrap();
    // BAD: same class, with prose attached
    let ck = t.checkpoint.take().expect("restore without a checkpoint");
    if t.zombie {
        // BAD: bare panic in recoverable code
        panic!("zombie tenant harvested");
    }
    t.finish(ck)
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_in_tests_is_fine() {
        let x: Option<u32> = Some(1);
        assert_eq!(x.unwrap(), 1);
    }
}
