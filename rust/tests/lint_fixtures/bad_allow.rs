//! Known-bad fixture for `lint-allow`: a suppression with no written
//! justification. Every `allow` must carry the argument for why the
//! site is sound — same contract as `// SAFETY:`.

fn f(buf: &SharedBuf) -> usize {
    // lint: allow(unsafe-safety)
    unsafe { (*buf.0.get()).len() }
}
