//! Companion file in the counter-coverage fixture tree: exercises
//! `used_counter` on both sides (incremented and asserted) so only the
//! orphan is flagged.

fn spawn_worker() {
    crate::util::counters::note_used_counter(1);
}

fn audit() {
    assert!(crate::util::counters::used_counter() >= 1);
}
