//! Known-bad fixture tree for `counter-coverage`: `orphan_counter` is
//! declared with the full note/getter pair but nothing outside this
//! module ever increments or asserts it — an invariant nobody checks.

use std::sync::atomic::{AtomicU64, Ordering};

static USED: AtomicU64 = AtomicU64::new(0);
static ORPHAN: AtomicU64 = AtomicU64::new(0);

pub fn note_used_counter(n: u64) {
    USED.fetch_add(n, Ordering::Release);
}

pub fn used_counter() -> u64 {
    USED.load(Ordering::Acquire)
}

pub fn note_orphan_counter(n: u64) {
    ORPHAN.fetch_add(n, Ordering::Release);
}

pub fn orphan_counter() -> u64 {
    ORPHAN.load(Ordering::Acquire)
}
