//! Known-bad fixture for `lock-order`: acquisitions inverting the
//! declared hierarchy, plus a same-level re-acquisition (std mutexes
//! are not reentrant — that one is a guaranteed self-deadlock).

// lock-order: sched < tenant < slab

fn inverted(f: &Farm) {
    let slab = f.slab.lock().unwrap_or_else(|p| p.into_inner());
    // BAD: sched ranks below slab, so it must be taken first
    let sched = f.sched.lock().unwrap_or_else(|p| p.into_inner());
    drop(sched);
    drop(slab);
}

fn reentrant(f: &Farm) {
    let a = f.tenant.lock().unwrap_or_else(|p| p.into_inner());
    // BAD: tenant is already held — self-deadlock
    let b = f.tenant.lock().unwrap_or_else(|p| p.into_inner());
    drop(b);
    drop(a);
}

fn fine(f: &Farm) {
    let sched = f.sched.lock().unwrap_or_else(|p| p.into_inner());
    let slab = f.slab.lock().unwrap_or_else(|p| p.into_inner());
    drop(slab);
    drop(sched);
}
