//! Known-bad fixture for `hot-path-alloc`: allocating calls inside a
//! fenced advance loop. The PERKS story is zero alloc / zero spawn per
//! iteration — each of these pays per epoch.

fn advance(state: &mut State, steps: usize) {
    // hot-path: begin
    for _ in 0..steps {
        // BAD: fresh vector every iteration
        let scratch: Vec<f64> = Vec::new();
        // BAD: clone of the resident buffer
        let snapshot = state.grid.clone();
        // BAD: formatting allocates even when the string is discarded
        let label = format!("epoch {}", state.epoch);
        state.consume(scratch, snapshot, label);
    }
    // hot-path: end
}

fn unbalanced(state: &mut State) {
    // hot-path: begin
    state.step();
    // BAD: fence never closed before end of file
}
