//! Known-bad fixture for `condvar-shutdown`: the PR-5 teardown race.
//! The worker re-checks only the epoch stamp on wake — a shutdown
//! signalled while it is parked across stamp changes is never observed
//! and the thread is stranded forever.

fn worker_main(sh: &Shared, mut seen: u64) {
    let mut g = sh.ctl.lock().unwrap_or_else(|p| p.into_inner());
    loop {
        if g.epoch != seen {
            break;
        }
        // BAD: wake path never consults a teardown flag
        g = sh.cmd_cv.wait(g).unwrap_or_else(|p| p.into_inner());
    }
    seen = g.epoch;
    let _ = seen;
}

fn wait_outside_any_loop(sh: &Shared) {
    let g = sh.ctl.lock().unwrap_or_else(|p| p.into_inner());
    // BAD: a single un-looped wait also misses spurious wakeups
    let _g = sh.done_cv.wait(g).unwrap_or_else(|p| p.into_inner());
}
