//! Known-bad fixture for `unsafe-safety`: unsafe sites with no written
//! proof obligation. Every unsafe site in the runtime is justified by a
//! protocol (claim/complete handshake, band ownership between barriers)
//! and the argument must be written where the site is.

struct SharedBuf(std::cell::UnsafeCell<Vec<f64>>);

// BAD: cross-thread sharing asserted with no argument
unsafe impl Sync for SharedBuf {}

fn read_slab(buf: &SharedBuf, out: &mut [f64]) {
    // BAD: raw access with no written justification
    let data = unsafe { &*buf.0.get() };
    out.copy_from_slice(&data[..out.len()]);
}

fn fine(buf: &SharedBuf) -> usize {
    // SAFETY: len is immutable after construction; no aliasing write
    // can race this read.
    unsafe { (*buf.0.get()).len() }
}
