//! Black-box tests of the `perks::session` API: builder validation,
//! cross-backend state agreement, resumable advance semantics, and the
//! `Auto` execution policy. Everything here runs without AOT artifacts
//! except the PJRT cross-backend checks, which skip cleanly.

use std::rc::Rc;

use perks::runtime::farm::SolverFarm;
use perks::runtime::Runtime;
use perks::session::{Backend, ExecMode, ExecPolicy, Preconditioner, SessionBuilder};
use perks::simgpu::device::{a100, v100};
use perks::sparse::gen;
use perks::stencil::{self, gold, Domain};
use perks::util::counters;

fn runtime() -> Option<Rc<Runtime>> {
    let dir = Runtime::default_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return None;
    }
    Some(Rc::new(Runtime::new(dir).expect("runtime")))
}

fn err_msg(r: perks::Result<perks::Session>) -> String {
    format!("{}", r.err().expect("expected a build error"))
}

// ---------------------------------------------------------------------
// builder validation (no artifacts needed)
// ---------------------------------------------------------------------

#[test]
fn builder_requires_backend_and_workload() {
    assert!(err_msg(SessionBuilder::new().build()).contains("no backend"));
    assert!(
        err_msg(SessionBuilder::new().backend(Backend::cpu(1)).build()).contains("no workload")
    );
}

#[test]
fn builder_rejects_bad_dtype_bench_interior_and_n() {
    let stencil = |b: &str, i: &str, d: &str| {
        SessionBuilder::stencil(b, i, d).backend(Backend::cpu(1)).build()
    };
    assert!(err_msg(stencil("2d5pt", "16x16", "bf16")).contains("bad dtype"));
    assert!(err_msg(stencil("nope", "16x16", "f64")).contains("unknown stencil benchmark"));
    assert!(err_msg(stencil("3d7pt", "16x16", "f64")).contains("rank"));
    assert!(err_msg(stencil("2d5pt", "0x16", "f64")).contains("bad interior"));
    assert!(err_msg(SessionBuilder::cg(1000).backend(Backend::cpu(1)).build())
        .contains("perfect square"));
}

#[test]
fn builder_rejects_missing_artifacts() {
    // a PJRT runtime over an empty dir fails before that; with artifacts,
    // an un-lowered family must fail with a manifest error
    let Some(rt) = runtime() else { return };
    let err = SessionBuilder::stencil("2d5pt", "9999x9999", "f32")
        .backend(Backend::pjrt(rt))
        .mode(ExecMode::Persistent)
        .build();
    let msg = format!("{}", err.err().expect("no artifact for 9999x9999"));
    assert!(msg.contains("artifact"), "{msg}");
}

#[test]
fn builder_rejects_incompatible_modes() {
    assert!(err_msg(
        SessionBuilder::stencil("2d5pt", "16x16", "f64")
            .backend(Backend::cpu(1))
            .mode(ExecMode::HostLoopResident)
            .build()
    )
    .contains("not supported"));
    // CG substrates distinguish only host-loop vs persistent
    assert!(err_msg(
        SessionBuilder::cg(1024)
            .backend(Backend::simulated(a100()))
            .mode(ExecMode::HostLoopResident)
            .build()
    )
    .contains("not supported"));
}

#[test]
fn steps_not_a_multiple_of_the_chunk_is_an_error() {
    let Some(rt) = runtime() else { return };
    let mut session = SessionBuilder::stencil("2d5pt", "128x128", "f32")
        .backend(Backend::pjrt(rt))
        .mode(ExecMode::Persistent)
        .seed(1)
        .build()
        .unwrap();
    let chunk = session.fused_chunk();
    assert!(chunk > 1, "persistent artifacts fuse more than one step");
    let err = session.run(chunk + 1).unwrap_err();
    assert!(matches!(err, perks::Error::Invalid(_)), "{err}");
    // aligned_steps makes the same request valid
    assert_eq!(session.aligned_steps(chunk + 1), 2 * chunk);
    session.run(session.aligned_steps(chunk + 1)).unwrap();
}

// ---------------------------------------------------------------------
// cross-backend state agreement for stencils
// ---------------------------------------------------------------------

#[test]
fn cpu_backend_modes_are_bit_identical_and_match_gold() {
    let seed = 99;
    let spec = stencil::spec("2d5pt").unwrap();
    let mut dom = Domain::for_spec(&spec, &[24, 24]).unwrap();
    dom.randomize(seed);
    let want = gold::run(&spec, &dom, 6).unwrap();

    let mut states = Vec::new();
    for mode in [ExecMode::HostLoop, ExecMode::Persistent] {
        let mut s = SessionBuilder::stencil("2d5pt", "24x24", "f64")
            .backend(Backend::cpu(3))
            .mode(mode)
            .seed(seed)
            .build()
            .unwrap();
        s.run(6).unwrap();
        let got = s.state_f64().unwrap();
        let diff = got
            .iter()
            .zip(&want.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max);
        assert!(diff < 1e-12, "{}: diverged from gold by {diff}", mode.name());
        states.push(got);
    }
    // same arithmetic, same partitioning: the two models are bit-identical
    assert_eq!(states[0], states[1]);
}

#[test]
fn pjrt_and_cpu_backends_agree_on_the_same_workload() {
    let Some(rt) = runtime() else { return };
    let seed = 31;
    let steps = 16;
    let mut pjrt = SessionBuilder::stencil("2d5pt", "128x128", "f32")
        .backend(Backend::pjrt(rt))
        .mode(ExecMode::HostLoop)
        .seed(seed)
        .build()
        .unwrap();
    let mut cpu = SessionBuilder::stencil("2d5pt", "128x128", "f64")
        .backend(Backend::cpu(4))
        .mode(ExecMode::Persistent)
        .seed(seed)
        .build()
        .unwrap();
    pjrt.run(steps).unwrap();
    cpu.run(steps).unwrap();
    let a = pjrt.state_f64().unwrap();
    let b = cpu.state_f64().unwrap();
    assert_eq!(a.len(), b.len(), "both backends expose the padded domain");
    let diff = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max);
    // f32 artifact vs f64 CPU substrate: agreement to f32 accuracy
    assert!(diff < 2e-4, "backends diverged by {diff}");
}

// ---------------------------------------------------------------------
// temporal-blocking composition (epoch-batched resident exchange)
// ---------------------------------------------------------------------

#[test]
fn temporal_sessions_are_bit_identical_to_bt1_and_gold() {
    let seed = 19;
    let spec = stencil::spec("2d5pt").unwrap();
    let mut dom = Domain::for_spec(&spec, &[24, 24]).unwrap();
    dom.randomize(seed);
    let want = gold::run(&spec, &dom, 10).unwrap();
    for bt in [1usize, 2, 4] {
        let mut s = SessionBuilder::stencil("2d5pt", "24x24", "f64")
            .temporal(bt)
            .backend(Backend::cpu(3))
            .mode(ExecMode::Persistent)
            .seed(seed)
            .build()
            .unwrap();
        assert_eq!(s.temporal_degree(), bt);
        s.prepare().unwrap();
        s.advance(3).unwrap(); // partial epochs at bt = 4
        s.advance(7).unwrap();
        assert_eq!(s.state_f64().unwrap(), want.data, "bt={bt}: diverged from gold");
        let rep = s.report();
        assert_eq!(rep.steps, 10);
        assert_eq!(rep.invocations, 2, "bt={bt}: one resident launch per advance");
        match bt {
            1 => assert_eq!(rep.redundancy, Some(1.0), "no overlap work at bt=1"),
            _ => assert!(
                rep.redundancy.unwrap() > 1.0,
                "bt={bt}: trapezoid overlap must be accounted"
            ),
        }
    }
}

#[test]
fn temporal_advance_until_stops_identically_at_every_thread_count() {
    let (bt, tol, max) = (2usize, 1e-8, 20_000usize);
    let mut reference: Option<(usize, u64)> = None;
    for threads in [1usize, 3] {
        let mut s = SessionBuilder::stencil("2d5pt", "8x8", "f64")
            .temporal(bt)
            .backend(Backend::cpu(threads))
            .mode(ExecMode::Persistent)
            .seed(13)
            .build()
            .unwrap();
        let steps = s.advance_until(tol, max).unwrap();
        assert!(steps > 0 && steps < max && steps % bt == 0, "threads={threads}: {steps}");
        let res = s.report().residual.unwrap();
        assert!(res <= tol);
        match &reference {
            None => reference = Some((steps, res.to_bits())),
            Some((want_steps, bits)) => {
                assert_eq!(steps, *want_steps, "threads={threads}: stop epoch differs");
                assert_eq!(res.to_bits(), *bits, "threads={threads}: residual bits");
            }
        }
    }
}

// ---------------------------------------------------------------------
// advance semantics and reports
// ---------------------------------------------------------------------

#[test]
fn advance_is_resumable_and_run_restarts() {
    let build = || {
        SessionBuilder::stencil("2d5pt", "16x16", "f64")
            .backend(Backend::cpu(2))
            .mode(ExecMode::Persistent)
            .seed(5)
            .build()
            .unwrap()
    };
    let mut once = build();
    once.run(8).unwrap();
    let mut twice = build();
    twice.prepare().unwrap();
    twice.advance(3).unwrap();
    twice.advance(5).unwrap();
    assert_eq!(once.state_f64().unwrap(), twice.state_f64().unwrap());
    assert_eq!(twice.report().steps, 8);
    // run() re-prepares: a second run is independent, not 16 more steps
    let again = once.run(8).unwrap();
    assert_eq!(again.steps, 8);
    assert_eq!(once.state_f64().unwrap(), twice.state_f64().unwrap());
}

#[test]
fn reports_are_finite_and_account_traffic() {
    let mut s = SessionBuilder::stencil("2d5pt", "32x32", "f64")
        .backend(Backend::cpu(2))
        .mode(ExecMode::Persistent)
        .build()
        .unwrap();
    let rep = s.run(4).unwrap();
    assert!(rep.fom.is_finite() && rep.fom > 0.0);
    assert_eq!(rep.fom_unit, "cells/s");
    assert_eq!(rep.invocations, 1); // one persistent launch
    assert!(rep.host_bytes > 0);
    assert!(rep.barrier_wait_seconds.is_some());
    assert!(rep.residual.is_none());

    let mut h = SessionBuilder::stencil("2d5pt", "32x32", "f64")
        .backend(Backend::cpu(2))
        .mode(ExecMode::HostLoop)
        .build()
        .unwrap();
    let hrep = h.run(4).unwrap();
    assert_eq!(hrep.invocations, 4); // one relaunch per step
    assert!(
        hrep.host_bytes > rep.host_bytes,
        "host-loop must move more slow-tier traffic ({} vs {})",
        hrep.host_bytes,
        rep.host_bytes
    );
}

#[test]
fn threaded_cg_sessions_walk_serial_iterates_at_every_thread_count() {
    // the pooled persistent runtime (threaded) and the serial substrate
    // must be bit-identical: the reductions fold fixed per-block partials
    // in block order, never arrival order
    let build = |threads: usize, threaded: bool, mode: ExecMode| {
        SessionBuilder::cg(576)
            .parts(8)
            .threaded(threaded)
            .backend(Backend::cpu(threads))
            .mode(mode)
            .seed(11)
            .build()
            .unwrap()
    };
    let mut serial = build(1, false, ExecMode::Persistent);
    serial.prepare().unwrap();
    serial.advance(9).unwrap();
    serial.advance(8).unwrap();
    let want = serial.state_f64().unwrap();
    for threads in [1, 2, 3, 8] {
        let mut pooled = build(threads, true, ExecMode::Persistent);
        pooled.prepare().unwrap();
        pooled.advance(9).unwrap();
        pooled.advance(8).unwrap();
        assert_eq!(pooled.state_f64().unwrap(), want, "threads={threads}");
        assert_eq!(pooled.report().invocations, 2, "one resident launch per advance");
    }
    // and the spawn-per-iteration host-loop baseline agrees too
    let mut host = build(3, true, ExecMode::HostLoop);
    host.prepare().unwrap();
    host.advance(17).unwrap();
    assert_eq!(host.state_f64().unwrap(), want);
    assert_eq!(host.report().invocations, 17, "one relaunch per iteration");
}

#[test]
fn cg_sessions_report_residuals_across_backends() {
    let mut s = SessionBuilder::cg(256)
        .backend(Backend::cpu(1))
        .mode(ExecMode::Persistent)
        .seed(3)
        .build()
        .unwrap();
    let rep = s.run(10).unwrap();
    assert_eq!(rep.fom_unit, "iters/s");
    let rr = rep.residual.expect("cg reports the rr recurrence");
    let true_r = s.true_residual().unwrap().expect("cpu cg computes ||b-Ax||^2");
    assert!(rr >= 0.0 && true_r >= 0.0);
    // while not deeply converged, the recurrence tracks the true residual
    let rr0: f64 = perks::sparse::gen::rhs(256, 3).iter().map(|v| v * v).sum();
    assert!(
        (true_r - rr).abs() <= 1e-9 * rr0.max(1.0),
        "recurrence {rr} vs true {true_r} (rr0 {rr0})"
    );
    // x is exposed as state
    assert_eq!(s.state_f64().unwrap().len(), 256);
}

// ---------------------------------------------------------------------
// convergence-driven advance
// ---------------------------------------------------------------------

#[test]
fn advance_until_converges_stencils_inside_the_resident_loop() {
    let build = |mode: ExecMode| {
        SessionBuilder::stencil("2d5pt", "8x8", "f64")
            .backend(Backend::cpu(2))
            .mode(mode)
            .seed(13)
            .build()
            .unwrap()
    };
    let tol = 1e-8;
    let mut pooled = build(ExecMode::Persistent);
    let steps = pooled.advance_until(tol, 20_000).unwrap();
    assert!(steps > 0 && steps < 20_000, "did not converge in bound ({steps})");
    let rep = pooled.report();
    assert_eq!(rep.steps, steps);
    assert_eq!(rep.invocations, 1, "one resident launch for the whole search");
    let res = rep.residual.expect("tracked run reports a residual");
    assert!(res <= tol);
    // the host-loop baseline shares the residual arithmetic: same stop
    // step, same bits, same state
    let mut host = build(ExecMode::HostLoop);
    let hsteps = host.advance_until(tol, 20_000).unwrap();
    assert_eq!(hsteps, steps);
    assert_eq!(host.report().residual.unwrap().to_bits(), res.to_bits());
    assert_eq!(host.state_f64().unwrap(), pooled.state_f64().unwrap());
}

#[test]
fn advance_until_converges_cg_and_rejects_modelled_backends() {
    let mut cg = SessionBuilder::cg(256)
        .backend(Backend::cpu(1))
        .mode(ExecMode::Persistent)
        .seed(3)
        .build()
        .unwrap();
    let rr0: f64 = perks::sparse::gen::rhs(256, 3).iter().map(|v| v * v).sum();
    let iters = cg.advance_until(1e-10 * rr0, 10_000).unwrap();
    assert!(iters < 10_000, "CG converged early");
    assert!(cg.report().residual.unwrap() <= 1e-10 * rr0);
    assert_eq!(cg.report().steps, iters);

    // the simulated backend has no numeric state to converge on
    let mut sim = SessionBuilder::stencil("2d5pt", "1024x1024", "f64")
        .backend(Backend::simulated(a100()))
        .mode(ExecMode::Persistent)
        .build()
        .unwrap();
    assert!(sim.advance_until(1e-8, 100).is_err());
}

// ---------------------------------------------------------------------
// ExecPolicy::Auto
// ---------------------------------------------------------------------

#[test]
fn auto_policy_resolves_to_a_valid_mode_everywhere() {
    // (backend, workload) grid that runs without artifacts
    let builds: Vec<(&str, perks::Result<perks::Session>)> = vec![
        (
            "cpu stencil",
            SessionBuilder::stencil("2d5pt", "24x24", "f64")
                .backend(Backend::cpu(2))
                .policy(ExecPolicy::Auto)
                .build(),
        ),
        (
            "cpu cg",
            SessionBuilder::cg(64).backend(Backend::cpu(1)).policy(ExecPolicy::Auto).build(),
        ),
        (
            "sim-a100 stencil",
            SessionBuilder::stencil("2d5pt", "3072x3072", "f64")
                .backend(Backend::simulated(a100()))
                .policy(ExecPolicy::Auto)
                .build(),
        ),
        (
            "sim-v100 cg",
            SessionBuilder::cg(16384)
                .backend(Backend::simulated(v100()))
                .policy(ExecPolicy::Auto)
                .build(),
        ),
    ];
    for (name, built) in builds {
        let mut s = built.unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(
            ExecMode::all().contains(&s.mode()),
            "{name}: auto picked an unknown mode"
        );
        let rep = s.run(s.aligned_steps(8)).unwrap();
        assert!(rep.fom.is_finite(), "{name}: {:?}", rep);
    }
}

#[test]
fn auto_thread_count_resolves_on_the_cpu_backend() {
    // threads == 0 => measured autotune; the session must still build and
    // produce gold-accurate results
    let seed = 12;
    let spec = stencil::spec("2d5pt").unwrap();
    let mut dom = Domain::for_spec(&spec, &[16, 16]).unwrap();
    dom.randomize(seed);
    let want = gold::run(&spec, &dom, 4).unwrap();
    let mut s = SessionBuilder::stencil("2d5pt", "16x16", "f64")
        .backend(Backend::cpu(0))
        .mode(ExecMode::Persistent)
        .seed(seed)
        .build()
        .unwrap();
    s.run(4).unwrap();
    let got = s.state_f64().unwrap();
    let diff = got
        .iter()
        .zip(&want.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f64::max);
    assert!(diff < 1e-12, "auto-threaded run diverged from gold by {diff}");
}

// ---------------------------------------------------------------------
// simulated backend
// ---------------------------------------------------------------------

#[test]
fn simulated_backend_reproduces_the_paper_ordering() {
    let mut walls = Vec::new();
    // pipelined is CG-only; the simulated stencil models the other three
    for mode in ExecMode::all().into_iter().filter(|m| *m != ExecMode::Pipelined) {
        let mut s = SessionBuilder::stencil("2d5pt", "3072x3072", "f64")
            .backend(Backend::simulated(a100()))
            .mode(mode)
            .build()
            .unwrap();
        walls.push(s.run(1000).unwrap().wall_seconds);
    }
    // host-loop > resident > persistent
    assert!(walls[0] > walls[1] && walls[1] > walls[2], "{walls:?}");
    // no numeric state to expose
    let mut s = SessionBuilder::stencil("2d5pt", "1024x1024", "f32")
        .backend(Backend::simulated(v100()))
        .mode(ExecMode::Persistent)
        .build()
        .unwrap();
    s.run(10).unwrap();
    assert!(s.state_f64().is_err());
}

// ---------------------------------------------------------------------
// pipelined CG + preconditioning (the one-barrier-per-iteration model)
// ---------------------------------------------------------------------

/// The ill-conditioned system these tests drive: n = 220, six decades of
/// diagonal spread, fixed rhs — small enough that the Krylov walk is
/// cheap, skewed enough that the preconditioners visibly pay off.
fn ill_system() -> (perks::sparse::csr::Csr, Vec<f64>) {
    (gen::ill_conditioned(220, 1e6, 11).unwrap(), gen::rhs(220, 3))
}

/// A pipelined (or classic, via `pipelined(false)`) preconditioned CG
/// session over [`ill_system`]. `threaded(false)` is the serial reference
/// recurrence; `threaded(true)` runs the slot-ordered persistent pool.
fn ill_cg(pc: Preconditioner, pipelined: bool, threaded: bool, threads: usize) -> perks::Session {
    let (a, b) = ill_system();
    SessionBuilder::cg_system(a, b)
        .parts(6)
        .threaded(threaded)
        .preconditioner(pc)
        .pipelined(pipelined)
        .backend(Backend::cpu(threads))
        .build()
        .unwrap()
}

/// The tentpole acceptance bar, pool half: pooled pipelined CG walks the
/// serial pipelined recurrence bit-for-bit at worker counts {1, 2, 3, 8},
/// across resumed advances and every preconditioner, paying exactly one
/// slot-ordered barrier reduction per iteration.
#[test]
fn pipelined_pool_walks_the_serial_pipelined_bits_at_every_worker_count() {
    let base_reductions = counters::barrier_reductions();
    for pc in
        [Preconditioner::None, Preconditioner::Jacobi, Preconditioner::BlockJacobi { block: 4 }]
    {
        let mut serial = ill_cg(pc, true, false, 1);
        serial.advance(7).unwrap();
        serial.advance(11).unwrap();
        let want = serial.state_f64().unwrap();
        let want_rr = serial.report().residual.unwrap();
        for workers in [1usize, 2, 3, 8] {
            let mut s = ill_cg(pc, true, true, workers);
            assert_eq!(s.mode(), ExecMode::Pipelined);
            s.advance(7).unwrap();
            s.advance(11).unwrap();
            assert_eq!(
                s.state_f64().unwrap(),
                want,
                "{pc:?} workers={workers}: pooled pipelined diverged from the serial recurrence"
            );
            let rep = s.report();
            assert_eq!(
                rep.residual.unwrap().to_bits(),
                want_rr.to_bits(),
                "{pc:?} workers={workers}: recurrence residual bits"
            );
            assert_eq!(rep.steps, 18);
            assert_eq!(rep.invocations, 2, "one resident launch per advance");
        }
    }
    // 3 preconditioners x 4 worker counts x 18 pooled iterations, ONE
    // reduction generation each; the serial reference pays none. The
    // counter is process-global and monotonic: assert >=, never ==.
    assert!(counters::barrier_reductions() >= base_reductions + 3 * 4 * 18);
}

/// The tentpole acceptance bar, farm half: pipelined CG tenants on the
/// shared-worker farm walk the serial pipelined bits at farm worker
/// counts {1, 2, 3, 8} without spawning past startup — and the classic
/// farm path refuses preconditioners instead of silently dropping them.
#[test]
fn pipelined_farm_tenants_walk_the_serial_pipelined_bits() {
    for pc in [Preconditioner::None, Preconditioner::BlockJacobi { block: 4 }] {
        let mut serial = ill_cg(pc, true, false, 1);
        serial.advance(6).unwrap();
        serial.advance(9).unwrap();
        let want = serial.state_f64().unwrap();
        let want_rr = serial.report().residual.unwrap();
        for workers in [1usize, 2, 3, 8] {
            let farm = SolverFarm::spawn(workers).unwrap();
            let (a, b) = ill_system();
            let mut s = SessionBuilder::cg_system(a, b)
                .parts(6)
                .preconditioner(pc)
                .pipelined(true)
                .backend(Backend::cpu(2))
                .farm(&farm)
                .build()
                .unwrap();
            assert_eq!(s.mode(), ExecMode::Pipelined);
            s.advance(6).unwrap();
            s.advance(9).unwrap();
            assert_eq!(
                s.state_f64().unwrap(),
                want,
                "{pc:?} farm workers={workers}: diverged from the serial recurrence"
            );
            let rep = s.report();
            assert_eq!(
                rep.residual.unwrap().to_bits(),
                want_rr.to_bits(),
                "{pc:?} farm workers={workers}: recurrence residual bits"
            );
            assert!(rep.queue_wait_seconds.is_some(), "farm sessions report queue wait");
            assert_eq!(farm.spawn_count(), workers as u64, "advances reused the worker set");
        }
    }
    // the classic farm path has no preconditioner plumbing: the builder
    // routes the combination to an error naming the pipelined model
    let farm = SolverFarm::spawn(2).unwrap();
    let (a, b) = ill_system();
    let msg = err_msg(
        SessionBuilder::cg_system(a, b)
            .preconditioner(Preconditioner::Jacobi)
            .backend(Backend::cpu(2))
            .farm(&farm)
            .build(),
    );
    assert!(msg.contains("pipelined"), "unexpected rejection text: {msg}");
}

/// The convergence story end-to-end: on the ill-conditioned system both
/// preconditioners cut `advance_until` iterations for the classic model,
/// and the pipelined recurrence (same Krylov space, different roundoff)
/// keeps the win.
#[test]
fn preconditioning_cuts_iterations_for_classic_and_pipelined_sessions() {
    let (_, b) = ill_system();
    let rr0: f64 = b.iter().map(|v| v * v).sum();
    let tol = 1e-9 * rr0;
    let mut run = |pc: Preconditioner, pipelined: bool| {
        let mut s = ill_cg(pc, pipelined, true, 3);
        let iters = s.advance_until(tol, 50_000).unwrap();
        assert!(iters < 50_000, "{pc:?} pipelined={pipelined} did not converge");
        assert!(s.report().residual.unwrap() <= tol);
        iters
    };
    let plain = run(Preconditioner::None, false);
    assert!(run(Preconditioner::Jacobi, false) < plain, "classic Jacobi must cut iterations");
    assert!(
        run(Preconditioner::BlockJacobi { block: 4 }, false) < plain,
        "classic block-Jacobi must cut iterations"
    );
    let pipe_plain = run(Preconditioner::None, true);
    assert!(
        run(Preconditioner::Jacobi, true) <= pipe_plain,
        "pipelined Jacobi must not lose iterations"
    );
    assert!(
        run(Preconditioner::BlockJacobi { block: 4 }, true) <= pipe_plain,
        "pipelined block-Jacobi must not lose iterations"
    );
}
