//! Failure-injection tests: the runtime and manifest layers must fail
//! loudly and precisely on corrupted inputs — not crash inside XLA —
//! and the farm/plane runtime must contain injected faults to the
//! owning tenant without leaking admission slots.

use std::io::Write;

use perks::runtime::{HostTensor, Manifest, Runtime};

fn temp_dir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("perks_failinj_{name}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn missing_manifest_is_io_error() {
    let dir = temp_dir("missing");
    let err = match Runtime::new(&dir) {
        Err(e) => e,
        Ok(_) => panic!("runtime built without a manifest"),
    };
    assert!(matches!(err, perks::Error::Io(_)), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn corrupted_manifest_lines_reported() {
    for bad in [
        "name=a kind=x in=f32[1] out=f32[1]",          // missing tuple
        "name=a kind=x in=f32[1 out=f32[1] tuple=1",   // unterminated spec
        "name=a in=f32[1] out=f32[1] tuple=1",          // missing kind
        "garbage",                                       // not key=value
    ] {
        let err = Manifest::parse(bad, std::path::Path::new(".")).unwrap_err();
        assert!(matches!(err, perks::Error::Manifest(_)), "{bad:?} -> {err}");
    }
}

#[test]
fn truncated_hlo_file_fails_at_load_not_execute() {
    let dir = temp_dir("trunc");
    let mut mf = std::fs::File::create(dir.join("manifest.txt")).unwrap();
    writeln!(mf, "name=broken kind=x in=f32[2] out=f32[2] tuple=0").unwrap();
    std::fs::write(dir.join("broken.hlo.txt"), "HloModule broken\nthis is not hlo").unwrap();
    let rt = Runtime::new(&dir).unwrap();
    let err = match rt.load("broken") {
        Err(e) => e,
        Ok(_) => panic!("truncated HLO unexpectedly loaded"),
    };
    assert!(matches!(err, perks::Error::Xla(_)), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn shape_mismatch_caught_before_xla() {
    let dir = Runtime::default_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let rt = Runtime::new(dir).unwrap();
    let exe = rt.load("stencil_2d5pt_128x128_f32_step").unwrap();
    // wrong rank
    let bad = HostTensor::f32(&[130 * 130], vec![0.0; 130 * 130]);
    let err = exe.run(&[bad]).unwrap_err();
    assert!(matches!(err, perks::Error::Shape(_)), "{err}");
    // wrong dtype
    let bad = HostTensor::f64(&[130, 130], vec![0.0; 130 * 130]);
    assert!(matches!(exe.run(&[bad]).unwrap_err(), perks::Error::Shape(_)));
    // wrong arity
    let ok = HostTensor::f32(&[130, 130], vec![0.0; 130 * 130]);
    assert!(matches!(
        exe.run(&[ok.clone(), ok]).unwrap_err(),
        perks::Error::Shape(_)
    ));
}

#[test]
fn unknown_artifact_name_is_manifest_error() {
    let dir = Runtime::default_dir();
    if !dir.join("manifest.txt").exists() {
        return;
    }
    let rt = Runtime::new(dir).unwrap();
    match rt.load("no_such_artifact") {
        Err(perks::Error::Manifest(_)) => {}
        other => panic!("expected manifest error, got {:?}", other.err().map(|e| e.to_string())),
    }
}

#[test]
fn solver_guards_fire() {
    use perks::sparse::csr::Csr;
    // non-square matrix into CG
    let rect = Csr::from_coo(2, 3, vec![(0, 0, 1.0)]).unwrap();
    let err = perks::cg::solve_persistent(&rect, &[1.0, 1.0], &Default::default()).unwrap_err();
    assert!(matches!(err, perks::Error::Solver(_)));
    // pipelined is a CG-only execution model: a stencil session pinned to
    // it must fail validation instead of reaching a driver
    let err = perks::session::SessionBuilder::stencil("2d5pt", "16x16", "f64")
        .backend(perks::session::Backend::cpu(2))
        .mode(perks::session::ExecMode::Pipelined)
        .build()
        .unwrap_err();
    assert!(matches!(err, perks::Error::Invalid(_)), "{err}");
}

/// A worker panic on one farm tenant errors only the owning session:
/// the concurrently-running peer tenant harvests normally and its final
/// state stays bit-identical to the solo gold run.
#[test]
fn farm_panic_errors_only_the_owning_session() {
    use perks::runtime::{FaultPlan, FaultSpec, SolverFarm};
    use perks::stencil::{gold, spec, Domain};

    let s = spec("2d5pt").unwrap();
    let mut d = Domain::for_spec(&s, &[12, 12]).unwrap();
    d.randomize(33);
    let want = gold::run(&s, &d, 6).unwrap().data;

    let farm = SolverFarm::spawn(2).unwrap();
    farm.install_faults(FaultPlan::new().inject(FaultSpec::panic_at(1).tenant(0)));
    let h = farm.handle();
    let mut victim = h.admit_stencil(&s, &d, 2, 1).unwrap(); // slot 0
    let mut peer = h.admit_stencil(&s, &d, 2, 1).unwrap(); // slot 1
    victim.submit(6, None).unwrap();
    peer.submit(6, None).unwrap();

    match victim.wait() {
        Err(perks::Error::Fault { epoch, .. }) => assert_eq!(epoch, 1),
        other => panic!("expected Error::Fault on the victim, got {other:?}"),
    }
    let run = peer.wait().unwrap();
    assert_eq!(run.steps, 6);
    assert_eq!(run.recoveries, 0, "the fault bled into the peer tenant");
    assert_eq!(peer.state().unwrap(), want, "peer diverged while its neighbor panicked");
}

/// Waiting again after a fault has been harvested is a structured error
/// ("nothing in flight"), not a hang and not a stale replay of the
/// first failure.
#[test]
fn farm_wait_after_fault_is_a_structured_error() {
    use perks::runtime::{FaultPlan, FaultSpec, SolverFarm};
    use perks::stencil::{spec, Domain};

    let s = spec("2d5pt").unwrap();
    let mut d = Domain::for_spec(&s, &[10, 10]).unwrap();
    d.randomize(35);
    let farm = SolverFarm::spawn(1).unwrap();
    farm.install_faults(FaultPlan::new().inject(FaultSpec::panic_at(0)));
    let mut t = farm.handle().admit_stencil(&s, &d, 1, 1).unwrap();
    assert!(matches!(t.advance(4, None), Err(perks::Error::Fault { .. })));
    match t.wait() {
        Err(perks::Error::Solver(msg)) => {
            assert!(msg.contains("no farm command in flight"), "unexpected message: {msg}");
        }
        other => panic!("expected a no-command-in-flight error, got {other:?}"),
    }
}

/// Admission failures must not leak plane slots: after a shed rejection
/// and a harvested fault, the bounded plane still has its full capacity
/// and a fresh submission goes through.
#[test]
fn farm_shed_and_fault_leak_no_plane_slots() {
    use perks::runtime::{AdmissionPolicy, FaultPlan, FaultSpec, PlaneConfig, SolverFarm};
    use perks::stencil::{gold, spec, Domain};

    let s = spec("2d5pt").unwrap();
    let mut d = Domain::for_spec(&s, &[10, 10]).unwrap();
    d.randomize(37);
    let want = gold::run(&s, &d, 3).unwrap().data;

    let farm =
        SolverFarm::spawn_with(1, PlaneConfig::bounded(1).policy(AdmissionPolicy::Shed)).unwrap();
    farm.install_faults(FaultPlan::new().inject(FaultSpec::panic_at(0).tenant(0)));
    let h = farm.handle();
    let mut a = h.admit_stencil(&s, &d, 1, 1).unwrap(); // slot 0: will fault
    let mut b = h.admit_stencil(&s, &d, 1, 1).unwrap();
    a.submit(4, None).unwrap(); // holds the only plane slot
    match b.submit(1, None) {
        Err(perks::Error::Shed(_)) => {} // rejected, must not consume the slot
        other => panic!("expected Shed on the full plane, got {other:?}"),
    }
    // harvesting the fault releases the holder's slot
    assert!(matches!(a.wait(), Err(perks::Error::Fault { .. })));
    // both the shed tenant and the faulted tenant can use the plane again
    let run = b.advance(3, None).unwrap();
    assert_eq!(run.steps, 3);
    assert_eq!(b.state().unwrap(), want, "post-shed run diverged from gold");
    // the panic hit the first LOAD claim, so nothing was resident yet:
    // the rerun reloads from x0 and lands exactly on gold
    let rerun = a.advance(3, None).unwrap(); // the fault spec already fired
    assert_eq!(rerun.steps, 3);
    assert_eq!(a.state().unwrap(), want, "faulted tenant's rerun diverged from gold");
    assert_eq!(farm.metrics().plane_sheds, 1, "exactly the one rejected submit shed");
}
