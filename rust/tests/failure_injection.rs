//! Failure-injection tests: the runtime and manifest layers must fail
//! loudly and precisely on corrupted inputs — not crash inside XLA.

use std::io::Write;

use perks::runtime::{HostTensor, Manifest, Runtime};

fn temp_dir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("perks_failinj_{name}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn missing_manifest_is_io_error() {
    let dir = temp_dir("missing");
    let err = match Runtime::new(&dir) {
        Err(e) => e,
        Ok(_) => panic!("runtime built without a manifest"),
    };
    assert!(matches!(err, perks::Error::Io(_)), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn corrupted_manifest_lines_reported() {
    for bad in [
        "name=a kind=x in=f32[1] out=f32[1]",          // missing tuple
        "name=a kind=x in=f32[1 out=f32[1] tuple=1",   // unterminated spec
        "name=a in=f32[1] out=f32[1] tuple=1",          // missing kind
        "garbage",                                       // not key=value
    ] {
        let err = Manifest::parse(bad, std::path::Path::new(".")).unwrap_err();
        assert!(matches!(err, perks::Error::Manifest(_)), "{bad:?} -> {err}");
    }
}

#[test]
fn truncated_hlo_file_fails_at_load_not_execute() {
    let dir = temp_dir("trunc");
    let mut mf = std::fs::File::create(dir.join("manifest.txt")).unwrap();
    writeln!(mf, "name=broken kind=x in=f32[2] out=f32[2] tuple=0").unwrap();
    std::fs::write(dir.join("broken.hlo.txt"), "HloModule broken\nthis is not hlo").unwrap();
    let rt = Runtime::new(&dir).unwrap();
    let err = match rt.load("broken") {
        Err(e) => e,
        Ok(_) => panic!("truncated HLO unexpectedly loaded"),
    };
    assert!(matches!(err, perks::Error::Xla(_)), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn shape_mismatch_caught_before_xla() {
    let dir = Runtime::default_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let rt = Runtime::new(dir).unwrap();
    let exe = rt.load("stencil_2d5pt_128x128_f32_step").unwrap();
    // wrong rank
    let bad = HostTensor::f32(&[130 * 130], vec![0.0; 130 * 130]);
    let err = exe.run(&[bad]).unwrap_err();
    assert!(matches!(err, perks::Error::Shape(_)), "{err}");
    // wrong dtype
    let bad = HostTensor::f64(&[130, 130], vec![0.0; 130 * 130]);
    assert!(matches!(exe.run(&[bad]).unwrap_err(), perks::Error::Shape(_)));
    // wrong arity
    let ok = HostTensor::f32(&[130, 130], vec![0.0; 130 * 130]);
    assert!(matches!(
        exe.run(&[ok.clone(), ok]).unwrap_err(),
        perks::Error::Shape(_)
    ));
}

#[test]
fn unknown_artifact_name_is_manifest_error() {
    let dir = Runtime::default_dir();
    if !dir.join("manifest.txt").exists() {
        return;
    }
    let rt = Runtime::new(dir).unwrap();
    match rt.load("no_such_artifact") {
        Err(perks::Error::Manifest(_)) => {}
        other => panic!("expected manifest error, got {:?}", other.err().map(|e| e.to_string())),
    }
}

#[test]
fn solver_guards_fire() {
    use perks::sparse::csr::Csr;
    // non-square matrix into CG
    let rect = Csr::from_coo(2, 3, vec![(0, 0, 1.0)]).unwrap();
    let err = perks::cg::solve_persistent(&rect, &[1.0, 1.0], &Default::default()).unwrap_err();
    assert!(matches!(err, perks::Error::Solver(_)));
    // steps not a multiple of fused count (through the deprecated driver
    // shim, which must keep compiling and guarding)
    let dir = Runtime::default_dir();
    if dir.join("manifest.txt").exists() {
        let rt = Runtime::new(dir).unwrap();
        #[allow(deprecated)]
        let d = perks::coordinator::StencilDriver::new(&rt, "2d5pt", "128x128", "f32").unwrap();
        let x0 = HostTensor::f32(&[130, 130], vec![0.0; 130 * 130]);
        let err = d
            .run(perks::coordinator::ExecMode::Persistent, &x0, d.fused_steps + 1)
            .unwrap_err();
        assert!(matches!(err, perks::Error::Invalid(_)), "{err}");
    }
}
