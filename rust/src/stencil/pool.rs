//! Spawn-once persistent stencil worker pool: the PERKS execution model
//! for iterative stencils, with the time loop resident in the workers
//! *across* `advance` boundaries — optionally composed with overlapped
//! temporal blocking (degree `bt`), the optimization the paper calls
//! orthogonal to PERKS (§I, §II-C).
//!
//! # Why a pool
//!
//! The paper's whole point is that the time loop lives inside the
//! persistent kernel, so nothing is relaunched per step. The one-shot
//! [`crate::stencil::parallel::persistent`] driver realizes that *within*
//! one call — but still pays a full spawn/join cycle on every call, which
//! is exactly the amortization boundary the kernel-batching literature
//! (and our own `cg::pool`, PR 2) pushes launches across. This module is
//! the stencil counterpart of [`crate::cg::pool::CgPool`]:
//!
//! | GPU (PERKS kernel)            | CPU (`StencilPool`)                    |
//! |-------------------------------|----------------------------------------|
//! | thread block                  | pool worker (OS thread, spawn-once)    |
//! | kernel launch / relaunch      | `StencilPool::spawn` (once per solve)  |
//! | TB's domain tile              | worker's banded `ThreadPlan`           |
//! | registers/smem-resident tile  | worker's slab pair, hot in L1/L2       |
//! |                               | **across `advance` calls**             |
//! | `grid.sync()`                 | `GridBarrier::sync`                    |
//! | grid-sync + device reduction  | `put` + `read_sum` residual all-reduce |
//!
//! # Epochs and sub-steps
//!
//! The resident loop advances time in exchange *epochs* of `bt`
//! *sub-steps* each (`bt = 1`, the default, is per-step exchange — the
//! classic PERKS loop). Within an epoch a worker touches nothing shared:
//! it runs `temporal::advance_slab` on its resident slab pair, computing
//! a trapezoid that starts at the band grown by `(bt - 1) * radius`
//! planes and shrinks by `radius` per sub-step — redundant overlap work
//! (accounted in [`StencilRun::computed_cells`]) that buys the right to
//! exchange only at epoch boundaries. A `steps`-step advance therefore
//! pays `2 * ceil(steps / bt)` barrier syncs instead of `2 * steps`
//! (plus the one-time initial-load sync), observable via
//! [`StencilPool::barrier_syncs`].
//!
//! # The widened-halo exchange invariant
//!
//! Each epoch ends with the band's boundary planes — now `bt * radius`
//! deep on each side, the depth the neighbor's opening trapezoid reads —
//! stored to the shared grid, and the worker's own `bt * radius`-deep
//! halo planes reloaded, bracketed by two grid barriers (see
//! `stencil::parallel`'s module docs): barrier 1 orders every boundary
//! *store* before any halo *load*; barrier 2 orders every halo load
//! before the next epoch's stores. Every plane a worker loads as halo
//! lies within `bt * radius` of some band's edge and is therefore
//! covered by that band's same-epoch boundary store (thin bands store
//! the lo/hi *union*, and traffic counts it once — Eq 5). Between the
//! two barriers the grid is read-only — which is where the in-loop
//! residual folds: workers `put` one squared-delta partial per interior
//! plane (last sub-step vs the level before it) before barrier 1, and
//! every worker folds the slots in plane order (`read_sum`) right after
//! it, giving a deterministic, thread-count-invariant convergence norm
//! with **zero extra barriers**. With `bt > 1` the norm is checked at
//! epoch granularity: a tolerance stop lands on the same epoch at every
//! worker count.
//!
//! # Determinism
//!
//! Cell updates — redundant or not — are pure functions of the previous
//! level with a fixed accumulation order (`gold::accumulate_row`), so
//! pooled iterates are bit-identical to `gold::run`, to the one-shot
//! driver, to themselves at every worker count and across resumed
//! `advance`s, **and across temporal degrees**: `bt = 4` walks the same
//! bits as `bt = 1`. The residual norm folds fixed per-plane partials in
//! plane-index order, so it too is identical at every worker count.
//!
//! # Safety protocol
//!
//! The grid lives in a [`SharedGrid`] (`UnsafeCell`) shared by the main
//! thread and the workers. Exclusive access is phased exactly as in
//! `cg::pool`: the main thread touches it only while the pool is idle
//! (the command/completion handshake below), and within a run the
//! workers partition writes by band ownership with the two-barrier
//! protocol separating producer and consumer phases. Every run ends with
//! a whole-band store, so slab and grid agree at every park.
//!
//! # Command protocol
//!
//! Workers are spawned once by [`StencilPool::spawn`] and then park on a
//! condvar. The main thread drives them with epoch-stamped `Run { steps,
//! tol }` commands through the control mutex; each worker executes the
//! whole resident time loop for a `Run`, reports into the shared
//! `Outcome`, bumps `finished`, and parks again. The command/completion
//! handshake establishes happens-before in both directions, so between
//! runs the main thread may read the shared grid ([`StencilPool::state`])
//! while the workers' slabs stay untouched. Teardown is a dedicated flag
//! checked on every condvar wake — never a value raced through the
//! command slot — so `drop`'s join cannot hang on a worker parked while
//! the epoch stamp advances.
//!
//! # Checkpoint / restore
//!
//! The solo pool participates in the resilience layer
//! (`runtime::resilience`) with the same [`Checkpoint`] type the farm
//! uses: every run ends with a whole-band store, so between runs the
//! shared grid alone *is* the resident state, and
//! [`StencilPool::checkpoint`] snapshots it (grid-only payload — no slab
//! copies needed). [`StencilPool::restore`] rewrites the grid and bumps a
//! reload generation that forces every worker's resident slab pair to
//! reload from the restored grid on its next run, so a restored replay
//! walks the same bits as the original. Tracked runs additionally guard
//! the residual fold: a non-finite norm (NaN/Inf state) fails the run
//! with `Error::Solver` naming the step and epoch instead of silently
//! iterating poisoned state to the step cap — and because the fold is
//! replicated identically on every worker, the failure break is as
//! collective as a tolerance stop.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::coordinator::barrier::GridBarrier;
use crate::error::{Error, Result};
use crate::runtime::resilience::{Checkpoint, CheckpointPayload};
use crate::stencil::grid::Domain;
use crate::stencil::parallel::{
    bands_for, boundary_union_planes, plans, slab_delta_partials, SharedGrid, ThreadPlan,
};
use crate::stencil::shape::StencilSpec;
use crate::stencil::temporal;
use crate::util::counters;

/// Command issued to the parked workers; epoch-stamped in `CtlState`.
/// Teardown is *not* a command: it is the dedicated `CtlState::shutdown`
/// flag, checked on every condvar wake, so a worker parked while the
/// epoch stamp advances during teardown can never miss it (and a pending
/// command slot is never overwritten by a shutdown race).
#[derive(Clone, Copy)]
enum Cmd {
    Idle,
    /// Run up to `steps` resident time steps (sub-steps, grouped into
    /// exchange epochs of the pool's `bt`). With `tol = Some(t)` the
    /// workers track the squared step-delta norm each epoch and stop
    /// (collectively) once it drops to `t`; with `None` no residual is
    /// computed — fixed-step advances pay nothing for the machinery.
    Run { steps: usize, tol: Option<f64> },
}

/// What one `Run` produced. `steps`/`residual` are replicated values
/// (worker 0 publishes them); `moved`/`computed` are summed over workers.
#[derive(Clone, Default)]
struct Outcome {
    steps: usize,
    residual: Option<f64>,
    moved: u64,
    computed: u64,
    error: Option<String>,
}

struct CtlState {
    epoch: u64,
    cmd: Cmd,
    finished: usize,
    outcome: Outcome,
    /// Teardown flag, separate from the command slot (see [`Cmd`]).
    shutdown: bool,
}

struct Control {
    state: Mutex<CtlState>,
    cmd_cv: Condvar,
    done_cv: Condvar,
}

impl Control {
    /// Lock the control state, recovering from poisoning (a worker panic
    /// while holding the lock) — the state is plain data with no invariant
    /// a panic can break, and refusing would turn one panic into a
    /// double-panic abort in `Drop`.
    fn lock(&self) -> std::sync::MutexGuard<'_, CtlState> {
        self.state.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
    }
}

/// Everything the resident workers share.
struct Shared {
    spec: StencilSpec,
    /// Domain geometry template; `data` is empty — the numbers live in
    /// `grid`, and [`StencilPool::state_domain`] re-attaches them.
    meta: Domain,
    /// Banded axis (0 for 3D, 1 for 2D) and plane stride, as in
    /// `parallel::Bands`.
    axis: usize,
    plane: usize,
    /// First interior plane in padded coords (the reduction-slot offset).
    first: usize,
    /// Interior plane count of the banded axis.
    interior_planes: usize,
    /// Temporal-blocking degree: sub-steps per exchange epoch (>= 1).
    bt: usize,
    plans: Vec<ThreadPlan>,
    weights: Vec<f64>,
    grid: SharedGrid,
    barrier: GridBarrier,
    ctl: Control,
    /// Slab-reload generation: bumped by [`StencilPool::restore`] after
    /// rewriting the grid. Workers compare it against a local copy at
    /// the top of every run and drop their `loaded` flag on a mismatch,
    /// so stale resident slabs are re-read from the restored grid.
    reload: AtomicU64,
}

/// Result of one [`StencilPool::run`].
#[derive(Clone, Debug)]
pub struct StencilRun {
    /// Time steps actually performed (early-stop on `tol` lands on an
    /// epoch boundary when `bt > 1`).
    pub steps: usize,
    /// Last in-loop residual norm (squared step delta of the final
    /// sub-step), `Some` iff the run tracked one.
    pub residual: Option<f64>,
    /// Bytes this run moved through the shared ("global") array, summed
    /// over workers: initial slab loads on the first run, per-epoch
    /// boundary-union stores + halo reloads, and the final band store.
    pub global_bytes: u64,
    /// Cell updates performed, including the redundant trapezoid overlap
    /// of temporal blocking (== `useful_cells` at `bt = 1`).
    pub computed_cells: u64,
    /// Useful cell updates: interior cells x steps.
    pub useful_cells: u64,
}

impl StencilRun {
    /// Redundant-compute ratio >= 1 (the measured `OverlapCost`).
    pub fn redundancy(&self) -> f64 {
        temporal::redundancy_ratio(self.computed_cells, self.useful_cells)
    }
}

/// A pool of persistent banded stencil workers: spawned once, parked
/// between runs, slabs resident across runs, joined on drop. See the
/// module docs for the execution model and the epoch/sub-step structure.
pub struct StencilPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    workers: usize,
    spawned: u64,
    /// Time steps completed over the pool's lifetime — the epoch
    /// coordinate stamped on [`StencilPool::checkpoint`] snapshots.
    advanced: u64,
}

impl StencilPool {
    /// Spawn the resident workers for one domain with per-step exchange
    /// (`bt = 1`). The worker count is the band count: `threads` clamped
    /// to the interior planes, so no worker is idle by construction.
    /// Fails on `threads == 0` and on domains with no interior planes.
    pub fn spawn(spec: &StencilSpec, x0: &Domain, threads: usize) -> Result<Self> {
        Self::spawn_temporal(spec, x0, threads, 1)
    }

    /// [`StencilPool::spawn`] with overlapped temporal blocking at degree
    /// `bt`: slabs widen to `bt * radius` halo planes and the resident
    /// loop exchanges (and syncs) once per `bt` sub-steps. `bt = 1` is
    /// per-step exchange; `bt == 0` is rejected.
    pub fn spawn_temporal(
        spec: &StencilSpec,
        x0: &Domain,
        threads: usize,
        bt: usize,
    ) -> Result<Self> {
        if threads == 0 {
            return Err(Error::invalid("threads must be > 0"));
        }
        if bt == 0 {
            return Err(Error::invalid("temporal blocking degree bt must be >= 1"));
        }
        let geometry = bands_for(x0, spec, threads)?;
        let r = spec.radius;
        let plane = geometry.plane;
        let total_planes = x0.data.len() / plane;
        // slabs carry bt*r halo planes: the depth the opening trapezoid
        // of an epoch reads
        let plans = plans(&geometry, bt * r, total_planes, plane);
        let workers = plans.len();
        // one residual-reduction slot per interior plane of the banded
        // axis: partials are per *plane*, not per worker, which is what
        // makes the folded norm invariant to the thread count
        let interior_planes = if geometry.axis == 0 { x0.interior[0] } else { x0.interior[1] };
        let mut meta = x0.clone();
        meta.data = Vec::new();
        let shared = Arc::new(Shared {
            spec: spec.clone(),
            meta,
            axis: geometry.axis,
            plane,
            first: geometry.first,
            interior_planes,
            bt,
            plans,
            weights: spec.weights(),
            grid: SharedGrid::new(x0.data.clone()),
            barrier: GridBarrier::with_reduction(workers, interior_planes),
            ctl: Control {
                state: Mutex::new(CtlState {
                    epoch: 0,
                    cmd: Cmd::Idle,
                    finished: 0,
                    outcome: Outcome::default(),
                    shutdown: false,
                }),
                cmd_cv: Condvar::new(),
                done_cv: Condvar::new(),
            },
            reload: AtomicU64::new(0),
        });
        counters::note_thread_spawns(workers as u64);
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let sh = shared.clone();
            let spawned = std::thread::Builder::new()
                .name(format!("stencil-pool-{w}"))
                .spawn(move || worker_main(&sh, w));
            match spawned {
                Ok(h) => handles.push(h),
                Err(e) => {
                    // don't leak the workers that did start: they are
                    // parked on cmd_cv and would otherwise pin their
                    // Arc<Shared> (and the grid) forever. The barrier is
                    // not armed yet — no worker enters the resident loop
                    // without a Run command — so teardown is safe here.
                    {
                        let mut g = shared.ctl.lock();
                        g.shutdown = true;
                        shared.ctl.cmd_cv.notify_all();
                    }
                    for h in handles {
                        let _ = h.join();
                    }
                    return Err(Error::Solver(format!("pool spawn failed: {e}")));
                }
            }
        }
        Ok(Self { shared, handles, workers, spawned: workers as u64, advanced: 0 })
    }

    /// Resident worker count (threads clamped to the band count).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Temporal-blocking degree this pool exchanges at (1 = every step).
    pub fn temporal_degree(&self) -> usize {
        self.shared.bt
    }

    /// OS threads this pool has ever spawned — constant after `spawn`,
    /// which is the point: `run` must never add to it.
    pub fn spawn_count(&self) -> u64 {
        self.spawned
    }

    /// Grid-barrier syncs this pool's workers have performed so far
    /// (generations of the shared barrier, not per-worker arrivals). A
    /// `run(steps)` costs `2 * ceil(steps / bt)` syncs — one pair per
    /// exchange epoch — plus a single initial-load sync on the pool's
    /// first run; early tolerance stops cost `2 * epochs_run`.
    pub fn barrier_syncs(&self) -> u64 {
        self.shared.barrier.generations()
    }

    /// Total time workers spent blocked at the grid barrier (summed).
    pub fn barrier_wait(&self) -> std::time::Duration {
        self.shared.barrier.total_wait()
    }

    /// [`StencilPool::barrier_wait`] in seconds.
    pub fn barrier_wait_seconds(&self) -> f64 {
        self.barrier_wait().as_secs_f64()
    }

    /// Run up to `steps` resident time steps on the parked workers (no
    /// thread spawns), grouped into exchange epochs of the pool's
    /// temporal degree. With `tol = Some(t)` the workers compute the
    /// squared step-delta norm each epoch and stop collectively once it
    /// drops to `t`; the last norm is returned in
    /// [`StencilRun::residual`]. `Err` is reserved for a *collective*
    /// worker panic (all workers fail at the same deterministic point —
    /// the shape every replicated-control-flow bug takes), after which
    /// the pool stays usable. As in `cg::pool`, a panic in only *some*
    /// workers strands their peers at the grid barrier and hangs the run;
    /// the deterministic lockstep control flow is what rules that out.
    pub fn run(&mut self, steps: usize, tol: Option<f64>) -> Result<StencilRun> {
        if self.handles.is_empty() {
            // after shutdown() there is no worker left to execute the
            // command — error out instead of waiting forever on done_cv
            return Err(Error::Solver("stencil pool is shut down".into()));
        }
        {
            let mut g = self.shared.ctl.lock();
            g.epoch += 1;
            g.cmd = Cmd::Run { steps, tol };
            g.finished = 0;
            g.outcome = Outcome::default(); // no stale error/steps carry over
            self.shared.ctl.cmd_cv.notify_all();
        }
        let outcome = {
            let mut g = self.shared.ctl.lock();
            while g.finished < self.workers {
                // lint: allow(condvar-shutdown) -- client-side completion wait; the pool is torn down only by this same thread's Drop, so no concurrent shutdown can strand it
                g = self.shared.ctl.done_cv.wait(g).unwrap_or_else(|p| p.into_inner());
            }
            g.outcome.clone()
        };
        if let Some(msg) = outcome.error {
            return Err(Error::Solver(msg));
        }
        self.advanced += outcome.steps as u64;
        Ok(StencilRun {
            steps: outcome.steps,
            residual: outcome.residual,
            global_bytes: outcome.moved,
            computed_cells: outcome.computed,
            useful_cells: (self.shared.meta.interior_cells() * outcome.steps) as u64,
        })
    }

    /// Snapshot the padded domain data. Callable only between runs: the
    /// completion handshake of the previous `run` happened-before this
    /// read, and no worker touches the grid while parked.
    pub fn state(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.shared.grid.len()];
        // SAFETY: pool idle (see above) — no concurrent writer.
        unsafe { self.shared.grid.read(0..out.len(), &mut out) };
        out
    }

    /// [`StencilPool::state`] re-attached to the domain geometry.
    pub fn state_domain(&self) -> Domain {
        let mut d = self.shared.meta.clone();
        d.data = self.state();
        d
    }

    /// Snapshot the pool's resident state into a restorable
    /// [`Checkpoint`], stamped with the lifetime step count. Callable
    /// only between runs (same contract as [`StencilPool::state`]). The
    /// payload is grid-only: every run ends with a whole-band store, so
    /// the shared grid already holds everything the workers' slabs do —
    /// no per-band copies needed. Snapshot traffic is accounted in
    /// `util::counters::checkpoint_bytes`.
    pub fn checkpoint(&self) -> Checkpoint {
        let ck = Checkpoint::new(
            self.advanced,
            CheckpointPayload::Stencil {
                grid: self.state(),
                slabs: Vec::new(),
                done_steps: 0,
                residual: None,
                loaded: false,
                moved: 0,
                computed: 0,
                steps_target: 0,
                segs: Vec::new(),
                resubmits: 0,
            },
        );
        counters::note_checkpoint_bytes(ck.bytes);
        ck
    }

    /// Restore a [`StencilPool::checkpoint`] snapshot: rewrite the
    /// shared grid and invalidate every worker's resident slab pair (a
    /// reload-generation bump — the next run's first epoch re-reads the
    /// slabs from the restored grid, paying one initial-load sync like a
    /// first run). A subsequent `run` replays bit-identically to the
    /// original post-checkpoint run. Rejects checkpoints from a
    /// different engine or geometry.
    pub fn restore(&mut self, ck: &Checkpoint) -> Result<()> {
        let CheckpointPayload::Stencil { grid, .. } = &ck.payload else {
            return Err(Error::invalid("checkpoint does not hold stencil state"));
        };
        if grid.len() != self.shared.grid.len() {
            return Err(Error::invalid(format!(
                "checkpoint grid has {} cells, pool expects {}",
                grid.len(),
                self.shared.grid.len()
            )));
        }
        // SAFETY: pool idle between runs (the completion handshake of
        // the previous run happened-before this call) — no concurrent
        // accessor, exactly as in `state`.
        unsafe { self.shared.grid.write(0, grid) };
        // order the grid rewrite before the generation becomes visible
        // to a worker's Acquire load at its next run
        self.shared.reload.fetch_add(1, Ordering::Release);
        self.advanced = ck.epoch;
        Ok(())
    }

    /// Shut the workers down and join them, leaving the grid readable:
    /// [`StencilPool::state`]/[`StencilPool::state_domain`] still work
    /// afterwards, but `run` must not be called again (there are no
    /// workers left to execute it). The one-shot driver uses this to keep
    /// the join inside its timed region (matching the host-loop baseline,
    /// whose per-step joins are always timed); `drop` after this is a
    /// no-op. Teardown is a dedicated flag — not an epoch-stamped command
    /// — so a worker parked on the condvar while the epoch stamp advances
    /// can never miss it: the join cannot hang (see the rapid create/drop
    /// stress test).
    pub fn shutdown(&mut self) {
        {
            let mut g = self.shared.ctl.lock();
            g.shutdown = true;
            self.shared.ctl.cmd_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }

    #[cfg(test)]
    fn shared_weak(&self) -> std::sync::Weak<Shared> {
        Arc::downgrade(&self.shared)
    }
}

impl Drop for StencilPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Park on the control condvar; execute each epoch's command; exit on
/// shutdown. The resident slab *pair* (`cur`/`nxt`, ping-ponged by the
/// trapezoid core) and the linearized stencil offsets live *here*,
/// outside the command loop: they are built once per pool lifetime and
/// stay resident across `advance` commands — the CPU analog of a thread
/// block keeping its tile in registers/smem for the whole solve.
fn worker_main(sh: &Shared, w: usize) {
    let plan = &sh.plans[w];
    let mut cur = vec![0.0f64; plan.slab.len()];
    let mut nxt = vec![0.0f64; plan.slab.len()];
    let deltas =
        crate::stencil::gold::linear_deltas(&sh.spec, sh.meta.padded[1], sh.meta.padded[2]);
    let mut loaded = false;
    let mut reload_seen = 0u64;

    let mut seen = 0u64;
    loop {
        let cmd = {
            let mut g = sh.ctl.lock();
            loop {
                // the shutdown flag is checked on *every* wake — before
                // and independently of the epoch stamp — so teardown can
                // never be missed by a worker parked across stamp changes
                if g.shutdown {
                    return;
                }
                if g.epoch != seen {
                    break;
                }
                g = sh.ctl.cmd_cv.wait(g).unwrap_or_else(|p| p.into_inner());
            }
            seen = g.epoch;
            g.cmd
        };
        match cmd {
            Cmd::Idle => {}
            Cmd::Run { steps, tol } => {
                // a restore since the last run rewrote the shared grid:
                // drop the resident slabs and reload them (the Acquire
                // pairs with restore's Release, ordering the grid bytes)
                let gen = sh.reload.load(Ordering::Acquire);
                if gen != reload_seen {
                    reload_seen = gen;
                    loaded = false;
                }
                // A panic inside the resident loop would otherwise leave
                // `finished` forever short and hang `run()`. Catching it
                // lets a *collective* panic (all workers fail at the same
                // deterministic point) surface as an error, as in cg::pool.
                let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    run_steps(sh, w, steps, tol, &mut cur, &mut nxt, &deltas, &mut loaded)
                }))
                .unwrap_or_else(|_| Outcome {
                    steps: 0,
                    residual: None,
                    moved: 0,
                    computed: 0,
                    error: Some(format!("stencil pool worker {w} panicked during run")),
                });
                let mut g = sh.ctl.lock();
                g.outcome.moved += out.moved; // every worker's traffic counts
                g.outcome.computed += out.computed; // and its (overlap) work
                if w == 0 {
                    // steps/residual are replicated; worker 0 publishes
                    g.outcome.steps = out.steps;
                    g.outcome.residual = out.residual;
                }
                if out.error.is_some() && g.outcome.error.is_none() {
                    g.outcome.error = out.error;
                }
                g.finished += 1;
                if g.finished == sh.barrier.participants() {
                    sh.ctl.done_cv.notify_all();
                }
            }
        }
    }
}

/// The resident time loop of worker `w` for one `Run` command: epochs of
/// up to `bt` locally-advanced sub-steps, each followed by one widened
/// boundary/halo exchange under two barriers. All workers execute the
/// same control flow on an identical residual (the slot-ordered fold),
/// so early breaks are collective and the barrier never deadlocks.
#[allow(clippy::too_many_arguments)]
fn run_steps(
    sh: &Shared,
    w: usize,
    steps: usize,
    tol: Option<f64>,
    cur: &mut Vec<f64>,
    nxt: &mut Vec<f64>,
    deltas: &[isize],
    loaded: &mut bool,
) -> Outcome {
    let plan = &sh.plans[w];
    let r = sh.spec.radius;
    let bt = sh.bt;
    let plane = sh.plane;
    let slab_first = plan.slab.start / plane;
    let band_planes = plan.band.len();
    let depth = bt * r; // exchange depth: boundary stores and halo loads
    let mut moved = 0u64;
    let mut computed = 0u64;

    if !*loaded {
        // --- first run only: initial load, slab (band + bt*r halos) ---
        // SAFETY: no writer before the barrier below; disjoint reads.
        unsafe { sh.grid.read(plan.slab.clone(), cur) };
        // the ping-pong partner starts as an identical copy so its
        // never-computed Dirichlet cells stay valid forever (the
        // advance_slab contract)
        nxt.copy_from_slice(cur);
        moved += (plan.slab.len() * 8) as u64;
        *loaded = true;
        // everyone must finish the initial load before anyone's first
        // boundary store mutates the shared grid
        sh.barrier.sync();
    }

    let mut done = 0usize;
    let mut residual = None;
    let mut error = None;
    // hot-path: begin -- the resident epoch loop: slab-local compute,
    // boundary exchange, and barrier folds, with no allocation allowed
    while done < steps {
        // a trailing partial epoch advances fewer sub-steps; the slab's
        // bt*r halo depth covers any sub <= bt
        let sub = bt.min(steps - done);
        computed += temporal::advance_slab(
            &sh.spec,
            &sh.meta,
            sh.axis,
            cur,
            nxt,
            slab_first,
            &plan.band,
            sub,
            sh.first,
            sh.interior_planes,
            &sh.weights,
            deltas,
        );
        if tol.is_some() {
            // publish per-plane squared-delta partials (the epoch's final
            // sub-step vs the level before it — `cur` vs `nxt` after the
            // core's last swap) into the reduction slots; folded by every
            // worker right after the store barrier below
            slab_delta_partials(
                &sh.spec,
                &sh.meta,
                cur,
                nxt,
                slab_first,
                &plan.band,
                sh.axis,
                sh.first,
                |slot, partial| sh.barrier.put(slot, partial),
            );
        }
        // --- exchange: store only bt*r-deep boundary planes to global ---
        let band_off = (plan.band.start - slab_first) * plane;
        let lo_planes = depth.min(band_planes);
        // SAFETY: band-owned planes; no reader until the barrier below.
        unsafe {
            sh.grid
                .write(plan.band.start * plane, &cur[band_off..band_off + lo_planes * plane])
        };
        // thin bands overlap lo/hi: store (and count — Eq 5) the union
        // once, so the hi store covers only the planes the lo store
        // didn't already publish
        let hi_first = (plan.band.end - lo_planes).max(plan.band.start + lo_planes);
        if hi_first < plan.band.end {
            let hi_off = (hi_first - slab_first) * plane;
            let hi_len = (plan.band.end - hi_first) * plane;
            // SAFETY: band-owned planes; no reader until the barrier below.
            unsafe { sh.grid.write(hi_first * plane, &cur[hi_off..hi_off + hi_len]) };
        }
        moved += (boundary_union_planes(depth, band_planes) * plane * 8) as u64;
        // barrier 1: all boundary stores (and residual puts) published
        sh.barrier.sync();
        if tol.is_some() {
            // identical fold on every worker: slot order, not arrival
            residual = Some(sh.barrier.read_sum());
        }
        // --- load neighbor halo planes from global (into `cur` only:
        // `nxt`'s halo interiors are recomputed before they are read) ---
        let halo_lo = slab_first..plan.band.start;
        if !halo_lo.is_empty() {
            let off = halo_lo.start * plane;
            let len = halo_lo.len() * plane;
            // SAFETY: read-only phase between the two barriers.
            unsafe {
                sh.grid.read(off..off + len, &mut cur[..len]);
            }
            moved += (len * 8) as u64;
        }
        let halo_hi = plan.band.end..plan.slab.end / plane;
        if !halo_hi.is_empty() {
            let off = halo_hi.start * plane;
            let len = halo_hi.len() * plane;
            let loff = (halo_hi.start - slab_first) * plane;
            // SAFETY: read-only phase between the two barriers.
            unsafe {
                sh.grid.read(off..off + len, &mut cur[loff..loff + len]);
            }
            moved += (len * 8) as u64;
        }
        // barrier 2: nobody may overwrite boundary planes or reduction
        // slots (next epoch's store/put) before all neighbors read them
        sh.barrier.sync();
        done += sub;
        if let Some(res) = residual {
            // non-finite guard: NaN/Inf anywhere in the interior poisons
            // the squared step delta, and the slot-ordered fold
            // replicates the poisoned norm identically on every worker —
            // so this break is exactly as collective as a tolerance stop
            if !res.is_finite() {
                // lint: allow(hot-path-alloc) -- cold error exit: the format! runs once, right before the loop breaks
                error = Some(format!(
                    "non-finite residual ({res}) at step {done} (epoch {})",
                    done.div_ceil(bt)
                ));
                break;
            }
        }
        if let (Some(t), Some(res)) = (tol, residual) {
            if res <= t {
                break; // identical residual everywhere: a collective break
            }
        }
    }
    // hot-path: end
    // --- final store: whole band back to global, so the main thread can
    // observe the advanced state between runs ---
    let band_off = (plan.band.start - slab_first) * plane;
    let band_len = band_planes * plane;
    // SAFETY: every worker writes only its own band; the completion
    // handshake orders these stores before any main-thread read.
    unsafe { sh.grid.write(plan.band.start * plane, &cur[band_off..band_off + band_len]) };
    moved += (band_len * 8) as u64;
    Outcome { steps: done, residual, moved, computed, error }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::gold;
    use crate::stencil::parallel;
    use crate::stencil::shape::spec;

    /// The acceptance bar: pooled resident advances are bit-identical to
    /// `gold::run` and to the one-shot persistent driver at every worker
    /// count, including across resumed `advance` calls — all from one
    /// spawn batch.
    #[test]
    fn pooled_matches_gold_and_one_shot_bit_identical_across_threads_and_resume() {
        let s = spec("2d5pt").unwrap();
        let mut d = Domain::for_spec(&s, &[16, 16]).unwrap();
        d.randomize(42);
        let want = gold::run(&s, &d, 7).unwrap();
        for threads in [1usize, 2, 3, 8] {
            let one_shot = parallel::persistent(&s, &d, 7, threads).unwrap();
            assert_eq!(one_shot.result.data, want.data, "threads={threads}");
            let mut pool = StencilPool::spawn(&s, &d, threads).unwrap();
            let r1 = pool.run(3, None).unwrap();
            let r2 = pool.run(4, None).unwrap();
            assert_eq!(r1.steps + r2.steps, 7);
            assert_eq!(pool.state(), want.data, "threads={threads}: pooled vs gold");
            assert_eq!(
                pool.state(),
                one_shot.result.data,
                "threads={threads}: pooled vs one-shot"
            );
            assert_eq!(pool.spawn_count(), pool.workers() as u64, "one spawn batch");
        }
    }

    /// The composition acceptance bar: pooled temporal blocking at
    /// `bt ∈ {2, 4}` is bit-identical to `gold::run` and to pooled
    /// `bt = 1` at every worker count, including across resumed advances
    /// whose step counts are *not* epoch-aligned.
    #[test]
    fn pooled_temporal_bt_2_and_4_bit_identical_to_gold_and_bt1_across_threads_and_resume() {
        let s = spec("2d9pt").unwrap();
        let mut d = Domain::for_spec(&s, &[20, 18]).unwrap();
        d.randomize(8);
        let want = gold::run(&s, &d, 9).unwrap();
        // reference: pooled bt = 1
        let mut base = StencilPool::spawn(&s, &d, 3).unwrap();
        base.run(9, None).unwrap();
        assert_eq!(base.state(), want.data, "bt=1 vs gold");
        for bt in [2usize, 4] {
            for threads in [1usize, 2, 3, 8] {
                let mut pool = StencilPool::spawn_temporal(&s, &d, threads, bt).unwrap();
                assert_eq!(pool.temporal_degree(), bt);
                // 4 + 5: partial epochs inside both resumed runs
                let r1 = pool.run(4, None).unwrap();
                let r2 = pool.run(5, None).unwrap();
                assert_eq!(r1.steps + r2.steps, 9, "bt={bt} threads={threads}");
                assert_eq!(
                    pool.state(),
                    want.data,
                    "bt={bt} threads={threads}: pooled temporal vs gold"
                );
                assert_eq!(pool.spawn_count(), pool.workers() as u64);
                if bt > 1 {
                    assert!(
                        r1.redundancy() > 1.0,
                        "bt={bt} threads={threads}: overlap work must be accounted"
                    );
                }
            }
        }
    }

    /// Thin-band regression: bands thinner than `2 * bt * radius` overlap
    /// their lo/hi boundary stores and force neighbors' halos through
    /// *several* bands — the union-store invariant must still cover every
    /// halo load, and results stay gold-exact.
    #[test]
    fn pooled_temporal_thin_bands_stay_gold_exact() {
        let s = spec("2ds25pt").unwrap(); // radius 6
        let mut d = Domain::for_spec(&s, &[20, 16]).unwrap();
        d.randomize(5);
        let bt = 2;
        // premise: every band is thinner than 2*bt*r (and even than bt*r)
        let bands = parallel::partition(d.interior[1], 4);
        assert!(bands.iter().all(|&(_, l)| l < 2 * bt * s.radius));
        let want = gold::run(&s, &d, 6).unwrap();
        let mut pool = StencilPool::spawn_temporal(&s, &d, 4, bt).unwrap();
        pool.run(6, None).unwrap();
        assert_eq!(pool.state(), want.data);
    }

    #[test]
    fn pooled_matches_gold_3d() {
        let s = spec("3d13pt").unwrap(); // radius 2
        let mut d = Domain::for_spec(&s, &[8, 6, 6]).unwrap();
        d.randomize(9);
        let want = gold::run(&s, &d, 4).unwrap();
        let mut pool = StencilPool::spawn(&s, &d, 3).unwrap();
        pool.run(4, None).unwrap();
        assert_eq!(pool.state(), want.data);
        // and the temporal composition in 3D
        let mut tpool = StencilPool::spawn_temporal(&s, &d, 3, 2).unwrap();
        tpool.run(4, None).unwrap();
        assert_eq!(tpool.state(), want.data, "3D bt=2 vs gold");
    }

    #[test]
    fn run_never_spawns_after_start() {
        let s = spec("2d5pt").unwrap();
        let mut d = Domain::for_spec(&s, &[12, 12]).unwrap();
        d.randomize(1);
        let mut pool = StencilPool::spawn_temporal(&s, &d, 4, 2).unwrap();
        let after_start = pool.spawn_count();
        for _ in 0..5 {
            pool.run(2, None).unwrap();
        }
        assert_eq!(pool.spawn_count(), after_start, "run() must not spawn");
        assert_eq!(after_start, pool.workers() as u64);
    }

    /// Satellite acceptance: a pooled `advance(steps)` at degree `bt`
    /// performs exactly `2 * ceil(steps / bt)` barrier syncs, plus the
    /// one-time initial-load sync on the pool's first run.
    #[test]
    fn barrier_syncs_are_two_per_epoch_plus_the_load_sync() {
        let s = spec("2d5pt").unwrap();
        let mut d = Domain::for_spec(&s, &[16, 16]).unwrap();
        d.randomize(3);
        for (bt, steps) in [(1usize, 6usize), (2, 6), (4, 10), (4, 3)] {
            let mut pool = StencilPool::spawn_temporal(&s, &d, 3, bt).unwrap();
            assert_eq!(pool.barrier_syncs(), 0, "no syncs before the first run");
            let epochs = steps.div_ceil(bt);
            pool.run(steps, None).unwrap();
            assert_eq!(
                pool.barrier_syncs(),
                1 + 2 * epochs as u64,
                "bt={bt} steps={steps}: first run = load sync + 2/epoch"
            );
            // a resumed run re-pays only the per-epoch pairs
            pool.run(steps, None).unwrap();
            assert_eq!(
                pool.barrier_syncs(),
                1 + 4 * epochs as u64,
                "bt={bt} steps={steps}: resumed run adds 2/epoch"
            );
            // and the process-wide counter mirrors the pool's view
            assert!(crate::util::counters::barrier_syncs() >= pool.barrier_syncs());
        }
    }

    #[test]
    fn traffic_accounting_matches_the_one_shot_driver() {
        // one run of `steps` through the pool must account exactly the
        // bytes the one-shot driver reports (it *is* the pool inside)
        let s = spec("2d9pt").unwrap();
        let mut d = Domain::for_spec(&s, &[24, 24]).unwrap();
        d.randomize(3);
        let one_shot = parallel::persistent(&s, &d, 5, 3).unwrap();
        let mut pool = StencilPool::spawn(&s, &d, 3).unwrap();
        let run = pool.run(5, None).unwrap();
        assert_eq!(run.global_bytes, one_shot.global_bytes);
        // a resumed run re-pays boundary/halo/final-store traffic but not
        // the initial slab load
        let again = pool.run(5, None).unwrap();
        assert!(again.global_bytes < run.global_bytes);
    }

    /// With bands thinner than the exchange depth, batching the exchange
    /// into epochs moves strictly fewer bytes per step: the whole thin
    /// band is stored once per *epoch* instead of once per *step*.
    #[test]
    fn temporal_epochs_reduce_thin_band_exchange_traffic() {
        let s = spec("2d5pt").unwrap();
        let mut d = Domain::for_spec(&s, &[12, 64]).unwrap();
        d.randomize(6);
        // threads 4 => bands of 3 planes < 2*bt*r = 8 at bt = 4
        let mut p1 = StencilPool::spawn_temporal(&s, &d, 4, 1).unwrap();
        let mut p4 = StencilPool::spawn_temporal(&s, &d, 4, 4).unwrap();
        // first runs differ by slab-load depth; compare *resumed* runs,
        // which pay only the steady-state exchange + final-store traffic
        p1.run(8, None).unwrap();
        p4.run(8, None).unwrap();
        let steady1 = p1.run(8, None).unwrap();
        let steady4 = p4.run(8, None).unwrap();
        assert!(
            steady4.global_bytes < steady1.global_bytes,
            "bt=4 {} vs bt=1 {}",
            steady4.global_bytes,
            steady1.global_bytes
        );
        // identical numerics all along
        assert_eq!(p1.state(), p4.state());
    }

    #[test]
    fn tolerance_stops_early_with_identical_residual_at_every_thread_count() {
        let s = spec("2d5pt").unwrap();
        let mut d = Domain::for_spec(&s, &[8, 8]).unwrap();
        d.randomize(7);
        let tol = 1e-8;
        let max = 20_000;
        let mut reference: Option<(usize, u64, Vec<f64>)> = None;
        for threads in [1usize, 2, 3] {
            let mut pool = StencilPool::spawn(&s, &d, threads).unwrap();
            let run = pool.run(max, Some(tol)).unwrap();
            let res = run.residual.expect("tracked run reports a residual");
            assert!(run.steps < max, "threads={threads}: did not converge");
            assert!(res <= tol, "threads={threads}: stopped above tol ({res})");
            let state = pool.state();
            match &reference {
                None => reference = Some((run.steps, res.to_bits(), state)),
                Some((steps, bits, want)) => {
                    assert_eq!(run.steps, *steps, "threads={threads}: stop step differs");
                    assert_eq!(res.to_bits(), *bits, "threads={threads}: residual bits");
                    assert_eq!(&state, want, "threads={threads}: state bits");
                }
            }
        }
        // and the serial residual helper agrees with the in-loop norm on
        // a single tracked step
        let mut pool = StencilPool::spawn(&s, &d, 2).unwrap();
        let one = pool.run(1, Some(0.0)).unwrap();
        let next = gold::run(&s, &d, 1).unwrap();
        assert_eq!(
            one.residual.unwrap().to_bits(),
            parallel::residual_norm(&s, &d, &next).to_bits(),
            "in-loop norm must match the host-side helper bit-for-bit"
        );
    }

    /// With `bt > 1` the tolerance check runs at epoch granularity: the
    /// stop lands on the same epoch (same step count, same residual bits,
    /// same state bits) at every worker count.
    #[test]
    fn temporal_tolerance_stops_on_the_same_epoch_at_every_thread_count() {
        let s = spec("2d5pt").unwrap();
        let mut d = Domain::for_spec(&s, &[8, 8]).unwrap();
        d.randomize(7);
        let bt = 4;
        let tol = 1e-8;
        let max = 20_000;
        let mut reference: Option<(usize, u64, Vec<f64>)> = None;
        for threads in [1usize, 2, 3, 8] {
            let mut pool = StencilPool::spawn_temporal(&s, &d, threads, bt).unwrap();
            let run = pool.run(max, Some(tol)).unwrap();
            let res = run.residual.expect("tracked run reports a residual");
            assert!(run.steps < max, "threads={threads}: did not converge");
            assert!(res <= tol, "threads={threads}: stopped above tol ({res})");
            assert_eq!(run.steps % bt, 0, "threads={threads}: stop is epoch-aligned");
            let state = pool.state();
            match &reference {
                None => reference = Some((run.steps, res.to_bits(), state)),
                Some((steps, bits, want)) => {
                    assert_eq!(run.steps, *steps, "threads={threads}: stop epoch differs");
                    assert_eq!(res.to_bits(), *bits, "threads={threads}: residual bits");
                    assert_eq!(&state, want, "threads={threads}: state bits");
                }
            }
        }
        // the epoch-granular residual is the *final sub-step's* norm:
        // identical to what a bt=1 pool reports after the same number of
        // steps when that count is epoch-aligned
        let (steps, bits, _) = reference.unwrap();
        let mut base = StencilPool::spawn(&s, &d, 2).unwrap();
        let base_run = base.run(steps, Some(0.0)).unwrap();
        assert_eq!(base_run.residual.unwrap().to_bits(), bits);
    }

    #[test]
    fn untracked_runs_report_no_residual() {
        let s = spec("2d5pt").unwrap();
        let mut d = Domain::for_spec(&s, &[8, 8]).unwrap();
        d.randomize(2);
        let mut pool = StencilPool::spawn(&s, &d, 2).unwrap();
        let run = pool.run(3, None).unwrap();
        assert!(run.residual.is_none());
        assert_eq!(run.steps, 3);
    }

    #[test]
    fn drop_joins_all_workers() {
        let s = spec("2d5pt").unwrap();
        let mut d = Domain::for_spec(&s, &[8, 8]).unwrap();
        d.randomize(4);
        let pool = StencilPool::spawn(&s, &d, 4).unwrap();
        let weak = pool.shared_weak();
        drop(pool);
        // every worker held an Arc clone; all joined => all released
        assert_eq!(weak.strong_count(), 0, "workers not joined on drop");
    }

    #[test]
    fn run_after_shutdown_errors_instead_of_hanging() {
        let s = spec("2d5pt").unwrap();
        let mut d = Domain::for_spec(&s, &[8, 8]).unwrap();
        d.randomize(6);
        let mut pool = StencilPool::spawn(&s, &d, 2).unwrap();
        pool.run(2, None).unwrap();
        pool.shutdown();
        // the grid stays readable after shutdown...
        assert_eq!(pool.state().len(), d.data.len());
        // ...but a further run is an error, not a silent deadlock
        let err = pool.run(1, None).unwrap_err();
        assert!(format!("{err}").contains("shut down"), "{err}");
    }

    /// Satellite: the teardown race — 64 rapid create/drop cycles, mixing
    /// dropped-idle pools, dropped-after-run pools, and explicit
    /// shutdowns. Every join must complete promptly (the test hanging IS
    /// the failure mode the shutdown flag closes), and every worker must
    /// release its `Arc<Shared>`.
    #[test]
    fn rapid_create_drop_cycles_never_hang() {
        let s = spec("2d5pt").unwrap();
        let mut d = Domain::for_spec(&s, &[8, 8]).unwrap();
        d.randomize(12);
        for cycle in 0..64usize {
            let mut pool = StencilPool::spawn(&s, &d, 1 + cycle % 4).unwrap();
            let weak = pool.shared_weak();
            match cycle % 3 {
                0 => {} // drop a pool that never ran
                1 => {
                    pool.run(1, None).unwrap();
                }
                _ => {
                    pool.run(2, None).unwrap();
                    pool.shutdown(); // explicit teardown, then drop's no-op
                }
            }
            drop(pool);
            assert_eq!(weak.strong_count(), 0, "cycle {cycle}: workers not joined");
        }
    }

    /// Satellite: solo-pool participation in the resilience layer — a
    /// grid-only checkpoint taken between runs restores bit-identically,
    /// with the reload generation forcing the workers' resident slabs to
    /// re-read the restored grid.
    #[test]
    fn checkpoint_restore_replays_bit_identically() {
        let s = spec("2d9pt").unwrap();
        let mut d = Domain::for_spec(&s, &[16, 14]).unwrap();
        d.randomize(11);
        let mut pool = StencilPool::spawn_temporal(&s, &d, 3, 2).unwrap();
        pool.run(4, None).unwrap();
        let ck = pool.checkpoint();
        assert_eq!(ck.epoch, 4, "checkpoint stamps the lifetime step count");
        assert!(ck.bytes >= (d.data.len() * 8) as u64);
        pool.run(6, None).unwrap();
        let want = pool.state();
        pool.restore(&ck).unwrap();
        let replay = pool.run(6, None).unwrap();
        assert_eq!(replay.steps, 6);
        assert_eq!(pool.state(), want, "restored replay must walk the same bits");
        // a checkpoint from a different geometry is rejected, not mangled
        let mut other = Domain::for_spec(&s, &[8, 8]).unwrap();
        other.randomize(1);
        let small = StencilPool::spawn(&s, &other, 2).unwrap();
        assert!(pool.restore(&small.checkpoint()).is_err());
    }

    /// Satellite: the in-loop residual fold guards against non-finite
    /// state — a tracked run over NaN-poisoned data fails with a solver
    /// error naming the step/epoch instead of silently iterating to the
    /// step cap, and the pool stays usable (restorable) afterwards.
    #[test]
    fn non_finite_residual_fails_naming_the_epoch() {
        let s = spec("2d5pt").unwrap();
        let mut d = Domain::for_spec(&s, &[8, 8]).unwrap();
        d.randomize(13);
        let clean = d.clone();
        let plane = d.padded[2];
        d.data[(d.padded[1] / 2) * plane + plane / 2] = f64::NAN; // interior cell
        let mut pool = StencilPool::spawn(&s, &d, 2).unwrap();
        let err = pool.run(50, Some(1e-12)).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("non-finite residual"), "{msg}");
        assert!(msg.contains("epoch 1"), "{msg}");
        // the failure break is collective, so the pool survives: restore
        // clean state and the same pool runs (and converges) again
        let reference = StencilPool::spawn(&s, &clean, 2).unwrap();
        pool.restore(&reference.checkpoint()).unwrap();
        let run = pool.run(3, Some(-1.0)).unwrap();
        assert!(run.residual.unwrap().is_finite());
        let want = gold::run(&s, &clean, 3).unwrap();
        assert_eq!(pool.state(), want.data);
    }

    #[test]
    fn spawn_rejects_zero_threads_zero_bt_and_empty_domains() {
        let s = spec("2d5pt").unwrap();
        let mut d = Domain::for_spec(&s, &[8, 8]).unwrap();
        d.randomize(4);
        assert!(StencilPool::spawn(&s, &d, 0).is_err());
        assert!(StencilPool::spawn_temporal(&s, &d, 2, 0).is_err());
        let empty = Domain::zeros([1, 0, 8], s.radius, 2);
        assert!(StencilPool::spawn(&s, &empty, 2).is_err());
    }
}
