//! Spawn-once persistent stencil worker pool: the PERKS execution model
//! for iterative stencils, with the time loop resident in the workers
//! *across* `advance` boundaries.
//!
//! # Why a pool
//!
//! The paper's whole point is that the time loop lives inside the
//! persistent kernel, so nothing is relaunched per step. The one-shot
//! [`crate::stencil::parallel::persistent`] driver realizes that *within*
//! one call — but still pays a full spawn/join cycle on every call, which
//! is exactly the amortization boundary the kernel-batching literature
//! (and our own `cg::pool`, PR 2) pushes launches across. This module is
//! the stencil counterpart of [`crate::cg::pool::CgPool`]:
//!
//! | GPU (PERKS kernel)            | CPU (`StencilPool`)                    |
//! |-------------------------------|----------------------------------------|
//! | thread block                  | pool worker (OS thread, spawn-once)    |
//! | kernel launch / relaunch      | `StencilPool::spawn` (once per solve)  |
//! | TB's domain tile              | worker's banded `ThreadPlan`           |
//! | registers/smem-resident tile  | worker's slab (`local`), hot in L1/L2  |
//! |                               | **across `advance` calls**             |
//! | `grid.sync()`                 | `GridBarrier::sync`                    |
//! | grid-sync + device reduction  | `put` + `read_sum` residual all-reduce |
//!
//! # Command protocol
//!
//! Workers are spawned once by [`StencilPool::spawn`] and then park on a
//! condvar. The main thread drives them with epoch-stamped commands
//! (`Run { steps, tol }` / `Shutdown`) through the control mutex; each
//! worker executes the whole resident time loop for a `Run`, reports into
//! the shared `Outcome`, bumps `finished`, and parks again. The
//! command/completion handshake establishes happens-before in both
//! directions, so between runs the main thread may read the shared grid
//! ([`StencilPool::state`]) while the workers' slabs stay untouched — and
//! current: every run ends with a whole-band store, and the resident loop
//! refreshes halos before finishing, so slab and grid agree at every park.
//!
//! # The two-barrier exchange invariant
//!
//! Each resident step stores only the band's boundary planes to the
//! shared grid and reloads the halo planes, bracketed by two grid
//! barriers (see `stencil::parallel`'s module docs): barrier 1 orders
//! every boundary *store* before any halo *load*; barrier 2 orders every
//! halo load before the next step's stores. Between the two barriers the
//! grid is read-only — which is where the in-loop residual folds: workers
//! `put` one squared-delta partial per interior plane before barrier 1,
//! and every worker folds the slots in plane order (`read_sum`) right
//! after it, giving a deterministic, thread-count-invariant convergence
//! norm with **zero extra barriers**.
//!
//! # Determinism
//!
//! Cell updates are pure functions of the previous state with a fixed
//! accumulation order (`gold::accumulate_row`), so pooled iterates are
//! bit-identical to `gold::run`, to the one-shot driver, and to
//! themselves at every worker count and across resumed `advance`s. The
//! residual norm folds fixed per-plane partials in plane-index order, so
//! it too is identical at every worker count — a tolerance stop happens
//! on the same step everywhere.
//!
//! # Safety protocol
//!
//! The grid lives in a [`SharedGrid`] (`UnsafeCell`) shared by the main
//! thread and the workers. Exclusive access is phased exactly as in
//! `cg::pool`: the main thread touches it only while the pool is idle
//! (the handshake above), and within a run the workers partition writes
//! by band ownership with the two-barrier protocol separating producer
//! and consumer phases.

use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::coordinator::barrier::GridBarrier;
use crate::error::{Error, Result};
use crate::stencil::grid::Domain;
use crate::stencil::parallel::{
    band_delta_partials, bands_for, boundary_union_planes, compute_band, plans, scatter_band,
    SharedGrid, ThreadPlan,
};
use crate::stencil::shape::StencilSpec;
use crate::util::counters;

/// Command issued to the parked workers; epoch-stamped in `CtlState`.
#[derive(Clone, Copy)]
enum Cmd {
    Idle,
    /// Run up to `steps` resident time steps. With `tol = Some(t)` the
    /// workers track the squared step-delta norm each step and stop
    /// (collectively) once it drops to `t`; with `None` no residual is
    /// computed — fixed-step advances pay nothing for the machinery.
    Run { steps: usize, tol: Option<f64> },
    Shutdown,
}

/// What one `Run` produced. `steps`/`residual` are replicated values
/// (worker 0 publishes them); `moved` is summed over all workers.
#[derive(Clone, Default)]
struct Outcome {
    steps: usize,
    residual: Option<f64>,
    moved: u64,
    error: Option<String>,
}

struct CtlState {
    epoch: u64,
    cmd: Cmd,
    finished: usize,
    outcome: Outcome,
}

struct Control {
    state: Mutex<CtlState>,
    cmd_cv: Condvar,
    done_cv: Condvar,
}

impl Control {
    /// Lock the control state, recovering from poisoning (a worker panic
    /// while holding the lock) — the state is plain data with no invariant
    /// a panic can break, and refusing would turn one panic into a
    /// double-panic abort in `Drop`.
    fn lock(&self) -> std::sync::MutexGuard<'_, CtlState> {
        self.state.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
    }
}

/// Everything the resident workers share.
struct Shared {
    spec: StencilSpec,
    /// Domain geometry template; `data` is empty — the numbers live in
    /// `grid`, and [`StencilPool::state_domain`] re-attaches them.
    meta: Domain,
    /// Banded axis (0 for 3D, 1 for 2D) and plane stride, as in
    /// `parallel::Bands`.
    axis: usize,
    plane: usize,
    /// First interior plane in padded coords (the reduction-slot offset).
    first: usize,
    plans: Vec<ThreadPlan>,
    weights: Vec<f64>,
    grid: SharedGrid,
    barrier: GridBarrier,
    ctl: Control,
}

/// Result of one [`StencilPool::run`].
#[derive(Clone, Debug)]
pub struct StencilRun {
    /// Time steps actually performed (early-stop on `tol`).
    pub steps: usize,
    /// Last in-loop residual norm (squared step delta), `Some` iff the
    /// run tracked one.
    pub residual: Option<f64>,
    /// Bytes this run moved through the shared ("global") array, summed
    /// over workers: initial slab loads on the first run, per-step
    /// boundary-union stores + halo reloads, and the final band store.
    pub global_bytes: u64,
}

/// A pool of persistent banded stencil workers: spawned once, parked
/// between runs, slabs resident across runs, joined on drop. See the
/// module docs for the execution model.
pub struct StencilPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    workers: usize,
    spawned: u64,
}

impl StencilPool {
    /// Spawn the resident workers for one domain. The worker count is the
    /// band count: `threads` clamped to the interior planes, so no worker
    /// is idle by construction. Fails on `threads == 0` and on domains
    /// with no interior planes to band.
    pub fn spawn(spec: &StencilSpec, x0: &Domain, threads: usize) -> Result<Self> {
        if threads == 0 {
            return Err(Error::invalid("threads must be > 0"));
        }
        let geometry = bands_for(x0, spec, threads)?;
        let r = spec.radius;
        let plane = geometry.plane;
        let total_planes = x0.data.len() / plane;
        let plans = plans(&geometry, r, total_planes, plane);
        let workers = plans.len();
        // one residual-reduction slot per interior plane of the banded
        // axis: partials are per *plane*, not per worker, which is what
        // makes the folded norm invariant to the thread count
        let interior_planes = if geometry.axis == 0 { x0.interior[0] } else { x0.interior[1] };
        let mut meta = x0.clone();
        meta.data = Vec::new();
        let shared = Arc::new(Shared {
            spec: spec.clone(),
            meta,
            axis: geometry.axis,
            plane,
            first: geometry.first,
            plans,
            weights: spec.weights(),
            grid: SharedGrid::new(x0.data.clone()),
            barrier: GridBarrier::with_reduction(workers, interior_planes),
            ctl: Control {
                state: Mutex::new(CtlState {
                    epoch: 0,
                    cmd: Cmd::Idle,
                    finished: 0,
                    outcome: Outcome::default(),
                }),
                cmd_cv: Condvar::new(),
                done_cv: Condvar::new(),
            },
        });
        counters::note_thread_spawns(workers as u64);
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let sh = shared.clone();
            let spawned = std::thread::Builder::new()
                .name(format!("stencil-pool-{w}"))
                .spawn(move || worker_main(&sh, w));
            match spawned {
                Ok(h) => handles.push(h),
                Err(e) => {
                    // don't leak the workers that did start: they are
                    // parked on cmd_cv and would otherwise pin their
                    // Arc<Shared> (and the grid) forever. The barrier is
                    // not armed yet — no worker enters the resident loop
                    // without a Run command — so a shutdown epoch is safe.
                    {
                        let mut g = shared.ctl.lock();
                        g.epoch += 1;
                        g.cmd = Cmd::Shutdown;
                        shared.ctl.cmd_cv.notify_all();
                    }
                    for h in handles {
                        let _ = h.join();
                    }
                    return Err(Error::Solver(format!("pool spawn failed: {e}")));
                }
            }
        }
        Ok(Self { shared, handles, workers, spawned: workers as u64 })
    }

    /// Resident worker count (threads clamped to the band count).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// OS threads this pool has ever spawned — constant after `spawn`,
    /// which is the point: `run` must never add to it.
    pub fn spawn_count(&self) -> u64 {
        self.spawned
    }

    /// Total time workers spent blocked at the grid barrier (summed).
    pub fn barrier_wait(&self) -> std::time::Duration {
        self.shared.barrier.total_wait()
    }

    /// [`StencilPool::barrier_wait`] in seconds.
    pub fn barrier_wait_seconds(&self) -> f64 {
        self.barrier_wait().as_secs_f64()
    }

    /// Run up to `steps` resident time steps on the parked workers (no
    /// thread spawns). With `tol = Some(t)` the workers compute the
    /// squared step-delta norm each step and stop collectively once it
    /// drops to `t`; the last norm is returned in
    /// [`StencilRun::residual`]. `Err` is reserved for a *collective*
    /// worker panic (all workers fail at the same deterministic point —
    /// the shape every replicated-control-flow bug takes), after which
    /// the pool stays usable. As in `cg::pool`, a panic in only *some*
    /// workers strands their peers at the grid barrier and hangs the run;
    /// the deterministic lockstep control flow is what rules that out.
    pub fn run(&mut self, steps: usize, tol: Option<f64>) -> Result<StencilRun> {
        if self.handles.is_empty() {
            // after shutdown() there is no worker left to execute the
            // command — error out instead of waiting forever on done_cv
            return Err(Error::Solver("stencil pool is shut down".into()));
        }
        {
            let mut g = self.shared.ctl.lock();
            g.epoch += 1;
            g.cmd = Cmd::Run { steps, tol };
            g.finished = 0;
            g.outcome = Outcome::default(); // no stale error/steps carry over
            self.shared.ctl.cmd_cv.notify_all();
        }
        let outcome = {
            let mut g = self.shared.ctl.lock();
            while g.finished < self.workers {
                g = self.shared.ctl.done_cv.wait(g).unwrap_or_else(|p| p.into_inner());
            }
            g.outcome.clone()
        };
        if let Some(msg) = outcome.error {
            return Err(Error::Solver(msg));
        }
        Ok(StencilRun {
            steps: outcome.steps,
            residual: outcome.residual,
            global_bytes: outcome.moved,
        })
    }

    /// Snapshot the padded domain data. Callable only between runs: the
    /// completion handshake of the previous `run` happened-before this
    /// read, and no worker touches the grid while parked.
    pub fn state(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.shared.grid.len()];
        // SAFETY: pool idle (see above) — no concurrent writer.
        unsafe { self.shared.grid.read(0..out.len(), &mut out) };
        out
    }

    /// [`StencilPool::state`] re-attached to the domain geometry.
    pub fn state_domain(&self) -> Domain {
        let mut d = self.shared.meta.clone();
        d.data = self.state();
        d
    }

    /// Shut the workers down and join them, leaving the grid readable:
    /// [`StencilPool::state`]/[`StencilPool::state_domain`] still work
    /// afterwards, but `run` must not be called again (there are no
    /// workers left to execute it). The one-shot driver uses this to keep
    /// the join inside its timed region (matching the host-loop baseline,
    /// whose per-step joins are always timed); `drop` after this is a
    /// no-op.
    pub fn shutdown(&mut self) {
        {
            let mut g = self.shared.ctl.lock();
            g.epoch += 1;
            g.cmd = Cmd::Shutdown;
            self.shared.ctl.cmd_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }

    #[cfg(test)]
    fn shared_weak(&self) -> std::sync::Weak<Shared> {
        Arc::downgrade(&self.shared)
    }
}

impl Drop for StencilPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Park on the control condvar; execute each epoch's command; exit on
/// shutdown. The slab (`local`), the results buffer and the linearized
/// stencil offsets live *here*, outside the command loop: they are built
/// once per pool lifetime and stay resident across `advance` commands —
/// the CPU analog of a thread block keeping its tile in registers/smem
/// for the whole solve.
fn worker_main(sh: &Shared, w: usize) {
    let plan = &sh.plans[w];
    let r = sh.spec.radius;
    let band_planes = plan.band.len();
    let interior_per_plane = if sh.axis == 0 {
        (sh.meta.padded[1] - 2 * r) * (sh.meta.padded[2] - 2 * r)
    } else {
        sh.meta.padded[2] - 2 * r
    };
    let mut local = vec![0.0f64; plan.slab.len()];
    let mut results = vec![0.0f64; band_planes * interior_per_plane];
    let deltas =
        crate::stencil::gold::linear_deltas(&sh.spec, sh.meta.padded[1], sh.meta.padded[2]);
    let mut loaded = false;

    let mut seen = 0u64;
    loop {
        let cmd = {
            let mut g = sh.ctl.lock();
            while g.epoch == seen {
                g = sh.ctl.cmd_cv.wait(g).unwrap_or_else(|p| p.into_inner());
            }
            seen = g.epoch;
            g.cmd
        };
        match cmd {
            Cmd::Idle => {}
            Cmd::Shutdown => break,
            Cmd::Run { steps, tol } => {
                // A panic inside the resident loop would otherwise leave
                // `finished` forever short and hang `run()`. Catching it
                // lets a *collective* panic (all workers fail at the same
                // deterministic point) surface as an error, as in cg::pool.
                let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    run_steps(sh, w, steps, tol, &mut local, &mut results, &deltas, &mut loaded)
                }))
                .unwrap_or_else(|_| Outcome {
                    steps: 0,
                    residual: None,
                    moved: 0,
                    error: Some(format!("stencil pool worker {w} panicked during run")),
                });
                let mut g = sh.ctl.lock();
                g.outcome.moved += out.moved; // every worker's traffic counts
                if w == 0 {
                    // steps/residual are replicated; worker 0 publishes
                    g.outcome.steps = out.steps;
                    g.outcome.residual = out.residual;
                }
                if out.error.is_some() && g.outcome.error.is_none() {
                    g.outcome.error = out.error;
                }
                g.finished += 1;
                if g.finished == sh.barrier.participants() {
                    sh.ctl.done_cv.notify_all();
                }
            }
        }
    }
}

/// The resident time loop of worker `w` for one `Run` command. All
/// workers execute the same control flow on an identical residual (the
/// slot-ordered fold), so early breaks are collective and the barrier
/// never deadlocks.
#[allow(clippy::too_many_arguments)]
fn run_steps(
    sh: &Shared,
    w: usize,
    steps: usize,
    tol: Option<f64>,
    local: &mut [f64],
    results: &mut [f64],
    deltas: &[isize],
    loaded: &mut bool,
) -> Outcome {
    let plan = &sh.plans[w];
    let r = sh.spec.radius;
    let plane = sh.plane;
    let slab_first = plan.slab.start / plane;
    let band_planes = plan.band.len();
    let mut moved = 0u64;

    if !*loaded {
        // --- first run only: initial load, slab (band + halos) ---
        // SAFETY: no writer before the barrier below; disjoint reads.
        unsafe { sh.grid.read(plan.slab.clone(), local) };
        moved += (plan.slab.len() * 8) as u64;
        *loaded = true;
        // everyone must finish the initial load before anyone's first
        // boundary store mutates the shared grid
        sh.barrier.sync();
    }

    let mut done = 0usize;
    let mut residual = None;
    for _ in 0..steps {
        compute_band(
            &sh.spec, &sh.meta, local, slab_first, &plan.band, &sh.weights, deltas, sh.axis,
            results,
        );
        if tol.is_some() {
            // publish per-plane squared-delta partials (results vs the
            // pre-update slab) into the reduction slots; folded by every
            // worker right after the store barrier below
            band_delta_partials(
                &sh.spec,
                &sh.meta,
                local,
                slab_first,
                &plan.band,
                sh.axis,
                sh.first,
                results,
                |slot, partial| sh.barrier.put(slot, partial),
            );
        }
        // update local slab interior with new values
        let band_off = (plan.band.start - slab_first) * plane;
        let band_len = band_planes * plane;
        scatter_band(
            &sh.spec,
            &sh.meta,
            &plan.band,
            sh.axis,
            results,
            &mut local[band_off..band_off + band_len],
            plan.band.start,
        );
        // --- exchange: store only boundary planes to global ---
        let lo_planes = r.min(band_planes);
        // SAFETY: band-owned planes; no reader until the barrier below.
        unsafe {
            sh.grid
                .write(plan.band.start * plane, &local[band_off..band_off + lo_planes * plane])
        };
        let hi_planes = r.min(band_planes);
        let hi_first = plan.band.end - hi_planes;
        let hi_off = (hi_first - slab_first) * plane;
        unsafe {
            sh.grid.write(hi_first * plane, &local[hi_off..hi_off + hi_planes * plane])
        };
        // thin bands overlap lo/hi: traffic counts the union once (Eq 5)
        moved += (boundary_union_planes(r, band_planes) * plane * 8) as u64;
        // barrier 1: all boundary stores (and residual puts) published
        sh.barrier.sync();
        if tol.is_some() {
            // identical fold on every worker: slot order, not arrival
            residual = Some(sh.barrier.read_sum());
        }
        // --- load neighbor halo planes from global ---
        let halo_lo = plan.slab.start / plane..plan.band.start;
        if !halo_lo.is_empty() {
            let off = halo_lo.start * plane;
            let len = halo_lo.len() * plane;
            // SAFETY: read-only phase between the two barriers.
            unsafe {
                sh.grid.read(off..off + len, &mut local[..len]);
            }
            moved += (len * 8) as u64;
        }
        let halo_hi = plan.band.end..plan.slab.end / plane;
        if !halo_hi.is_empty() {
            let off = halo_hi.start * plane;
            let len = halo_hi.len() * plane;
            let loff = (halo_hi.start - slab_first) * plane;
            unsafe {
                sh.grid.read(off..off + len, &mut local[loff..loff + len]);
            }
            moved += (len * 8) as u64;
        }
        // barrier 2: nobody may overwrite boundary planes or reduction
        // slots (next step's store/put) before all neighbors read them
        sh.barrier.sync();
        done += 1;
        if let (Some(t), Some(res)) = (tol, residual) {
            if res <= t {
                break; // identical residual everywhere: a collective break
            }
        }
    }
    // --- final store: whole band back to global, so the main thread can
    // observe the advanced state between runs ---
    let band_off = (plan.band.start - slab_first) * plane;
    let band_len = band_planes * plane;
    // SAFETY: every worker writes only its own band; the completion
    // handshake orders these stores before any main-thread read.
    unsafe { sh.grid.write(plan.band.start * plane, &local[band_off..band_off + band_len]) };
    moved += (band_len * 8) as u64;
    Outcome { steps: done, residual, moved, error: None }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::gold;
    use crate::stencil::parallel;
    use crate::stencil::shape::spec;

    /// The acceptance bar: pooled resident advances are bit-identical to
    /// `gold::run` and to the one-shot persistent driver at every worker
    /// count, including across resumed `advance` calls — all from one
    /// spawn batch.
    #[test]
    fn pooled_matches_gold_and_one_shot_bit_identical_across_threads_and_resume() {
        let s = spec("2d5pt").unwrap();
        let mut d = Domain::for_spec(&s, &[16, 16]).unwrap();
        d.randomize(42);
        let want = gold::run(&s, &d, 7).unwrap();
        for threads in [1usize, 2, 3, 8] {
            let one_shot = parallel::persistent(&s, &d, 7, threads).unwrap();
            assert_eq!(one_shot.result.data, want.data, "threads={threads}");
            let mut pool = StencilPool::spawn(&s, &d, threads).unwrap();
            let r1 = pool.run(3, None).unwrap();
            let r2 = pool.run(4, None).unwrap();
            assert_eq!(r1.steps + r2.steps, 7);
            assert_eq!(pool.state(), want.data, "threads={threads}: pooled vs gold");
            assert_eq!(
                pool.state(),
                one_shot.result.data,
                "threads={threads}: pooled vs one-shot"
            );
            assert_eq!(pool.spawn_count(), pool.workers() as u64, "one spawn batch");
        }
    }

    #[test]
    fn pooled_matches_gold_3d() {
        let s = spec("3d13pt").unwrap(); // radius 2
        let mut d = Domain::for_spec(&s, &[8, 6, 6]).unwrap();
        d.randomize(9);
        let want = gold::run(&s, &d, 4).unwrap();
        let mut pool = StencilPool::spawn(&s, &d, 3).unwrap();
        pool.run(4, None).unwrap();
        assert_eq!(pool.state(), want.data);
    }

    #[test]
    fn run_never_spawns_after_start() {
        let s = spec("2d5pt").unwrap();
        let mut d = Domain::for_spec(&s, &[12, 12]).unwrap();
        d.randomize(1);
        let mut pool = StencilPool::spawn(&s, &d, 4).unwrap();
        let after_start = pool.spawn_count();
        for _ in 0..5 {
            pool.run(2, None).unwrap();
        }
        assert_eq!(pool.spawn_count(), after_start, "run() must not spawn");
        assert_eq!(after_start, pool.workers() as u64);
    }

    #[test]
    fn traffic_accounting_matches_the_one_shot_driver() {
        // one run of `steps` through the pool must account exactly the
        // bytes the one-shot driver reports (it *is* the pool inside)
        let s = spec("2d9pt").unwrap();
        let mut d = Domain::for_spec(&s, &[24, 24]).unwrap();
        d.randomize(3);
        let one_shot = parallel::persistent(&s, &d, 5, 3).unwrap();
        let mut pool = StencilPool::spawn(&s, &d, 3).unwrap();
        let run = pool.run(5, None).unwrap();
        assert_eq!(run.global_bytes, one_shot.global_bytes);
        // a resumed run re-pays boundary/halo/final-store traffic but not
        // the initial slab load
        let again = pool.run(5, None).unwrap();
        assert!(again.global_bytes < run.global_bytes);
    }

    #[test]
    fn tolerance_stops_early_with_identical_residual_at_every_thread_count() {
        let s = spec("2d5pt").unwrap();
        let mut d = Domain::for_spec(&s, &[8, 8]).unwrap();
        d.randomize(7);
        let tol = 1e-8;
        let max = 20_000;
        let mut reference: Option<(usize, u64, Vec<f64>)> = None;
        for threads in [1usize, 2, 3] {
            let mut pool = StencilPool::spawn(&s, &d, threads).unwrap();
            let run = pool.run(max, Some(tol)).unwrap();
            let res = run.residual.expect("tracked run reports a residual");
            assert!(run.steps < max, "threads={threads}: did not converge");
            assert!(res <= tol, "threads={threads}: stopped above tol ({res})");
            let state = pool.state();
            match &reference {
                None => reference = Some((run.steps, res.to_bits(), state)),
                Some((steps, bits, want)) => {
                    assert_eq!(run.steps, *steps, "threads={threads}: stop step differs");
                    assert_eq!(res.to_bits(), *bits, "threads={threads}: residual bits");
                    assert_eq!(&state, want, "threads={threads}: state bits");
                }
            }
        }
        // and the serial residual helper agrees with the in-loop norm on
        // a single tracked step
        let mut pool = StencilPool::spawn(&s, &d, 2).unwrap();
        let one = pool.run(1, Some(0.0)).unwrap();
        let next = gold::run(&s, &d, 1).unwrap();
        assert_eq!(
            one.residual.unwrap().to_bits(),
            parallel::residual_norm(&s, &d, &next).to_bits(),
            "in-loop norm must match the host-side helper bit-for-bit"
        );
    }

    #[test]
    fn untracked_runs_report_no_residual() {
        let s = spec("2d5pt").unwrap();
        let mut d = Domain::for_spec(&s, &[8, 8]).unwrap();
        d.randomize(2);
        let mut pool = StencilPool::spawn(&s, &d, 2).unwrap();
        let run = pool.run(3, None).unwrap();
        assert!(run.residual.is_none());
        assert_eq!(run.steps, 3);
    }

    #[test]
    fn drop_joins_all_workers() {
        let s = spec("2d5pt").unwrap();
        let mut d = Domain::for_spec(&s, &[8, 8]).unwrap();
        d.randomize(4);
        let pool = StencilPool::spawn(&s, &d, 4).unwrap();
        let weak = pool.shared_weak();
        drop(pool);
        // every worker held an Arc clone; all joined => all released
        assert_eq!(weak.strong_count(), 0, "workers not joined on drop");
    }

    #[test]
    fn run_after_shutdown_errors_instead_of_hanging() {
        let s = spec("2d5pt").unwrap();
        let mut d = Domain::for_spec(&s, &[8, 8]).unwrap();
        d.randomize(6);
        let mut pool = StencilPool::spawn(&s, &d, 2).unwrap();
        pool.run(2, None).unwrap();
        pool.shutdown();
        // the grid stays readable after shutdown...
        assert_eq!(pool.state().len(), d.data.len());
        // ...but a further run is an error, not a silent deadlock
        let err = pool.run(1, None).unwrap_err();
        assert!(format!("{err}").contains("shut down"), "{err}");
    }

    #[test]
    fn spawn_rejects_zero_threads_and_empty_domains() {
        let s = spec("2d5pt").unwrap();
        let mut d = Domain::for_spec(&s, &[8, 8]).unwrap();
        d.randomize(4);
        assert!(StencilPool::spawn(&s, &d, 0).is_err());
        let empty = Domain::zeros([1, 0, 8], s.radius, 2);
        assert!(StencilPool::spawn(&s, &empty, 2).is_err());
    }
}
