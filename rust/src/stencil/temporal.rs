//! Overlapped temporal blocking — the optimization family the paper
//! positions PERKS as *orthogonal* to (§I, §II-C).
//!
//! With temporal blocking degree `bt`, each thread block loads its tile
//! plus a halo of `bt * rad` layers and advances `bt` steps locally with
//! redundant computation in the shrinking halo, so a device-wide exchange
//! is needed only every `bt` steps. The cost is the redundant loads and
//! computation in the overlap region (which is why high degrees stop
//! paying off — the paper's argument for PERKS instead).
//!
//! This module implements overlapped temporal blocking for the CPU
//! persistent-threads substrate, both standalone (relaunch every bt
//! steps: the AN5D-style baseline) and *composed with* PERKS (persistent
//! threads + temporal blocking inside each exchange epoch) — directly
//! demonstrating the paper's claim that the two compose.

use crate::error::{Error, Result};
use crate::stencil::grid::Domain;
use crate::stencil::gold;
use crate::stencil::shape::StencilSpec;

/// Redundant-computation accounting for one temporal-blocking epoch.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OverlapCost {
    /// Cells computed per epoch including redundant halo work.
    pub computed_cells: f64,
    /// Useful cells per epoch (tile area x bt).
    pub useful_cells: f64,
}

impl OverlapCost {
    /// Redundancy ratio >= 1; grows with bt — the paper's limit on
    /// temporal blocking degree.
    pub fn redundancy(&self) -> f64 {
        self.computed_cells / self.useful_cells
    }
}

/// Analytic overlap cost for a 2D tile of (tx, ty) at degree `bt` and
/// stencil radius `rad` (overlapped/trapezoidal tiling: at step k the
/// computed region is the tile grown by (bt - k) * rad on each side).
pub fn overlap_cost_2d(tx: usize, ty: usize, rad: usize, bt: usize) -> OverlapCost {
    let mut computed = 0.0;
    for k in 1..=bt {
        let grow = (bt - k) * rad;
        computed += ((tx + 2 * grow) * (ty + 2 * grow)) as f64;
    }
    OverlapCost { computed_cells: computed, useful_cells: (tx * ty * bt) as f64 }
}

/// One thread's slab advanced `bt` steps without any exchange, using an
/// overlap halo of `bt * rad` planes. Returns the number of *computed*
/// (including redundant) cell updates for accounting.
///
/// `slab` is a padded sub-domain of `full` covering the thread's band
/// plus `bt * rad` halo planes each side (clamped at the domain edge,
/// where the Dirichlet ring substitutes).
fn advance_slab_2d(
    spec: &StencilSpec,
    full: &Domain,
    slab: &mut [f64],
    slab_first: usize, // first padded row held in `slab`
    slab_rows: usize,
    band: std::ops::Range<usize>, // rows this thread owns (padded coords)
    bt: usize,
) -> u64 {
    let px = full.padded[2];
    let r = spec.radius;
    let weights = spec.weights();
    let mut scratch = vec![0.0f64; slab.len()];
    let mut computed = 0u64;
    let top_edge = r; // first interior row of the global domain
    let bot_edge = full.padded[1] - r; // one past last interior row
    for k in 1..=bt {
        let grow = (bt - k) * r;
        // rows to compute this sub-step: band grown by `grow`, clamped to
        // the global interior and to what the slab can source (slab rows
        // shrink by r each sub-step from each un-clamped edge)
        let lo = band.start.saturating_sub(grow).max(top_edge).max(slab_first + 1);
        let hi = (band.end + grow).min(bot_edge).min(slab_first + slab_rows - 1);
        scratch.copy_from_slice(slab);
        for y in lo..hi {
            let ly = y - slab_first;
            for x in r..px - r {
                let mut acc = 0.0;
                for (&(_, dy, dx), &w) in spec.offsets.iter().zip(&weights) {
                    let yy = (ly as i64 + dy as i64) as usize;
                    let xx = (x as i64 + dx as i64) as usize;
                    acc += w * slab[yy * px + xx];
                }
                scratch[ly * px + x] = acc;
                computed += 1;
            }
        }
        slab.copy_from_slice(&scratch);
    }
    computed
}

/// Report of a temporal-blocking run.
#[derive(Debug)]
pub struct TemporalReport {
    pub result: Domain,
    pub wall_seconds: f64,
    /// Total cell updates including redundant overlap work.
    pub computed_cells: u64,
    /// Useful cell updates (interior x steps).
    pub useful_cells: u64,
    /// Bytes moved through the shared array.
    pub global_bytes: u64,
    pub epochs: usize,
}

impl TemporalReport {
    pub fn redundancy(&self) -> f64 {
        self.computed_cells as f64 / self.useful_cells as f64
    }
}

/// Sequential overlapped temporal blocking over row-bands (2D only): the
/// domain is split into `parts` bands; each epoch advances every band by
/// `bt` steps independently (with redundant halo compute), then commits
/// the bands back — the relaunch-per-epoch baseline.
pub fn run_2d(
    spec: &StencilSpec,
    x0: &Domain,
    steps: usize,
    bt: usize,
    parts: usize,
) -> Result<TemporalReport> {
    if spec.dims != 2 {
        return Err(Error::invalid("temporal blocking implemented for 2D benchmarks"));
    }
    if bt == 0 || steps % bt != 0 {
        return Err(Error::invalid(format!("steps {steps} not a multiple of bt {bt}")));
    }
    let r = spec.radius;
    let px = x0.padded[2];
    let py = x0.padded[1];
    let bands = crate::stencil::parallel::partition(x0.interior[1], parts);
    let t0 = std::time::Instant::now();
    let mut cur = x0.clone();
    let mut computed = 0u64;
    let mut global_bytes = 0u64;
    let epochs = steps / bt;
    for _ in 0..epochs {
        let mut next = cur.clone();
        for &(s, len) in &bands {
            let b0 = r + s;
            let b1 = b0 + len;
            // slab: band + bt*r halo rows each side (clamped)
            let s0 = b0.saturating_sub(bt * r);
            let s1 = (b1 + bt * r).min(py);
            let mut slab = cur.data[s0 * px..s1 * px].to_vec();
            global_bytes += (slab.len() * 8) as u64;
            computed += advance_slab_2d(spec, &cur, &mut slab, s0, s1 - s0, b0..b1, bt);
            // commit only the owned band
            let off = (b0 - s0) * px;
            next.data[b0 * px..b1 * px].copy_from_slice(&slab[off..off + (b1 - b0) * px]);
            global_bytes += ((b1 - b0) * px * 8) as u64;
        }
        cur = next;
    }
    Ok(TemporalReport {
        wall_seconds: t0.elapsed().as_secs_f64(),
        computed_cells: computed,
        useful_cells: (x0.interior_cells() * steps) as u64,
        global_bytes,
        epochs,
        result: cur,
    })
}

/// Temporal blocking *composed with* PERKS: persistent bands keep their
/// slab locally across epochs; only the `bt*r`-deep epoch halos are
/// re-read and only the band boundary is re-published each epoch. Here we
/// model it sequentially per band within an epoch (the parallel variant
/// lives in `parallel.rs`; this one isolates the traffic accounting).
pub fn run_2d_perks(
    spec: &StencilSpec,
    x0: &Domain,
    steps: usize,
    bt: usize,
    parts: usize,
) -> Result<TemporalReport> {
    if spec.dims != 2 {
        return Err(Error::invalid("temporal blocking implemented for 2D benchmarks"));
    }
    if bt == 0 || steps % bt != 0 {
        return Err(Error::invalid(format!("steps {steps} not a multiple of bt {bt}")));
    }
    let r = spec.radius;
    let px = x0.padded[2];
    let py = x0.padded[1];
    let bands = crate::stencil::parallel::partition(x0.interior[1], parts);
    let t0 = std::time::Instant::now();
    let mut cur = x0.clone();
    let mut computed = 0u64;
    let mut global_bytes = 0u64;
    let epochs = steps / bt;
    // persistent local slabs: loaded once
    let mut slabs: Vec<(usize, usize, Vec<f64>)> = bands
        .iter()
        .map(|&(s, len)| {
            let b0 = r + s;
            let b1 = b0 + len;
            let s0 = b0.saturating_sub(bt * r);
            let s1 = (b1 + bt * r).min(py);
            global_bytes += ((s1 - s0) * px * 8) as u64;
            (s0, s1, cur.data[s0 * px..s1 * px].to_vec())
        })
        .collect();
    for _ in 0..epochs {
        let mut next = cur.clone();
        for (i, &(s, len)) in bands.iter().enumerate() {
            let b0 = r + s;
            let b1 = b0 + len;
            let (s0, s1, slab) = &mut slabs[i];
            // refresh only the halo rows from global (PERKS keeps the band)
            let lo_halo = *s0..b0;
            let hi_halo = b1..*s1;
            for range in [lo_halo, hi_halo] {
                if !range.is_empty() {
                    let off = (range.start - *s0) * px;
                    let len = range.len() * px;
                    slab[off..off + len]
                        .copy_from_slice(&cur.data[range.start * px..range.start * px + len]);
                    global_bytes += (len * 8) as u64;
                }
            }
            computed += advance_slab_2d(spec, &cur, slab, *s0, *s1 - *s0, b0..b1, bt);
            // publish only the boundary rows needed by neighbor halos
            let publish = (bt * r).min(b1 - b0);
            let top = b0..b0 + publish;
            let bot = b1 - publish..b1;
            for range in [top, bot] {
                let off = (range.start - *s0) * px;
                let len = range.len() * px;
                next.data[range.start * px..range.start * px + len]
                    .copy_from_slice(&slab[off..off + len]);
                global_bytes += (len * 8) as u64;
            }
        }
        cur = next;
    }
    // final commit of full bands
    for (i, &(s, len)) in bands.iter().enumerate() {
        let b0 = r + s;
        let b1 = b0 + len;
        let (s0, _, slab) = &slabs[i];
        let off = (b0 - s0) * px;
        cur.data[b0 * px..b1 * px].copy_from_slice(&slab[off..off + (b1 - b0) * px]);
        global_bytes += ((b1 - b0) * px * 8) as u64;
    }
    Ok(TemporalReport {
        wall_seconds: t0.elapsed().as_secs_f64(),
        computed_cells: computed,
        useful_cells: (x0.interior_cells() * steps) as u64,
        global_bytes,
        epochs,
        result: cur,
    })
}

/// Validate a temporal-blocking run against the gold executor.
pub fn check_against_gold(
    spec: &StencilSpec,
    x0: &Domain,
    steps: usize,
    report: &TemporalReport,
) -> Result<f64> {
    let want = gold::run(spec, x0, steps)?;
    Ok(report.result.max_abs_diff(&want))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::shape::spec;

    fn domain(name: &str, h: usize, w: usize, seed: u64) -> (StencilSpec, Domain) {
        let s = spec(name).unwrap();
        let mut d = Domain::for_spec(&s, &[h, w]).unwrap();
        d.randomize(seed);
        (s, d)
    }

    #[test]
    fn temporal_blocking_matches_gold() {
        for (name, bt, parts) in
            [("2d5pt", 2, 3), ("2d5pt", 4, 2), ("2d9pt", 2, 4), ("2ds9pt", 3, 2)]
        {
            let (s, d) = domain(name, 24, 20, 5);
            let rep = run_2d(&s, &d, 12, bt, parts).unwrap();
            let diff = check_against_gold(&s, &d, 12, &rep).unwrap();
            assert!(diff < 1e-12, "{name} bt={bt}: {diff}");
        }
    }

    #[test]
    fn perks_composition_matches_gold() {
        for (name, bt, parts) in [("2d5pt", 2, 3), ("2d5pt", 4, 2), ("2d9pt", 2, 2)] {
            let (s, d) = domain(name, 24, 20, 7);
            let rep = run_2d_perks(&s, &d, 12, bt, parts).unwrap();
            let diff = check_against_gold(&s, &d, 12, &rep).unwrap();
            assert!(diff < 1e-12, "{name} bt={bt} perks: {diff}");
        }
    }

    #[test]
    fn bt1_equals_plain_blocking() {
        let (s, d) = domain("2d5pt", 16, 16, 3);
        let rep = run_2d(&s, &d, 4, 1, 2).unwrap();
        assert!(check_against_gold(&s, &d, 4, &rep).unwrap() < 1e-12);
        // no overlap at bt=1: zero redundancy
        assert!((rep.redundancy() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn redundancy_grows_with_bt() {
        // the paper's limit on temporal blocking: overlap work grows
        let c2 = overlap_cost_2d(64, 64, 1, 2).redundancy();
        let c4 = overlap_cost_2d(64, 64, 1, 4).redundancy();
        let c8 = overlap_cost_2d(64, 64, 1, 8).redundancy();
        assert!(c2 < c4 && c4 < c8, "{c2} {c4} {c8}");
        assert!(c2 > 1.0);
        // higher radius amplifies the overlap
        let r2 = overlap_cost_2d(64, 64, 2, 4).redundancy();
        assert!(r2 > c4);
    }

    #[test]
    fn measured_redundancy_matches_analytic_direction() {
        let (s, d) = domain("2d5pt", 32, 32, 9);
        let r2 = run_2d(&s, &d, 8, 2, 2).unwrap().redundancy();
        let r4 = run_2d(&s, &d, 8, 4, 2).unwrap().redundancy();
        assert!(r4 > r2, "{r4} vs {r2}");
    }

    #[test]
    fn perks_composition_reduces_traffic() {
        let (s, d) = domain("2d5pt", 64, 64, 1);
        let plain = run_2d(&s, &d, 16, 4, 4).unwrap();
        let perks = run_2d_perks(&s, &d, 16, 4, 4).unwrap();
        assert!(
            (perks.global_bytes as f64) < 0.8 * plain.global_bytes as f64,
            "perks {} vs plain {}",
            perks.global_bytes,
            plain.global_bytes
        );
        // identical numerics
        assert!(perks.result.max_abs_diff(&plain.result) < 1e-12);
    }

    #[test]
    fn rejects_bad_params() {
        let (s, d) = domain("2d5pt", 8, 8, 1);
        assert!(run_2d(&s, &d, 5, 2, 2).is_err()); // 5 % 2 != 0
        assert!(run_2d(&s, &d, 4, 0, 2).is_err());
        let s3 = spec("3d7pt").unwrap();
        let d3 = Domain::for_spec(&s3, &[4, 4, 4]).unwrap();
        assert!(run_2d(&s3, &d3, 4, 2, 2).is_err());
    }
}
