//! Overlapped temporal blocking — the optimization family the paper
//! positions PERKS as *orthogonal* to (§I, §II-C) — and the shared
//! slab-advance core behind every temporal-blocked path in the crate.
//!
//! # Epochs and sub-steps
//!
//! With temporal blocking degree `bt`, time is grouped into *epochs* of
//! `bt` *sub-steps*. A worker loads its band plus a halo of `bt * radius`
//! planes once per epoch, then advances `bt` sub-steps entirely locally:
//! at sub-step `k` (1-based) the computed region is the band grown by
//! `(bt - k) * radius` planes on each side — the trapezoid shrinks by
//! `radius` per sub-step, so every read lands on a plane computed in the
//! previous sub-step (or on the immutable Dirichlet ring). Planes outside
//! the band are computed *redundantly* (the neighbor owns them); that
//! redundant work is the price of exchanging only once per epoch, and it
//! grows with `bt` — the paper's limit on temporal blocking, quantified
//! by [`OverlapCost`].
//!
//! # One core, every path
//!
//! [`advance_slab`] is that trapezoid, written once over the banded plane
//! representation shared with `stencil::parallel` (axis 0 = z planes for
//! 3D, axis 1 = y rows for 2D) and using the same `gold::accumulate_row`
//! kernel with precomputed `gold::linear_deltas` offsets as every other
//! executor — which is why temporally-blocked results are bit-identical
//! to `gold::run` wherever a cell is computed, redundantly or not. It
//! drives:
//!
//! * [`run_2d`] — the relaunch-per-epoch baseline (AN5D-style): every
//!   epoch reloads whole slabs from the shared array;
//! * [`run_2d_perks`] — the sequential PERKS composition: slabs persist
//!   across epochs, only `bt*radius`-deep halos are re-read and only the
//!   band boundary republished (isolates the traffic accounting);
//! * [`crate::stencil::pool::StencilPool`] — the resident parallel
//!   composition: the pool's workers run this core between their
//!   epoch-batched barrier exchanges (2 barriers per epoch instead of 2
//!   per step).
//!
//! The core ping-pongs two slab buffers (`cur`/`nxt`) instead of cloning
//! a scratch slab every sub-step; both buffers must be initialized
//! identically once so the never-written Dirichlet cells stay valid in
//! each (see `advance_slab`'s contract).

use crate::error::{Error, Result};
use crate::stencil::gold;
use crate::stencil::grid::Domain;
use crate::stencil::shape::StencilSpec;

/// Redundant-computation accounting for one temporal-blocking epoch.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OverlapCost {
    /// Cells computed per epoch including redundant halo work.
    pub computed_cells: f64,
    /// Useful cells per epoch (tile area x bt).
    pub useful_cells: f64,
}

impl OverlapCost {
    /// Redundancy ratio >= 1; grows with bt — the paper's limit on
    /// temporal blocking degree.
    pub fn redundancy(&self) -> f64 {
        self.computed_cells / self.useful_cells
    }
}

/// Analytic overlap cost for a 2D tile of (tx, ty) at degree `bt` and
/// stencil radius `rad` (overlapped/trapezoidal tiling: at step k the
/// computed region is the tile grown by (bt - k) * rad on each side).
pub fn overlap_cost_2d(tx: usize, ty: usize, rad: usize, bt: usize) -> OverlapCost {
    let mut computed = 0.0;
    for k in 1..=bt {
        let grow = (bt - k) * rad;
        computed += ((tx + 2 * grow) * (ty + 2 * grow)) as f64;
    }
    OverlapCost { computed_cells: computed, useful_cells: (tx * ty * bt) as f64 }
}

/// The measured redundant-compute ratio, shared by every report type
/// that carries a (computed, useful) cell-count pair
/// (`StencilRun`, `ParallelReport`, `session::Report.redundancy`):
/// `computed / useful`, defined as 1.0 (no overlap work) when nothing
/// useful ran yet.
pub(crate) fn redundancy_ratio(computed_cells: u64, useful_cells: u64) -> f64 {
    if useful_cells == 0 {
        return 1.0;
    }
    computed_cells as f64 / useful_cells as f64
}

/// Analytic overlap cost for a *banded* slab of `band_planes` planes at
/// degree `bt` and radius `rad` — the geometry of the pool's 1D
/// decomposition, where the trapezoid grows along the banded axis only
/// (each plane is computed in full, so there is no in-plane overlap).
/// Counted in planes; the ratio is what matters. Ignores domain-edge
/// clamping, so it upper-bounds the measured redundancy — which is what
/// the `ExecPolicy::Auto` bt probe uses it for (pruning degrees whose
/// redundant compute cannot pay for the saved barriers).
pub fn overlap_cost_banded(band_planes: usize, rad: usize, bt: usize) -> OverlapCost {
    let mut computed = 0.0;
    for k in 1..=bt {
        computed += (band_planes + 2 * (bt - k) * rad) as f64;
    }
    OverlapCost { computed_cells: computed, useful_cells: (band_planes * bt) as f64 }
}

/// Advance a banded slab `bt` sub-steps of overlapped temporal blocking
/// with **no exchange**, ping-ponging `cur`/`nxt`. On return `cur` holds
/// the advanced level (the buffers are swapped every sub-step, so the
/// caller's `cur` binding always names the newest one). Returns the
/// number of *computed* (including redundant) cell updates.
///
/// Geometry contract (the banded plane representation of
/// `stencil::parallel`):
///
/// * `cur`/`nxt` are equally-sized slabs of whole planes
///   `[slab_first, slab_first + len/plane)` in padded coords, where
///   `plane` is `padded[1] * padded[2]` for `axis == 0` (3D z bands) and
///   `padded[2]` for `axis == 1` (2D y bands);
/// * the slab must cover `band` grown by `bt * radius` planes each side,
///   clamped only at the domain edges (where the Dirichlet ring
///   substitutes) — exactly what `parallel::plans` builds;
/// * `first..first + interior_planes` is the interior plane range of the
///   banded axis; planes outside it are never computed;
/// * both buffers must hold identical, current Dirichlet values in every
///   never-computed cell (halo planes beyond the trapezoid and the
///   in-plane halo ring). The core never writes those cells, so
///   initializing `nxt` as a copy of `cur` once — at slab creation —
///   keeps them valid forever.
#[allow(clippy::too_many_arguments)]
pub(crate) fn advance_slab(
    spec: &StencilSpec,
    domain: &Domain,
    axis: usize,
    cur: &mut Vec<f64>,
    nxt: &mut Vec<f64>,
    slab_first: usize,
    band: &std::ops::Range<usize>,
    bt: usize,
    first: usize,
    interior_planes: usize,
    weights: &[f64],
    deltas: &[isize],
) -> u64 {
    debug_assert_eq!(cur.len(), nxt.len());
    let r = spec.radius;
    let (py, px) = (domain.padded[1], domain.padded[2]);
    let plane = if axis == 0 { py * px } else { px };
    let slab_planes = cur.len() / plane;
    let width = px - 2 * r;
    let mut computed = 0u64;
    for k in 1..=bt {
        let grow = (bt - k) * r;
        // shrinking trapezoid: band grown by `grow`, clamped to the global
        // interior (the Dirichlet ring substitutes past the edge). The
        // slab-coverage contract guarantees every read of `lo..hi` lands
        // inside the slab.
        let lo = band.start.saturating_sub(grow).max(first);
        let hi = (band.end + grow).min(first + interior_planes);
        debug_assert!(lo >= slab_first + r, "slab does not cover the trapezoid's lo reads");
        debug_assert!(
            hi + r <= slab_first + slab_planes,
            "slab does not cover the trapezoid's hi reads"
        );
        for p in lo..hi {
            if axis == 0 {
                for y in r..py - r {
                    let base = ((p - slab_first) * py + y) * px + r;
                    gold::accumulate_row(
                        &mut nxt[base..base + width],
                        cur,
                        base,
                        deltas,
                        weights,
                    );
                }
                computed += ((py - 2 * r) * width) as u64;
            } else {
                let base = (p - slab_first) * px + r;
                gold::accumulate_row(&mut nxt[base..base + width], cur, base, deltas, weights);
                computed += width as u64;
            }
        }
        std::mem::swap(cur, nxt);
    }
    computed
}

/// Report of a temporal-blocking run.
#[derive(Debug)]
pub struct TemporalReport {
    pub result: Domain,
    pub wall_seconds: f64,
    /// Total cell updates including redundant overlap work.
    pub computed_cells: u64,
    /// Useful cell updates (interior x steps).
    pub useful_cells: u64,
    /// Bytes moved through the shared array.
    pub global_bytes: u64,
    pub epochs: usize,
}

impl TemporalReport {
    pub fn redundancy(&self) -> f64 {
        self.computed_cells as f64 / self.useful_cells as f64
    }
}

/// One band's persistent pair of ping-pong slab buffers plus its plane
/// extent, reused across epochs (allocation-free time loop).
struct BandSlab {
    s0: usize,
    s1: usize,
    cur: Vec<f64>,
    nxt: Vec<f64>,
}

fn band_slabs(x0: &Domain, bands: &[(usize, usize)], r: usize, bt: usize) -> Vec<BandSlab> {
    let px = x0.padded[2];
    let py = x0.padded[1];
    bands
        .iter()
        .map(|&(s, len)| {
            let b0 = r + s;
            let b1 = b0 + len;
            let s0 = b0.saturating_sub(bt * r);
            let s1 = (b1 + bt * r).min(py);
            let init = x0.data[s0 * px..s1 * px].to_vec();
            BandSlab { s0, s1, cur: init.clone(), nxt: init }
        })
        .collect()
}

fn check_2d(spec: &StencilSpec, steps: usize, bt: usize) -> Result<()> {
    if spec.dims != 2 {
        return Err(Error::invalid("temporal blocking implemented for 2D benchmarks"));
    }
    if bt == 0 || steps % bt != 0 {
        return Err(Error::invalid(format!("steps {steps} not a multiple of bt {bt}")));
    }
    Ok(())
}

/// Sequential overlapped temporal blocking over row-bands (2D only): the
/// domain is split into `parts` bands; each epoch reloads every band's
/// slab from the shared array, advances it `bt` sub-steps via
/// [`advance_slab`], and commits the band back — the relaunch-per-epoch
/// baseline (whole slabs round-trip every epoch).
pub fn run_2d(
    spec: &StencilSpec,
    x0: &Domain,
    steps: usize,
    bt: usize,
    parts: usize,
) -> Result<TemporalReport> {
    check_2d(spec, steps, bt)?;
    let r = spec.radius;
    let px = x0.padded[2];
    let bands = crate::stencil::parallel::partition(x0.interior[1], parts);
    let weights = spec.weights();
    let deltas = gold::linear_deltas(spec, x0.padded[1], px);
    let t0 = std::time::Instant::now();
    let mut cur = x0.clone();
    // reused double buffer instead of a per-epoch clone. No copy between
    // epochs either: every epoch commits every interior row (the bands
    // partition them exactly) into `next` before the swap, and the
    // Dirichlet halo rows are identical in both buffers from the initial
    // clones and never written.
    let mut next = x0.clone();
    let mut slabs = band_slabs(x0, &bands, r, bt);
    let mut computed = 0u64;
    let mut global_bytes = 0u64;
    let epochs = steps / bt;
    for _ in 0..epochs {
        for (slab, &(s, len)) in slabs.iter_mut().zip(&bands) {
            let b0 = r + s;
            let b1 = b0 + len;
            // relaunch model: the whole slab reloads from global each epoch
            slab.cur.copy_from_slice(&cur.data[slab.s0 * px..slab.s1 * px]);
            global_bytes += (slab.cur.len() * 8) as u64;
            computed += advance_slab(
                spec,
                x0,
                1,
                &mut slab.cur,
                &mut slab.nxt,
                slab.s0,
                &(b0..b1),
                bt,
                r,
                x0.interior[1],
                &weights,
                &deltas,
            );
            // commit only the owned band
            let off = (b0 - slab.s0) * px;
            next.data[b0 * px..b1 * px].copy_from_slice(&slab.cur[off..off + (b1 - b0) * px]);
            global_bytes += ((b1 - b0) * px * 8) as u64;
        }
        std::mem::swap(&mut cur, &mut next);
    }
    Ok(TemporalReport {
        wall_seconds: t0.elapsed().as_secs_f64(),
        computed_cells: computed,
        useful_cells: (x0.interior_cells() * steps) as u64,
        global_bytes,
        epochs,
        result: cur,
    })
}

/// Temporal blocking *composed with* PERKS: persistent bands keep their
/// slab locally across epochs; only the `bt*r`-deep epoch halos are
/// re-read and only the band boundary is re-published each epoch. Here we
/// model it sequentially per band within an epoch (the parallel variant
/// is the pool's resident loop, `stencil::pool`; this one isolates the
/// traffic accounting).
pub fn run_2d_perks(
    spec: &StencilSpec,
    x0: &Domain,
    steps: usize,
    bt: usize,
    parts: usize,
) -> Result<TemporalReport> {
    check_2d(spec, steps, bt)?;
    let r = spec.radius;
    let px = x0.padded[2];
    let bands = crate::stencil::parallel::partition(x0.interior[1], parts);
    let weights = spec.weights();
    let deltas = gold::linear_deltas(spec, x0.padded[1], px);
    let t0 = std::time::Instant::now();
    let mut cur = x0.clone();
    // reused double buffer, never copied between epochs: the only rows an
    // epoch *reads* from the shared buffers are halo rows within bt*r of
    // a band edge, and each epoch *publishes* exactly those rows into
    // `next` before the swap (mid-band rows go stale in the buffers but
    // are never read, and the final commit below rewrites every band row
    // from the authoritative slabs; Dirichlet halo rows are identical in
    // both buffers from the initial clones and never written).
    let mut next = x0.clone();
    let mut computed = 0u64;
    let mut global_bytes = 0u64;
    let epochs = steps / bt;
    // persistent local slabs: loaded once, resident across epochs
    let mut slabs = band_slabs(x0, &bands, r, bt);
    for slab in &slabs {
        global_bytes += (slab.cur.len() * 8) as u64;
    }
    for _ in 0..epochs {
        for (slab, &(s, len)) in slabs.iter_mut().zip(&bands) {
            let b0 = r + s;
            let b1 = b0 + len;
            // refresh only the halo planes from global (PERKS keeps the band)
            let lo_halo = slab.s0..b0;
            let hi_halo = b1..slab.s1;
            for range in [lo_halo, hi_halo] {
                if !range.is_empty() {
                    let off = (range.start - slab.s0) * px;
                    let len = range.len() * px;
                    slab.cur[off..off + len]
                        .copy_from_slice(&cur.data[range.start * px..range.start * px + len]);
                    global_bytes += (len * 8) as u64;
                }
            }
            computed += advance_slab(
                spec,
                x0,
                1,
                &mut slab.cur,
                &mut slab.nxt,
                slab.s0,
                &(b0..b1),
                bt,
                r,
                x0.interior[1],
                &weights,
                &deltas,
            );
            // publish only the boundary planes a neighbor's halo reads
            let publish = (bt * r).min(b1 - b0);
            let top = b0..b0 + publish;
            let bot = b1 - publish..b1;
            for range in [top, bot] {
                let off = (range.start - slab.s0) * px;
                let len = range.len() * px;
                // overlapping top/bot copies of a thin band are idempotent
                next.data[range.start * px..range.start * px + len]
                    .copy_from_slice(&slab.cur[off..off + len]);
            }
            // thin bands overlap top/bot: traffic counts the union of the
            // two plane ranges once (Eq 5), exactly as the pool does
            let union = crate::stencil::parallel::boundary_union_planes(bt * r, b1 - b0);
            global_bytes += (union * px * 8) as u64;
        }
        std::mem::swap(&mut cur, &mut next);
    }
    // final commit of full bands
    for (slab, &(s, len)) in slabs.iter().zip(&bands) {
        let b0 = r + s;
        let b1 = b0 + len;
        let off = (b0 - slab.s0) * px;
        cur.data[b0 * px..b1 * px].copy_from_slice(&slab.cur[off..off + (b1 - b0) * px]);
        global_bytes += ((b1 - b0) * px * 8) as u64;
    }
    Ok(TemporalReport {
        wall_seconds: t0.elapsed().as_secs_f64(),
        computed_cells: computed,
        useful_cells: (x0.interior_cells() * steps) as u64,
        global_bytes,
        epochs,
        result: cur,
    })
}

/// Validate a temporal-blocking run against the gold executor.
pub fn check_against_gold(
    spec: &StencilSpec,
    x0: &Domain,
    steps: usize,
    report: &TemporalReport,
) -> Result<f64> {
    let want = gold::run(spec, x0, steps)?;
    Ok(report.result.max_abs_diff(&want))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::shape::spec;

    fn domain(name: &str, h: usize, w: usize, seed: u64) -> (StencilSpec, Domain) {
        let s = spec(name).unwrap();
        let mut d = Domain::for_spec(&s, &[h, w]).unwrap();
        d.randomize(seed);
        (s, d)
    }

    #[test]
    fn temporal_blocking_matches_gold() {
        for (name, bt, parts) in
            [("2d5pt", 2, 3), ("2d5pt", 4, 2), ("2d9pt", 2, 4), ("2ds9pt", 3, 2)]
        {
            let (s, d) = domain(name, 24, 20, 5);
            let rep = run_2d(&s, &d, 12, bt, parts).unwrap();
            let diff = check_against_gold(&s, &d, 12, &rep).unwrap();
            assert!(diff < 1e-12, "{name} bt={bt}: {diff}");
        }
    }

    /// The shared core uses `gold::accumulate_row`, so the agreement is
    /// not merely within tolerance — the bits match wherever a cell is
    /// computed (redundantly or not).
    #[test]
    fn temporal_blocking_is_bit_identical_to_gold() {
        let (s, d) = domain("2d9pt", 20, 16, 11);
        let want = gold::run(&s, &d, 8).unwrap();
        let rep = run_2d(&s, &d, 8, 4, 3).unwrap();
        assert_eq!(rep.result.data, want.data);
        let repc = run_2d_perks(&s, &d, 8, 4, 3).unwrap();
        assert_eq!(repc.result.data, want.data);
    }

    #[test]
    fn perks_composition_matches_gold() {
        for (name, bt, parts) in [("2d5pt", 2, 3), ("2d5pt", 4, 2), ("2d9pt", 2, 2)] {
            let (s, d) = domain(name, 24, 20, 7);
            let rep = run_2d_perks(&s, &d, 12, bt, parts).unwrap();
            let diff = check_against_gold(&s, &d, 12, &rep).unwrap();
            assert!(diff < 1e-12, "{name} bt={bt} perks: {diff}");
        }
    }

    #[test]
    fn bt1_equals_plain_blocking() {
        let (s, d) = domain("2d5pt", 16, 16, 3);
        let rep = run_2d(&s, &d, 4, 1, 2).unwrap();
        assert!(check_against_gold(&s, &d, 4, &rep).unwrap() < 1e-12);
        // no overlap at bt=1: zero redundancy
        assert!((rep.redundancy() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn redundancy_grows_with_bt() {
        // the paper's limit on temporal blocking: overlap work grows
        let c2 = overlap_cost_2d(64, 64, 1, 2).redundancy();
        let c4 = overlap_cost_2d(64, 64, 1, 4).redundancy();
        let c8 = overlap_cost_2d(64, 64, 1, 8).redundancy();
        assert!(c2 < c4 && c4 < c8, "{c2} {c4} {c8}");
        assert!(c2 > 1.0);
        // higher radius amplifies the overlap
        let r2 = overlap_cost_2d(64, 64, 2, 4).redundancy();
        assert!(r2 > c4);
    }

    #[test]
    fn banded_overlap_cost_tracks_band_thickness_and_degree() {
        // thin bands pay proportionally more redundancy: 1 + r*(bt-1)/len
        let thick = overlap_cost_banded(64, 1, 4).redundancy();
        let thin = overlap_cost_banded(4, 1, 4).redundancy();
        assert!(thin > thick, "{thin} vs {thick}");
        assert!(overlap_cost_banded(16, 1, 1).redundancy() == 1.0);
        let b2 = overlap_cost_banded(16, 2, 2).redundancy();
        let b4 = overlap_cost_banded(16, 2, 4).redundancy();
        assert!(b2 < b4);
        // exact closed form: 1 + rad * (bt - 1) / band
        assert!((b4 - (1.0 + 2.0 * 3.0 / 16.0)).abs() < 1e-12);
    }

    #[test]
    fn measured_redundancy_matches_analytic_direction() {
        let (s, d) = domain("2d5pt", 32, 32, 9);
        let r2 = run_2d(&s, &d, 8, 2, 2).unwrap().redundancy();
        let r4 = run_2d(&s, &d, 8, 4, 2).unwrap().redundancy();
        assert!(r4 > r2, "{r4} vs {r2}");
    }

    #[test]
    fn perks_composition_reduces_traffic() {
        let (s, d) = domain("2d5pt", 64, 64, 1);
        let plain = run_2d(&s, &d, 16, 4, 4).unwrap();
        let perks = run_2d_perks(&s, &d, 16, 4, 4).unwrap();
        assert!(
            (perks.global_bytes as f64) < 0.8 * plain.global_bytes as f64,
            "perks {} vs plain {}",
            perks.global_bytes,
            plain.global_bytes
        );
        // identical numerics
        assert!(perks.result.max_abs_diff(&plain.result) < 1e-12);
    }

    /// Eq-5 regression: a band thinner than `2*bt*r` publishes
    /// overlapping top/bot boundary ranges; `global_bytes` must count the
    /// union once (the rule the pool enforces), computed here
    /// independently from the band geometry.
    #[test]
    fn perks_thin_band_publish_counts_the_union_once() {
        let (s, d) = domain("2d5pt", 12, 64, 3);
        let (steps, bt, parts) = (8usize, 4usize, 4usize);
        let r = s.radius;
        let (py, px) = (d.padded[1], d.padded[2]);
        let bands = crate::stencil::parallel::partition(d.interior[1], parts);
        assert!(bands.iter().all(|&(_, l)| l < 2 * bt * r), "thin-band premise");
        let rep = run_2d_perks(&s, &d, steps, bt, parts).unwrap();
        assert!(check_against_gold(&s, &d, steps, &rep).unwrap() < 1e-12);
        let epochs = steps / bt;
        let mut expect = 0usize;
        let mut double_counted = 0usize;
        for &(start, len) in &bands {
            let b0 = r + start;
            let b1 = b0 + len;
            let s0 = b0.saturating_sub(bt * r);
            let s1 = (b1 + bt * r).min(py);
            let halo = (b0 - s0) + (s1 - b1);
            let union = (2 * bt * r).min(len);
            // initial slab load + per-epoch (halo refresh + union publish)
            // + final whole-band commit, all in planes
            expect += (s1 - s0) + epochs * (halo + union) + len;
            double_counted += (s1 - s0) + epochs * (halo + 2 * (bt * r).min(len)) + len;
        }
        assert_eq!(rep.global_bytes, (expect * px * 8) as u64, "Eq-5 union accounting");
        assert!(
            rep.global_bytes < (double_counted * px * 8) as u64,
            "the old top+bot sum would have inflated traffic"
        );
    }

    #[test]
    fn rejects_bad_params() {
        let (s, d) = domain("2d5pt", 8, 8, 1);
        assert!(run_2d(&s, &d, 5, 2, 2).is_err()); // 5 % 2 != 0
        assert!(run_2d(&s, &d, 4, 0, 2).is_err());
        let s3 = spec("3d7pt").unwrap();
        let d3 = Domain::for_spec(&s3, &[4, 4, 4]).unwrap();
        assert!(run_2d(&s3, &d3, 4, 2, 2).is_err());
    }
}
