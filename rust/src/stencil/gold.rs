//! Sequential CPU gold executor for the stencil benchmarks.
//!
//! This is the rust-side correctness oracle: the PJRT-executed artifacts
//! (which came from the Pallas kernels, which were checked against the jnp
//! oracle) must match this executor bit-for-bit in f64 and to float
//! tolerance in f32 — closing the four-way gold chain described in
//! DESIGN.md §6. It is also the baseline for the persistent-threads CPU
//! executor in `parallel.rs`.

use crate::error::Result;
use crate::stencil::grid::Domain;
use crate::stencil::shape::StencilSpec;

/// Accumulate one interior row: `acc[i] = sum_k w[k] * src[base + d[k] + i]`.
///
/// The per-offset inner loops run over contiguous slices, which the
/// compiler auto-vectorizes — this is the optimized form of the cell-
/// update kernel (see EXPERIMENTS.md §Perf: ~5x over per-cell indexing).
#[inline]
pub(crate) fn accumulate_row(
    acc: &mut [f64],
    src: &[f64],
    base: usize,
    deltas: &[isize],
    weights: &[f64],
) {
    acc.fill(0.0);
    let n = acc.len();
    for (&d, &w) in deltas.iter().zip(weights) {
        let start = (base as isize + d) as usize;
        let row = &src[start..start + n];
        for (a, &s) in acc.iter_mut().zip(row) {
            *a += w * s;
        }
    }
}

/// Linearized offsets for a padded geometry.
pub(crate) fn linear_deltas(spec: &StencilSpec, py: usize, px: usize) -> Vec<isize> {
    spec.offsets
        .iter()
        .map(|&(dz, dy, dx)| {
            dz as isize * (py * px) as isize + dy as isize * px as isize + dx as isize
        })
        .collect()
}

/// Apply one Jacobi step of `spec` to `src`, writing into `dst`.
/// `src` and `dst` must have identical geometry. The halo ring is copied
/// through unchanged (Dirichlet boundary).
pub fn step_into(spec: &StencilSpec, src: &Domain, dst: &mut Domain) -> Result<()> {
    debug_assert_eq!(src.padded, dst.padded);
    let weights = spec.weights();
    let r = spec.radius;
    let zr = src.z_range();
    let (py, px) = (src.padded[1], src.padded[2]);
    let deltas = linear_deltas(spec, py, px);
    let width = px - 2 * r;
    let plane = py * px;
    let pz = src.padded[0];
    // copy only the halo through (full-array copies dominated the profile
    // at low orders — see EXPERIMENTS.md §Perf): z-halo planes, then the
    // y-halo rows and x-halo columns of each interior plane
    let zr_start = zr.start;
    let zr_end = zr.end;
    for z in 0..pz {
        let p0 = z * plane;
        if !(zr_start..zr_end).contains(&z) {
            dst.data[p0..p0 + plane].copy_from_slice(&src.data[p0..p0 + plane]);
            continue;
        }
        // top/bottom y-halo rows
        dst.data[p0..p0 + r * px].copy_from_slice(&src.data[p0..p0 + r * px]);
        let tail = p0 + (py - r) * px;
        dst.data[tail..p0 + plane].copy_from_slice(&src.data[tail..p0 + plane]);
        for y in r..py - r {
            let row = p0 + y * px;
            // x-halo columns
            dst.data[row..row + r].copy_from_slice(&src.data[row..row + r]);
            dst.data[row + px - r..row + px].copy_from_slice(&src.data[row + px - r..row + px]);
            // interior: accumulate straight into dst (no staging buffer)
            let base = row + r;
            accumulate_row(&mut dst.data[base..base + width], &src.data, base, &deltas, &weights);
        }
    }
    Ok(())
}

/// Advance `steps` Jacobi steps, ping-ponging two buffers; returns the
/// final domain.
pub fn run(spec: &StencilSpec, x0: &Domain, steps: usize) -> Result<Domain> {
    let mut a = x0.clone();
    let mut b = x0.clone();
    for _ in 0..steps {
        step_into(spec, &a, &mut b)?;
        std::mem::swap(&mut a, &mut b);
    }
    Ok(a)
}

/// FLOP count for `steps` steps (for roofline estimates in benches).
pub fn flops(spec: &StencilSpec, domain: &Domain, steps: usize) -> u64 {
    domain.interior_cells() as u64 * spec.flops_per_cell as u64 * steps as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::shape::{catalog, spec};

    #[test]
    fn constant_field_is_fixed_point() {
        // convex weights: a constant domain is exactly invariant
        for s in catalog() {
            let interior: Vec<usize> = if s.dims == 2 { vec![6, 6] } else { vec![4, 4, 4] };
            let mut d = Domain::for_spec(&s, &interior).unwrap();
            for v in d.data.iter_mut() {
                *v = 2.5;
            }
            let out = run(&s, &d, 3).unwrap();
            assert!(out.max_abs_diff(&d) < 1e-12, "{}", s.name);
        }
    }

    #[test]
    fn boundary_untouched() {
        let s = spec("2d9pt").unwrap();
        let mut d = Domain::for_spec(&s, &[8, 8]).unwrap();
        d.randomize(7);
        let out = run(&s, &d, 5).unwrap();
        let r = s.radius;
        let (py, px) = (d.padded[1], d.padded[2]);
        for y in 0..py {
            for x in 0..px {
                let on_halo = y < r || y >= py - r || x < r || x >= px - r;
                if on_halo {
                    assert_eq!(out.get(0, y, x), d.get(0, y, x));
                }
            }
        }
    }

    #[test]
    fn manual_2d5pt_single_cell() {
        // 1x1 interior: new value = sum(w_i * neighbor_i) computed by hand
        let s = spec("2d5pt").unwrap();
        let mut d = Domain::for_spec(&s, &[1, 1]).unwrap();
        // padded 3x3; offsets sorted: (-1,0),(0,-1),(0,0),(0,1),(1,0) as
        // (dy,dx); weights 1/15..5/15
        d.set(0, 0, 1, 1.0); // (dy=-1)
        d.set(0, 1, 0, 2.0); // (dx=-1)
        d.set(0, 1, 1, 3.0); // center
        d.set(0, 1, 2, 4.0); // (dx=+1)
        d.set(0, 2, 1, 5.0); // (dy=+1)
        let out = run(&s, &d, 1).unwrap();
        let want =
            (1.0 / 15.0) * 1.0 + (2.0 / 15.0) * 2.0 + (3.0 / 15.0) * 3.0 + (4.0 / 15.0) * 4.0
                + (5.0 / 15.0) * 5.0;
        assert!((out.get(0, 1, 1) - want).abs() < 1e-12);
    }

    #[test]
    fn steps_compose() {
        let s = spec("3d7pt").unwrap();
        let mut d = Domain::for_spec(&s, &[4, 4, 4]).unwrap();
        d.randomize(3);
        let two = run(&s, &d, 2).unwrap();
        let one = run(&s, &d, 1).unwrap();
        let one_one = run(&s, &one, 1).unwrap();
        assert!(two.max_abs_diff(&one_one) < 1e-15);
    }

    #[test]
    fn flops_accounting() {
        let s = spec("2d5pt").unwrap();
        let d = Domain::for_spec(&s, &[10, 10]).unwrap();
        assert_eq!(flops(&s, &d, 3), 100 * 10 * 3);
    }
}
