//! Padded domain representation shared by the gold executor, the
//! persistent-threads executor and the PJRT drivers.
//!
//! Domains are stored padded with a Dirichlet halo ring of width `radius`
//! (matching the python side). 2D domains are represented as 3D with a
//! depth of 1 and dz == 0 offsets, so one code path serves both.

use crate::error::{Error, Result};
use crate::stencil::shape::StencilSpec;
use crate::util::rng::Rng;

/// A padded, row-major domain (f64 internally; converted at the PJRT edge).
#[derive(Clone, Debug, PartialEq)]
pub struct Domain {
    /// Interior extents (d, h, w); d == 1 for 2D.
    pub interior: [usize; 3],
    pub radius: usize,
    /// Padded extents.
    pub padded: [usize; 3],
    pub data: Vec<f64>,
}

impl Domain {
    /// Create a zeroed padded domain. For 2D pass `[1, h, w]` and the 2D
    /// padding is only applied to y/x.
    pub fn zeros(interior: [usize; 3], radius: usize, dims: usize) -> Self {
        let pad_z = if dims == 3 { 2 * radius } else { 0 };
        let padded = [interior[0] + pad_z, interior[1] + 2 * radius, interior[2] + 2 * radius];
        let data = vec![0.0; padded[0] * padded[1] * padded[2]];
        Self { interior, radius, padded, data }
    }

    /// Create for a named benchmark spec with the given interior.
    pub fn for_spec(spec: &StencilSpec, interior: &[usize]) -> Result<Self> {
        let interior3 = match (spec.dims, interior.len()) {
            (2, 2) => [1, interior[0], interior[1]],
            (3, 3) => [interior[0], interior[1], interior[2]],
            _ => {
                return Err(Error::invalid(format!(
                    "{}: interior rank {} does not match dims {}",
                    spec.name,
                    interior.len(),
                    spec.dims
                )))
            }
        };
        Ok(Self::zeros(interior3, spec.radius, spec.dims))
    }

    /// Fill interior + halo with deterministic pseudo-random values.
    pub fn randomize(&mut self, seed: u64) {
        let mut rng = Rng::new(seed);
        rng.fill_f64(&mut self.data);
    }

    pub fn idx(&self, z: usize, y: usize, x: usize) -> usize {
        (z * self.padded[1] + y) * self.padded[2] + x
    }

    pub fn get(&self, z: usize, y: usize, x: usize) -> f64 {
        self.data[self.idx(z, y, x)]
    }

    pub fn set(&mut self, z: usize, y: usize, x: usize, v: f64) {
        let i = self.idx(z, y, x);
        self.data[i] = v;
    }

    pub fn interior_cells(&self) -> usize {
        self.interior.iter().product()
    }

    /// Z-range of the interior in padded coordinates.
    pub fn z_range(&self) -> std::ops::Range<usize> {
        let z0 = self.padded[0] - self.interior[0]; // 0 offset for 2D, radius for 3D
        let start = (self.padded[0] - self.interior[0]) / 2;
        debug_assert!(z0 == 0 || z0 == 2 * self.radius);
        start..start + self.interior[0]
    }

    /// Export as f32 vec (for the PJRT f32 artifacts). 2D domains are
    /// flattened to their (padded_y, padded_x) plane.
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&v| v as f32).collect()
    }

    /// Import from f32 (must match padded size).
    pub fn from_f32(&mut self, src: &[f32]) -> Result<()> {
        if src.len() != self.data.len() {
            return Err(Error::Shape(format!(
                "domain has {} elements, source {}",
                self.data.len(),
                src.len()
            )));
        }
        for (d, &s) in self.data.iter_mut().zip(src) {
            *d = s as f64;
        }
        Ok(())
    }

    /// Max absolute difference over the whole padded array.
    pub fn max_abs_diff(&self, other: &Domain) -> f64 {
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::shape::spec;

    #[test]
    fn padding_2d() {
        let s = spec("2ds9pt").unwrap(); // radius 2
        let d = Domain::for_spec(&s, &[8, 10]).unwrap();
        assert_eq!(d.padded, [1, 12, 14]);
        assert_eq!(d.interior_cells(), 80);
        assert_eq!(d.z_range(), 0..1);
    }

    #[test]
    fn padding_3d() {
        let s = spec("3d13pt").unwrap(); // radius 2
        let d = Domain::for_spec(&s, &[4, 6, 8]).unwrap();
        assert_eq!(d.padded, [8, 10, 12]);
        assert_eq!(d.z_range(), 2..6);
    }

    #[test]
    fn rank_mismatch_rejected() {
        let s = spec("2d5pt").unwrap();
        assert!(Domain::for_spec(&s, &[4, 4, 4]).is_err());
    }

    #[test]
    fn f32_roundtrip() {
        let s = spec("2d5pt").unwrap();
        let mut d = Domain::for_spec(&s, &[4, 4]).unwrap();
        d.randomize(42);
        let f = d.to_f32();
        let mut d2 = Domain::for_spec(&s, &[4, 4]).unwrap();
        d2.from_f32(&f).unwrap();
        assert!(d.max_abs_diff(&d2) < 1e-7);
        assert!(d2.from_f32(&f[1..]).is_err());
    }

    #[test]
    fn index_math() {
        let s = spec("2d5pt").unwrap();
        let mut d = Domain::for_spec(&s, &[3, 3]).unwrap();
        d.set(0, 1, 1, 5.0);
        assert_eq!(d.get(0, 1, 1), 5.0);
        assert_eq!(d.idx(0, 0, 0), 0);
        assert_eq!(d.idx(0, 1, 0), 5);
    }
}
