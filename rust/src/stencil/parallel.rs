//! Persistent-threads CPU stencil executor.
//!
//! This substrate demonstrates the PERKS execution model *physically* on
//! the CPU: OS threads play the role of thread blocks, per-thread slabs of
//! the domain play the role of register/shared-memory caches (they stay
//! hot in the core's L1/L2), the shared padded array plays the role of GPU
//! global memory, and `coordinator::barrier::GridBarrier` plays the role
//! of `grid.sync()`.
//!
//! Two modes, mirroring Fig 3 of the paper:
//!
//! * `host_loop` — threads are (re)spawned every time step and the whole
//!   domain round-trips through the shared array: the traditional model.
//! * `persistent` — threads are spawned once and keep their slab locally
//!   across all steps; only the slab *boundary planes* are exchanged
//!   through the shared array each step (plus one final full store).
//!
//! Both produce results identical to `gold::run`, which the tests assert.

use std::cell::UnsafeCell;
use std::sync::Arc;

use crate::coordinator::barrier::GridBarrier;
use crate::error::{Error, Result};
use crate::stencil::grid::Domain;
use crate::stencil::shape::StencilSpec;

/// Shared mutable grid with disjoint-region writes coordinated by the
/// barrier protocol below (safety argument in `SharedGrid::slice_mut`).
struct SharedGrid {
    data: UnsafeCell<Vec<f64>>,
    len: usize,
}

unsafe impl Sync for SharedGrid {}

impl SharedGrid {
    fn new(data: Vec<f64>) -> Self {
        let len = data.len();
        Self { data: UnsafeCell::new(data), len }
    }

    fn len(&self) -> usize {
        self.len
    }

    /// Read a range. Caller must guarantee no concurrent writer overlaps
    /// the range (enforced by the band ownership + barrier protocol).
    unsafe fn read(&self, range: std::ops::Range<usize>, dst: &mut [f64]) {
        debug_assert!(range.end <= self.len && range.len() == dst.len());
        let base = (*self.data.get()).as_ptr();
        std::ptr::copy_nonoverlapping(base.add(range.start), dst.as_mut_ptr(), range.len());
    }

    /// Write a range. Caller must guarantee exclusive ownership of the
    /// range between barriers.
    unsafe fn write(&self, offset: usize, src: &[f64]) {
        debug_assert!(offset + src.len() <= self.len);
        let base = (*self.data.get()).as_mut_ptr();
        std::ptr::copy_nonoverlapping(src.as_ptr(), base.add(offset), src.len());
    }

    fn into_inner(self) -> Vec<f64> {
        self.data.into_inner()
    }
}

/// Partition `count` planes into `parts` contiguous bands (first bands get
/// the remainder). Returns (start, len) pairs; never empty bands.
pub fn partition(count: usize, parts: usize) -> Vec<(usize, usize)> {
    let parts = parts.min(count).max(1);
    let base = count / parts;
    let rem = count % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let len = base + usize::from(i < rem);
        out.push((start, len));
        start += len;
    }
    out
}

/// Geometry of the banded decomposition for one domain.
struct Bands {
    /// Axis 0 for 3D (z), axis 1 for 2D (y).
    axis: usize,
    /// Plane size in elements (stride between consecutive planes).
    plane: usize,
    /// Interior plane range start in padded coords (== radius for the
    /// banded axis... 0-pad for 2D z).
    first: usize,
    bands: Vec<(usize, usize)>,
}

fn bands_for(domain: &Domain, spec: &StencilSpec, threads: usize) -> Bands {
    if spec.dims == 3 {
        Bands {
            axis: 0,
            plane: domain.padded[1] * domain.padded[2],
            first: spec.radius,
            bands: partition(domain.interior[0], threads),
        }
    } else {
        Bands {
            axis: 1,
            plane: domain.padded[2],
            first: spec.radius,
            bands: partition(domain.interior[1], threads),
        }
    }
}

/// Report from a parallel run.
#[derive(Debug)]
pub struct ParallelReport {
    pub result: Domain,
    pub wall_seconds: f64,
    pub threads: usize,
    /// Bytes moved through the shared ("global") array, summed over
    /// threads: the traffic the paper's Eq 5 accounts.
    pub global_bytes: u64,
    pub barrier_wait: std::time::Duration,
}

struct ThreadPlan {
    /// Banded-axis plane range owned by this thread, padded coords.
    band: std::ops::Range<usize>,
    /// Slab (band + halo planes) element range in the padded array.
    slab: std::ops::Range<usize>,
}

fn plans(geometry: &Bands, radius: usize, total_planes: usize, plane: usize) -> Vec<ThreadPlan> {
    geometry
        .bands
        .iter()
        .map(|&(s, l)| {
            let b0 = geometry.first + s;
            let b1 = b0 + l;
            let s0 = b0.saturating_sub(radius);
            let s1 = (b1 + radius).min(total_planes);
            ThreadPlan { band: b0..b1, slab: s0 * plane..s1 * plane }
        })
        .collect()
}

/// Compute one Jacobi step for the planes `band` (padded coords along the
/// banded axis) reading from `local` (a slab starting at plane
/// `slab_first`), writing new interior values into `out` (band-sized).
/// `deltas` are the precomputed `gold::linear_deltas` offsets — hoisted to
/// the caller so persistent threads build them once, not every time step.
#[allow(clippy::too_many_arguments)]
fn compute_band(
    spec: &StencilSpec,
    domain: &Domain,
    local: &[f64],
    slab_first: usize,
    band: &std::ops::Range<usize>,
    weights: &[f64],
    deltas: &[isize],
    axis: usize,
    out: &mut [f64],
) {
    let r = spec.radius;
    let (py, px) = (domain.padded[1], domain.padded[2]);
    let width = px - 2 * r;
    let mut o = 0;
    if axis == 0 {
        for z in band.clone() {
            for y in r..py - r {
                let base = ((z - slab_first) * py + y) * px + r;
                crate::stencil::gold::accumulate_row(
                    &mut out[o..o + width],
                    local,
                    base,
                    deltas,
                    weights,
                );
                o += width;
            }
        }
    } else {
        for y in band.clone() {
            let base = (y - slab_first) * px + r;
            crate::stencil::gold::accumulate_row(
                &mut out[o..o + width],
                local,
                base,
                deltas,
                weights,
            );
            o += width;
        }
    }
}

/// Scatter band results (interior columns only) into a full-width plane
/// buffer `planes` whose first plane is `dst_first` (padded coords).
/// Rows are contiguous in both `results` and `planes`, so each row moves
/// as one `copy_from_slice` (memcpy) instead of an element-wise loop.
fn scatter_band(
    spec: &StencilSpec,
    domain: &Domain,
    band: &std::ops::Range<usize>,
    axis: usize,
    results: &[f64],
    planes: &mut [f64],
    dst_first: usize,
) {
    let r = spec.radius;
    let (py, px) = (domain.padded[1], domain.padded[2]);
    let plane = py * px;
    let width = px - 2 * r;
    let mut i = 0;
    if axis == 0 {
        for z in band.clone() {
            for y in r..py - r {
                let dst = (z - dst_first) * plane + y * px + r;
                planes[dst..dst + width].copy_from_slice(&results[i..i + width]);
                i += width;
            }
        }
    } else {
        for y in band.clone() {
            let dst = (y - dst_first) * px + r;
            planes[dst..dst + width].copy_from_slice(&results[i..i + width]);
            i += width;
        }
    }
}

/// Run `steps` Jacobi steps with persistent threads (the PERKS model).
pub fn persistent(
    spec: &StencilSpec,
    x0: &Domain,
    steps: usize,
    threads: usize,
) -> Result<ParallelReport> {
    if threads == 0 {
        return Err(Error::invalid("threads must be > 0"));
    }
    let geometry = bands_for(x0, spec, threads);
    let r = spec.radius;
    let plane = geometry.plane;
    let total_planes = x0.data.len() / plane;
    let plans = plans(&geometry, r, total_planes, plane);
    let nthreads = plans.len();
    let barrier = Arc::new(GridBarrier::new(nthreads));
    let shared = Arc::new(SharedGrid::new(x0.data.clone()));
    let weights = spec.weights();
    let global_bytes = Arc::new(std::sync::atomic::AtomicU64::new(0));

    let t0 = std::time::Instant::now();
    crate::util::counters::note_thread_spawns(nthreads as u64);
    std::thread::scope(|scope| {
        for plan in &plans {
            let barrier = barrier.clone();
            let shared = shared.clone();
            let weights = weights.clone();
            let global_bytes = global_bytes.clone();
            let domain = x0;
            let axis = geometry.axis;
            scope.spawn(move || {
                let slab_first = plan.slab.start / plane;
                // --- initial load: slab (band + halos) from global ---
                let mut local = vec![0.0f64; plan.slab.len()];
                unsafe { shared.read(plan.slab.clone(), &mut local) };
                let mut moved = (plan.slab.len() * 8) as u64;
                // everyone must finish the initial load before anyone's
                // first boundary store mutates the shared array
                barrier.sync();

                let band_planes = plan.band.len();
                let interior_per_plane = if axis == 0 {
                    (domain.padded[1] - 2 * r) * (domain.padded[2] - 2 * r)
                } else {
                    domain.padded[2] - 2 * r
                };
                let mut results = vec![0.0f64; band_planes * interior_per_plane];
                // loop invariants of the resident time loop, built once
                // per persistent thread (not once per step)
                let deltas = crate::stencil::gold::linear_deltas(
                    spec,
                    domain.padded[1],
                    domain.padded[2],
                );

                for _ in 0..steps {
                    compute_band(
                        spec, domain, &local, slab_first, &plan.band, &weights, &deltas,
                        axis, &mut results,
                    );
                    // update local slab interior with new values
                    let band_off = (plan.band.start - slab_first) * plane;
                    let band_len = band_planes * plane;
                    scatter_band(
                        spec,
                        domain,
                        &plan.band,
                        axis,
                        &results,
                        &mut local[band_off..band_off + band_len],
                        plan.band.start,
                    );
                    // --- exchange: store only boundary planes to global ---
                    let lo_planes = r.min(band_planes);
                    let lo_start = plan.band.start * plane;
                    unsafe {
                        shared.write(
                            lo_start,
                            &local[band_off..band_off + lo_planes * plane],
                        )
                    };
                    let hi_planes = r.min(band_planes);
                    let hi_first = plan.band.end - hi_planes;
                    let hi_off = (hi_first - slab_first) * plane;
                    unsafe {
                        shared.write(hi_first * plane, &local[hi_off..hi_off + hi_planes * plane])
                    };
                    moved += ((lo_planes + hi_planes) * plane * 8) as u64;
                    barrier.sync();
                    // --- load neighbor halo planes from global ---
                    let halo_lo = plan.slab.start / plane..plan.band.start;
                    if !halo_lo.is_empty() {
                        let off = halo_lo.start * plane;
                        let len = halo_lo.len() * plane;
                        unsafe {
                            shared.read(off..off + len, &mut local[..len]);
                        }
                        moved += (len * 8) as u64;
                    }
                    let halo_hi = plan.band.end..plan.slab.end / plane;
                    if !halo_hi.is_empty() {
                        let off = halo_hi.start * plane;
                        let len = halo_hi.len() * plane;
                        let loff = (halo_hi.start - slab_first) * plane;
                        unsafe {
                            shared.read(off..off + len, &mut local[loff..loff + len]);
                        }
                        moved += (len * 8) as u64;
                    }
                    // second barrier: nobody may overwrite boundary planes
                    // (next step's store) before all neighbors read them
                    barrier.sync();
                }
                // --- final store: whole band back to global ---
                let band_off = (plan.band.start - slab_first) * plane;
                let band_len = band_planes * plane;
                unsafe {
                    shared.write(
                        plan.band.start * plane,
                        &local[band_off..band_off + band_len],
                    )
                };
                moved += (band_len * 8) as u64;
                global_bytes.fetch_add(moved, std::sync::atomic::Ordering::Relaxed);
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();

    let shared = Arc::try_unwrap(shared).ok().expect("threads joined");
    let mut result = x0.clone();
    result.data = shared.into_inner();
    Ok(ParallelReport {
        result,
        wall_seconds: wall,
        threads: nthreads,
        global_bytes: global_bytes.load(std::sync::atomic::Ordering::Relaxed),
        barrier_wait: barrier.total_wait(),
    })
}

/// Run `steps` Jacobi steps in the host-loop model: threads are respawned
/// each step (kernel relaunch) and the full domain round-trips through the
/// shared arrays.
pub fn host_loop(
    spec: &StencilSpec,
    x0: &Domain,
    steps: usize,
    threads: usize,
) -> Result<ParallelReport> {
    if threads == 0 {
        return Err(Error::invalid("threads must be > 0"));
    }
    let geometry = bands_for(x0, spec, threads);
    let r = spec.radius;
    let plane = geometry.plane;
    let total_planes = x0.data.len() / plane;
    let plans = plans(&geometry, r, total_planes, plane);
    let nthreads = plans.len();
    let weights = spec.weights();

    let mut src = SharedGrid::new(x0.data.clone());
    let mut dst = SharedGrid::new(x0.data.clone());
    let mut global_bytes = 0u64;
    let deltas = crate::stencil::gold::linear_deltas(spec, x0.padded[1], x0.padded[2]);

    let t0 = std::time::Instant::now();
    for _ in 0..steps {
        let src_ref = &src;
        let dst_ref = &dst;
        // kernel "launch": spawn, compute, join — the implicit barrier
        crate::util::counters::note_thread_spawns(nthreads as u64);
        std::thread::scope(|scope| {
            for plan in &plans {
                let weights = weights.clone();
                let deltas = &deltas;
                let domain = x0;
                let axis = geometry.axis;
                scope.spawn(move || {
                    // load slab from global each step
                    let mut local = vec![0.0f64; plan.slab.len()];
                    unsafe { src_ref.read(plan.slab.clone(), &mut local) };
                    let slab_first = plan.slab.start / plane;
                    let band_planes = plan.band.len();
                    let interior_per_plane = if axis == 0 {
                        (domain.padded[1] - 2 * r) * (domain.padded[2] - 2 * r)
                    } else {
                        domain.padded[2] - 2 * r
                    };
                    let mut results = vec![0.0f64; band_planes * interior_per_plane];
                    compute_band(
                        spec, domain, &local, slab_first, &plan.band, &weights, deltas,
                        axis, &mut results,
                    );
                    // store whole band to global each step
                    let band_off = (plan.band.start - slab_first) * plane;
                    let band_len = band_planes * plane;
                    let mut band_new = local[band_off..band_off + band_len].to_vec();
                    scatter_band(
                        spec,
                        domain,
                        &plan.band,
                        axis,
                        &results,
                        &mut band_new,
                        plan.band.start,
                    );
                    unsafe { dst_ref.write(plan.band.start * plane, &band_new) };
                });
            }
        });
        // each step: every thread loaded its slab and stored its band
        global_bytes += plans
            .iter()
            .map(|p| (p.slab.len() + p.band.len() * plane) as u64 * 8)
            .sum::<u64>();
        // halo planes of dst keep the Dirichlet values: copy from src once
        unsafe {
            let mut halo_lo = vec![0.0; geometry.first * plane];
            src.read(0..halo_lo.len(), &mut halo_lo);
            dst.write(0, &halo_lo);
            let tail_first = (geometry.first
                + if geometry.axis == 0 { x0.interior[0] } else { x0.interior[1] })
                * plane;
            let tail_len = dst.len() - tail_first;
            let mut halo_hi = vec![0.0; tail_len];
            src.read(tail_first..tail_first + tail_len, &mut halo_hi);
            dst.write(tail_first, &halo_hi);
        }
        std::mem::swap(&mut src, &mut dst);
    }
    let wall = t0.elapsed().as_secs_f64();

    let mut result = x0.clone();
    result.data = src.into_inner();
    Ok(ParallelReport {
        result,
        wall_seconds: wall,
        threads: nthreads,
        global_bytes,
        barrier_wait: std::time::Duration::ZERO,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::gold;
    use crate::stencil::shape::spec;

    fn check_matches_gold(name: &str, interior: &[usize], steps: usize, threads: usize) {
        let s = spec(name).unwrap();
        let mut d = Domain::for_spec(&s, interior).unwrap();
        d.randomize(99);
        let want = gold::run(&s, &d, steps).unwrap();
        let got_p = persistent(&s, &d, steps, threads).unwrap();
        assert!(
            got_p.result.max_abs_diff(&want) < 1e-12,
            "{name} persistent diverged: {}",
            got_p.result.max_abs_diff(&want)
        );
        let got_h = host_loop(&s, &d, steps, threads).unwrap();
        assert!(
            got_h.result.max_abs_diff(&want) < 1e-12,
            "{name} host_loop diverged: {}",
            got_h.result.max_abs_diff(&want)
        );
    }

    #[test]
    fn matches_gold_2d_various_threads() {
        for threads in [1, 2, 3, 4] {
            check_matches_gold("2d5pt", &[16, 16], 4, threads);
        }
    }

    #[test]
    fn matches_gold_2d_high_order() {
        check_matches_gold("2ds25pt", &[20, 16], 3, 3); // radius 6
        check_matches_gold("2d25pt", &[18, 14], 3, 2); // box radius 2
    }

    #[test]
    fn matches_gold_3d() {
        check_matches_gold("3d7pt", &[8, 8, 8], 3, 2);
        check_matches_gold("3d13pt", &[8, 6, 6], 2, 3); // radius 2
        check_matches_gold("poisson", &[6, 6, 6], 3, 2);
    }

    #[test]
    fn more_threads_than_planes_is_clamped() {
        check_matches_gold("2d5pt", &[4, 8], 2, 16);
    }

    #[test]
    fn persistent_moves_less_global_traffic() {
        let s = spec("2d5pt").unwrap();
        let mut d = Domain::for_spec(&s, &[64, 64]).unwrap();
        d.randomize(1);
        let steps = 16;
        let p = persistent(&s, &d, steps, 4).unwrap();
        let h = host_loop(&s, &d, steps, 4).unwrap();
        // the PERKS claim, measured: persistent traffic « host-loop traffic
        assert!(
            (p.global_bytes as f64) < 0.35 * h.global_bytes as f64,
            "persistent {} vs host {}",
            p.global_bytes,
            h.global_bytes
        );
    }

    #[test]
    fn partition_covers_exactly() {
        for (count, parts) in [(10, 3), (7, 7), (5, 9), (1, 1), (100, 8)] {
            let bands = partition(count, parts);
            let total: usize = bands.iter().map(|&(_, l)| l).sum();
            assert_eq!(total, count);
            assert!(bands.iter().all(|&(_, l)| l > 0));
            // contiguous
            let mut next = 0;
            for (s, l) in bands {
                assert_eq!(s, next);
                next = s + l;
            }
        }
    }
}
