//! Banded-decomposition machinery + execution models for the CPU stencil
//! substrate.
//!
//! This substrate demonstrates the PERKS execution model *physically* on
//! the CPU: OS threads play the role of thread blocks, per-thread slabs of
//! the domain play the role of register/shared-memory caches (they stay
//! hot in the core's L1/L2), the shared padded array plays the role of GPU
//! global memory, and `coordinator::barrier::GridBarrier` plays the role
//! of `grid.sync()`.
//!
//! One implementation of the banded geometry ([`partition`], `bands_for`,
//! `ThreadPlan`), the cell-update kernel (`compute_band`/`scatter_band`)
//! and the shared array ([`SharedGrid`]) serves three drivers:
//!
//! * [`host_loop`] — threads are (re)spawned every time step and the whole
//!   domain round-trips through the shared array: the traditional model.
//! * [`persistent`] / [`persistent_temporal`] — one-shot PERKS run
//!   (optionally composed with temporal blocking at degree `bt`): spawn a
//!   [`crate::stencil::pool::StencilPool`], run the resident time loop
//!   once, join. Threads are spawned once per *call*.
//! * [`crate::stencil::pool::StencilPool`] — the spawn-once runtime:
//!   workers park between `advance` commands and keep their slabs
//!   resident *across* calls, which is what `session::CpuStencil` rides.
//!
//! # The two-barrier exchange invariant
//!
//! The resident loop stores only a band's *boundary planes* (the planes a
//! neighbor's halo reads) to the shared array once per exchange *epoch*
//! (`bt` locally-advanced sub-steps; `bt = 1` — one epoch per step — is
//! the default), then loads its own halo planes back. Two grid barriers
//! per epoch make that sound:
//!
//! 1. after every thread's boundary **store** — no thread may read halo
//!    planes before all neighbors have published them;
//! 2. after every thread's halo **load** — no thread may overwrite its
//!    boundary planes (next epoch's store) before all neighbors have read
//!    the current ones.
//!
//! Between the two barriers the shared array is read-only, which is also
//! where the pool folds its residual-norm reduction slots (see
//! `GridBarrier::read_sum`).
//!
//! With temporal blocking (`bt > 1`, see `stencil::temporal` and the
//! pool docs) the exchanged boundary/halo ranges deepen to `bt * radius`
//! planes and the barriers drop to `2 * ceil(steps / bt)` per advance —
//! the widened-halo exchange invariant: every plane a worker loads as
//! halo lies within `bt * radius` of some band edge, and is therefore
//! covered by that band's boundary store of the same epoch.
//!
//! Traffic accounting follows the paper's Eq 5: a band thinner than
//! twice the exchange depth has overlapping lo/hi boundary ranges, so the
//! per-epoch boundary traffic is the **union** of the two plane ranges
//! ([`boundary_union_planes`]), not their sum.
//!
//! All drivers produce results identical to `gold::run`, which the tests
//! assert.

use std::cell::UnsafeCell;

use crate::error::{Error, Result};
use crate::stencil::grid::Domain;
use crate::stencil::pool::StencilPool;
use crate::stencil::shape::StencilSpec;

/// Shared mutable grid with disjoint-region writes coordinated by the
/// two-barrier protocol above (safety argument on each accessor).
pub(crate) struct SharedGrid {
    data: UnsafeCell<Vec<f64>>,
    len: usize,
}

// SAFETY: all cross-thread access goes through `read`/`write`, whose
// callers hold disjoint band ownership between barriers; the UnsafeCell
// is never touched outside those accessors while threads are live.
unsafe impl Sync for SharedGrid {}

impl SharedGrid {
    pub(crate) fn new(data: Vec<f64>) -> Self {
        let len = data.len();
        Self { data: UnsafeCell::new(data), len }
    }

    pub(crate) fn len(&self) -> usize {
        self.len
    }

    /// Read a range.
    ///
    /// SAFETY: caller must guarantee no concurrent writer overlaps the
    /// range (enforced by the band ownership + barrier protocol).
    pub(crate) unsafe fn read(&self, range: std::ops::Range<usize>, dst: &mut [f64]) {
        debug_assert!(range.end <= self.len && range.len() == dst.len());
        let base = (*self.data.get()).as_ptr();
        std::ptr::copy_nonoverlapping(base.add(range.start), dst.as_mut_ptr(), range.len());
    }

    /// Write a range.
    ///
    /// SAFETY: caller must guarantee exclusive ownership of the range
    /// between barriers.
    pub(crate) unsafe fn write(&self, offset: usize, src: &[f64]) {
        debug_assert!(offset + src.len() <= self.len);
        let base = (*self.data.get()).as_mut_ptr();
        std::ptr::copy_nonoverlapping(src.as_ptr(), base.add(offset), src.len());
    }

    fn into_inner(self) -> Vec<f64> {
        self.data.into_inner()
    }
}

/// Partition `count` planes into `parts` contiguous bands (first bands get
/// the remainder). Returns (start, len) pairs; bands are never empty:
/// `parts` is clamped to `count`, and a zero-plane domain yields **no
/// bands at all** (an empty `Vec`), never a `(0, 0)` placeholder.
pub fn partition(count: usize, parts: usize) -> Vec<(usize, usize)> {
    if count == 0 {
        return Vec::new();
    }
    let parts = parts.min(count).max(1);
    let base = count / parts;
    let rem = count % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let len = base + usize::from(i < rem);
        out.push((start, len));
        start += len;
    }
    out
}

/// Geometry of the banded decomposition for one domain.
pub(crate) struct Bands {
    /// Axis 0 for 3D (z), axis 1 for 2D (y).
    pub(crate) axis: usize,
    /// Plane size in elements (stride between consecutive planes).
    pub(crate) plane: usize,
    /// Interior plane range start in padded coords (== radius for the
    /// banded axis... 0-pad for 2D z).
    pub(crate) first: usize,
    pub(crate) bands: Vec<(usize, usize)>,
}

pub(crate) fn bands_for(domain: &Domain, spec: &StencilSpec, threads: usize) -> Result<Bands> {
    let (axis, plane, count) = if spec.dims == 3 {
        (0, domain.padded[1] * domain.padded[2], domain.interior[0])
    } else {
        (1, domain.padded[2], domain.interior[1])
    };
    if count == 0 {
        return Err(Error::invalid("domain has no interior planes to band"));
    }
    Ok(Bands { axis, plane, first: spec.radius, bands: partition(count, threads) })
}

/// Report from a parallel run.
#[derive(Debug)]
pub struct ParallelReport {
    pub result: Domain,
    pub wall_seconds: f64,
    pub threads: usize,
    /// Time steps actually performed (== requested unless a convergence
    /// threshold stopped the resident loop early).
    pub steps: usize,
    /// Bytes moved through the shared ("global") array, summed over
    /// threads: the traffic the paper's Eq 5 accounts. Boundary stores of
    /// thin bands count the union of the lo/hi plane ranges once.
    pub global_bytes: u64,
    pub barrier_wait: std::time::Duration,
    /// Last in-loop residual norm (squared step delta), when the run
    /// tracked one (`None` for fixed-step runs and for `host_loop`).
    pub residual: Option<f64>,
    /// Cell updates actually performed, including the redundant overlap
    /// work of temporal blocking (== `useful_cells` at `bt = 1`).
    pub computed_cells: u64,
    /// Useful cell updates: interior cells x steps.
    pub useful_cells: u64,
}

impl ParallelReport {
    /// Redundant-compute ratio >= 1 (the `OverlapCost` measurement):
    /// 1.0 when no temporal blocking overlap was computed.
    pub fn redundancy(&self) -> f64 {
        crate::stencil::temporal::redundancy_ratio(self.computed_cells, self.useful_cells)
    }
}

pub(crate) struct ThreadPlan {
    /// Banded-axis plane range owned by this thread, padded coords.
    pub(crate) band: std::ops::Range<usize>,
    /// Slab (band + halo planes) element range in the padded array.
    pub(crate) slab: std::ops::Range<usize>,
}

/// Build one slab plan per band, with `halo` planes of halo each side
/// (clamped at the domain edges). `halo` is `radius` for per-step
/// exchange and `bt * radius` for temporal blocking at degree `bt`.
pub(crate) fn plans(
    geometry: &Bands,
    halo: usize,
    total_planes: usize,
    plane: usize,
) -> Vec<ThreadPlan> {
    geometry
        .bands
        .iter()
        .map(|&(s, l)| {
            let b0 = geometry.first + s;
            let b1 = b0 + l;
            let s0 = b0.saturating_sub(halo);
            let s1 = (b1 + halo).min(total_planes);
            ThreadPlan { band: b0..b1, slab: s0 * plane..s1 * plane }
        })
        .collect()
}

/// Distinct boundary planes a band publishes each exchange epoch: the lo
/// range covers the first `depth` band planes, the hi range the last
/// `depth` (`depth` is `radius` at `bt = 1`, `bt * radius` under temporal
/// blocking); for bands thinner than `2*depth` the two overlap, and the
/// per-epoch traffic is the union — `min(2*depth, band_planes)` — not the
/// sum (counting both inflates `global_bytes` against the Eq 5 model).
pub(crate) fn boundary_union_planes(depth: usize, band_planes: usize) -> usize {
    (2 * depth).min(band_planes)
}

/// Compute one Jacobi step for the planes `band` (padded coords along the
/// banded axis) reading from `local` (a slab starting at plane
/// `slab_first`), writing new interior values into `out` (band-sized).
/// `deltas` are the precomputed `gold::linear_deltas` offsets — hoisted to
/// the caller so persistent threads build them once, not every time step.
#[allow(clippy::too_many_arguments)]
pub(crate) fn compute_band(
    spec: &StencilSpec,
    domain: &Domain,
    local: &[f64],
    slab_first: usize,
    band: &std::ops::Range<usize>,
    weights: &[f64],
    deltas: &[isize],
    axis: usize,
    out: &mut [f64],
) {
    let r = spec.radius;
    let (py, px) = (domain.padded[1], domain.padded[2]);
    let width = px - 2 * r;
    let mut o = 0;
    if axis == 0 {
        for z in band.clone() {
            for y in r..py - r {
                let base = ((z - slab_first) * py + y) * px + r;
                crate::stencil::gold::accumulate_row(
                    &mut out[o..o + width],
                    local,
                    base,
                    deltas,
                    weights,
                );
                o += width;
            }
        }
    } else {
        for y in band.clone() {
            let base = (y - slab_first) * px + r;
            crate::stencil::gold::accumulate_row(
                &mut out[o..o + width],
                local,
                base,
                deltas,
                weights,
            );
            o += width;
        }
    }
}

/// Scatter band results (interior columns only) into a full-width plane
/// buffer `planes` whose first plane is `dst_first` (padded coords).
/// Rows are contiguous in both `results` and `planes`, so each row moves
/// as one `copy_from_slice` (memcpy) instead of an element-wise loop.
pub(crate) fn scatter_band(
    spec: &StencilSpec,
    domain: &Domain,
    band: &std::ops::Range<usize>,
    axis: usize,
    results: &[f64],
    planes: &mut [f64],
    dst_first: usize,
) {
    let r = spec.radius;
    let (py, px) = (domain.padded[1], domain.padded[2]);
    let plane = py * px;
    let width = px - 2 * r;
    let mut i = 0;
    if axis == 0 {
        for z in band.clone() {
            for y in r..py - r {
                let dst = (z - dst_first) * plane + y * px + r;
                planes[dst..dst + width].copy_from_slice(&results[i..i + width]);
                i += width;
            }
        }
    } else {
        for y in band.clone() {
            let dst = (y - dst_first) * px + r;
            planes[dst..dst + width].copy_from_slice(&results[i..i + width]);
            i += width;
        }
    }
}

/// Per-plane squared-delta partials between two same-geometry slabs over
/// a band's planes: `cur` holds the freshly advanced level, `prev` the
/// level one sub-step behind (the pool's ping-pong pair, where the
/// epoch's last sub-step leaves exactly those two levels in the buffers).
/// Calls `put(plane_slot, partial)` once per band plane, where
/// `plane_slot` is the *global* interior plane index (`plane - first`) —
/// the reduction-slot protocol of the pool's in-loop residual. Each
/// partial accumulates left-to-right in row-major order from 0.0, so the
/// slot-ordered fold is bit-identical at every thread count and matches
/// the serial [`residual_norm`].
#[allow(clippy::too_many_arguments)]
pub(crate) fn slab_delta_partials(
    spec: &StencilSpec,
    domain: &Domain,
    cur: &[f64],
    prev: &[f64],
    slab_first: usize,
    band: &std::ops::Range<usize>,
    axis: usize,
    first: usize,
    mut put: impl FnMut(usize, f64),
) {
    let r = spec.radius;
    let (py, px) = (domain.padded[1], domain.padded[2]);
    let width = px - 2 * r;
    if axis == 0 {
        for z in band.clone() {
            let mut partial = 0.0;
            for y in r..py - r {
                let base = ((z - slab_first) * py + y) * px + r;
                for i in 0..width {
                    let d = cur[base + i] - prev[base + i];
                    partial += d * d;
                }
            }
            put(z - first, partial);
        }
    } else {
        for y in band.clone() {
            let base = (y - slab_first) * px + r;
            let mut partial = 0.0;
            for i in 0..width {
                let d = cur[base + i] - prev[base + i];
                partial += d * d;
            }
            put(y - first, partial);
        }
    }
}

/// Deterministic squared step-delta norm between two same-geometry
/// domains: per-interior-plane partials along the banded axis, each
/// accumulated in row-major order from 0.0, folded in plane order — the
/// exact arithmetic of the pool's in-loop residual
/// ([`slab_delta_partials`] + `GridBarrier::read_sum`), so a host-side
/// convergence check stops on the same step as the resident one, with the
/// same bits.
pub fn residual_norm(spec: &StencilSpec, old: &Domain, new: &Domain) -> f64 {
    debug_assert_eq!(old.padded, new.padded);
    let r = spec.radius;
    let (py, px) = (old.padded[1], old.padded[2]);
    let width = px - 2 * r;
    let mut acc = 0.0;
    if spec.dims == 3 {
        for z in old.z_range() {
            let mut partial = 0.0;
            for y in r..py - r {
                let base = (z * py + y) * px + r;
                for i in 0..width {
                    let d = new.data[base + i] - old.data[base + i];
                    partial += d * d;
                }
            }
            acc += partial;
        }
    } else {
        for y in r..py - r {
            let base = y * px + r;
            let mut partial = 0.0;
            for i in 0..width {
                let d = new.data[base + i] - old.data[base + i];
                partial += d * d;
            }
            acc += partial;
        }
    }
    acc
}

/// Run `steps` Jacobi steps with persistent threads (the PERKS model),
/// one-shot: spawns a [`StencilPool`], runs the resident loop once, joins
/// the workers on return. Callers that advance repeatedly should hold a
/// pool (or a `session::CpuStencil` in persistent mode) instead, which
/// keeps the workers parked — and their slabs resident — between calls.
pub fn persistent(
    spec: &StencilSpec,
    x0: &Domain,
    steps: usize,
    threads: usize,
) -> Result<ParallelReport> {
    persistent_temporal(spec, x0, steps, threads, 1)
}

/// [`persistent`] composed with overlapped temporal blocking at degree
/// `bt`: each exchange epoch advances `bt` sub-steps locally on slabs
/// widened to `bt * radius` halo planes, so the run pays
/// `2 * ceil(steps / bt)` grid barriers instead of `2 * steps` (plus the
/// one-time load sync). `bt = 1` is exactly [`persistent`]. Results are
/// bit-identical to `gold::run` at every degree.
pub fn persistent_temporal(
    spec: &StencilSpec,
    x0: &Domain,
    steps: usize,
    threads: usize,
    bt: usize,
) -> Result<ParallelReport> {
    let t0 = std::time::Instant::now();
    let mut pool = StencilPool::spawn_temporal(spec, x0, threads, bt)?;
    let run = pool.run(steps, None)?;
    // join the workers inside the timed region: the host-loop baseline
    // pays its per-step joins in its wall, so the one-shot comparison
    // (benches, Auto-mode probes) must pay this one too
    pool.shutdown();
    let wall = t0.elapsed().as_secs_f64();
    Ok(ParallelReport {
        result: pool.state_domain(),
        wall_seconds: wall,
        threads: pool.workers(),
        steps: run.steps,
        global_bytes: run.global_bytes,
        barrier_wait: pool.barrier_wait(),
        residual: run.residual,
        computed_cells: run.computed_cells,
        useful_cells: run.useful_cells,
    })
}

/// Run `steps` Jacobi steps in the host-loop model: threads are respawned
/// each step (kernel relaunch) and the full domain round-trips through the
/// shared arrays.
pub fn host_loop(
    spec: &StencilSpec,
    x0: &Domain,
    steps: usize,
    threads: usize,
) -> Result<ParallelReport> {
    if threads == 0 {
        return Err(Error::invalid("threads must be > 0"));
    }
    let geometry = bands_for(x0, spec, threads)?;
    let r = spec.radius;
    let plane = geometry.plane;
    let total_planes = x0.data.len() / plane;
    let plans = plans(&geometry, r, total_planes, plane);
    let nthreads = plans.len();
    let weights = spec.weights();

    let mut src = SharedGrid::new(x0.data.clone());
    let mut dst = SharedGrid::new(x0.data.clone());
    let mut global_bytes = 0u64;
    let deltas = crate::stencil::gold::linear_deltas(spec, x0.padded[1], x0.padded[2]);
    // Dirichlet halo carry buffers, hoisted out of the time loop (they
    // were reallocated every step)
    let mut halo_lo = vec![0.0; geometry.first * plane];
    let tail_first = (geometry.first
        + if geometry.axis == 0 { x0.interior[0] } else { x0.interior[1] })
        * plane;
    let mut halo_hi = vec![0.0; dst.len() - tail_first];

    let t0 = std::time::Instant::now();
    for _ in 0..steps {
        let src_ref = &src;
        let dst_ref = &dst;
        // kernel "launch": spawn, compute, join — the implicit barrier
        crate::util::counters::note_thread_spawns(nthreads as u64);
        std::thread::scope(|scope| {
            for plan in &plans {
                let weights = weights.clone();
                let deltas = &deltas;
                let domain = x0;
                let axis = geometry.axis;
                scope.spawn(move || {
                    // load slab from global each step
                    let mut local = vec![0.0f64; plan.slab.len()];
                    // SAFETY: src is read-only this step; writers only
                    // touch dst, and the swap happens after scope join.
                    unsafe { src_ref.read(plan.slab.clone(), &mut local) };
                    let slab_first = plan.slab.start / plane;
                    let band_planes = plan.band.len();
                    let interior_per_plane = if axis == 0 {
                        (domain.padded[1] - 2 * r) * (domain.padded[2] - 2 * r)
                    } else {
                        domain.padded[2] - 2 * r
                    };
                    let mut results = vec![0.0f64; band_planes * interior_per_plane];
                    compute_band(
                        spec, domain, &local, slab_first, &plan.band, &weights, deltas,
                        axis, &mut results,
                    );
                    // store whole band to global each step
                    let band_off = (plan.band.start - slab_first) * plane;
                    let band_len = band_planes * plane;
                    let mut band_new = local[band_off..band_off + band_len].to_vec();
                    scatter_band(
                        spec,
                        domain,
                        &plan.band,
                        axis,
                        &results,
                        &mut band_new,
                        plan.band.start,
                    );
                    // SAFETY: bands partition the interior, so this
                    // thread owns [band.start*plane, +band_len) of dst
                    // exclusively until the scope joins.
                    unsafe { dst_ref.write(plan.band.start * plane, &band_new) };
                });
            }
        });
        // each step: every thread loaded its slab and stored its band
        global_bytes += plans
            .iter()
            .map(|p| (p.slab.len() + p.band.len() * plane) as u64 * 8)
            .sum::<u64>();
        // halo planes of dst keep the Dirichlet values: copy from src once
        // SAFETY: the worker scope has joined, so this thread is the
        // sole accessor of both grids; halo ranges are in bounds.
        unsafe {
            src.read(0..halo_lo.len(), &mut halo_lo);
            dst.write(0, &halo_lo);
            src.read(tail_first..tail_first + halo_hi.len(), &mut halo_hi);
            dst.write(tail_first, &halo_hi);
        }
        std::mem::swap(&mut src, &mut dst);
    }
    let wall = t0.elapsed().as_secs_f64();

    let useful = (x0.interior_cells() * steps) as u64;
    let mut result = x0.clone();
    result.data = src.into_inner();
    Ok(ParallelReport {
        result,
        wall_seconds: wall,
        threads: nthreads,
        steps,
        global_bytes,
        barrier_wait: std::time::Duration::ZERO,
        residual: None,
        computed_cells: useful, // no overlap work in the host-loop model
        useful_cells: useful,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::gold;
    use crate::stencil::shape::spec;

    fn check_matches_gold(name: &str, interior: &[usize], steps: usize, threads: usize) {
        let s = spec(name).unwrap();
        let mut d = Domain::for_spec(&s, interior).unwrap();
        d.randomize(99);
        let want = gold::run(&s, &d, steps).unwrap();
        let got_p = persistent(&s, &d, steps, threads).unwrap();
        assert!(
            got_p.result.max_abs_diff(&want) < 1e-12,
            "{name} persistent diverged: {}",
            got_p.result.max_abs_diff(&want)
        );
        let got_h = host_loop(&s, &d, steps, threads).unwrap();
        assert!(
            got_h.result.max_abs_diff(&want) < 1e-12,
            "{name} host_loop diverged: {}",
            got_h.result.max_abs_diff(&want)
        );
    }

    #[test]
    fn matches_gold_2d_various_threads() {
        for threads in [1, 2, 3, 4] {
            check_matches_gold("2d5pt", &[16, 16], 4, threads);
        }
    }

    #[test]
    fn matches_gold_2d_high_order() {
        check_matches_gold("2ds25pt", &[20, 16], 3, 3); // radius 6
        check_matches_gold("2d25pt", &[18, 14], 3, 2); // box radius 2
    }

    #[test]
    fn matches_gold_3d() {
        check_matches_gold("3d7pt", &[8, 8, 8], 3, 2);
        check_matches_gold("3d13pt", &[8, 6, 6], 2, 3); // radius 2
        check_matches_gold("poisson", &[6, 6, 6], 3, 2);
    }

    #[test]
    fn more_threads_than_planes_is_clamped() {
        check_matches_gold("2d5pt", &[4, 8], 2, 16);
    }

    #[test]
    fn persistent_moves_less_global_traffic() {
        let s = spec("2d5pt").unwrap();
        let mut d = Domain::for_spec(&s, &[64, 64]).unwrap();
        d.randomize(1);
        let steps = 16;
        let p = persistent(&s, &d, steps, 4).unwrap();
        let h = host_loop(&s, &d, steps, 4).unwrap();
        // the PERKS claim, measured: persistent traffic « host-loop traffic
        assert!(
            (p.global_bytes as f64) < 0.35 * h.global_bytes as f64,
            "persistent {} vs host {}",
            p.global_bytes,
            h.global_bytes
        );
    }

    /// Satellite regression: a band thinner than `2*radius` stores
    /// overlapping lo/hi boundary ranges; `global_bytes` must count the
    /// union exactly once (Eq 5), computed here independently from the
    /// band geometry.
    #[test]
    fn thin_band_traffic_matches_eq5_boundary_union() {
        let s = spec("2ds25pt").unwrap();
        assert_eq!(s.radius, 6);
        let mut d = Domain::for_spec(&s, &[20, 16]).unwrap();
        d.randomize(5);
        let (steps, threads) = (3usize, 4usize);
        // thin-band premise: every band is thinner than 2r
        let bands = partition(d.interior[1], threads);
        assert!(bands.iter().all(|&(_, l)| l < 2 * s.radius));

        let want = gold::run(&s, &d, steps).unwrap();
        let rep = persistent(&s, &d, steps, threads).unwrap();
        assert!(rep.result.max_abs_diff(&want) < 1e-12, "thin-band run must stay gold-exact");

        let r = s.radius;
        let plane = d.padded[2];
        let total_planes = d.padded[1];
        let mut expect = 0u64;
        let mut double_counted = 0u64;
        for &(start, len) in &bands {
            let b0 = r + start;
            let b1 = b0 + len;
            let s0 = b0.saturating_sub(r);
            let s1 = (b1 + r).min(total_planes);
            let slab = s1 - s0;
            let halo = (b0 - s0) + (s1 - b1);
            // initial slab load + per-step (boundary union + halo reload)
            // + final whole-band store, all in planes
            let union = boundary_union_planes(r, len);
            expect += ((slab + steps * (union + halo) + len) * plane * 8) as u64;
            let lo_plus_hi = 2 * r.min(len);
            double_counted += ((slab + steps * (lo_plus_hi + halo) + len) * plane * 8) as u64;
        }
        assert_eq!(rep.global_bytes, expect, "Eq-5 boundary-union accounting");
        assert!(
            rep.global_bytes < double_counted,
            "the old lo+hi sum would have inflated traffic ({} vs {})",
            rep.global_bytes,
            double_counted
        );
    }

    /// The temporal composition runs the same `accumulate_row` arithmetic
    /// as gold, so it is *bit*-identical at every degree — including in
    /// 3D, which the banded-plane core supports (the sequential
    /// `temporal::run_2d*` paths are 2D-only).
    #[test]
    fn persistent_temporal_matches_gold_2d_and_3d() {
        for (name, interior, steps, threads, bt) in [
            ("2d5pt", vec![16usize, 16], 6usize, 3usize, 2usize),
            ("2d9pt", vec![18, 18], 8, 4, 4),
            ("3d7pt", vec![8, 8, 8], 4, 2, 2),
            ("3d13pt", vec![8, 6, 6], 4, 3, 2),
        ] {
            let s = spec(name).unwrap();
            let mut d = Domain::for_spec(&s, &interior).unwrap();
            d.randomize(17);
            let want = gold::run(&s, &d, steps).unwrap();
            let rep = persistent_temporal(&s, &d, steps, threads, bt).unwrap();
            assert_eq!(rep.result.data, want.data, "{name} bt={bt}");
            assert!(rep.redundancy() >= 1.0, "{name} bt={bt}");
        }
    }

    #[test]
    fn persistent_temporal_handles_partial_epochs_and_reports_redundancy() {
        let s = spec("2d5pt").unwrap();
        let mut d = Domain::for_spec(&s, &[16, 16]).unwrap();
        d.randomize(2);
        let want = gold::run(&s, &d, 7).unwrap();
        // 7 = 4 + 3: the last epoch is a partial one
        let rep = persistent_temporal(&s, &d, 7, 2, 4).unwrap();
        assert_eq!(rep.result.data, want.data);
        assert_eq!(rep.steps, 7);
        assert!(rep.redundancy() > 1.0, "overlap work must be accounted");
        // bt = 1 computes no overlap at all
        let base = persistent(&s, &d, 7, 2).unwrap();
        assert!((base.redundancy() - 1.0).abs() < 1e-12);
        assert_eq!(base.computed_cells, base.useful_cells);
    }

    #[test]
    fn partition_covers_exactly() {
        for (count, parts) in [(10, 3), (7, 7), (5, 9), (1, 1), (100, 8)] {
            let bands = partition(count, parts);
            let total: usize = bands.iter().map(|&(_, l)| l).sum();
            assert_eq!(total, count);
            assert!(bands.iter().all(|&(_, l)| l > 0));
            // contiguous
            let mut next = 0;
            for (s, l) in bands {
                assert_eq!(s, next);
                next = s + l;
            }
        }
    }

    /// Satellite regression: `partition(0, parts)` used to fabricate a
    /// single `(0, 0)` band, violating the "never empty bands" contract
    /// and producing a zero-work thread plan downstream.
    #[test]
    fn partition_of_zero_planes_is_empty() {
        for parts in [1usize, 2, 8] {
            assert!(partition(0, parts).is_empty(), "parts={parts}");
        }
        // and the domain-level validation rejects un-bandable domains
        let s = spec("2d5pt").unwrap();
        let d = Domain::zeros([1, 0, 4], s.radius, 2);
        assert!(bands_for(&d, &s, 2).is_err());
    }

    #[test]
    fn residual_norm_is_zero_only_at_a_fixed_point() {
        let s = spec("2d5pt").unwrap();
        let mut d = Domain::for_spec(&s, &[8, 8]).unwrap();
        d.randomize(3);
        let next = gold::run(&s, &d, 1).unwrap();
        assert!(residual_norm(&s, &d, &next) > 0.0);
        // constant field: a fixed point up to rounding in the convex
        // weights => the squared delta norm is negligibly small
        let mut c = Domain::for_spec(&s, &[8, 8]).unwrap();
        c.data.iter_mut().for_each(|v| *v = 1.5);
        let cn = gold::run(&s, &c, 1).unwrap();
        assert!(residual_norm(&s, &c, &cn) < 1e-20);
    }
}
