//! Stencil substrate: the 13 benchmarks of Table III, a sequential CPU
//! gold executor, and a persistent-threads CPU executor that demonstrates
//! the PERKS execution model physically (thread-local slabs as the on-chip
//! cache, a shared array as global memory, a grid barrier as grid.sync).
//! The `pool` module holds the spawn-once worker runtime (workers parked
//! between `advance` commands, slabs resident across them, exchanges
//! optionally epoch-batched by temporal blocking); `parallel` holds the
//! shared banded machinery plus the one-shot/host-loop drivers;
//! `temporal` holds the trapezoidal slab-advance core every
//! temporally-blocked path shares, plus the sequential ablation runners.

pub mod gold;
pub mod grid;
pub mod parallel;
pub mod pool;
pub mod shape;
pub mod temporal;

pub use grid::Domain;
pub use shape::{catalog, spec, StencilSpec};
