//! Stencil substrate: the 13 benchmarks of Table III, a sequential CPU
//! gold executor, and a persistent-threads CPU executor that demonstrates
//! the PERKS execution model physically (thread-local slabs as the on-chip
//! cache, a shared array as global memory, a grid barrier as grid.sync).

pub mod gold;
pub mod grid;
pub mod parallel;
pub mod shape;
pub mod temporal;

pub use grid::Domain;
pub use shape::{catalog, spec, StencilSpec};
