//! Stencil benchmark catalog (Table III) — exact mirror of
//! `python/compile/stencils.py`.
//!
//! The weight rule is language-independent: offsets sorted
//! lexicographically, `weight_i = (i+1) / sum_j (j+1)`. The jnp oracle, the
//! Pallas kernels, the AOT HLO and this rust substrate therefore all apply
//! the *same* Jacobi operator, which the integration tests assert across
//! the PJRT boundary.

/// Neighbourhood offset: (dz, dy, dx); dz == 0 for 2D stencils.
pub type Offset = (i32, i32, i32);

/// One benchmark of Table III.
#[derive(Clone, Debug)]
pub struct StencilSpec {
    pub name: &'static str,
    pub dims: usize,
    pub radius: usize,
    pub offsets: Vec<Offset>,
    /// FLOPs/cell as reported in Table III.
    pub flops_per_cell: u32,
}

impl StencilSpec {
    pub fn points(&self) -> usize {
        self.offsets.len()
    }

    /// Deterministic convex weights (see module docs).
    pub fn weights(&self) -> Vec<f64> {
        let n = self.offsets.len();
        let total = (n * (n + 1) / 2) as f64;
        (0..n).map(|i| (i + 1) as f64 / total).collect()
    }

    /// Bytes touched per interior cell per step in the host-loop model:
    /// one load of the cell + one store (spatial reuse of neighbours is
    /// assumed perfect through on-chip memory, as in the paper's model).
    pub fn bytes_per_cell(&self, elem_size: usize) -> usize {
        2 * elem_size
    }
}

fn sorted_dedup(mut offs: Vec<Offset>) -> Vec<Offset> {
    offs.sort();
    offs.dedup();
    offs
}

fn star2d(radius: i32) -> Vec<Offset> {
    let mut offs = vec![(0, 0, 0)];
    for r in 1..=radius {
        offs.extend_from_slice(&[(0, r, 0), (0, -r, 0), (0, 0, r), (0, 0, -r)]);
    }
    sorted_dedup(offs)
}

fn box2d(radius: i32) -> Vec<Offset> {
    let mut offs = Vec::new();
    for dy in -radius..=radius {
        for dx in -radius..=radius {
            offs.push((0, dy, dx));
        }
    }
    sorted_dedup(offs)
}

fn star3d(radius: i32) -> Vec<Offset> {
    let mut offs = vec![(0, 0, 0)];
    for r in 1..=radius {
        offs.extend_from_slice(&[
            (r, 0, 0),
            (-r, 0, 0),
            (0, r, 0),
            (0, -r, 0),
            (0, 0, r),
            (0, 0, -r),
        ]);
    }
    sorted_dedup(offs)
}

fn box3d(radius: i32) -> Vec<Offset> {
    let mut offs = Vec::new();
    for dz in -radius..=radius {
        for dy in -radius..=radius {
            for dx in -radius..=radius {
                offs.push((dz, dy, dx));
            }
        }
    }
    sorted_dedup(offs)
}

/// 19-point 3D Poisson: all |dz|+|dy|+|dx| <= 2 within the unit box.
fn faces_edges3d() -> Vec<Offset> {
    let mut offs = Vec::new();
    for dz in -1..=1i32 {
        for dy in -1..=1i32 {
            for dx in -1..=1i32 {
                if dz.abs() + dy.abs() + dx.abs() <= 2 {
                    offs.push((dz, dy, dx));
                }
            }
        }
    }
    sorted_dedup(offs)
}

/// 17-point 3D: center + 6 faces + 8 corners + (0,0,±2). See the python
/// catalog for the rationale (Table III is not prescriptive here).
fn pt17_3d() -> Vec<Offset> {
    let mut offs = vec![(0, 0, 0), (0, 0, 2), (0, 0, -2)];
    for &dz in &[-1i32, 1] {
        for &dy in &[-1i32, 1] {
            for &dx in &[-1i32, 1] {
                offs.push((dz, dy, dx));
            }
        }
    }
    offs.extend_from_slice(&[(1, 0, 0), (-1, 0, 0), (0, 1, 0), (0, -1, 0), (0, 0, 1), (0, 0, -1)]);
    sorted_dedup(offs)
}

/// The 13 benchmarks of Table III, in the paper's order.
pub fn catalog() -> Vec<StencilSpec> {
    vec![
        StencilSpec { name: "2d5pt", dims: 2, radius: 1, offsets: star2d(1), flops_per_cell: 10 },
        StencilSpec { name: "2ds9pt", dims: 2, radius: 2, offsets: star2d(2), flops_per_cell: 18 },
        StencilSpec { name: "2d13pt", dims: 2, radius: 3, offsets: star2d(3), flops_per_cell: 26 },
        StencilSpec { name: "2d17pt", dims: 2, radius: 4, offsets: star2d(4), flops_per_cell: 34 },
        StencilSpec { name: "2d21pt", dims: 2, radius: 5, offsets: star2d(5), flops_per_cell: 42 },
        StencilSpec { name: "2ds25pt", dims: 2, radius: 6, offsets: star2d(6), flops_per_cell: 59 },
        StencilSpec { name: "2d9pt", dims: 2, radius: 1, offsets: box2d(1), flops_per_cell: 18 },
        StencilSpec { name: "2d25pt", dims: 2, radius: 2, offsets: box2d(2), flops_per_cell: 50 },
        StencilSpec { name: "3d7pt", dims: 3, radius: 1, offsets: star3d(1), flops_per_cell: 14 },
        StencilSpec { name: "3d13pt", dims: 3, radius: 2, offsets: star3d(2), flops_per_cell: 26 },
        StencilSpec { name: "3d17pt", dims: 3, radius: 2, offsets: pt17_3d(), flops_per_cell: 34 },
        StencilSpec { name: "3d27pt", dims: 3, radius: 1, offsets: box3d(1), flops_per_cell: 54 },
        StencilSpec { name: "poisson", dims: 3, radius: 1, offsets: faces_edges3d(), flops_per_cell: 38 },
    ]
}

/// Look up a benchmark by name.
pub fn spec(name: &str) -> Option<StencilSpec> {
    catalog().into_iter().find(|s| s.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thirteen_benchmarks() {
        assert_eq!(catalog().len(), 13);
    }

    #[test]
    fn point_counts_match_names() {
        let expect = [
            ("2d5pt", 5),
            ("2ds9pt", 9),
            ("2d13pt", 13),
            ("2d17pt", 17),
            ("2d21pt", 21),
            ("2ds25pt", 25),
            ("2d9pt", 9),
            ("2d25pt", 25),
            ("3d7pt", 7),
            ("3d13pt", 13),
            ("3d17pt", 17),
            ("3d27pt", 27),
            ("poisson", 19),
        ];
        for (name, pts) in expect {
            assert_eq!(spec(name).unwrap().points(), pts, "{name}");
        }
    }

    #[test]
    fn weights_sum_to_one() {
        for s in catalog() {
            let sum: f64 = s.weights().iter().sum();
            assert!((sum - 1.0).abs() < 1e-12, "{}", s.name);
            assert!(s.weights().iter().all(|&w| w > 0.0));
        }
    }

    #[test]
    fn offsets_sorted_unique_within_radius() {
        for s in catalog() {
            let mut sorted = s.offsets.clone();
            sorted.sort();
            sorted.dedup();
            assert_eq!(sorted, s.offsets, "{}", s.name);
            for &(dz, dy, dx) in &s.offsets {
                assert!(dz.unsigned_abs() as usize <= s.radius);
                assert!(dy.unsigned_abs() as usize <= s.radius);
                assert!(dx.unsigned_abs() as usize <= s.radius);
                if s.dims == 2 {
                    assert_eq!(dz, 0);
                }
            }
        }
    }

    #[test]
    fn center_present() {
        for s in catalog() {
            assert!(s.offsets.contains(&(0, 0, 0)), "{}", s.name);
        }
    }
}
