//! CPU persistent-threads solvers: the physically-measured PERKS
//! demonstration behind `Backend::CpuPersistent`. Stencils run on the
//! `stencil::parallel` substrate (OS threads as thread blocks, slabs as
//! on-chip caches); CG runs on the merge-SpMV substrate with the paper's
//! plan-caching and pass-fusion mechanisms.

use crate::coordinator::executor::ExecMode;
use crate::error::{Error, Result};
use crate::session::{Report, Solver};
use crate::sparse::csr::Csr;
use crate::sparse::gen;
use crate::spmv::merge::{self, MergePlan};
use crate::stencil::shape::StencilSpec;
use crate::stencil::{self, parallel, Domain};

/// Iterative stencil on the persistent-threads CPU substrate (f64).
pub struct CpuStencil {
    spec: StencilSpec,
    x0: Domain,
    threads: usize,
    mode: ExecMode,
    state: Option<Domain>,
    steps: usize,
    wall_seconds: f64,
    invocations: u64,
    host_bytes: u64,
    barrier_wait_seconds: f64,
}

impl CpuStencil {
    pub(crate) fn new(
        bench: &str,
        dims: &[usize],
        threads: usize,
        mode: ExecMode,
        seed: u64,
        init: Option<&[f64]>,
    ) -> Result<Self> {
        let spec = stencil::spec(bench)
            .ok_or_else(|| Error::invalid(format!("unknown stencil benchmark {bench:?}")))?;
        let x0 = crate::session::stencil_domain(&spec, dims, seed, init)?;
        Ok(Self {
            spec,
            x0,
            threads,
            mode,
            state: None,
            steps: 0,
            wall_seconds: 0.0,
            invocations: 0,
            host_bytes: 0,
            barrier_wait_seconds: 0.0,
        })
    }
}

impl Solver for CpuStencil {
    fn prepare(&mut self) -> Result<()> {
        self.state = Some(self.x0.clone());
        self.steps = 0;
        self.wall_seconds = 0.0;
        self.invocations = 0;
        self.host_bytes = 0;
        self.barrier_wait_seconds = 0.0;
        Ok(())
    }

    fn advance(&mut self, steps: usize) -> Result<()> {
        let cur = match self.state.take() {
            Some(s) => s,
            None => self.x0.clone(),
        };
        let rep = match self.mode {
            ExecMode::HostLoop => parallel::host_loop(&self.spec, &cur, steps, self.threads)?,
            ExecMode::Persistent => {
                parallel::persistent(&self.spec, &cur, steps, self.threads)?
            }
            ExecMode::HostLoopResident => {
                return Err(Error::invalid(
                    "host-loop-resident is a PJRT-only execution model",
                ))
            }
        };
        self.steps += steps;
        self.wall_seconds += rep.wall_seconds;
        self.invocations += match self.mode {
            ExecMode::HostLoop => steps as u64, // one "launch" (respawn) per step
            _ => 1,                             // one persistent launch per advance
        };
        self.host_bytes += rep.global_bytes;
        self.barrier_wait_seconds += rep.barrier_wait.as_secs_f64();
        self.state = Some(rep.result);
        Ok(())
    }

    fn report(&self) -> Report {
        Report::new(
            self.mode,
            self.steps,
            self.wall_seconds,
            self.invocations,
            self.host_bytes,
            self.x0.interior_cells() as f64 * self.steps as f64,
            "cells/s",
            None,
            Some(self.barrier_wait_seconds),
        )
    }

    fn state_f64(&self) -> Result<Vec<f64>> {
        Ok(match &self.state {
            Some(d) => d.data.clone(),
            None => self.x0.data.clone(),
        })
    }
}

/// Conjugate gradient on the rust-native merge-SpMV substrate, with
/// resumable state (x/r/p held across `advance` calls). Host-loop mode
/// re-searches the merge plan every iteration and streams each BLAS-1 op
/// as a separate pass; persistent mode caches the plan once and fuses the
/// passes — the paper's two CG mechanisms. The iterates are identical.
pub struct CpuCg {
    a: Csr,
    b: Vec<f64>,
    parts: usize,
    threaded: bool,
    mode: ExecMode,
    plan: MergePlan,
    x: Vec<f64>,
    r: Vec<f64>,
    p: Vec<f64>,
    ap: Vec<f64>,
    rr: f64,
    iters: usize,
    wall_seconds: f64,
    invocations: u64,
    host_bytes: u64,
    plan_searches: u64,
}

impl CpuCg {
    pub(crate) fn poisson(
        n: usize,
        seed: u64,
        parts: usize,
        threaded: bool,
        mode: ExecMode,
    ) -> Result<Self> {
        let g = (n as f64).sqrt().round() as usize;
        let a = gen::poisson2d(g);
        let b = gen::rhs(n, seed);
        Self::system(a, b, parts, threaded, mode)
    }

    pub(crate) fn system(
        a: Csr,
        b: Vec<f64>,
        parts: usize,
        threaded: bool,
        mode: ExecMode,
    ) -> Result<Self> {
        if a.n_rows != a.n_cols {
            return Err(Error::Solver(format!(
                "matrix not square: {}x{}",
                a.n_rows, a.n_cols
            )));
        }
        if b.len() != a.n_rows {
            return Err(Error::Solver(format!(
                "rhs has {} entries, matrix {}",
                b.len(),
                a.n_rows
            )));
        }
        let n = a.n_rows;
        let plan = MergePlan::new(&a, parts);
        Ok(Self {
            a,
            b,
            parts,
            threaded,
            mode,
            plan,
            x: vec![0.0; n],
            r: vec![0.0; n],
            p: vec![0.0; n],
            ap: vec![0.0; n],
            rr: 0.0,
            iters: 0,
            wall_seconds: 0.0,
            invocations: 0,
            host_bytes: 0,
            plan_searches: 0,
        })
    }

    /// Global ("slow tier") bytes one iteration streams under this mode:
    /// the matrix plus 5 (host-loop) or 2 (fused persistent) vector passes.
    fn bytes_per_iter(&self) -> u64 {
        let matrix = (self.a.nnz() * 12 + (self.a.n_rows + 1) * 4) as u64;
        let passes = if self.mode == ExecMode::Persistent { 2 } else { 5 };
        matrix + (passes * self.a.n_rows * 8) as u64
    }

    /// One CG iteration; returns false once the residual is exactly zero
    /// (further iterations would divide by zero and are no-ops anyway).
    fn step(&mut self) -> Result<bool> {
        if self.rr <= 0.0 {
            return Ok(false);
        }
        if self.mode != ExecMode::Persistent {
            // the host-loop baseline recomputes the workload split every
            // launch (the sample-code behaviour the paper improves on)
            self.plan = MergePlan::new(&self.a, self.parts);
            self.plan_searches += 1;
        }
        if self.threaded {
            merge::spmv_parallel(&self.a, &self.plan, &self.p, &mut self.ap);
        } else {
            merge::spmv(&self.a, &self.plan, &self.p, &mut self.ap);
        }
        let pap: f64 = self.p.iter().zip(&self.ap).map(|(x, y)| x * y).sum();
        if pap <= 0.0 {
            return Err(Error::Solver(format!(
                "matrix not positive definite (pAp={pap})"
            )));
        }
        let alpha = self.rr / pap;
        let mut rr_new = 0.0;
        for i in 0..self.x.len() {
            self.x[i] += alpha * self.p[i];
            let ri = self.r[i] - alpha * self.ap[i];
            self.r[i] = ri;
            rr_new += ri * ri;
        }
        let beta = rr_new / self.rr;
        for i in 0..self.p.len() {
            self.p[i] = self.r[i] + beta * self.p[i];
        }
        self.rr = rr_new;
        self.iters += 1;
        Ok(true)
    }
}

impl Solver for CpuCg {
    fn prepare(&mut self) -> Result<()> {
        self.x.iter_mut().for_each(|v| *v = 0.0);
        self.r.copy_from_slice(&self.b);
        self.p.copy_from_slice(&self.b);
        self.rr = self.b.iter().map(|v| v * v).sum();
        if self.mode == ExecMode::Persistent {
            // the paper's TB-level "workload" cache: searched exactly once
            self.plan = MergePlan::new(&self.a, self.parts);
            self.plan_searches = 1;
        } else {
            self.plan_searches = 0;
        }
        self.iters = 0;
        self.wall_seconds = 0.0;
        self.invocations = 0;
        self.host_bytes = 0;
        Ok(())
    }

    fn advance(&mut self, iters: usize) -> Result<()> {
        let t0 = std::time::Instant::now();
        let mut done = 0;
        for _ in 0..iters {
            if !self.step()? {
                break;
            }
            done += 1;
        }
        self.wall_seconds += t0.elapsed().as_secs_f64();
        self.invocations += match self.mode {
            ExecMode::Persistent => 1,
            _ => done as u64,
        };
        self.host_bytes += done as u64 * self.bytes_per_iter();
        Ok(())
    }

    fn report(&self) -> Report {
        Report::new(
            self.mode,
            self.iters,
            self.wall_seconds,
            self.invocations,
            self.host_bytes,
            self.iters as f64,
            "iters/s",
            Some(self.rr),
            None,
        )
    }

    fn state_f64(&self) -> Result<Vec<f64>> {
        Ok(self.x.clone())
    }

    fn true_residual(&self) -> Result<Option<f64>> {
        let mut ax = vec![0.0; self.a.n_rows];
        self.a.spmv_gold(&self.x, &mut ax);
        Ok(Some(
            self.b
                .iter()
                .zip(&ax)
                .map(|(bi, ai)| (bi - ai) * (bi - ai))
                .sum(),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cg::{solve_persistent, CgOptions};

    #[test]
    fn cpu_cg_matches_the_batch_solver_iterates() {
        let a = gen::poisson2d(16);
        let b = gen::rhs(a.n_rows, 4);
        let mut s =
            CpuCg::system(a.clone(), b.clone(), 8, false, ExecMode::Persistent).unwrap();
        s.prepare().unwrap();
        s.advance(12).unwrap();
        s.advance(12).unwrap(); // resumable: 12 + 12 == one 24-iteration solve
        let opts = CgOptions { max_iters: 24, tol: 0.0, parts: 8, threaded: false };
        let want = solve_persistent(&a, &b, &opts).unwrap();
        let got = s.state_f64().unwrap();
        let diff = got
            .iter()
            .zip(&want.x)
            .map(|(g, w)| (g - w).abs())
            .fold(0.0, f64::max);
        assert!(diff < 1e-12, "session CG diverged from batch solver by {diff}");
        assert_eq!(s.report().steps, 24);
        assert_eq!(s.report().invocations, 2); // one launch per advance
    }

    #[test]
    fn cpu_cg_modes_walk_identical_iterates() {
        let a = gen::poisson2d(12);
        let b = gen::rhs(a.n_rows, 9);
        let mut h = CpuCg::system(a.clone(), b.clone(), 8, false, ExecMode::HostLoop).unwrap();
        let mut p = CpuCg::system(a, b, 8, false, ExecMode::Persistent).unwrap();
        h.prepare().unwrap();
        p.prepare().unwrap();
        h.advance(20).unwrap();
        p.advance(20).unwrap();
        assert_eq!(h.state_f64().unwrap(), p.state_f64().unwrap());
        assert!(h.plan_searches > p.plan_searches);
        assert!(h.report().host_bytes > p.report().host_bytes);
    }
}
