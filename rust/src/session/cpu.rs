//! CPU persistent-threads solvers: the physically-measured PERKS
//! demonstration behind `Backend::CpuPersistent`. Stencils run on the
//! spawn-once `stencil::pool` runtime (OS threads as thread blocks, slabs
//! as on-chip caches, resident across `advance` calls); CG runs on the
//! merge-SpMV substrate with the paper's plan-caching and pass-fusion
//! mechanisms.

use std::sync::Arc;

use crate::cg::pipeline::{self, PipePool, PipeState};
use crate::cg::pool::CgPool;
use crate::cg::precond::{Precond, Preconditioner};
use crate::coordinator::executor::ExecMode;
use crate::error::{Error, Result};
use crate::runtime::farm::{FarmCg, FarmCgPipe, FarmHandle, FarmStencil};
use crate::runtime::plane::graph::CommandGraph;
use crate::runtime::resilience::ResilienceConfig;
use crate::session::{Report, Solver};
use crate::sparse::csr::Csr;
use crate::sparse::gen;
use crate::spmv::merge::{self, MergePlan};
use crate::stencil::parallel::ParallelReport;
use crate::stencil::pool::StencilPool;
use crate::stencil::shape::StencilSpec;
use crate::stencil::{self, parallel, Domain};

/// Construction options for [`CpuStencil`] — the stencil-substrate knobs
/// the [`crate::session::SessionBuilder`] resolves (thread count,
/// execution model, seed, and the temporal-blocking degree `bt`).
#[derive(Clone, Debug)]
pub struct StencilOptions {
    /// Banded worker count (resolved, never 0 here). On a farm this is
    /// the band-shard count of the admitted tenant (the partition is the
    /// solo pool's, so traffic accounting matches it exactly).
    pub threads: usize,
    pub mode: ExecMode,
    /// Seed for the deterministic initial domain.
    pub seed: u64,
    /// Temporal-blocking degree: sub-steps advanced locally per exchange
    /// epoch. `1` (the default) is per-step exchange — bit-identical to
    /// the pre-temporal runtime. `> 1` requires the persistent model.
    pub temporal: usize,
    /// Shared multi-tenant worker pool to admit the solver to instead of
    /// spawning a solo [`StencilPool`] (persistent mode only).
    pub farm: Option<FarmHandle>,
    /// Batched-graph granularity on the farm path, in exchange epochs per
    /// graph segment: `0` (default) submits each advance as one
    /// monolithic command; `> 0` encodes it as a [`CommandGraph`] of
    /// `batch_epochs * bt`-step segments enqueued under a single
    /// scheduler-lock acquisition. Bit-identical either way.
    pub batch_epochs: usize,
    /// Supervision config applied to the admitted tenant on the farm
    /// path (checkpoint cadence / retry policy / watchdog deadline);
    /// disabled by default and ignored off-farm.
    pub resilience: ResilienceConfig,
}

impl Default for StencilOptions {
    fn default() -> Self {
        Self {
            threads: 1,
            mode: ExecMode::Persistent,
            seed: 42,
            temporal: 1,
            farm: None,
            batch_epochs: 0,
            resilience: ResilienceConfig::disabled(),
        }
    }
}

impl StencilOptions {
    pub fn new(threads: usize, mode: ExecMode, seed: u64) -> Self {
        Self {
            threads,
            mode,
            seed,
            temporal: 1,
            farm: None,
            batch_epochs: 0,
            resilience: ResilienceConfig::disabled(),
        }
    }

    /// Set the temporal-blocking degree `bt` (see [`StencilOptions::temporal`]).
    pub fn temporal(mut self, bt: usize) -> Self {
        self.temporal = bt;
        self
    }

    /// Admit the solver to a shared farm (see [`StencilOptions::farm`]).
    pub fn farm(mut self, handle: FarmHandle) -> Self {
        self.farm = Some(handle);
        self
    }

    /// Set the batched-graph granularity (see [`StencilOptions::batch_epochs`]).
    pub fn batch_epochs(mut self, epochs: usize) -> Self {
        self.batch_epochs = epochs;
        self
    }

    /// Set the supervision config (see [`StencilOptions::resilience`]).
    pub fn resilience(mut self, cfg: ResilienceConfig) -> Self {
        self.resilience = cfg;
        self
    }
}

/// Iterative stencil on the persistent-threads CPU substrate (f64).
///
/// Persistent mode rides the spawn-once [`StencilPool`]: the banded
/// workers are spawned in `prepare`, park on a condvar between `advance`
/// calls, keep their slabs resident across them, and are joined on drop
/// or `prepare` re-entry — so `advance` performs **zero** thread spawns.
/// With a temporal degree `bt > 1` the resident loop batches its
/// boundary exchange into epochs of `bt` locally-advanced sub-steps:
/// `2 * ceil(steps / bt)` barrier syncs per advance instead of
/// `2 * steps`, at the price of redundant trapezoid compute (surfaced as
/// [`Report::redundancy`]). Host-loop mode respawns its threads every
/// step (the measured relaunch-per-step baseline) and supports only
/// `bt = 1`.
pub struct CpuStencil {
    spec: StencilSpec,
    x0: Domain,
    threads: usize,
    mode: ExecMode,
    /// Temporal-blocking degree (sub-steps per exchange epoch).
    bt: usize,
    /// Host-loop state; `None` while the pool owns the state.
    state: Option<Domain>,
    /// Spawn-once banded worker pool; `Some` iff persistent mode without
    /// a farm, from `prepare` (or the first `advance`) until the next
    /// `prepare`/drop.
    pool: Option<StencilPool>,
    /// Shared farm to admit to instead of spawning a solo pool.
    farm: Option<FarmHandle>,
    /// Admitted farm tenant; `Some` iff persistent mode with a farm.
    farm_session: Option<FarmStencil>,
    steps: usize,
    wall_seconds: f64,
    invocations: u64,
    host_bytes: u64,
    /// Host-loop accumulation; the pooled path reads the pool's counter.
    barrier_wait_seconds: f64,
    /// Last in-loop residual norm (squared step delta), from
    /// convergence-driven advances.
    residual: Option<f64>,
    /// Cell updates performed including temporal-blocking overlap work.
    computed_cells: u64,
    /// Useful cell updates (interior x steps).
    useful_cells: u64,
    /// Time this solver's commands waited in the farm's submission queue
    /// (farm-backed solves only; surfaced as `Report::queue_wait_seconds`).
    queue_wait_seconds: f64,
    /// Batched-graph granularity (epochs per segment; 0 = monolithic).
    batch_epochs: usize,
    /// Submission-plane telemetry since `prepare` (farm-backed only).
    plane_batches: u64,
    plane_sheds: u64,
    plane_timeouts: u64,
    /// Supervision config applied to the admitted tenant (farm only).
    resilience: ResilienceConfig,
    /// Recovery telemetry since `prepare` (farm-backed only).
    recoveries: u64,
    replayed_epochs: u64,
    checkpoint_bytes: u64,
}

impl CpuStencil {
    pub(crate) fn new(
        bench: &str,
        dims: &[usize],
        opts: &StencilOptions,
        init: Option<&[f64]>,
    ) -> Result<Self> {
        let spec = stencil::spec(bench)
            .ok_or_else(|| Error::invalid(format!("unknown stencil benchmark {bench:?}")))?;
        if opts.mode == ExecMode::Pipelined {
            return Err(Error::invalid(
                "pipelined is a CG-only execution model; stencils have no \
                 dot-product pipeline",
            ));
        }
        if opts.temporal == 0 {
            return Err(Error::invalid("temporal blocking degree must be >= 1"));
        }
        if opts.temporal > 1 && opts.mode != ExecMode::Persistent {
            return Err(Error::invalid(
                "temporal blocking (bt > 1) requires the persistent execution model",
            ));
        }
        if opts.farm.is_some() && opts.mode != ExecMode::Persistent {
            return Err(Error::invalid(
                "farm execution requires the persistent execution model",
            ));
        }
        if opts.batch_epochs > 0 && opts.farm.is_none() {
            return Err(Error::invalid(
                "batched command graphs (batch_epochs > 0) require a farm",
            ));
        }
        if opts.resilience.enabled() && opts.farm.is_none() {
            return Err(Error::invalid(
                "resilience (checkpoint/retry/deadline) requires a farm",
            ));
        }
        let x0 = crate::session::stencil_domain(&spec, dims, opts.seed, init)?;
        Ok(Self {
            spec,
            x0,
            threads: opts.threads,
            mode: opts.mode,
            bt: opts.temporal,
            state: None,
            pool: None,
            farm: opts.farm.clone(),
            farm_session: None,
            steps: 0,
            wall_seconds: 0.0,
            invocations: 0,
            host_bytes: 0,
            barrier_wait_seconds: 0.0,
            residual: None,
            computed_cells: 0,
            useful_cells: 0,
            queue_wait_seconds: 0.0,
            batch_epochs: opts.batch_epochs,
            plane_batches: 0,
            plane_sheds: 0,
            plane_timeouts: 0,
            resilience: opts.resilience.clone(),
            recoveries: 0,
            replayed_epochs: 0,
            checkpoint_bytes: 0,
        })
    }

    /// OS threads the active pool has spawned (`None` when not pooled) —
    /// constant across `advance` calls, which the tests assert.
    #[cfg(test)]
    fn pool_spawns(&self) -> Option<u64> {
        self.pool.as_ref().map(|p| p.spawn_count())
    }

    fn record_host_rep(&mut self, rep: &ParallelReport) {
        self.steps += rep.steps;
        self.wall_seconds += rep.wall_seconds;
        self.invocations += rep.steps as u64; // one "launch" (respawn) per step
        self.host_bytes += rep.global_bytes;
        self.barrier_wait_seconds += rep.barrier_wait.as_secs_f64();
        self.computed_cells += rep.computed_cells;
        self.useful_cells += rep.useful_cells;
    }

    /// Shared engine of `advance` (`tol == None`) and `advance_until`
    /// (`tol == Some(_)`); returns the steps actually performed. With
    /// `bt > 1`, convergence is checked at epoch granularity (the pool's
    /// residual is the final sub-step's norm, identical at every worker
    /// count, so the stop epoch is too).
    fn advance_inner(&mut self, steps: usize, tol: Option<f64>) -> Result<usize> {
        match self.mode {
            ExecMode::Persistent => {
                if let Some(farm) = &self.farm {
                    // multi-tenant path: the advance is enqueued into the
                    // shared farm's submission queue and executed on its
                    // resident workers — zero thread spawns, slabs stay
                    // resident in the admitted tenant between commands
                    if self.farm_session.is_none() {
                        let mut tenant =
                            farm.admit_stencil(&self.spec, &self.x0, self.threads, self.bt)?;
                        if self.resilience.enabled() {
                            tenant.configure_resilience(self.resilience.clone())?;
                        }
                        self.farm_session = Some(tenant);
                    }
                    let tenant = self.farm_session.as_mut().expect("admitted above");
                    let t0 = std::time::Instant::now();
                    let run = if self.batch_epochs > 0 && steps > 0 {
                        // batched path: the whole advance schedule is one
                        // CommandGraph — one enqueue-lock acquisition,
                        // segment boundaries chained inside the farm
                        let seg = self.batch_epochs.saturating_mul(self.bt).max(1);
                        match CommandGraph::schedule(steps, seg, tol) {
                            Ok(graph) => tenant.advance_graph(&graph),
                            Err(e) => Err(e),
                        }
                    } else {
                        tenant.advance(steps, tol)
                    };
                    // the command happened even if the run failed: record
                    // wall + launch before propagating (as the pool paths)
                    self.wall_seconds += t0.elapsed().as_secs_f64();
                    self.invocations += 1; // one farm command per advance
                    let run = match run {
                        Ok(run) => {
                            self.plane_batches += 1;
                            run
                        }
                        Err(e) => {
                            match &e {
                                Error::Shed(_) => self.plane_sheds += 1,
                                Error::Timeout(_) => self.plane_timeouts += 1,
                                _ => {}
                            }
                            return Err(e);
                        }
                    };
                    self.steps += run.steps;
                    self.host_bytes += run.global_bytes;
                    self.computed_cells += run.computed_cells;
                    self.useful_cells +=
                        (self.x0.interior_cells() * run.steps) as u64;
                    self.queue_wait_seconds += run.queue_wait_seconds;
                    self.recoveries += run.recoveries;
                    self.replayed_epochs += run.replayed_epochs;
                    self.checkpoint_bytes += run.checkpoint_bytes;
                    if run.residual.is_some() {
                        self.residual = run.residual;
                    }
                    return Ok(run.steps);
                }
                if self.pool.is_none() {
                    // direct (un-prepared) use: spawn the residents now
                    self.pool = Some(StencilPool::spawn_temporal(
                        &self.spec,
                        &self.x0,
                        self.threads,
                        self.bt,
                    )?);
                }
                let pool = self.pool.as_mut().expect("spawned above");
                let t0 = std::time::Instant::now();
                // resident time loop: the slab state rides the pool's
                // workers, which iterate internally — zero thread spawns
                let run = pool.run(steps, tol);
                // the launch happened even if the run failed (collective
                // worker panic): record wall + launch before propagating,
                // as the CG path does for its completed-iteration metrics
                self.wall_seconds += t0.elapsed().as_secs_f64();
                self.invocations += 1; // one persistent launch per advance
                let run = run?;
                self.steps += run.steps;
                self.host_bytes += run.global_bytes;
                self.computed_cells += run.computed_cells;
                self.useful_cells += run.useful_cells;
                if run.residual.is_some() {
                    self.residual = run.residual;
                }
                Ok(run.steps)
            }
            ExecMode::HostLoop => {
                let mut cur = match self.state.take() {
                    Some(s) => s,
                    None => self.x0.clone(),
                };
                let did;
                if let Some(tol) = tol {
                    // relaunch-per-step baseline with a host-side norm
                    // after every launch — same residual arithmetic as the
                    // pool's in-loop fold, so both stop on the same step
                    let mut n = 0;
                    for _ in 0..steps {
                        let rep = parallel::host_loop(&self.spec, &cur, 1, self.threads)?;
                        self.record_host_rep(&rep);
                        let res = parallel::residual_norm(&self.spec, &cur, &rep.result);
                        self.residual = Some(res);
                        cur = rep.result;
                        n += 1;
                        if res <= tol {
                            break;
                        }
                    }
                    did = n;
                } else {
                    let rep = parallel::host_loop(&self.spec, &cur, steps, self.threads)?;
                    self.record_host_rep(&rep);
                    cur = rep.result;
                    did = steps;
                }
                self.state = Some(cur);
                Ok(did)
            }
            ExecMode::HostLoopResident => {
                Err(Error::invalid("host-loop-resident is a PJRT-only execution model"))
            }
            ExecMode::Pipelined => Err(Error::invalid(
                "pipelined is a CG-only execution model; stencils have no \
                 dot-product pipeline",
            )),
        }
    }
}

impl Solver for CpuStencil {
    fn prepare(&mut self) -> Result<()> {
        // shut the previous solve's pool down first (workers joined) /
        // release the previous farm tenant, so re-entry never leaks
        // resident threads or farm slots
        self.pool = None;
        self.farm_session = None;
        self.state = None;
        if self.mode == ExecMode::Persistent {
            if let Some(farm) = &self.farm {
                // multi-tenant admission: registers resident state on the
                // farm's spawn-once workers — zero thread spawns
                let mut tenant =
                    farm.admit_stencil(&self.spec, &self.x0, self.threads, self.bt)?;
                if self.resilience.enabled() {
                    tenant.configure_resilience(self.resilience.clone())?;
                }
                self.farm_session = Some(tenant);
            } else {
                // spawn-once worker pool: the only thread creation of the
                // whole solve; every subsequent `advance` is spawn-free
                self.pool = Some(StencilPool::spawn_temporal(
                    &self.spec,
                    &self.x0,
                    self.threads,
                    self.bt,
                )?);
            }
        } else {
            self.state = Some(self.x0.clone());
        }
        self.steps = 0;
        self.wall_seconds = 0.0;
        self.invocations = 0;
        self.host_bytes = 0;
        self.barrier_wait_seconds = 0.0;
        self.residual = None;
        self.computed_cells = 0;
        self.useful_cells = 0;
        self.queue_wait_seconds = 0.0;
        self.plane_batches = 0;
        self.plane_sheds = 0;
        self.plane_timeouts = 0;
        self.recoveries = 0;
        self.replayed_epochs = 0;
        self.checkpoint_bytes = 0;
        Ok(())
    }

    fn advance(&mut self, steps: usize) -> Result<()> {
        self.advance_inner(steps, None).map(|_| ())
    }

    fn advance_until(&mut self, tol: f64, max_steps: usize) -> Result<usize> {
        self.advance_inner(max_steps, Some(tol))
    }

    fn report(&self) -> Report {
        let barrier_wait = match &self.pool {
            Some(p) => p.barrier_wait_seconds(),
            None => self.barrier_wait_seconds,
        };
        let mut rep = Report::new(
            self.mode,
            self.steps,
            self.wall_seconds,
            self.invocations,
            self.host_bytes,
            self.x0.interior_cells() as f64 * self.steps as f64,
            "cells/s",
            self.residual,
            Some(barrier_wait),
        );
        if self.useful_cells > 0 {
            rep.redundancy = Some(crate::stencil::temporal::redundancy_ratio(
                self.computed_cells,
                self.useful_cells,
            ));
        }
        if self.farm.is_some() {
            rep.queue_wait_seconds = Some(self.queue_wait_seconds);
            rep.plane_batches = Some(self.plane_batches);
            rep.plane_sheds = Some(self.plane_sheds);
            rep.plane_timeouts = Some(self.plane_timeouts);
            rep.recoveries = Some(self.recoveries);
            rep.replayed_epochs = Some(self.replayed_epochs);
            rep.checkpoint_bytes = Some(self.checkpoint_bytes);
        }
        rep
    }

    fn state_f64(&self) -> Result<Vec<f64>> {
        if let Some(tenant) = &self.farm_session {
            return tenant.state();
        }
        if let Some(pool) = &self.pool {
            return Ok(pool.state());
        }
        Ok(match &self.state {
            Some(d) => d.data.clone(),
            None => self.x0.data.clone(),
        })
    }
}

/// Conjugate gradient on the rust-native merge-SpMV substrate, with
/// resumable state (x/r/p held across `advance` calls). Host-loop mode
/// re-searches the merge plan every iteration, streams each BLAS-1 op as
/// a separate pass, and (when threaded) respawns its SpMV workers on
/// every iteration; persistent mode caches the plan once, fuses the
/// passes, and (when threaded) runs the whole iteration loop on the
/// spawn-once [`CgPool`] with barrier-reduced dots — the paper's CG
/// mechanisms. [`ExecMode::Pipelined`] swaps the classic recurrence for
/// the Ghysels–Vanroose pipelined PCG ([`crate::cg::pipeline`]): one
/// fused pass and ONE slot-ordered barrier reduction per iteration
/// (classic needs two), with the preconditioner folded into the same
/// pass. The iterates are identical across paths and thread counts
/// *within* each recurrence: all reductions fold per-block partials in
/// block-index order (the pool's canonical order), never full-vector or
/// arrival order.
pub struct CpuCg {
    a: Arc<Csr>,
    b: Vec<f64>,
    parts: usize,
    /// Resolved worker count (never 0): queried from
    /// `available_parallelism` once at construction, not per call.
    threads: usize,
    threaded: bool,
    mode: ExecMode,
    plan: MergePlan,
    /// Reduction blocks shared with the pool: `partition(n, parts)`.
    blocks: Vec<(usize, usize)>,
    /// Spawn-once worker pool; `Some` iff threaded persistent mode
    /// without a farm, from `prepare` until the next `prepare`/drop
    /// (joined on replacement).
    pool: Option<CgPool>,
    /// Shared farm to admit to instead of spawning a solo pool
    /// (persistent mode; supersedes the `threaded` pool).
    farm: Option<FarmHandle>,
    /// Admitted farm tenant; `Some` iff persistent mode with a farm.
    farm_session: Option<FarmCg>,
    /// Farm submission-queue wait accumulated since `prepare`.
    queue_wait_seconds: f64,
    /// Batched-graph granularity (iterations per segment; 0 = monolithic).
    batch_iters: usize,
    /// Submission-plane telemetry since `prepare` (farm-backed only).
    plane_batches: u64,
    plane_sheds: u64,
    plane_timeouts: u64,
    /// Supervision config applied to the admitted tenant (farm only).
    resilience: ResilienceConfig,
    /// Recovery telemetry since `prepare` (farm-backed only).
    recoveries: u64,
    replayed_epochs: u64,
    checkpoint_bytes: u64,
    /// Preconditioner spec, applied identically on every path (serial /
    /// pooled / farm, classic and pipelined). Identity by default.
    precond_spec: Preconditioner,
    /// Built preconditioner; `Some` from `prepare` until the next
    /// `prepare` (rebuilt there so a changed spec takes effect).
    pc: Option<Arc<Precond>>,
    /// Pipelined recurrence state (x,r,u,w,p,s,q,z,m + scalars); `Some`
    /// iff `mode == Pipelined`, primed in `prepare`.
    pipe: Option<PipeState>,
    /// Spawn-once pipelined pool; `Some` iff threaded pipelined mode
    /// without a farm.
    pipe_pool: Option<PipePool>,
    /// Admitted pipelined farm tenant; `Some` iff pipelined mode with a
    /// farm.
    farm_pipe: Option<FarmCgPipe>,
    x: Vec<f64>,
    r: Vec<f64>,
    /// Preconditioned residual `z = M⁻¹r` for classic PCG (identity spec
    /// leaves it shadowing `r`).
    z: Vec<f64>,
    p: Vec<f64>,
    ap: Vec<f64>,
    rr: f64,
    /// Classic-PCG recurrence scalar `r·z` (equals `rr` under identity).
    rz: f64,
    iters: usize,
    wall_seconds: f64,
    invocations: u64,
    host_bytes: u64,
    plan_searches: u64,
}

impl CpuCg {
    pub(crate) fn poisson(
        n: usize,
        seed: u64,
        parts: usize,
        threads: usize,
        threaded: bool,
        mode: ExecMode,
    ) -> Result<Self> {
        let g = (n as f64).sqrt().round() as usize;
        let a = gen::poisson2d(g);
        let b = gen::rhs(n, seed);
        Self::system(a, b, parts, threads, threaded, mode)
    }

    pub(crate) fn system(
        a: Csr,
        b: Vec<f64>,
        parts: usize,
        threads: usize,
        threaded: bool,
        mode: ExecMode,
    ) -> Result<Self> {
        if a.n_rows != a.n_cols {
            return Err(Error::Solver(format!(
                "matrix not square: {}x{}",
                a.n_rows, a.n_cols
            )));
        }
        if a.n_rows == 0 {
            // partition(0, parts) is (correctly) empty: there are no
            // reduction blocks and no rows to iterate — reject up front
            // instead of building a zero-work solver
            return Err(Error::Solver("matrix has no rows (empty system)".into()));
        }
        if b.len() != a.n_rows {
            return Err(Error::Solver(format!(
                "rhs has {} entries, matrix {}",
                b.len(),
                a.n_rows
            )));
        }
        let n = a.n_rows;
        let parts = parts.max(1);
        let threads = crate::util::resolve_workers(threads);
        let plan = MergePlan::new(&a, parts);
        Ok(Self {
            blocks: parallel::partition(n, parts),
            a: Arc::new(a),
            b,
            parts,
            threads,
            threaded,
            mode,
            plan,
            pool: None,
            farm: None,
            farm_session: None,
            queue_wait_seconds: 0.0,
            batch_iters: 0,
            plane_batches: 0,
            plane_sheds: 0,
            plane_timeouts: 0,
            resilience: ResilienceConfig::disabled(),
            recoveries: 0,
            replayed_epochs: 0,
            checkpoint_bytes: 0,
            precond_spec: Preconditioner::None,
            pc: None,
            pipe: None,
            pipe_pool: None,
            farm_pipe: None,
            x: vec![0.0; n],
            r: vec![0.0; n],
            z: vec![0.0; n],
            p: vec![0.0; n],
            ap: vec![0.0; n],
            rr: 0.0,
            rz: 0.0,
            iters: 0,
            wall_seconds: 0.0,
            invocations: 0,
            host_bytes: 0,
            plan_searches: 0,
        })
    }

    /// Route this solver onto a shared farm (persistent mode only; set
    /// before `prepare`). The farm supersedes the solo `threaded` pool.
    pub(crate) fn with_farm(mut self, handle: FarmHandle) -> Self {
        self.farm = Some(handle);
        self
    }

    /// Set the batched-graph granularity in iterations per segment (farm
    /// path only; 0 = monolithic commands).
    pub(crate) fn with_batch_iters(mut self, iters: usize) -> Self {
        self.batch_iters = iters;
        self
    }

    /// Set the supervision config (checkpoint cadence / retry policy /
    /// watchdog deadline) applied to the admitted tenant (farm path
    /// only; set before `prepare`).
    pub(crate) fn with_resilience(mut self, cfg: ResilienceConfig) -> Self {
        self.resilience = cfg;
        self
    }

    /// Set the preconditioner spec (built in `prepare`; applied on every
    /// execution path — serial, pooled, farm, classic and pipelined).
    pub(crate) fn with_preconditioner(mut self, pc: Preconditioner) -> Self {
        self.precond_spec = pc;
        self
    }

    /// OS threads the active pool has spawned (`None` when not pooled) —
    /// constant across `advance` calls, which the tests assert.
    #[cfg(test)]
    fn pool_spawns(&self) -> Option<u64> {
        self.pool.as_ref().map(|p| p.spawn_count())
    }

    /// Global ("slow tier") bytes one iteration streams under this mode:
    /// the matrix plus 3 (pipelined: one fused recurrence pass over the
    /// widened vector set), 5 (host-loop), 2 (fused persistent pool), or
    /// 4 (classic farm: the phase-split resident iteration un-fuses the
    /// two sweeps into spmv / fixup+dot / update+dot / direction passes)
    /// vector passes, plus the preconditioner's extra row-local passes
    /// (0 identity, 1 Jacobi, 2 block-Jacobi).
    fn bytes_per_iter(&self) -> u64 {
        let matrix = (self.a.nnz() * 12 + (self.a.n_rows + 1) * 4) as u64;
        // the if-else chain must stay parenthesized: without the parens
        // the `+ extra_passes()` binds into the final else block
        let passes = (if self.mode == ExecMode::Pipelined {
            3.0
        } else if self.mode != ExecMode::Persistent {
            5.0
        } else if self.farm.is_some() {
            4.0
        } else {
            2.0
        }) + self.precond_spec.extra_passes();
        matrix + (passes * (self.a.n_rows * 8) as f64) as u64
    }

    /// One CG iteration; returns false once the residual is exactly zero
    /// (further iterations would divide by zero and are no-ops anyway).
    ///
    /// Reductions run in the pool's canonical order — per-block partials
    /// accumulated left-to-right, folded in block-index order — so the
    /// serial path walks bit-identical iterates to the pooled path at
    /// every worker count.
    fn step(&mut self) -> Result<bool> {
        if self.rr <= 0.0 {
            return Ok(false);
        }
        if self.mode != ExecMode::Persistent {
            // the host-loop baseline recomputes the workload split every
            // launch (the sample-code behaviour the paper improves on)
            self.plan = MergePlan::new(&self.a, self.parts);
            self.plan_searches += 1;
        }
        if self.threaded {
            merge::spmv_parallel(&self.a, &self.plan, &self.p, &mut self.ap, self.threads);
        } else {
            merge::spmv(&self.a, &self.plan, &self.p, &mut self.ap);
        }
        let mut pap = 0.0;
        for &(s, l) in &self.blocks {
            pap += crate::cg::block_partial(s, l, |i| self.p[i] * self.ap[i]);
        }
        if !pap.is_finite() {
            // fail before alpha spreads the poison into x/r — the caller
            // can restore a checkpoint and replay from clean iterates
            return Err(Error::Solver(format!(
                "non-finite p·Ap ({pap}) at iteration {}",
                self.iters + 1
            )));
        }
        if pap <= 0.0 {
            return Err(Error::Solver(format!(
                "matrix not positive definite (pAp={pap})"
            )));
        }
        let alpha = self.rr / pap;
        let mut rr_new = 0.0;
        let (x, r, p, ap) = (&mut self.x, &mut self.r, &self.p, &self.ap);
        for &(s, l) in &self.blocks {
            rr_new += crate::cg::block_partial(s, l, |i| {
                x[i] += alpha * p[i];
                let ri = r[i] - alpha * ap[i];
                r[i] = ri;
                ri * ri
            });
        }
        if !rr_new.is_finite() {
            return Err(Error::Solver(format!(
                "non-finite r·r ({rr_new}) at iteration {}",
                self.iters + 1
            )));
        }
        let beta = rr_new / self.rr;
        for i in 0..self.p.len() {
            self.p[i] = self.r[i] + beta * self.p[i];
        }
        self.rr = rr_new;
        self.iters += 1;
        Ok(true)
    }

    /// One classic *preconditioned* CG iteration, sharing the pooled
    /// arithmetic ([`crate::cg::classic_precond_block_pass`]) and fold
    /// order, so the serial path walks bit-identical iterates to the
    /// preconditioned pool at every worker count.
    fn step_precond(&mut self) -> Result<bool> {
        if self.rr <= 0.0 {
            return Ok(false);
        }
        if self.mode != ExecMode::Persistent {
            self.plan = MergePlan::new(&self.a, self.parts);
            self.plan_searches += 1;
        }
        if self.threaded {
            merge::spmv_parallel(&self.a, &self.plan, &self.p, &mut self.ap, self.threads);
        } else {
            merge::spmv(&self.a, &self.plan, &self.p, &mut self.ap);
        }
        let mut pap = 0.0;
        for &(s, l) in &self.blocks {
            pap += crate::cg::block_partial(s, l, |i| self.p[i] * self.ap[i]);
        }
        if !pap.is_finite() {
            return Err(Error::Solver(format!(
                "non-finite p·Ap ({pap}) at iteration {}",
                self.iters + 1
            )));
        }
        if pap <= 0.0 {
            return Err(Error::Solver(format!(
                "matrix not positive definite (pAp={pap})"
            )));
        }
        let alpha = self.rz / pap;
        let pc = self.pc.as_ref().expect("preconditioner built in prepare");
        let mut rz_new = 0.0;
        let mut rr_new = 0.0;
        for &(s, l) in &self.blocks {
            // SAFETY: single caller thread — this solver exclusively owns
            // x/r/z, the pointers cover all n rows, and p/ap have no
            // concurrent writer; blocks partition [0, n) disjointly.
            let (prz, prr) = unsafe {
                crate::cg::classic_precond_block_pass(
                    pc,
                    s,
                    l,
                    alpha,
                    &self.p,
                    &self.ap,
                    self.x.as_mut_ptr(),
                    self.r.as_mut_ptr(),
                    self.z.as_mut_ptr(),
                )
            };
            rz_new += prz;
            rr_new += prr;
        }
        if !rz_new.is_finite() || !rr_new.is_finite() {
            return Err(Error::Solver(format!(
                "non-finite preconditioned reduction (r·z={rz_new}, r·r={rr_new}) at iteration {}",
                self.iters + 1
            )));
        }
        let beta = rz_new / self.rz;
        for i in 0..self.p.len() {
            self.p[i] = self.z[i] + beta * self.p[i];
        }
        self.rr = rr_new;
        self.rz = rz_new;
        self.iters += 1;
        Ok(true)
    }

    /// Shared engine of `advance` (`threshold == 0.0`, fixed-iteration)
    /// and `advance_until` (`threshold == tol` on the `r·r` recurrence).
    ///
    /// A solver error (not positive definite) can fire after iterations
    /// that *completed*; those iterations advanced state and `iters`, so
    /// the launch metrics (wall/invocations/host_bytes) are recorded for
    /// them **before** the error propagates — `report()` stays consistent
    /// with its own step count.
    fn advance_inner(&mut self, iters: usize, threshold: f64) -> Result<usize> {
        let t0 = std::time::Instant::now();
        let done;
        let mut failure: Option<Error> = None;
        if let Some(tenant) = self.farm_pipe.as_mut() {
            // pipelined multi-tenant path: one scheduled phase (and ONE
            // barrier reduction) per iteration on the shared farm
            // workers, same bits as the serial pipelined recurrence
            let st = self.pipe.as_mut().expect("pipelined state primed in prepare");
            let run = match tenant.run(st, threshold, iters) {
                Ok(run) => {
                    self.plane_batches += 1;
                    run
                }
                Err(e) => {
                    match &e {
                        Error::Shed(_) => self.plane_sheds += 1,
                        Error::Timeout(_) => self.plane_timeouts += 1,
                        _ => {}
                    }
                    return Err(e);
                }
            };
            self.rr = st.rr;
            self.iters += run.iters;
            self.queue_wait_seconds += run.queue_wait_seconds;
            self.recoveries += run.recoveries;
            self.replayed_epochs += run.replayed_epochs;
            self.checkpoint_bytes += run.checkpoint_bytes;
            done = run.iters;
            if let Some(msg) = run.error {
                failure = Some(Error::Solver(msg));
            }
        } else if let Some(pool) = self.pipe_pool.as_mut() {
            // pipelined resident pool: the recurrence loop runs on the
            // spawn-once workers with ONE slot-ordered barrier reduction
            // per iteration (classic CG needs two)
            let st = self.pipe.as_mut().expect("pipelined state primed in prepare");
            let run = pool.run(st, threshold, iters)?;
            self.rr = st.rr;
            self.iters += run.iters;
            done = run.iters;
            if let Some(msg) = run.error {
                failure = Some(Error::Solver(msg));
            }
        } else if self.mode == ExecMode::Pipelined {
            // serial pipelined reference recurrence — the bit-identity
            // oracle for the pooled and farm pipelined paths
            let pc = self.pc.as_ref().expect("preconditioner built in prepare");
            let st = self.pipe.as_mut().expect("pipelined state primed in prepare");
            let run = pipeline::advance_serial(&self.a, &self.blocks, pc, st, threshold, iters);
            self.rr = st.rr;
            self.iters += run.iters;
            done = run.iters;
            if let Some(msg) = run.error {
                failure = Some(Error::Solver(msg));
            }
        } else if let Some(tenant) = self.farm_session.as_mut() {
            // multi-tenant path: the command is enqueued into the shared
            // farm and the iteration loop runs resident on its workers —
            // zero spawns, same bits as the pooled/serial paths
            let run = if self.batch_iters > 0 && iters > 0 {
                // batched path: the whole schedule is one CommandGraph —
                // one enqueue-lock acquisition for all segments
                let tol = (threshold > 0.0).then_some(threshold);
                CommandGraph::schedule(iters, self.batch_iters, tol).and_then(|graph| {
                    tenant.run_graph(&mut self.x, &mut self.r, &mut self.p, self.rr, &graph)
                })
            } else {
                tenant.run(&mut self.x, &mut self.r, &mut self.p, self.rr, threshold, iters)
            };
            let run = match run {
                Ok(run) => {
                    self.plane_batches += 1;
                    run
                }
                Err(e) => {
                    match &e {
                        Error::Shed(_) => self.plane_sheds += 1,
                        Error::Timeout(_) => self.plane_timeouts += 1,
                        _ => {}
                    }
                    return Err(e);
                }
            };
            self.rr = run.rr;
            self.iters += run.iters;
            self.queue_wait_seconds += run.queue_wait_seconds;
            self.recoveries += run.recoveries;
            self.replayed_epochs += run.replayed_epochs;
            self.checkpoint_bytes += run.checkpoint_bytes;
            done = run.iters;
            if let Some(msg) = run.error {
                failure = Some(Error::Solver(msg));
            }
        } else if let Some(pool) = self.pool.as_mut() {
            // resident time loop: state rides the pool's buffers, the
            // workers iterate internally, zero spawns; preconditioned
            // runs additionally carry z and the r·z recurrence
            let run = if self.precond_spec == Preconditioner::None {
                pool.run(&mut self.x, &mut self.r, &mut self.p, self.rr, threshold, iters)?
            } else {
                pool.run_preconditioned(
                    &mut self.x,
                    &mut self.r,
                    &mut self.z,
                    &mut self.p,
                    self.rr,
                    self.rz,
                    threshold,
                    iters,
                )?
            };
            self.rr = run.rr;
            self.rz = run.rz;
            self.iters += run.iters;
            done = run.iters;
            if let Some(msg) = run.error {
                failure = Some(Error::Solver(msg));
            }
        } else {
            // serial loop with the pool's threshold semantics: stop once
            // rr <= threshold (threshold 0.0 == the rr <= 0 short-circuit)
            let mut n = 0;
            for _ in 0..iters {
                if self.rr <= threshold {
                    break;
                }
                let stepped = if self.precond_spec == Preconditioner::None {
                    self.step()
                } else {
                    self.step_precond()
                };
                match stepped {
                    Ok(true) => n += 1,
                    Ok(false) => break,
                    Err(e) => {
                        failure = Some(e);
                        break;
                    }
                }
            }
            done = n;
        }
        self.wall_seconds += t0.elapsed().as_secs_f64();
        self.invocations += match self.mode {
            ExecMode::Persistent | ExecMode::Pipelined => 1,
            _ => done as u64,
        };
        self.host_bytes += done as u64 * self.bytes_per_iter();
        match failure {
            Some(e) => Err(e),
            None => Ok(done),
        }
    }
}

impl Solver for CpuCg {
    fn prepare(&mut self) -> Result<()> {
        // shut the previous solve's pools down first (workers joined) /
        // release the previous farm tenants, so re-entry never leaks
        // resident threads or farm slots
        self.pool = None;
        self.pipe_pool = None;
        self.farm_session = None;
        self.farm_pipe = None;
        self.pipe = None;
        let pc = Arc::new(Precond::build(self.precond_spec, &self.a, &self.blocks)?);
        if self.mode == ExecMode::Pipelined {
            // the pipelined recurrence is primed serially once (two SpMVs
            // + three dots); the widened vector set lives in PipeState
            let st = PipeState::prime(&self.a, &self.b, None, &pc)?;
            self.rr = st.rr;
            self.rz = 0.0;
            self.pipe = Some(st);
            // row-partitioned SpMV inside the fused pass — no merge plan
            self.plan_searches = 0;
            if let Some(farm) = &self.farm {
                if self.batch_iters > 0 {
                    return Err(Error::invalid(
                        "batched command graphs are not supported for pipelined CG \
                         farm sessions",
                    ));
                }
                let mut tenant =
                    farm.admit_cg_pipelined(self.a.clone(), self.parts, self.precond_spec)?;
                if self.resilience.enabled() {
                    // FarmCgPipe rejects resilience; surface that here
                    // instead of silently dropping the supervision config
                    tenant.configure_resilience(self.resilience.clone())?;
                }
                self.farm_pipe = Some(tenant);
            } else if self.threaded {
                self.pipe_pool = Some(PipePool::spawn(
                    self.a.clone(),
                    pc.clone(),
                    self.parts,
                    self.threads,
                )?);
            }
        } else {
            self.x.iter_mut().for_each(|v| *v = 0.0);
            self.r.copy_from_slice(&self.b);
            // classic PCG priming: z = M⁻¹r, p = z, rz = r·z (identity
            // preconditioner reduces to the classic r=p=b, rz=rr start)
            pc.apply(&self.r, &mut self.z);
            self.p.copy_from_slice(&self.z);
            self.rr = self.b.iter().map(|v| v * v).sum();
            self.rz = self.r.iter().zip(&self.z).map(|(a, b)| a * b).sum();
            if self.mode == ExecMode::Persistent {
                // the paper's TB-level "workload" cache: searched exactly once
                self.plan = MergePlan::new(&self.a, self.parts);
                self.plan_searches = 1;
                if let Some(farm) = &self.farm {
                    if !pc.is_identity() {
                        return Err(Error::invalid(
                            "preconditioned CG on the farm requires the pipelined \
                             execution model (CgSessionBuilder::pipelined): the \
                             classic farm path has no preconditioner plumbing",
                        ));
                    }
                    // multi-tenant admission: resident vectors registered on
                    // the farm's spawn-once workers — zero thread spawns
                    let mut tenant = farm.admit_cg(self.a.clone(), self.plan.clone())?;
                    if self.resilience.enabled() {
                        tenant.configure_resilience(self.resilience.clone())?;
                    }
                    self.farm_session = Some(tenant);
                } else if self.threaded {
                    // spawn-once worker pool: the only thread creation of the
                    // whole solve; every subsequent `advance` is spawn-free
                    self.pool = Some(CgPool::spawn_preconditioned(
                        self.a.clone(),
                        self.plan.clone(),
                        self.threads,
                        pc.clone(),
                    )?);
                }
            } else {
                self.plan_searches = 0;
            }
        }
        self.pc = Some(pc);
        self.iters = 0;
        self.wall_seconds = 0.0;
        self.invocations = 0;
        self.host_bytes = 0;
        self.queue_wait_seconds = 0.0;
        self.plane_batches = 0;
        self.plane_sheds = 0;
        self.plane_timeouts = 0;
        self.recoveries = 0;
        self.replayed_epochs = 0;
        self.checkpoint_bytes = 0;
        Ok(())
    }

    fn advance(&mut self, iters: usize) -> Result<()> {
        self.advance_inner(iters, 0.0).map(|_| ())
    }

    fn advance_until(&mut self, tol: f64, max_steps: usize) -> Result<usize> {
        self.advance_inner(max_steps, tol)
    }

    fn report(&self) -> Report {
        let mut rep = Report::new(
            self.mode,
            self.iters,
            self.wall_seconds,
            self.invocations,
            self.host_bytes,
            self.iters as f64,
            "iters/s",
            Some(self.rr),
            self.pool
                .as_ref()
                .map(|p| p.barrier_wait_seconds())
                .or_else(|| self.pipe_pool.as_ref().map(|p| p.barrier_wait_seconds())),
        );
        if self.farm.is_some() {
            rep.queue_wait_seconds = Some(self.queue_wait_seconds);
            rep.plane_batches = Some(self.plane_batches);
            rep.plane_sheds = Some(self.plane_sheds);
            rep.plane_timeouts = Some(self.plane_timeouts);
            rep.recoveries = Some(self.recoveries);
            rep.replayed_epochs = Some(self.replayed_epochs);
            rep.checkpoint_bytes = Some(self.checkpoint_bytes);
        }
        rep
    }

    fn state_f64(&self) -> Result<Vec<f64>> {
        // pipelined iterates live in the PipeState, not the classic x
        Ok(match &self.pipe {
            Some(st) => st.x.clone(),
            None => self.x.clone(),
        })
    }

    fn true_residual(&self) -> Result<Option<f64>> {
        let x = self.pipe.as_ref().map(|st| st.x.as_slice()).unwrap_or(&self.x);
        let mut ax = vec![0.0; self.a.n_rows];
        self.a.spmv_gold(x, &mut ax);
        Ok(Some(
            self.b
                .iter()
                .zip(&ax)
                .map(|(bi, ai)| (bi - ai) * (bi - ai))
                .sum(),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cg::{solve_persistent, CgOptions};
    use crate::stencil::gold;

    #[test]
    fn cpu_cg_matches_the_batch_solver_iterates() {
        let a = gen::poisson2d(16);
        let b = gen::rhs(a.n_rows, 4);
        let mut s =
            CpuCg::system(a.clone(), b.clone(), 8, 1, false, ExecMode::Persistent).unwrap();
        s.prepare().unwrap();
        s.advance(12).unwrap();
        s.advance(12).unwrap(); // resumable: 12 + 12 == one 24-iteration solve
        let opts = CgOptions { max_iters: 24, tol: 0.0, ..Default::default() };
        let want = solve_persistent(&a, &b, &opts).unwrap();
        let got = s.state_f64().unwrap();
        let diff = got
            .iter()
            .zip(&want.x)
            .map(|(g, w)| (g - w).abs())
            .fold(0.0, f64::max);
        assert!(diff < 1e-12, "session CG diverged from batch solver by {diff}");
        assert_eq!(s.report().steps, 24);
        assert_eq!(s.report().invocations, 2); // one launch per advance
    }

    #[test]
    fn cpu_cg_modes_walk_identical_iterates() {
        let a = gen::poisson2d(12);
        let b = gen::rhs(a.n_rows, 9);
        let mut h =
            CpuCg::system(a.clone(), b.clone(), 8, 1, false, ExecMode::HostLoop).unwrap();
        let mut p = CpuCg::system(a, b, 8, 1, false, ExecMode::Persistent).unwrap();
        h.prepare().unwrap();
        p.prepare().unwrap();
        h.advance(20).unwrap();
        p.advance(20).unwrap();
        assert_eq!(h.state_f64().unwrap(), p.state_f64().unwrap());
        assert!(h.plan_searches > p.plan_searches);
        assert!(h.report().host_bytes > p.report().host_bytes);
    }

    /// The pooled-CG guarantee: the runtime walks the serial path's
    /// iterates bit-for-bit at every worker count, including across
    /// resumed `advance` calls.
    #[test]
    fn pooled_cg_is_bit_identical_to_serial_across_threads_and_resume() {
        let a = gen::poisson2d(20);
        let b = gen::rhs(a.n_rows, 3);
        let mut serial =
            CpuCg::system(a.clone(), b.clone(), 8, 1, false, ExecMode::Persistent).unwrap();
        serial.prepare().unwrap();
        serial.advance(9).unwrap();
        serial.advance(7).unwrap();
        let want = serial.state_f64().unwrap();
        let want_rr = serial.rr;
        for threads in [1, 2, 3, 8] {
            let mut pooled =
                CpuCg::system(a.clone(), b.clone(), 8, threads, true, ExecMode::Persistent)
                    .unwrap();
            pooled.prepare().unwrap();
            pooled.advance(9).unwrap();
            pooled.advance(7).unwrap();
            assert_eq!(pooled.state_f64().unwrap(), want, "threads={threads}");
            assert_eq!(pooled.rr.to_bits(), want_rr.to_bits(), "threads={threads}");
            assert_eq!(pooled.report().steps, 16);
            assert_eq!(pooled.report().invocations, 2);
        }
    }

    /// Acceptance criterion: persistent threaded CG performs **zero**
    /// thread spawns per `advance` once the pool is up; the host-loop
    /// threaded baseline respawns workers every iteration.
    #[test]
    fn pooled_advance_never_spawns_host_loop_always_does() {
        let a = gen::poisson2d(16);
        let b = gen::rhs(a.n_rows, 5);
        let mut pooled =
            CpuCg::system(a.clone(), b.clone(), 8, 4, true, ExecMode::Persistent).unwrap();
        pooled.prepare().unwrap(); // the pool's one spawn batch
        let spawned = pooled.pool_spawns().expect("threaded persistent CG rides the pool");
        assert!(spawned >= 1);
        pooled.advance(10).unwrap();
        pooled.advance(10).unwrap();
        assert_eq!(
            pooled.pool_spawns().unwrap(),
            spawned,
            "advance must not spawn threads after pool start"
        );

        // the baseline pays spawn-per-iteration (global counter only ever
        // grows, so a positive delta cannot be a concurrency artifact)
        let mut host =
            CpuCg::system(a, b, 8, 4, true, ExecMode::HostLoop).unwrap();
        host.prepare().unwrap();
        assert!(host.pool_spawns().is_none(), "host-loop has no pool");
        let before = crate::util::counters::thread_spawns();
        host.advance(5).unwrap();
        assert!(
            crate::util::counters::thread_spawns() >= before + 5 * 4,
            "5 threaded host-loop iterations respawn 4 workers each"
        );
    }

    /// `prepare()` re-entry tears the old pool down (workers joined) and
    /// spawns a fresh one; the restarted solve matches a fresh serial run.
    #[test]
    fn prepare_reentry_replaces_the_pool_cleanly() {
        let a = gen::poisson2d(14);
        let b = gen::rhs(a.n_rows, 8);
        let mut pooled =
            CpuCg::system(a.clone(), b.clone(), 8, 3, true, ExecMode::Persistent).unwrap();
        pooled.prepare().unwrap();
        pooled.advance(5).unwrap();
        pooled.prepare().unwrap(); // old pool joined here, new pool spawned
        pooled.advance(12).unwrap();
        let mut serial =
            CpuCg::system(a, b, 8, 1, false, ExecMode::Persistent).unwrap();
        serial.prepare().unwrap();
        serial.advance(12).unwrap();
        assert_eq!(pooled.state_f64().unwrap(), serial.state_f64().unwrap());
        assert_eq!(pooled.report().steps, 12, "metrics reset on re-entry");
    }

    /// Satellite regression: a solver error after completed iterations
    /// (here: iteration 2 hits pAp < 0 after iteration 1 succeeded) must
    /// still record wall/invocations/host_bytes for the iterations that
    /// ran — `report()` stays consistent with its own step count.
    #[test]
    fn cg_error_path_still_records_completed_iteration_metrics() {
        // D = diag(2, -1), b = (1, 1): iteration 1 has pAp = 1 > 0 and
        // completes; iteration 2 has pAp = -72 and fails.
        let a = Csr::from_coo(2, 2, vec![(0, 0, 2.0), (1, 1, -1.0)]).unwrap();
        let b = vec![1.0, 1.0];
        for (threads, threaded) in [(1usize, false), (2usize, true)] {
            let mut s = CpuCg::system(a.clone(), b.clone(), 2, threads, threaded,
                ExecMode::Persistent)
                .unwrap();
            s.prepare().unwrap();
            let err = s.advance(10).unwrap_err();
            assert!(
                format!("{err}").contains("positive definite"),
                "threaded={threaded}: {err}"
            );
            let rep = s.report();
            assert_eq!(rep.steps, 1, "threaded={threaded}: one completed iteration");
            assert_eq!(rep.invocations, 1, "threaded={threaded}: the launch happened");
            assert_eq!(
                rep.host_bytes,
                s.bytes_per_iter(),
                "threaded={threaded}: traffic recorded for the completed iteration"
            );
            assert!(rep.wall_seconds > 0.0, "threaded={threaded}: wall recorded");
        }
    }

    /// Satellite regression: an empty system is rejected up front instead
    /// of building a solver over zero reduction blocks.
    #[test]
    fn cpu_cg_rejects_empty_system() {
        let a = Csr::from_coo(0, 0, Vec::new()).unwrap();
        let err = CpuCg::system(a, Vec::new(), 8, 1, false, ExecMode::Persistent).unwrap_err();
        assert!(format!("{err}").contains("no rows"), "{err}");
    }

    #[test]
    fn cg_advance_until_stops_on_the_recurrence_threshold() {
        let a = gen::poisson2d(12);
        let b = gen::rhs(a.n_rows, 6);
        let rr0: f64 = b.iter().map(|v| v * v).sum();
        let tol = 1e-10 * rr0;
        let mut serial =
            CpuCg::system(a.clone(), b.clone(), 8, 1, false, ExecMode::Persistent).unwrap();
        serial.prepare().unwrap();
        let iters = serial.advance_until(tol, 10_000).unwrap();
        assert!(iters < 10_000, "converged early");
        assert!(serial.rr <= tol);
        assert_eq!(serial.report().steps, iters);
        // the pooled path stops on the same iterate (same recurrence bits)
        let mut pooled =
            CpuCg::system(a, b, 8, 3, true, ExecMode::Persistent).unwrap();
        pooled.prepare().unwrap();
        let pooled_iters = pooled.advance_until(tol, 10_000).unwrap();
        assert_eq!(pooled_iters, iters);
        assert_eq!(pooled.rr.to_bits(), serial.rr.to_bits());
        assert_eq!(pooled.state_f64().unwrap(), serial.state_f64().unwrap());
    }

    // -----------------------------------------------------------------
    // Preconditioned classic CG and pipelined CG through the solver seam
    // -----------------------------------------------------------------

    /// Tentpole acceptance: the pipelined solver walks the serial
    /// pipelined recurrence bit-for-bit at workers {1, 2, 3, 8} and
    /// across resumed advances, for every preconditioner, and the
    /// threaded path pays exactly ONE barrier reduction per iteration.
    #[test]
    fn pipelined_cg_is_bit_identical_across_threads_resume_and_preconditioners() {
        let a = gen::poisson2d(14);
        let b = gen::rhs(a.n_rows, 5);
        for spec in [
            Preconditioner::None,
            Preconditioner::Jacobi,
            Preconditioner::BlockJacobi { block: 5 },
        ] {
            // oracle: the raw serial recurrence, one uninterrupted run
            let blocks = parallel::partition(a.n_rows, 6);
            let pc = Precond::build(spec, &a, &blocks).unwrap();
            let mut want = PipeState::prime(&a, &b, None, &pc).unwrap();
            let run = pipeline::advance_serial(&a, &blocks, &pc, &mut want, 0.0, 18);
            assert_eq!(run.iters, 18, "spec={spec:?}");
            for threads in [1usize, 2, 3, 8] {
                let mut s = CpuCg::system(
                    a.clone(),
                    b.clone(),
                    6,
                    threads,
                    threads > 1,
                    ExecMode::Pipelined,
                )
                .unwrap()
                .with_preconditioner(spec);
                s.prepare().unwrap();
                s.advance(7).unwrap();
                s.advance(11).unwrap();
                assert_eq!(
                    s.state_f64().unwrap(),
                    want.x,
                    "spec={spec:?} threads={threads}"
                );
                assert_eq!(
                    s.rr.to_bits(),
                    want.rr.to_bits(),
                    "spec={spec:?} threads={threads}"
                );
                let rep = s.report();
                assert_eq!(rep.steps, 18);
                assert_eq!(rep.invocations, 2, "one resident launch per advance");
                if let Some(pool) = &s.pipe_pool {
                    assert_eq!(
                        pool.barrier_reduction_generations(),
                        18,
                        "spec={spec:?} threads={threads}: ONE reduction per iteration"
                    );
                }
            }
        }
    }

    /// Classic PCG: the serial `step_precond` path and the widened-slot
    /// pool walk identical bits at every worker count, across resumes;
    /// the pool pays exactly TWO barrier reductions per iteration.
    #[test]
    fn preconditioned_classic_cg_is_bit_identical_serial_vs_pool() {
        let a = gen::poisson2d(12);
        let b = gen::rhs(a.n_rows, 7);
        for spec in [Preconditioner::Jacobi, Preconditioner::BlockJacobi { block: 4 }] {
            let mut serial =
                CpuCg::system(a.clone(), b.clone(), 8, 1, false, ExecMode::Persistent)
                    .unwrap()
                    .with_preconditioner(spec);
            serial.prepare().unwrap();
            serial.advance(9).unwrap();
            serial.advance(6).unwrap();
            let want = serial.state_f64().unwrap();
            for threads in [2usize, 3, 8] {
                let mut pooled =
                    CpuCg::system(a.clone(), b.clone(), 8, threads, true, ExecMode::Persistent)
                        .unwrap()
                        .with_preconditioner(spec);
                pooled.prepare().unwrap();
                pooled.advance(9).unwrap();
                pooled.advance(6).unwrap();
                assert_eq!(
                    pooled.state_f64().unwrap(),
                    want,
                    "spec={spec:?} threads={threads}"
                );
                assert_eq!(pooled.rr.to_bits(), serial.rr.to_bits(), "spec={spec:?}");
                assert_eq!(pooled.rz.to_bits(), serial.rz.to_bits(), "spec={spec:?}");
                let pool = pooled.pool.as_ref().expect("threaded persistent rides the pool");
                assert_eq!(
                    pool.barrier_reduction_generations(),
                    2 * 15,
                    "spec={spec:?} threads={threads}: TWO reductions per iteration"
                );
            }
        }
    }

    /// Preconditioning must *do* something: on an ill-conditioned system
    /// Jacobi reaches the tolerance in strictly fewer iterations than
    /// identity, and pipelined agrees with classic on the iterate.
    #[test]
    fn preconditioning_cuts_iterations_on_an_ill_conditioned_system() {
        let a = gen::ill_conditioned(220, 1e6, 11).unwrap();
        let b = gen::rhs(a.n_rows, 3);
        let rr0: f64 = b.iter().map(|v| v * v).sum();
        let tol = 1e-9 * rr0;
        let mut run = |spec: Preconditioner, mode: ExecMode| {
            let mut s = CpuCg::system(a.clone(), b.clone(), 8, 1, false, mode)
                .unwrap()
                .with_preconditioner(spec);
            s.prepare().unwrap();
            let iters = s.advance_until(tol, 50_000).unwrap();
            assert!(iters < 50_000, "spec={spec:?} mode={mode:?} did not converge");
            (iters, s.true_residual().unwrap().unwrap())
        };
        let (plain, _) = run(Preconditioner::None, ExecMode::Persistent);
        let (jacobi, _) = run(Preconditioner::Jacobi, ExecMode::Persistent);
        assert!(
            jacobi < plain,
            "Jacobi must cut iterations on an ill-conditioned diagonal ({jacobi} vs {plain})"
        );
        let (pipe_jacobi, res) = run(Preconditioner::Jacobi, ExecMode::Pipelined);
        // same Krylov space, different recurrence roundoff: allow slack
        assert!(
            pipe_jacobi <= plain,
            "pipelined Jacobi must also beat plain classic ({pipe_jacobi} vs {plain})"
        );
        assert!(res.is_finite());
    }

    /// Pipelined `advance_until` stops on the recurrence threshold with
    /// the same iterate serial vs pooled, and the error path (a
    /// not-positive-definite system) surfaces through the pipelined
    /// solver while still recording the completed-iteration metrics.
    #[test]
    fn pipelined_advance_until_and_error_paths() {
        let a = gen::poisson2d(12);
        let b = gen::rhs(a.n_rows, 6);
        let rr0: f64 = b.iter().map(|v| v * v).sum();
        let tol = 1e-10 * rr0;
        let mut serial = CpuCg::system(a.clone(), b.clone(), 8, 1, false, ExecMode::Pipelined)
            .unwrap()
            .with_preconditioner(Preconditioner::Jacobi);
        serial.prepare().unwrap();
        let iters = serial.advance_until(tol, 10_000).unwrap();
        assert!(iters > 0 && iters < 10_000, "converged early ({iters})");
        assert!(serial.rr <= tol);
        let mut pooled = CpuCg::system(a, b, 8, 3, true, ExecMode::Pipelined)
            .unwrap()
            .with_preconditioner(Preconditioner::Jacobi);
        pooled.prepare().unwrap();
        let pooled_iters = pooled.advance_until(tol, 10_000).unwrap();
        assert_eq!(pooled_iters, iters);
        assert_eq!(pooled.rr.to_bits(), serial.rr.to_bits());
        assert_eq!(pooled.state_f64().unwrap(), serial.state_f64().unwrap());

        // indefinite system: the pipelined recurrence fails cleanly
        let bad = Csr::from_coo(2, 2, vec![(0, 0, 2.0), (1, 1, -1.0)]).unwrap();
        let mut s = CpuCg::system(bad, vec![1.0, 1.0], 2, 1, false, ExecMode::Pipelined).unwrap();
        s.prepare().unwrap();
        let err = s.advance(10).unwrap_err();
        assert!(format!("{err}").contains("positive definite"), "{err}");
        let rep = s.report();
        assert_eq!(rep.invocations, 1, "the launch happened");
        assert!(rep.wall_seconds > 0.0);
    }

    /// Pipelined streams fewer global bytes per iteration than the
    /// host-loop path and accounts the preconditioner's extra row-local
    /// passes; identity persistent stays exactly the fused two passes.
    #[test]
    fn cg_bytes_per_iter_accounts_mode_and_preconditioner() {
        let a = gen::poisson2d(10);
        let b = gen::rhs(a.n_rows, 2);
        let n = a.n_rows as u64;
        let mk = |mode: ExecMode, spec: Preconditioner| {
            CpuCg::system(a.clone(), b.clone(), 4, 1, false, mode)
                .unwrap()
                .with_preconditioner(spec)
        };
        let persistent = mk(ExecMode::Persistent, Preconditioner::None).bytes_per_iter();
        let pipelined = mk(ExecMode::Pipelined, Preconditioner::None).bytes_per_iter();
        let host = mk(ExecMode::HostLoop, Preconditioner::None).bytes_per_iter();
        assert!(persistent < pipelined && pipelined < host);
        assert_eq!(pipelined - persistent, n * 8, "3 passes vs 2");
        assert_eq!(
            mk(ExecMode::Pipelined, Preconditioner::Jacobi).bytes_per_iter() - pipelined,
            n * 8,
            "Jacobi adds one row-local pass"
        );
        assert_eq!(
            mk(ExecMode::Pipelined, Preconditioner::BlockJacobi { block: 4 }).bytes_per_iter()
                - pipelined,
            2 * n * 8,
            "block-Jacobi adds two row-local passes"
        );
    }

    // -----------------------------------------------------------------
    // CpuStencil on the spawn-once pool
    // -----------------------------------------------------------------

    /// Acceptance criterion (the stencil mirror of
    /// `pooled_advance_never_spawns_host_loop_always_does`): persistent
    /// stencil `advance` performs **zero** thread spawns after `prepare`;
    /// the host-loop baseline respawns its threads every step.
    #[test]
    fn pooled_stencil_advance_never_spawns() {
        let mut s = CpuStencil::new(
            "2d5pt",
            &[16, 16],
            &StencilOptions::new(4, ExecMode::Persistent, 1),
            None,
        )
        .unwrap();
        s.prepare().unwrap(); // the pool's one spawn batch
        let spawned = s.pool_spawns().expect("persistent stencil rides the pool");
        assert!(spawned >= 1);
        s.advance(5).unwrap();
        s.advance(7).unwrap();
        assert_eq!(
            s.pool_spawns().unwrap(),
            spawned,
            "advance must not spawn threads after pool start"
        );

        // the baseline pays spawn-per-step (global counter only ever
        // grows, so a positive delta cannot be a concurrency artifact)
        let mut h = CpuStencil::new(
            "2d5pt",
            &[16, 16],
            &StencilOptions::new(4, ExecMode::HostLoop, 1),
            None,
        )
        .unwrap();
        h.prepare().unwrap();
        assert!(h.pool_spawns().is_none(), "host-loop has no pool");
        let before = crate::util::counters::thread_spawns();
        h.advance(5).unwrap();
        assert!(
            crate::util::counters::thread_spawns() >= before + 5 * 4,
            "5 host-loop steps respawn 4 workers each"
        );
    }

    /// Acceptance criterion: pooled stencil results are bit-identical to
    /// `gold::run` and to the one-shot persistent path at every tested
    /// thread count, including across resumed advances.
    #[test]
    fn pooled_stencil_is_bit_identical_to_one_shot_across_threads_and_resume() {
        let seed = 77;
        let spec = stencil::spec("2d9pt").unwrap();
        let mut dom = Domain::for_spec(&spec, &[18, 18]).unwrap();
        dom.randomize(seed);
        let want = gold::run(&spec, &dom, 7).unwrap();
        for threads in [1usize, 2, 3, 8] {
            let one_shot = parallel::persistent(&spec, &dom, 7, threads).unwrap();
            assert_eq!(one_shot.result.data, want.data, "threads={threads}: one-shot vs gold");
            let mut s = CpuStencil::new(
                "2d9pt",
                &[18, 18],
                &StencilOptions::new(threads, ExecMode::Persistent, seed),
                Some(&dom.data),
            )
            .unwrap();
            s.prepare().unwrap();
            s.advance(3).unwrap();
            s.advance(4).unwrap();
            let got = s.state_f64().unwrap();
            assert_eq!(got, want.data, "threads={threads}: pooled vs gold");
            assert_eq!(got, one_shot.result.data, "threads={threads}: pooled vs one-shot");
            assert_eq!(s.report().steps, 7);
            assert_eq!(s.report().invocations, 2, "one resident launch per advance");
        }
    }

    /// Convergence path: the pooled in-loop residual and the host-loop
    /// host-side norm share one arithmetic, so both modes stop on the
    /// same step with the same bits.
    #[test]
    fn stencil_advance_until_agrees_across_modes() {
        let seed = 21;
        let (tol, max) = (1e-8, 20_000);
        let mut pooled = CpuStencil::new(
            "2d5pt",
            &[8, 8],
            &StencilOptions::new(2, ExecMode::Persistent, seed),
            None,
        )
        .unwrap();
        pooled.prepare().unwrap();
        let steps_p = pooled.advance_until(tol, max).unwrap();
        assert!(steps_p > 0 && steps_p < max, "pooled did not converge ({steps_p})");
        let rep = pooled.report();
        let res_p = rep.residual.expect("convergence-driven advance reports a residual");
        assert!(res_p <= tol);
        assert_eq!(rep.steps, steps_p);
        assert_eq!(rep.invocations, 1, "one resident launch for the whole search");

        let mut host = CpuStencil::new(
            "2d5pt",
            &[8, 8],
            &StencilOptions::new(2, ExecMode::HostLoop, seed),
            None,
        )
        .unwrap();
        host.prepare().unwrap();
        let steps_h = host.advance_until(tol, max).unwrap();
        assert_eq!(steps_h, steps_p, "both modes stop on the same step");
        let res_h = host.report().residual.unwrap();
        assert_eq!(res_h.to_bits(), res_p.to_bits(), "identical residual bits");
        assert_eq!(host.state_f64().unwrap(), pooled.state_f64().unwrap());
    }

    /// The temporal composition through the solver seam: `bt ∈ {2, 4}`
    /// walks gold's bits across resumed advances, reports one launch per
    /// advance, and surfaces the overlap redundancy in the report.
    #[test]
    fn temporal_stencil_solver_is_bit_identical_and_reports_redundancy() {
        let seed = 23;
        let spec = stencil::spec("2d5pt").unwrap();
        let mut dom = Domain::for_spec(&spec, &[16, 16]).unwrap();
        dom.randomize(seed);
        let want = gold::run(&spec, &dom, 11).unwrap();
        for bt in [2usize, 4] {
            let mut s = CpuStencil::new(
                "2d5pt",
                &[16, 16],
                &StencilOptions::new(3, ExecMode::Persistent, seed).temporal(bt),
                None,
            )
            .unwrap();
            s.prepare().unwrap();
            s.advance(5).unwrap(); // partial epochs at bt = 4
            s.advance(6).unwrap();
            assert_eq!(s.state_f64().unwrap(), want.data, "bt={bt}");
            let rep = s.report();
            assert_eq!(rep.steps, 11);
            assert_eq!(rep.invocations, 2, "one resident launch per advance");
            let red = rep.redundancy.expect("cpu stencil reports redundancy");
            assert!(red > 1.0, "bt={bt}: overlap work must show up ({red})");
        }
        // bt = 1 (and host-loop) report exactly 1.0 — no overlap work
        let mut base = CpuStencil::new(
            "2d5pt",
            &[16, 16],
            &StencilOptions::new(3, ExecMode::Persistent, seed),
            None,
        )
        .unwrap();
        base.prepare().unwrap();
        base.advance(11).unwrap();
        assert_eq!(base.report().redundancy, Some(1.0));
    }

    /// `advance_until` with `bt > 1` stops at epoch granularity, on the
    /// same epoch at every thread count, with identical residual bits.
    #[test]
    fn temporal_advance_until_stops_on_the_same_epoch_at_every_thread_count() {
        let seed = 21;
        let (bt, tol, max) = (2usize, 1e-8, 20_000usize);
        let mut reference: Option<(usize, u64, Vec<f64>)> = None;
        for threads in [1usize, 2, 3, 8] {
            let mut s = CpuStencil::new(
                "2d5pt",
                &[8, 8],
                &StencilOptions::new(threads, ExecMode::Persistent, seed).temporal(bt),
                None,
            )
            .unwrap();
            s.prepare().unwrap();
            let steps = s.advance_until(tol, max).unwrap();
            assert!(steps > 0 && steps < max, "threads={threads}: no convergence");
            assert_eq!(steps % bt, 0, "threads={threads}: stop is epoch-aligned");
            let res = s.report().residual.unwrap();
            assert!(res <= tol, "threads={threads}");
            let state = s.state_f64().unwrap();
            match &reference {
                None => reference = Some((steps, res.to_bits(), state)),
                Some((want_steps, bits, want)) => {
                    assert_eq!(steps, *want_steps, "threads={threads}: stop epoch");
                    assert_eq!(res.to_bits(), *bits, "threads={threads}: residual bits");
                    assert_eq!(&state, want, "threads={threads}: state bits");
                }
            }
        }
    }

    /// Invalid temporal degrees are rejected at construction: 0 always,
    /// and `bt > 1` outside the persistent model.
    #[test]
    fn stencil_options_reject_bad_temporal_degrees() {
        let err = CpuStencil::new(
            "2d5pt",
            &[8, 8],
            &StencilOptions::new(2, ExecMode::Persistent, 1).temporal(0),
            None,
        )
        .unwrap_err();
        assert!(format!("{err}").contains(">= 1"), "{err}");
        let err = CpuStencil::new(
            "2d5pt",
            &[8, 8],
            &StencilOptions::new(2, ExecMode::HostLoop, 1).temporal(2),
            None,
        )
        .unwrap_err();
        assert!(format!("{err}").contains("persistent"), "{err}");
    }

    /// Pipelined is a CG-only execution model: stencil construction
    /// rejects it up front.
    #[test]
    fn stencil_rejects_the_pipelined_model() {
        let err = CpuStencil::new(
            "2d5pt",
            &[8, 8],
            &StencilOptions::new(2, ExecMode::Pipelined, 1),
            None,
        )
        .unwrap_err();
        assert!(format!("{err}").contains("CG-only"), "{err}");
    }

    /// `prepare()` re-entry replaces the stencil pool cleanly (old
    /// workers joined, state and metrics reset).
    #[test]
    fn stencil_prepare_reentry_replaces_the_pool_cleanly() {
        let mut s = CpuStencil::new(
            "2d5pt",
            &[12, 12],
            &StencilOptions::new(3, ExecMode::Persistent, 4),
            None,
        )
        .unwrap();
        s.prepare().unwrap();
        s.advance(6).unwrap();
        s.prepare().unwrap(); // old pool joined here, new pool spawned
        s.advance(2).unwrap();
        let spec = stencil::spec("2d5pt").unwrap();
        let mut dom = Domain::for_spec(&spec, &[12, 12]).unwrap();
        dom.randomize(4);
        let want = gold::run(&spec, &dom, 2).unwrap();
        assert_eq!(s.state_f64().unwrap(), want.data, "restart runs from x0");
        assert_eq!(s.report().steps, 2, "metrics reset on re-entry");
    }
}
