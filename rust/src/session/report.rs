//! The unified run report shared by every backend and workload.

use crate::coordinator::executor::ExecMode;
use crate::util::stats::finite_rate;

/// Metrics accumulated by a [`crate::session::Solver`] since its last
/// `prepare()`. Subsumes the legacy `RunReport` (stencil) and `CgReport`:
/// one shape for every backend, with workload-specific fields optional.
#[derive(Clone, Debug)]
pub struct Report {
    /// Execution model the solver ran under.
    pub mode: ExecMode,
    /// Time steps (stencil) or iterations (CG) advanced.
    pub steps: usize,
    /// Wall-clock seconds — measured for the PJRT/CPU backends, modeled
    /// for the simulated backend.
    pub wall_seconds: f64,
    /// Executable/kernel launches (CPU persistent counts one per
    /// `advance`, matching the single-launch PERKS model).
    pub invocations: u64,
    /// Bytes moved through the slow tier: host<->device marshalling for
    /// PJRT, shared-array ("global") traffic for the CPU substrate,
    /// modeled host-link traffic for the simulator.
    pub host_bytes: u64,
    /// Figure of merit: cell updates/s (stencil) or iterations/s (CG).
    /// Always finite — the wall time is clamped to a measurable epsilon.
    pub fom: f64,
    /// Unit of `fom`, for display.
    pub fom_unit: &'static str,
    /// Final squared-residual recurrence value (CG workloads only).
    pub residual: Option<f64>,
    /// Time spent in grid-sync barriers, where the substrate exposes it
    /// (CPU persistent threads; modeled for the simulator).
    pub barrier_wait_seconds: Option<f64>,
    /// Redundant-compute ratio of overlapped temporal blocking
    /// (`computed cells / useful cells`, >= 1.0), where the substrate
    /// measures it (CPU stencil; 1.0 means no overlap work, `None` means
    /// the backend does not track it).
    pub redundancy: Option<f64>,
    /// Total time this solver's commands waited in a shared
    /// [`crate::runtime::farm::SolverFarm`] submission queue before their
    /// first shard was dispatched (farm-backed sessions only; `None` on
    /// solo substrates). Per-session queue latency — the farm-level
    /// p50/p99/fairness view lives in
    /// [`crate::runtime::farm::FarmMetrics`].
    pub queue_wait_seconds: Option<f64>,
    /// Submission-plane batches this session enqueued (one per farm
    /// command; with `SessionBuilder::batch_epochs` an entire
    /// `advance_until` schedule is one batch). Farm-backed sessions only;
    /// `None` on solo substrates.
    pub plane_batches: Option<u64>,
    /// Submissions of this session rejected by the farm's admission
    /// control (`Shed` policy / over-cap batches). Farm-backed only.
    pub plane_sheds: Option<u64>,
    /// Submissions of this session that timed out waiting for a plane
    /// slot (`Timeout` admission policy). Farm-backed only.
    pub plane_timeouts: Option<u64>,
    /// Supervised recoveries this session's commands went through
    /// (checkpoint-restore replays under
    /// `runtime::resilience::RetryPolicy`). Farm-backed sessions only;
    /// `None` on solo substrates. Clean runs report `Some(0)`.
    pub recoveries: Option<u64>,
    /// Epochs/iterations re-executed by those recovery replays (the
    /// work between the restored checkpoint and the failure point).
    /// Farm-backed only.
    pub replayed_epochs: Option<u64>,
    /// Bytes copied into resident-state checkpoints on behalf of this
    /// session (cadence + command-entry snapshots). Farm-backed only.
    pub checkpoint_bytes: Option<u64>,
}

impl Report {
    /// Build a report computing the FOM from `work_units` (total cell
    /// updates or iterations) over `wall_seconds`, clamped to finite.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn new(
        mode: ExecMode,
        steps: usize,
        wall_seconds: f64,
        invocations: u64,
        host_bytes: u64,
        work_units: f64,
        fom_unit: &'static str,
        residual: Option<f64>,
        barrier_wait_seconds: Option<f64>,
    ) -> Self {
        Report {
            mode,
            steps,
            wall_seconds,
            invocations,
            host_bytes,
            fom: finite_rate(work_units, wall_seconds),
            fom_unit,
            residual,
            barrier_wait_seconds,
            redundancy: None,
            queue_wait_seconds: None,
            plane_batches: None,
            plane_sheds: None,
            plane_timeouts: None,
            recoveries: None,
            replayed_epochs: None,
            checkpoint_bytes: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fom_is_finite_even_for_zero_wall() {
        let r = Report::new(
            ExecMode::Persistent,
            64,
            0.0,
            1,
            0,
            64.0 * 16384.0,
            "cells/s",
            None,
            None,
        );
        assert!(r.fom.is_finite());
        assert!(r.fom > 0.0);
    }
}
