//! PJRT-backed solvers: the AOT-artifact execution path behind
//! `Backend::Pjrt`, wrapping the coordinator drivers with persistent
//! state so `advance` can be called repeatedly (device state chains
//! between calls exactly as the drivers chain it between launches).

use crate::coordinator::executor::{CgDriver, ExecMode, StencilDriver};
use crate::error::{Error, Result};
use crate::runtime::{HostTensor, Runtime};
use crate::session::{Report, Solver};
use crate::sparse::csr::Csr;
use crate::sparse::gen;
use crate::stencil;

/// Iterative stencil through the AOT HLO artifacts.
pub struct PjrtStencil {
    driver: StencilDriver,
    mode: ExecMode,
    x0: HostTensor,
    interior_cells: usize,
    state: Option<HostTensor>,
    steps: usize,
    wall_seconds: f64,
    invocations: u64,
    host_bytes: u64,
}

impl PjrtStencil {
    pub(crate) fn new(
        rt: &Runtime,
        bench: &str,
        interior: &str,
        dtype: &str,
        mode: ExecMode,
        seed: u64,
        init: Option<&[f64]>,
    ) -> Result<Self> {
        let driver = StencilDriver::from_runtime(rt, bench, interior, dtype)?;
        let spec = stencil::spec(bench)
            .ok_or_else(|| Error::invalid(format!("unknown stencil benchmark {bench:?}")))?;
        let dims = driver.interior.clone();
        let dom = crate::session::stencil_domain(&spec, &dims, seed, init)?;
        let padded: Vec<usize> = if spec.dims == 2 {
            vec![dom.padded[1], dom.padded[2]]
        } else {
            dom.padded.to_vec()
        };
        let x0 = match dtype {
            "f64" => HostTensor::f64(&padded, dom.data.clone()),
            _ => HostTensor::f32(&padded, dom.to_f32()),
        };
        Ok(Self {
            interior_cells: driver.interior_cells(),
            driver,
            mode,
            x0,
            state: None,
            steps: 0,
            wall_seconds: 0.0,
            invocations: 0,
            host_bytes: 0,
        })
    }
}

impl Solver for PjrtStencil {
    fn prepare(&mut self) -> Result<()> {
        self.state = Some(self.x0.clone());
        self.steps = 0;
        self.wall_seconds = 0.0;
        self.invocations = 0;
        self.host_bytes = 0;
        Ok(())
    }

    fn advance(&mut self, steps: usize) -> Result<()> {
        let cur = match self.state.take() {
            Some(s) => s,
            None => self.x0.clone(),
        };
        let rep = self.driver.run(self.mode, &cur, steps)?;
        self.steps += rep.steps;
        self.wall_seconds += rep.wall_seconds;
        self.invocations += rep.invocations;
        self.host_bytes += rep.host_bytes;
        self.state = rep.state.into_iter().next();
        Ok(())
    }

    fn report(&self) -> Report {
        Report::new(
            self.mode,
            self.steps,
            self.wall_seconds,
            self.invocations,
            self.host_bytes,
            self.interior_cells as f64 * self.steps as f64,
            "cells/s",
            None,
            None,
        )
    }

    fn state_f64(&self) -> Result<Vec<f64>> {
        match &self.state {
            Some(t) => t.to_f64_vec(),
            None => self.x0.to_f64_vec(),
        }
    }

    fn fused_chunk(&self) -> usize {
        match self.mode {
            ExecMode::Persistent => self.driver.fused_steps.max(1),
            _ => 1,
        }
    }
}

/// Conjugate gradient through the AOT HLO artifacts.
pub struct PjrtCg {
    driver: CgDriver,
    data: HostTensor,
    cols: HostTensor,
    rows: HostTensor,
    b: Vec<f32>,
    mode: ExecMode,
    state: Option<Vec<HostTensor>>,
    /// rr recurrence value of the current state, parsed (with errors
    /// surfaced) in `prepare`/`advance` rather than swallowed in `report`.
    last_rr: Option<f64>,
    iters: usize,
    wall_seconds: f64,
    invocations: u64,
    host_bytes: u64,
}

impl PjrtCg {
    /// The `Workload::Cg { n }` convenience: a 5-point Poisson system on a
    /// sqrt(n) x sqrt(n) grid with a deterministic rhs.
    pub(crate) fn poisson(rt: &Runtime, n: usize, mode: ExecMode, seed: u64) -> Result<Self> {
        let g = (n as f64).sqrt().round() as usize;
        let a = gen::poisson2d(g);
        let b = gen::rhs(n, seed);
        Self::system(rt, &a, &b, mode)
    }

    /// An explicit SPD system; the matrix structure must match the AOT
    /// artifact lowered for this `n`.
    pub(crate) fn system(rt: &Runtime, a: &Csr, b: &[f64], mode: ExecMode) -> Result<Self> {
        let driver = CgDriver::from_runtime(rt, a.n_rows)?;
        if a.nnz() != driver.nnz {
            return Err(Error::invalid(format!(
                "matrix nnz {} does not match the cg artifact for n={} (nnz {})",
                a.nnz(),
                a.n_rows,
                driver.nnz
            )));
        }
        let (data, cols, rows) = a.to_coo_f32();
        let data = HostTensor::f32(&[driver.nnz], data);
        let cols = HostTensor::i32(&[driver.nnz], cols);
        let rows = HostTensor::i32(&[driver.nnz], rows);
        let b: Vec<f32> = b.iter().map(|&v| v as f32).collect();
        Ok(Self {
            driver,
            data,
            cols,
            rows,
            b,
            mode,
            state: None,
            last_rr: None,
            iters: 0,
            wall_seconds: 0.0,
            invocations: 0,
            host_bytes: 0,
        })
    }

    fn current_x(&self) -> Result<Option<&[f32]>> {
        match &self.state {
            Some(s) => Ok(Some(s[0].as_f32()?)),
            None => Ok(None),
        }
    }
}

impl Solver for PjrtCg {
    fn prepare(&mut self) -> Result<()> {
        let state = self.driver.initial_state(&self.b);
        self.last_rr = Some(state[3].as_f32()?[0] as f64);
        self.state = Some(state);
        self.iters = 0;
        self.wall_seconds = 0.0;
        self.invocations = 0;
        self.host_bytes = 0;
        Ok(())
    }

    fn advance(&mut self, iters: usize) -> Result<()> {
        let state = match self.state.take() {
            Some(s) => s,
            None => self.driver.initial_state(&self.b),
        };
        let state_bytes: u64 = state.iter().map(|t| t.bytes() as u64).sum();
        let matrix_bytes =
            (self.data.bytes() + self.cols.bytes() + self.rows.bytes()) as u64;
        let t0 = std::time::Instant::now();
        let (state, invocations) =
            self.driver
                .advance(self.mode, &self.data, &self.cols, &self.rows, state, iters)?;
        self.wall_seconds += t0.elapsed().as_secs_f64();
        self.iters += iters;
        self.invocations += invocations;
        // every launch re-marshals the matrix + state up and the state down
        self.host_bytes += invocations * (matrix_bytes + 2 * state_bytes);
        self.last_rr = Some(state[3].as_f32()?[0] as f64);
        self.state = Some(state);
        Ok(())
    }

    fn report(&self) -> Report {
        let residual = self.last_rr;
        Report::new(
            self.mode,
            self.iters,
            self.wall_seconds,
            self.invocations,
            self.host_bytes,
            self.iters as f64,
            "iters/s",
            residual,
            None,
        )
    }

    fn state_f64(&self) -> Result<Vec<f64>> {
        match &self.state {
            Some(s) => s[0].to_f64_vec(),
            None => Ok(vec![0.0; self.driver.n]),
        }
    }

    fn fused_chunk(&self) -> usize {
        match self.mode {
            ExecMode::Persistent => self.driver.fused_iters.max(1),
            _ => 1,
        }
    }

    fn true_residual(&self) -> Result<Option<f64>> {
        match self.current_x()? {
            Some(x) => {
                let x = x.to_vec();
                Ok(Some(self.driver.residual(
                    &self.data,
                    &self.cols,
                    &self.rows,
                    &x,
                    &self.b,
                )?))
            }
            None => Ok(None),
        }
    }
}
