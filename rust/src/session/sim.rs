//! Simulated solvers: the paper's analytical performance model behind
//! `Backend::Simulated`. No numeric state is advanced — `advance` costs a
//! modeled wall time via the harness projection (Eqs 5-11 for stencils,
//! the Fig 7 launch/sync + traffic model for CG), so paper-scale devices
//! (A100/V100) can be "run" through the same `Session` API as the
//! measured backends.

use crate::coordinator::executor::ExecMode;
use crate::error::{Error, Result};
use crate::harness::{cg_exp, stencil_exp, StencilExperiment};
use crate::session::{Report, Solver};
use crate::simgpu::device::DeviceSpec;
use crate::stencil;

/// Modeled iterative stencil on a paper-catalog device.
pub struct SimStencil {
    dev: DeviceSpec,
    exp: StencilExperiment,
    mode: ExecMode,
    steps: usize,
    wall_seconds: f64,
    invocations: u64,
    host_bytes: u64,
    barrier_wait_seconds: f64,
}

impl SimStencil {
    pub(crate) fn new(
        dev: DeviceSpec,
        bench: &str,
        dims: &[usize],
        elem: usize,
        mode: ExecMode,
    ) -> Result<Self> {
        if mode == ExecMode::Pipelined {
            return Err(Error::invalid(
                "pipelined is a CG-only execution model; stencils have no dot-product pipeline",
            ));
        }
        let spec = stencil::spec(bench)
            .ok_or_else(|| Error::invalid(format!("unknown stencil benchmark {bench:?}")))?;
        let exp = StencilExperiment { bench: spec, elem, domain: dims.to_vec(), steps: 0 };
        Ok(Self {
            dev,
            exp,
            mode,
            steps: 0,
            wall_seconds: 0.0,
            invocations: 0,
            host_bytes: 0,
            barrier_wait_seconds: 0.0,
        })
    }
}

impl Solver for SimStencil {
    fn prepare(&mut self) -> Result<()> {
        self.steps = 0;
        self.wall_seconds = 0.0;
        self.invocations = 0;
        self.host_bytes = 0;
        self.barrier_wait_seconds = 0.0;
        Ok(())
    }

    fn advance(&mut self, steps: usize) -> Result<()> {
        let mut exp = self.exp.clone();
        exp.steps = steps;
        let m = stencil_exp::modeled_run(&self.dev, &exp, self.mode);
        self.steps += steps;
        self.wall_seconds += m.wall_seconds;
        self.invocations += m.invocations;
        self.host_bytes += m.host_bytes;
        self.barrier_wait_seconds += m.barrier_wait_seconds;
        Ok(())
    }

    fn report(&self) -> Report {
        Report::new(
            self.mode,
            self.steps,
            self.wall_seconds,
            self.invocations,
            self.host_bytes,
            self.exp.cells() * self.steps as f64,
            "cells/s",
            None,
            Some(self.barrier_wait_seconds),
        )
    }

    fn state_f64(&self) -> Result<Vec<f64>> {
        Err(Error::invalid(
            "the simulated backend models performance only and has no numeric state",
        ))
    }
}

/// Modeled CG solve on a paper-catalog device.
pub struct SimCg {
    dev: DeviceSpec,
    rows: usize,
    nnz: usize,
    mode: ExecMode,
    iters: usize,
    wall_seconds: f64,
    invocations: u64,
    host_bytes: u64,
    barrier_wait_seconds: f64,
}

impl SimCg {
    pub(crate) fn new(dev: DeviceSpec, rows: usize, nnz: usize, mode: ExecMode) -> Self {
        Self {
            dev,
            rows,
            nnz,
            mode,
            iters: 0,
            wall_seconds: 0.0,
            invocations: 0,
            host_bytes: 0,
            barrier_wait_seconds: 0.0,
        }
    }
}

/// nnz of the 5-point Poisson matrix on a g x g grid (every node has a
/// diagonal entry plus its in-grid neighbours): 5g^2 - 4g.
pub(crate) fn poisson2d_nnz(g: usize) -> usize {
    5 * g * g - 4 * g
}

impl Solver for SimCg {
    fn prepare(&mut self) -> Result<()> {
        self.iters = 0;
        self.wall_seconds = 0.0;
        self.invocations = 0;
        self.host_bytes = 0;
        self.barrier_wait_seconds = 0.0;
        Ok(())
    }

    fn advance(&mut self, iters: usize) -> Result<()> {
        let m = cg_exp::modeled_cg_run(&self.dev, self.rows, self.nnz, 4, self.mode, iters);
        self.iters += iters;
        self.wall_seconds += m.wall_seconds;
        self.invocations += m.invocations;
        self.host_bytes += m.host_bytes;
        self.barrier_wait_seconds += m.barrier_wait_seconds;
        Ok(())
    }

    fn report(&self) -> Report {
        Report::new(
            self.mode,
            self.iters,
            self.wall_seconds,
            self.invocations,
            self.host_bytes,
            self.iters as f64,
            "iters/s",
            None,
            Some(self.barrier_wait_seconds),
        )
    }

    fn state_f64(&self) -> Result<Vec<f64>> {
        Err(Error::invalid(
            "the simulated backend models performance only and has no numeric state",
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_nnz_formula_matches_the_generator() {
        for g in [4usize, 8, 16, 32] {
            let a = crate::sparse::gen::poisson2d(g);
            assert_eq!(a.nnz(), poisson2d_nnz(g), "g={g}");
        }
    }

    #[test]
    fn sim_stencil_persistent_is_fastest_and_accumulates() {
        let dev = crate::simgpu::device::a100();
        let mut walls = Vec::new();
        // the three paper stencil modes; Pipelined is CG-only and is
        // rejected by SimStencil::new
        assert!(SimStencil::new(dev.clone(), "2d5pt", &[64, 64], 8, ExecMode::Pipelined)
            .is_err());
        for mode in [ExecMode::HostLoop, ExecMode::HostLoopResident, ExecMode::Persistent] {
            let mut s = SimStencil::new(dev.clone(), "2d5pt", &[3072, 3072], 8, mode).unwrap();
            s.prepare().unwrap();
            s.advance(500).unwrap();
            s.advance(500).unwrap();
            let rep = s.report();
            assert_eq!(rep.steps, 1000);
            assert!(rep.fom.is_finite() && rep.fom > 0.0);
            walls.push(rep.wall_seconds);
        }
        // [host-loop, resident, persistent]
        assert!(walls[2] < walls[1] && walls[1] < walls[0], "{walls:?}");
    }
}
