//! # `session` — the unified PERKS entrypoint
//!
//! The paper's central claim is that the PERKS execution model is "largely
//! independent of the solver's implementation" (§III). This module is that
//! independence made concrete: typed builders, one [`Solver`] trait, one
//! [`Report`] shape — over every backend the crate implements:
//!
//! * [`Backend::Pjrt`] — the AOT HLO artifacts executed through the PJRT
//!   runtime (the measured cross-language path);
//! * [`Backend::CpuPersistent`] — the persistent-threads CPU substrate
//!   (the physically-measured PERKS demonstration);
//! * [`Backend::Simulated`] — the paper's analytical performance model on
//!   the Table I device catalog (A100/V100/P100 at paper scale).
//!
//! ## Typed sub-builders
//!
//! Entry is one of two typed sub-builders, so solver-specific knobs are
//! scoped at compile time instead of validated at `build()`:
//!
//! * [`SessionBuilder::stencil`]`(bench, interior, dtype)` →
//!   [`StencilSessionBuilder`], which alone carries
//!   [`temporal`](StencilSessionBuilder::temporal) and
//!   [`initial_domain`](StencilSessionBuilder::initial_domain);
//! * [`SessionBuilder::cg`]`(n)` / [`SessionBuilder::cg_system`]`(a, b)` →
//!   [`CgSessionBuilder`], which alone carries
//!   [`preconditioner`](CgSessionBuilder::preconditioner),
//!   [`pipelined`](CgSessionBuilder::pipelined),
//!   [`parts`](CgSessionBuilder::parts) and
//!   [`threaded`](CgSessionBuilder::threaded).
//!
//! Shared knobs — backend/threads, mode/policy/auto, seed, farm,
//! batch_epochs, and the resilience family — exist identically on both.
//! The pre-existing flat knobs still compile as `#[deprecated]`
//! forwarders; migration is mechanical:
//!
//! | flat (deprecated) | typed replacement |
//! |---|---|
//! | `.workload(Workload::stencil(b, i, d))` | [`SessionBuilder::stencil`]`(b, i, d)` |
//! | `.workload(Workload::cg(n))` | [`SessionBuilder::cg`]`(n)` |
//! | `.workload(Workload::cg_system(a, b))` | [`SessionBuilder::cg_system`]`(a, b)` |
//! | `.temporal(bt)` | [`StencilSessionBuilder::temporal`] |
//! | `.initial_domain(v)` | [`StencilSessionBuilder::initial_domain`] |
//! | `.cg_parts(p)` | [`CgSessionBuilder::parts`] |
//! | `.cg_threaded(t)` | [`CgSessionBuilder::threaded`] |
//!
//! The execution model is either fixed ([`ExecPolicy::Fixed`]) or chosen
//! by measurement/projection ([`ExecPolicy::Auto`], which probes every
//! candidate mode through `coordinator::autotune::tune_exec_mode` and, on
//! the CPU backend, autotunes the thread count). CG sessions on the CPU
//! backend additionally expose [`ExecMode::Pipelined`] — Ghysels–Vanroose
//! pipelined CG, **one** grid-barrier reduction per iteration instead of
//! classic CG's two ([`crate::cg::pipeline`]), optionally preconditioned
//! (none / Jacobi / block-Jacobi, [`Preconditioner`]) — selected with
//! [`CgSessionBuilder::pipelined`] or raced against the classic
//! persistent pool by `Auto`. Iterates are bit-identical to the serial
//! pipelined recurrence at every worker count.
//!
//! Stencil workloads on the CPU backend additionally compose PERKS with
//! overlapped **temporal blocking** via
//! [`StencilSessionBuilder::temporal`]: at degree `bt` the resident
//! workers advance `bt` sub-steps locally per boundary exchange
//! (2 barriers per *epoch* instead of 2 per *step*), bit-identically to
//! `bt = 1`, trading redundant trapezoid compute ([`Report::redundancy`])
//! for `bt`x fewer grid syncs. Left unset, `ExecPolicy::Auto` probes
//! `bt ∈ {1, 2, 4}` by measurement, cross-checked against the analytic
//! [`stencil::temporal::overlap_cost_banded`] model; the resolved degree
//! is visible as [`Session::temporal_degree`].
//!
//! ## Quickstart
//!
//! ```no_run
//! use perks::session::{Backend, ExecMode, Preconditioner, SessionBuilder};
//! use perks::runtime::Runtime;
//!
//! fn main() -> perks::Result<()> {
//!     // a measured PJRT run of the 2d5pt stencil under the PERKS model
//!     let rt = Runtime::new(Runtime::default_dir())?;
//!     let mut session = SessionBuilder::stencil("2d5pt", "128x128", "f32")
//!         .backend(Backend::pjrt(rt))
//!         .mode(ExecMode::Persistent)
//!         .build()?;
//!     let report = session.run(session.aligned_steps(64))?;
//!     println!("{:.2e} {}", report.fom, report.fom_unit);
//!
//!     // the same workload, CPU persistent threads, auto-tuned
//!     let mut cpu = SessionBuilder::stencil("2d5pt", "128x128", "f64")
//!         .threads(0) // 0 = autotune the thread count
//!         .auto()
//!         .build()?;
//!     let rep = cpu.run(64)?;
//!     println!("auto picked {} ({:.2e} cells/s)", rep.mode.name(), rep.fom);
//!
//!     // pipelined, Jacobi-preconditioned CG on the persistent pool
//!     let mut cg = SessionBuilder::cg(256 * 256)
//!         .threads(8)
//!         .threaded(true)
//!         .pipelined(true)
//!         .preconditioner(Preconditioner::Jacobi)
//!         .build()?;
//!     let iters = cg.advance_until(1e-10, 10_000)?;
//!     println!("converged in {iters} iterations");
//!     Ok(())
//! }
//! ```
//!
//! Incremental use (`prepare` / `advance` / `report`) keeps solver state
//! across calls — e.g. advancing CG in fused-chunk slabs until converged —
//! while [`Session::run`] is the one-shot convenience that re-prepares.
//!
//! ## Multi-tenant serving: [`SessionBuilder::farm`]
//!
//! CPU-persistent sessions can share one
//! [`crate::runtime::farm::SolverFarm`] instead of building a solo worker
//! pool each: `.farm(&farm)` admits the session onto the farm's
//! spawn-once resident workers (zero thread spawns per admission), routes
//! `advance`/`advance_until` through the farm's submission queue, and
//! keeps the session's slabs/vectors resident in the farm between its
//! epochs — bit-identically to the solo-pool session at every farm worker
//! count. [`Report::queue_wait_seconds`] surfaces the per-session queue
//! latency; farm-level throughput/latency/fairness live in
//! [`crate::runtime::farm::FarmMetrics`]. Solo pools remain the default.
//!
//! Farm sessions can additionally opt into the supervision layer
//! (`runtime::resilience`): [`SessionBuilder::checkpoint_every`] sets the
//! epoch cadence at which the farm snapshots the session's resident
//! state, [`SessionBuilder::retry`] makes retryable failures (a panicked
//! shard, an injected fault, a NaN-tripped reduction) restore the last
//! checkpoint and replay bit-identically instead of erroring the
//! command, and [`SessionBuilder::command_deadline`] arms a watchdog
//! that fails blocking waits with `Error::Stuck` instead of hanging.
//! [`SessionBuilder::durable`] extends the checkpoints past the process
//! boundary: every snapshot is also persisted to a directory with
//! crash-consistent writes
//! ([`crate::runtime::resilience::snapshot::SnapshotStore`]), so a
//! killed process resumes bit-identical via the `perks_recover` binary
//! (see `docs/RECOVERY.md`).
//! [`Report::recoveries`] / [`Report::replayed_epochs`] /
//! [`Report::checkpoint_bytes`] surface what the supervision did.

pub mod cpu;
pub mod pjrt;
pub mod report;
pub mod sim;

use std::rc::Rc;

pub use crate::cg::precond::Preconditioner;
use crate::coordinator::autotune;
pub use crate::coordinator::executor::ExecMode;
use crate::error::{Error, Result};
use crate::runtime::farm::{FarmHandle, SolverFarm};
use crate::runtime::resilience::{ResilienceConfig, RetryPolicy};
use crate::runtime::Runtime;
use crate::simgpu::device::DeviceSpec;
use crate::sparse::csr::Csr;
use crate::stencil;
pub use self::report::Report;

/// Where a session executes.
#[derive(Clone)]
pub enum Backend {
    /// AOT HLO artifacts through the PJRT runtime. Shared via `Rc` so one
    /// compiled-artifact cache can serve several sessions (e.g. one per
    /// execution model in a comparison table).
    Pjrt(Rc<Runtime>),
    /// Persistent-threads CPU substrate; `threads == 0` means autotune.
    CpuPersistent { threads: usize },
    /// The analytical performance model on a paper-catalog device.
    Simulated(DeviceSpec),
}

impl Backend {
    /// PJRT backend; accepts an owned `Runtime` or an existing `Rc`.
    pub fn pjrt(rt: impl Into<Rc<Runtime>>) -> Self {
        Backend::Pjrt(rt.into())
    }

    /// CPU persistent-threads backend (`threads == 0` autotunes).
    pub fn cpu(threads: usize) -> Self {
        Backend::CpuPersistent { threads }
    }

    /// Simulated backend on one of the `simgpu::device` catalog entries.
    pub fn simulated(dev: DeviceSpec) -> Self {
        Backend::Simulated(dev)
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::Pjrt(_) => "pjrt",
            Backend::CpuPersistent { .. } => "cpu-persistent",
            Backend::Simulated(_) => "simulated",
        }
    }
}

/// What a session computes.
#[derive(Clone, Debug)]
pub enum Workload {
    /// One of the Table III stencil benchmarks. `interior` is `"128x128"`
    /// style; `dtype` is `"f32"` or `"f64"` (the CPU substrate always
    /// computes in f64).
    Stencil { bench: String, interior: String, dtype: String },
    /// CG on the 5-point Poisson system of a sqrt(n) x sqrt(n) grid
    /// (n must be a perfect square).
    Cg { n: usize },
    /// CG on an explicit SPD system.
    CgSystem { a: Csr, b: Vec<f64> },
}

impl Workload {
    pub fn stencil(bench: &str, interior: &str, dtype: &str) -> Self {
        Workload::Stencil {
            bench: bench.to_string(),
            interior: interior.to_string(),
            dtype: dtype.to_string(),
        }
    }

    pub fn cg(n: usize) -> Self {
        Workload::Cg { n }
    }

    pub fn cg_system(a: Csr, b: Vec<f64>) -> Self {
        Workload::CgSystem { a, b }
    }
}

/// How the execution model is chosen.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecPolicy {
    /// Run exactly this model (validated against the backend/workload).
    Fixed(ExecMode),
    /// Probe every candidate model (measured on the PJRT/CPU backends,
    /// projected on the simulated one) and keep the fastest.
    Auto,
}

/// A solver that can be prepared, advanced and inspected — the seam that
/// makes every backend/workload pair interchangeable downstream.
pub trait Solver {
    /// (Re)initialize state from the workload seed; resets all metrics.
    fn prepare(&mut self) -> Result<()>;

    /// Advance by `steps` time steps (stencil) or iterations (CG). Under
    /// the persistent model, `steps` must be a multiple of
    /// [`Solver::fused_chunk`].
    fn advance(&mut self, steps: usize) -> Result<()>;

    /// Advance until the solver's convergence measure drops to `tol`, or
    /// `max_steps` elapse; returns the steps actually performed. The
    /// measure is the squared step-delta norm for stencils and the `r·r`
    /// recurrence for CG (both surfaced as [`Report::residual`]). On the
    /// CPU persistent substrates the check runs *inside* the resident
    /// loop (the pool's barrier-fused residual / the CG threshold path);
    /// backends without in-loop convergence detection return an error.
    fn advance_until(&mut self, _tol: f64, _max_steps: usize) -> Result<usize> {
        Err(Error::invalid(
            "convergence-driven advance is not supported by this backend",
        ))
    }

    /// Metrics accumulated since the last `prepare`.
    fn report(&self) -> Report;

    /// Final state as f64: the padded domain (stencil) or the solution
    /// iterate x (CG). Errors on the simulated backend (no numeric state).
    fn state_f64(&self) -> Result<Vec<f64>>;

    /// Steps fused into one launch under the persistent model (1 for the
    /// per-step models and for substrates without AOT fusion).
    fn fused_chunk(&self) -> usize {
        1
    }

    /// On-substrate `||b - Ax||^2` check (CG workloads; `None` elsewhere).
    fn true_residual(&self) -> Result<Option<f64>> {
        Ok(None)
    }
}

/// Calibration depth for `ExecPolicy::Auto` probes (rounded up to the
/// fused chunk). Deep enough that one-time costs (initial upload, cache
/// fill) amortize the way they do in a real run.
const AUTO_PROBE_STEPS: usize = 128;

/// Temporal-blocking degrees `ExecPolicy::Auto` probes on the CPU
/// stencil substrate when no explicit `temporal(bt)` was set.
const AUTO_TEMPORAL_CANDIDATES: [usize; 3] = [1, 2, 4];

/// Analytic prune for the `Auto` temporal probe: degrees whose banded
/// overlap redundancy ([`stencil::temporal::overlap_cost_banded`])
/// exceeds this cap are skipped without measuring — the redundant
/// trapezoid compute alone outweighs any barrier saving.
const TEMPORAL_REDUNDANCY_CAP: f64 = 2.0;

/// Builder for a [`Session`] — the crate's front door.
pub struct SessionBuilder {
    backend: Option<Backend>,
    workload: Option<Workload>,
    policy: ExecPolicy,
    seed: u64,
    cg_parts: usize,
    cg_threaded: bool,
    /// CG preconditioner, applied identically on every CG execution path
    /// (serial / pooled / farm); identity (`None`) by default.
    precond: Preconditioner,
    /// Temporal-blocking degree: `None` = default (1, or auto-probed
    /// under `ExecPolicy::Auto` on the CPU stencil substrate).
    temporal: Option<usize>,
    init: Option<Vec<f64>>,
    /// Shared multi-tenant worker pool; `None` = solo pools (default).
    farm: Option<FarmHandle>,
    /// Batched command-graph granularity on the farm path (epochs per
    /// graph segment for stencils, iterations per segment for CG);
    /// `0` = monolithic commands (default).
    batch_epochs: usize,
    /// Supervision config on the farm path: checkpoint cadence, retry
    /// policy, watchdog deadline. Disabled (all zero) by default.
    resilience: ResilienceConfig,
}

impl Default for SessionBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl SessionBuilder {
    pub fn new() -> Self {
        Self {
            backend: None,
            workload: None,
            policy: ExecPolicy::Fixed(ExecMode::Persistent),
            seed: 42,
            cg_parts: 8,
            cg_threaded: false,
            precond: Preconditioner::None,
            temporal: None,
            init: None,
            farm: None,
            batch_epochs: 0,
            resilience: ResilienceConfig::disabled(),
        }
    }

    /// Typed entry for a stencil session: one of the Table III benchmarks
    /// on a `"128x128"`-style interior with dtype `"f32"` or `"f64"`.
    /// The returned [`StencilSessionBuilder`] scopes the stencil-only
    /// knobs (`temporal`, `initial_domain`) at compile time.
    pub fn stencil(bench: &str, interior: &str, dtype: &str) -> StencilSessionBuilder {
        let mut inner = Self::new();
        inner.workload = Some(Workload::stencil(bench, interior, dtype));
        StencilSessionBuilder { inner }
    }

    /// Typed entry for a CG session on the 5-point Poisson system of a
    /// `sqrt(n) x sqrt(n)` grid (`n` must be a perfect square). The
    /// returned [`CgSessionBuilder`] scopes the CG-only knobs
    /// (`preconditioner`, `pipelined`, `parts`, `threaded`) at compile
    /// time.
    pub fn cg(n: usize) -> CgSessionBuilder {
        let mut inner = Self::new();
        inner.workload = Some(Workload::cg(n));
        CgSessionBuilder { inner }
    }

    /// Typed entry for a CG session on an explicit SPD system.
    pub fn cg_system(a: Csr, b: Vec<f64>) -> CgSessionBuilder {
        let mut inner = Self::new();
        inner.workload = Some(Workload::CgSystem { a, b });
        CgSessionBuilder { inner }
    }

    pub fn backend(mut self, backend: Backend) -> Self {
        self.backend = Some(backend);
        self
    }

    #[deprecated(note = "use the typed sub-builders: SessionBuilder::stencil / \
                         SessionBuilder::cg / SessionBuilder::cg_system")]
    pub fn workload(mut self, workload: Workload) -> Self {
        self.workload = Some(workload);
        self
    }

    /// Fix the execution model (default: `Persistent`).
    pub fn mode(mut self, mode: ExecMode) -> Self {
        self.policy = ExecPolicy::Fixed(mode);
        self
    }

    pub fn policy(mut self, policy: ExecPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Shorthand for `.policy(ExecPolicy::Auto)`.
    pub fn auto(self) -> Self {
        self.policy(ExecPolicy::Auto)
    }

    /// Temporal-blocking degree `bt` for stencil workloads on the CPU
    /// persistent-threads backend: the resident workers advance `bt`
    /// sub-steps locally per boundary exchange (slabs widened to
    /// `bt * radius` halo planes), paying `2 * ceil(steps / bt)` grid
    /// barriers per advance instead of `2 * steps`, at the price of
    /// redundant trapezoid compute (reported as [`Report::redundancy`]).
    /// Results are bit-identical at every degree. `bt = 1` — the default
    /// — is per-step exchange; `bt > 1` requires the persistent model.
    /// Left unset, [`ExecPolicy::Auto`] probes `bt ∈ {1, 2, 4}` by
    /// measured wall time, cross-checked against the
    /// [`stencil::temporal::overlap_cost_banded`] analytic model.
    #[deprecated(note = "use StencilSessionBuilder::temporal (via SessionBuilder::stencil)")]
    pub fn temporal(mut self, bt: usize) -> Self {
        self.temporal = Some(bt);
        self
    }

    /// Run this session's solver on a shared multi-tenant
    /// [`SolverFarm`] instead of building it a solo worker pool: the
    /// session is *admitted* to the farm's resident workers (zero thread
    /// spawns), its `advance`/`advance_until` calls are enqueued into the
    /// farm's submission queue, and its slab/vector state stays resident
    /// in the farm between epochs. Requires the CPU persistent-threads
    /// backend and the persistent execution model (`ExecPolicy::Auto`
    /// resolves to it directly — farm sessions never probe solo pools).
    /// Iterates are bit-identical to the solo-pool session at every farm
    /// worker count. Solo pools remain the default.
    pub fn farm(self, farm: &SolverFarm) -> Self {
        self.farm_handle(farm.handle())
    }

    /// [`SessionBuilder::farm`] from an already-cloned [`FarmHandle`].
    pub fn farm_handle(mut self, handle: FarmHandle) -> Self {
        self.farm = Some(handle);
        self
    }

    /// Batched command graphs on the farm path: encode each
    /// `advance`/`advance_until` as a
    /// [`crate::runtime::plane::CommandGraph`] of `epochs`-epoch segments
    /// (stencils: `epochs * bt` steps per segment; CG: `epochs`
    /// iterations), enqueued under a *single* scheduler-lock acquisition
    /// with segment boundaries chained inside the farm's completion
    /// transitions. Bit-identical to monolithic submission; only the
    /// enqueue-lock traffic changes (`Report::plane_batches` vs
    /// `util::counters::sched_lock_acquisitions`). `0` — the default —
    /// submits monolithic commands. Requires [`SessionBuilder::farm`].
    pub fn batch_epochs(mut self, epochs: usize) -> Self {
        self.batch_epochs = epochs;
        self
    }

    /// Checkpoint cadence on the farm path: every `epochs` exchange
    /// epochs (stencil) or iterations (CG) the farm snapshots this
    /// session's resident state — slabs/vectors plus progress and
    /// traffic counters — into a restorable
    /// [`crate::runtime::resilience::Checkpoint`]. The copy happens
    /// inside the completion transition, under the scheduler lock the
    /// transition already holds, so it adds **no barriers**; its cost is
    /// the memcpy, bounded by the `< 5%` overhead gate in
    /// `BENCH_resilience.json`. `runtime::resilience::
    /// DEFAULT_CHECKPOINT_EVERY` (16) is the gated default; `0` disables
    /// cadence snapshots (a [`SessionBuilder::retry`] policy still takes
    /// one snapshot at each command entry). Requires
    /// [`SessionBuilder::farm`]. Accounted in
    /// [`Report::checkpoint_bytes`].
    pub fn checkpoint_every(mut self, epochs: u64) -> Self {
        self.resilience.checkpoint_every = epochs;
        self
    }

    /// Supervised recovery on the farm path: when a retryable failure
    /// hits this session's command — a worker panic (injected or real),
    /// a non-finite reduction — the farm restores the session's last
    /// checkpoint and replays the lost epochs instead of erroring the
    /// command, up to `policy.max_attempts` times per command (with
    /// `policy.backoff` between attempts). Replays are **bit-identical**
    /// to an uninjected run: shard math is deterministic and the restore
    /// rewinds state, schedule, and traffic accounting together.
    /// [`Report::recoveries`] / [`Report::replayed_epochs`] count what
    /// happened; `RetryPolicy::disabled()` (the default) surfaces
    /// `Error::Fault` instead. Requires [`SessionBuilder::farm`].
    pub fn retry(mut self, policy: RetryPolicy) -> Self {
        self.resilience.retry = policy;
        self
    }

    /// Watchdog deadline for this session's blocking waits on the farm
    /// path: a `wait()` whose command is still in flight after `d`
    /// returns `Error::Stuck { phase, epoch, waited_ms }` instead of
    /// blocking forever (the command keeps draining; releasing the
    /// session reaps it). Off by default. Requires
    /// [`SessionBuilder::farm`].
    pub fn command_deadline(mut self, d: std::time::Duration) -> Self {
        self.resilience.deadline = Some(d);
        self
    }

    /// Durable snapshots on the farm path: persist every checkpoint this
    /// session takes (cadence and command-entry alike) into `dir` as
    /// checksummed, generation-numbered frames written crash-consistently
    /// — serialize to a temp file, fsync, atomically rename — by a
    /// [`crate::runtime::resilience::snapshot::SnapshotStore`]. The
    /// write-out runs on a farm worker *outside* the scheduler lock, so
    /// disk latency never serializes scheduling; overhead at the default
    /// cadence is gated at `<= 10%` by `BENCH_resilience.json`. Pair
    /// with [`SessionBuilder::checkpoint_every`] (cadence `0` persists
    /// nothing but the command-entry snapshots a retry policy takes) and
    /// recover a killed process with the `perks_recover` binary — the
    /// walkthrough lives in `docs/RECOVERY.md`. Requires
    /// [`SessionBuilder::farm`].
    pub fn durable(mut self, dir: impl Into<std::path::PathBuf>) -> Self {
        self.resilience = self.resilience.durable(dir);
        self
    }

    /// Set the whole supervision config at once (see
    /// [`SessionBuilder::checkpoint_every`], [`SessionBuilder::retry`],
    /// [`SessionBuilder::command_deadline`] for the individual knobs).
    pub fn resilience(mut self, cfg: ResilienceConfig) -> Self {
        self.resilience = cfg;
        self
    }

    /// Seed for the deterministic initial state (stencil domain / CG rhs).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Explicit padded initial domain for stencil workloads (overrides the
    /// seeded randomization); length must match the padded extents.
    #[deprecated(note = "use StencilSessionBuilder::initial_domain (via SessionBuilder::stencil)")]
    pub fn initial_domain(mut self, data: Vec<f64>) -> Self {
        self.init = Some(data);
        self
    }

    /// Worker shares for the CPU merge-SpMV (CG workloads).
    #[deprecated(note = "use CgSessionBuilder::parts (via SessionBuilder::cg)")]
    pub fn cg_parts(mut self, parts: usize) -> Self {
        self.cg_parts = parts;
        self
    }

    /// Threaded execution for the CPU CG substrate: host-loop mode
    /// respawns SpMV workers every iteration (the measured baseline),
    /// persistent mode runs the backend's `threads` as a spawn-once
    /// worker pool with the iteration loop resident in the workers
    /// (`cg::pool`). Iterates are identical either way.
    #[deprecated(note = "use CgSessionBuilder::threaded (via SessionBuilder::cg)")]
    pub fn cg_threaded(mut self, threaded: bool) -> Self {
        self.cg_threaded = threaded;
        self
    }

    /// Validate, resolve `Auto` choices, construct and prepare the solver.
    pub fn build(self) -> Result<Session> {
        let backend = self
            .backend
            .ok_or_else(|| Error::invalid("SessionBuilder: no backend selected"))?;
        let workload = self
            .workload
            .ok_or_else(|| Error::invalid("SessionBuilder: no workload selected"))?;
        validate_workload(&workload)?;
        if self.init.is_some() && !matches!(workload, Workload::Stencil { .. }) {
            return Err(Error::invalid(
                "initial_domain only applies to stencil workloads",
            ));
        }
        // temporal-degree validation: 0 is always invalid; bt > 1 is a
        // feature of the CPU stencil substrate's persistent model
        if let Some(bt) = self.temporal {
            if bt == 0 {
                return Err(Error::invalid("temporal blocking degree must be >= 1"));
            }
            if bt > 1 {
                if !matches!(workload, Workload::Stencil { .. }) {
                    return Err(Error::invalid(
                        "temporal blocking (bt > 1) only applies to stencil workloads",
                    ));
                }
                if !matches!(backend, Backend::CpuPersistent { .. }) {
                    return Err(Error::invalid(
                        "temporal blocking (bt > 1) is implemented on the CPU \
                         persistent-threads backend",
                    ));
                }
                if matches!(self.policy, ExecPolicy::Fixed(m) if m != ExecMode::Persistent) {
                    return Err(Error::invalid(
                        "temporal blocking (bt > 1) requires the persistent \
                         execution model",
                    ));
                }
            }
        }
        let is_cg = matches!(workload, Workload::Cg { .. } | Workload::CgSystem { .. });
        // preconditioning is a feature of the native CG substrates (the
        // serial recurrence, the persistent pool, the pipelined farm path)
        if self.precond != Preconditioner::None {
            if !is_cg {
                return Err(Error::invalid("preconditioner only applies to CG workloads"));
            }
            if !matches!(backend, Backend::CpuPersistent { .. }) {
                return Err(Error::invalid(
                    "preconditioned CG is implemented on the CPU persistent-threads \
                     backend",
                ));
            }
        }
        // farm sessions: shared-worker execution is CPU-persistent-only,
        // and the execution model is resident by definition — the classic
        // persistent one, or (for CG) the pipelined one
        let pipelined_farm =
            self.farm.is_some() && matches!(self.policy, ExecPolicy::Fixed(ExecMode::Pipelined));
        if self.farm.is_some() {
            if !matches!(backend, Backend::CpuPersistent { .. }) {
                return Err(Error::invalid(
                    "farm sessions run on the CPU persistent-threads backend",
                ));
            }
            if matches!(self.policy, ExecPolicy::Fixed(m)
                if m != ExecMode::Persistent && m != ExecMode::Pipelined)
            {
                return Err(Error::invalid(
                    "farm sessions require the persistent execution model",
                ));
            }
            if pipelined_farm {
                if !is_cg {
                    return Err(Error::invalid(
                        "pipelined is a CG-only execution model; stencils have no \
                         dot-product pipeline",
                    ));
                }
                if self.batch_epochs > 0 {
                    return Err(Error::invalid(
                        "batched command graphs are not supported for pipelined CG \
                         farm sessions",
                    ));
                }
                if self.resilience.enabled() {
                    return Err(Error::invalid(
                        "resilience is not supported for pipelined CG farm sessions; \
                         use the classic CG farm path for checkpoint/replay",
                    ));
                }
            } else if is_cg && self.precond != Preconditioner::None {
                return Err(Error::invalid(
                    "preconditioned CG on the farm requires the pipelined execution \
                     model (CgSessionBuilder::pipelined): the classic farm path has \
                     no preconditioner plumbing",
                ));
            }
        }
        if self.batch_epochs > 0 && self.farm.is_none() {
            return Err(Error::invalid(
                "batched command graphs (batch_epochs > 0) require a farm session",
            ));
        }
        if self.resilience.enabled() && self.farm.is_none() {
            return Err(Error::invalid(
                "resilience (checkpoint_every / retry / command_deadline / durable) \
                 requires a farm session",
            ));
        }
        // resolve the CPU thread count before any mode probing. Farm
        // sessions skip the *measured* autotune: a probe would build solo
        // pools (thread spawns) for a session whose whole point is to
        // reuse the farm's resident workers — 0 resolves structurally.
        let backend = match backend {
            Backend::CpuPersistent { threads: 0 } if self.farm.is_some() => {
                Backend::CpuPersistent { threads: crate::util::resolve_workers(0) }
            }
            Backend::CpuPersistent { threads: 0 } => {
                Backend::CpuPersistent { threads: auto_threads(&workload, self.seed)? }
            }
            b => b,
        };
        if let Some(farm) = self.farm.clone() {
            // the farm decides scheduling; no mode/temporal probing
            let temporal = self.temporal.unwrap_or(1);
            let mode =
                if pipelined_farm { ExecMode::Pipelined } else { ExecMode::Persistent };
            let mut solver = make_solver(
                &backend,
                &workload,
                mode,
                self.seed,
                self.cg_parts,
                self.cg_threaded,
                self.precond,
                temporal,
                self.init.as_deref(),
                Some(farm),
                self.batch_epochs,
                self.resilience,
            )?;
            solver.prepare()?;
            return Ok(Session { solver, mode, temporal, backend_name: backend.name() });
        }
        let candidates = mode_candidates(&backend, &workload);
        // a pinned bt > 1 narrows Auto's mode search to the persistent
        // model (the only one that can honor it)
        let candidates: Vec<ExecMode> = if matches!(self.temporal, Some(bt) if bt > 1) {
            candidates.into_iter().filter(|m| *m == ExecMode::Persistent).collect()
        } else {
            candidates
        };
        // resolved temporal degree; the Auto arm below may raise it after
        // racing the composed (Persistent, bt) candidates
        let mut temporal = self.temporal.unwrap_or(1);
        let mode = match self.policy {
            ExecPolicy::Fixed(m) => {
                if !candidates.contains(&m) {
                    return Err(Error::invalid(format!(
                        "execution model {:?} is not supported for the {} backend with this workload",
                        m.name(),
                        backend.name()
                    )));
                }
                m
            }
            ExecPolicy::Auto => {
                let choice = autotune::tune_exec_mode(&candidates, |m| {
                    let bt = match (m, self.temporal) {
                        (ExecMode::Persistent, Some(bt)) => bt,
                        _ => 1,
                    };
                    let mut probe = make_solver(
                        &backend,
                        &workload,
                        m,
                        self.seed,
                        self.cg_parts,
                        self.cg_threaded,
                        self.precond,
                        bt,
                        self.init.as_deref(),
                        None,
                        0,
                        ResilienceConfig::disabled(),
                    )?;
                    probe.prepare()?;
                    // probe at steady-state depth (chunk-aligned): the
                    // persistent model amortizes its caching over many
                    // steps, so a too-shallow probe would misrank it
                    let steps = round_up_to(AUTO_PROBE_STEPS, probe.fused_chunk().max(1));
                    probe.advance(steps)?;
                    // normalize to per-step cost: chunks differ across modes
                    Ok(probe.report().wall_seconds / steps as f64)
                })?;
                let mut mode = choice.mode;
                // The race above measured the persistent model at bt = 1
                // only. For CPU stencil sessions with no pinned degree,
                // the composed (Persistent, bt ∈ {2, 4}) candidates must
                // be measured too — otherwise a host-loop win at bt = 1
                // locks out the epoch-batched configurations this knob
                // exists for.
                if self.temporal.is_none() {
                    if let (Backend::CpuPersistent { threads }, Workload::Stencil { .. }) =
                        (&backend, &workload)
                    {
                        // reuse the race's persistent bt=1 measurement as
                        // the baseline instead of probing it again
                        let bt1_cost = choice
                            .sweep
                            .iter()
                            .find(|(m, _)| *m == ExecMode::Persistent)
                            .map(|&(_, c)| c);
                        let t = tune_temporal(
                            &workload,
                            *threads,
                            self.seed,
                            self.init.as_deref(),
                            bt1_cost,
                        )?;
                        if mode == ExecMode::Persistent || t.cost < choice.cost {
                            mode = ExecMode::Persistent;
                            temporal = t.bt;
                        }
                    }
                }
                mode
            }
        };
        // a per-step model never batches epochs (an explicit bt == 1 on
        // host-loop, or a host-loop Auto win, resolves to degree 1)
        if mode != ExecMode::Persistent {
            temporal = 1;
        }
        let mut solver = make_solver(
            &backend,
            &workload,
            mode,
            self.seed,
            self.cg_parts,
            self.cg_threaded,
            self.precond,
            temporal,
            self.init.as_deref(),
            None,
            0,
            ResilienceConfig::disabled(),
        )?;
        solver.prepare()?;
        Ok(Session { solver, mode, temporal, backend_name: backend.name() })
    }
}

/// Typed builder for stencil sessions (see [`SessionBuilder::stencil`]).
/// Carries the stencil-only knobs; everything shared with CG sessions is
/// generated by `shared_knobs!` below.
pub struct StencilSessionBuilder {
    inner: SessionBuilder,
}

impl StencilSessionBuilder {
    /// Temporal-blocking degree `bt` — see the module docs. Stencil-only:
    /// CG has no trapezoid overlap to batch.
    pub fn temporal(mut self, bt: usize) -> Self {
        self.inner.temporal = Some(bt);
        self
    }

    /// Explicit padded initial domain (overrides the seeded
    /// randomization); length must match the padded extents.
    pub fn initial_domain(mut self, data: Vec<f64>) -> Self {
        self.inner.init = Some(data);
        self
    }
}

/// Typed builder for CG sessions (see [`SessionBuilder::cg`] /
/// [`SessionBuilder::cg_system`]). Carries the CG-only knobs; everything
/// shared with stencil sessions is generated by `shared_knobs!` below.
pub struct CgSessionBuilder {
    inner: SessionBuilder,
}

impl CgSessionBuilder {
    /// Preconditioner applied inside every execution path — the serial
    /// recurrence, the spawn-once pool, and the pipelined farm — with
    /// identical (bit-exact) iterates across them. Jacobi and
    /// block-Jacobi cost one extra fused vector pass per iteration
    /// (accounted in the traffic model); identity ([`Preconditioner::None`],
    /// the default) costs nothing.
    pub fn preconditioner(mut self, pc: Preconditioner) -> Self {
        self.inner.precond = pc;
        self
    }

    /// Pipelined CG ([`ExecMode::Pipelined`]): fold p·Ap, r·r and the
    /// preconditioned pipeline terms through **one** grid-barrier
    /// reduction per iteration instead of classic CG's two, at the price
    /// of four auxiliary vectors. `pipelined(false)` restores the classic
    /// persistent model. Equivalent to `.mode(ExecMode::Pipelined)`.
    pub fn pipelined(mut self, on: bool) -> Self {
        self.inner.policy =
            ExecPolicy::Fixed(if on { ExecMode::Pipelined } else { ExecMode::Persistent });
        self
    }

    /// Worker shares for the CPU merge-SpMV and the barrier-reduction
    /// block partition.
    pub fn parts(mut self, parts: usize) -> Self {
        self.inner.cg_parts = parts;
        self
    }

    /// Threaded execution for the CPU CG substrate: host-loop mode
    /// respawns SpMV workers every iteration (the measured baseline),
    /// resident modes run the backend's `threads` as a spawn-once worker
    /// pool with the iteration loop inside the workers. Iterates are
    /// identical either way.
    pub fn threaded(mut self, threaded: bool) -> Self {
        self.inner.cg_threaded = threaded;
        self
    }
}

/// The knobs shared by both typed sub-builders, generated once per
/// sub-builder so the flat [`SessionBuilder`] stays the single source of
/// truth for their semantics (each method forwards to its namesake
/// there — see those docs).
macro_rules! shared_knobs {
    ($T:ident) => {
        impl $T {
            /// See [`SessionBuilder::backend`].
            pub fn backend(mut self, backend: Backend) -> Self {
                self.inner = self.inner.backend(backend);
                self
            }

            /// Shorthand for `.backend(Backend::cpu(n))` — the CPU
            /// persistent-threads backend; `n == 0` autotunes.
            pub fn threads(mut self, n: usize) -> Self {
                self.inner = self.inner.backend(Backend::cpu(n));
                self
            }

            /// See [`SessionBuilder::mode`].
            pub fn mode(mut self, mode: ExecMode) -> Self {
                self.inner = self.inner.mode(mode);
                self
            }

            /// See [`SessionBuilder::policy`].
            pub fn policy(mut self, policy: ExecPolicy) -> Self {
                self.inner = self.inner.policy(policy);
                self
            }

            /// See [`SessionBuilder::auto`].
            pub fn auto(mut self) -> Self {
                self.inner = self.inner.auto();
                self
            }

            /// See [`SessionBuilder::seed`].
            pub fn seed(mut self, seed: u64) -> Self {
                self.inner = self.inner.seed(seed);
                self
            }

            /// See [`SessionBuilder::farm`].
            pub fn farm(mut self, farm: &SolverFarm) -> Self {
                self.inner = self.inner.farm(farm);
                self
            }

            /// See [`SessionBuilder::farm_handle`].
            pub fn farm_handle(mut self, handle: FarmHandle) -> Self {
                self.inner = self.inner.farm_handle(handle);
                self
            }

            /// See [`SessionBuilder::batch_epochs`].
            pub fn batch_epochs(mut self, epochs: usize) -> Self {
                self.inner = self.inner.batch_epochs(epochs);
                self
            }

            /// See [`SessionBuilder::checkpoint_every`].
            pub fn checkpoint_every(mut self, epochs: u64) -> Self {
                self.inner = self.inner.checkpoint_every(epochs);
                self
            }

            /// See [`SessionBuilder::retry`].
            pub fn retry(mut self, policy: RetryPolicy) -> Self {
                self.inner = self.inner.retry(policy);
                self
            }

            /// See [`SessionBuilder::command_deadline`].
            pub fn command_deadline(mut self, d: std::time::Duration) -> Self {
                self.inner = self.inner.command_deadline(d);
                self
            }

            /// See [`SessionBuilder::durable`].
            pub fn durable(mut self, dir: impl Into<std::path::PathBuf>) -> Self {
                self.inner = self.inner.durable(dir);
                self
            }

            /// See [`SessionBuilder::resilience`].
            pub fn resilience(mut self, cfg: ResilienceConfig) -> Self {
                self.inner = self.inner.resilience(cfg);
                self
            }

            /// See [`SessionBuilder::build`].
            pub fn build(self) -> Result<Session> {
                self.inner.build()
            }
        }
    };
}

shared_knobs!(StencilSessionBuilder);
shared_knobs!(CgSessionBuilder);

/// A built, prepared solver plus its resolved execution model.
pub struct Session {
    solver: Box<dyn Solver>,
    mode: ExecMode,
    temporal: usize,
    backend_name: &'static str,
}

impl Session {
    pub fn builder() -> SessionBuilder {
        SessionBuilder::new()
    }

    /// The resolved execution model (`Auto` has been decided by now).
    pub fn mode(&self) -> ExecMode {
        self.mode
    }

    /// The resolved temporal-blocking degree (1 unless the CPU stencil
    /// substrate runs epoch-batched exchanges; `Auto` may have probed it).
    pub fn temporal_degree(&self) -> usize {
        self.temporal
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend_name
    }

    /// Steps fused into one persistent launch (1 for per-step models).
    pub fn fused_chunk(&self) -> usize {
        self.solver.fused_chunk().max(1)
    }

    /// Round `requested` up to the next multiple of the fused chunk, so
    /// callers need not know the artifact's fusion depth.
    pub fn aligned_steps(&self, requested: usize) -> usize {
        round_up_to(requested, self.fused_chunk())
    }

    /// Reset the solver to its initial state and clear all metrics.
    pub fn prepare(&mut self) -> Result<()> {
        self.solver.prepare()
    }

    /// Advance the current state (see [`Solver::advance`]).
    pub fn advance(&mut self, steps: usize) -> Result<()> {
        self.solver.advance(steps)
    }

    /// Advance until converged to `tol` or `max_steps` elapse; returns
    /// the steps performed (see [`Solver::advance_until`]).
    pub fn advance_until(&mut self, tol: f64, max_steps: usize) -> Result<usize> {
        self.solver.advance_until(tol, max_steps)
    }

    /// Metrics accumulated since the last `prepare`.
    pub fn report(&self) -> Report {
        self.solver.report()
    }

    pub fn state_f64(&self) -> Result<Vec<f64>> {
        self.solver.state_f64()
    }

    pub fn true_residual(&self) -> Result<Option<f64>> {
        self.solver.true_residual()
    }

    /// One-shot: re-prepare, advance `steps`, report. Repeated calls are
    /// independent runs (benches time this directly).
    pub fn run(&mut self, steps: usize) -> Result<Report> {
        self.solver.prepare()?;
        self.solver.advance(steps)?;
        Ok(self.solver.report())
    }
}

/// Round `n` up to the next multiple of `chunk` (chunk >= 1).
fn round_up_to(n: usize, chunk: usize) -> usize {
    n.saturating_add(chunk - 1) / chunk * chunk
}

/// Build the seeded (or explicitly initialized) padded domain shared by
/// the stencil solvers of every backend.
pub(crate) fn stencil_domain(
    spec: &stencil::StencilSpec,
    dims: &[usize],
    seed: u64,
    init: Option<&[f64]>,
) -> Result<stencil::Domain> {
    let mut dom = stencil::Domain::for_spec(spec, dims)?;
    match init {
        Some(data) => {
            if data.len() != dom.data.len() {
                return Err(Error::invalid(format!(
                    "initial domain has {} elements, padded domain needs {}",
                    data.len(),
                    dom.data.len()
                )));
            }
            dom.data.copy_from_slice(data);
        }
        None => dom.randomize(seed),
    }
    Ok(dom)
}

/// Parse a `"128x128"`-style interior string, rejecting empty and
/// zero-sized extents (crate-visible: the farm harness shares it).
pub(crate) fn parse_interior(interior: &str) -> Result<Vec<usize>> {
    let dims = interior
        .split('x')
        .map(|d| {
            d.parse::<usize>()
                .map_err(|_| Error::invalid(format!("bad interior {interior:?}")))
        })
        .collect::<Result<Vec<_>>>()?;
    if dims.is_empty() || dims.iter().any(|&d| d == 0) {
        return Err(Error::invalid(format!("bad interior {interior:?}")));
    }
    Ok(dims)
}

fn validate_workload(w: &Workload) -> Result<()> {
    match w {
        Workload::Stencil { bench, interior, dtype } => {
            let spec = stencil::spec(bench).ok_or_else(|| {
                Error::invalid(format!(
                    "unknown stencil benchmark {bench:?} (see stencil::catalog)"
                ))
            })?;
            let dims = parse_interior(interior)?;
            if dims.len() != spec.dims {
                return Err(Error::invalid(format!(
                    "{bench} is {}D but interior {interior:?} has rank {}",
                    spec.dims,
                    dims.len()
                )));
            }
            if dtype != "f32" && dtype != "f64" {
                return Err(Error::invalid(format!(
                    "bad dtype {dtype:?}: expected \"f32\" or \"f64\""
                )));
            }
            Ok(())
        }
        Workload::Cg { n } => {
            let g = (*n as f64).sqrt().round() as usize;
            if *n == 0 || g * g != *n {
                return Err(Error::invalid(format!(
                    "cg workload n={n} must be a positive perfect square (poisson grid)"
                )));
            }
            Ok(())
        }
        Workload::CgSystem { a, b } => {
            if a.n_rows != a.n_cols {
                return Err(Error::invalid(format!(
                    "cg system matrix not square: {}x{}",
                    a.n_rows, a.n_cols
                )));
            }
            if b.len() != a.n_rows {
                return Err(Error::invalid(format!(
                    "cg system rhs has {} entries, matrix {}",
                    b.len(),
                    a.n_rows
                )));
            }
            Ok(())
        }
    }
}

/// Candidate execution models for a backend/workload pair. The CPU
/// substrate has no device-resident variant; the AOT/simulated CG
/// substrates distinguish only relaunch vs persistent, while the native
/// CPU CG substrate adds the pipelined (one-barrier) model, so `Auto`
/// races classic vs pipelined by measurement there.
fn mode_candidates(backend: &Backend, workload: &Workload) -> Vec<ExecMode> {
    let is_stencil = matches!(workload, Workload::Stencil { .. });
    match backend {
        Backend::Pjrt(_) | Backend::Simulated(_) if is_stencil => {
            vec![ExecMode::HostLoop, ExecMode::HostLoopResident, ExecMode::Persistent]
        }
        Backend::CpuPersistent { .. } if is_stencil => {
            vec![ExecMode::HostLoop, ExecMode::Persistent]
        }
        Backend::CpuPersistent { .. } => {
            vec![ExecMode::HostLoop, ExecMode::Persistent, ExecMode::Pipelined]
        }
        _ => vec![ExecMode::HostLoop, ExecMode::Persistent],
    }
}

/// Measured thread autotune for `Backend::CpuPersistent { threads: 0 }`.
fn auto_threads(workload: &Workload, seed: u64) -> Result<usize> {
    let max = crate::util::resolve_workers(0);
    match workload {
        Workload::Stencil { bench, interior, .. } => {
            let spec = stencil::spec(bench)
                .ok_or_else(|| Error::invalid(format!("unknown stencil benchmark {bench:?}")))?;
            let dims = parse_interior(interior)?;
            let mut dom = stencil::Domain::for_spec(&spec, &dims)?;
            dom.randomize(seed);
            Ok(autotune::tune_threads(&spec, &dom, 2, max)?.threads)
        }
        // CG workers (pool / threaded SpMV) scale with the machine; the
        // solver clamps to its share/block counts, so the full
        // parallelism is the right resolution for `threads == 0`
        _ => Ok(max),
    }
}

/// Measured temporal-degree autotune for stencil workloads on the CPU
/// persistent backend: probe [`AUTO_TEMPORAL_CANDIDATES`] one-shot runs
/// and keep the fastest per-step wall, after pruning degrees whose
/// analytic banded overlap cost ([`stencil::temporal::overlap_cost_banded`])
/// exceeds [`TEMPORAL_REDUNDANCY_CAP`] — the measured pick is thereby
/// cross-checked against the `OverlapCost` model in both directions: the
/// model gates what gets measured, the measurement decides among the
/// survivors. `bt1_cost` is the per-step cost the mode tuner already
/// measured for persistent `bt = 1`; when present it seeds the baseline
/// so that configuration is not measured a second time. Every probe —
/// including that seed, which the mode tuner measured as a prepared
/// solver's `advance` — times only the resident `run` on an
/// already-spawned pool, so degrees compete symmetrically: none pays
/// spawn/join inside its measured region. Returns the winning degree
/// with its per-step cost, so the caller can also race the composition
/// against the host-loop model's cost.
fn tune_temporal(
    workload: &Workload,
    threads: usize,
    seed: u64,
    init: Option<&[f64]>,
    bt1_cost: Option<f64>,
) -> Result<TemporalChoice> {
    let Workload::Stencil { bench, interior, .. } = workload else {
        return Ok(TemporalChoice { bt: 1, cost: f64::INFINITY });
    };
    let spec = stencil::spec(bench)
        .ok_or_else(|| Error::invalid(format!("unknown stencil benchmark {bench:?}")))?;
    let dims = parse_interior(interior)?;
    let dom = stencil_domain(&spec, &dims, seed, init)?;
    // the banded axis is the first interior extent in both 2D and 3D;
    // the thinnest band bounds the worst-case redundancy
    let bands = stencil::parallel::partition(dims[0], threads.max(1));
    let min_band = bands.iter().map(|&(_, l)| l).min().unwrap_or(1);
    let mut best = (1usize, f64::INFINITY);
    for bt in AUTO_TEMPORAL_CANDIDATES {
        if bt == 1 {
            if let Some(cost) = bt1_cost {
                best = (1, cost);
                continue;
            }
        } else if stencil::temporal::overlap_cost_banded(min_band, spec.radius, bt).redundancy()
            > TEMPORAL_REDUNDANCY_CAP
        {
            continue;
        }
        let steps = round_up_to(AUTO_PROBE_STEPS, bt);
        // time the resident run only: spawn before, join after the clock,
        // matching the advance-only accounting of the seeded bt=1 cost
        let mut pool = stencil::pool::StencilPool::spawn_temporal(&spec, &dom, threads, bt)?;
        let t0 = std::time::Instant::now();
        pool.run(steps, None)?;
        let cost = t0.elapsed().as_secs_f64() / steps as f64;
        if cost < best.1 {
            best = (bt, cost);
        }
    }
    Ok(TemporalChoice { bt: best.0, cost: best.1 })
}

/// Result of [`tune_temporal`]: the winning degree and its measured
/// per-step cost.
struct TemporalChoice {
    bt: usize,
    cost: f64,
}

#[allow(clippy::too_many_arguments)]
fn make_solver(
    backend: &Backend,
    workload: &Workload,
    mode: ExecMode,
    seed: u64,
    cg_parts: usize,
    cg_threaded: bool,
    precond: Preconditioner,
    temporal: usize,
    init: Option<&[f64]>,
    farm: Option<FarmHandle>,
    batch_epochs: usize,
    resilience: ResilienceConfig,
) -> Result<Box<dyn Solver>> {
    match (backend, workload) {
        (Backend::Pjrt(rt), Workload::Stencil { bench, interior, dtype }) => Ok(Box::new(
            pjrt::PjrtStencil::new(rt, bench, interior, dtype, mode, seed, init)?,
        )),
        (Backend::Pjrt(rt), Workload::Cg { n }) => {
            Ok(Box::new(pjrt::PjrtCg::poisson(rt, *n, mode, seed)?))
        }
        (Backend::Pjrt(rt), Workload::CgSystem { a, b }) => {
            Ok(Box::new(pjrt::PjrtCg::system(rt, a, b, mode)?))
        }
        (Backend::CpuPersistent { threads }, Workload::Stencil { bench, interior, .. }) => {
            let dims = parse_interior(interior)?;
            let opts = cpu::StencilOptions {
                threads: *threads,
                mode,
                seed,
                temporal,
                farm,
                batch_epochs,
                resilience,
            };
            Ok(Box::new(cpu::CpuStencil::new(bench, &dims, &opts, init)?))
        }
        (Backend::CpuPersistent { threads }, Workload::Cg { n }) => {
            let mut s = cpu::CpuCg::poisson(*n, seed, cg_parts, *threads, cg_threaded, mode)?
                .with_preconditioner(precond);
            if let Some(h) = farm {
                s = s.with_farm(h).with_batch_iters(batch_epochs).with_resilience(resilience);
            }
            Ok(Box::new(s))
        }
        (Backend::CpuPersistent { threads }, Workload::CgSystem { a, b }) => {
            let mut s =
                cpu::CpuCg::system(a.clone(), b.clone(), cg_parts, *threads, cg_threaded, mode)?
                    .with_preconditioner(precond);
            if let Some(h) = farm {
                s = s.with_farm(h).with_batch_iters(batch_epochs).with_resilience(resilience);
            }
            Ok(Box::new(s))
        }
        (Backend::Simulated(dev), Workload::Stencil { bench, interior, dtype }) => {
            let dims = parse_interior(interior)?;
            let elem = if dtype == "f64" { 8 } else { 4 };
            Ok(Box::new(sim::SimStencil::new(dev.clone(), bench, &dims, elem, mode)?))
        }
        (Backend::Simulated(dev), Workload::Cg { n }) => {
            let g = (*n as f64).sqrt().round() as usize;
            Ok(Box::new(sim::SimCg::new(dev.clone(), *n, sim::poisson2d_nnz(g), mode)))
        }
        (Backend::Simulated(dev), Workload::CgSystem { a, .. }) => {
            Ok(Box::new(sim::SimCg::new(dev.clone(), a.n_rows, a.nnz(), mode)))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simgpu::device::a100;

    fn msg(r: Result<Session>) -> String {
        format!("{}", r.err().expect("expected a build error"))
    }

    #[test]
    fn build_rejects_missing_pieces() {
        assert!(msg(SessionBuilder::new().build()).contains("no backend"));
        assert!(msg(SessionBuilder::new().backend(Backend::cpu(2)).build())
            .contains("no workload"));
        // typed sub-builders carry their workload, so only the backend can
        // be missing
        assert!(msg(SessionBuilder::cg(64).build()).contains("no backend"));
        assert!(msg(SessionBuilder::stencil("2d5pt", "8x8", "f64").build())
            .contains("no backend"));
    }

    #[test]
    fn build_rejects_bad_stencil_workloads() {
        assert!(msg(SessionBuilder::stencil("17d99pt", "8x8", "f64").threads(2).build())
            .contains("unknown stencil benchmark"));
        assert!(msg(SessionBuilder::stencil("2d5pt", "8x8x8", "f64").threads(2).build())
            .contains("rank"));
        assert!(msg(SessionBuilder::stencil("2d5pt", "8xbroken", "f64").threads(2).build())
            .contains("bad interior"));
        assert!(msg(SessionBuilder::stencil("2d5pt", "8x8", "f16").threads(2).build())
            .contains("bad dtype"));
    }

    #[test]
    fn build_rejects_bad_cg_and_mode_combos() {
        assert!(msg(
            SessionBuilder::cg(1000) // not a perfect square
                .threads(1)
                .mode(ExecMode::Persistent)
                .build()
        )
        .contains("perfect square"));
        // the CPU substrate has no device-resident model
        assert!(msg(
            SessionBuilder::stencil("2d5pt", "8x8", "f64")
                .threads(2)
                .mode(ExecMode::HostLoopResident)
                .build()
        )
        .contains("not supported"));
    }

    #[test]
    fn build_rejects_bad_temporal_combos() {
        // bt == 0
        assert!(msg(
            SessionBuilder::stencil("2d5pt", "8x8", "f64").threads(2).temporal(0).build()
        )
        .contains(">= 1"));
        // bt > 1 on a backend without the composition
        assert!(msg(
            SessionBuilder::stencil("2d5pt", "64x64", "f64")
                .backend(Backend::simulated(a100()))
                .temporal(2)
                .build()
        )
        .contains("CPU"));
        // bt > 1 pinned to a per-step model
        assert!(msg(
            SessionBuilder::stencil("2d5pt", "8x8", "f64")
                .threads(2)
                .mode(ExecMode::HostLoop)
                .temporal(2)
                .build()
        )
        .contains("persistent"));
        // bt == 1 is today's behavior and valid anywhere
        let s = SessionBuilder::stencil("2d5pt", "8x8", "f64")
            .threads(2)
            .mode(ExecMode::HostLoop)
            .temporal(1)
            .build()
            .unwrap();
        assert_eq!(s.temporal_degree(), 1);
    }

    #[test]
    fn temporal_sessions_resolve_their_degree() {
        let mut s = SessionBuilder::stencil("2d5pt", "16x16", "f64")
            .threads(3)
            .mode(ExecMode::Persistent)
            .temporal(4)
            .build()
            .unwrap();
        assert_eq!(s.temporal_degree(), 4);
        let rep = s.run(8).unwrap();
        assert_eq!(rep.steps, 8);
        assert!(rep.redundancy.unwrap() > 1.0, "epoch overlap work reported");
        // an Auto build with a pinned bt > 1 only considers persistent
        let s = SessionBuilder::stencil("2d5pt", "16x16", "f64")
            .threads(2)
            .auto()
            .temporal(2)
            .build()
            .unwrap();
        assert_eq!(s.mode(), ExecMode::Persistent);
        assert_eq!(s.temporal_degree(), 2);
    }

    #[test]
    fn auto_probes_a_temporal_degree_on_cpu_stencils() {
        let s = SessionBuilder::stencil("2d5pt", "24x24", "f64")
            .threads(2)
            .auto()
            .build()
            .unwrap();
        if s.mode() == ExecMode::Persistent {
            assert!(
                AUTO_TEMPORAL_CANDIDATES.contains(&s.temporal_degree()),
                "auto picked bt={}",
                s.temporal_degree()
            );
        } else {
            assert_eq!(s.temporal_degree(), 1, "per-step models never batch epochs");
        }
        // non-stencil and non-CPU sessions always resolve bt = 1
        let s = SessionBuilder::cg(64).threads(1).auto().build().unwrap();
        assert_eq!(s.temporal_degree(), 1);
    }

    #[test]
    fn auto_picks_a_valid_mode_on_every_workload() {
        // CPU stencil
        let s = SessionBuilder::stencil("2d5pt", "16x16", "f64")
            .threads(2)
            .auto()
            .build()
            .unwrap();
        assert!([ExecMode::HostLoop, ExecMode::Persistent].contains(&s.mode()));
        // CPU CG races classic against pipelined too
        let s = SessionBuilder::cg(64).threads(1).auto().build().unwrap();
        assert!([ExecMode::HostLoop, ExecMode::Persistent, ExecMode::Pipelined]
            .contains(&s.mode()));
        // simulated stencil: the model must prefer PERKS at paper scale
        let s = SessionBuilder::stencil("2d5pt", "3072x3072", "f64")
            .backend(Backend::simulated(a100()))
            .auto()
            .build()
            .unwrap();
        assert_eq!(s.mode(), ExecMode::Persistent);
        // simulated CG
        let s = SessionBuilder::cg(1024)
            .backend(Backend::simulated(a100()))
            .auto()
            .build()
            .unwrap();
        assert!([ExecMode::HostLoop, ExecMode::Persistent].contains(&s.mode()));
    }

    #[test]
    fn farm_sessions_validate_backend_and_mode() {
        let farm = SolverFarm::spawn(1).unwrap();
        // non-CPU backend
        assert!(msg(
            SessionBuilder::stencil("2d5pt", "64x64", "f64")
                .backend(Backend::simulated(a100()))
                .farm(&farm)
                .build()
        )
        .contains("CPU"));
        // per-step execution model
        assert!(msg(
            SessionBuilder::stencil("2d5pt", "8x8", "f64")
                .threads(2)
                .mode(ExecMode::HostLoop)
                .farm(&farm)
                .build()
        )
        .contains("persistent"));
        // a valid farm session resolves to Persistent (Auto included) and
        // honors a pinned temporal degree without probing
        let s = SessionBuilder::stencil("2d5pt", "8x8", "f64")
            .threads(2)
            .auto()
            .temporal(2)
            .farm(&farm)
            .build()
            .unwrap();
        assert_eq!(s.mode(), ExecMode::Persistent);
        assert_eq!(s.temporal_degree(), 2);
    }

    #[test]
    fn resilience_knobs_require_a_farm_session() {
        // each knob alone trips the validation off-farm
        assert!(msg(
            SessionBuilder::stencil("2d5pt", "8x8", "f64")
                .threads(1)
                .retry(RetryPolicy::attempts(2))
                .build()
        )
        .contains("farm"));
        assert!(msg(SessionBuilder::cg(64).threads(1).checkpoint_every(8).build())
            .contains("farm"));
        assert!(msg(
            SessionBuilder::stencil("2d5pt", "8x8", "f64")
                .threads(1)
                .command_deadline(std::time::Duration::from_secs(5))
                .build()
        )
        .contains("farm"));
        assert!(msg(
            SessionBuilder::cg(64)
                .threads(1)
                .durable(std::env::temp_dir().join("perks-session-durable-knob"))
                .build()
        )
        .contains("farm"));
        // on a farm the knobs build (and a disabled config is always fine)
        let farm = SolverFarm::spawn(1).unwrap();
        let s = SessionBuilder::stencil("2d5pt", "8x8", "f64")
            .threads(1)
            .farm(&farm)
            .checkpoint_every(4)
            .retry(RetryPolicy::attempts(2))
            .build()
            .unwrap();
        assert_eq!(s.mode(), ExecMode::Persistent);
    }

    #[test]
    fn aligned_steps_rounds_up_to_the_chunk() {
        let s = SessionBuilder::stencil("2d5pt", "8x8", "f64")
            .threads(1)
            .mode(ExecMode::Persistent)
            .build()
            .unwrap();
        // CPU substrate has chunk 1: identity
        assert_eq!(s.fused_chunk(), 1);
        assert_eq!(s.aligned_steps(7), 7);
    }

    #[test]
    fn pipelined_and_preconditioner_combos_validate() {
        // pipelined is CG-only: never a stencil mode candidate...
        assert!(msg(
            SessionBuilder::stencil("2d5pt", "8x8", "f64")
                .threads(2)
                .mode(ExecMode::Pipelined)
                .build()
        )
        .contains("not supported"));
        // ...and not an AOT/simulated CG candidate either
        assert!(msg(
            SessionBuilder::cg(64)
                .backend(Backend::simulated(a100()))
                .pipelined(true)
                .build()
        )
        .contains("not supported"));
        // preconditioning is native-CPU-only
        assert!(msg(
            SessionBuilder::cg(64)
                .backend(Backend::simulated(a100()))
                .preconditioner(Preconditioner::Jacobi)
                .build()
        )
        .contains("CPU"));
        // the valid combination builds, resolves, and runs
        let mut s = SessionBuilder::cg(64)
            .threads(2)
            .pipelined(true)
            .preconditioner(Preconditioner::BlockJacobi { block: 4 })
            .parts(3)
            .build()
            .unwrap();
        assert_eq!(s.mode(), ExecMode::Pipelined);
        let rep = s.run(8).unwrap();
        assert_eq!(rep.steps, 8);
        assert!(rep.residual.unwrap() >= 0.0);
        // pipelined(false) restores the classic persistent model
        let s = SessionBuilder::cg(64).threads(1).pipelined(false).build().unwrap();
        assert_eq!(s.mode(), ExecMode::Persistent);
    }

    #[test]
    fn pipelined_farm_sessions_validate_and_build() {
        let farm = SolverFarm::spawn(1).unwrap();
        // pipelined is CG-only, on the farm too
        assert!(msg(
            SessionBuilder::stencil("2d5pt", "8x8", "f64")
                .threads(2)
                .mode(ExecMode::Pipelined)
                .farm(&farm)
                .build()
        )
        .contains("CG-only"));
        // batching and resilience stay classic-path features
        assert!(msg(
            SessionBuilder::cg(64)
                .threads(2)
                .pipelined(true)
                .farm(&farm)
                .batch_epochs(4)
                .build()
        )
        .contains("batched"));
        assert!(msg(
            SessionBuilder::cg(64)
                .threads(2)
                .pipelined(true)
                .farm(&farm)
                .checkpoint_every(4)
                .build()
        )
        .contains("resilience"));
        // a classic farm CG session cannot silently drop a preconditioner
        assert!(msg(
            SessionBuilder::cg(64)
                .threads(2)
                .preconditioner(Preconditioner::Jacobi)
                .farm(&farm)
                .build()
        )
        .contains("pipelined"));
        // and the valid combination builds and runs on the shared workers
        let mut s = SessionBuilder::cg(64)
            .threads(2)
            .pipelined(true)
            .preconditioner(Preconditioner::Jacobi)
            .parts(3)
            .farm(&farm)
            .build()
            .unwrap();
        assert_eq!(s.mode(), ExecMode::Pipelined);
        let rep = s.run(6).unwrap();
        assert_eq!(rep.steps, 6);
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_flat_knobs_still_build() {
        // the flat knobs forward to the same fields as the typed surface
        let s = SessionBuilder::new()
            .backend(Backend::cpu(2))
            .workload(Workload::stencil("2d5pt", "8x8", "f64"))
            .temporal(1)
            .build()
            .unwrap();
        assert_eq!(s.temporal_degree(), 1);
        let mut flat = SessionBuilder::new()
            .backend(Backend::cpu(1))
            .workload(Workload::cg(64))
            .cg_parts(3)
            .cg_threaded(false)
            .build()
            .unwrap();
        let rep = flat.run(4).unwrap();
        assert_eq!(rep.steps, 4);
        // flat cross-workload misuse is still caught at build() — the
        // typed sub-builders make these states unrepresentable
        assert!(msg(
            SessionBuilder::new()
                .backend(Backend::cpu(1))
                .workload(Workload::cg(64))
                .initial_domain(vec![0.0; 64])
                .build()
        )
        .contains("initial_domain"));
        assert!(msg(
            SessionBuilder::new()
                .backend(Backend::cpu(2))
                .workload(Workload::cg(64))
                .temporal(2)
                .build()
        )
        .contains("stencil"));
    }
}
