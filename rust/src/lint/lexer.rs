//! A dependency-free, line-oriented scanner for Rust source.
//!
//! The lint rules are deliberately *heuristic*: they reason about lines
//! of code, comment text, and brace depth — not a full AST. This module
//! does the one part that must be exact for the heuristics to be sound:
//! separating **code** from **comments and literals**. Rule patterns
//! (`.unwrap()`, `unsafe`, `.lock()`, …) are matched only against code
//! with every string/char literal blanked out, so a doc example or an
//! error message can never trip a rule; marker comments
//! (`// SAFETY:`, `// hot-path: begin`, `// lint: allow(...)`) are read
//! only from comment text, so code can never forge one.
//!
//! The scanner handles line comments, nested block comments, string and
//! byte-string literals with escapes, raw strings (`r#"…"#`), char
//! literals, and the char-vs-lifetime ambiguity (`'a'` vs `'static`).
//! It is the same hand-rolled spirit as `util::json`: small, exact
//! about its state machine, and dependency-free.

/// One physical source line, split into its code and comment parts.
#[derive(Debug, Clone)]
pub struct SourceLine {
    /// Code text with comments removed and every string/char literal
    /// replaced by spaces (quotes kept, contents blanked). Safe to
    /// pattern-match without literal false positives.
    pub code: String,
    /// Concatenated text of every comment on the line (line comments,
    /// block comments, doc comments — markers `//`, `/*` stripped).
    pub comment: String,
    /// Brace depth (count of `{` minus `}` in *code*) at line start.
    pub depth_start: usize,
    /// Brace depth at line end.
    pub depth_end: usize,
}

/// Scanner state carried across lines.
enum Mode {
    Code,
    /// Inside `/* … */`; the payload is the nesting level (Rust block
    /// comments nest).
    Block(usize),
}

/// Scan a whole source file into per-line code/comment splits.
pub fn scan(src: &str) -> Vec<SourceLine> {
    let mut out = Vec::new();
    let mut mode = Mode::Code;
    let mut depth: usize = 0;
    for raw in src.lines() {
        let depth_start = depth;
        let mut code = String::with_capacity(raw.len());
        let mut comment = String::new();
        let bytes: Vec<char> = raw.chars().collect();
        let n = bytes.len();
        let mut i = 0;
        while i < n {
            match mode {
                Mode::Block(ref mut level) => {
                    if bytes[i] == '/' && i + 1 < n && bytes[i + 1] == '*' {
                        *level += 1;
                        i += 2;
                    } else if bytes[i] == '*' && i + 1 < n && bytes[i + 1] == '/' {
                        if *level == 1 {
                            mode = Mode::Code;
                        } else {
                            *level -= 1;
                        }
                        i += 2;
                    } else {
                        comment.push(bytes[i]);
                        i += 1;
                    }
                }
                Mode::Code => {
                    let c = bytes[i];
                    if c == '/' && i + 1 < n && bytes[i + 1] == '/' {
                        // line comment (incl. doc comments): rest of line
                        let mut j = i + 2;
                        while j < n && (bytes[j] == '/' || bytes[j] == '!') {
                            j += 1; // strip `///`, `//!` markers
                        }
                        comment.push_str(&raw.chars().skip(j).collect::<String>());
                        i = n;
                    } else if c == '/' && i + 1 < n && bytes[i + 1] == '*' {
                        mode = Mode::Block(1);
                        i += 2;
                    } else if c == '"' {
                        code.push('"');
                        i = skip_string(&bytes, i + 1, &mut code);
                    } else if c == 'r' && is_raw_start(&bytes, i) {
                        i = skip_raw_string(&bytes, i, &mut code);
                    } else if c == 'b' && i + 1 < n && bytes[i + 1] == '"' {
                        code.push_str("b\"");
                        i = skip_string(&bytes, i + 2, &mut code);
                    } else if c == 'b' && i + 1 < n && bytes[i + 1] == 'r' && is_raw_start(&bytes, i + 1) {
                        code.push('b');
                        i = skip_raw_string(&bytes, i + 1, &mut code);
                    } else if c == '\'' {
                        i = char_or_lifetime(&bytes, i, &mut code);
                    } else {
                        if c == '{' {
                            depth += 1;
                        } else if c == '}' {
                            depth = depth.saturating_sub(1);
                        }
                        code.push(c);
                        i += 1;
                    }
                }
            }
        }
        out.push(SourceLine {
            code,
            comment: comment.trim().to_string(),
            depth_start,
            depth_end: depth,
        });
    }
    out
}

/// Consume a (non-raw) string literal body starting just past the open
/// quote; blank the contents, keep the closing quote. A string that runs
/// past end-of-line (multi-line literal) is treated as closed at EOL —
/// good enough for the patterns the rules match, and it keeps the
/// scanner line-oriented.
fn skip_string(bytes: &[char], mut i: usize, code: &mut String) -> usize {
    let n = bytes.len();
    while i < n {
        match bytes[i] {
            '\\' => {
                code.push(' ');
                if i + 1 < n {
                    code.push(' ');
                }
                i += 2;
            }
            '"' => {
                code.push('"');
                return i + 1;
            }
            _ => {
                code.push(' ');
                i += 1;
            }
        }
    }
    i
}

/// Is `bytes[i] == 'r'` the start of a raw string (`r"`, `r#"`, …)?
fn is_raw_start(bytes: &[char], i: usize) -> bool {
    let mut j = i + 1;
    while j < bytes.len() && bytes[j] == '#' {
        j += 1;
    }
    j < bytes.len() && bytes[j] == '"'
}

/// Consume a raw string `r##"…"##` starting at the `r`; blanks contents.
/// Like `skip_string`, treats end-of-line as closing.
fn skip_raw_string(bytes: &[char], i: usize, code: &mut String) -> usize {
    let n = bytes.len();
    let mut j = i + 1;
    let mut hashes = 0;
    while j < n && bytes[j] == '#' {
        hashes += 1;
        j += 1;
    }
    code.push('r');
    for _ in 0..hashes {
        code.push('#');
    }
    code.push('"');
    j += 1; // past the open quote
    while j < n {
        if bytes[j] == '"' {
            let mut k = 0;
            while k < hashes && j + 1 + k < n && bytes[j + 1 + k] == '#' {
                k += 1;
            }
            if k == hashes {
                code.push('"');
                for _ in 0..hashes {
                    code.push('#');
                }
                return j + 1 + hashes;
            }
        }
        code.push(' ');
        j += 1;
    }
    j
}

/// Disambiguate `'a'` (char literal — blank it) from `'static`
/// (lifetime — plain code). Escapes (`'\n'`, `'\u{..}'`) are always
/// char literals.
fn char_or_lifetime(bytes: &[char], i: usize, code: &mut String) -> usize {
    let n = bytes.len();
    if i + 1 < n && bytes[i + 1] == '\\' {
        // escaped char literal: consume to the closing quote
        code.push('\'');
        let mut j = i + 2;
        while j < n && bytes[j] != '\'' {
            code.push(' ');
            j += 1;
        }
        code.push(' '); // the backslash position
        if j < n {
            code.push('\'');
            return j + 1;
        }
        return j;
    }
    // `'x'` exactly: char literal
    if i + 2 < n && bytes[i + 2] == '\'' && bytes[i + 1] != '\'' {
        code.push_str("' '");
        return i + 3;
    }
    // otherwise: lifetime (or stray quote) — pass through as code
    code.push('\'');
    i + 1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strips_line_comments() {
        let l = &scan("let x = 1; // SAFETY: fine")[0];
        assert_eq!(l.code.trim(), "let x = 1;");
        assert!(l.comment.contains("SAFETY"));
    }

    #[test]
    fn blanks_string_contents() {
        let l = &scan(r#"let s = "unsafe .unwrap()";"#)[0];
        assert!(!l.code.contains("unsafe"));
        assert!(!l.code.contains("unwrap"));
        assert!(l.code.contains('"'));
    }

    #[test]
    fn nested_block_comments() {
        let src = "a /* x /* y */ z */ b\nc";
        let lines = scan(src);
        assert_eq!(lines[0].code.replace(' ', ""), "ab");
        assert_eq!(lines[1].code, "c");
    }

    #[test]
    fn block_comment_across_lines() {
        let src = "start /* one\ntwo\nthree */ end";
        let lines = scan(src);
        assert_eq!(lines[0].code.trim(), "start");
        assert_eq!(lines[1].code, "");
        assert!(lines[1].comment.contains("two"));
        assert_eq!(lines[2].code.trim(), "end");
    }

    #[test]
    fn tracks_brace_depth() {
        let src = "fn f() {\n    if x {\n    }\n}";
        let lines = scan(src);
        assert_eq!(lines[0].depth_start, 0);
        assert_eq!(lines[0].depth_end, 1);
        assert_eq!(lines[1].depth_end, 2);
        assert_eq!(lines[3].depth_end, 0);
    }

    #[test]
    fn braces_in_strings_do_not_count() {
        let src = "let s = \"{{{\";\nlet t = 1;";
        let lines = scan(src);
        assert_eq!(lines[1].depth_start, 0);
    }

    #[test]
    fn lifetime_is_not_a_char_literal() {
        let l = &scan("fn f<'a>(x: &'a str) { x.wait(); }")[0];
        assert!(l.code.contains(".wait("));
    }

    #[test]
    fn char_literal_is_blanked() {
        let l = &scan("let c = '{';\nlet d = 1;")[0];
        assert_eq!(l.depth_end, 0);
    }

    #[test]
    fn raw_string_blanked() {
        let l = &scan(r##"let s = r#"unsafe { panic!() }"#;"##)[0];
        assert!(!l.code.contains("unsafe"));
        assert!(!l.code.contains("panic"));
    }
}
