//! `perks-lint`: project-specific static analysis for the persistent
//! runtime's concurrency invariants.
//!
//! The PERKS execution model lives and dies by hand-rolled
//! synchronization — workers parked on condvars, slot-ordered barrier
//! folds, countdown transitions under one scheduler lock — and by
//! zero-alloc hot loops. Those invariants were previously enforced only
//! dynamically (`util::counters` asserts), and one whole defect class
//! (the condvar-wake-without-shutdown-check teardown race) was found by
//! luck. This module is the static gate: a dependency-free,
//! line-oriented analysis (see [`lexer`]) with named, suppressible
//! rules, run over `rust/src/**` by `bin/perks_lint` as a blocking CI
//! step. The full invariant catalogue lives in `docs/INVARIANTS.md`.
//!
//! ## Rules
//!
//! | rule | defect class |
//! |------|--------------|
//! | `condvar-shutdown` | condvar wait loop that cannot observe teardown |
//! | `lock-order`       | acquisition order inverting a declared hierarchy |
//! | `hot-path-alloc`   | allocation inside a `// hot-path:` fenced region |
//! | `unsafe-safety`    | `unsafe` without a `// SAFETY:` justification |
//! | `no-panic`         | `unwrap`/`expect`/`panic!` in recoverable runtime code |
//! | `counter-coverage` | `util::counters` counter never incremented or never asserted |
//!
//! ## Suppression
//!
//! Any finding can be silenced on its own line or the line above with
//!
//! ```text
//! // lint: allow(rule-name) -- why this site is sound
//! ```
//!
//! The justification after `--` is mandatory: an `allow` without one is
//! itself a violation (`lint-allow`). This keeps every suppression a
//! reviewed, written-down argument — the same contract as `// SAFETY:`.

pub mod lexer;
pub mod rules;

use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

use lexer::SourceLine;

/// One lint finding.
#[derive(Debug, Clone)]
pub struct Violation {
    pub path: PathBuf,
    /// 1-based line number.
    pub line: usize,
    /// Stable rule name (usable in `lint: allow(...)`).
    pub rule: &'static str,
    pub msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.path.display(), self.line, self.rule, self.msg)
    }
}

/// Rule registry: `(name, one-line description)` for `--list-rules` and
/// the docs. Order is display order.
pub const RULES: &[(&str, &str)] = &[
    (
        "condvar-shutdown",
        "every Condvar wait loop must re-check a shutdown flag on wake (teardown race)",
    ),
    (
        "lock-order",
        "lock acquisitions must respect the file's declared `// lock-order: a < b` hierarchy",
    ),
    (
        "hot-path-alloc",
        "no allocating calls inside `// hot-path: begin/end` fenced regions",
    ),
    ("unsafe-safety", "every `unsafe` site carries a `// SAFETY:` comment"),
    (
        "no-panic",
        "no unwrap/expect/panic! in non-test runtime/, cg/pool, stencil/pool code",
    ),
    (
        "counter-coverage",
        "every util::counters counter is both incremented and asserted outside its module",
    ),
    ("lint-allow", "every `lint: allow(...)` suppression carries a `--` justification"),
];

/// A scanned file plus its suppression table — the input every per-file
/// rule consumes.
pub struct FileCtx {
    pub path: PathBuf,
    pub lines: Vec<SourceLine>,
    /// Per line (0-based): rules allowed on that line, with whether a
    /// justification was written.
    allows: Vec<Vec<(String, bool)>>,
}

impl FileCtx {
    pub fn from_source(path: impl Into<PathBuf>, src: &str) -> Self {
        let lines = lexer::scan(src);
        let allows = lines.iter().map(|l| parse_allows(&l.comment)).collect();
        Self { path: path.into(), lines, allows }
    }

    pub fn load(path: &Path) -> io::Result<Self> {
        let src = fs::read_to_string(path)?;
        Ok(Self::from_source(path, &src))
    }

    /// Is `rule` suppressed at 0-based line `i`? An allow applies to its
    /// own line and the line directly below it (so it can sit above the
    /// flagged statement).
    pub fn suppressed(&self, rule: &str, i: usize) -> bool {
        let hit = |idx: usize| self.allows[idx].iter().any(|(r, _)| r == rule);
        hit(i) || (i > 0 && hit(i - 1))
    }

    fn violation(&self, i: usize, rule: &'static str, msg: String) -> Violation {
        Violation { path: self.path.clone(), line: i + 1, rule, msg }
    }
}

/// Parse a `lint: allow(rule)` suppression. The marker must *start*
/// the comment (suppressions are standalone comments by convention —
/// prose that merely mentions the syntax does not suppress anything).
/// Returns the rule with whether a justification was written; the
/// justification is anything non-empty after a `--` separator.
fn parse_allows(comment: &str) -> Vec<(String, bool)> {
    let Some(tail) = comment.trim_start().strip_prefix("lint: allow(") else {
        return Vec::new();
    };
    let Some(close) = tail.find(')') else { return Vec::new() };
    let rule = tail[..close].trim().to_string();
    if rule.is_empty() {
        return Vec::new();
    }
    let after = &tail[close + 1..];
    let justified =
        after.find("--").map(|d| !after[d + 2..].trim().is_empty()).unwrap_or(false);
    vec![(rule, justified)]
}

/// Run every per-file rule over one file.
pub fn lint_file(ctx: &FileCtx) -> Vec<Violation> {
    let mut v = Vec::new();
    rules::condvar_shutdown(ctx, &mut v);
    rules::lock_order(ctx, &mut v);
    rules::hot_path_alloc(ctx, &mut v);
    rules::unsafe_safety(ctx, &mut v);
    rules::no_panic(ctx, &mut v);
    // a suppression without a justification is itself a finding — and is
    // deliberately not suppressible
    for (i, allows) in ctx.allows.iter().enumerate() {
        for (rule, justified) in allows {
            if !justified {
                v.push(ctx.violation(
                    i,
                    "lint-allow",
                    format!("allow({rule}) has no `-- justification`"),
                ));
            }
        }
    }
    v
}

/// Lint a source tree: every `.rs` file under `root` gets the per-file
/// rules, then the cross-file `counter-coverage` rule runs over the
/// whole tree (plus the sibling `tests/` and `benches/` dirs, where the
/// counter asserts live).
pub fn lint_root(root: &Path) -> io::Result<Vec<Violation>> {
    let mut files = Vec::new();
    walk(root, &mut files)?;
    files.sort();
    let mut out = Vec::new();
    for f in &files {
        let ctx = FileCtx::load(f)?;
        out.extend(lint_file(&ctx));
    }
    rules::counter_coverage(root, &files, &mut out)?;
    Ok(out)
}

/// Collect `.rs` files under `dir`, recursively, skipping lint fixture
/// trees (they are known-bad by construction).
pub(crate) fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    if dir.file_name().map_or(false, |n| n == "lint_fixtures") {
        return Ok(());
    }
    for entry in fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            walk(&path, out)?;
        } else if path.extension().map_or(false, |e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allow_parsing() {
        let a = parse_allows("lint: allow(no-panic) -- injected fault, test-only");
        assert_eq!(a, vec![("no-panic".to_string(), true)]);
        let b = parse_allows("lint: allow(hot-path-alloc)");
        assert_eq!(b, vec![("hot-path-alloc".to_string(), false)]);
        assert!(parse_allows("nothing here").is_empty());
    }

    #[test]
    fn unjustified_allow_is_flagged() {
        let ctx = FileCtx::from_source("x.rs", "// lint: allow(no-panic)\nlet x = 1;\n");
        let v = lint_file(&ctx);
        assert!(v.iter().any(|v| v.rule == "lint-allow"), "{v:?}");
    }

    #[test]
    fn suppression_reaches_next_line() {
        let ctx = FileCtx::from_source(
            "x.rs",
            "// lint: allow(unsafe-safety) -- covered by module invariant\nunsafe { x() };\n",
        );
        assert!(ctx.suppressed("unsafe-safety", 1));
        assert!(!ctx.suppressed("no-panic", 1));
    }
}
