//! The six perks-lint rules.
//!
//! Every rule here is a *heuristic* over the [`lexer`](super::lexer)
//! line model — deliberately so: a full AST would need a dependency or
//! thousands of lines, and the runtime's code style (one statement per
//! line, rustfmt-enforced) makes line-level reasoning reliable. Each
//! rule documents exactly what it matches so false positives are
//! predictable and suppressible with a written justification.

use std::io;
use std::path::{Path, PathBuf};

use super::lexer::SourceLine;
use super::{FileCtx, Violation};

// ---------------------------------------------------------------------
// shared text helpers
// ---------------------------------------------------------------------

fn ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Word-boundary substring search over code text.
fn has_word(code: &str, word: &str) -> bool {
    let mut from = 0;
    while let Some(pos) = code[from..].find(word) {
        let at = from + pos;
        let before_ok = at == 0 || !ident_char(code[..at].chars().next_back().unwrap());
        let after = at + word.len();
        let after_ok = after >= code.len() || !ident_char(code[after..].chars().next().unwrap());
        if before_ok && after_ok {
            return true;
        }
        from = after;
    }
    false
}

/// The dotted receiver chain ending just before byte `at` in `code`,
/// e.g. `sh.work_cv` for `sh.work_cv.wait(...)` with `at` pointing at
/// the final `.`.
fn receiver_before(code: &str, at: usize) -> &str {
    let bytes = code.as_bytes();
    let mut start = at;
    while start > 0 {
        let c = bytes[start - 1] as char;
        if ident_char(c) || c == '.' {
            start -= 1;
        } else {
            break;
        }
    }
    &code[start..at]
}

/// Last `.`-separated segment of a receiver chain.
fn last_segment(recv: &str) -> &str {
    recv.rsplit('.').next().unwrap_or(recv)
}

/// First line after `open` whose end depth returns to at most the depth
/// the block at `open` started from — i.e. the line closing that block.
fn block_end(lines: &[SourceLine], open: usize) -> usize {
    let base = lines[open].depth_start;
    for (k, line) in lines.iter().enumerate().skip(open + 1) {
        if line.depth_end <= base {
            return k;
        }
    }
    lines.len() - 1
}

/// Innermost enclosing `loop`/`while`/`for` block of line `i`:
/// `(header_line, end_line)`. Walks outward one block at a time; a block
/// whose header line carries no loop keyword is skipped (plain scope,
/// `if`, match arm, …).
fn enclosing_loop(lines: &[SourceLine], i: usize) -> Option<(usize, usize)> {
    let mut level = lines[i].depth_start;
    for j in (0..i).rev() {
        if lines[j].depth_start < level && lines[j].depth_end >= lines[j].depth_start {
            // line j opened the block we are inside of
            let header = &lines[j].code;
            if has_word(header, "loop") || has_word(header, "while") || has_word(header, "for") {
                return Some((j, block_end(lines, j)));
            }
            level = lines[j].depth_start;
            if level == 0 {
                return None;
            }
        }
    }
    None
}

/// 0-based mask of lines inside `#[cfg(test)]`-gated items (the
/// attribute line through the close of the item's block).
fn test_mask(lines: &[SourceLine]) -> Vec<bool> {
    let mut mask = vec![false; lines.len()];
    let mut i = 0;
    while i < lines.len() {
        if lines[i].code.contains("#[cfg(test)]") {
            let base = lines[i].depth_start;
            // find where the gated item's block opens (attribute and
            // item header may span a few lines), then mark through its
            // close; an unbraced item (e.g. a gated `use`) marks itself
            let mut open = None;
            for (k, line) in lines.iter().enumerate().skip(i).take(8) {
                if line.depth_end > base {
                    open = Some(k);
                    break;
                }
            }
            let end = match open {
                Some(k) => block_end(lines, k),
                None => i,
            };
            for m in mask.iter_mut().take(end + 1).skip(i) {
                *m = true;
            }
            i = end + 1;
        } else {
            i += 1;
        }
    }
    mask
}

// ---------------------------------------------------------------------
// rule 1: condvar-shutdown
// ---------------------------------------------------------------------

/// Words whose presence in a wait loop's body counts as "re-checks a
/// shutdown flag". Substring match, so `g.shutdown`, `shutdown_flag`,
/// `stop_requested` all qualify.
const SHUTDOWN_WORDS: &[&str] = &["shutdown", "stop"];

/// Every `Condvar::wait`/`wait_timeout`/`wait_while` call — recognized
/// by its receiver naming a condvar (`*cv*`/`*condvar*`) — must sit in
/// a loop whose body also consults a shutdown flag. This is the PR-5
/// teardown-race class: a worker parked across epoch stamps misses
/// teardown forever if the wake path only checks the work predicate.
pub(super) fn condvar_shutdown(ctx: &FileCtx, out: &mut Vec<Violation>) {
    const CALLS: &[&str] = &[".wait(", ".wait_timeout(", ".wait_while("];
    for (i, line) in ctx.lines.iter().enumerate() {
        let code = &line.code;
        let Some(at) = CALLS.iter().filter_map(|c| code.find(c)).min() else { continue };
        let mut recv = last_segment(receiver_before(code, at)).to_ascii_lowercase();
        if recv.is_empty() && i > 0 {
            // rustfmt splits long chains: `sh.done_cv` / `.wait_timeout(..)`
            // — the receiver is the previous line's trailing segment
            let prev = ctx.lines[i - 1].code.trim_end();
            recv = last_segment(receiver_before(prev, prev.len())).to_ascii_lowercase();
        }
        if !(recv.contains("cv") || recv.contains("condvar")) {
            continue; // not a condvar (std Barrier::wait, futures, …)
        }
        if ctx.suppressed("condvar-shutdown", i) {
            continue;
        }
        let ok = match enclosing_loop(&ctx.lines, i) {
            Some((start, end)) => ctx.lines[start..=end]
                .iter()
                .any(|l| SHUTDOWN_WORDS.iter().any(|w| l.code.to_ascii_lowercase().contains(w))),
            None => false,
        };
        if !ok {
            out.push(ctx.violation(
                i,
                "condvar-shutdown",
                format!(
                    "condvar wait on `{recv}` in a loop that never re-checks a \
                     shutdown/stop flag (teardown can strand this thread)"
                ),
            ));
        }
    }
}

// ---------------------------------------------------------------------
// rule 2: lock-order
// ---------------------------------------------------------------------

/// A currently-held lock guard.
struct Hold {
    name: String,
    rank: usize,
    depth: usize,
    guard: Option<String>,
}

/// Enforce the file's declared lock hierarchy. A file opts in with
///
/// ```text
/// // lock-order: sched < tenant < slab
/// ```
///
/// naming mutex *fields* in acquisition order (lower first). Every
/// `name.lock()` whose receiver's final segment is a declared name is
/// tracked as a hold until its scope closes (brace depth drops below
/// the acquisition depth) or the guard is explicitly `drop(..)`ed.
/// Acquiring a lower- or equally-ranked lock while a higher one is held
/// is an inversion (or a self-deadlock) and is flagged.
pub(super) fn lock_order(ctx: &FileCtx, out: &mut Vec<Violation>) {
    // the declaration must *start* its comment, like every lint marker
    let mut ranks: Vec<String> = Vec::new();
    for line in &ctx.lines {
        if let Some(decl) = line.comment.trim_start().strip_prefix("lock-order:") {
            ranks = decl
                .split('<')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty() && s.chars().all(ident_char))
                .collect();
            break;
        }
    }
    if ranks.len() < 2 {
        return; // no (meaningful) hierarchy declared
    }
    let rank_of = |name: &str| ranks.iter().position(|r| r == name);
    let mut holds: Vec<Hold> = Vec::new();
    for (i, line) in ctx.lines.iter().enumerate() {
        // scope-based release
        holds.retain(|h| line.depth_start >= h.depth);
        // explicit drop(guard) release
        if line.code.contains("drop(") {
            holds.retain(|h| match &h.guard {
                Some(g) => !line.code.contains(&format!("drop({g})")),
                None => true,
            });
        }
        let code = &line.code;
        let mut from = 0;
        while let Some(pos) = code[from..].find(".lock()") {
            let at = from + pos;
            from = at + ".lock()".len();
            let name = last_segment(receiver_before(code, at)).to_string();
            let Some(rank) = rank_of(&name) else { continue };
            if !ctx.suppressed("lock-order", i) {
                for h in &holds {
                    if h.rank > rank {
                        out.push(ctx.violation(
                            i,
                            "lock-order",
                            format!(
                                "acquiring `{name}` while holding `{}` inverts the declared \
                                 order `{}`",
                                h.name,
                                ranks.join(" < "),
                            ),
                        ));
                    } else if h.rank == rank {
                        out.push(ctx.violation(
                            i,
                            "lock-order",
                            format!("re-acquiring `{name}` while already held (self-deadlock)"),
                        ));
                    }
                }
            }
            let guard = line
                .code
                .trim_start()
                .strip_prefix("let ")
                .map(|r| r.trim_start().trim_start_matches("mut "))
                .map(|r| r.chars().take_while(|&c| ident_char(c)).collect::<String>())
                .filter(|g| !g.is_empty());
            holds.push(Hold { name, rank, depth: line.depth_start, guard });
        }
    }
}

// ---------------------------------------------------------------------
// rule 3: hot-path-alloc
// ---------------------------------------------------------------------

/// Allocating (or otherwise per-iteration-cost) constructs banned
/// between `// hot-path: begin` and `// hot-path: end` markers.
const BANNED_ALLOCS: &[&str] = &[
    "Vec::new",
    "vec!",
    ".to_vec()",
    ".clone()",
    ".collect()",
    ".collect::",
    "Box::new",
    "format!",
    ".to_string()",
    "String::new",
    "String::from",
    "with_capacity",
    "Arc::new",
    "Rc::new",
];

/// The pool/farm advance loops are the product: the paper's speedup is
/// exactly "nothing allocates, nothing spawns, per iteration". The
/// fences make that reviewable: any allocating call inside one is
/// flagged unless suppressed with a justification (e.g. a cold error
/// path that only runs once on failure).
pub(super) fn hot_path_alloc(ctx: &FileCtx, out: &mut Vec<Violation>) {
    // markers must *start* the comment — prose that merely mentions the
    // syntax (like this module's docs) is not a fence
    let mut open: Option<usize> = None;
    for (i, line) in ctx.lines.iter().enumerate() {
        if line.comment.trim_start().starts_with("hot-path: begin") {
            if let Some(prev) = open {
                out.push(ctx.violation(
                    i,
                    "hot-path-alloc",
                    format!("nested `hot-path: begin` (previous fence opened on line {})", prev + 1),
                ));
            }
            open = Some(i);
            continue;
        }
        if line.comment.trim_start().starts_with("hot-path: end") {
            if open.is_none() {
                out.push(ctx.violation(
                    i,
                    "hot-path-alloc",
                    "`hot-path: end` without a matching begin".to_string(),
                ));
            }
            open = None;
            continue;
        }
        if open.is_none() || ctx.suppressed("hot-path-alloc", i) {
            continue;
        }
        for b in BANNED_ALLOCS {
            if line.code.contains(b) {
                out.push(ctx.violation(
                    i,
                    "hot-path-alloc",
                    format!("`{}` inside a hot-path fence", b.trim_matches('.')),
                ));
            }
        }
    }
    if let Some(prev) = open {
        out.push(ctx.violation(
            prev,
            "hot-path-alloc",
            "`hot-path: begin` fence never closed".to_string(),
        ));
    }
}

// ---------------------------------------------------------------------
// rule 4: unsafe-safety
// ---------------------------------------------------------------------

/// How many lines above an `unsafe` site a `SAFETY` comment may sit
/// (doc comments on `unsafe fn`s span a few lines).
const SAFETY_WINDOW: usize = 6;

/// Every `unsafe` keyword — block, fn, or impl — needs a comment
/// containing `SAFETY` on the same line or within the preceding few
/// lines. The comment *is* the proof obligation: the runtime's unsafe
/// sites are all justified by a protocol (claim/complete handshake,
/// band ownership between barriers), and the argument must be written
/// where the site is.
pub(super) fn unsafe_safety(ctx: &FileCtx, out: &mut Vec<Violation>) {
    for (i, line) in ctx.lines.iter().enumerate() {
        if !has_word(&line.code, "unsafe") || ctx.suppressed("unsafe-safety", i) {
            continue;
        }
        let lo = i.saturating_sub(SAFETY_WINDOW);
        let covered =
            ctx.lines[lo..=i].iter().any(|l| l.comment.contains("SAFETY"));
        if !covered {
            out.push(ctx.violation(
                i,
                "unsafe-safety",
                "`unsafe` without a `// SAFETY:` comment nearby".to_string(),
            ));
        }
    }
}

// ---------------------------------------------------------------------
// rule 5: no-panic
// ---------------------------------------------------------------------

/// Is this file in the no-panic scope: code the resilience layer must
/// be able to recover, where a panic means a stranded countdown or a
/// poisoned pool instead of a structured `Error::Fault`.
fn no_panic_scope(path: &Path) -> bool {
    let p = path.to_string_lossy().replace('\\', "/");
    p.contains("/runtime/") || p.ends_with("cg/pool.rs") || p.ends_with("stencil/pool.rs")
}

/// No `.unwrap()` / `.expect(` / `panic!` in non-test runtime, cg-pool,
/// or stencil-pool code. `unwrap_or_else(|p| p.into_inner())` — the
/// repo-wide poison-recovery idiom — is *not* a panic site and is not
/// matched. `unreachable!` on exhaustive phase matches is likewise out
/// of scope (it documents impossibility, not a recoverable failure).
pub(super) fn no_panic(ctx: &FileCtx, out: &mut Vec<Violation>) {
    if !no_panic_scope(&ctx.path) {
        return;
    }
    let mask = test_mask(&ctx.lines);
    for (i, line) in ctx.lines.iter().enumerate() {
        if mask[i] || ctx.suppressed("no-panic", i) {
            continue;
        }
        let code = &line.code;
        for pat in [".unwrap()", ".expect(", "panic!"] {
            if code.contains(pat) {
                out.push(ctx.violation(
                    i,
                    "no-panic",
                    format!(
                        "`{}` in recoverable runtime code (surface a structured Error instead)",
                        pat.trim_matches('.'),
                    ),
                ));
            }
        }
    }
}

// ---------------------------------------------------------------------
// rule 6: counter-coverage
// ---------------------------------------------------------------------

/// Cross-file rule: every counter declared in `util/counters.rs` (one
/// `note_*` incrementer + one getter) must be incremented somewhere
/// *and* read/asserted somewhere outside the counters module itself —
/// a counter nobody asserts is an invariant nobody checks. The scan
/// covers `root` plus the sibling `tests/` and `benches/` trees, where
/// the integration asserts live.
pub(super) fn counter_coverage(
    root: &Path,
    root_files: &[PathBuf],
    out: &mut Vec<Violation>,
) -> io::Result<()> {
    let counters_path = root.join("util").join("counters.rs");
    if !counters_path.exists() {
        return Ok(());
    }
    let ctr = FileCtx::load(&counters_path)?;
    // declared counters: (name, 0-based decl line)
    let mut names: Vec<(String, usize)> = Vec::new();
    for (i, line) in ctr.lines.iter().enumerate() {
        let code = line.code.trim();
        if let Some(rest) = code.strip_prefix("pub fn note_") {
            let name: String = rest.chars().take_while(|&c| ident_char(c)).collect();
            if !name.is_empty() {
                names.push((name, i));
            }
        }
    }
    // scan set: the linted tree plus sibling tests/ and benches/
    let mut files: Vec<PathBuf> = root_files.to_vec();
    if let Some(parent) = root.parent() {
        for sib in ["tests", "benches"] {
            let dir = parent.join(sib);
            if dir.is_dir() {
                super::walk(&dir, &mut files)?;
            }
        }
    }
    let mut bodies = Vec::new();
    for f in &files {
        if f.ends_with(Path::new("util").join("counters.rs").as_path()) {
            continue;
        }
        let ctx = FileCtx::load(f)?;
        let code: String =
            ctx.lines.iter().map(|l| l.code.as_str()).collect::<Vec<_>>().join("\n");
        bodies.push(code);
    }
    for (name, decl) in names {
        let incremented = bodies.iter().any(|b| b.contains(&format!("note_{name}(")));
        let asserted = bodies.iter().any(|b| has_word(b, &name) && b.contains(&format!("{name}()")));
        if !incremented {
            out.push(Violation {
                path: counters_path.clone(),
                line: decl + 1,
                rule: "counter-coverage",
                msg: format!("counter `{name}` is never incremented outside util::counters"),
            });
        }
        if !asserted {
            out.push(Violation {
                path: counters_path.clone(),
                line: decl + 1,
                rule: "counter-coverage",
                msg: format!(
                    "counter `{name}` is never read/asserted outside util::counters \
                     (an unasserted counter checks nothing)"
                ),
            });
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::lint_file;

    fn lint(src: &str) -> Vec<Violation> {
        lint_file(&FileCtx::from_source("src/runtime/x.rs", src))
    }

    fn rules_of(v: &[Violation]) -> Vec<&str> {
        v.iter().map(|v| v.rule).collect()
    }

    #[test]
    fn wait_without_shutdown_flagged() {
        let src = "fn f() {\n    loop {\n        g = work_cv.wait(g);\n    }\n}\n";
        assert!(rules_of(&lint(src)).contains(&"condvar-shutdown"), "{:?}", lint(src));
    }

    #[test]
    fn wait_with_shutdown_passes() {
        let src = "fn f() {\n    loop {\n        if g.shutdown { return; }\n        g = work_cv.wait(g);\n    }\n}\n";
        assert!(!rules_of(&lint(src)).contains(&"condvar-shutdown"));
    }

    #[test]
    fn wait_outside_loop_flagged() {
        let src = "fn f() {\n    g = done_cv.wait(g);\n}\n";
        assert!(rules_of(&lint(src)).contains(&"condvar-shutdown"));
    }

    #[test]
    fn non_condvar_wait_ignored() {
        let src = "fn f() {\n    barrier.wait();\n    handle.wait();\n}\n";
        assert!(!rules_of(&lint(src)).contains(&"condvar-shutdown"));
    }

    #[test]
    fn lock_inversion_flagged() {
        let src = "// lock-order: sched < slab\nfn f() {\n    let g = slab.lock();\n    let h = sched.lock();\n}\n";
        let v = lint(src);
        assert!(rules_of(&v).contains(&"lock-order"), "{v:?}");
    }

    #[test]
    fn lock_in_declared_order_passes() {
        let src = "// lock-order: sched < slab\nfn f() {\n    let g = sched.lock();\n    let h = slab.lock();\n}\n";
        assert!(!rules_of(&lint(src)).contains(&"lock-order"));
    }

    #[test]
    fn lock_released_by_scope_and_drop() {
        let src = "// lock-order: sched < slab\nfn f() {\n    {\n        let g = slab.lock();\n    }\n    let h = sched.lock();\n    drop(h);\n    let g2 = slab.lock();\n    let h2 = slab.lock();\n}\n";
        // h dropped before g2; but h2 re-acquires slab while g2 held
        let v = lint(src);
        assert_eq!(v.iter().filter(|v| v.rule == "lock-order").count(), 1, "{v:?}");
    }

    #[test]
    fn hot_path_alloc_flagged_and_fence_balance() {
        let src = "fn f() {\n    // hot-path: begin\n    let v = Vec::new();\n    let s = format!(\"x\");\n    // hot-path: end\n}\n";
        let v = lint(src);
        assert_eq!(v.iter().filter(|v| v.rule == "hot-path-alloc").count(), 2, "{v:?}");
        let unclosed = "fn f() {\n    // hot-path: begin\n}\n";
        assert!(rules_of(&lint(unclosed)).contains(&"hot-path-alloc"));
    }

    #[test]
    fn hot_path_suppression_honored() {
        let src = "fn f() {\n    // hot-path: begin\n    // lint: allow(hot-path-alloc) -- cold error path\n    let s = format!(\"x\");\n    // hot-path: end\n}\n";
        let v = lint(src);
        assert!(!rules_of(&v).contains(&"hot-path-alloc"), "{v:?}");
    }

    #[test]
    fn unsafe_without_safety_flagged() {
        let src = "fn f() {\n    unsafe { g() };\n}\n";
        assert!(rules_of(&lint(src)).contains(&"unsafe-safety"));
        let ok = "fn f() {\n    // SAFETY: g is only called while parked\n    unsafe { g() };\n}\n";
        assert!(!rules_of(&lint(ok)).contains(&"unsafe-safety"));
    }

    #[test]
    fn safety_in_doc_comment_counts() {
        let src = "/// Run one shard. SAFETY: claimed by one worker.\npub unsafe fn run(&self) {}\n";
        assert!(!rules_of(&lint(src)).contains(&"unsafe-safety"));
    }

    #[test]
    fn no_panic_in_scope_flagged() {
        let src = "fn f() {\n    let x = y.unwrap();\n    let z = w.expect(\"set\");\n    panic!(\"boom\");\n}\n";
        let v = lint(src);
        assert_eq!(v.iter().filter(|v| v.rule == "no-panic").count(), 3, "{v:?}");
    }

    #[test]
    fn no_panic_skips_tests_poison_idiom_and_out_of_scope() {
        let src = "fn f() {\n    let g = m.lock().unwrap_or_else(|p| p.into_inner());\n}\n#[cfg(test)]\nmod tests {\n    fn t() { x.unwrap(); }\n}\n";
        assert!(!rules_of(&lint(src)).contains(&"no-panic"));
        let out_of_scope = lint_file(&FileCtx::from_source(
            "src/util/json.rs",
            "fn f() { x.unwrap(); }\n",
        ));
        assert!(!rules_of(&out_of_scope).contains(&"no-panic"));
    }

    #[test]
    fn string_literals_never_trip_rules() {
        let src = "fn f() {\n    let s = \"unsafe panic! .unwrap() Vec::new\";\n}\n";
        assert!(lint(src).is_empty(), "{:?}", lint(src));
    }
}
