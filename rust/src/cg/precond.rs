//! Preconditioners for the CG solvers: Jacobi and block-Jacobi.
//!
//! Both preconditioners are **row-local by construction**, which is what
//! lets them run *inside* the fused persistent passes (pool workers and
//! farm shards apply them to their own rows only, with no extra barrier):
//!
//! * Jacobi — `M⁻¹ = diag(A)⁻¹`; applying it touches one row at a time.
//! * Block-Jacobi — dense Cholesky solves over principal sub-blocks of
//!   `A`. The sub-blocks are carved **within** each reduction block of
//!   `partition(n, parts)` (never straddling one), so every sub-block is
//!   owned by exactly one pool worker / farm shard and the apply needs no
//!   cross-owner reads. As a corollary the operator `M⁻¹` itself depends
//!   on `parts` (the deterministic-reduction block count) but **not** on
//!   the worker count — the same property the dot-product folds have —
//!   so preconditioned iterates stay bit-identical at every thread count.
//!
//! The resolved operator ([`Precond`]) is built once per `prepare` and
//! shared read-only by the resident workers; the spec ([`Preconditioner`])
//! is the session-facing knob (`CgSessionBuilder::preconditioner`).

use crate::error::{Error, Result};
use crate::sparse::csr::Csr;

/// Session-facing preconditioner selection (the spec; resolve with
/// [`Precond::build`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Preconditioner {
    /// Identity: plain CG. The resolved apply is a copy, so the pipelined
    /// recurrences run unchanged (u = r, m = w).
    #[default]
    None,
    /// Diagonal scaling `M = diag(A)`.
    Jacobi,
    /// Dense Cholesky solves over principal sub-blocks of at most `block`
    /// rows, carved within each reduction block.
    BlockJacobi {
        /// Maximum sub-block size (rows); must be >= 1.
        block: usize,
    },
}

impl Preconditioner {
    /// Short name for reports/logs.
    pub fn name(&self) -> &'static str {
        match self {
            Preconditioner::None => "none",
            Preconditioner::Jacobi => "jacobi",
            Preconditioner::BlockJacobi { .. } => "block-jacobi",
        }
    }

    /// Extra n-length vector passes per iteration the apply costs, for
    /// `CpuCg::bytes_per_iter` accounting: Jacobi streams `minv` once,
    /// block-Jacobi's two triangular solves stream the factors twice.
    pub fn extra_passes(&self) -> f64 {
        match self {
            Preconditioner::None => 0.0,
            Preconditioner::Jacobi => 1.0,
            Preconditioner::BlockJacobi { .. } => 2.0,
        }
    }
}

/// One factored sub-block of the block-Jacobi operator: rows
/// `[start, start + size)`, lower-triangular Cholesky factor `L` stored
/// row-major (`size * size`, upper half unused).
#[derive(Clone, Debug)]
struct CholBlock {
    start: usize,
    size: usize,
    l: Vec<f64>,
}

/// A resolved, row-local preconditioner operator. Cheap to share
/// (`Arc<Precond>`) and immutable after construction.
#[derive(Clone, Debug)]
pub struct Precond {
    spec: Preconditioner,
    /// Jacobi: 1/diag(A); empty otherwise.
    minv: Vec<f64>,
    /// Block-Jacobi: factored sub-blocks sorted by `start`, tiling
    /// exactly the row ranges of the reduction blocks; empty otherwise.
    chol: Vec<CholBlock>,
}

impl Precond {
    /// Resolve `spec` against `a` and the deterministic reduction blocks
    /// (`partition(n, parts)` — the same blocks the dot-product folds
    /// use). Fails on a non-positive diagonal (Jacobi) or a Cholesky
    /// breakdown (block-Jacobi), both of which certify the matrix is not
    /// SPD before the solver ever runs.
    pub fn build(spec: Preconditioner, a: &Csr, blocks: &[(usize, usize)]) -> Result<Self> {
        match spec {
            Preconditioner::None => Ok(Self { spec, minv: Vec::new(), chol: Vec::new() }),
            Preconditioner::Jacobi => {
                let mut minv = vec![0.0; a.n_rows];
                for (i, m) in minv.iter_mut().enumerate() {
                    let d = diag_of(a, i);
                    if !(d > 0.0) {
                        return Err(Error::Solver(format!(
                            "Jacobi preconditioner needs a positive diagonal (row {i} has {d})"
                        )));
                    }
                    *m = 1.0 / d;
                }
                Ok(Self { spec, minv, chol: Vec::new() })
            }
            Preconditioner::BlockJacobi { block } => {
                if block == 0 {
                    return Err(Error::Solver(
                        "block-Jacobi block size must be at least 1".into(),
                    ));
                }
                let mut chol = Vec::new();
                for &(s, l) in blocks {
                    let mut off = 0;
                    while off < l {
                        let size = block.min(l - off);
                        chol.push(factor_block(a, s + off, size)?);
                        off += size;
                    }
                }
                Ok(Self { spec, minv: Vec::new(), chol })
            }
        }
    }

    /// The spec this operator was built from.
    pub fn spec(&self) -> Preconditioner {
        self.spec
    }

    /// Is this the identity (no preconditioning)?
    pub fn is_identity(&self) -> bool {
        matches!(self.spec, Preconditioner::None)
    }

    /// Apply `dst[s..s+l] = (M⁻¹ src)[s..s+l]` where `[s, s+l)` is a
    /// union of whole reduction blocks (the caller's owned rows). Reads
    /// only `src[s..s+l]` and writes only `dst[s..s+l]` — the row-local
    /// contract that lets concurrent owners apply disjoint ranges.
    ///
    /// # Safety
    ///
    /// `src` and `dst` must be valid for the full vector length, the
    /// caller must own rows `[s, s+l)` of `dst` exclusively, and no
    /// concurrent writer may touch `src[s..s+l]` during the call.
    pub unsafe fn apply_raw(&self, src: *const f64, dst: *mut f64, s: usize, l: usize) {
        match self.spec {
            Preconditioner::None => {
                for i in s..s + l {
                    dst.add(i).write(src.add(i).read());
                }
            }
            Preconditioner::Jacobi => {
                for i in s..s + l {
                    dst.add(i).write(self.minv[i] * src.add(i).read());
                }
            }
            Preconditioner::BlockJacobi { .. } => {
                // sub-blocks tile the reduction blocks exactly, so the
                // partition-point search finds the caller's sub-block run
                let lo = self.chol.partition_point(|b| b.start < s);
                let hi = self.chol.partition_point(|b| b.start < s + l);
                for b in &self.chol[lo..hi] {
                    solve_block(b, src, dst);
                }
            }
        }
    }

    /// Safe whole-vector apply for the serial paths: `dst = M⁻¹ src`.
    pub fn apply(&self, src: &[f64], dst: &mut [f64]) {
        // SAFETY: exclusive &mut dst and shared &src uphold the raw
        // contract trivially for the full row range on one thread.
        unsafe { self.apply_raw(src.as_ptr(), dst.as_mut_ptr(), 0, src.len()) }
    }
}

fn diag_of(a: &Csr, i: usize) -> f64 {
    let (cols, vals) = a.row(i);
    match cols.binary_search(&i) {
        Ok(k) => vals[k],
        Err(_) => 0.0,
    }
}

/// Extract the dense principal sub-block `A[start..start+size)²` and
/// Cholesky-factor it in place (lower triangle).
fn factor_block(a: &Csr, start: usize, size: usize) -> Result<CholBlock> {
    let mut m = vec![0.0; size * size];
    for li in 0..size {
        let (cols, vals) = a.row(start + li);
        for (&c, &v) in cols.iter().zip(vals) {
            if c >= start && c < start + size {
                m[li * size + (c - start)] = v;
            }
        }
    }
    // in-place Cholesky: m becomes L (row-major, lower)
    for j in 0..size {
        let mut d = m[j * size + j];
        for k in 0..j {
            d -= m[j * size + k] * m[j * size + k];
        }
        if !(d > 0.0) || !d.is_finite() {
            return Err(Error::Solver(format!(
                "block-Jacobi Cholesky breakdown at row {} (pivot {d}): matrix not positive definite",
                start + j
            )));
        }
        let dj = d.sqrt();
        m[j * size + j] = dj;
        for i in j + 1..size {
            let mut s = m[i * size + j];
            for k in 0..j {
                s -= m[i * size + k] * m[j * size + k];
            }
            m[i * size + j] = s / dj;
        }
    }
    Ok(CholBlock { start, size, l: m })
}

/// Solve `L Lᵀ z = src_block` for one factored sub-block, writing `z`
/// into `dst` rows. Deterministic: fixed forward/backward substitution
/// order, no data-dependent branching.
///
/// # Safety
///
/// Caller (via [`Precond::apply_raw`]) guarantees exclusive ownership of
/// the block's `dst` rows and no concurrent writer of its `src` rows.
unsafe fn solve_block(b: &CholBlock, src: *const f64, dst: *mut f64) {
    let n = b.size;
    // forward solve L y = src, staging y in dst rows
    for i in 0..n {
        let mut acc = src.add(b.start + i).read();
        for k in 0..i {
            acc -= b.l[i * n + k] * dst.add(b.start + k).read();
        }
        dst.add(b.start + i).write(acc / b.l[i * n + i]);
    }
    // backward solve Lᵀ z = y, in place
    for i in (0..n).rev() {
        let mut acc = dst.add(b.start + i).read();
        for k in i + 1..n {
            acc -= b.l[k * n + i] * dst.add(b.start + k).read();
        }
        dst.add(b.start + i).write(acc / b.l[i * n + i]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;
    use crate::stencil::parallel::partition;

    fn spmv_dense(a: &Csr, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; a.n_rows];
        a.spmv_gold(x, &mut y);
        y
    }

    #[test]
    fn jacobi_inverts_the_diagonal() {
        let a = gen::poisson2d(6);
        let blocks = partition(a.n_rows, 4);
        let pc = Precond::build(Preconditioner::Jacobi, &a, &blocks).unwrap();
        let src: Vec<f64> = (0..a.n_rows).map(|i| (i % 7) as f64 - 3.0).collect();
        let mut dst = vec![0.0; a.n_rows];
        pc.apply(&src, &mut dst);
        for i in 0..a.n_rows {
            assert_eq!(dst[i].to_bits(), (src[i] * 0.25).to_bits(), "row {i}");
        }
    }

    #[test]
    fn block_jacobi_solves_each_subblock_exactly() {
        let a = gen::clustered_spd(96, 5, 8, 11).unwrap();
        let blocks = partition(a.n_rows, 4);
        let pc = Precond::build(Preconditioner::BlockJacobi { block: 6 }, &a, &blocks).unwrap();
        let src = gen::rhs(a.n_rows, 3);
        let mut z = vec![0.0; a.n_rows];
        pc.apply(&src, &mut z);
        // check M z == src block-by-block: M is block-diagonal, so A's
        // sub-block times z's sub-block must reproduce src's sub-block
        for b in &pc.chol {
            for li in 0..b.size {
                let row = b.start + li;
                let (cols, vals) = a.row(row);
                let mut acc = 0.0;
                for (&c, &v) in cols.iter().zip(vals) {
                    if c >= b.start && c < b.start + b.size {
                        acc += v * z[c];
                    }
                }
                assert!((acc - src[row]).abs() < 1e-9, "row {row}: {acc} vs {}", src[row]);
            }
        }
    }

    #[test]
    fn subblocks_never_straddle_reduction_blocks() {
        let a = gen::poisson2d(7); // n = 49, awkward split
        let blocks = partition(a.n_rows, 5);
        let pc = Precond::build(Preconditioner::BlockJacobi { block: 8 }, &a, &blocks).unwrap();
        for cb in &pc.chol {
            let inside = blocks
                .iter()
                .any(|&(s, l)| cb.start >= s && cb.start + cb.size <= s + l);
            assert!(inside, "sub-block at {} size {} straddles", cb.start, cb.size);
        }
        // and they tile the whole index space
        let total: usize = pc.chol.iter().map(|b| b.size).sum();
        assert_eq!(total, a.n_rows);
    }

    #[test]
    fn identity_apply_is_a_copy_and_row_local_ranges_compose() {
        let a = gen::tridiag(20);
        let blocks = partition(20, 4);
        let pc = Precond::build(Preconditioner::None, &a, &blocks).unwrap();
        let src = gen::rhs(20, 5);
        let mut dst = vec![9.0; 20];
        // apply per reduction block, as the pool workers do
        for &(s, l) in &blocks {
            // SAFETY: single-threaded; disjoint row ranges per call.
            unsafe { pc.apply_raw(src.as_ptr(), dst.as_mut_ptr(), s, l) }
        }
        assert_eq!(src, dst);
    }

    #[test]
    fn non_spd_inputs_are_rejected_at_build() {
        let blocks = partition(2, 1);
        let bad = Csr::from_coo(2, 2, vec![(0, 0, -1.0), (1, 1, 2.0)]).unwrap();
        let err = Precond::build(Preconditioner::Jacobi, &bad, &blocks).unwrap_err();
        assert!(format!("{err}").contains("positive diagonal"), "{err}");
        let err =
            Precond::build(Preconditioner::BlockJacobi { block: 2 }, &bad, &blocks).unwrap_err();
        assert!(format!("{err}").contains("not positive definite"), "{err}");
        let err = Precond::build(Preconditioner::BlockJacobi { block: 0 }, &bad, &blocks)
            .unwrap_err();
        assert!(format!("{err}").contains("at least 1"), "{err}");
    }

    #[test]
    fn block_jacobi_beats_jacobi_on_a_coupled_block() {
        // a 2x2-coupled SPD matrix: block-Jacobi with block >= 2 inverts
        // it exactly, Jacobi does not
        let a = Csr::from_coo(
            2,
            2,
            vec![(0, 0, 4.0), (0, 1, 1.0), (1, 0, 1.0), (1, 1, 3.0)],
        )
        .unwrap();
        let blocks = partition(2, 1);
        let pc = Precond::build(Preconditioner::BlockJacobi { block: 2 }, &a, &blocks).unwrap();
        let src = vec![1.0, 2.0];
        let mut z = vec![0.0; 2];
        pc.apply(&src, &mut z);
        let back = spmv_dense(&a, &z);
        assert!((back[0] - 1.0).abs() < 1e-12 && (back[1] - 2.0).abs() < 1e-12, "{back:?}");
    }
}
