//! Pipelined preconditioned CG: **one barrier per iteration**.
//!
//! Classic pooled CG ([`crate::cg::pool`]) pays two slot-ordered
//! reduction generations per iteration — p·Ap, then r·r — so on small
//! systems sync cost, not bandwidth, bounds the iteration rate. This
//! module implements the pipelined/fused formulation (Ghysels–Vanroose;
//! cf. the pipelined solvers surveyed by Rupp et al., arXiv 1410.4054):
//! auxiliary recurrences for `w = A u`, `s = A p`, `q = M⁻¹ s`, `z = A q`
//! let the three dot products of an iteration (γ = r·u, δ = w·u, and the
//! convergence norm r·r) fold through a **single**
//! [`GridBarrier::sync_reduce`] generation, overlapped with the SpMV.
//!
//! # Recurrences
//!
//! With `u = M⁻¹ r`, `w = A u`, `m = M⁻¹ w`, `n = A m` and
//! `γ = (r, u)`, `δ = (w, u)`:
//!
//! ```text
//! β_i = γ_i / γ_{i-1}                 (0 on the first iteration)
//! α_i = γ_i / (δ_i - β_i γ_i / α_{i-1})   (γ_i / δ_i first)
//! z ← n + β z;  q ← m + β q;  s ← w + β s;  p ← u + β p
//! x ← x + α p;  r ← r - α s;  u ← u - α q;  w ← w - α z
//! m' = M⁻¹ w
//! ```
//!
//! Every vector update is row-local, the SpMV `n = A m` is
//! **row-partitioned** over the deterministic reduction blocks (each row
//! accumulated left-to-right by its owner — no merge-path carries, so no
//! fixup barrier), and the preconditioner is row-local by construction
//! ([`crate::cg::precond`]). One iteration is therefore one fused pass
//! per worker over its resident rows, one `put` triple per block, one
//! barrier.
//!
//! # Determinism and the two parities
//!
//! Iterates are bit-identical to the serial [`advance_serial`] reference
//! at every worker count. The per-row arithmetic is single-sourced in
//! [`fused_block_pass`] (serial stepper, pool workers and farm shards
//! all call it), partials fold in block-index order, and the scalar
//! recurrences are replicated on every worker. Two double-buffers remove
//! the cross-iteration races a single barrier would otherwise allow:
//!
//! * `m` is parity-buffered — iteration *i* reads `m[i%2]` (stable all
//!   iteration) and writes `m' = M⁻¹ w` into `m[(i+1)%2]`;
//! * the reduction slots are parity-buffered — iteration *i* publishes
//!   its γ'/δ'/rr' partials into the other parity's slot range, which is
//!   folded only *after* the iteration's barrier, so a fold never races
//!   the next iteration's `put`s.
//!
//! The fold of iteration *i*'s partials happens at the top of iteration
//! *i+1* (or after the loop, for the final iteration) — that is the
//! pipelining: the reduction latency hides behind the next SpMV.

use std::cell::UnsafeCell;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::cg::pool::PoolRun;
use crate::cg::precond::Precond;
use crate::coordinator::barrier::GridBarrier;
use crate::error::{Error, Result};
use crate::sparse::csr::Csr;
use crate::stencil::parallel::partition;
use crate::util::counters;

/// Full resident state of a pipelined CG solve between advances. Owns
/// every recurrence vector and scalar, so resumed advances (pool, farm,
/// or serial) continue bit-identically from where the last one stopped.
#[derive(Clone, Debug)]
pub struct PipeState {
    pub x: Vec<f64>,
    pub r: Vec<f64>,
    /// `u = M⁻¹ r`.
    pub u: Vec<f64>,
    /// `w = A u`.
    pub w: Vec<f64>,
    pub p: Vec<f64>,
    /// `s = A p`.
    pub s: Vec<f64>,
    /// `q = M⁻¹ s`.
    pub q: Vec<f64>,
    /// `z = A q`.
    pub z: Vec<f64>,
    /// `m = M⁻¹ w` (current parity).
    pub m: Vec<f64>,
    /// `γ = (r, u)`.
    pub gamma: f64,
    /// `δ = (w, u)`.
    pub delta: f64,
    /// Convergence recurrence `r·r`.
    pub rr: f64,
    /// Previous iteration's γ (0.0 marks "no previous iteration").
    pub gamma_prev: f64,
    /// Previous iteration's α (unused while `gamma_prev == 0`).
    pub alpha_prev: f64,
}

impl PipeState {
    /// Prime the pipelined recurrences from `x0` (zeros when `None`):
    /// one SpMV for `r = b - A x`, the preconditioner applies for `u`
    /// and `m`, one SpMV for `w`, and the three initial dots. Runs on
    /// the client thread, once per `prepare` — the pipelined analog of
    /// classic CG's serial `rr = b·b` priming.
    pub fn prime(a: &Csr, b: &[f64], x0: Option<&[f64]>, pc: &Precond) -> Result<Self> {
        let n = a.n_rows;
        let mut x = vec![0.0; n];
        if let Some(x0) = x0 {
            x.copy_from_slice(x0);
        }
        let mut r = vec![0.0; n];
        spmv_rows(a, &x, &mut r, 0, n);
        for i in 0..n {
            r[i] = b[i] - r[i];
        }
        let mut u = vec![0.0; n];
        pc.apply(&r, &mut u);
        let mut w = vec![0.0; n];
        spmv_rows(a, &u, &mut w, 0, n);
        let mut m = vec![0.0; n];
        pc.apply(&w, &mut m);
        let gamma = dot(&r, &u);
        let delta = dot(&w, &u);
        let rr = dot(&r, &r);
        if !gamma.is_finite() || !delta.is_finite() || !rr.is_finite() {
            return Err(Error::Solver(format!(
                "non-finite reduction while priming pipelined CG (r·u={gamma}, w·u={delta}, r·r={rr})"
            )));
        }
        Ok(Self {
            x,
            r,
            u,
            w,
            p: vec![0.0; n],
            s: vec![0.0; n],
            q: vec![0.0; n],
            z: vec![0.0; n],
            m,
            gamma,
            delta,
            rr,
            gamma_prev: 0.0,
            alpha_prev: 0.0,
        })
    }

    fn n(&self) -> usize {
        self.x.len()
    }
}

/// Result of a serial pipelined advance; `rr`/scalars live in the state.
#[derive(Clone, Debug)]
pub struct PipeRun {
    /// Iterations whose folds completed cleanly.
    pub iters: usize,
    /// Collective solver error, detected identically at every
    /// replication site (serial, every pool worker, the farm
    /// transition).
    pub error: Option<String>,
}

fn dot(a: &[f64], b: &[f64]) -> f64 {
    let mut acc = 0.0;
    for i in 0..a.len() {
        acc += a[i] * b[i];
    }
    acc
}

/// Row-partitioned SpMV over rows `[lo, hi)`: each row accumulated
/// left-to-right in column order. This — not the merge-path kernel — is
/// the pipelined SpMV: per-row ownership needs no carry fixup (and so no
/// extra barrier), and the per-row fold order is worker-count-invariant
/// by construction.
pub(crate) fn spmv_rows(a: &Csr, x: &[f64], y: &mut [f64], lo: usize, hi: usize) {
    for row in lo..hi {
        let (cols, vals) = a.row(row);
        let mut acc = 0.0;
        for (&c, &v) in cols.iter().zip(vals) {
            acc += v * x[c];
        }
        y[row] = acc;
    }
}

/// The pipelined scalar recurrence, replicated bit-identically at every
/// site: β and α from (γ, δ, γ_prev, α_prev). `γ_prev == 0.0` marks the
/// first iteration. Errors are strings so each site can wrap them in its
/// own failure type.
pub(crate) fn pipe_coeffs(
    gamma: f64,
    delta: f64,
    gamma_prev: f64,
    alpha_prev: f64,
) -> std::result::Result<(f64, f64), String> {
    let (beta, denom) = if gamma_prev == 0.0 {
        (0.0, delta)
    } else {
        let beta = gamma / gamma_prev;
        (beta, delta - beta * gamma / alpha_prev)
    };
    if !denom.is_finite() {
        return Err(format!("non-finite pipelined denominator ({denom})"));
    }
    if denom <= 0.0 {
        return Err(format!("matrix not positive definite (pipelined denom={denom})"));
    }
    Ok((beta, gamma / denom))
}

/// Guard the three folded reductions of iteration `iter` (1-based).
/// Identical at every replication site, so the resulting break/failure
/// is collective.
pub(crate) fn check_folds(gamma: f64, delta: f64, rr: f64, iter: usize) -> Option<String> {
    if !gamma.is_finite() || !delta.is_finite() || !rr.is_finite() {
        return Some(format!(
            "non-finite pipelined reduction (r·u={gamma}, w·u={delta}, r·r={rr}) at iteration {iter}"
        ));
    }
    None
}

/// One fused pipelined pass over the rows of reduction block
/// `[s, s + l)`: the row SpMV `n = A m_cur`, all eight vector
/// recurrences, the preconditioner solve `m_next = M⁻¹ w` for the
/// block, and the three scalar partials `(γ', δ', rr')` accumulated
/// left-to-right. **Single-sourced**: the serial stepper, the pool
/// workers and the farm shards all call this, which is what makes the
/// bit-identity contract a property of one function.
///
/// # Safety
///
/// The caller must own rows `[s, s + l)` of every `*mut` vector
/// exclusively for the duration of the call, `m_cur` must have no
/// concurrent writer at all (it is read at arbitrary columns by the
/// SpMV), and no other thread may read the caller's `m_next` rows until
/// a synchronization point orders the writes. All pointers must cover
/// the full vector length.
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn fused_block_pass(
    a: &Csr,
    pc: &Precond,
    s: usize,
    l: usize,
    alpha: f64,
    beta: f64,
    m_cur: &[f64],
    x: *mut f64,
    r: *mut f64,
    u: *mut f64,
    w: *mut f64,
    p: *mut f64,
    sv: *mut f64,
    q: *mut f64,
    z: *mut f64,
    m_next: *mut f64,
) -> (f64, f64, f64) {
    let mut pg = 0.0;
    let mut pd = 0.0;
    let mut pt = 0.0;
    for i in s..s + l {
        // n_i = (A m)_i, row accumulation in column order
        let (cols, vals) = a.row(i);
        let mut ni = 0.0;
        for (&c, &v) in cols.iter().zip(vals) {
            ni += v * m_cur[c];
        }
        // search directions first (they read the pre-update u/w) ...
        let zi = ni + beta * z.add(i).read();
        z.add(i).write(zi);
        let qi = m_cur[i] + beta * q.add(i).read();
        q.add(i).write(qi);
        let si = w.add(i).read() + beta * sv.add(i).read();
        sv.add(i).write(si);
        let pi = u.add(i).read() + beta * p.add(i).read();
        p.add(i).write(pi);
        // ... then the iterate updates, then the partials on the new
        // r/u/w (γ' = r·u, δ' = w·u, rr' = r·r)
        x.add(i).write(x.add(i).read() + alpha * pi);
        let ri = r.add(i).read() - alpha * si;
        r.add(i).write(ri);
        let ui = u.add(i).read() - alpha * qi;
        u.add(i).write(ui);
        let wi = w.add(i).read() - alpha * zi;
        w.add(i).write(wi);
        pg += ri * ui;
        pd += wi * ui;
        pt += ri * ri;
    }
    // m' = M⁻¹ w over the updated block rows (row-local: reads only
    // w[s..s+l], writes only m_next[s..s+l])
    pc.apply_raw(w as *const f64, m_next, s, l);
    (pg, pd, pt)
}

/// Serial pipelined advance: up to `max_iters` iterations on `st`,
/// stopping early on `rr <= threshold` (or `rr <= 0`). This is the
/// bit-identity reference for the pool and farm paths — same
/// [`fused_block_pass`] per block, same block-order folds, same scalar
/// recurrence and guard order.
pub fn advance_serial(
    a: &Csr,
    blocks: &[(usize, usize)],
    pc: &Precond,
    st: &mut PipeState,
    threshold: f64,
    max_iters: usize,
) -> PipeRun {
    let n = st.n();
    let mut mn = vec![0.0; n];
    let mut done = 0usize;
    let mut error = None;
    while done < max_iters {
        if st.rr <= threshold || st.rr <= 0.0 {
            break;
        }
        let (beta, alpha) =
            match pipe_coeffs(st.gamma, st.delta, st.gamma_prev, st.alpha_prev) {
                Ok(v) => v,
                Err(msg) => {
                    error = Some(msg);
                    break;
                }
            };
        let mut g = 0.0;
        let mut d = 0.0;
        let mut t = 0.0;
        {
            let m_cur = st.m.as_slice();
            let (x, r) = (st.x.as_mut_ptr(), st.r.as_mut_ptr());
            let (u, w) = (st.u.as_mut_ptr(), st.w.as_mut_ptr());
            let (p, sv) = (st.p.as_mut_ptr(), st.s.as_mut_ptr());
            let (q, z) = (st.q.as_mut_ptr(), st.z.as_mut_ptr());
            let m_next = mn.as_mut_ptr();
            for &(s, l) in blocks {
                // SAFETY: single-threaded — this thread owns every row
                // of every vector, and m_cur/m_next are distinct Vecs.
                let (pg, pd, pt) = unsafe {
                    fused_block_pass(a, pc, s, l, alpha, beta, m_cur, x, r, u, w, p, sv, q, z, m_next)
                };
                g += pg;
                d += pd;
                t += pt;
            }
        }
        std::mem::swap(&mut st.m, &mut mn);
        if let Some(msg) = check_folds(g, d, t, done + 1) {
            error = Some(msg);
            break;
        }
        st.gamma_prev = st.gamma;
        st.alpha_prev = alpha;
        st.gamma = g;
        st.delta = d;
        st.rr = t;
        done += 1;
    }
    PipeRun { iters: done, error }
}

// ---------------------------------------------------------------------
// The persistent pipelined pool
// ---------------------------------------------------------------------

/// Command to the parked pipelined workers; epoch-stamped like
/// [`crate::cg::pool`]'s (teardown is the separate shutdown flag,
/// checked on every wake).
#[derive(Clone, Copy)]
enum Cmd {
    Idle,
    Run {
        iters: usize,
        threshold: f64,
        gamma: f64,
        delta: f64,
        rr: f64,
        gamma_prev: f64,
        alpha_prev: f64,
    },
}

/// Replicated outcome of one `Run`; worker 0 publishes it (an error —
/// first wins — from any worker).
#[derive(Clone, Default)]
struct Outcome {
    iters: usize,
    /// Fused vector passes executed (≥ `iters`: a pass whose fold then
    /// failed still moved the vectors) — determines the final m parity.
    vec_iters: usize,
    gamma: f64,
    delta: f64,
    rr: f64,
    gamma_prev: f64,
    alpha_prev: f64,
    error: Option<String>,
}

struct CtlState {
    epoch: u64,
    cmd: Cmd,
    finished: usize,
    outcome: Outcome,
    shutdown: bool,
}

struct Control {
    state: Mutex<CtlState>,
    cmd_cv: Condvar,
    done_cv: Condvar,
}

impl Control {
    /// Poison-recovering lock (plain data, same argument as the classic
    /// pool's control).
    fn lock(&self) -> std::sync::MutexGuard<'_, CtlState> {
        self.state.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
    }
}

/// Shared mutable buffer with phase-disjoint access — the pipelined
/// pool's copy of [`crate::cg::pool::SharedBuf`]'s protocol, kept local
/// so this pool stays self-contained (the farm reuses the crate-visible
/// original).
struct Buf {
    _storage: UnsafeCell<Vec<f64>>,
    ptr: *mut f64,
    len: usize,
}

// SAFETY: access is coordinated by the control handshake + barrier
// phases, exactly as in `cg::pool::SharedBuf`.
unsafe impl Sync for Buf {}
unsafe impl Send for Buf {}

impl Buf {
    fn new(mut v: Vec<f64>) -> Self {
        let ptr = v.as_mut_ptr();
        let len = v.len();
        Self { _storage: UnsafeCell::new(v), ptr, len }
    }

    /// SAFETY: no concurrent writer may overlap the read (phase protocol).
    unsafe fn whole(&self) -> &[f64] {
        std::slice::from_raw_parts(self.ptr, self.len)
    }

    fn ptr(&self) -> *mut f64 {
        self.ptr
    }

    /// SAFETY: caller must be the only thread touching the buffer (the
    /// main thread between runs).
    #[allow(clippy::mut_from_ref)]
    unsafe fn whole_mut(&self) -> &mut [f64] {
        std::slice::from_raw_parts_mut(self.ptr, self.len)
    }
}

/// Everything the resident pipelined workers share.
struct Shared {
    a: Arc<Csr>,
    pc: Arc<Precond>,
    blocks: Vec<(usize, usize)>,
    x: Buf,
    r: Buf,
    u: Buf,
    w: Buf,
    p: Buf,
    s: Buf,
    q: Buf,
    z: Buf,
    /// Parity-buffered m (see module docs): iteration i reads `m[i%2]`,
    /// writes `m[(i+1)%2]`.
    m: [Buf; 2],
    /// Width `6 * nblocks`: two parity halves of (γ | δ | rr) block
    /// ranges.
    barrier: GridBarrier,
    ctl: Control,
}

/// A pool of persistent pipelined-CG workers: spawned once, parked
/// between runs, joined on drop; **one reduction barrier per
/// iteration**.
pub struct PipePool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    workers: usize,
    spawned: u64,
}

impl PipePool {
    /// Spawn the resident workers. `threads == 0` resolves to
    /// `available_parallelism`; the effective count is clamped to the
    /// block count so no worker idles by construction.
    pub fn spawn(a: Arc<Csr>, pc: Arc<Precond>, parts: usize, threads: usize) -> Result<Self> {
        if a.n_rows != a.n_cols {
            return Err(Error::Solver(format!(
                "matrix not square: {}x{}",
                a.n_rows, a.n_cols
            )));
        }
        let n = a.n_rows;
        let blocks = partition(n, parts);
        let nblocks = blocks.len();
        let workers = crate::util::resolve_workers(threads).min(nblocks);
        let shared = Arc::new(Shared {
            barrier: GridBarrier::with_reduction(workers, 6 * nblocks),
            blocks,
            x: Buf::new(vec![0.0; n]),
            r: Buf::new(vec![0.0; n]),
            u: Buf::new(vec![0.0; n]),
            w: Buf::new(vec![0.0; n]),
            p: Buf::new(vec![0.0; n]),
            s: Buf::new(vec![0.0; n]),
            q: Buf::new(vec![0.0; n]),
            z: Buf::new(vec![0.0; n]),
            m: [Buf::new(vec![0.0; n]), Buf::new(vec![0.0; n])],
            a,
            pc,
            ctl: Control {
                state: Mutex::new(CtlState {
                    epoch: 0,
                    cmd: Cmd::Idle,
                    finished: 0,
                    outcome: Outcome::default(),
                    shutdown: false,
                }),
                cmd_cv: Condvar::new(),
                done_cv: Condvar::new(),
            },
        });
        counters::note_thread_spawns(workers as u64);
        let mut handles = Vec::with_capacity(workers);
        for wk in 0..workers {
            let sh = shared.clone();
            let spawned = std::thread::Builder::new()
                .name(format!("cg-pipe-{wk}"))
                .spawn(move || worker_main(&sh, wk));
            match spawned {
                Ok(h) => handles.push(h),
                Err(e) => {
                    // join the workers that did start (parked on cmd_cv;
                    // the barrier is not armed before the first Run)
                    {
                        let mut g = shared.ctl.lock();
                        g.shutdown = true;
                        shared.ctl.cmd_cv.notify_all();
                    }
                    for h in handles {
                        let _ = h.join();
                    }
                    return Err(Error::Solver(format!("pipe pool spawn failed: {e}")));
                }
            }
        }
        Ok(Self { shared, handles, workers, spawned: workers as u64 })
    }

    /// Resident worker count.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// OS threads this pool has ever spawned — constant after `spawn`.
    pub fn spawn_count(&self) -> u64 {
        self.spawned
    }

    /// Total time workers spent blocked at the grid barrier (summed).
    pub fn barrier_wait_seconds(&self) -> f64 {
        self.shared.barrier.total_wait().as_secs_f64()
    }

    /// Completed grid-barrier **reduction** generations — exact per-pool
    /// (unlike the process-global counter), so tests can assert the
    /// tentpole invariant with equality: pipelined CG pays ONE
    /// slot-ordered reduction per iteration.
    pub fn barrier_reduction_generations(&self) -> u64 {
        self.shared.barrier.reduction_generations()
    }

    /// Run up to `iters` pipelined iterations on `st`, stopping early
    /// when `rr <= threshold`. State round-trips completely (all nine
    /// vectors and the five scalars), so resumed advances are
    /// bit-identical to one uninterrupted run. On a collective solver
    /// error (`PoolRun::error`) the cleanly folded iterations are
    /// counted in `iters`; the vectors may additionally hold the failing
    /// iteration's updates (the state is then only good for diagnosis,
    /// as with the serial reference).
    pub fn run(&mut self, st: &mut PipeState, threshold: f64, iters: usize) -> Result<PoolRun> {
        let n = self.shared.a.n_rows;
        if st.n() != n {
            return Err(Error::Solver("pipe pool state length mismatch".into()));
        }
        // SAFETY: workers are parked (previous completion handshake
        // happened-before through the control mutex), so the main thread
        // has exclusive access to the buffers.
        unsafe {
            self.shared.x.whole_mut().copy_from_slice(&st.x);
            self.shared.r.whole_mut().copy_from_slice(&st.r);
            self.shared.u.whole_mut().copy_from_slice(&st.u);
            self.shared.w.whole_mut().copy_from_slice(&st.w);
            self.shared.p.whole_mut().copy_from_slice(&st.p);
            self.shared.s.whole_mut().copy_from_slice(&st.s);
            self.shared.q.whole_mut().copy_from_slice(&st.q);
            self.shared.z.whole_mut().copy_from_slice(&st.z);
            self.shared.m[0].whole_mut().copy_from_slice(&st.m);
        }
        {
            let mut g = self.shared.ctl.lock();
            g.epoch += 1;
            g.cmd = Cmd::Run {
                iters,
                threshold,
                gamma: st.gamma,
                delta: st.delta,
                rr: st.rr,
                gamma_prev: st.gamma_prev,
                alpha_prev: st.alpha_prev,
            };
            g.finished = 0;
            g.outcome = Outcome::default();
            self.shared.ctl.cmd_cv.notify_all();
        }
        let outcome = {
            let mut g = self.shared.ctl.lock();
            while g.finished < self.workers {
                // lint: allow(condvar-shutdown) -- client-side completion wait; the pool is torn down only by this same thread's Drop, so no concurrent shutdown can strand it
                g = self.shared.ctl.done_cv.wait(g).unwrap_or_else(|p| p.into_inner());
            }
            g.outcome.clone()
        };
        // SAFETY: all workers reported done (handshake above), so they
        // are parked again and the buffers are quiescent.
        unsafe {
            st.x.copy_from_slice(self.shared.x.whole());
            st.r.copy_from_slice(self.shared.r.whole());
            st.u.copy_from_slice(self.shared.u.whole());
            st.w.copy_from_slice(self.shared.w.whole());
            st.p.copy_from_slice(self.shared.p.whole());
            st.s.copy_from_slice(self.shared.s.whole());
            st.q.copy_from_slice(self.shared.q.whole());
            st.z.copy_from_slice(self.shared.z.whole());
            st.m.copy_from_slice(self.shared.m[outcome.vec_iters % 2].whole());
        }
        st.gamma = outcome.gamma;
        st.delta = outcome.delta;
        st.rr = outcome.rr;
        st.gamma_prev = outcome.gamma_prev;
        st.alpha_prev = outcome.alpha_prev;
        // rz is classic-PCG bookkeeping; the pipelined recurrences carry
        // γ/δ instead, so it mirrors rr here (the unpreconditioned identity)
        Ok(PoolRun { iters: outcome.iters, rr: outcome.rr, rz: outcome.rr, error: outcome.error })
    }

    #[cfg(test)]
    fn shared_weak(&self) -> std::sync::Weak<Shared> {
        Arc::downgrade(&self.shared)
    }
}

impl Drop for PipePool {
    fn drop(&mut self) {
        {
            let mut g = self.shared.ctl.lock();
            g.shutdown = true;
            self.shared.ctl.cmd_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Park on the control condvar; execute each epoch's command; exit on
/// shutdown — the classic pool's lifecycle with the pipelined loop
/// inside.
fn worker_main(sh: &Shared, wk: usize) {
    let mut seen = 0u64;
    loop {
        let cmd = {
            let mut g = sh.ctl.lock();
            loop {
                // shutdown is checked on every wake, independent of the
                // epoch stamp, so teardown can never be missed
                if g.shutdown {
                    return;
                }
                if g.epoch != seen {
                    break;
                }
                g = sh.ctl.cmd_cv.wait(g).unwrap_or_else(|p| p.into_inner());
            }
            seen = g.epoch;
            g.cmd
        };
        match cmd {
            Cmd::Idle => {}
            Cmd::Run { iters, threshold, gamma, delta, rr, gamma_prev, alpha_prev } => {
                let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    iterate(sh, wk, iters, threshold, gamma, delta, rr, gamma_prev, alpha_prev)
                }))
                .unwrap_or_else(|_| Outcome {
                    iters: 0,
                    vec_iters: 0,
                    gamma,
                    delta,
                    rr,
                    gamma_prev,
                    alpha_prev,
                    error: Some(format!("pipe pool worker {wk} panicked during iterate")),
                });
                let mut g = sh.ctl.lock();
                if g.outcome.error.is_none() && (wk == 0 || out.error.is_some()) {
                    g.outcome = out;
                }
                g.finished += 1;
                if g.finished == sh.barrier.participants() {
                    sh.ctl.done_cv.notify_all();
                }
            }
        }
    }
}

/// The resident pipelined iteration loop of worker `wk`: one
/// [`fused_block_pass`] per owned block, one `put` triple per block,
/// one `sync_reduce` per iteration. All workers run the same control
/// flow on identical scalars, so breaks are collective.
#[allow(clippy::too_many_arguments)]
fn iterate(
    sh: &Shared,
    wk: usize,
    max_iters: usize,
    threshold: f64,
    mut gamma: f64,
    mut delta: f64,
    mut rr: f64,
    mut gamma_prev: f64,
    mut alpha_prev: f64,
) -> Outcome {
    let workers = sh.barrier.participants();
    let nb = sh.blocks.len();
    let (k_lo, k_hi) = (nb * wk / workers, nb * (wk + 1) / workers);
    let mut done = 0usize;
    let mut vec_iters = 0usize;
    let mut last_alpha = alpha_prev;
    let mut pending = false;
    let mut error = None;
    // hot-path: begin -- the resident pipelined loop: one barrier
    // generation + raw-pointer arithmetic per iteration, no allocation
    loop {
        if pending {
            // fold the previous pass's partials (its parity's slot
            // ranges) — identical bits on every worker: slot-index order
            let off = (vec_iters % 2) * 3 * nb;
            let g = sh.barrier.read_sum_range(off, off + nb);
            let d = sh.barrier.read_sum_range(off + nb, off + 2 * nb);
            let t = sh.barrier.read_sum_range(off + 2 * nb, off + 3 * nb);
            if let Some(msg) = check_folds(g, d, t, done + 1) {
                error = Some(msg);
                break;
            }
            gamma_prev = gamma;
            alpha_prev = last_alpha;
            gamma = g;
            delta = d;
            rr = t;
            done += 1;
            pending = false;
        }
        if done == max_iters || rr <= threshold || rr <= 0.0 {
            break;
        }
        let (beta, alpha) = match pipe_coeffs(gamma, delta, gamma_prev, alpha_prev) {
            Ok(v) => v,
            Err(msg) => {
                error = Some(msg);
                break;
            }
        };
        last_alpha = alpha;
        let par = vec_iters % 2;
        // SAFETY: m[par] has no writer this iteration (writes target
        // m[1-par]); every *mut vector is written only at rows owned by
        // this worker's blocks; the barrier below orders this
        // iteration's writes before the next iteration's reads.
        unsafe {
            let m_cur = sh.m[par].whole();
            let m_next = sh.m[1 - par].ptr();
            let off_next = ((vec_iters + 1) % 2) * 3 * nb;
            for k in k_lo..k_hi {
                let (s, l) = sh.blocks[k];
                let (pg, pd, pt) = fused_block_pass(
                    &sh.a,
                    &sh.pc,
                    s,
                    l,
                    alpha,
                    beta,
                    m_cur,
                    sh.x.ptr(),
                    sh.r.ptr(),
                    sh.u.ptr(),
                    sh.w.ptr(),
                    sh.p.ptr(),
                    sh.s.ptr(),
                    sh.q.ptr(),
                    sh.z.ptr(),
                    m_next,
                );
                sh.barrier.put(off_next + k, pg);
                sh.barrier.put(off_next + nb + k, pd);
                sh.barrier.put(off_next + 2 * nb + k, pt);
            }
        }
        vec_iters += 1;
        // THE barrier: the iteration's only sync, counted as one
        // reduction generation
        sh.barrier.sync_reduce();
        pending = true;
    }
    // hot-path: end
    Outcome { iters: done, vec_iters, gamma, delta, rr, gamma_prev, alpha_prev, error }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cg::precond::Preconditioner;
    use crate::sparse::gen;

    fn setup(
        a: &Csr,
        spec: Preconditioner,
        parts: usize,
    ) -> (Arc<Csr>, Arc<Precond>, Vec<(usize, usize)>) {
        let blocks = partition(a.n_rows, parts);
        let pc = Precond::build(spec, a, &blocks).unwrap();
        (Arc::new(a.clone()), Arc::new(pc), blocks)
    }

    fn serial(
        a: &Csr,
        b: &[f64],
        spec: Preconditioner,
        parts: usize,
        chunks: &[usize],
    ) -> PipeState {
        let (_, pc, blocks) = setup(a, spec, parts);
        let mut st = PipeState::prime(a, b, None, &pc).unwrap();
        for &c in chunks {
            let run = advance_serial(a, &blocks, &pc, &mut st, 0.0, c);
            assert!(run.error.is_none(), "{:?}", run.error);
        }
        st
    }

    fn pooled(
        a: &Csr,
        b: &[f64],
        spec: Preconditioner,
        parts: usize,
        threads: usize,
        chunks: &[usize],
    ) -> (PipeState, u64) {
        let (arc, pc, _) = setup(a, spec, parts);
        let mut st = PipeState::prime(a, b, None, &pc).unwrap();
        let mut pool = PipePool::spawn(arc, pc, parts, threads).unwrap();
        for &c in chunks {
            let run = pool.run(&mut st, 0.0, c).unwrap();
            assert!(run.error.is_none(), "{:?}", run.error);
        }
        (st, pool.spawn_count())
    }

    fn assert_states_eq(a: &PipeState, b: &PipeState, what: &str) {
        assert_eq!(a.x, b.x, "{what}: x");
        assert_eq!(a.r, b.r, "{what}: r");
        assert_eq!(a.u, b.u, "{what}: u");
        assert_eq!(a.w, b.w, "{what}: w");
        assert_eq!(a.p, b.p, "{what}: p");
        assert_eq!(a.s, b.s, "{what}: s");
        assert_eq!(a.q, b.q, "{what}: q");
        assert_eq!(a.z, b.z, "{what}: z");
        assert_eq!(a.m, b.m, "{what}: m");
        assert_eq!(a.rr.to_bits(), b.rr.to_bits(), "{what}: rr");
        assert_eq!(a.gamma.to_bits(), b.gamma.to_bits(), "{what}: gamma");
        assert_eq!(a.delta.to_bits(), b.delta.to_bits(), "{what}: delta");
    }

    #[test]
    fn pooled_is_bit_identical_to_serial_at_every_worker_count() {
        let a = gen::poisson2d(14);
        let b = gen::rhs(a.n_rows, 7);
        for spec in [
            Preconditioner::None,
            Preconditioner::Jacobi,
            Preconditioner::BlockJacobi { block: 7 },
        ] {
            let want = serial(&a, &b, spec, 8, &[23]);
            for threads in [1, 2, 3, 8] {
                let (got, _) = pooled(&a, &b, spec, 8, threads, &[23]);
                assert_states_eq(&got, &want, &format!("{} threads={threads}", spec.name()));
            }
        }
    }

    #[test]
    fn resumed_advances_match_one_shot_bitwise() {
        let a = gen::clustered_spd(300, 6, 24, 5).unwrap();
        let b = gen::rhs(300, 2);
        let spec = Preconditioner::Jacobi;
        let want = serial(&a, &b, spec, 12, &[30]);
        let split = serial(&a, &b, spec, 12, &[9, 13, 8]);
        assert_states_eq(&split, &want, "serial resume");
        let (res, spawned) = pooled(&a, &b, spec, 12, 4, &[9, 13, 8]);
        assert_states_eq(&res, &want, "pooled resume");
        assert_eq!(spawned, 4, "resumed runs reuse the same resident workers");
    }

    #[test]
    fn one_reduction_and_one_sync_per_iteration() {
        let a = gen::poisson2d(10);
        let b = gen::rhs(a.n_rows, 1);
        let (arc, pc, _) = setup(&a, Preconditioner::None, 8);
        let mut st = PipeState::prime(&a, &b, None, &pc).unwrap();
        let mut pool = PipePool::spawn(arc, pc, 8, 3).unwrap();
        let syncs0 = counters::barrier_syncs();
        let reds0 = counters::barrier_reductions();
        let run = pool.run(&mut st, 0.0, 17).unwrap();
        assert_eq!(run.iters, 17);
        // per-pool barrier generations are exact even when other tests
        // run concurrently: one generation per iteration
        assert_eq!(pool.shared.barrier.generations(), 17);
        assert!(counters::barrier_syncs() >= syncs0 + 17);
        assert!(counters::barrier_reductions() >= reds0 + 17);
    }

    #[test]
    fn converges_to_the_true_solution() {
        let a = gen::poisson2d(12);
        let b = gen::rhs(a.n_rows, 4);
        let (arc, pc, _) = setup(&a, Preconditioner::Jacobi, 8);
        let mut st = PipeState::prime(&a, &b, None, &pc).unwrap();
        let rr0 = st.rr;
        let mut pool = PipePool::spawn(arc, pc, 8, 2).unwrap();
        let run = pool.run(&mut st, 1e-14 * rr0, 10_000).unwrap();
        assert!(run.error.is_none(), "{:?}", run.error);
        assert!(run.iters < 10_000, "converged early");
        let mut ax = vec![0.0; a.n_rows];
        a.spmv_gold(&st.x, &mut ax);
        let err = b.iter().zip(&ax).map(|(bi, ai)| (bi - ai).abs()).fold(0.0, f64::max);
        assert!(err < 1e-5, "true residual {err}");
    }

    #[test]
    fn non_positive_definite_is_a_collective_error() {
        let neg = Csr::from_coo(4, 4, (0..4).map(|i| (i, i, -1.0)).collect()).unwrap();
        let b = vec![1.0; 4];
        let blocks = partition(4, 2);
        let pc = Precond::build(Preconditioner::None, &neg, &blocks).unwrap();
        let mut st = PipeState::prime(&neg, &b, None, &pc).unwrap();
        // serial and pooled agree on the error and the iteration count
        let mut st2 = st.clone();
        let srun = advance_serial(&neg, &blocks, &pc, &mut st2, 0.0, 10);
        assert_eq!(srun.iters, 0);
        let smsg = srun.error.expect("serial must fail");
        assert!(smsg.contains("positive definite"), "{smsg}");
        let mut pool = PipePool::spawn(Arc::new(neg), Arc::new(pc), 2, 2).unwrap();
        let prun = pool.run(&mut st, 0.0, 10).unwrap();
        assert_eq!(prun.iters, 0);
        assert_eq!(prun.error.as_deref(), Some(smsg.as_str()));
        // the pool survives the collective break
        let again = pool.run(&mut st, f64::MAX, 1).unwrap();
        assert!(again.error.is_none());
    }

    #[test]
    fn drop_joins_all_workers() {
        let a = gen::poisson2d(6);
        let (arc, pc, _) = setup(&a, Preconditioner::None, 4);
        let pool = PipePool::spawn(arc, pc, 4, 4).unwrap();
        let weak = pool.shared_weak();
        drop(pool);
        assert_eq!(weak.strong_count(), 0, "workers not joined on drop");
    }
}
