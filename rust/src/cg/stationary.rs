//! Stationary iterative solvers (Jacobi, weighted Jacobi, Gauss-Seidel)
//! — the other solver family the paper's introduction targets ("iterative
//! stationary methods for solving systems of linear equations").
//!
//! Both run under the two execution models: `host_loop` re-derives the
//! diagonal/splitting data every sweep (the relaunch analog) and streams
//! each BLAS-1 pass separately; `persistent` hoists the invariant
//! splitting data out of the loop and fuses the sweeps — the PERKS
//! treatment. Identical iterates, different memory behaviour.

use crate::error::{Error, Result};
use crate::sparse::csr::Csr;

/// Execution model for the stationary solvers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Model {
    HostLoop,
    Persistent,
}

/// Solve report.
#[derive(Clone, Debug)]
pub struct StationaryResult {
    pub x: Vec<f64>,
    pub iters: usize,
    pub residual_norm2: f64,
    pub converged: bool,
    pub wall_seconds: f64,
    /// Times the diagonal/splitting arrays were (re)derived.
    pub splitting_builds: usize,
}

fn diagonal(a: &Csr) -> Result<Vec<f64>> {
    (0..a.n_rows)
        .map(|r| {
            a.get(r, r)
                .filter(|&d| d != 0.0)
                .ok_or_else(|| Error::Solver(format!("zero/missing diagonal at row {r}")))
        })
        .collect()
}

fn residual_norm2(a: &Csr, x: &[f64], b: &[f64], scratch: &mut [f64]) -> f64 {
    a.spmv_gold(x, scratch);
    scratch.iter().zip(b).map(|(ax, bi)| (bi - ax) * (bi - ax)).sum()
}

/// Weighted Jacobi: x' = x + w D^-1 (b - A x). `omega` in (0, 1];
/// converges for diagonally dominant systems.
pub fn jacobi(
    a: &Csr,
    b: &[f64],
    omega: f64,
    tol: f64,
    max_iters: usize,
    model: Model,
) -> Result<StationaryResult> {
    if b.len() != a.n_rows {
        return Err(Error::Solver("rhs size mismatch".into()));
    }
    let t0 = std::time::Instant::now();
    let n = a.n_rows;
    let mut x = vec![0.0; n];
    let mut ax = vec![0.0; n];
    let bb: f64 = b.iter().map(|v| v * v).sum();
    let threshold = tol * tol * bb;
    let mut splitting_builds = 0;
    // persistent: hoist the invariant diagonal out of the sweep loop
    let diag_hoisted = if model == Model::Persistent {
        splitting_builds += 1;
        Some(diagonal(a)?)
    } else {
        None
    };
    let mut iters = 0;
    let mut rr = f64::INFINITY;
    while iters < max_iters {
        let diag = match (&diag_hoisted, model) {
            (Some(d), _) => d.clone(),
            (None, _) => {
                // host-loop: the relaunch analog re-derives the splitting
                splitting_builds += 1;
                diagonal(a)?
            }
        };
        match model {
            Model::HostLoop => {
                // separate passes: spmv, residual, update, norm
                a.spmv_gold(&x, &mut ax);
                let r: Vec<f64> = b.iter().zip(&ax).map(|(bi, axi)| bi - axi).collect();
                for i in 0..n {
                    x[i] += omega * r[i] / diag[i];
                }
                rr = r.iter().map(|v| v * v).sum();
            }
            Model::Persistent => {
                // fused single pass
                a.spmv_gold(&x, &mut ax);
                rr = 0.0;
                for i in 0..n {
                    let ri = b[i] - ax[i];
                    x[i] += omega * ri / diag[i];
                    rr += ri * ri;
                }
            }
        }
        iters += 1;
        if rr <= threshold {
            break;
        }
    }
    let final_rr = residual_norm2(a, &x, b, &mut ax);
    Ok(StationaryResult {
        x,
        iters,
        residual_norm2: final_rr,
        converged: rr <= threshold,
        wall_seconds: t0.elapsed().as_secs_f64(),
        splitting_builds,
    })
}

/// Gauss-Seidel: in-place forward sweep x_i = (b_i - sum_{j!=i} a_ij x_j)/a_ii.
pub fn gauss_seidel(
    a: &Csr,
    b: &[f64],
    tol: f64,
    max_iters: usize,
    model: Model,
) -> Result<StationaryResult> {
    if b.len() != a.n_rows {
        return Err(Error::Solver("rhs size mismatch".into()));
    }
    let t0 = std::time::Instant::now();
    let n = a.n_rows;
    let mut x = vec![0.0; n];
    let mut scratch = vec![0.0; n];
    let bb: f64 = b.iter().map(|v| v * v).sum();
    let threshold = tol * tol * bb;
    let mut splitting_builds = 0;
    let diag_hoisted = if model == Model::Persistent {
        splitting_builds += 1;
        Some(diagonal(a)?)
    } else {
        None
    };
    let mut iters = 0;
    let mut rr = f64::INFINITY;
    while iters < max_iters {
        let diag = match &diag_hoisted {
            Some(d) => d.clone(),
            None => {
                splitting_builds += 1;
                diagonal(a)?
            }
        };
        for i in 0..n {
            let (cols, vals) = a.row(i);
            let mut acc = b[i];
            for (&c, &v) in cols.iter().zip(vals) {
                if c != i {
                    acc -= v * x[c];
                }
            }
            x[i] = acc / diag[i];
        }
        rr = residual_norm2(a, &x, b, &mut scratch);
        iters += 1;
        if rr <= threshold {
            break;
        }
    }
    Ok(StationaryResult {
        x,
        iters,
        residual_norm2: rr,
        converged: rr <= threshold,
        wall_seconds: t0.elapsed().as_secs_f64(),
        splitting_builds,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;

    #[test]
    fn jacobi_converges_on_poisson() {
        let a = gen::poisson2d(12);
        let b = gen::rhs(a.n_rows, 4);
        let r = jacobi(&a, &b, 0.8, 1e-6, 20_000, Model::Persistent).unwrap();
        assert!(r.converged, "rr {}", r.residual_norm2);
        let bb: f64 = b.iter().map(|v| v * v).sum();
        assert!(r.residual_norm2 < 1e-10 * bb);
    }

    #[test]
    fn models_walk_identical_iterates() {
        let a = gen::clustered_spd(200, 5, 12, 3).unwrap();
        let b = gen::rhs(200, 2);
        let h = jacobi(&a, &b, 0.7, 0.0, 50, Model::HostLoop).unwrap();
        let p = jacobi(&a, &b, 0.7, 0.0, 50, Model::Persistent).unwrap();
        let diff = h.x.iter().zip(&p.x).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
        assert!(diff < 1e-13, "{diff}");
        assert_eq!(p.splitting_builds, 1);
        assert_eq!(h.splitting_builds, 50);
    }

    #[test]
    fn gauss_seidel_converges_faster_than_jacobi() {
        let a = gen::poisson2d(10);
        let b = gen::rhs(a.n_rows, 6);
        let j = jacobi(&a, &b, 1.0, 1e-8, 50_000, Model::Persistent).unwrap();
        let g = gauss_seidel(&a, &b, 1e-8, 50_000, Model::Persistent).unwrap();
        assert!(j.converged && g.converged);
        assert!(g.iters < j.iters, "GS {} vs Jacobi {}", g.iters, j.iters);
    }

    #[test]
    fn zero_diagonal_rejected() {
        let a = crate::sparse::csr::Csr::from_coo(2, 2, vec![(0, 1, 1.0), (1, 0, 1.0)]).unwrap();
        assert!(jacobi(&a, &[1.0, 1.0], 1.0, 1e-6, 10, Model::Persistent).is_err());
        assert!(gauss_seidel(&a, &[1.0, 1.0], 1e-6, 10, Model::HostLoop).is_err());
    }

    #[test]
    fn solution_satisfies_system() {
        let a = gen::poisson2d(8);
        let b = gen::rhs(a.n_rows, 9);
        let g = gauss_seidel(&a, &b, 1e-10, 100_000, Model::Persistent).unwrap();
        let mut ax = vec![0.0; a.n_rows];
        a.spmv_gold(&g.x, &mut ax);
        for (axi, bi) in ax.iter().zip(&b) {
            assert!((axi - bi).abs() < 1e-4, "{axi} vs {bi}");
        }
    }
}
