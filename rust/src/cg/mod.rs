//! Conjugate-gradient solver substrate: host-loop (Ginkgo-like baseline)
//! and persistent (PERKS) execution models, plus the §VI-G2 caching
//! policies.

pub mod krylov;
pub mod policy;
pub mod solver;
pub mod stationary;

pub use policy::{CgPolicy, CgTraffic};
pub use solver::{solve_host_loop, solve_persistent, CgOptions, CgResult};
