//! Conjugate-gradient solver substrate: host-loop (Ginkgo-like baseline)
//! and persistent (PERKS) execution models, plus the §VI-G2 caching
//! policies. The persistent model has two realizations: `solver`'s fused
//! single-thread loop, and `pool`'s spawn-once worker-pool runtime with
//! the time loop resident in the workers (the paper's execution model,
//! physically).

pub mod krylov;
pub mod pipeline;
pub mod policy;
pub mod pool;
pub mod precond;
pub mod solver;
pub mod stationary;

pub use pipeline::{PipePool, PipeRun, PipeState};
pub use policy::{CgPolicy, CgTraffic};
pub use pool::{CgPool, PoolRun};
pub use precond::{Precond, Preconditioner};
pub use solver::{
    solve_host_loop, solve_persistent, solve_pipelined, solve_pooled, CgOptions, CgResult,
};

/// The canonical per-block partial of the pooled reduction order: `f(i)`
/// accumulated left-to-right over rows `[s, s + l)` from a fresh 0.0.
///
/// Every site that participates in the bit-identity contract — the pool
/// workers' dot/norm partials, the serial `session::cpu::CpuCg::step`,
/// and the pool's test reference — computes block partials through this
/// one helper, so the fold order the contract depends on is single-sourced
/// (the cross-block fold is block-index order: `GridBarrier::sync_sum`
/// slot order, or a plain left fold serially).
#[inline]
pub(crate) fn block_partial(s: usize, l: usize, mut f: impl FnMut(usize) -> f64) -> f64 {
    let mut part = 0.0;
    for i in s..s + l {
        part += f(i);
    }
    part
}

/// Classic *preconditioned* CG, fused second half over one reduction
/// block: the x/r updates, the row-local preconditioner solve
/// `z = M⁻¹ r`, and the (r·z, r·r) partials, all left-to-right.
/// Single-sourced so the serial `session::cpu::CpuCg` step and the
/// pooled workers produce bit-identical iterates (the unpreconditioned
/// path keeps its original one-loop arithmetic and never calls this).
///
/// # Safety
///
/// The caller must own rows `[s, s + l)` of `x`, `r` and `z`
/// exclusively for the duration of the call; `p`/`ap` must have no
/// concurrent writer; all pointers/slices cover the full vector length.
#[inline]
pub(crate) unsafe fn classic_precond_block_pass(
    pc: &precond::Precond,
    s: usize,
    l: usize,
    alpha: f64,
    p: &[f64],
    ap: &[f64],
    x: *mut f64,
    r: *mut f64,
    z: *mut f64,
) -> (f64, f64) {
    for i in s..s + l {
        x.add(i).write(x.add(i).read() + alpha * p[i]);
        r.add(i).write(r.add(i).read() - alpha * ap[i]);
    }
    // z = M⁻¹ r needs the whole block's r updated first (block-Jacobi
    // couples rows within a sub-block), hence the two-loop shape
    pc.apply_raw(r as *const f64, z, s, l);
    let mut prz = 0.0;
    let mut prr = 0.0;
    for i in s..s + l {
        let ri = r.add(i).read();
        prz += ri * z.add(i).read();
        prr += ri * ri;
    }
    (prz, prr)
}
