//! Conjugate-gradient solver substrate: host-loop (Ginkgo-like baseline)
//! and persistent (PERKS) execution models, plus the §VI-G2 caching
//! policies. The persistent model has two realizations: `solver`'s fused
//! single-thread loop, and `pool`'s spawn-once worker-pool runtime with
//! the time loop resident in the workers (the paper's execution model,
//! physically).

pub mod krylov;
pub mod policy;
pub mod pool;
pub mod solver;
pub mod stationary;

pub use policy::{CgPolicy, CgTraffic};
pub use pool::{CgPool, PoolRun};
pub use solver::{solve_host_loop, solve_persistent, solve_pooled, CgOptions, CgResult};

/// The canonical per-block partial of the pooled reduction order: `f(i)`
/// accumulated left-to-right over rows `[s, s + l)` from a fresh 0.0.
///
/// Every site that participates in the bit-identity contract — the pool
/// workers' dot/norm partials, the serial `session::cpu::CpuCg::step`,
/// and the pool's test reference — computes block partials through this
/// one helper, so the fold order the contract depends on is single-sourced
/// (the cross-block fold is block-index order: `GridBarrier::sync_sum`
/// slot order, or a plain left fold serially).
#[inline]
pub(crate) fn block_partial(s: usize, l: usize, mut f: impl FnMut(usize) -> f64) -> f64 {
    let mut part = 0.0;
    for i in s..s + l {
        part += f(i);
    }
    part
}
