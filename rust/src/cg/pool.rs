//! Persistent CG worker pool: the PERKS execution model for CG, physically
//! realized on CPU.
//!
//! # The GPU ↔ CPU analogy
//!
//! The paper's persistent CG kernel moves the *time loop inside the
//! kernel*: thread blocks are launched once, keep their share of the
//! matrix/vectors resident, synchronize iterations with `grid.sync()`, and
//! compute the two CG dot products as device-wide reductions between
//! barriers (§V-C). This module is that model with CPU nouns:
//!
//! | GPU (PERKS kernel)              | CPU (`CgPool`)                       |
//! |---------------------------------|--------------------------------------|
//! | thread block                    | pool worker (OS thread, spawn-once)  |
//! | kernel launch / relaunch        | `CgPool::spawn` (exactly once/solve) |
//! | TB's merge-path share           | worker's `MergePlan` share range     |
//! | registers/smem-resident slices  | worker's x/r/p/Ap row blocks (hot in |
//! |                                 | the core's L1/L2 across iterations)  |
//! | `grid.sync()`                   | `GridBarrier::sync`                  |
//! | grid-sync + device reduction    | `GridBarrier::sync_sum` all-reduce   |
//!
//! The host-loop baseline (`spmv::merge::spmv_parallel` called per
//! iteration) re-spawns and re-joins its workers on **every SpMV** — the
//! relaunch overhead the paper eliminates. Here `advance` performs zero
//! thread spawns: the workers are parked on a condvar between solves and
//! run the whole iteration loop internally.
//!
//! # Fused passes
//!
//! Each iteration is two fused sweeps per worker over its resident rows —
//! (SpMV share consumption + carry fixup + partial `p·Ap`) then
//! (x/r update + partial `r·r` + p update) — so the per-iteration vector
//! traffic physically matches the 2-pass model `CpuCg::bytes_per_iter`
//! advertises, instead of the 5 separate streamed passes of the baseline.
//!
//! # Determinism
//!
//! Iterates are **bit-identical to the serial `CpuCg::step` path at every
//! worker count**. Three rules make that hold:
//!
//! 1. SpMV shares are consumed with the exact `consume_share` arithmetic,
//!    and partial-row carries are applied in share-index order (the serial
//!    fixup order) by the owner of the target row.
//! 2. Dot products are reduced over `parts` fixed row *blocks* — not over
//!    workers — with per-block partials accumulated left-to-right and
//!    folded in block-index order by `GridBarrier::sync_sum`. The serial
//!    path uses the same block decomposition.
//! 3. All scalar recurrences (alpha, beta, rr) are replicated: every
//!    worker folds the same slots in the same order, so every worker
//!    computes the same bits without a broadcast.
//!
//! # Safety protocol
//!
//! Vectors live in `UnsafeCell` buffers shared by the main thread and the
//! workers. Exclusive access is phased: the main thread touches them only
//! while the pool is idle (the command/completion handshake through the
//! control mutex establishes happens-before in both directions), and
//! within a run the workers partition writes by row ownership with
//! `GridBarrier::sync` separating producer and consumer phases — the same
//! argument as `stencil::parallel::SharedGrid` and the `spmv_parallel`
//! scoped spawn.
//!
//! CPU pinning: on a thread-per-core substrate each worker would also be
//! pinned to its own core (`sched_setaffinity`, as in the mini-async
//! runtime's `LocalExecutor`); that needs a libc binding the vendored
//! dependency set doesn't carry, so [`pin_to_core`] is a documented no-op
//! hook — see its docs for the production shape.

use std::cell::UnsafeCell;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::coordinator::barrier::GridBarrier;
use crate::error::{Error, Result};
use crate::sparse::csr::Csr;
use crate::spmv::merge::{self, MergePlan};
use crate::stencil::parallel::partition;
use crate::util::counters;

/// Shared mutable buffer with phase-disjoint access (see module docs).
///
/// The base pointer is captured once at construction (the heap block never
/// moves: the Vec is never grown), so no exclusive reference to the
/// container or its contents is ever formed while workers run: concurrent
/// writes go through [`SharedBuf::ptr`] at owner-disjoint indices, shared
/// reads through [`SharedBuf::whole`] only in phases where no thread
/// writes, and barriers order every cross-owner handoff. Raw pointers
/// carry no aliasing contract, so the disjoint-write protocol is sound
/// without overlapping `&mut` views. Crate-visible because
/// `runtime::farm`'s CG tenants phase their vectors with the same
/// discipline (claim/complete handoffs standing in for barriers).
pub(crate) struct SharedBuf<T> {
    /// Owns the allocation (dropped with the pool); never accessed as a
    /// `Vec` again after construction.
    _storage: UnsafeCell<Vec<T>>,
    ptr: *mut T,
    len: usize,
}

// SAFETY: access is coordinated by the control handshake + barrier phases.
unsafe impl<T: Send> Sync for SharedBuf<T> {}
unsafe impl<T: Send> Send for SharedBuf<T> {}

impl<T> SharedBuf<T> {
    pub(crate) fn new(mut v: Vec<T>) -> Self {
        let ptr = v.as_mut_ptr();
        let len = v.len();
        Self { _storage: UnsafeCell::new(v), ptr, len }
    }

    /// SAFETY: no concurrent writer may overlap the read (phase protocol).
    pub(crate) unsafe fn whole(&self) -> &[T] {
        std::slice::from_raw_parts(self.ptr, self.len)
    }

    /// Base pointer for concurrent disjoint-index writes (workers never
    /// form `&mut` views — all shared-phase writes go through this).
    pub(crate) fn ptr(&self) -> *mut T {
        self.ptr
    }

    /// SAFETY: caller must be the only thread touching the buffer (the
    /// main thread between runs); used for the state copy in/out.
    #[allow(clippy::mut_from_ref)]
    pub(crate) unsafe fn whole_mut(&self) -> &mut [T] {
        std::slice::from_raw_parts_mut(self.ptr, self.len)
    }
}

/// Command issued to the parked workers; epoch-stamped in `CtlState`.
/// Teardown is the dedicated `CtlState::shutdown` flag, checked on every
/// condvar wake — never a value raced through the command slot — so a
/// worker parked while the epoch stamp advances can never miss it.
#[derive(Clone, Copy)]
enum Cmd {
    Idle,
    /// Run up to `iters` iterations from recurrence state `rr` (and
    /// `rz = r·z`, equal to `rr` for the identity preconditioner),
    /// stopping early once `rr <= threshold` (or `rr <= 0`, the
    /// exact-solution short-circuit of the serial path).
    Run { iters: usize, rr: f64, rz: f64, threshold: f64 },
}

/// What one `Run` produced. Every worker computes identical values; worker
/// 0 publishes them.
#[derive(Clone, Default)]
struct Outcome {
    iters: usize,
    rr: f64,
    rz: f64,
    error: Option<String>,
}

struct CtlState {
    epoch: u64,
    cmd: Cmd,
    finished: usize,
    outcome: Outcome,
    /// Teardown flag, separate from the command slot (see [`Cmd`]).
    shutdown: bool,
}

struct Control {
    state: Mutex<CtlState>,
    cmd_cv: Condvar,
    done_cv: Condvar,
}

impl Control {
    /// Lock the control state, recovering from poisoning (a worker panic
    /// while holding the lock) — the state is plain data with no invariant
    /// a panic can break, and refusing would turn one panic into a
    /// double-panic abort in `Drop`.
    fn lock(&self) -> std::sync::MutexGuard<'_, CtlState> {
        self.state.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
    }
}

/// Everything the resident workers share.
struct Shared {
    a: Arc<Csr>,
    plan: MergePlan,
    /// Row-local preconditioner; identity for classic unpreconditioned
    /// CG, in which case `z` is untouched and the original one-loop
    /// pass-B arithmetic runs byte-for-byte.
    pc: Arc<crate::cg::precond::Precond>,
    /// Row blocks of the deterministic reduction (and of vector-update
    /// ownership): `partition(n, parts)`, identical to the serial path.
    blocks: Vec<(usize, usize)>,
    x: SharedBuf<f64>,
    r: SharedBuf<f64>,
    p: SharedBuf<f64>,
    /// `z = M⁻¹ r`, resident like the rest (preconditioned pools only).
    z: SharedBuf<f64>,
    ap: SharedBuf<f64>,
    /// Per-share partial-row carries, written by share owners, applied in
    /// share order by row owners (the serial fixup order).
    carries: SharedBuf<(usize, f64)>,
    barrier: GridBarrier,
    ctl: Control,
}

/// Result of one [`CgPool::run`].
#[derive(Clone, Debug)]
pub struct PoolRun {
    /// Iterations actually performed (early-stop on threshold/zero rr,
    /// or on `error` — the completed iterations are still valid).
    pub iters: usize,
    /// Final `r·r` recurrence value after `iters` iterations.
    pub rr: f64,
    /// Final `r·z` recurrence value (equals `rr` for unpreconditioned
    /// runs); feed it back into the next `run_preconditioned` to resume.
    pub rz: f64,
    /// Collective solver error (not positive definite), detected
    /// identically by every worker before any state update of the failing
    /// iteration — mirroring the serial `step()` error point.
    pub error: Option<String>,
}

impl PoolRun {
    /// Fold the solver error into a `Result`, for callers that do not
    /// need the partial-progress accounting.
    pub fn into_result(self) -> Result<Self> {
        match self.error {
            Some(msg) => Err(Error::Solver(msg)),
            None => Ok(self),
        }
    }
}

/// A pool of persistent CG workers: spawned once, parked between runs,
/// joined on drop. See the module docs for the execution model.
pub struct CgPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    workers: usize,
    spawned: u64,
}

impl CgPool {
    /// Spawn the resident workers for one solve. `threads == 0` resolves
    /// to `available_parallelism`; the effective worker count is clamped
    /// to the share/block counts so no worker is idle by construction.
    pub fn spawn(a: Arc<Csr>, plan: MergePlan, threads: usize) -> Result<Self> {
        let blocks = partition(a.n_rows, plan.parts());
        let pc = crate::cg::precond::Precond::build(
            crate::cg::precond::Preconditioner::None,
            &a,
            &blocks,
        )?;
        Self::spawn_preconditioned(a, plan, threads, Arc::new(pc))
    }

    /// [`CgPool::spawn`] with a row-local preconditioner resident in the
    /// workers (classic PCG: `z = M⁻¹ r` kept alongside x/r/p). Passing
    /// the identity preserves the unpreconditioned arithmetic
    /// byte-for-byte.
    pub fn spawn_preconditioned(
        a: Arc<Csr>,
        plan: MergePlan,
        threads: usize,
        pc: Arc<crate::cg::precond::Precond>,
    ) -> Result<Self> {
        if a.n_rows != a.n_cols {
            // x/p are indexed by column inside the share consumption: a
            // rectangular matrix would panic some workers mid-barrier
            return Err(Error::Solver(format!(
                "matrix not square: {}x{}",
                a.n_rows, a.n_cols
            )));
        }
        if a.n_rows != plan.n_rows || a.nnz() != plan.nnz {
            return Err(Error::Solver(format!(
                "merge plan mismatch: plan for {} rows / {} nnz, matrix has {} rows / {} nnz",
                plan.n_rows,
                plan.nnz,
                a.n_rows,
                a.nnz()
            )));
        }
        let n = a.n_rows;
        let parts = plan.parts();
        let blocks = partition(n, parts);
        let workers = crate::util::resolve_workers(threads).min(parts).min(blocks.len());
        // preconditioned pass B folds (r·z | r·r) through one combined
        // generation, so those pools need two block ranges of slots
        let width = if pc.is_identity() { blocks.len() } else { 2 * blocks.len() };
        let shared = Arc::new(Shared {
            carries: SharedBuf::new(vec![(0usize, 0.0f64); parts]),
            barrier: GridBarrier::with_reduction(workers, width),
            blocks,
            x: SharedBuf::new(vec![0.0; n]),
            r: SharedBuf::new(vec![0.0; n]),
            p: SharedBuf::new(vec![0.0; n]),
            z: SharedBuf::new(vec![0.0; n]),
            ap: SharedBuf::new(vec![0.0; n]),
            a,
            plan,
            pc,
            ctl: Control {
                state: Mutex::new(CtlState {
                    epoch: 0,
                    cmd: Cmd::Idle,
                    finished: 0,
                    outcome: Outcome::default(),
                    shutdown: false,
                }),
                cmd_cv: Condvar::new(),
                done_cv: Condvar::new(),
            },
        });
        counters::note_thread_spawns(workers as u64);
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let sh = shared.clone();
            let spawned = std::thread::Builder::new()
                .name(format!("cg-pool-{w}"))
                .spawn(move || worker_main(&sh, w));
            match spawned {
                Ok(h) => handles.push(h),
                Err(e) => {
                    // don't leak the workers that did start: they are
                    // parked on cmd_cv and would otherwise pin their
                    // Arc<Shared> (and the matrix) forever. The barrier is
                    // not armed yet — no worker enters `iterate` without a
                    // Run command — so teardown is safe here.
                    {
                        let mut g = shared.ctl.lock();
                        g.shutdown = true;
                        shared.ctl.cmd_cv.notify_all();
                    }
                    for h in handles {
                        let _ = h.join();
                    }
                    return Err(Error::Solver(format!("pool spawn failed: {e}")));
                }
            }
        }
        Ok(Self { shared, handles, workers, spawned: workers as u64 })
    }

    /// Resident worker count (threads clamped to shares/blocks).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// OS threads this pool has ever spawned — constant after `spawn`,
    /// which is the point: `run` must never add to it.
    pub fn spawn_count(&self) -> u64 {
        self.spawned
    }

    /// Total time workers spent blocked at the grid barrier (summed).
    pub fn barrier_wait_seconds(&self) -> f64 {
        self.shared.barrier.total_wait().as_secs_f64()
    }

    /// Completed grid-barrier **reduction** generations — exact per-pool
    /// (unlike the process-global counter), so tests can assert classic
    /// CG's barriers-per-iteration invariant with equality: two
    /// reductions (p·Ap, then r·z/r·r) per iteration.
    pub fn barrier_reduction_generations(&self) -> u64 {
        self.shared.barrier.reduction_generations()
    }

    /// Run up to `iters` CG iterations on state (x, r, p, rr), stopping
    /// early when `rr <= threshold` (pass 0.0 for fixed-iteration /
    /// benchmark mode). State is copied into the resident buffers, the
    /// workers iterate internally (no thread spawns), and the advanced
    /// state is copied back out — including on a not-positive-definite
    /// error (`PoolRun::error`), where the iterations completed before
    /// the failing one are still valid (matching the serial path).
    /// `Err` is reserved for infrastructure failures (length mismatch).
    pub fn run(
        &mut self,
        x: &mut [f64],
        r: &mut [f64],
        p: &mut [f64],
        rr: f64,
        threshold: f64,
        iters: usize,
    ) -> Result<PoolRun> {
        let mut z_scratch = vec![0.0; r.len()];
        self.run_preconditioned(x, r, &mut z_scratch, p, rr, rr, threshold, iters)
    }

    /// Preconditioned [`CgPool::run`]: the resident state additionally
    /// carries `z = M⁻¹ r` and the `rz = r·z` recurrence (pass `rz == rr`
    /// and `z == r` for the identity). Same handshake, same
    /// partial-progress semantics.
    #[allow(clippy::too_many_arguments)]
    pub fn run_preconditioned(
        &mut self,
        x: &mut [f64],
        r: &mut [f64],
        z: &mut [f64],
        p: &mut [f64],
        rr: f64,
        rz: f64,
        threshold: f64,
        iters: usize,
    ) -> Result<PoolRun> {
        let n = self.shared.a.n_rows;
        if x.len() != n || r.len() != n || z.len() != n || p.len() != n {
            return Err(Error::Solver("pool state length mismatch".into()));
        }
        // SAFETY: workers are parked (previous completion handshake
        // happened-before through the control mutex), so the main thread
        // has exclusive access to the buffers.
        unsafe {
            self.shared.x.whole_mut().copy_from_slice(x);
            self.shared.r.whole_mut().copy_from_slice(r);
            self.shared.z.whole_mut().copy_from_slice(z);
            self.shared.p.whole_mut().copy_from_slice(p);
        }
        {
            let mut g = self.shared.ctl.lock();
            g.epoch += 1;
            g.cmd = Cmd::Run { iters, rr, rz, threshold };
            g.finished = 0;
            g.outcome = Outcome::default(); // no stale error/iters carry over
            self.shared.ctl.cmd_cv.notify_all();
        }
        let outcome = {
            let mut g = self.shared.ctl.lock();
            while g.finished < self.workers {
                // lint: allow(condvar-shutdown) -- client-side completion wait; the pool is torn down only by this same thread's Drop, so no concurrent shutdown can strand it
                g = self.shared.ctl.done_cv.wait(g).unwrap_or_else(|p| p.into_inner());
            }
            g.outcome.clone()
        };
        // SAFETY: all workers reported done (handshake above), so they are
        // parked again and the buffers are quiescent.
        unsafe {
            x.copy_from_slice(self.shared.x.whole());
            r.copy_from_slice(self.shared.r.whole());
            z.copy_from_slice(self.shared.z.whole());
            p.copy_from_slice(self.shared.p.whole());
        }
        Ok(PoolRun {
            iters: outcome.iters,
            rr: outcome.rr,
            rz: outcome.rz,
            error: outcome.error,
        })
    }

    #[cfg(test)]
    fn shared_weak(&self) -> std::sync::Weak<Shared> {
        Arc::downgrade(&self.shared)
    }
}

impl Drop for CgPool {
    fn drop(&mut self) {
        {
            let mut g = self.shared.ctl.lock();
            g.shutdown = true;
            self.shared.ctl.cmd_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Park on the control condvar; execute each epoch's command; exit on
/// shutdown. The whole CG time loop runs inside `iterate` — this thread is
/// the CPU realization of a persistent thread block.
fn worker_main(sh: &Shared, w: usize) {
    pin_to_core(w);
    let mut seen = 0u64;
    loop {
        let cmd = {
            let mut g = sh.ctl.lock();
            loop {
                // the shutdown flag is checked on *every* wake — before
                // and independently of the epoch stamp — so teardown can
                // never be missed by a worker parked across stamp changes
                if g.shutdown {
                    return;
                }
                if g.epoch != seen {
                    break;
                }
                g = sh.ctl.cmd_cv.wait(g).unwrap_or_else(|p| p.into_inner());
            }
            seen = g.epoch;
            g.cmd
        };
        match cmd {
            Cmd::Idle => {}
            Cmd::Run { iters, rr, rz, threshold } => {
                // A panic inside the iteration loop would otherwise leave
                // `finished` forever short and hang `run()`. Catching it
                // lets a *collective* panic (all workers fail at the same
                // deterministic point — the shape every replicated-scalar
                // bug takes) surface as an error; `spawn`'s plan/matrix
                // validation closes the reachable asymmetric case.
                let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    iterate(sh, w, iters, rr, rz, threshold)
                }))
                .unwrap_or_else(|_| Outcome {
                    iters: 0,
                    rr,
                    rz,
                    error: Some(format!("pool worker {w} panicked during iterate")),
                });
                let mut g = sh.ctl.lock();
                // worker 0 publishes the (replicated) outcome; an error —
                // first one wins — is sticky and never overwritten by a
                // later clean outcome
                if g.outcome.error.is_none() && (w == 0 || out.error.is_some()) {
                    g.outcome = out;
                }
                g.finished += 1;
                if g.finished == sh.barrier.participants() {
                    sh.ctl.done_cv.notify_all();
                }
            }
        }
    }
}

/// The resident iteration loop of worker `w`. All workers execute the same
/// control flow on identical scalars (see module docs, "Determinism"), so
/// early breaks are collective and the barrier never deadlocks. The
/// identity-preconditioner path is the original unpreconditioned
/// arithmetic, untouched; preconditioned pools branch into
/// [`iterate_preconditioned`].
fn iterate(
    sh: &Shared,
    w: usize,
    max_iters: usize,
    rr_in: f64,
    rz_in: f64,
    threshold: f64,
) -> Outcome {
    if !sh.pc.is_identity() {
        return iterate_preconditioned(sh, w, max_iters, rr_in, rz_in, threshold);
    }
    let workers = sh.barrier.participants();
    let parts = sh.plan.parts();
    let nblocks = sh.blocks.len();
    // this worker's merge shares (SpMV ownership) ...
    let (s_lo, s_hi) = (parts * w / workers, parts * (w + 1) / workers);
    // ... and its reduction blocks == vector-update rows
    let (k_lo, k_hi) = (nblocks * w / workers, nblocks * (w + 1) / workers);
    let row_lo = sh.blocks[k_lo].0;
    let row_hi = {
        let (s, l) = sh.blocks[k_hi - 1];
        s + l
    };

    let mut rr = rr_in;
    let mut done = 0usize;
    let mut error = None;
    // hot-path: begin -- the resident CG iteration loop: every epoch is
    // barrier sync + raw-pointer arithmetic, no allocation allowed
    for _ in 0..max_iters {
        if rr <= threshold || rr <= 0.0 {
            break;
        }
        // -- fused pass A, part 1: consume my merge shares (SpMV) --------
        // SAFETY: p is read-shared (no writer this phase); ap rows and
        // carry slots are written through raw pointers, only by their
        // share owner.
        unsafe {
            let p_v = sh.p.whole();
            let ap = sh.ap.ptr();
            let carries = sh.carries.ptr();
            for i in s_lo..s_hi {
                let c = merge::consume_share_raw(
                    &sh.a,
                    p_v,
                    ap,
                    sh.plan.shares[i],
                    sh.plan.shares[i + 1],
                );
                carries.add(i).write(c);
            }
        }
        sh.barrier.sync();
        // -- fused pass A, part 2: carry fixup + partial p·Ap ------------
        // SAFETY: carries are read-shared now; each worker touches only ap
        // indices it owns (row_lo..row_hi), which are hot from part 1 when
        // share and block ownership coincide.
        unsafe {
            let p_v = sh.p.whole();
            let ap = sh.ap.ptr();
            for &(row, carry) in sh.carries.whole() {
                // serial fixup order and skip condition, restricted to our
                // rows (carries iterate in share-index order)
                if row >= row_lo && row < row_hi && carry != 0.0 {
                    ap.add(row).write(ap.add(row).read() + carry);
                }
            }
            for k in k_lo..k_hi {
                let (s, l) = sh.blocks[k];
                // SAFETY: ap has no writer this phase (fixups above are
                // barrier-ordered before the dot-product reads).
                let part =
                    crate::cg::block_partial(s, l, |i| p_v[i] * unsafe { ap.add(i).read() });
                sh.barrier.put(k, part);
            }
        }
        let pap = sh.barrier.sync_sum();
        if !pap.is_finite() {
            // non-finite guard: NaN/Inf in p or Ap poisons the fold,
            // identically on every worker — a collective break, before
            // alpha can spread the poison into x/r
            // lint: allow(hot-path-alloc) -- cold error exit: the format! runs once, right before the loop breaks
            error = Some(format!("non-finite p·Ap ({pap}) at iteration {}", done + 1));
            break;
        }
        if pap <= 0.0 {
            // identical pap on every worker: a collective break
            // lint: allow(hot-path-alloc) -- cold error exit: the format! runs once, right before the loop breaks
            error = Some(format!("matrix not positive definite (pAp={pap})"));
            break;
        }
        let alpha = rr / pap;
        // -- fused pass B, part 1: x/r update + partial r·r --------------
        // SAFETY: x/r writes go through raw pointers inside our rows; p
        // and ap have no writer this phase.
        unsafe {
            let x = sh.x.ptr();
            let r = sh.r.ptr();
            let p_v = sh.p.whole();
            let ap = sh.ap.whole();
            for k in k_lo..k_hi {
                let (s, l) = sh.blocks[k];
                // SAFETY: block k's rows belong to this worker alone, so
                // the x/r read-modify-writes cannot race another writer.
                let part = crate::cg::block_partial(s, l, |i| unsafe {
                    x.add(i).write(x.add(i).read() + alpha * p_v[i]);
                    let ri = r.add(i).read() - alpha * ap[i];
                    r.add(i).write(ri);
                    ri * ri
                });
                sh.barrier.put(k, part);
            }
        }
        let rr_new = sh.barrier.sync_sum();
        if !rr_new.is_finite() {
            // same guard on the r·r recurrence: the fold is identical on
            // every worker, so the break is collective and leaves x/r at
            // the failing iteration's update (p not yet touched)
            // lint: allow(hot-path-alloc) -- cold error exit: the format! runs once, right before the loop breaks
            error = Some(format!("non-finite r·r ({rr_new}) at iteration {}", done + 1));
            break;
        }
        let beta = rr_new / rr;
        // -- fused pass B, part 2: p update (still resident rows) --------
        // SAFETY: p writes go through the raw pointer inside our rows; r
        // has no writer this phase.
        unsafe {
            let p_v = sh.p.ptr();
            let r = sh.r.whole();
            for i in row_lo..row_hi {
                p_v.add(i).write(r[i] + beta * p_v.add(i).read());
            }
        }
        rr = rr_new;
        done += 1;
        // next iteration's SpMV reads p globally: wait for all p writes
        sh.barrier.sync();
    }
    // hot-path: end
    Outcome { iters: done, rr, rz: rr, error }
}

/// Classic *preconditioned* CG iteration loop: same SpMV/carry phases as
/// the identity path, but pass B runs the single-sourced
/// [`crate::cg::classic_precond_block_pass`] (x/r update, `z = M⁻¹ r`,
/// and the (r·z | r·r) partials) and folds both dot products through one
/// combined reduction generation over the doubled slot width. Still two
/// reductions and six barrier generations per iteration — pipelined CG
/// ([`crate::cg::pipeline`]) is the one-reduction model.
fn iterate_preconditioned(
    sh: &Shared,
    w: usize,
    max_iters: usize,
    rr_in: f64,
    rz_in: f64,
    threshold: f64,
) -> Outcome {
    let workers = sh.barrier.participants();
    let parts = sh.plan.parts();
    let nblocks = sh.blocks.len();
    let (s_lo, s_hi) = (parts * w / workers, parts * (w + 1) / workers);
    let (k_lo, k_hi) = (nblocks * w / workers, nblocks * (w + 1) / workers);
    let row_lo = sh.blocks[k_lo].0;
    let row_hi = {
        let (s, l) = sh.blocks[k_hi - 1];
        s + l
    };

    let mut rr = rr_in;
    let mut rz = rz_in;
    let mut done = 0usize;
    let mut error = None;
    // hot-path: begin -- the resident preconditioned CG loop: barrier
    // sync + raw-pointer arithmetic per epoch, no allocation allowed
    for _ in 0..max_iters {
        if rr <= threshold || rr <= 0.0 {
            break;
        }
        // -- fused pass A, part 1: consume my merge shares (SpMV) --------
        // SAFETY: p is read-shared (no writer this phase); ap rows and
        // carry slots are written through raw pointers, only by their
        // share owner.
        unsafe {
            let p_v = sh.p.whole();
            let ap = sh.ap.ptr();
            let carries = sh.carries.ptr();
            for i in s_lo..s_hi {
                let c = merge::consume_share_raw(
                    &sh.a,
                    p_v,
                    ap,
                    sh.plan.shares[i],
                    sh.plan.shares[i + 1],
                );
                carries.add(i).write(c);
            }
        }
        sh.barrier.sync();
        // -- fused pass A, part 2: carry fixup + partial p·Ap ------------
        // SAFETY: carries are read-shared now; each worker touches only ap
        // indices it owns (row_lo..row_hi).
        unsafe {
            let p_v = sh.p.whole();
            let ap = sh.ap.ptr();
            for &(row, carry) in sh.carries.whole() {
                if row >= row_lo && row < row_hi && carry != 0.0 {
                    ap.add(row).write(ap.add(row).read() + carry);
                }
            }
            for k in k_lo..k_hi {
                let (s, l) = sh.blocks[k];
                // SAFETY: ap has no writer this phase (fixups above are
                // barrier-ordered before the dot-product reads).
                let part =
                    crate::cg::block_partial(s, l, |i| p_v[i] * unsafe { ap.add(i).read() });
                sh.barrier.put(k, part);
            }
        }
        // the slot width is 2*nblocks here, so the p·Ap fold reads only
        // its own block range (not the stale r·r half)
        sh.barrier.sync_reduce();
        let pap = sh.barrier.read_sum_range(0, nblocks);
        sh.barrier.sync();
        if !pap.is_finite() {
            // lint: allow(hot-path-alloc) -- cold error exit: the format! runs once, right before the loop breaks
            error = Some(format!("non-finite p·Ap ({pap}) at iteration {}", done + 1));
            break;
        }
        if pap <= 0.0 {
            // lint: allow(hot-path-alloc) -- cold error exit: the format! runs once, right before the loop breaks
            error = Some(format!("matrix not positive definite (pAp={pap})"));
            break;
        }
        let alpha = rz / pap;
        // -- fused pass B, part 1: x/r update + z = M⁻¹r + (r·z | r·r) ---
        // SAFETY: x/r/z writes go through raw pointers inside our rows
        // (the preconditioner is row-local by construction); p and ap
        // have no writer this phase.
        unsafe {
            let x = sh.x.ptr();
            let r = sh.r.ptr();
            let z = sh.z.ptr();
            let p_v = sh.p.whole();
            let ap = sh.ap.whole();
            for k in k_lo..k_hi {
                let (s, l) = sh.blocks[k];
                let (prz, prr) = crate::cg::classic_precond_block_pass(
                    &sh.pc, s, l, alpha, p_v, ap, x, r, z,
                );
                sh.barrier.put(k, prz);
                sh.barrier.put(nblocks + k, prr);
            }
        }
        // one combined generation folds both recurrences in slot order
        sh.barrier.sync_reduce();
        let rz_new = sh.barrier.read_sum_range(0, nblocks);
        let rr_new = sh.barrier.read_sum_range(nblocks, 2 * nblocks);
        sh.barrier.sync();
        if !rz_new.is_finite() || !rr_new.is_finite() {
            // lint: allow(hot-path-alloc) -- cold error exit: the format! runs once, right before the loop breaks
            error = Some(format!(
                "non-finite preconditioned reduction (r·z={rz_new}, r·r={rr_new}) at iteration {}",
                done + 1
            ));
            break;
        }
        let beta = rz_new / rz;
        // -- fused pass B, part 2: p = z + beta p (still resident rows) --
        // SAFETY: p writes go through the raw pointer inside our rows; z
        // has no writer this phase.
        unsafe {
            let p_v = sh.p.ptr();
            let z = sh.z.whole();
            for i in row_lo..row_hi {
                p_v.add(i).write(z[i] + beta * p_v.add(i).read());
            }
        }
        rr = rr_new;
        rz = rz_new;
        done += 1;
        // next iteration's SpMV reads p globally: wait for all p writes
        sh.barrier.sync();
    }
    // hot-path: end
    Outcome { iters: done, rr, rz, error }
}

/// Best-effort CPU pinning hook (thread-per-core). A production deployment
/// would pin worker `w` to core `w` here via `sched_setaffinity` with
/// pid 0 (the calling thread), as in the mini-async runtime's
/// `LocalExecutor::bind_to_cpu_set` — stabilizing each worker's L1/L2
/// residency, the CPU analog of a thread block staying on its SM. The
/// vendored dependency set carries no libc binding, so the hook is a
/// deliberate no-op: the pool's correctness and the determinism guarantees
/// never depend on placement.
fn pin_to_core(_core: usize) {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;

    /// Serial reference with the pool's (and `CpuCg::step`'s) canonical
    /// block-ordered reductions.
    fn serial_cg(a: &Csr, b: &[f64], parts: usize, iters: usize) -> (Vec<f64>, f64) {
        let n = a.n_rows;
        let plan = MergePlan::new(a, parts);
        let blocks = partition(n, parts);
        let mut x = vec![0.0; n];
        let mut r = b.to_vec();
        let mut p = b.to_vec();
        let mut ap = vec![0.0; n];
        let mut rr: f64 = b.iter().map(|v| v * v).sum();
        for _ in 0..iters {
            if rr <= 0.0 {
                break;
            }
            merge::spmv(a, &plan, &p, &mut ap);
            let mut pap = 0.0;
            for &(s, l) in &blocks {
                pap += crate::cg::block_partial(s, l, |i| p[i] * ap[i]);
            }
            let alpha = rr / pap;
            let mut rr_new = 0.0;
            for &(s, l) in &blocks {
                rr_new += crate::cg::block_partial(s, l, |i| {
                    x[i] += alpha * p[i];
                    let ri = r[i] - alpha * ap[i];
                    r[i] = ri;
                    ri * ri
                });
            }
            let beta = rr_new / rr;
            for i in 0..n {
                p[i] = r[i] + beta * p[i];
            }
            rr = rr_new;
        }
        (x, rr)
    }

    fn pooled_cg(
        a: &Csr,
        b: &[f64],
        parts: usize,
        threads: usize,
        chunks: &[usize],
    ) -> (Vec<f64>, f64, u64) {
        let arc = Arc::new(a.clone());
        let plan = MergePlan::new(a, parts);
        let mut pool = CgPool::spawn(arc, plan, threads).unwrap();
        let n = a.n_rows;
        let mut x = vec![0.0; n];
        let mut r = b.to_vec();
        let mut p = b.to_vec();
        let mut rr: f64 = b.iter().map(|v| v * v).sum();
        for &c in chunks {
            let run = pool.run(&mut x, &mut r, &mut p, rr, 0.0, c).unwrap();
            rr = run.rr;
        }
        let spawned = pool.spawn_count();
        (x, rr, spawned)
    }

    #[test]
    fn pooled_iterates_are_bit_identical_to_serial_at_every_thread_count() {
        let a = gen::poisson2d(20);
        let b = gen::rhs(a.n_rows, 7);
        let (want_x, want_rr) = serial_cg(&a, &b, 8, 25);
        for threads in [1, 2, 3, 8] {
            let (x, rr, _) = pooled_cg(&a, &b, 8, threads, &[25]);
            assert_eq!(x, want_x, "threads={threads}");
            assert_eq!(rr.to_bits(), want_rr.to_bits(), "threads={threads}");
        }
    }

    #[test]
    fn pooled_resume_matches_one_shot() {
        let a = gen::clustered_spd(400, 6, 24, 5).unwrap();
        let b = gen::rhs(400, 2);
        let (one_x, one_rr, _) = pooled_cg(&a, &b, 12, 4, &[30]);
        let (res_x, res_rr, spawned) = pooled_cg(&a, &b, 12, 4, &[9, 13, 8]);
        assert_eq!(one_x, res_x);
        assert_eq!(one_rr.to_bits(), res_rr.to_bits());
        // resumed runs reuse the same resident workers: one spawn batch
        assert_eq!(spawned, 4);
    }

    /// Serial classic-PCG reference sharing the pooled arithmetic
    /// ([`crate::cg::classic_precond_block_pass`]) and fold order.
    #[allow(clippy::type_complexity)]
    fn serial_pcg(
        a: &Csr,
        b: &[f64],
        spec: crate::cg::precond::Preconditioner,
        parts: usize,
        chunks: &[usize],
    ) -> (Vec<f64>, f64, f64) {
        let n = a.n_rows;
        let plan = MergePlan::new(a, parts);
        let blocks = partition(n, parts);
        let pc = crate::cg::precond::Precond::build(spec, a, &blocks).unwrap();
        let mut x = vec![0.0; n];
        let mut r = b.to_vec();
        let mut z = vec![0.0; n];
        pc.apply(&r, &mut z);
        let mut p = z.clone();
        let mut ap = vec![0.0; n];
        let mut rr: f64 = b.iter().map(|v| v * v).sum();
        let mut rz: f64 = r.iter().zip(&z).map(|(a, b)| a * b).sum();
        for &c in chunks {
            for _ in 0..c {
                if rr <= 0.0 {
                    break;
                }
                merge::spmv(a, &plan, &p, &mut ap);
                let mut pap = 0.0;
                for &(s, l) in &blocks {
                    pap += crate::cg::block_partial(s, l, |i| p[i] * ap[i]);
                }
                let alpha = rz / pap;
                let mut rz_new = 0.0;
                let mut rr_new = 0.0;
                for &(s, l) in &blocks {
                    // SAFETY: single-threaded; the Vec pointers cover n
                    // rows and nothing else aliases them.
                    let (prz, prr) = unsafe {
                        crate::cg::classic_precond_block_pass(
                            &pc,
                            s,
                            l,
                            alpha,
                            &p,
                            &ap,
                            x.as_mut_ptr(),
                            r.as_mut_ptr(),
                            z.as_mut_ptr(),
                        )
                    };
                    rz_new += prz;
                    rr_new += prr;
                }
                let beta = rz_new / rz;
                for i in 0..n {
                    p[i] = z[i] + beta * p[i];
                }
                rr = rr_new;
                rz = rz_new;
            }
        }
        (x, rr, rz)
    }

    #[test]
    fn preconditioned_pool_is_bit_identical_to_serial_pcg() {
        let a = gen::poisson2d(14);
        let b = gen::rhs(a.n_rows, 5);
        let n = a.n_rows;
        for spec in [
            crate::cg::precond::Preconditioner::Jacobi,
            crate::cg::precond::Preconditioner::BlockJacobi { block: 5 },
        ] {
            let (want_x, want_rr, want_rz) = serial_pcg(&a, &b, spec, 8, &[20]);
            for threads in [1, 2, 3, 8] {
                let blocks = partition(n, 8);
                let pc = crate::cg::precond::Precond::build(spec, &a, &blocks).unwrap();
                let plan = MergePlan::new(&a, 8);
                let mut pool = CgPool::spawn_preconditioned(
                    Arc::new(a.clone()),
                    plan,
                    threads,
                    Arc::new(pc.clone()),
                )
                .unwrap();
                let mut x = vec![0.0; n];
                let mut r = b.clone();
                let mut z = vec![0.0; n];
                pc.apply(&r, &mut z);
                let mut p = z.clone();
                let mut rr: f64 = b.iter().map(|v| v * v).sum();
                let mut rz: f64 = r.iter().zip(&z).map(|(a, b)| a * b).sum();
                // resumed chunks must compose exactly like one shot
                for c in [7, 9, 4] {
                    let run = pool
                        .run_preconditioned(&mut x, &mut r, &mut z, &mut p, rr, rz, 0.0, c)
                        .unwrap();
                    assert!(run.error.is_none(), "{:?}", run.error);
                    rr = run.rr;
                    rz = run.rz;
                }
                assert_eq!(x, want_x, "{} threads={threads}", spec.name());
                assert_eq!(rr.to_bits(), want_rr.to_bits(), "threads={threads}");
                assert_eq!(rz.to_bits(), want_rz.to_bits(), "threads={threads}");
            }
        }
    }

    #[test]
    fn run_never_spawns_after_start() {
        let a = gen::poisson2d(12);
        let b = gen::rhs(a.n_rows, 1);
        let plan = MergePlan::new(&a, 8);
        let mut pool = CgPool::spawn(Arc::new(a.clone()), plan, 3).unwrap();
        let after_start = pool.spawn_count();
        let n = a.n_rows;
        let (mut x, mut r, mut p) = (vec![0.0; n], b.clone(), b.clone());
        let mut rr: f64 = b.iter().map(|v| v * v).sum();
        for _ in 0..5 {
            rr = pool.run(&mut x, &mut r, &mut p, rr, 0.0, 4).unwrap().rr;
        }
        assert_eq!(pool.spawn_count(), after_start, "run() must not spawn");
        assert_eq!(after_start, pool.workers() as u64);
    }

    #[test]
    fn tolerance_threshold_stops_early_and_reports_iters() {
        let a = gen::poisson2d(10);
        let b = gen::rhs(a.n_rows, 9);
        let rr0: f64 = b.iter().map(|v| v * v).sum();
        let plan = MergePlan::new(&a, 8);
        let mut pool = CgPool::spawn(Arc::new(a.clone()), plan, 2).unwrap();
        let n = a.n_rows;
        let (mut x, mut r, mut p) = (vec![0.0; n], b.clone(), b.clone());
        let run = pool.run(&mut x, &mut r, &mut p, rr0, 1e-12 * rr0, 10_000).unwrap();
        assert!(run.iters < 10_000, "converged early");
        assert!(run.rr <= 1e-12 * rr0);
        // the solution actually solves the system
        let mut ax = vec![0.0; n];
        a.spmv_gold(&x, &mut ax);
        let err = b.iter().zip(&ax).map(|(bi, ai)| (bi - ai).abs()).fold(0.0, f64::max);
        assert!(err < 1e-5, "true residual {err}");
    }

    /// Satellite: the hot-path reductions guard against non-finite
    /// folds. A NaN smuggled into `p` poisons the p·Ap fold, and the
    /// collective break names the iteration instead of iterating NaNs
    /// to the cap; the pool stays usable afterwards.
    #[test]
    fn non_finite_reductions_fail_naming_the_iteration() {
        let a = gen::poisson2d(8);
        let b = gen::rhs(a.n_rows, 3);
        let plan = MergePlan::new(&a, 4);
        let mut pool = CgPool::spawn(Arc::new(a.clone()), plan, 2).unwrap();
        let n = a.n_rows;
        let (mut x, mut r, mut p) = (vec![0.0; n], b.clone(), b.clone());
        let rr0: f64 = b.iter().map(|v| v * v).sum();
        p[n / 2] = f64::NAN;
        let run = pool.run(&mut x, &mut r, &mut p, rr0, 0.0, 10).unwrap();
        assert_eq!(run.iters, 0, "the poisoned fold fires before any state update");
        let err = run.into_result().unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("non-finite p·Ap"), "{msg}");
        assert!(msg.contains("iteration 1"), "{msg}");
        // the pool survives the collective break: a clean run converges
        let (mut x, mut r, mut p) = (vec![0.0; n], b.clone(), b.clone());
        let clean = pool.run(&mut x, &mut r, &mut p, rr0, 1e-10 * rr0, 10_000).unwrap();
        assert!(clean.error.is_none());
        assert!(clean.iters < 10_000);
    }

    #[test]
    fn non_positive_definite_reports_error_from_inside_the_pool() {
        let neg = Csr::from_coo(4, 4, (0..4).map(|i| (i, i, -1.0)).collect()).unwrap();
        let b = vec![1.0; 4];
        let plan = MergePlan::new(&neg, 2);
        let mut pool = CgPool::spawn(Arc::new(neg), plan, 2).unwrap();
        let (mut x, mut r, mut p) = (vec![0.0; 4], b.clone(), b.clone());
        let run = pool.run(&mut x, &mut r, &mut p, 4.0, 0.0, 10).unwrap();
        assert_eq!(run.iters, 0, "pAp < 0 on the very first iteration");
        let err = run.into_result().unwrap_err();
        assert!(format!("{err}").contains("positive definite"), "{err}");
        // state is untouched: the error fires before any x/r/p update
        assert_eq!(x, vec![0.0; 4]);
        // pool is still usable after the error (workers re-parked)
        let again = pool.run(&mut x, &mut r, &mut p, 0.0, 0.0, 1).unwrap();
        assert!(again.error.is_none());
        assert_eq!(again.iters, 0);
    }

    #[test]
    fn drop_joins_all_workers() {
        let a = gen::poisson2d(8);
        let plan = MergePlan::new(&a, 4);
        let pool = CgPool::spawn(Arc::new(a), plan, 4).unwrap();
        let weak = pool.shared_weak();
        drop(pool);
        // every worker held an Arc clone; all joined => all released
        assert_eq!(weak.strong_count(), 0, "workers not joined on drop");
    }

    /// Satellite: the teardown race — rapid create/drop cycles with and
    /// without runs must always join promptly (the shutdown flag is
    /// checked on every wake, independent of the epoch stamp).
    #[test]
    fn rapid_create_drop_cycles_never_hang() {
        let a = Arc::new(gen::poisson2d(6));
        let b = gen::rhs(a.n_rows, 2);
        for cycle in 0..64usize {
            let plan = MergePlan::new(&a, 4);
            let mut pool = CgPool::spawn(a.clone(), plan, 1 + cycle % 4).unwrap();
            let weak = pool.shared_weak();
            if cycle % 2 == 1 {
                let n = a.n_rows;
                let (mut x, mut r, mut p) = (vec![0.0; n], b.clone(), b.clone());
                let rr: f64 = b.iter().map(|v| v * v).sum();
                pool.run(&mut x, &mut r, &mut p, rr, 0.0, 2).unwrap();
            }
            drop(pool);
            assert_eq!(weak.strong_count(), 0, "cycle {cycle}: workers not joined");
        }
    }
}
