//! CG caching policies (paper §VI-G2, Fig 9).
//!
//! Which data the persistent CG kernel pins in on-chip memory:
//!
//! * `Imp` — nothing explicitly; rely on L2 hits;
//! * `Vec` — the residual/direction vectors (plus the TB-level workload
//!   boundaries, as the paper's footnote 2 specifies);
//! * `Mat` — the matrix A (plus TB- and thread-level workload boundaries);
//! * `Mix` — vectors first, remaining capacity to the matrix.
//!
//! `traffic_per_iter` implements the per-iteration global-memory byte
//! count for each policy; the simulator turns it into Fig 9's speedups.

use crate::coordinator::caching::{self, CacheLocation, CachePlan};
use crate::sparse::csr::Csr;

/// The paper's four CG caching policies.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CgPolicy {
    Imp,
    Vec,
    Mat,
    Mix,
}

impl CgPolicy {
    pub fn all() -> [CgPolicy; 4] {
        [CgPolicy::Imp, CgPolicy::Vec, CgPolicy::Mat, CgPolicy::Mix]
    }

    pub fn name(self) -> &'static str {
        match self {
            CgPolicy::Imp => "IMP",
            CgPolicy::Vec => "VEC",
            CgPolicy::Mat => "MAT",
            CgPolicy::Mix => "MIX",
        }
    }
}

/// Per-iteration global-memory traffic of one CG iteration (merge SpMV +
/// fused vector update), in bytes.
///
/// Accounting (per paper §III-B-2, with element size `elem`):
/// * matrix A: 1 load of (vals + col idx) + row_ptr share;
/// * residual r: 3 loads + 1 store; direction p: 3 loads + 1 store;
///   solution x: 1 load + 1 store; Ap: 1 store + 2 loads;
/// * workload (merge plan): TB-level boundaries re-searched (loads of
///   row_ptr) unless cached.
#[derive(Clone, Copy, Debug)]
pub struct CgTraffic {
    pub matrix_bytes: f64,
    pub vector_bytes: f64,
    pub workload_bytes: f64,
}

impl CgTraffic {
    pub fn total(&self) -> f64 {
        self.matrix_bytes + self.vector_bytes + self.workload_bytes
    }
}

/// Uncached per-iteration traffic for a matrix (baseline).
pub fn baseline_traffic(a: &Csr, elem: usize) -> CgTraffic {
    let matrix = (a.nnz() * (elem + 4) + (a.n_rows + 1) * 4) as f64;
    // r: 4, p: 4, x: 2, Ap: 3 passes of n*elem each
    let vector = (13 * a.n_rows * elem) as f64;
    // plan re-search: one pass over row_ptr
    let workload = ((a.n_rows + 1) * 4) as f64;
    CgTraffic { matrix_bytes: matrix, vector_bytes: vector, workload_bytes: workload }
}

/// Per-iteration traffic under a policy, given the on-chip capacity
/// available for caching (bytes). Returns (traffic, plan).
pub fn policy_traffic(
    a: &Csr,
    elem: usize,
    policy: CgPolicy,
    capacity_bytes: f64,
) -> (CgTraffic, CachePlan) {
    let base = baseline_traffic(a, elem);
    let matrix_bytes = (a.nnz() * (elem + 4)) as f64;
    let vector_bytes = (4 * a.n_rows * elem) as f64; // r, p, x, Ap resident set
    let arrays = match policy {
        CgPolicy::Imp => vec![],
        CgPolicy::Vec => vec![caching::CacheableArray::new("vec", vector_bytes, 3.0, 1.0)],
        CgPolicy::Mat => vec![caching::CacheableArray::new("mat", matrix_bytes, 1.0, 0.0)],
        CgPolicy::Mix => vec![
            caching::CacheableArray::new("vec", vector_bytes, 3.0, 1.0),
            caching::CacheableArray::new("mat", matrix_bytes, 1.0, 0.0),
        ],
    };
    let plan = caching::plan(CacheLocation::Both, &arrays, capacity_bytes * 0.6, capacity_bytes * 0.4);
    // reduce traffic proportionally to the cached fraction of each class
    let vec_frac = plan.allocation("vec").map(|al| al.fraction()).unwrap_or(0.0);
    let mat_frac = plan.allocation("mat").map(|al| al.fraction()).unwrap_or(0.0);
    // workload cache: VEC/MAT/MIX all cache the TB-level search result
    let workload = if policy == CgPolicy::Imp { base.workload_bytes } else { 0.0 };
    let traffic = CgTraffic {
        matrix_bytes: base.matrix_bytes * (1.0 - mat_frac),
        vector_bytes: base.vector_bytes * (1.0 - vec_frac),
        workload_bytes: workload,
    };
    (traffic, plan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;

    #[test]
    fn more_caching_less_traffic() {
        let a = gen::poisson2d(32);
        let cap = 1e6; // plenty for vectors, partial matrix
        let base = baseline_traffic(&a, 4).total();
        let imp = policy_traffic(&a, 4, CgPolicy::Imp, cap).0.total();
        let vec = policy_traffic(&a, 4, CgPolicy::Vec, cap).0.total();
        let mix = policy_traffic(&a, 4, CgPolicy::Mix, cap).0.total();
        assert!(imp <= base);
        assert!(vec < imp);
        assert!(mix <= vec, "mix {mix} vec {vec}");
    }

    #[test]
    fn vec_policy_fully_caches_small_vectors() {
        let a = gen::poisson2d(16);
        let cap = 1e9;
        let (t, plan) = policy_traffic(&a, 4, CgPolicy::Vec, cap);
        assert!((plan.allocation("vec").unwrap().fraction() - 1.0).abs() < 1e-12);
        assert_eq!(t.vector_bytes, 0.0);
        // matrix untouched by VEC
        assert!(t.matrix_bytes > 0.0);
    }

    #[test]
    fn mix_prefers_vectors_then_matrix() {
        let a = gen::poisson2d(32);
        let vector_bytes = (4 * a.n_rows * 4) as f64;
        // capacity = vectors + half the matrix
        let matrix_bytes = (a.nnz() * 8) as f64;
        let cap = vector_bytes + matrix_bytes / 2.0;
        let (_, plan) = policy_traffic(&a, 4, CgPolicy::Mix, cap);
        assert!((plan.allocation("vec").unwrap().fraction() - 1.0).abs() < 1e-9);
        let mf = plan.allocation("mat").unwrap().fraction();
        assert!(mf > 0.2 && mf < 0.8, "mat fraction {mf}");
    }
}
