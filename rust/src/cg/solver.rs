//! Conjugate-gradient solver over the merge-based SpMV substrate.
//!
//! Two execution models, mirroring the paper's CG experiment (§V-C):
//!
//! * `solve_host_loop` — the Ginkgo-like baseline: every BLAS-1 op is a
//!   separate pass over the vectors (each pass streams the vectors through
//!   "global memory" — here, through memory levels beyond the core caches
//!   for large n), and the merge-path search result is *recomputed every
//!   iteration* (the sample-code behaviour the paper improves on).
//! * `solve_persistent` — the PERKS model: the merge plan is computed once
//!   and cached (the paper's TB-level "workload" caching), and the vector
//!   updates are fused into single passes (the analog of keeping r/p/x
//!   resident on-chip; this is exactly what the fused Pallas kernel does
//!   in the artifact path).
//!
//! Both produce identical iterates (tested), differing only in memory
//! behaviour — the paper's claim, again.

use crate::error::{Error, Result};
use crate::sparse::csr::Csr;
use crate::spmv::merge::{self, MergePlan};

/// Solver options.
#[derive(Clone, Debug)]
pub struct CgOptions {
    pub max_iters: usize,
    /// Stop when rr <= tol^2 * rr0 (relative residual). Set to 0.0 to run
    /// exactly `max_iters` iterations (benchmark mode, as the paper does
    /// with its fixed 10,000 steps).
    pub tol: f64,
    /// Worker shares for the merge SpMV.
    pub parts: usize,
    /// Use threaded SpMV (`solve_host_loop` / `solve_persistent`) or the
    /// persistent worker pool (`solve_pooled`).
    pub threaded: bool,
    /// OS worker threads when threaded; 0 = `available_parallelism`,
    /// resolved once per solve (never per iteration).
    pub workers: usize,
}

impl Default for CgOptions {
    fn default() -> Self {
        Self { max_iters: 1000, tol: 1e-8, parts: 8, threaded: false, workers: 0 }
    }
}

/// Solve outcome.
#[derive(Clone, Debug)]
pub struct CgResult {
    pub x: Vec<f64>,
    pub iters: usize,
    pub rr_final: f64,
    pub rr0: f64,
    pub converged: bool,
    pub wall_seconds: f64,
    /// Passes over the n-length vectors per iteration (locality metric:
    /// the host-loop model needs more passes).
    pub vector_passes_per_iter: f64,
    /// Merge-path searches performed (PERKS caches the plan: exactly 1).
    pub plan_searches: usize,
}

fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Resolve `CgOptions::workers` exactly once per solve, so the sysconf
/// query behind `available_parallelism` is never re-paid per iteration.
fn resolve_workers(opts: &CgOptions) -> usize {
    crate::util::resolve_workers(opts.workers)
}

fn validate(a: &Csr, b: &[f64]) -> Result<()> {
    if a.n_rows != a.n_cols {
        return Err(Error::Solver(format!("matrix not square: {}x{}", a.n_rows, a.n_cols)));
    }
    if b.len() != a.n_rows {
        return Err(Error::Solver(format!("rhs has {} entries, matrix {}", b.len(), a.n_rows)));
    }
    Ok(())
}

/// Baseline CG: separate BLAS-1 passes, plan re-searched per iteration.
pub fn solve_host_loop(a: &Csr, b: &[f64], opts: &CgOptions) -> Result<CgResult> {
    validate(a, b)?;
    let n = a.n_rows;
    let t0 = std::time::Instant::now();
    let mut x = vec![0.0; n];
    let mut r = b.to_vec();
    let mut p = b.to_vec();
    let mut ap = vec![0.0; n];
    let rr0 = dot(&r, &r);
    let mut rr = rr0;
    let mut iters = 0;
    let mut plan_searches = 0;
    let threshold = opts.tol * opts.tol * rr0;
    let workers = resolve_workers(opts);
    while iters < opts.max_iters && rr > threshold && rr > 0.0 {
        // the baseline recomputes the workload split every launch
        let plan = MergePlan::new(a, opts.parts);
        plan_searches += 1;
        if opts.threaded {
            merge::spmv_parallel(a, &plan, &p, &mut ap, workers);
        } else {
            merge::spmv(a, &plan, &p, &mut ap);
        }
        // separate passes (each streams whole vectors):
        let pap = dot(&p, &ap); // pass 1
        if pap <= 0.0 {
            return Err(Error::Solver(format!("matrix not positive definite (pAp={pap})")));
        }
        let alpha = rr / pap;
        for i in 0..n {
            x[i] += alpha * p[i]; // pass 2
        }
        for i in 0..n {
            r[i] -= alpha * ap[i]; // pass 3
        }
        let rr_new = dot(&r, &r); // pass 4
        let beta = rr_new / rr;
        for i in 0..n {
            p[i] = r[i] + beta * p[i]; // pass 5
        }
        rr = rr_new;
        iters += 1;
    }
    Ok(CgResult {
        x,
        iters,
        rr_final: rr,
        rr0,
        converged: rr <= threshold,
        wall_seconds: t0.elapsed().as_secs_f64(),
        vector_passes_per_iter: 5.0,
        plan_searches,
    })
}

/// PERKS CG: plan cached once; vector updates fused into two passes.
pub fn solve_persistent(a: &Csr, b: &[f64], opts: &CgOptions) -> Result<CgResult> {
    validate(a, b)?;
    let n = a.n_rows;
    let t0 = std::time::Instant::now();
    let mut x = vec![0.0; n];
    let mut r = b.to_vec();
    let mut p = b.to_vec();
    let mut ap = vec![0.0; n];
    let rr0 = dot(&r, &r);
    let mut rr = rr0;
    let mut iters = 0;
    let threshold = opts.tol * opts.tol * rr0;
    // cached TB-level search result (the paper's "workload" cache)
    let plan = MergePlan::new(a, opts.parts);
    let workers = resolve_workers(opts);
    while iters < opts.max_iters && rr > threshold && rr > 0.0 {
        if opts.threaded {
            merge::spmv_parallel(a, &plan, &p, &mut ap, workers);
        } else {
            merge::spmv(a, &plan, &p, &mut ap);
        }
        // fused pass 1: pAp + x/r updates in a single sweep
        let pap = dot(&p, &ap);
        if pap <= 0.0 {
            return Err(Error::Solver(format!("matrix not positive definite (pAp={pap})")));
        }
        let alpha = rr / pap;
        let mut rr_new = 0.0;
        for i in 0..n {
            x[i] += alpha * p[i];
            let ri = r[i] - alpha * ap[i];
            r[i] = ri;
            rr_new += ri * ri;
        }
        // fused pass 2: p update
        let beta = rr_new / rr;
        for i in 0..n {
            p[i] = r[i] + beta * p[i];
        }
        rr = rr_new;
        iters += 1;
    }
    Ok(CgResult {
        x,
        iters,
        rr_final: rr,
        rr0,
        converged: rr <= threshold,
        wall_seconds: t0.elapsed().as_secs_f64(),
        vector_passes_per_iter: 2.0,
        plan_searches: 1,
    })
}

/// PERKS CG on the persistent worker-pool runtime ([`crate::cg::pool`]):
/// `opts.workers` OS threads are spawned **once**, the whole iteration
/// loop runs inside them, and the dot products are device-wide barrier
/// reductions (`GridBarrier::sync_sum`) instead of post-join serial
/// passes. Iterates are bit-identical at every worker count (the
/// reductions fold per-block partials in block order, not arrival order)
/// and match the serial pooled-canonical order used by
/// `session::cpu::CpuCg::step`.
pub fn solve_pooled(a: &Csr, b: &[f64], opts: &CgOptions) -> Result<CgResult> {
    validate(a, b)?;
    let n = a.n_rows;
    // the deep copy is an artifact of the borrowed API, not of the
    // execution model: keep it out of the timed region so wall_seconds
    // stays comparable with the borrowing solvers above
    let arc = std::sync::Arc::new(a.clone());
    let t0 = std::time::Instant::now();
    // cached TB-level search result (the paper's "workload" cache),
    // searched exactly once and owned by the resident workers
    let plan = MergePlan::new(a, opts.parts);
    let mut pool = crate::cg::pool::CgPool::spawn(arc, plan, opts.workers)?;
    let mut x = vec![0.0; n];
    let mut r = b.to_vec();
    let mut p = b.to_vec();
    let rr0 = dot(&r, &r);
    let threshold = opts.tol * opts.tol * rr0;
    let run =
        pool.run(&mut x, &mut r, &mut p, rr0, threshold, opts.max_iters)?.into_result()?;
    Ok(CgResult {
        x,
        iters: run.iters,
        rr_final: run.rr,
        rr0,
        converged: run.rr <= threshold,
        wall_seconds: t0.elapsed().as_secs_f64(),
        vector_passes_per_iter: 2.0,
        plan_searches: 1,
    })
}

/// Pipelined preconditioned CG ([`crate::cg::pipeline`]): one fused
/// vector pass and **one** reduction point per iteration. Serial here —
/// this is the bit-identity reference the pooled ([`PipePool`]) and
/// farm paths are validated against; the pooled variant is reached
/// through `ExecMode::Pipelined` in the session layer. `threaded` is
/// ignored (use the session/pool path for parallel pipelined CG).
pub fn solve_pipelined(
    a: &Csr,
    b: &[f64],
    precond: crate::cg::precond::Preconditioner,
    opts: &CgOptions,
) -> Result<CgResult> {
    use crate::cg::pipeline::{advance_serial, PipeState};
    use crate::cg::precond::Precond;
    validate(a, b)?;
    let blocks = crate::stencil::parallel::partition(a.n_rows, opts.parts);
    let pc = Precond::build(precond, a, &blocks)?;
    let t0 = std::time::Instant::now();
    let mut st = PipeState::prime(a, b, None, &pc)?;
    let rr0 = st.rr;
    let threshold = opts.tol * opts.tol * rr0;
    let run = advance_serial(a, &blocks, &pc, &mut st, threshold, opts.max_iters);
    if let Some(msg) = run.error {
        return Err(Error::Solver(msg));
    }
    Ok(CgResult {
        x: st.x,
        iters: run.iters,
        rr_final: st.rr,
        rr0,
        converged: st.rr <= threshold,
        wall_seconds: t0.elapsed().as_secs_f64(),
        // x/r/u/w/p/s/q/z/m fused into one sweep + the m' solve + the
        // SpMV read of m ≈ 3 effective vector passes
        vector_passes_per_iter: 3.0 + pc.spec().extra_passes(),
        plan_searches: 0,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;
    use crate::util::check::{allclose, forall, Prop};

    #[test]
    fn converges_on_poisson2d() {
        let a = gen::poisson2d(16);
        let b = gen::rhs(a.n_rows, 4);
        let opts = CgOptions::default();
        let res = solve_host_loop(&a, &b, &opts).unwrap();
        assert!(res.converged, "rr {} of {}", res.rr_final, res.rr0);
        // check the actual residual, not just the recurrence
        let mut ax = vec![0.0; a.n_rows];
        a.spmv_gold(&res.x, &mut ax);
        let rnorm: f64 =
            b.iter().zip(&ax).map(|(bi, ai)| (bi - ai) * (bi - ai)).sum::<f64>().sqrt();
        assert!(rnorm < 1e-6 * res.rr0.sqrt(), "true residual {rnorm}");
    }

    #[test]
    fn persistent_matches_host_loop_iterates() {
        let a = gen::clustered_spd(300, 7, 20, 9).unwrap();
        let b = gen::rhs(300, 1);
        let opts = CgOptions { max_iters: 40, tol: 0.0, ..Default::default() };
        let h = solve_host_loop(&a, &b, &opts).unwrap();
        let p = solve_persistent(&a, &b, &opts).unwrap();
        assert_eq!(h.iters, p.iters);
        if let Prop::Fail(m) = allclose(&h.x, &p.x, 1e-10, 1e-10) {
            panic!("{m}");
        }
        assert_eq!(p.plan_searches, 1);
        assert!(h.plan_searches >= h.iters);
        assert!(p.vector_passes_per_iter < h.vector_passes_per_iter);
    }

    #[test]
    fn rejects_bad_inputs() {
        let a = gen::poisson2d(4);
        assert!(solve_host_loop(&a, &[0.0; 3], &CgOptions::default()).is_err());
        // non-SPD: -I is symmetric but negative definite
        let neg = Csr::from_coo(2, 2, vec![(0, 0, -1.0), (1, 1, -1.0)]).unwrap();
        let err = solve_host_loop(&neg, &[1.0, 1.0], &CgOptions::default());
        assert!(err.is_err());
    }
    use crate::sparse::csr::Csr;

    #[test]
    fn exact_solution_short_circuits() {
        let a = gen::poisson2d(4);
        let b = vec![0.0; a.n_rows];
        let res = solve_persistent(&a, &b, &CgOptions::default()).unwrap();
        assert_eq!(res.iters, 0);
        assert!(res.converged || res.rr0 == 0.0);
    }

    #[test]
    fn property_solutions_satisfy_system() {
        forall(
            0xC6_u64 ^ 0xBEEF,
            8,
            |rng| {
                let n = 50 + rng.index(150);
                let a = gen::clustered_spd(n, 5, 16, rng.next_u64()).unwrap();
                let b: Vec<f64> = (0..n).map(|_| rng.f64() * 2.0 - 1.0).collect();
                (a, b)
            },
            |(a, b)| {
                let opts = CgOptions { max_iters: 5000, tol: 1e-10, ..Default::default() };
                let res = solve_persistent(a, b, &opts).unwrap();
                let mut ax = vec![0.0; a.n_rows];
                a.spmv_gold(&res.x, &mut ax);
                allclose(&ax, b, 1e-5, 1e-5)
            },
        );
    }

    #[test]
    fn pooled_solve_matches_the_other_models_and_converges() {
        let a = gen::poisson2d(14);
        let b = gen::rhs(a.n_rows, 6);
        let opts =
            CgOptions { max_iters: 30, tol: 0.0, parts: 8, threaded: true, workers: 3 };
        let s = solve_persistent(&a, &b, &CgOptions { threaded: false, ..opts.clone() })
            .unwrap();
        let pl = solve_pooled(&a, &b, &opts).unwrap();
        assert_eq!(s.iters, pl.iters);
        if let Prop::Fail(m) = allclose(&s.x, &pl.x, 1e-10, 1e-10) {
            panic!("{m}");
        }
        assert_eq!(pl.plan_searches, 1);
        assert_eq!(pl.vector_passes_per_iter, 2.0);
        // tolerance mode converges to a solution of the system
        let conv = solve_pooled(
            &a,
            &b,
            &CgOptions { max_iters: 5000, tol: 1e-9, parts: 8, threaded: true, workers: 2 },
        )
        .unwrap();
        assert!(conv.converged);
        let mut ax = vec![0.0; a.n_rows];
        a.spmv_gold(&conv.x, &mut ax);
        if let Prop::Fail(m) = allclose(&ax, &b, 1e-5, 1e-5) {
            panic!("{m}");
        }
    }

    #[test]
    fn pipelined_reaches_the_same_solution() {
        use crate::cg::precond::Preconditioner;
        let a = gen::poisson2d(14);
        let b = gen::rhs(a.n_rows, 3);
        let opts = CgOptions { max_iters: 5000, tol: 1e-10, ..Default::default() };
        let classic = solve_persistent(&a, &b, &opts).unwrap();
        for spec in [
            Preconditioner::None,
            Preconditioner::Jacobi,
            Preconditioner::BlockJacobi { block: 4 },
        ] {
            let piped = solve_pipelined(&a, &b, spec, &opts).unwrap();
            assert!(piped.converged, "{} did not converge", spec.name());
            if let Prop::Fail(m) = allclose(&classic.x, &piped.x, 1e-6, 1e-6) {
                panic!("{}: {m}", spec.name());
            }
            assert_eq!(piped.plan_searches, 0, "pipelined SpMV is row-partitioned");
        }
    }

    #[test]
    fn threaded_matches_sequential() {
        let a = gen::poisson2d(20);
        let b = gen::rhs(a.n_rows, 2);
        let seq = CgOptions { max_iters: 30, tol: 0.0, threaded: false, ..Default::default() };
        let thr = CgOptions { max_iters: 30, tol: 0.0, threaded: true, ..Default::default() };
        let s = solve_persistent(&a, &b, &seq).unwrap();
        let t = solve_persistent(&a, &b, &thr).unwrap();
        if let Prop::Fail(m) = allclose(&s.x, &t.x, 1e-12, 1e-12) {
            panic!("{m}");
        }
    }
}
