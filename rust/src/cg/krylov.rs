//! Further Krylov solvers from the paper's motivation (§I cites CG, BiCG
//! and GMRES as the iterative families PERKS targets): Jacobi-
//! preconditioned CG and BiCGstab, each under both execution models.
//!
//! The PERKS treatment is identical to `solver.rs`: hoist loop-invariant
//! data (merge plan, preconditioner diagonal), fuse the BLAS-1 passes.
//! Host-loop rebuilds/streams them per iteration. Iterates are identical
//! across models (tested).
//!
//! These solvers run single-threaded; the spawn-once worker-pool runtime
//! that gives plain CG its resident time loop and barrier-reduced dots
//! lives in [`crate::cg::pool`] (exposed as [`crate::cg::solve_pooled`]).
//! Extending the pool protocol to the preconditioned `z`/`rz` recurrence
//! here is the natural follow-up — the reduction slots and phase barriers
//! generalize unchanged.

use crate::error::{Error, Result};
use crate::sparse::csr::Csr;
use crate::spmv::merge::{self, MergePlan};

/// Execution model (re-exported shape of `stationary::Model`).
pub use crate::cg::stationary::Model;

/// Result of a Krylov solve.
#[derive(Clone, Debug)]
pub struct KrylovResult {
    pub x: Vec<f64>,
    pub iters: usize,
    pub rr_final: f64,
    pub converged: bool,
    pub wall_seconds: f64,
    /// Loop-invariant rebuilds (plan + preconditioner): 1 for persistent.
    pub invariant_builds: usize,
}

fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

fn jacobi_diag(a: &Csr) -> Result<Vec<f64>> {
    (0..a.n_rows)
        .map(|r| {
            a.get(r, r)
                .filter(|&d| d != 0.0)
                .map(|d| 1.0 / d)
                .ok_or_else(|| Error::Solver(format!("zero/missing diagonal at row {r}")))
        })
        .collect()
}

/// Jacobi-preconditioned CG. `model` decides whether the merge plan and
/// the preconditioner are cached (persistent) or rebuilt per iteration.
pub fn pcg(a: &Csr, b: &[f64], tol: f64, max_iters: usize, model: Model) -> Result<KrylovResult> {
    if b.len() != a.n_rows {
        return Err(Error::Solver("rhs size mismatch".into()));
    }
    let n = a.n_rows;
    let t0 = std::time::Instant::now();
    let mut x = vec![0.0; n];
    let mut r = b.to_vec();
    let mut invariant_builds = 0;
    let mut cached: Option<(MergePlan, Vec<f64>)> = None;
    let mut get_invariants = |a: &Csr| -> Result<(MergePlan, Vec<f64>)> {
        if model == Model::Persistent {
            if cached.is_none() {
                invariant_builds += 1;
                cached = Some((MergePlan::new(a, 16), jacobi_diag(a)?));
            }
            Ok(cached.clone().unwrap())
        } else {
            invariant_builds += 1;
            Ok((MergePlan::new(a, 16), jacobi_diag(a)?))
        }
    };
    let (_, minv) = get_invariants(a)?;
    let mut z: Vec<f64> = r.iter().zip(&minv).map(|(ri, mi)| ri * mi).collect();
    let mut p = z.clone();
    let mut rz = dot(&r, &z);
    let bb = dot(b, b);
    let threshold = tol * tol * bb;
    let mut ap = vec![0.0; n];
    let mut iters = 0;
    let mut rr = dot(&r, &r);
    while iters < max_iters && rr > threshold && rr > 0.0 {
        let (plan, minv) = get_invariants(a)?;
        merge::spmv(a, &plan, &p, &mut ap);
        let pap = dot(&p, &ap);
        if pap <= 0.0 {
            return Err(Error::Solver(format!("not positive definite (pAp={pap})")));
        }
        let alpha = rz / pap;
        match model {
            Model::Persistent => {
                // fused: x, r updates + rr in one pass; z + rz in another
                rr = 0.0;
                for i in 0..n {
                    x[i] += alpha * p[i];
                    let ri = r[i] - alpha * ap[i];
                    r[i] = ri;
                    rr += ri * ri;
                }
                let mut rz_new = 0.0;
                for i in 0..n {
                    let zi = r[i] * minv[i];
                    z[i] = zi;
                    rz_new += r[i] * zi;
                }
                let beta = rz_new / rz;
                rz = rz_new;
                for i in 0..n {
                    p[i] = z[i] + beta * p[i];
                }
            }
            Model::HostLoop => {
                // separate streamed passes
                for i in 0..n {
                    x[i] += alpha * p[i];
                }
                for i in 0..n {
                    r[i] -= alpha * ap[i];
                }
                rr = dot(&r, &r);
                for i in 0..n {
                    z[i] = r[i] * minv[i];
                }
                let rz_new = dot(&r, &z);
                let beta = rz_new / rz;
                rz = rz_new;
                for i in 0..n {
                    p[i] = z[i] + beta * p[i];
                }
            }
        }
        iters += 1;
    }
    Ok(KrylovResult {
        x,
        iters,
        rr_final: rr,
        converged: rr <= threshold,
        wall_seconds: t0.elapsed().as_secs_f64(),
        invariant_builds,
    })
}

/// BiCGstab (works for general nonsymmetric systems; here used as the
/// paper's BiCG-family representative). Same model split as `pcg`.
pub fn bicgstab(
    a: &Csr,
    b: &[f64],
    tol: f64,
    max_iters: usize,
    model: Model,
) -> Result<KrylovResult> {
    if b.len() != a.n_rows {
        return Err(Error::Solver("rhs size mismatch".into()));
    }
    let n = a.n_rows;
    let t0 = std::time::Instant::now();
    let mut x = vec![0.0; n];
    let mut r = b.to_vec();
    let r0 = b.to_vec();
    let mut p = r.clone();
    let mut v = vec![0.0; n];
    let mut s = vec![0.0; n];
    let mut t = vec![0.0; n];
    let mut rho = dot(&r0, &r);
    let bb = dot(b, b);
    let threshold = tol * tol * bb;
    let mut invariant_builds = 0;
    let plan_cached = if model == Model::Persistent {
        invariant_builds += 1;
        Some(MergePlan::new(a, 16))
    } else {
        None
    };
    let mut iters = 0;
    let mut rr = dot(&r, &r);
    while iters < max_iters && rr > threshold && rr > 0.0 {
        let plan = match &plan_cached {
            Some(p) => p.clone(),
            None => {
                invariant_builds += 1;
                MergePlan::new(a, 16)
            }
        };
        merge::spmv(a, &plan, &p, &mut v);
        let alpha = rho / dot(&r0, &v);
        if !alpha.is_finite() {
            return Err(Error::Solver("breakdown: r0.v == 0".into()));
        }
        for i in 0..n {
            s[i] = r[i] - alpha * v[i];
        }
        merge::spmv(a, &plan, &s, &mut t);
        let tt = dot(&t, &t);
        let omega = if tt > 0.0 { dot(&t, &s) / tt } else { 0.0 };
        rr = 0.0;
        for i in 0..n {
            x[i] += alpha * p[i] + omega * s[i];
            let ri = s[i] - omega * t[i];
            r[i] = ri;
            rr += ri * ri;
        }
        let rho_new = dot(&r0, &r);
        let beta = (rho_new / rho) * (alpha / omega.max(1e-300));
        rho = rho_new;
        for i in 0..n {
            p[i] = r[i] + beta * (p[i] - omega * v[i]);
        }
        iters += 1;
    }
    Ok(KrylovResult {
        x,
        iters,
        rr_final: rr,
        converged: rr <= threshold,
        wall_seconds: t0.elapsed().as_secs_f64(),
        invariant_builds,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;
    use crate::util::check::{allclose, Prop};

    #[test]
    fn pcg_converges_faster_than_plain_cg_on_skewed_diagonal() {
        // scale rows so the condition number hurts plain CG; Jacobi
        // preconditioning equalizes
        let base = gen::poisson2d(12);
        let n = base.n_rows;
        let mut trip = Vec::new();
        for row in 0..n {
            let scale = 1.0 + (row % 7) as f64 * 4.0;
            let (cols, vals) = base.row(row);
            for (&c, &v) in cols.iter().zip(vals) {
                // symmetric scaling keeps SPD
                let s = scale * (1.0 + (c % 7) as f64 * 4.0);
                trip.push((row, c, v * s.sqrt()));
            }
        }
        let a = crate::sparse::csr::Csr::from_coo(n, n, trip).unwrap();
        let b = gen::rhs(n, 3);
        let plain = crate::cg::solve_persistent(
            &a,
            &b,
            &crate::cg::CgOptions { max_iters: 3000, tol: 1e-8, ..Default::default() },
        )
        .unwrap();
        let pre = pcg(&a, &b, 1e-8, 3000, Model::Persistent).unwrap();
        assert!(pre.converged);
        assert!(
            pre.iters <= plain.iters,
            "PCG {} should not exceed CG {}",
            pre.iters,
            plain.iters
        );
    }

    #[test]
    fn pcg_models_identical_iterates() {
        let a = gen::clustered_spd(300, 7, 20, 11).unwrap();
        let b = gen::rhs(300, 5);
        let h = pcg(&a, &b, 0.0, 40, Model::HostLoop).unwrap();
        let p = pcg(&a, &b, 0.0, 40, Model::Persistent).unwrap();
        if let Prop::Fail(m) = allclose(&h.x, &p.x, 1e-10, 1e-10) {
            panic!("{m}");
        }
        assert_eq!(p.invariant_builds, 1);
        assert!(h.invariant_builds > 40);
    }

    #[test]
    fn pcg_solution_satisfies_system() {
        let a = gen::poisson2d(10);
        let b = gen::rhs(a.n_rows, 2);
        let res = pcg(&a, &b, 1e-10, 5000, Model::Persistent).unwrap();
        assert!(res.converged);
        let mut ax = vec![0.0; a.n_rows];
        a.spmv_gold(&res.x, &mut ax);
        if let Prop::Fail(m) = allclose(&ax, &b, 1e-5, 1e-5) {
            panic!("{m}");
        }
    }

    #[test]
    fn bicgstab_solves_spd_and_matches_models() {
        let a = gen::poisson2d(8);
        let b = gen::rhs(a.n_rows, 7);
        let h = bicgstab(&a, &b, 1e-9, 2000, Model::HostLoop).unwrap();
        let p = bicgstab(&a, &b, 1e-9, 2000, Model::Persistent).unwrap();
        assert!(h.converged && p.converged);
        let mut ax = vec![0.0; a.n_rows];
        a.spmv_gold(&p.x, &mut ax);
        if let Prop::Fail(m) = allclose(&ax, &b, 1e-4, 1e-4) {
            panic!("{m}");
        }
        assert_eq!(p.invariant_builds, 1);
    }

    #[test]
    fn bicgstab_solves_nonsymmetric() {
        // upwind-ish convection-diffusion: nonsymmetric, CG would fail
        let n = 100;
        let mut trip = Vec::new();
        for i in 0..n {
            trip.push((i, i, 4.0));
            if i > 0 {
                trip.push((i, i - 1, -1.5)); // asymmetric couplings
            }
            if i + 1 < n {
                trip.push((i, i + 1, -0.5));
            }
        }
        let a = crate::sparse::csr::Csr::from_coo(n, n, trip).unwrap();
        assert!(!a.is_symmetric(0.0));
        let b = gen::rhs(n, 1);
        let res = bicgstab(&a, &b, 1e-10, 2000, Model::Persistent).unwrap();
        assert!(res.converged, "rr {}", res.rr_final);
        let mut ax = vec![0.0; n];
        a.spmv_gold(&res.x, &mut ax);
        if let Prop::Fail(m) = allclose(&ax, &b, 1e-5, 1e-5) {
            panic!("{m}");
        }
    }

    #[test]
    fn bad_inputs_rejected() {
        let a = gen::poisson2d(4);
        assert!(pcg(&a, &[1.0; 3], 1e-6, 10, Model::HostLoop).is_err());
        assert!(bicgstab(&a, &[1.0; 3], 1e-6, 10, Model::HostLoop).is_err());
    }
}
