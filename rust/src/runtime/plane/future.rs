//! Completion futures: the async face of a farm command.
//!
//! A completion future resolves when the farm's workers finish the
//! in-flight command of its tenant. Polling registers the task's waker
//! in the tenant (under the scheduler lock); the worker that completes
//! the command — or a farm shutdown — fires it. Resolving *harvests* the
//! command exactly like the blocking `wait` (clears the in-flight state,
//! takes the run/error, releases the tenant's plane slots), so
//! `submit` + await is interchangeable with `submit` + `wait`; indeed
//! the blocking wrappers are `block_on` over these futures.
//!
//! Dropping a completion future before it resolves does **not** cancel
//! the command — the farm keeps executing it, and a later `wait` (or new
//! future) can still harvest it — but it *does* release the tenant's
//! plane slots immediately, so an abandoned client cannot pin admission
//! capacity (the zombie-future guarantee, exercised by the plane tests).

use std::future::Future;
use std::marker::PhantomData;
use std::pin::Pin;
use std::task::{Context, Poll};

use crate::error::Result;
use crate::runtime::farm::{CgFarmRun, FarmCg, FarmHandle, FarmStencil, StencilFarmRun};

/// Future of an in-flight stencil command; created by
/// [`FarmStencil::completion`] / [`FarmStencil::submit_async`]. Borrows
/// the session handle for its lifetime (the submit/await handshake —
/// like `wait`, nothing else may touch the session mid-flight).
pub struct StencilCompletion<'t> {
    farm: FarmHandle,
    tid: usize,
    finished: bool,
    _session: PhantomData<&'t mut FarmStencil>,
}

impl<'t> StencilCompletion<'t> {
    pub(crate) fn new(farm: FarmHandle, tid: usize) -> Self {
        Self { farm, tid, finished: false, _session: PhantomData }
    }
}

impl Future for StencilCompletion<'_> {
    type Output = Result<StencilFarmRun>;

    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<Self::Output> {
        let this = self.get_mut();
        match this.farm.poll_stencil_done(this.tid, cx.waker()) {
            Poll::Ready(out) => {
                this.finished = true;
                Poll::Ready(out)
            }
            Poll::Pending => Poll::Pending,
        }
    }
}

impl Drop for StencilCompletion<'_> {
    fn drop(&mut self) {
        if !self.finished {
            self.farm.forget_completion(self.tid);
        }
    }
}

/// Future of an in-flight CG command; resolving copies the advanced
/// x/r/p back into the borrowed output slices (the same command-boundary
/// copy-out as the blocking `wait`). Created by [`FarmCg::completion`] /
/// [`FarmCg::submit_async`].
pub struct CgCompletion<'t> {
    farm: FarmHandle,
    tid: usize,
    finished: bool,
    x: &'t mut [f64],
    r: &'t mut [f64],
    p: &'t mut [f64],
    _session: PhantomData<&'t mut FarmCg>,
}

impl<'t> CgCompletion<'t> {
    pub(crate) fn new(
        farm: FarmHandle,
        tid: usize,
        x: &'t mut [f64],
        r: &'t mut [f64],
        p: &'t mut [f64],
    ) -> Self {
        Self { farm, tid, finished: false, x, r, p, _session: PhantomData }
    }
}

impl Future for CgCompletion<'_> {
    type Output = Result<CgFarmRun>;

    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<Self::Output> {
        let this = self.get_mut();
        match this.farm.poll_cg_done(this.tid, cx.waker(), this.x, this.r, this.p) {
            Poll::Ready(out) => {
                this.finished = true;
                Poll::Ready(out)
            }
            Poll::Pending => Poll::Pending,
        }
    }
}

impl Drop for CgCompletion<'_> {
    fn drop(&mut self) {
        if !self.finished {
            self.farm.forget_completion(self.tid);
        }
    }
}
