//! The plane's reactor primitives: dependency-free wakers and `block_on`.
//!
//! The farm's completion events are condvar broadcasts; the plane turns
//! them into `std::task` wakes. Two waker flavors cover every consumer:
//!
//! * [`TaskWaker`] — a task id plus a cross-thread [`WakeQueue`]. A farm
//!   worker finishing a command calls `Waker::wake`, which enqueues the
//!   id (deduplicated by an atomic flag, so a wake storm costs one queue
//!   entry per task) and signals the queue's condvar; the owning
//!   [`super::LocalExecutor`] drains ids and re-polls exactly those
//!   tasks. This is the mini-async-runtime structure with the reactor's
//!   event source being the farm scheduler instead of an OS poller.
//! * the thread-parking waker inside [`block_on`] — drives one future on
//!   the calling thread, which is how the farm's *blocking* `wait`
//!   wrappers are now implemented on top of the async completion path.

use std::future::Future;
use std::pin::Pin;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::task::{Context, Poll, Wake, Waker};
use std::thread::Thread;

/// Cross-thread ready queue: task ids whose futures should be re-polled.
pub(crate) struct WakeQueue {
    ready: Mutex<Vec<usize>>,
    cv: Condvar,
}

impl WakeQueue {
    pub(crate) fn new() -> Arc<Self> {
        Arc::new(Self { ready: Mutex::new(Vec::new()), cv: Condvar::new() })
    }

    /// Enqueue a task id and signal the draining executor. Callers
    /// deduplicate via [`TaskWaker::queued`]; the queue itself is dumb.
    pub(crate) fn push(&self, id: usize) {
        let mut q = self.ready.lock().unwrap_or_else(|p| p.into_inner());
        q.push(id);
        self.cv.notify_one();
    }

    /// Park until at least one id is queued, then take the whole batch.
    pub(crate) fn wait_drain(&self) -> Vec<usize> {
        let mut q = self.ready.lock().unwrap_or_else(|p| p.into_inner());
        while q.is_empty() {
            // lint: allow(condvar-shutdown) -- the executor owns this queue and drains it on its own thread; there is no cross-thread teardown protocol that could strand the wait
            q = self.cv.wait(q).unwrap_or_else(|p| p.into_inner());
        }
        std::mem::take(&mut *q)
    }
}

/// Waker of one executor task: pushes the task id into the executor's
/// [`WakeQueue`]. The `queued` flag collapses redundant wakes between
/// polls; the executor clears it immediately before polling so a wake
/// arriving *during* the poll still lands.
pub(crate) struct TaskWaker {
    id: usize,
    queued: AtomicBool,
    queue: Arc<WakeQueue>,
}

impl TaskWaker {
    pub(crate) fn new(id: usize, queue: Arc<WakeQueue>) -> Self {
        Self { id, queued: AtomicBool::new(false), queue }
    }

    /// Re-arm the dedup flag; called by the executor right before polling.
    pub(crate) fn clear(&self) {
        self.queued.store(false, Ordering::Release);
    }
}

impl Wake for TaskWaker {
    fn wake(self: Arc<Self>) {
        Self::wake_by_ref(&self);
    }

    fn wake_by_ref(self: &Arc<Self>) {
        if !self.queued.swap(true, Ordering::AcqRel) {
            self.queue.push(self.id);
        }
    }
}

/// Thread-parking waker: `wake` unparks the captured thread. Parking
/// tokens make the unpark-before-park race benign, and [`block_on`]
/// re-polls on every wake (spurious unparks are just extra polls).
struct ThreadWaker {
    thread: Thread,
}

impl Wake for ThreadWaker {
    fn wake(self: Arc<Self>) {
        self.thread.unpark();
    }

    fn wake_by_ref(self: &Arc<Self>) {
        self.thread.unpark();
    }
}

/// Drive one future to completion on the calling thread, parking between
/// polls. This is the degenerate single-task executor the farm's blocking
/// `wait` wrappers are built on; for multiplexing many completions on one
/// thread use [`super::LocalExecutor`].
pub fn block_on<F: Future>(fut: F) -> F::Output {
    let mut fut = Box::pin(fut);
    let waker = Waker::from(Arc::new(ThreadWaker { thread: std::thread::current() }));
    let mut cx = Context::from_waker(&waker);
    loop {
        match Pin::new(&mut fut).as_mut().poll(&mut cx) {
            Poll::Ready(v) => return v,
            Poll::Pending => std::thread::park(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_on_drives_ready_and_yielding_futures() {
        assert_eq!(block_on(async { 7 }), 7);

        /// Pends once, waking itself immediately.
        struct YieldOnce(bool);
        impl Future for YieldOnce {
            type Output = u32;
            fn poll(mut self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<u32> {
                if self.0 {
                    Poll::Ready(42)
                } else {
                    self.0 = true;
                    cx.waker().wake_by_ref();
                    Poll::Pending
                }
            }
        }
        assert_eq!(block_on(YieldOnce(false)), 42);
    }

    #[test]
    fn block_on_crosses_threads_through_the_waker() {
        struct Gate {
            fired: Arc<AtomicBool>,
            waker_slot: Arc<Mutex<Option<Waker>>>,
        }
        impl Future for Gate {
            type Output = ();
            fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<()> {
                if self.fired.load(Ordering::Acquire) {
                    Poll::Ready(())
                } else {
                    // publish the waker for the setter thread — the same
                    // register-then-park protocol the farm futures use
                    let mut slot = self.waker_slot.lock().unwrap();
                    *slot = Some(cx.waker().clone());
                    Poll::Pending
                }
            }
        }
        let fired = Arc::new(AtomicBool::new(false));
        let slot: Arc<Mutex<Option<Waker>>> = Arc::new(Mutex::new(None));
        let (f2, s2) = (fired.clone(), slot.clone());
        let setter = std::thread::spawn(move || loop {
            if let Some(w) = s2.lock().unwrap().take() {
                f2.store(true, Ordering::Release);
                w.wake();
                break;
            }
            std::thread::yield_now();
        });
        block_on(Gate { fired, waker_slot: slot });
        setter.join().unwrap();
    }

    #[test]
    fn task_waker_dedups_until_cleared() {
        let q = WakeQueue::new();
        let w = Arc::new(TaskWaker::new(3, q.clone()));
        let waker = Waker::from(w.clone());
        waker.wake_by_ref();
        waker.wake_by_ref();
        waker.wake_by_ref();
        assert_eq!(q.wait_drain(), vec![3], "redundant wakes collapse");
        w.clear();
        waker.wake_by_ref();
        assert_eq!(q.wait_drain(), vec![3], "re-armed after clear");
    }
}
