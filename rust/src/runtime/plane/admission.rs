//! Admission control and backpressure for the submission plane.
//!
//! Every command enqueued to a [`crate::runtime::farm::SolverFarm`] —
//! blocking or async, single command or batched [`super::CommandGraph`] —
//! first claims *plane slots* from a bounded submission budget: one slot
//! per queued graph segment (a plain `submit` is a one-segment batch).
//! Slots are released when the command's result is harvested, when a
//! completion future is dropped before completing (the zombie-future
//! path), or when the tenant itself is released. The budget is two caps:
//!
//! * [`PlaneConfig::queue_cap`] — total slots across all tenants, the
//!   farm-wide submission queue bound;
//! * [`PlaneConfig::per_tenant`] — slots one tenant may hold at once,
//!   so a single chatty client cannot monopolize the queue.
//!
//! When a submission does not fit, the [`AdmissionPolicy`] decides:
//! `Block` parks the submitting thread until slots free up (the default —
//! with the default unbounded caps it never parks, preserving the PR-5
//! blocking semantics exactly), `Shed` fails fast with
//! [`crate::error::Error::Shed`], and `Timeout` parks up to a deadline
//! then fails with [`crate::error::Error::Timeout`]. Sheds and timeouts
//! are counted per farm ([`crate::runtime::farm::FarmMetrics`]) and
//! process-wide ([`crate::util::counters::plane_sheds`] /
//! [`crate::util::counters::plane_timeouts`]).
//!
//! The acquire itself is synchronous in every submit variant: `Block` and
//! `Timeout` park the *submitting OS thread*. Async front-ends that must
//! never park a [`super::LocalExecutor`] thread should either size the
//! caps to their tenancy or use `Shed` and treat the error as a retry
//! signal; a submission larger than either cap can never fit and is shed
//! immediately regardless of policy.

use std::time::Duration;

use crate::error::{Error, Result};

/// What to do when a submission does not fit the plane's bounded queue.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Park the submitting thread until enough slots free up (default).
    Block,
    /// Fail fast with [`Error::Shed`]; the command is not enqueued.
    Shed,
    /// Park up to the given duration, then fail with [`Error::Timeout`].
    Timeout(Duration),
}

/// Submission-plane budget of one farm: queue bound, per-tenant cap, and
/// the backpressure policy. The default is unbounded/`Block` — byte-for-
/// byte the pre-plane farm behavior.
#[derive(Clone, Copy, Debug)]
pub struct PlaneConfig {
    /// Total plane slots across all tenants (queued graph segments).
    pub queue_cap: usize,
    /// Plane slots one tenant may hold at once.
    pub per_tenant: usize,
    /// Policy applied when a submission does not fit.
    pub policy: AdmissionPolicy,
}

impl Default for PlaneConfig {
    fn default() -> Self {
        Self {
            queue_cap: usize::MAX,
            per_tenant: usize::MAX,
            policy: AdmissionPolicy::Block,
        }
    }
}

impl PlaneConfig {
    /// Unbounded queue, `Block` policy (the pre-plane farm behavior).
    pub fn unbounded() -> Self {
        Self::default()
    }

    /// Bound the farm-wide submission queue to `cap` slots.
    pub fn bounded(cap: usize) -> Self {
        Self { queue_cap: cap, ..Self::default() }
    }

    /// Cap the slots one tenant may hold at once.
    pub fn per_tenant(mut self, cap: usize) -> Self {
        self.per_tenant = cap;
        self
    }

    /// Set the backpressure policy.
    pub fn policy(mut self, policy: AdmissionPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Validate the caps (zero-capacity queues can admit nothing).
    pub fn validate(&self) -> Result<()> {
        if self.queue_cap == 0 {
            return Err(Error::invalid("plane queue_cap must be >= 1"));
        }
        if self.per_tenant == 0 {
            return Err(Error::invalid("plane per_tenant cap must be >= 1"));
        }
        if let AdmissionPolicy::Timeout(d) = self.policy {
            if d.is_zero() {
                return Err(Error::invalid(
                    "plane Timeout policy needs a non-zero duration (use Shed to fail fast)",
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_unbounded_block() {
        let c = PlaneConfig::default();
        assert_eq!(c.queue_cap, usize::MAX);
        assert_eq!(c.per_tenant, usize::MAX);
        assert_eq!(c.policy, AdmissionPolicy::Block);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn zero_caps_and_zero_timeouts_are_rejected() {
        assert!(PlaneConfig::bounded(0).validate().is_err());
        assert!(PlaneConfig::bounded(4).per_tenant(0).validate().is_err());
        let zero = PlaneConfig::bounded(4).policy(AdmissionPolicy::Timeout(Duration::ZERO));
        assert!(zero.validate().is_err());
        let ok = PlaneConfig::bounded(4)
            .per_tenant(2)
            .policy(AdmissionPolicy::Timeout(Duration::from_millis(5)));
        assert!(ok.validate().is_ok());
    }
}
