//! `LocalExecutor`: one front-end thread multiplexing many futures.
//!
//! The serving shape the plane exists for: thousands of tenants, each a
//! small async task (`submit` → await completion → resubmit), all driven
//! by one OS thread. The executor is single-threaded and dependency-free
//! — a slab of boxed futures, a shared [`super::reactor::WakeQueue`], and
//! per-task [`super::reactor::TaskWaker`]s that farm workers fire from
//! completion transitions. Only woken tasks are re-polled; an idle
//! executor parks on the queue's condvar and costs nothing.
//!
//! Structure follows the mini-async-runtime exemplar (SNIPPETS.md §1–2):
//! `spawn` returns a [`JoinHandle`] future, `run` drives a main future
//! (typically `async { for h in handles { h.await; } }`) until it
//! resolves. Tasks and handles are `!Send` — pin one executor per
//! front-end thread; cross-thread communication happens through wakers,
//! which are `Send` by construction.

use std::cell::RefCell;
use std::future::Future;
use std::pin::Pin;
use std::rc::Rc;
use std::sync::Arc;
use std::task::{Context, Poll, Waker};

use super::reactor::{TaskWaker, WakeQueue};

/// Reserved wake-queue id of the future passed to [`LocalExecutor::run`].
const MAIN_ID: usize = usize::MAX;

struct TaskEntry {
    fut: Pin<Box<dyn Future<Output = ()>>>,
    flag: Arc<TaskWaker>,
    waker: Waker,
}

/// Shared completion slot between a spawned task and its [`JoinHandle`].
struct JoinState<T> {
    value: Option<T>,
    waker: Option<Waker>,
}

/// Future resolving to a spawned task's output. Awaited from other tasks
/// on the same executor (usually the `run` main future).
pub struct JoinHandle<T> {
    state: Rc<RefCell<JoinState<T>>>,
}

impl<T> Future for JoinHandle<T> {
    type Output = T;

    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<T> {
        let mut st = self.state.borrow_mut();
        match st.value.take() {
            Some(v) => Poll::Ready(v),
            None => {
                st.waker = Some(cx.waker().clone());
                Poll::Pending
            }
        }
    }
}

/// A single-threaded executor driving spawned tasks plus one main future.
/// See the module docs for the serving shape it implements.
pub struct LocalExecutor {
    queue: Arc<WakeQueue>,
    tasks: RefCell<Vec<Option<TaskEntry>>>,
    free: RefCell<Vec<usize>>,
}

impl Default for LocalExecutor {
    fn default() -> Self {
        Self::new()
    }
}

impl LocalExecutor {
    pub fn new() -> Self {
        Self {
            queue: WakeQueue::new(),
            tasks: RefCell::new(Vec::new()),
            free: RefCell::new(Vec::new()),
        }
    }

    /// Number of spawned tasks that have not completed yet.
    pub fn live_tasks(&self) -> usize {
        self.tasks.borrow().iter().filter(|t| t.is_some()).count()
    }

    /// Spawn a task; it is polled by [`LocalExecutor::run`] whenever its
    /// waker fires (and once to start). The returned [`JoinHandle`]
    /// resolves to the task's output.
    pub fn spawn<T: 'static>(&self, fut: impl Future<Output = T> + 'static) -> JoinHandle<T> {
        let state = Rc::new(RefCell::new(JoinState { value: None, waker: None }));
        let st = state.clone();
        let wrapped: Pin<Box<dyn Future<Output = ()>>> = Box::pin(async move {
            let v = fut.await;
            let join_waker = {
                let mut s = st.borrow_mut();
                s.value = Some(v);
                s.waker.take()
            };
            if let Some(w) = join_waker {
                w.wake();
            }
        });
        let id = match self.free.borrow_mut().pop() {
            Some(id) => id,
            None => {
                let mut tasks = self.tasks.borrow_mut();
                tasks.push(None);
                tasks.len() - 1
            }
        };
        let flag = Arc::new(TaskWaker::new(id, self.queue.clone()));
        let waker = Waker::from(flag.clone());
        self.tasks.borrow_mut()[id] = Some(TaskEntry { fut: wrapped, flag, waker });
        // seed the first poll through the normal wake path
        // lint: allow(no-panic) -- the entry was inserted into the slab on the line above; nothing can remove it in between on this single thread
        self.tasks.borrow()[id].as_ref().expect("just inserted").waker.wake_by_ref();
        JoinHandle { state }
    }

    /// Drive `main` (and every spawned task) until `main` resolves.
    /// Re-entrant spawns — tasks spawning tasks mid-poll — are fine; the
    /// executor holds no slab borrow across a poll.
    pub fn run<T>(&self, main: impl Future<Output = T>) -> T {
        let mut main = Box::pin(main);
        let main_flag = Arc::new(TaskWaker::new(MAIN_ID, self.queue.clone()));
        let main_waker = Waker::from(main_flag.clone());
        main_waker.wake_by_ref(); // seed the first poll of main
        loop {
            for id in self.queue.wait_drain() {
                if id == MAIN_ID {
                    main_flag.clear();
                    let mut cx = Context::from_waker(&main_waker);
                    if let Poll::Ready(v) = main.as_mut().poll(&mut cx) {
                        return v;
                    }
                } else {
                    self.poll_task(id);
                }
            }
        }
    }

    /// Poll one spawned task. The entry is taken out of the slab for the
    /// duration of the poll so the task can call `spawn` re-entrantly; a
    /// wake landing mid-poll re-queues the id, and a queued id whose task
    /// already finished (or whose slot was reused) is a no-op/spurious
    /// poll, which futures tolerate by contract.
    fn poll_task(&self, id: usize) {
        let entry = self.tasks.borrow_mut()[id].take();
        let Some(mut entry) = entry else { return };
        entry.flag.clear();
        let mut cx = Context::from_waker(&entry.waker);
        match entry.fut.as_mut().poll(&mut cx) {
            Poll::Ready(()) => self.free.borrow_mut().push(id),
            Poll::Pending => self.tasks.borrow_mut()[id] = Some(entry),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cell::Cell;

    #[test]
    fn run_drives_main_to_completion() {
        let ex = LocalExecutor::new();
        assert_eq!(ex.run(async { 5 }), 5);
    }

    #[test]
    fn spawned_tasks_complete_and_join_in_any_order() {
        let ex = LocalExecutor::new();
        let a = ex.spawn(async { 1u64 });
        let b = ex.spawn(async { 2u64 });
        let c = ex.spawn(async { 3u64 });
        // join out of spawn order: values route through the right handles
        let got = ex.run(async move { (c.await, a.await, b.await) });
        assert_eq!(got, (3, 1, 2));
        assert_eq!(ex.live_tasks(), 0);
    }

    #[test]
    fn reentrant_spawn_from_a_running_task_works() {
        let ex = Rc::new(LocalExecutor::new());
        let ex2 = ex.clone();
        let h = ex.spawn(async move {
            let inner = ex2.spawn(async { 10u32 });
            inner.await + 1
        });
        assert_eq!(ex.run(async move { h.await }), 11);
    }

    #[test]
    fn task_slots_are_reused_across_generations() {
        let ex = LocalExecutor::new();
        for round in 0..50u64 {
            let h = ex.spawn(async move { round });
            assert_eq!(ex.run(async move { h.await }), round);
        }
        assert!(ex.tasks.borrow().len() <= 2, "slab must recycle slots");
    }

    #[test]
    fn yielding_tasks_interleave_on_one_thread() {
        /// Cooperative yield: pend once, self-wake.
        struct YieldNow(bool);
        impl Future for YieldNow {
            type Output = ();
            fn poll(mut self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<()> {
                if self.0 {
                    Poll::Ready(())
                } else {
                    self.0 = true;
                    cx.waker().wake_by_ref();
                    Poll::Pending
                }
            }
        }
        let ex = LocalExecutor::new();
        let order = Rc::new(RefCell::new(Vec::new()));
        let hits = Rc::new(Cell::new(0usize));
        let mut handles = Vec::new();
        for i in 0..4usize {
            let order = order.clone();
            let hits = hits.clone();
            handles.push(ex.spawn(async move {
                order.borrow_mut().push((i, 0));
                YieldNow(false).await;
                order.borrow_mut().push((i, 1));
                hits.set(hits.get() + 1);
            }));
        }
        ex.run(async move {
            for h in handles {
                h.await;
            }
        });
        assert_eq!(hits.get(), 4);
        let o = order.borrow();
        // every task ran its first leg before any ran its second:
        // genuine interleaving, not sequential task execution
        let first_second = o.iter().position(|&(_, leg)| leg == 1).unwrap();
        assert_eq!(first_second, 4, "all first legs precede the second legs: {o:?}");
    }
}
