//! The async submission plane in front of [`crate::runtime::farm`].
//!
//! PR 5's `SolverFarm` removed per-session thread spawns; its front-end,
//! however, was still blocking — one OS thread per in-flight command —
//! so tenancy capped at thread-count scale. The plane removes that last
//! per-session host cost with three cooperating pieces:
//!
//! 1. **Reactor + executor** ([`reactor`], [`executor`]): completion
//!    futures whose wakers are fired by the farm's own completion
//!    transitions, driven by a dependency-free single-threaded
//!    [`LocalExecutor`]. One front-end thread multiplexes thousands of
//!    in-flight sessions; the blocking `wait` wrappers are now
//!    [`block_on`] over the same futures.
//! 2. **Batched command graphs** ([`graph`]): a [`CommandGraph`] encodes
//!    an entire `advance_until` schedule — epoch-chain segments, the
//!    tolerance check, a resubmission policy — as one pre-built object
//!    enqueued under a *single* scheduler-lock acquisition. Segment
//!    boundaries are dequeued inside the farm's completion transition
//!    (the lock is already held), so lock acquisitions scale with
//!    batches, not epochs: `counters::sched_lock_acquisitions ==
//!    counters::plane_batches` on the batched path.
//! 3. **Admission control** ([`admission`]): a bounded submission queue
//!    with per-tenant caps and a block/shed/timeout policy, so overload
//!    degrades into counted backpressure instead of unbounded queueing.
//!
//! All three preserve the farm's bit-identity bar: the plane schedules
//! *when* work is enqueued and *who* waits, never how a shard computes.

pub mod admission;
pub mod executor;
pub mod future;
pub mod graph;
pub mod reactor;

pub use admission::{AdmissionPolicy, PlaneConfig};
pub use executor::{JoinHandle, LocalExecutor};
pub use future::{CgCompletion, StencilCompletion};
pub use graph::{CommandGraph, CommandGraphBuilder};
pub use reactor::block_on;
