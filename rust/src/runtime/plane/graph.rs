//! Batched command graphs: a whole `advance_until` schedule as one
//! pre-built submission.
//!
//! The per-epoch cost the farm still paid after PR 5 was *submission*:
//! a client advancing a session in small chunks re-acquired the scheduler
//! lock once per chunk. CUDA Graphs amortizes exactly this class of cost
//! by capturing a kernel chain once and launching it as a unit (Ekelund
//! et al., *Kernel Batching with CUDA Graphs*); the plane's analog is a
//! [`CommandGraph`] — an epoch-chain schedule (segments), an optional
//! tolerance check between segments, and a resubmission policy — built
//! once and enqueued under a **single scheduler-lock acquisition**.
//! Segment boundaries are then dequeued by the farm's own completion
//! transition, *under the already-held scheduler lock*, never by a
//! client round-trip: [`crate::util::counters::sched_lock_acquisitions`]
//! grows by exactly one per graph no matter how many segments it chains
//! (counter-asserted by `bench_check`).
//!
//! Because a dequeued segment simply extends the in-flight command's
//! target (steps for stencils, iterations for CG) before the final-store
//! phase is reached, a graph's execution is *literally* the monolithic
//! command's execution — same phases, same bytes, same bits — and a
//! tolerance stop inside any segment drops the rest of the schedule,
//! exactly like the monolithic `advance_until` epoch stop.

use crate::error::{Error, Result};

/// A pre-built batched submission. Build with [`CommandGraph::builder`]
/// or the [`CommandGraph::schedule`] convenience; submit with
/// `FarmStencil::submit_graph` / `FarmCg::submit_graph` (or their
/// blocking/async advance wrappers).
#[derive(Clone, Debug)]
pub struct CommandGraph {
    segments: Vec<usize>,
    tol: Option<f64>,
    resubmits: u32,
}

impl CommandGraph {
    pub fn builder() -> CommandGraphBuilder {
        CommandGraphBuilder { segments: Vec::new(), tol: None, resubmits: 0 }
    }

    /// Convenience: chunk a `total`-step (or -iteration) schedule into
    /// segments of `segment` each (last one partial), with an optional
    /// tolerance/threshold. Equivalent to the monolithic
    /// `advance(total, tol)` bit for bit.
    pub fn schedule(total: usize, segment: usize, tol: Option<f64>) -> Result<CommandGraph> {
        if total == 0 {
            return Err(Error::invalid("command graph schedule needs total >= 1"));
        }
        if segment == 0 {
            return Err(Error::invalid("command graph schedule needs segment >= 1"));
        }
        let mut b = Self::builder();
        let mut left = total;
        while left > 0 {
            let s = segment.min(left);
            b = b.segment(s);
            left -= s;
        }
        if let Some(t) = tol {
            b = b.tolerance(t);
        }
        b.build()
    }

    /// Epoch-chain segments, in execution order.
    pub fn segments(&self) -> &[usize] {
        &self.segments
    }

    /// Tolerance (stencil residual) / threshold (CG squared residual)
    /// checked while the schedule runs.
    pub fn tol(&self) -> Option<f64> {
        self.tol
    }

    /// How many times the whole schedule is re-enqueued if it finishes
    /// without converging (0 = run once).
    pub fn resubmits(&self) -> u32 {
        self.resubmits
    }

    /// Total steps/iterations of one pass over the schedule.
    pub fn total(&self) -> usize {
        self.segments.iter().sum()
    }
}

/// Builder for [`CommandGraph`]; validation happens in
/// [`CommandGraphBuilder::build`].
#[derive(Clone, Debug)]
pub struct CommandGraphBuilder {
    segments: Vec<usize>,
    tol: Option<f64>,
    resubmits: u32,
}

impl CommandGraphBuilder {
    /// Append one segment of `steps` steps (stencil) / iterations (CG).
    pub fn segment(mut self, steps: usize) -> Self {
        self.segments.push(steps);
        self
    }

    /// Append several segments in order.
    pub fn segments(mut self, steps: &[usize]) -> Self {
        self.segments.extend_from_slice(steps);
        self
    }

    /// Track the residual and stop the whole schedule once it reaches
    /// `tol` (stencil epoch residual / CG squared-residual threshold).
    pub fn tolerance(mut self, tol: f64) -> Self {
        self.tol = Some(tol);
        self
    }

    /// Re-enqueue the whole schedule up to `times` more times while the
    /// tolerance has not been reached — the graph-resident analog of a
    /// client retry loop, with zero extra lock acquisitions. Requires a
    /// tolerance (an unconditional resubmit could never terminate early
    /// and is almost certainly a bug).
    pub fn resubmit(mut self, times: u32) -> Self {
        self.resubmits = times;
        self
    }

    pub fn build(self) -> Result<CommandGraph> {
        if self.segments.is_empty() {
            return Err(Error::invalid("command graph needs at least one segment"));
        }
        if self.segments.iter().any(|&s| s == 0) {
            return Err(Error::invalid("command graph segments must be >= 1 steps"));
        }
        if self.resubmits > 0 && self.tol.is_none() {
            return Err(Error::invalid(
                "command graph resubmission requires a tolerance to converge on",
            ));
        }
        Ok(CommandGraph {
            segments: self.segments,
            tol: self.tol,
            resubmits: self.resubmits,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_validates_segments_and_resubmit() {
        assert!(CommandGraph::builder().build().is_err(), "empty graph");
        assert!(
            CommandGraph::builder().segment(4).segment(0).build().is_err(),
            "zero segment"
        );
        assert!(
            CommandGraph::builder().segment(4).resubmit(2).build().is_err(),
            "resubmit without tolerance"
        );
        let g = CommandGraph::builder()
            .segments(&[4, 4, 2])
            .tolerance(1e-8)
            .resubmit(3)
            .build()
            .unwrap();
        assert_eq!(g.segments(), &[4, 4, 2]);
        assert_eq!(g.total(), 10);
        assert_eq!(g.tol(), Some(1e-8));
        assert_eq!(g.resubmits(), 3);
    }

    #[test]
    fn schedule_chunks_with_a_partial_tail() {
        let g = CommandGraph::schedule(10, 4, None).unwrap();
        assert_eq!(g.segments(), &[4, 4, 2]);
        assert_eq!(g.tol(), None);
        let g = CommandGraph::schedule(8, 100, Some(1e-6)).unwrap();
        assert_eq!(g.segments(), &[8]);
        assert_eq!(g.tol(), Some(1e-6));
        assert!(CommandGraph::schedule(0, 4, None).is_err());
        assert!(CommandGraph::schedule(4, 0, None).is_err());
    }
}
