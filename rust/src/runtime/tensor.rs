//! Host-side tensors and conversion to/from XLA `Literal`s.
//!
//! The coordinator's state lives in `HostTensor`s; the runtime marshals
//! them across the PJRT boundary. Conversions validate against the
//! artifact's `TensorSpec` so shape/dtype bugs surface as `Error::Shape`
//! rather than runtime crashes inside XLA.

use crate::error::{Error, Result};
use crate::runtime::manifest::{DType, TensorSpec};

/// A host-resident tensor (row-major).
#[derive(Clone, Debug, PartialEq)]
pub enum HostTensor {
    F32 { dims: Vec<usize>, data: Vec<f32> },
    F64 { dims: Vec<usize>, data: Vec<f64> },
    I32 { dims: Vec<usize>, data: Vec<i32> },
}

impl HostTensor {
    pub fn f32(dims: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        HostTensor::F32 { dims: dims.to_vec(), data }
    }

    pub fn f64(dims: &[usize], data: Vec<f64>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        HostTensor::F64 { dims: dims.to_vec(), data }
    }

    pub fn i32(dims: &[usize], data: Vec<i32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        HostTensor::I32 { dims: dims.to_vec(), data }
    }

    /// Zero-filled tensor matching a spec.
    pub fn zeros(spec: &TensorSpec) -> Self {
        let n = spec.elements();
        match spec.dtype {
            DType::F32 => Self::f32(&spec.dims, vec![0.0; n]),
            DType::F64 => Self::f64(&spec.dims, vec![0.0; n]),
            DType::I32 => Self::i32(&spec.dims, vec![0; n]),
        }
    }

    pub fn dims(&self) -> &[usize] {
        match self {
            HostTensor::F32 { dims, .. }
            | HostTensor::F64 { dims, .. }
            | HostTensor::I32 { dims, .. } => dims,
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            HostTensor::F32 { .. } => DType::F32,
            HostTensor::F64 { .. } => DType::F64,
            HostTensor::I32 { .. } => DType::I32,
        }
    }

    pub fn spec(&self) -> TensorSpec {
        TensorSpec::new(self.dtype(), self.dims())
    }

    pub fn elements(&self) -> usize {
        self.dims().iter().product()
    }

    pub fn bytes(&self) -> usize {
        self.elements() * self.dtype().size_bytes()
    }

    /// Borrow as f32 slice; errors on dtype mismatch.
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            other => Err(Error::Shape(format!("expected f32, got {}", other.dtype().name()))),
        }
    }

    pub fn as_f64(&self) -> Result<&[f64]> {
        match self {
            HostTensor::F64 { data, .. } => Ok(data),
            other => Err(Error::Shape(format!("expected f64, got {}", other.dtype().name()))),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            other => Err(Error::Shape(format!("expected i32, got {}", other.dtype().name()))),
        }
    }

    /// Any-float accessor as f64 (for metrics / comparisons).
    pub fn to_f64_vec(&self) -> Result<Vec<f64>> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data.iter().map(|&x| x as f64).collect()),
            HostTensor::F64 { data, .. } => Ok(data.clone()),
            HostTensor::I32 { data, .. } => Ok(data.iter().map(|&x| x as f64).collect()),
        }
    }

    /// Validate this tensor against an artifact input spec.
    pub fn check(&self, spec: &TensorSpec) -> Result<()> {
        if self.dtype() != spec.dtype || self.dims() != spec.dims.as_slice() {
            return Err(Error::Shape(format!(
                "tensor {} does not match spec {}",
                self.spec(),
                spec
            )));
        }
        Ok(())
    }

    /// Convert to an XLA literal (copies).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims_i64: Vec<i64> = self.dims().iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32 { data, .. } => xla::Literal::vec1(data),
            HostTensor::F64 { data, .. } => xla::Literal::vec1(data),
            HostTensor::I32 { data, .. } => xla::Literal::vec1(data),
        };
        Ok(lit.reshape(&dims_i64)?)
    }

    /// Convert from an XLA literal, checking against `spec`.
    pub fn from_literal(lit: &xla::Literal, spec: &TensorSpec) -> Result<Self> {
        let out = match spec.dtype {
            DType::F32 => Self::f32(&spec.dims, lit.to_vec::<f32>()?),
            DType::F64 => Self::f64(&spec.dims, lit.to_vec::<f64>()?),
            DType::I32 => Self::i32(&spec.dims, lit.to_vec::<i32>()?),
        };
        if out.elements() != spec.elements() {
            return Err(Error::Shape(format!(
                "literal has {} elements, spec {} wants {}",
                out.elements(),
                spec,
                spec.elements()
            )));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_matches_spec() {
        let spec = TensorSpec::new(DType::F64, &[3, 2]);
        let t = HostTensor::zeros(&spec);
        assert_eq!(t.dims(), &[3, 2]);
        assert_eq!(t.bytes(), 48);
        t.check(&spec).unwrap();
    }

    #[test]
    fn check_rejects_mismatch() {
        let t = HostTensor::f32(&[4], vec![0.0; 4]);
        assert!(t.check(&TensorSpec::new(DType::F32, &[5])).is_err());
        assert!(t.check(&TensorSpec::new(DType::F64, &[4])).is_err());
        assert!(t.check(&TensorSpec::new(DType::F32, &[4])).is_ok());
    }

    #[test]
    fn accessors_typed() {
        let t = HostTensor::i32(&[2], vec![1, 2]);
        assert_eq!(t.as_i32().unwrap(), &[1, 2]);
        assert!(t.as_f32().is_err());
        assert_eq!(t.to_f64_vec().unwrap(), vec![1.0, 2.0]);
    }

    #[test]
    #[should_panic]
    fn constructor_validates_len() {
        HostTensor::f32(&[3], vec![0.0; 2]);
    }
}
