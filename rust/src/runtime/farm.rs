//! Multi-tenant `SolverFarm`: one spawn-once worker pool serving many
//! concurrent solver sessions.
//!
//! # Why a farm
//!
//! PERKS keeps the time loop resident in a persistent kernel because
//! launch/teardown dominates small iterative workloads (PAPER.md §3 and
//! the Table II concurrency study). [`crate::stencil::pool::StencilPool`]
//! and [`crate::cg::pool::CgPool`] apply that argument *within* one
//! solve: workers spawn once per solve and park between `advance` calls.
//! A serving deployment handling millions of small solves, however, still
//! pays a full pool build/teardown **per session** — exactly the
//! amortization boundary the kernel-batching literature (Ekelund et al.,
//! *Kernel Batching with CUDA Graphs*) pushes launches across. The farm
//! moves the boundary once more: OS threads are spawned once per *farm*,
//! and every admitted session — mixed 2D/3D stencils at any temporal
//! degree `bt`, and CG — runs on the same fixed set of resident workers.
//! Admitting a session and advancing it spawn **zero** threads
//! (counter-asserted by [`SolverFarm::spawn_count`]).
//!
//! # Execution model
//!
//! Sessions enqueue `advance` / `advance_until` commands into the farm's
//! submission queue ([`FarmStencil::submit`] / [`FarmCg::submit`]; the
//! blocking `advance`/`run` wrappers are submit + wait). A command is
//! executed as a sequence of *phases*, each fanned out as one task per
//! shard:
//!
//! * stencil sessions shard by **band** (the same banded `ThreadPlan`
//!   partition the solo pool uses): `load` (first command only) →
//!   per epoch `compute` (advance `bt` sub-steps on the resident slab,
//!   publish residual partials, store the `bt*radius`-deep boundary
//!   union) then `halo` (reload neighbor halos) → `final` (store whole
//!   bands so the client can observe state);
//! * CG sessions shard by **reduction block**: `spmv` (merge-share
//!   consumption) → `fixup` (carry fixup + partial `p·Ap`) → `xr`
//!   (x/r update + partial `r·r`) → `p` (direction update), one
//!   iteration per cycle.
//!
//! Instead of the solo pools' grid barriers, phase boundaries are
//! **countdowns**: the worker that completes a phase's last shard runs
//! the (cheap, scalar) transition under the scheduler lock — folding
//! residual/dot slots in slot-index order, deciding convergence, and
//! enqueueing the next phase. No worker ever blocks inside a session, so
//! a fixed worker set can serve any number of tenants without deadlock,
//! and a straggling session never strands a worker the way a torn barrier
//! would.
//!
//! # Scheduling and fairness
//!
//! The ready queue holds sessions with claimable shards. A worker claims
//! one shard from the front session; if the session still has unclaimed
//! shards it is rotated to the back (round-robin — concurrent small
//! solves interleave across the workers instead of serializing), unless
//! its current phase has waited more than [`FAIRNESS_BOUND`] scheduler
//! claims, in which case it keeps the head until fully dispatched (the
//! age bound: no ready session can be starved by a stream of newer
//! arrivals). Queue latency — command enqueue to first shard dispatch —
//! is sampled per command and surfaced through [`FarmMetrics`]
//! (p50/p99/max and the max/mean *fairness ratio*).
//!
//! # Residency and determinism
//!
//! Per-session state stays resident in the farm between that session's
//! epochs and commands: stencil slab pairs (and the shared grid), CG
//! vectors, plans, and linearized stencil offsets all live in the
//! admitted tenant, so a resumed `advance` pays no reload. Numerics are
//! **bit-identical to the solo pools** (and therefore to `gold::run` and
//! the serial CG path) at every worker count: cell updates use the same
//! `temporal::advance_slab` trapezoid core, CG uses the same per-share
//! consumption / share-order carry fixup / block-partial arithmetic, and
//! every reduction folds fixed slots in slot-index order — the farm's
//! worker count, scheduling order, and tenant mix are all invisible to
//! the bits.
//!
//! # Safety protocol
//!
//! Tenant numeric state lives in `UnsafeCell`-based shared buffers
//! (`SharedGrid`, `SharedBuf`, per-band slab cells). Exclusive access is
//! phased: a shard is claimed by exactly one worker per phase instance
//! (the claim/complete handshake through the scheduler mutex establishes
//! happens-before between successive owners), concurrent shards write
//! disjoint ranges (band-owned planes, block-owned rows — the same
//! ownership discipline as the solo pools), and the client touches a
//! tenant's buffers only while it has no command in flight (the
//! submit/wait handshake). Reduction slots are atomics written with
//! release stores before the countdown and folded after it.
//!
//! # The submission plane
//!
//! Every command enters through the async submission plane
//! ([`crate::runtime::plane`]): `submit` claims plane slots from the
//! farm's [`PlaneConfig`] admission budget (block/shed/timeout
//! backpressure), completion is exposed as a future whose waker the
//! finishing worker fires (the blocking `wait` wrappers are `block_on`
//! over the same futures), and a batched [`CommandGraph`] chains an
//! entire `advance_until` schedule under a single enqueue-lock
//! acquisition — segment boundaries are dequeued *inside* the completion
//! transition, where the scheduler lock is already held. The plane never
//! changes what a shard computes, so the bit-identity bar below is
//! untouched; it only changes when work is enqueued and who waits.
//!
//! # Resilience
//!
//! The farm carries the recovery machinery of
//! [`crate::runtime::resilience`]: tenants configured with a checkpoint
//! cadence snapshot their resident state inside the completion
//! transition (under the already-held scheduler lock — no extra phase or
//! barrier), an installed [`FaultPlan`] injects panics / NaN poisoning /
//! stalls at exact (tenant, epoch, phase, shard) coordinates when the
//! scheduler claims them (one `Option` check when disabled), and a
//! [`RetryPolicy`] turns a retryable failure into checkpoint-restore +
//! replay instead of a command error — bit-identical to an uninjected
//! run, because every reduction folds fixed slots in slot order.
//! Failures that do surface are structured: a panicked shard is
//! [`Error::Fault`] with its exact coordinates, a non-finite
//! residual / `p·Ap` / `r·r` fold is an `Error::Solver` naming the
//! epoch (instead of silently iterating on NaN to `max_steps`), and a
//! blocking wait armed with a watchdog deadline surfaces
//! [`Error::Stuck`]. Recoveries, replayed epochs, and checkpoint bytes
//! are counted per command ([`StencilFarmRun`]/[`CgFarmRun`]), per farm
//! ([`FarmMetrics`]), and process-wide (`util::counters`).
//!
//! Tenants configured with a durable snapshot directory
//! (`ResilienceConfig::durable`) additionally persist every checkpoint
//! through a [`SnapshotStore`]: the transition only parks the fresh
//! checkpoint in a pending slot under the lock; the worker that drained
//! the phase claims it after the scheduler guard drops and runs the
//! crash-consistent write-out (tmp + fsync + atomic rename) entirely
//! outside the lock, so disk latency never serializes claims. A killed
//! process ([`FaultKind::Kill`], a SIGKILL stand-in) resumes from the
//! last durable frame via [`SnapshotStore::restore`] +
//! [`FarmStencil::restore_from`] (CG resumes through its
//! command-boundary state), bit-identical to an uninterrupted run — see
//! `docs/RECOVERY.md`. A failed write-out surfaces as
//! [`Error::Snapshot`] on the tenant's next submit, never as a torn
//! frame: restore verifies checksums and falls back a generation.
//!
//! # Teardown
//!
//! Shutdown is a dedicated flag checked on every condvar wake — never a
//! value raced through the command slot — so `drop` joins promptly even
//! against workers parked mid-stream or tasks still in flight, and a
//! client blocked in `wait` on a farm that shuts down gets an error, not
//! a hang (async waiters: shutdown fires every registered completion
//! waker). Rapid create/drop cycles are exercised by the tests.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::task::{Poll, Waker};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::cg::pipeline::{self, PipeState};
use crate::cg::pool::SharedBuf;
use crate::cg::precond::{Precond, Preconditioner};
use crate::error::{Error, Result};
use crate::runtime::plane::admission::{AdmissionPolicy, PlaneConfig};
use crate::runtime::plane::future::{CgCompletion, StencilCompletion};
use crate::runtime::plane::graph::CommandGraph;
use crate::runtime::plane::reactor::block_on;
use crate::runtime::resilience::snapshot::{SnapshotStore, WorkloadMeta};
use crate::runtime::resilience::{
    Checkpoint, CheckpointPayload, FaultKind, FaultPlan, ResilienceConfig, RetryPolicy,
};
use crate::sparse::csr::Csr;
use crate::spmv::merge::{self, MergePlan};
use crate::stencil::grid::Domain;
use crate::stencil::parallel::{
    bands_for, boundary_union_planes, plans, slab_delta_partials, SharedGrid, ThreadPlan,
};
use crate::stencil::shape::StencilSpec;
use crate::stencil::temporal;
use crate::util::counters;
use crate::util::stats::percentile;

/// Age bound of the round-robin scheduler, in claim ticks: a ready
/// session whose current phase has waited longer than this keeps the
/// queue head until fully dispatched instead of rotating to the back.
pub const FAIRNESS_BOUND: u64 = 256;

/// Size of the rolling queue-latency sample window. Once full, new
/// samples overwrite the oldest (so percentiles track *recent* traffic
/// on long-lived farms instead of freezing on warm-up history); the
/// all-time maximum is tracked separately and never ages out.
const QUEUE_SAMPLE_CAP: usize = 1 << 16;

// ---------------------------------------------------------------------
// Engines: the numeric state of one admitted tenant
// ---------------------------------------------------------------------

/// Stencil phase: one-time slab load (first command of a tenant).
pub const P_LOAD: u8 = 0;
/// Stencil phase: advance `bt` sub-steps + store the boundary union.
pub const P_COMPUTE: u8 = 1;
/// Stencil phase: reload neighbor halos.
pub const P_HALO: u8 = 2;
/// Stencil phase: store whole bands so the client can observe state.
pub const P_FINAL: u8 = 3;
/// CG phase: merge-share SpMV consumption.
pub const P_SPMV: u8 = 0;
/// CG phase: carry fixup + partial `p·Ap`.
pub const P_FIXUP: u8 = 1;
/// CG phase: x/r update + partial `r·r`.
pub const P_XR: u8 = 2;
/// CG phase: direction update.
pub const P_PUP: u8 = 3;
/// Pipelined-CG phase: the whole iteration — one fused pass per shard
/// (row SpMV + all eight vector recurrences + preconditioner solve +
/// the three dot partials), so a pipelined tenant schedules **one**
/// phase per iteration where the classic CG tenant schedules four.
pub const P_PIPE: u8 = 4;

/// Resident slab pair of one stencil band (the worker-local state of the
/// solo pool, hoisted into the tenant so any worker can run the band).
struct Slab {
    cur: Vec<f64>,
    nxt: Vec<f64>,
}

/// One band's slab, claimed by exactly one worker per phase instance.
struct SlabCell(std::cell::UnsafeCell<Slab>);

// SAFETY: access is serialized by the claim/complete handshake through
// the scheduler mutex — one owner per phase instance, handoff ordered.
unsafe impl Sync for SlabCell {}
unsafe impl Send for SlabCell {}

/// What one shard task produced (accumulated into the tenant under the
/// scheduler lock at completion).
#[derive(Clone, Copy, Default)]
struct ShardOut {
    moved: u64,
    computed: u64,
}

struct StencilEngine {
    spec: StencilSpec,
    /// Geometry template; `data` empty — the numbers live in `grid`.
    meta: Domain,
    axis: usize,
    plane: usize,
    first: usize,
    interior_planes: usize,
    bt: usize,
    plans: Vec<ThreadPlan>,
    weights: Vec<f64>,
    deltas: Vec<isize>,
    grid: SharedGrid,
    slabs: Vec<SlabCell>,
    /// Residual-reduction slots: one per interior plane of the banded
    /// axis, folded in slot order — the same thread-count-invariant norm
    /// as the solo pool's barrier slots.
    slots: Vec<AtomicU64>,
}

impl StencilEngine {
    fn new(spec: &StencilSpec, x0: &Domain, shards: usize, bt: usize) -> Result<Self> {
        if shards == 0 {
            return Err(Error::invalid("farm stencil shards must be > 0"));
        }
        if bt == 0 {
            return Err(Error::invalid("temporal blocking degree bt must be >= 1"));
        }
        let geometry = bands_for(x0, spec, shards)?;
        let r = spec.radius;
        let plane = geometry.plane;
        let total_planes = x0.data.len() / plane;
        let plans = plans(&geometry, bt * r, total_planes, plane);
        let interior_planes = if geometry.axis == 0 { x0.interior[0] } else { x0.interior[1] };
        let mut meta = x0.clone();
        meta.data = Vec::new();
        let slabs = plans
            .iter()
            .map(|p| {
                SlabCell(std::cell::UnsafeCell::new(Slab {
                    cur: vec![0.0; p.slab.len()],
                    nxt: vec![0.0; p.slab.len()],
                }))
            })
            .collect();
        let deltas = crate::stencil::gold::linear_deltas(spec, meta.padded[1], meta.padded[2]);
        Ok(Self {
            spec: spec.clone(),
            meta,
            axis: geometry.axis,
            plane,
            first: geometry.first,
            interior_planes,
            bt,
            weights: spec.weights(),
            deltas,
            grid: SharedGrid::new(x0.data.clone()),
            slabs,
            slots: (0..interior_planes).map(|_| AtomicU64::new(0)).collect(),
            plans,
        })
    }

    /// SAFETY: shard `i` claimed by exactly one worker this phase.
    unsafe fn load_shard(&self, i: usize) -> ShardOut {
        let plan = &self.plans[i];
        let slab = &mut *self.slabs[i].0.get();
        self.grid.read(plan.slab.clone(), &mut slab.cur);
        // the ping-pong partner starts as an identical copy so its
        // never-computed Dirichlet cells stay valid forever
        slab.nxt.copy_from_slice(&slab.cur);
        ShardOut { moved: (plan.slab.len() * 8) as u64, computed: 0 }
    }

    /// Advance `sub` sub-steps on the resident slab, publish residual
    /// partials when tracking, and store the boundary union — the solo
    /// pool's per-epoch producer half, verbatim arithmetic.
    ///
    /// SAFETY: shard `i` claimed by one worker; band-owned grid planes are
    /// written by their owner only; no shard reads the grid this phase.
    unsafe fn compute_shard(&self, i: usize, sub: usize, track: bool) -> ShardOut {
        let plan = &self.plans[i];
        let slab = &mut *self.slabs[i].0.get();
        let r = self.spec.radius;
        let plane = self.plane;
        let slab_first = plan.slab.start / plane;
        let band_planes = plan.band.len();
        let depth = self.bt * r;
        let computed = temporal::advance_slab(
            &self.spec,
            &self.meta,
            self.axis,
            &mut slab.cur,
            &mut slab.nxt,
            slab_first,
            &plan.band,
            sub,
            self.first,
            self.interior_planes,
            &self.weights,
            &self.deltas,
        );
        if track {
            slab_delta_partials(
                &self.spec,
                &self.meta,
                &slab.cur,
                &slab.nxt,
                slab_first,
                &plan.band,
                self.axis,
                self.first,
                |slot, partial| self.slots[slot].store(partial.to_bits(), Ordering::Release),
            );
        }
        let band_off = (plan.band.start - slab_first) * plane;
        let lo_planes = depth.min(band_planes);
        self.grid.write(
            plan.band.start * plane,
            &slab.cur[band_off..band_off + lo_planes * plane],
        );
        // thin bands overlap lo/hi: store (and count — Eq 5) the union once
        let hi_first = (plan.band.end - lo_planes).max(plan.band.start + lo_planes);
        if hi_first < plan.band.end {
            let hi_off = (hi_first - slab_first) * plane;
            let hi_len = (plan.band.end - hi_first) * plane;
            self.grid.write(hi_first * plane, &slab.cur[hi_off..hi_off + hi_len]);
        }
        ShardOut {
            moved: (boundary_union_planes(depth, band_planes) * plane * 8) as u64,
            computed,
        }
    }

    /// Reload neighbor halos (the consumer half). SAFETY: the grid is
    /// read-only this phase (all boundary stores completed last phase).
    unsafe fn halo_shard(&self, i: usize) -> ShardOut {
        let plan = &self.plans[i];
        let slab = &mut *self.slabs[i].0.get();
        let plane = self.plane;
        let slab_first = plan.slab.start / plane;
        let mut moved = 0u64;
        let halo_lo = slab_first..plan.band.start;
        if !halo_lo.is_empty() {
            let off = halo_lo.start * plane;
            let len = halo_lo.len() * plane;
            self.grid.read(off..off + len, &mut slab.cur[..len]);
            moved += (len * 8) as u64;
        }
        let halo_hi = plan.band.end..plan.slab.end / plane;
        if !halo_hi.is_empty() {
            let off = halo_hi.start * plane;
            let len = halo_hi.len() * plane;
            let loff = (halo_hi.start - slab_first) * plane;
            self.grid.read(off..off + len, &mut slab.cur[loff..loff + len]);
            moved += (len * 8) as u64;
        }
        ShardOut { moved, computed: 0 }
    }

    /// Store the whole band so the client can observe the advanced state
    /// between commands. SAFETY: band-owned planes, owner-only writes.
    unsafe fn final_shard(&self, i: usize) -> ShardOut {
        let plan = &self.plans[i];
        let slab = &*self.slabs[i].0.get();
        let plane = self.plane;
        let slab_first = plan.slab.start / plane;
        let band_off = (plan.band.start - slab_first) * plane;
        let band_len = plan.band.len() * plane;
        self.grid
            .write(plan.band.start * plane, &slab.cur[band_off..band_off + band_len]);
        ShardOut { moved: (band_len * 8) as u64, computed: 0 }
    }
}

struct CgEngine {
    a: Arc<Csr>,
    plan: MergePlan,
    /// Reduction blocks == vector-update ownership == shard units.
    blocks: Vec<(usize, usize)>,
    x: SharedBuf<f64>,
    r: SharedBuf<f64>,
    p: SharedBuf<f64>,
    ap: SharedBuf<f64>,
    carries: SharedBuf<(usize, f64)>,
    /// Dot-product slots, one per block, folded in slot order.
    slots: Vec<AtomicU64>,
}

impl CgEngine {
    fn new(a: Arc<Csr>, plan: MergePlan) -> Result<Self> {
        if a.n_rows != a.n_cols {
            return Err(Error::Solver(format!(
                "matrix not square: {}x{}",
                a.n_rows, a.n_cols
            )));
        }
        if a.n_rows == 0 {
            return Err(Error::Solver("matrix has no rows (empty system)".into()));
        }
        if a.n_rows != plan.n_rows || a.nnz() != plan.nnz {
            return Err(Error::Solver(format!(
                "merge plan mismatch: plan for {} rows / {} nnz, matrix has {} rows / {} nnz",
                plan.n_rows,
                plan.nnz,
                a.n_rows,
                a.nnz()
            )));
        }
        let n = a.n_rows;
        let parts = plan.parts();
        let blocks = crate::stencil::parallel::partition(n, parts);
        Ok(Self {
            carries: SharedBuf::new(vec![(0usize, 0.0f64); parts]),
            slots: (0..blocks.len()).map(|_| AtomicU64::new(0)).collect(),
            x: SharedBuf::new(vec![0.0; n]),
            r: SharedBuf::new(vec![0.0; n]),
            p: SharedBuf::new(vec![0.0; n]),
            ap: SharedBuf::new(vec![0.0; n]),
            blocks,
            a,
            plan,
        })
    }

    /// Merge-share range of shard `k` (the solo pool's per-worker split
    /// with the shard count fixed at the block count, so the grouping —
    /// and the bits — never depend on the farm's worker count).
    fn share_range(&self, k: usize) -> (usize, usize) {
        let parts = self.plan.parts();
        let nk = self.blocks.len();
        (parts * k / nk, parts * (k + 1) / nk)
    }

    /// SAFETY: p read-shared; ap rows and carry slots written only by
    /// their share owner (disjoint across shards).
    unsafe fn spmv_shard(&self, k: usize) -> ShardOut {
        let (s_lo, s_hi) = self.share_range(k);
        let p_v = self.p.whole();
        let ap = self.ap.ptr();
        let carries = self.carries.ptr();
        for i in s_lo..s_hi {
            let c = merge::consume_share_raw(
                &self.a,
                p_v,
                ap,
                self.plan.shares[i],
                self.plan.shares[i + 1],
            );
            carries.add(i).write(c);
        }
        ShardOut::default()
    }

    /// SAFETY: carries read-shared; each shard touches only ap indices in
    /// its own block.
    unsafe fn fixup_shard(&self, k: usize) -> ShardOut {
        let (s, l) = self.blocks[k];
        let (row_lo, row_hi) = (s, s + l);
        let p_v = self.p.whole();
        let ap = self.ap.ptr();
        for &(row, carry) in self.carries.whole() {
            // serial fixup order and skip condition, restricted to our rows
            if row >= row_lo && row < row_hi && carry != 0.0 {
                ap.add(row).write(ap.add(row).read() + carry);
            }
        }
        let part = crate::cg::block_partial(s, l, |i| p_v[i] * ap.add(i).read());
        self.slots[k].store(part.to_bits(), Ordering::Release);
        ShardOut::default()
    }

    /// SAFETY: x/r writes inside our block; p/ap have no writer this phase.
    unsafe fn xr_shard(&self, k: usize, alpha: f64) -> ShardOut {
        let (s, l) = self.blocks[k];
        let x = self.x.ptr();
        let r = self.r.ptr();
        let p_v = self.p.whole();
        let ap = self.ap.whole();
        let part = crate::cg::block_partial(s, l, |i| {
            x.add(i).write(x.add(i).read() + alpha * p_v[i]);
            let ri = r.add(i).read() - alpha * ap[i];
            r.add(i).write(ri);
            ri * ri
        });
        self.slots[k].store(part.to_bits(), Ordering::Release);
        ShardOut::default()
    }

    /// SAFETY: p writes inside our block; r has no writer this phase.
    unsafe fn pup_shard(&self, k: usize, beta: f64) -> ShardOut {
        let (s, l) = self.blocks[k];
        let p_v = self.p.ptr();
        let r = self.r.whole();
        for i in s..s + l {
            p_v.add(i).write(r[i] + beta * p_v.add(i).read());
        }
        ShardOut::default()
    }
}

/// Resident state of a pipelined-CG tenant ([`crate::cg::pipeline`]):
/// the nine recurrence vectors, the parity-buffered `m`, and one
/// `(γ | δ | rr)` slot triple per reduction block. Unlike the solo
/// [`crate::cg::pipeline::PipePool`], the slots need **no** parity
/// halves here: the completion transition folds them under the
/// scheduler lock before the next `P_PIPE` phase can be claimed, so a
/// fold never races the next iteration's stores.
struct CgPipeEngine {
    a: Arc<Csr>,
    pc: Arc<Precond>,
    /// Reduction blocks == vector-update ownership == shard units.
    blocks: Vec<(usize, usize)>,
    x: SharedBuf<f64>,
    r: SharedBuf<f64>,
    u: SharedBuf<f64>,
    w: SharedBuf<f64>,
    p: SharedBuf<f64>,
    s: SharedBuf<f64>,
    q: SharedBuf<f64>,
    z: SharedBuf<f64>,
    /// Parity-buffered `m = M⁻¹ w`: the iteration at parity π reads
    /// `m[π]` (stable all phase — the SpMV reads arbitrary columns) and
    /// writes `m[1-π]` block-locally. The transition flips the parity.
    m: [SharedBuf<f64>; 2],
    /// Width `3 * blocks.len()`: γ partials, then δ, then rr.
    slots: Vec<AtomicU64>,
}

impl CgPipeEngine {
    fn new(a: Arc<Csr>, parts: usize, precond: Preconditioner) -> Result<Self> {
        if a.n_rows != a.n_cols {
            return Err(Error::Solver(format!(
                "matrix not square: {}x{}",
                a.n_rows, a.n_cols
            )));
        }
        if a.n_rows == 0 {
            return Err(Error::Solver("matrix has no rows (empty system)".into()));
        }
        let n = a.n_rows;
        let blocks = crate::stencil::parallel::partition(n, parts);
        let pc = Arc::new(Precond::build(precond, &a, &blocks)?);
        Ok(Self {
            slots: (0..3 * blocks.len()).map(|_| AtomicU64::new(0)).collect(),
            x: SharedBuf::new(vec![0.0; n]),
            r: SharedBuf::new(vec![0.0; n]),
            u: SharedBuf::new(vec![0.0; n]),
            w: SharedBuf::new(vec![0.0; n]),
            p: SharedBuf::new(vec![0.0; n]),
            s: SharedBuf::new(vec![0.0; n]),
            q: SharedBuf::new(vec![0.0; n]),
            z: SharedBuf::new(vec![0.0; n]),
            m: [SharedBuf::new(vec![0.0; n]), SharedBuf::new(vec![0.0; n])],
            blocks,
            a,
            pc,
        })
    }

    /// One whole pipelined iteration for block `k` — the same
    /// single-sourced [`pipeline::fused_block_pass`] the serial stepper
    /// and the solo pool run, with the three partials published to this
    /// block's slot triple.
    ///
    /// SAFETY: block-owned rows of every vector are written by their
    /// owner only; `m[parity]` has no writer this phase (all writes
    /// target `m[1-parity]`); slot stores are Release before the
    /// countdown, folded after it.
    unsafe fn pipe_shard(&self, k: usize, alpha: f64, beta: f64, parity: usize) -> ShardOut {
        let (s, l) = self.blocks[k];
        let m_cur = self.m[parity].whole();
        let m_next = self.m[1 - parity].ptr();
        let (pg, pd, pt) = pipeline::fused_block_pass(
            &self.a,
            &self.pc,
            s,
            l,
            alpha,
            beta,
            m_cur,
            self.x.ptr(),
            self.r.ptr(),
            self.u.ptr(),
            self.w.ptr(),
            self.p.ptr(),
            self.s.ptr(),
            self.q.ptr(),
            self.z.ptr(),
            m_next,
        );
        let nb = self.blocks.len();
        self.slots[k].store(pg.to_bits(), Ordering::Release);
        self.slots[nb + k].store(pd.to_bits(), Ordering::Release);
        self.slots[2 * nb + k].store(pt.to_bits(), Ordering::Release);
        ShardOut::default()
    }
}

enum EngineKind {
    Stencil(StencilEngine),
    Cg(CgEngine),
    CgPipe(CgPipeEngine),
}

impl EngineKind {
    /// Shard count — uniform across phases of a kind.
    fn shards(&self) -> usize {
        match self {
            EngineKind::Stencil(e) => e.plans.len(),
            EngineKind::Cg(e) => e.blocks.len(),
            EngineKind::CgPipe(e) => e.blocks.len(),
        }
    }

    /// Execute one shard of one phase. SAFETY: the claim/complete
    /// handshake guarantees single ownership per shard per phase and
    /// orders cross-phase handoffs (see module docs). `sub` is the
    /// sub-step count for stencil compute phases and the `m` parity for
    /// pipelined-CG phases; `scalar`/`scalar2` carry the phase's
    /// iteration coefficients (α, and for pipelined CG also β).
    unsafe fn run_shard(
        &self,
        phase: u8,
        shard: usize,
        sub: usize,
        track: bool,
        scalar: f64,
        scalar2: f64,
    ) -> ShardOut {
        match self {
            EngineKind::Stencil(e) => match phase {
                P_LOAD => e.load_shard(shard),
                P_COMPUTE => e.compute_shard(shard, sub, track),
                P_HALO => e.halo_shard(shard),
                P_FINAL => e.final_shard(shard),
                _ => unreachable!("bad stencil phase {phase}"),
            },
            EngineKind::Cg(e) => match phase {
                P_SPMV => e.spmv_shard(shard),
                P_FIXUP => e.fixup_shard(shard),
                P_XR => e.xr_shard(shard, scalar),
                P_PUP => e.pup_shard(shard, scalar),
                _ => unreachable!("bad cg phase {phase}"),
            },
            EngineKind::CgPipe(e) => match phase {
                P_PIPE => e.pipe_shard(shard, scalar, scalar2, sub),
                _ => unreachable!("bad pipelined cg phase {phase}"),
            },
        }
    }

    /// Inject NaN contamination into the shard's resident output (the
    /// `FaultKind::Nan` payload): the poisoned value propagates into the
    /// next residual / `p·Ap` fold, which the non-finite guards catch.
    /// SAFETY: same single-owner claim as `run_shard` — called by the
    /// worker that owns the shard this phase, after the shard ran.
    unsafe fn poison_shard(&self, shard: usize) {
        match self {
            EngineKind::Stencil(e) => {
                let plan = &e.plans[shard];
                let slab = &mut *e.slabs[shard].0.get();
                // poison an interior cell of the owned band (and its
                // ping-pong partner, so any sub-step grouping carries it)
                let mid = ((plan.band.start + plan.band.end) / 2 - plan.slab.start / e.plane)
                    * e.plane
                    + e.plane / 2;
                if let Some(v) = slab.cur.get_mut(mid) {
                    *v = f64::NAN;
                }
                if let Some(v) = slab.nxt.get_mut(mid) {
                    *v = f64::NAN;
                }
            }
            EngineKind::Cg(e) => {
                // poison one residual row of the owned block: r is
                // read-modify-written every iteration (never rebuilt from
                // scratch like ap), so the NaN reaches the next r·r or
                // p·Ap fold from *any* phase the fault fires in. During
                // P_XR the row belongs to this shard's block; in every
                // other phase r has no writer at all.
                let (s, _) = e.blocks[shard];
                e.r.ptr().add(s).write(f64::NAN);
            }
            EngineKind::CgPipe(e) => {
                // same residual poisoning: r is carried by recurrence,
                // so the NaN reaches the very next γ'/rr' fold
                let (s, _) = e.blocks[shard];
                e.r.ptr().add(s).write(f64::NAN);
            }
        }
    }
}

/// Classified failure of an in-flight command (tenant-side error state),
/// structured so the retry policy and the harvest path can classify
/// without string matching.
#[derive(Clone, Debug)]
enum Failure {
    /// A worker panicked running a shard (real or injected).
    Panic { phase: u8, shard: usize, epoch: u64 },
    /// A reduction fold produced NaN/Inf (state corruption — injected
    /// poisoning, or a genuinely diverged run; the latter fails
    /// identically on every replay and so exhausts retries quickly).
    NonFinite { what: &'static str, value: f64, epoch: u64 },
    /// Deterministic solver error (not positive definite, ...): a
    /// replay would fail identically, so never retried.
    Solver(String),
}

impl Failure {
    fn retryable(&self) -> bool {
        !matches!(self, Failure::Solver(_))
    }

    fn message(&self) -> String {
        match self {
            Failure::Panic { phase, shard, epoch } => {
                format!("farm worker panicked (phase {phase}, shard {shard}, epoch {epoch})")
            }
            Failure::NonFinite { what, value, epoch } => {
                format!("non-finite {what} ({value}) at epoch {epoch}")
            }
            Failure::Solver(msg) => msg.clone(),
        }
    }

    fn into_error(self) -> Error {
        match self {
            Failure::Panic { phase, shard, epoch } => {
                Error::Fault { phase: phase as usize, shard, epoch }
            }
            f @ Failure::NonFinite { .. } => Error::Solver(f.message()),
            Failure::Solver(msg) => Error::Solver(msg),
        }
    }
}

/// Fold reduction slots in slot-index order (left-to-right from 0.0) —
/// the same arithmetic as `GridBarrier::read_sum`, so farm reductions
/// are bit-identical to the solo pools'.
fn fold_slots(slots: &[AtomicU64]) -> f64 {
    let mut acc = 0.0;
    for s in slots {
        acc += f64::from_bits(s.load(Ordering::Acquire));
    }
    acc
}

// ---------------------------------------------------------------------
// Scheduler state
// ---------------------------------------------------------------------

/// One admitted session's scheduling + command bookkeeping (numeric state
/// lives in the engine; everything here is touched only under the
/// scheduler mutex).
struct Tenant {
    engine: Arc<EngineKind>,
    // --- current phase ---
    phase: u8,
    next_shard: usize,
    nshards: usize,
    outstanding: usize,
    enqueue_tick: u64,
    // --- command lifecycle ---
    active: bool,
    done_flag: bool,
    /// Released by the client while a command was in flight: free the
    /// slot at command completion instead of reporting.
    zombie: bool,
    first_dispatch: bool,
    enqueued_at: f64,
    queue_wait_cmd: f64,
    failure: Option<Failure>,
    moved: u64,
    computed: u64,
    // --- resilience ---
    /// Per-tenant checkpoint/retry/watchdog knobs (set between commands).
    res_cfg: ResilienceConfig,
    /// Lifetime completed-epoch counter (stencil exchange epochs + CG
    /// iterations) — the coordinate fault plans and checkpoints use.
    epoch: u64,
    /// Last resident-state snapshot (command-entry or cadence). Shared
    /// with the durable write-out path, which persists the same bytes
    /// outside the lock — hence the `Arc`, never a second copy.
    checkpoint: Option<Arc<Checkpoint>>,
    /// Durable write-out plumbing (`ResilienceConfig::durable`); `None`
    /// — the common case — costs one branch per checkpoint.
    durable: Option<Arc<DurableSink>>,
    /// Newest checkpoint awaiting durable write-out. Overwritten, never
    /// queued: only the latest epoch matters on disk, so a slow disk
    /// coalesces frames instead of building a backlog.
    durable_pending: Option<Arc<Checkpoint>>,
    /// A worker is persisting this tenant's frame outside the lock
    /// (claim guard: at most one write-out per tenant in flight).
    durable_writing: bool,
    /// A durable write-out failed; surfaced as [`Error::Snapshot`] on
    /// the next submit (the failing command itself already completed).
    /// Cleared by `configure_resilience`.
    durable_error: Option<String>,
    /// Recovery attempts consumed by the current command.
    attempts: u32,
    /// Backoff gate: the scheduler defers claims until this farm-clock
    /// time (0.0 = claimable now; set on restore when backoff > 0).
    resume_at: f64,
    /// Per-command recovery accounting, harvested into the run structs.
    recoveries_cmd: u64,
    replayed_cmd: u64,
    ckpt_bytes_cmd: u64,
    // --- submission plane ---
    /// Completion hook of a pending async waiter; fired by the worker
    /// that completes the command (and by shutdown).
    waker: Option<Waker>,
    /// Plane slots charged to this tenant by admission control (one per
    /// queued graph segment); released at harvest, future drop, or
    /// tenant release.
    slots_held: usize,
    /// Remaining command-graph segments; the next one is dequeued inside
    /// the completion transition, under the already-held scheduler lock.
    graph_segs: VecDeque<usize>,
    /// Full segment schedule, kept only while resubmissions remain.
    graph_schedule: Vec<usize>,
    /// Whole-schedule re-enqueues left (graph resubmission policy).
    graph_resubmits: u32,
    // --- stencil command ---
    steps_target: usize,
    tol: Option<f64>,
    done_steps: usize,
    sub: usize,
    residual: Option<f64>,
    /// Slabs loaded (persists across commands: residency).
    loaded: bool,
    // --- cg command ---
    iters_target: usize,
    threshold: f64,
    iters_done: usize,
    rr: f64,
    rr_next: f64,
    alpha: f64,
    beta: f64,
    // --- pipelined cg command (scalar recurrence state; `sub` carries
    // the m parity, `rr`/`alpha`/`beta` are shared with classic CG) ---
    pg_gamma: f64,
    pg_delta: f64,
    pg_gamma_prev: f64,
    pg_alpha_prev: f64,
}

impl Tenant {
    fn new(engine: Arc<EngineKind>) -> Self {
        Self {
            engine,
            phase: 0,
            next_shard: 0,
            nshards: 0,
            outstanding: 0,
            enqueue_tick: 0,
            active: false,
            done_flag: false,
            zombie: false,
            first_dispatch: false,
            enqueued_at: 0.0,
            queue_wait_cmd: 0.0,
            failure: None,
            moved: 0,
            computed: 0,
            res_cfg: ResilienceConfig::disabled(),
            epoch: 0,
            checkpoint: None,
            durable: None,
            durable_pending: None,
            durable_writing: false,
            durable_error: None,
            attempts: 0,
            resume_at: 0.0,
            recoveries_cmd: 0,
            replayed_cmd: 0,
            ckpt_bytes_cmd: 0,
            waker: None,
            slots_held: 0,
            graph_segs: VecDeque::new(),
            graph_schedule: Vec::new(),
            graph_resubmits: 0,
            steps_target: 0,
            tol: None,
            done_steps: 0,
            sub: 0,
            residual: None,
            loaded: false,
            iters_target: 0,
            threshold: 0.0,
            iters_done: 0,
            rr: 0.0,
            rr_next: 0.0,
            alpha: 0.0,
            beta: 0.0,
            pg_gamma: 0.0,
            pg_delta: 0.0,
            pg_gamma_prev: 0.0,
            pg_alpha_prev: 0.0,
        }
    }
}

/// Where one tenant's checkpoints go when durability is configured:
/// the opened store, the tenant's directory name, and the workload
/// descriptor stamped into every frame (so a recovering process can
/// rebuild the right engine before restoring bytes into it). Built by
/// `set_resilience` (store opened *before* the scheduler lock — directory
/// creation is filesystem I/O); shared by `Arc` so the off-lock writer
/// never clones the path buffers.
struct DurableSink {
    store: SnapshotStore,
    name: String,
    meta: WorkloadMeta,
}

/// Workload descriptor for a tenant's durable frames (see
/// [`WorkloadMeta`]): enough to re-admit an equivalent tenant in a fresh
/// process and have `restore` reject frames from a different workload.
fn workload_meta(engine: &EngineKind) -> WorkloadMeta {
    match engine {
        EngineKind::Stencil(e) => WorkloadMeta::Stencil {
            bench: e.spec.name.to_string(),
            dims: if e.spec.dims == 2 {
                vec![e.meta.interior[1], e.meta.interior[2]]
            } else {
                e.meta.interior.to_vec()
            },
            bt: e.bt,
            shards: e.plans.len(),
        },
        EngineKind::Cg(e) => WorkloadMeta::Cg { n: e.a.n_rows, shards: e.blocks.len() },
        // unreachable in practice: pipelined tenants reject resilience
        // configuration, so no durable sink is ever built for one
        EngineKind::CgPipe(e) => WorkloadMeta::Cg { n: e.a.n_rows, shards: e.blocks.len() },
    }
}

struct FarmState {
    shutdown: bool,
    /// Sessions with claimable shards (ids into `tenants`).
    ready: VecDeque<usize>,
    tenants: Vec<Option<Tenant>>,
    free: Vec<usize>,
    /// Scheduler claim counter (fairness clock).
    tick: u64,
    /// Rolling window of queue-latency samples (command enqueue -> first
    /// dispatch); see [`QUEUE_SAMPLE_CAP`].
    queue_waits: Vec<f64>,
    /// Overwrite cursor once the window is full.
    queue_next: usize,
    /// All-time maximum queue wait (survives window wraparound).
    queue_max: f64,
    /// Plane slots currently held across all tenants (admission queue
    /// occupancy; bounded by `PlaneConfig::queue_cap`).
    plane_inflight: usize,
    /// All-time peak of `plane_inflight` — the sustained-concurrency
    /// figure the stress bench asserts.
    plane_peak: usize,
    /// Installed fault-injection schedule, consulted (and mutated: specs
    /// fire once) at claim time under this very lock. `None` — the
    /// overwhelmingly common case — costs one branch per claim.
    faults: Option<FaultPlan>,
}

// lock-order: ready < ctl
// The executor wake-queue lock (`ready`, in plane::reactor) must never
// be acquired while holding the scheduler lock (`ctl`): completions
// defer their wakers and fire them only after the scheduler guard
// drops. Declared here so perks-lint flags any future `.ready.lock()`
// under `ctl` in this file.
struct FarmShared {
    ctl: Mutex<FarmState>,
    work_cv: Condvar,
    done_cv: Condvar,
    /// Admission gate: slot releases signal here so blocked/timed-out
    /// submitters re-check the plane budget.
    gate_cv: Condvar,
    clock: Instant,
    /// Resident worker count (constant after spawn).
    workers: usize,
    /// Submission-plane budget and backpressure policy (constant after
    /// spawn).
    plane: PlaneConfig,
    admissions: AtomicU64,
    commands: AtomicU64,
    tasks: AtomicU64,
    epochs: AtomicU64,
    plane_batches: AtomicU64,
    sched_locks: AtomicU64,
    plane_sheds: AtomicU64,
    plane_timeouts: AtomicU64,
    faults_injected: AtomicU64,
    recoveries: AtomicU64,
    replayed_epochs: AtomicU64,
    checkpoint_bytes: AtomicU64,
    durable_frames: AtomicU64,
    durable_bytes: AtomicU64,
}

impl FarmShared {
    /// Lock the scheduler state, recovering from poisoning (a panic in a
    /// transition) — plain data, no invariant a panic can break.
    fn lock(&self) -> MutexGuard<'_, FarmState> {
        self.ctl.lock().unwrap_or_else(|p| p.into_inner())
    }

    fn now(&self) -> f64 {
        self.clock.elapsed().as_secs_f64()
    }
}

/// A claimed task: everything a worker needs without re-locking.
struct Task {
    tid: usize,
    phase: u8,
    shard: usize,
    sub: usize,
    track: bool,
    scalar: f64,
    /// Second phase coefficient (β for pipelined CG; 0.0 elsewhere).
    scalar2: f64,
    /// Tenant's lifetime epoch at claim time (fault/failure coordinate).
    epoch: u64,
    /// Fault to inject while running this shard (claimed from the
    /// installed `FaultPlan`; `None` on every normal claim).
    inject: Option<FaultKind>,
    engine: Arc<EngineKind>,
}

/// Phase-completion decision.
enum Step {
    Phase(u8),
    Done,
}

// ---------------------------------------------------------------------
// The farm
// ---------------------------------------------------------------------

/// Farm-level metrics snapshot (see module docs: throughput counters,
/// queue latency, fairness).
#[derive(Clone, Debug)]
pub struct FarmMetrics {
    /// Resident worker count.
    pub workers: usize,
    /// OS threads ever spawned — constant after farm startup.
    pub threads_spawned: u64,
    /// Sessions admitted over the farm's lifetime.
    pub admissions: u64,
    /// Commands (advance/advance_until/run) executed or in flight.
    pub commands: u64,
    /// Shard tasks completed.
    pub tasks: u64,
    /// Epochs scheduled (stencil exchange epochs + CG iterations).
    pub epochs: u64,
    /// Queue latency (command enqueue -> first shard dispatch), seconds.
    /// Mean and percentiles cover the rolling sample window (recent
    /// traffic on long-lived farms); `queue_wait_max` is all-time.
    pub queue_wait_mean: f64,
    pub queue_wait_p50: f64,
    pub queue_wait_p99: f64,
    pub queue_wait_max: f64,
    /// Submission-plane batches enqueued (one per submit/submit_graph).
    pub plane_batches: u64,
    /// Enqueue-side scheduler-lock acquisitions. Equals `plane_batches`
    /// by construction: graph segments chain inside completion
    /// transitions without re-acquiring (the batched-path invariant
    /// `bench_check` asserts).
    pub sched_lock_acquisitions: u64,
    /// Submissions rejected by admission control (`Shed` policy or a
    /// batch larger than the caps).
    pub plane_sheds: u64,
    /// Submissions that timed out waiting for plane slots.
    pub plane_timeouts: u64,
    /// All-time peak of concurrently held plane slots — the sustained
    /// in-flight concurrency the stress bench asserts.
    pub plane_inflight_peak: usize,
    /// Faults injected from an installed `FaultPlan` (0 on clean farms —
    /// the invariant clean benches assert).
    pub faults_injected: u64,
    /// Supervised recoveries: retryable failures restored from a
    /// checkpoint and replayed instead of surfacing.
    pub recoveries: u64,
    /// Epochs re-executed by those replays (checkpoint-to-failure
    /// distance, summed — what the cadence bounds).
    pub replayed_epochs: u64,
    /// Bytes copied into resident-state checkpoints.
    pub checkpoint_bytes: u64,
    /// Snapshot frames this farm persisted durably (0 unless a tenant
    /// configured `ResilienceConfig::durable` — and always 0 at
    /// checkpoint cadence 0, the invariant `bench_check` asserts).
    pub durable_frames: u64,
    /// Checkpoint payload bytes those frames carried to disk.
    pub durable_bytes: u64,
}

impl FarmMetrics {
    /// Max/mean queue-wait ratio: 1.0 is perfectly even dispatch; large
    /// values mean some command waited far longer than typical (the
    /// starvation signal the age bound exists to cap).
    pub fn fairness(&self) -> f64 {
        if self.queue_wait_mean <= 0.0 {
            1.0
        } else {
            (self.queue_wait_max / self.queue_wait_mean).max(1.0)
        }
    }
}

/// A spawn-once multi-tenant worker pool serving many concurrent solver
/// sessions. See the module docs for the execution model.
pub struct SolverFarm {
    shared: Arc<FarmShared>,
    handles: Vec<JoinHandle<()>>,
    workers: usize,
    spawned: u64,
}

impl SolverFarm {
    /// Spawn the farm's resident workers with the default (unbounded)
    /// submission plane — the only thread creation of the farm's
    /// lifetime; admissions and commands never add to it.
    pub fn spawn(workers: usize) -> Result<Self> {
        Self::spawn_with(workers, PlaneConfig::default())
    }

    /// [`SolverFarm::spawn`] with an explicit submission-plane budget
    /// (bounded queue, per-tenant caps, block/shed/timeout policy).
    pub fn spawn_with(workers: usize, plane: PlaneConfig) -> Result<Self> {
        if workers == 0 {
            return Err(Error::invalid("farm workers must be > 0"));
        }
        plane.validate()?;
        // CI replay hook: a fault plan in the environment arms injection
        // on every farm the process spawns. A malformed plan fails the
        // spawn loudly — silently running *without* the injection CI
        // asked for would make a red test quietly green.
        let env_faults = FaultPlan::from_env()?;
        let shared = Arc::new(FarmShared {
            ctl: Mutex::new(FarmState {
                shutdown: false,
                ready: VecDeque::new(),
                tenants: Vec::new(),
                free: Vec::new(),
                tick: 0,
                queue_waits: Vec::new(),
                queue_next: 0,
                queue_max: 0.0,
                plane_inflight: 0,
                plane_peak: 0,
                faults: env_faults,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            gate_cv: Condvar::new(),
            clock: Instant::now(),
            workers,
            plane,
            admissions: AtomicU64::new(0),
            commands: AtomicU64::new(0),
            tasks: AtomicU64::new(0),
            epochs: AtomicU64::new(0),
            plane_batches: AtomicU64::new(0),
            sched_locks: AtomicU64::new(0),
            plane_sheds: AtomicU64::new(0),
            plane_timeouts: AtomicU64::new(0),
            faults_injected: AtomicU64::new(0),
            recoveries: AtomicU64::new(0),
            replayed_epochs: AtomicU64::new(0),
            checkpoint_bytes: AtomicU64::new(0),
            durable_frames: AtomicU64::new(0),
            durable_bytes: AtomicU64::new(0),
        });
        counters::note_thread_spawns(workers as u64);
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let sh = shared.clone();
            let spawned = std::thread::Builder::new()
                .name(format!("solver-farm-{w}"))
                .spawn(move || worker_main(&sh));
            match spawned {
                Ok(h) => handles.push(h),
                Err(e) => {
                    // don't leak the workers that did start
                    {
                        let mut g = shared.lock();
                        g.shutdown = true;
                        shared.work_cv.notify_all();
                    }
                    for h in handles {
                        let _ = h.join();
                    }
                    return Err(Error::Solver(format!("farm spawn failed: {e}")));
                }
            }
        }
        Ok(Self { shared, handles, workers, spawned: workers as u64 })
    }

    /// Resident worker count.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// OS threads this farm has ever spawned — constant after `spawn`,
    /// which is the point: admissions and advances must never add to it.
    pub fn spawn_count(&self) -> u64 {
        self.spawned
    }

    /// A cheap, cloneable handle sessions hold to admit tenants and
    /// enqueue commands. The handle keeps the farm's shared state alive,
    /// but not its workers: commands after [`SolverFarm::shutdown`] (or
    /// drop) error out instead of hanging.
    pub fn handle(&self) -> FarmHandle {
        FarmHandle { shared: self.shared.clone() }
    }

    /// Farm-level metrics snapshot.
    pub fn metrics(&self) -> FarmMetrics {
        self.handle().metrics()
    }

    /// Install (or replace) a deterministic fault-injection schedule.
    /// See [`FarmHandle::install_faults`].
    pub fn install_faults(&self, plan: FaultPlan) {
        self.handle().install_faults(plan)
    }

    /// Shut the workers down and join them. Idempotent; `drop` calls it.
    /// Clients blocked in `wait`, parked on the admission gate, or
    /// awaiting a completion future are all woken with an error.
    pub fn shutdown(&mut self) {
        let wakers: Vec<Waker> = {
            let mut g = self.shared.lock();
            g.shutdown = true;
            self.shared.work_cv.notify_all();
            self.shared.done_cv.notify_all();
            self.shared.gate_cv.notify_all();
            g.tenants
                .iter_mut()
                .filter_map(|t| t.as_mut().and_then(|t| t.waker.take()))
                .collect()
        };
        // fire completion wakers outside the lock: a woken future's poll
        // re-locks the scheduler immediately
        for w in wakers {
            w.wake();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }

    #[cfg(test)]
    fn shared_weak(&self) -> std::sync::Weak<FarmShared> {
        Arc::downgrade(&self.shared)
    }
}

impl Drop for SolverFarm {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Cloneable client handle to a [`SolverFarm`] (see [`SolverFarm::handle`]).
#[derive(Clone)]
pub struct FarmHandle {
    shared: Arc<FarmShared>,
}

impl std::fmt::Debug for FarmHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FarmHandle").finish()
    }
}

impl FarmHandle {
    /// Admit a stencil session: `shards` bands (clamped to the interior
    /// planes, like the solo pool's thread count) at temporal degree
    /// `bt`. Allocates the tenant's resident state; spawns nothing.
    pub fn admit_stencil(
        &self,
        spec: &StencilSpec,
        x0: &Domain,
        shards: usize,
        bt: usize,
    ) -> Result<FarmStencil> {
        let engine = StencilEngine::new(spec, x0, shards, bt)?;
        let tid = self.admit(EngineKind::Stencil(engine))?;
        Ok(FarmStencil { farm: self.clone(), tid })
    }

    /// Admit a CG session over a matrix and its cached merge plan.
    /// Allocates the tenant's resident vectors; spawns nothing.
    pub fn admit_cg(&self, a: Arc<Csr>, plan: MergePlan) -> Result<FarmCg> {
        let engine = CgEngine::new(a, plan)?;
        let tid = self.admit(EngineKind::Cg(engine))?;
        Ok(FarmCg { farm: self.clone(), tid })
    }

    /// Admit a **pipelined** (optionally preconditioned) CG session
    /// ([`crate::cg::pipeline`]): one scheduled phase — and one slot
    /// fold — per iteration, where [`FarmHandle::admit_cg`] schedules
    /// four. Iterates are bit-identical to
    /// [`crate::cg::pipeline::advance_serial`] over the same `parts`
    /// blocks at every farm worker count. Pipelined tenants do not
    /// support resilience configuration or command graphs.
    pub fn admit_cg_pipelined(
        &self,
        a: Arc<Csr>,
        parts: usize,
        precond: Preconditioner,
    ) -> Result<FarmCgPipe> {
        let engine = CgPipeEngine::new(a, parts, precond)?;
        let tid = self.admit(EngineKind::CgPipe(engine))?;
        Ok(FarmCgPipe { farm: self.clone(), tid })
    }

    fn admit(&self, engine: EngineKind) -> Result<usize> {
        let mut g = self.shared.lock();
        if g.shutdown {
            return Err(Error::Solver("solver farm is shut down".into()));
        }
        let tenant = Tenant::new(Arc::new(engine));
        let tid = match g.free.pop() {
            Some(slot) => {
                g.tenants[slot] = Some(tenant);
                slot
            }
            None => {
                g.tenants.push(Some(tenant));
                g.tenants.len() - 1
            }
        };
        self.shared.admissions.fetch_add(1, Ordering::Relaxed);
        counters::note_farm_admissions(1);
        Ok(tid)
    }

    /// Farm-level metrics snapshot. Percentiles and the mean cover the
    /// rolling sample window (recent traffic); the max is all-time.
    pub fn metrics(&self) -> FarmMetrics {
        let sh = &self.shared;
        let (samples, max, peak) = {
            let g = sh.lock();
            (g.queue_waits.clone(), g.queue_max, g.plane_peak)
        };
        let mean = if samples.is_empty() {
            0.0
        } else {
            samples.iter().sum::<f64>() / samples.len() as f64
        };
        FarmMetrics {
            workers: sh.workers,
            threads_spawned: sh.workers as u64,
            admissions: sh.admissions.load(Ordering::Relaxed),
            commands: sh.commands.load(Ordering::Relaxed),
            tasks: sh.tasks.load(Ordering::Relaxed),
            epochs: sh.epochs.load(Ordering::Relaxed),
            queue_wait_mean: mean,
            queue_wait_p50: percentile(&samples, 50.0),
            queue_wait_p99: percentile(&samples, 99.0),
            queue_wait_max: max,
            plane_batches: sh.plane_batches.load(Ordering::Relaxed),
            sched_lock_acquisitions: sh.sched_locks.load(Ordering::Relaxed),
            plane_sheds: sh.plane_sheds.load(Ordering::Relaxed),
            plane_timeouts: sh.plane_timeouts.load(Ordering::Relaxed),
            plane_inflight_peak: peak,
            faults_injected: sh.faults_injected.load(Ordering::Relaxed),
            recoveries: sh.recoveries.load(Ordering::Relaxed),
            replayed_epochs: sh.replayed_epochs.load(Ordering::Relaxed),
            checkpoint_bytes: sh.checkpoint_bytes.load(Ordering::Relaxed),
            durable_frames: sh.durable_frames.load(Ordering::Relaxed),
            durable_bytes: sh.durable_bytes.load(Ordering::Relaxed),
        }
    }

    /// Install (or replace) a deterministic fault-injection schedule on
    /// the farm: each [`crate::runtime::resilience::FaultSpec`] fires
    /// exactly once when the scheduler claims its (tenant, epoch, phase,
    /// shard) coordinate. The plan is also picked up automatically from
    /// the `PERKS_FAULT_PLAN` environment variable at spawn, so CI can
    /// replay any failure without code changes.
    pub fn install_faults(&self, plan: FaultPlan) {
        let mut g = self.shared.lock();
        g.faults = Some(plan);
    }

    /// Set a tenant's resilience knobs (checkpoint cadence, retry
    /// policy, watchdog deadline). Errors if the tenant has a command in
    /// flight — the knobs feed the completion transition and must not
    /// change under it.
    fn set_resilience(&self, tid: usize, cfg: ResilienceConfig) -> Result<()> {
        // open the snapshot store *before* taking the scheduler lock:
        // directory creation is filesystem I/O and must never ride `ctl`
        let store = match cfg.durable.as_deref() {
            Some(dir) => Some(SnapshotStore::open(dir)?),
            None => None,
        };
        let mut g = self.shared.lock();
        if g.shutdown {
            return Err(Error::Solver("solver farm is shut down".into()));
        }
        let Some(t) = g.tenants[tid].as_mut() else {
            return Err(Error::Solver("farm tenant released".into()));
        };
        if t.active {
            return Err(Error::Solver(
                "resilience config change with a command in flight".into(),
            ));
        }
        if matches!(&*t.engine, EngineKind::CgPipe(_)) {
            // a pipelined iteration's state spans the whole recurrence
            // pipeline (nine vectors + four scalars + the m parity);
            // checkpoint/replay is a classic-path feature
            return Err(Error::Solver(
                "resilience is not supported for pipelined CG tenants; \
                 use the classic CG farm path for checkpoint/replay"
                    .into(),
            ));
        }
        t.durable = store.map(|store| {
            Arc::new(DurableSink {
                store,
                // slot index as the on-disk tenant name: stable across a
                // kill + restart that re-admits tenants in the same order
                // (the recovery contract `perks_recover` documents)
                name: format!("t{tid}"),
                meta: workload_meta(&t.engine),
            })
        });
        // reconfiguring is the reset point for a failed write-out: the
        // new config names a (possibly different, possibly fixed)
        // directory, so the stale error must not poison it
        t.durable_error = None;
        t.res_cfg = cfg;
        Ok(())
    }

    // ----- command plumbing shared by the session handles -----

    fn submit_stencil(&self, tid: usize, steps: usize, tol: Option<f64>) -> Result<()> {
        self.submit_stencil_cmd(tid, steps, &[], tol, 0)
    }

    fn submit_stencil_graph(&self, tid: usize, graph: &CommandGraph) -> Result<()> {
        let segs = graph.segments();
        self.submit_stencil_cmd(tid, segs[0], &segs[1..], graph.tol(), graph.resubmits())
    }

    /// Enqueue one stencil batch: a first segment armed as the in-flight
    /// command plus trailing segments chained by the completion
    /// transition (the batch dequeue). One scheduler-lock acquisition
    /// per call, however many segments the batch carries.
    fn submit_stencil_cmd(
        &self,
        tid: usize,
        steps: usize,
        rest: &[usize],
        tol: Option<f64>,
        resubmits: u32,
    ) -> Result<()> {
        let sh = &self.shared;
        let g = sh.lock();
        if g.shutdown {
            return Err(Error::Solver("solver farm is shut down".into()));
        }
        // contract errors come before admission: a double submit must
        // fail loudly, never park on the gate it can only deadlock
        let bt = {
            // lint: allow(no-panic) -- the session owning `tid` is alive (it called us by &self), so its tenant slot cannot have been released
            let t = g.tenants[tid].as_ref().expect("tenant released");
            if t.active {
                return Err(Error::Solver(
                    "farm session already has a command in flight".into(),
                ));
            }
            // a durable write-out failed after an earlier command
            // completed: fail the next submit loudly instead of silently
            // advancing state the disk can no longer recover
            if let Some(msg) = t.durable_error.as_ref() {
                return Err(Error::Snapshot(msg.clone()));
            }
            match &*t.engine {
                EngineKind::Stencil(e) => e.bt,
                EngineKind::Cg(_) | EngineKind::CgPipe(_) => {
                    return Err(Error::Solver("not a stencil tenant".into()))
                }
            }
        };
        let mut g = acquire_plane_slots(sh, g, tid, 1 + rest.len())?;
        let now = sh.now();
        let tick = g.tick;
        // lint: allow(no-panic) -- the session owning `tid` is alive (it called us by &self), so its tenant slot cannot have been released
        let t = g.tenants[tid].as_mut().expect("tenant released");
        t.active = true;
        t.done_flag = false;
        t.failure = None;
        t.moved = 0;
        t.computed = 0;
        t.steps_target = steps;
        t.tol = tol;
        t.done_steps = 0;
        t.residual = None;
        t.first_dispatch = true;
        t.enqueued_at = now;
        t.queue_wait_cmd = 0.0;
        t.attempts = 0;
        t.resume_at = 0.0;
        t.recoveries_cmd = 0;
        t.replayed_cmd = 0;
        t.ckpt_bytes_cmd = 0;
        t.graph_segs.clear();
        t.graph_segs.extend(rest.iter().copied());
        t.graph_schedule.clear();
        t.graph_resubmits = resubmits;
        if resubmits > 0 {
            t.graph_schedule.push(steps);
            t.graph_schedule.extend_from_slice(rest);
        }
        // command-entry checkpoint: with a retry policy armed, recovery
        // must be possible at *any* epoch, not just past the first
        // cadence boundary — snapshot the pre-command resident state
        // (and the whole segment schedule, so a restored replay
        // re-dequeues segments exactly like the clean run)
        if t.res_cfg.retry.max_attempts > 0 {
            take_checkpoint(t, sh);
        }
        // first phase: one-time slab load, else straight into the first
        // epoch (or the final store for a 0-step command — the solo pool
        // also re-stores bands on a 0-step run)
        t.phase = if !t.loaded {
            P_LOAD
        } else if steps == 0 {
            P_FINAL
        } else {
            t.sub = bt.min(steps);
            P_COMPUTE
        };
        t.next_shard = 0;
        t.outstanding = 0;
        t.nshards = t.engine.shards();
        t.enqueue_tick = tick;
        g.ready.push_back(tid);
        note_batch_enqueued(sh);
        sh.work_cv.notify_all();
        Ok(())
    }

    fn wait_stencil(&self, tid: usize) -> Result<StencilFarmRun> {
        // the blocking wrapper is the async path driven by a parking
        // waker: one code path for harvest, shutdown, and error handling.
        // The watchdog runs first; once it passes, the future resolves
        // without parking.
        self.deadline_guard(tid)?;
        block_on(StencilCompletion::new(self.clone(), tid))
    }

    /// Watchdog for the blocking wait paths: with a tenant deadline
    /// armed ([`crate::runtime::resilience::ResilienceConfig::deadline`]),
    /// park on the completion condvar until the command finishes or the
    /// deadline expires, surfacing [`Error::Stuck`] with phase/epoch
    /// context on expiry. Without a deadline this is one lock + branch.
    /// An expired command keeps draining (its workers are not
    /// interruptible mid-shard); releasing the tenant reaps it through
    /// the existing zombie path.
    fn deadline_guard(&self, tid: usize) -> Result<()> {
        let sh = &self.shared;
        let mut g = sh.lock();
        let deadline = {
            let Some(t) = g.tenants[tid].as_ref() else { return Ok(()) };
            match t.res_cfg.deadline {
                Some(d) if t.active && !t.done_flag => d,
                _ => return Ok(()),
            }
        };
        let start = Instant::now();
        loop {
            if g.shutdown {
                return Ok(()); // the completion future surfaces shutdown
            }
            let (phase, epoch) = {
                let Some(t) = g.tenants[tid].as_ref() else { return Ok(()) };
                if !t.active || t.done_flag {
                    return Ok(());
                }
                (t.phase, t.epoch)
            };
            let waited = start.elapsed();
            if waited >= deadline {
                return Err(Error::Stuck {
                    phase: phase as usize,
                    epoch,
                    waited_ms: waited.as_millis() as u64,
                });
            }
            let (guard, _) = sh
                .done_cv
                .wait_timeout(g, deadline - waited)
                .unwrap_or_else(|p| p.into_inner());
            g = guard;
        }
    }

    /// Poll an in-flight stencil command (the completion-future core).
    /// Ready = harvest, exactly like the old blocking wait: clears the
    /// in-flight state, takes the run/error, releases the plane slots.
    /// Pending registers `waker` as the tenant's completion hook.
    pub(crate) fn poll_stencil_done(
        &self,
        tid: usize,
        waker: &Waker,
    ) -> Poll<Result<StencilFarmRun>> {
        enum Out {
            Done(Result<StencilFarmRun>),
            Inactive,
            Shutdown,
            Pending,
        }
        let sh = &self.shared;
        let mut g = sh.lock();
        let down = g.shutdown;
        let out = {
            let Some(t) = g.tenants[tid].as_mut() else {
                return Poll::Ready(Err(Error::Solver("farm tenant released".into())));
            };
            if t.done_flag {
                t.done_flag = false;
                t.active = false;
                t.waker = None;
                let run = StencilFarmRun {
                    steps: t.done_steps,
                    residual: t.residual,
                    global_bytes: t.moved,
                    computed_cells: t.computed,
                    queue_wait_seconds: t.queue_wait_cmd,
                    recoveries: t.recoveries_cmd,
                    replayed_epochs: t.replayed_cmd,
                    checkpoint_bytes: t.ckpt_bytes_cmd,
                };
                Out::Done(match t.failure.take() {
                    Some(f) => Err(f.into_error()),
                    None => Ok(run),
                })
            } else if !t.active {
                // nothing submitted (or already harvested): resolve with
                // an error instead of pending on a command that will
                // never come
                Out::Inactive
            } else if down {
                Out::Shutdown
            } else {
                match &t.waker {
                    Some(w) if w.will_wake(waker) => {}
                    _ => t.waker = Some(waker.clone()),
                }
                Out::Pending
            }
        };
        match out {
            Out::Done(res) => {
                release_plane_slots(&mut g, sh, tid);
                Poll::Ready(res)
            }
            Out::Inactive => {
                Poll::Ready(Err(Error::Solver("no farm command in flight to wait for".into())))
            }
            Out::Shutdown => {
                abandon_command(&mut g, tid);
                release_plane_slots(&mut g, sh, tid);
                Poll::Ready(Err(Error::Solver(
                    "solver farm shut down while a command was in flight".into(),
                )))
            }
            Out::Pending => Poll::Pending,
        }
    }

    /// A completion future was dropped before resolving: clear its
    /// waker hook and release the tenant's plane slots (the command
    /// keeps executing and stays harvestable by a later wait/future,
    /// but an abandoned client must not pin admission capacity).
    pub(crate) fn forget_completion(&self, tid: usize) {
        let sh = &self.shared;
        let mut g = sh.lock();
        if let Some(t) = g.tenants[tid].as_mut() {
            t.waker = None;
        }
        release_plane_slots(&mut g, sh, tid);
    }

    #[allow(clippy::too_many_arguments)]
    fn submit_cg(
        &self,
        tid: usize,
        x: &[f64],
        r: &[f64],
        p: &[f64],
        rr: f64,
        threshold: f64,
        iters: usize,
    ) -> Result<()> {
        self.submit_cg_cmd(tid, x, r, p, rr, threshold, iters, &[], 0)
    }

    #[allow(clippy::too_many_arguments)]
    fn submit_cg_graph(
        &self,
        tid: usize,
        x: &[f64],
        r: &[f64],
        p: &[f64],
        rr: f64,
        graph: &CommandGraph,
    ) -> Result<()> {
        let segs = graph.segments();
        // the graph's tolerance is the CG squared-residual threshold
        // (0.0 = fixed-iteration mode, as in `submit`)
        let threshold = graph.tol().unwrap_or(0.0);
        self.submit_cg_cmd(tid, x, r, p, rr, threshold, segs[0], &segs[1..], graph.resubmits())
    }

    /// Enqueue one CG batch (see [`FarmHandle::submit_stencil_cmd`] for
    /// the batching contract).
    #[allow(clippy::too_many_arguments)]
    fn submit_cg_cmd(
        &self,
        tid: usize,
        x: &[f64],
        r: &[f64],
        p: &[f64],
        rr: f64,
        threshold: f64,
        iters: usize,
        rest: &[usize],
        resubmits: u32,
    ) -> Result<()> {
        let sh = &self.shared;
        let g = sh.lock();
        if g.shutdown {
            return Err(Error::Solver("solver farm is shut down".into()));
        }
        // contract errors before admission (see submit_stencil_cmd)
        {
            // lint: allow(no-panic) -- the session owning `tid` is alive (it called us by &self), so its tenant slot cannot have been released
            let t = g.tenants[tid].as_ref().expect("tenant released");
            if t.active {
                return Err(Error::Solver(
                    "farm session already has a command in flight".into(),
                ));
            }
            // failed durable write-out: loud on the next submit (see
            // submit_stencil_cmd)
            if let Some(msg) = t.durable_error.as_ref() {
                return Err(Error::Snapshot(msg.clone()));
            }
            let EngineKind::Cg(ref e) = *t.engine else {
                return Err(Error::Solver("not a cg tenant".into()));
            };
            let n = e.a.n_rows;
            if x.len() != n || r.len() != n || p.len() != n {
                return Err(Error::Solver("farm cg state length mismatch".into()));
            }
        }
        let mut g = acquire_plane_slots(sh, g, tid, 1 + rest.len())?;
        let now = sh.now();
        let tick = g.tick;
        // lint: allow(no-panic) -- the session owning `tid` is alive (it called us by &self), so its tenant slot cannot have been released
        let t = g.tenants[tid].as_mut().expect("tenant released");
        let engine = t.engine.clone();
        let EngineKind::Cg(ref e) = *engine else { unreachable!() };
        // SAFETY: tenant idle (no command in flight, checked above under
        // the scheduler lock) — exclusive access to the resident buffers.
        unsafe {
            e.x.whole_mut().copy_from_slice(x);
            e.r.whole_mut().copy_from_slice(r);
            e.p.whole_mut().copy_from_slice(p);
        }
        t.active = true;
        t.done_flag = false;
        t.failure = None;
        t.moved = 0;
        t.computed = 0;
        t.iters_target = iters;
        t.threshold = threshold;
        t.iters_done = 0;
        t.rr = rr;
        t.first_dispatch = true;
        t.enqueued_at = now;
        t.queue_wait_cmd = 0.0;
        t.attempts = 0;
        t.resume_at = 0.0;
        t.recoveries_cmd = 0;
        t.replayed_cmd = 0;
        t.ckpt_bytes_cmd = 0;
        t.graph_segs.clear();
        t.graph_segs.extend(rest.iter().copied());
        t.graph_schedule.clear();
        t.graph_resubmits = resubmits;
        if resubmits > 0 {
            t.graph_schedule.push(iters);
            t.graph_schedule.extend_from_slice(rest);
        }
        note_batch_enqueued(sh);
        if rr <= threshold || rr <= 0.0 || iters == 0 {
            // nothing to iterate: complete immediately (the serial/pooled
            // top-of-loop short circuit); the whole batch retires with it
            t.graph_segs.clear();
            t.graph_resubmits = 0;
            t.done_flag = true;
            sh.done_cv.notify_all();
            return Ok(());
        }
        // command-entry checkpoint (see submit_stencil_cmd) — after the
        // short circuit: a command that never iterates never recovers
        if t.res_cfg.retry.max_attempts > 0 {
            take_checkpoint(t, sh);
        }
        t.phase = P_SPMV;
        t.next_shard = 0;
        t.outstanding = 0;
        t.nshards = t.engine.shards();
        t.enqueue_tick = tick;
        g.ready.push_back(tid);
        sh.work_cv.notify_all();
        Ok(())
    }

    fn wait_cg(
        &self,
        tid: usize,
        x: &mut [f64],
        r: &mut [f64],
        p: &mut [f64],
    ) -> Result<CgFarmRun> {
        // blocking wrapper over the async completion path (see
        // wait_stencil), watchdog first
        self.deadline_guard(tid)?;
        block_on(CgCompletion::new(self.clone(), tid, x, r, p))
    }

    /// Poll an in-flight CG command; Ready harvests (copying the
    /// advanced x/r/p out) exactly like the old blocking wait.
    pub(crate) fn poll_cg_done(
        &self,
        tid: usize,
        waker: &Waker,
        x: &mut [f64],
        r: &mut [f64],
        p: &mut [f64],
    ) -> Poll<Result<CgFarmRun>> {
        enum Out {
            Done(CgFarmRun),
            /// A fault (panicked shard) — structured error, state torn
            /// mid-iteration, nothing copied out.
            Fault(Error),
            Inactive,
            Shutdown,
            Pending,
        }
        let sh = &self.shared;
        let mut g = sh.lock();
        let down = g.shutdown;
        let out = {
            let Some(t) = g.tenants[tid].as_mut() else {
                return Poll::Ready(Err(Error::Solver("farm tenant released".into())));
            };
            if t.done_flag {
                t.done_flag = false;
                t.active = false;
                t.waker = None;
                match t.failure.take() {
                    Some(f @ Failure::Panic { .. }) => {
                        // torn mid-iteration: the resident vectors are in
                        // an unknown phase state — surface the structured
                        // fault and leave the caller's buffers untouched
                        Out::Fault(f.into_error())
                    }
                    other => {
                        let run = CgFarmRun {
                            iters: t.iters_done,
                            rr: t.rr,
                            // collective errors (non-PD, non-finite) fire
                            // at the transition, before any state update
                            // of the failing iteration: completed
                            // iterations remain valid and observable
                            error: other.map(|f| f.message()),
                            queue_wait_seconds: t.queue_wait_cmd,
                            recoveries: t.recoveries_cmd,
                            replayed_epochs: t.replayed_cmd,
                            checkpoint_bytes: t.ckpt_bytes_cmd,
                        };
                        let engine = t.engine.clone();
                        let EngineKind::Cg(ref e) = *engine else { unreachable!() };
                        // SAFETY: command done — workers re-parked,
                        // buffers quiescent.
                        unsafe {
                            x.copy_from_slice(e.x.whole());
                            r.copy_from_slice(e.r.whole());
                            p.copy_from_slice(e.p.whole());
                        }
                        Out::Done(run)
                    }
                }
            } else if !t.active {
                Out::Inactive
            } else if down {
                Out::Shutdown
            } else {
                match &t.waker {
                    Some(w) if w.will_wake(waker) => {}
                    _ => t.waker = Some(waker.clone()),
                }
                Out::Pending
            }
        };
        match out {
            Out::Done(run) => {
                release_plane_slots(&mut g, sh, tid);
                Poll::Ready(Ok(run))
            }
            Out::Fault(err) => {
                release_plane_slots(&mut g, sh, tid);
                Poll::Ready(Err(err))
            }
            Out::Inactive => {
                Poll::Ready(Err(Error::Solver("no farm command in flight to wait for".into())))
            }
            Out::Shutdown => {
                abandon_command(&mut g, tid);
                release_plane_slots(&mut g, sh, tid);
                Poll::Ready(Err(Error::Solver(
                    "solver farm shut down while a command was in flight".into(),
                )))
            }
            Out::Pending => Poll::Pending,
        }
    }

    /// Enqueue up to `iters` pipelined-CG iterations from the full
    /// recurrence state `st` (copied into the tenant's resident
    /// buffers; `m` lands at parity 0). The top-of-loop short circuit
    /// and the first iteration's coefficients run here, host-side —
    /// exactly where the solo pool computes them.
    fn submit_cg_pipe(
        &self,
        tid: usize,
        st: &PipeState,
        threshold: f64,
        iters: usize,
    ) -> Result<()> {
        let sh = &self.shared;
        let g = sh.lock();
        if g.shutdown {
            return Err(Error::Solver("solver farm is shut down".into()));
        }
        // contract errors before admission (see submit_stencil_cmd)
        {
            // lint: allow(no-panic) -- the session owning `tid` is alive (it called us by &self), so its tenant slot cannot have been released
            let t = g.tenants[tid].as_ref().expect("tenant released");
            if t.active {
                return Err(Error::Solver(
                    "farm session already has a command in flight".into(),
                ));
            }
            let EngineKind::CgPipe(ref e) = *t.engine else {
                return Err(Error::Solver("not a pipelined cg tenant".into()));
            };
            if st.x.len() != e.a.n_rows {
                return Err(Error::Solver("farm cg state length mismatch".into()));
            }
        }
        let mut g = acquire_plane_slots(sh, g, tid, 1)?;
        let now = sh.now();
        let tick = g.tick;
        // lint: allow(no-panic) -- the session owning `tid` is alive (it called us by &self), so its tenant slot cannot have been released
        let t = g.tenants[tid].as_mut().expect("tenant released");
        let engine = t.engine.clone();
        let EngineKind::CgPipe(ref e) = *engine else { unreachable!() };
        // SAFETY: tenant idle (no command in flight, checked above under
        // the scheduler lock) — exclusive access to the resident buffers.
        // m[1] needs no copy: every row is written before it is read.
        unsafe {
            e.x.whole_mut().copy_from_slice(&st.x);
            e.r.whole_mut().copy_from_slice(&st.r);
            e.u.whole_mut().copy_from_slice(&st.u);
            e.w.whole_mut().copy_from_slice(&st.w);
            e.p.whole_mut().copy_from_slice(&st.p);
            e.s.whole_mut().copy_from_slice(&st.s);
            e.q.whole_mut().copy_from_slice(&st.q);
            e.z.whole_mut().copy_from_slice(&st.z);
            e.m[0].whole_mut().copy_from_slice(&st.m);
        }
        t.active = true;
        t.done_flag = false;
        t.failure = None;
        t.moved = 0;
        t.computed = 0;
        t.iters_target = iters;
        t.threshold = threshold;
        t.iters_done = 0;
        t.rr = st.rr;
        t.pg_gamma = st.gamma;
        t.pg_delta = st.delta;
        t.pg_gamma_prev = st.gamma_prev;
        t.pg_alpha_prev = st.alpha_prev;
        t.sub = 0; // m parity
        t.first_dispatch = true;
        t.enqueued_at = now;
        t.queue_wait_cmd = 0.0;
        t.attempts = 0;
        t.resume_at = 0.0;
        t.recoveries_cmd = 0;
        t.replayed_cmd = 0;
        t.ckpt_bytes_cmd = 0;
        t.graph_segs.clear();
        t.graph_schedule.clear();
        t.graph_resubmits = 0;
        note_batch_enqueued(sh);
        if st.rr <= threshold || st.rr <= 0.0 || iters == 0 {
            // nothing to iterate: the serial/pooled top-of-loop short
            // circuit, completed immediately
            t.done_flag = true;
            sh.done_cv.notify_all();
            return Ok(());
        }
        // first iteration's coefficients — the same host-side recurrence
        // every replication site runs before its first fused pass
        match pipeline::pipe_coeffs(st.gamma, st.delta, st.gamma_prev, st.alpha_prev) {
            Ok((beta, alpha)) => {
                t.alpha = alpha;
                t.beta = beta;
            }
            Err(msg) => {
                t.failure = Some(Failure::Solver(msg));
                t.done_flag = true;
                sh.done_cv.notify_all();
                return Ok(());
            }
        }
        t.phase = P_PIPE;
        t.next_shard = 0;
        t.outstanding = 0;
        t.nshards = t.engine.shards();
        t.enqueue_tick = tick;
        g.ready.push_back(tid);
        sh.work_cv.notify_all();
        Ok(())
    }

    /// Block until the submitted pipelined-CG command completes,
    /// harvesting the advanced recurrence state back into `st` (`m`
    /// from the tenant's current parity). Panicked shards surface as
    /// [`Error::Fault`] with nothing copied out (the iteration was torn
    /// mid-pass), exactly like the classic CG harvest.
    fn wait_cg_pipe(&self, tid: usize, st: &mut PipeState) -> Result<CgFarmRun> {
        self.deadline_guard(tid)?;
        let sh = &self.shared;
        let mut g = sh.lock();
        loop {
            let done = {
                let Some(t) = g.tenants[tid].as_mut() else {
                    return Err(Error::Solver("farm tenant released".into()));
                };
                if !t.active && !t.done_flag {
                    return Err(Error::Solver(
                        "no farm command in flight to wait for".into(),
                    ));
                }
                t.done_flag
            };
            if done {
                break;
            }
            if g.shutdown {
                abandon_command(&mut g, tid);
                release_plane_slots(&mut g, sh, tid);
                return Err(Error::Solver(
                    "solver farm shut down while a command was in flight".into(),
                ));
            }
            // shutdown is re-checked on every wake (the loop head above)
            // lint: allow(condvar-shutdown) -- client-side completion wait; the loop re-checks the shutdown flag before parking again, so a farm teardown wakes us into the error return above
            g = sh.done_cv.wait(g).unwrap_or_else(|p| p.into_inner());
        }
        // lint: allow(no-panic) -- done_flag observed under this same uninterrupted lock hold
        let t = g.tenants[tid].as_mut().expect("tenant released");
        t.done_flag = false;
        t.active = false;
        t.waker = None;
        let out = match t.failure.take() {
            Some(f @ Failure::Panic { .. }) => Err(f.into_error()),
            other => {
                let run = CgFarmRun {
                    iters: t.iters_done,
                    rr: t.rr,
                    error: other.map(|f| f.message()),
                    queue_wait_seconds: t.queue_wait_cmd,
                    recoveries: t.recoveries_cmd,
                    replayed_epochs: t.replayed_cmd,
                    checkpoint_bytes: t.ckpt_bytes_cmd,
                };
                let engine = t.engine.clone();
                let EngineKind::CgPipe(ref e) = *engine else { unreachable!() };
                let parity = t.sub;
                // SAFETY: command done — workers re-parked, buffers
                // quiescent; the current parity holds the freshest m.
                unsafe {
                    st.x.copy_from_slice(e.x.whole());
                    st.r.copy_from_slice(e.r.whole());
                    st.u.copy_from_slice(e.u.whole());
                    st.w.copy_from_slice(e.w.whole());
                    st.p.copy_from_slice(e.p.whole());
                    st.s.copy_from_slice(e.s.whole());
                    st.q.copy_from_slice(e.q.whole());
                    st.z.copy_from_slice(e.z.whole());
                    st.m.copy_from_slice(e.m[parity].whole());
                }
                st.gamma = t.pg_gamma;
                st.delta = t.pg_delta;
                st.rr = t.rr;
                st.gamma_prev = t.pg_gamma_prev;
                st.alpha_prev = t.pg_alpha_prev;
                Ok(run)
            }
        };
        release_plane_slots(&mut g, sh, tid);
        out
    }

    /// Snapshot a stencil tenant's padded domain (between commands only).
    fn stencil_state(&self, tid: usize) -> Result<Vec<f64>> {
        let g = self.shared.lock();
        // lint: allow(no-panic) -- the session owning `tid` is alive (it called us by &self), so its tenant slot cannot have been released
        let t = g.tenants[tid].as_ref().expect("tenant released");
        if t.active {
            return Err(Error::Solver(
                "farm session state read with a command in flight".into(),
            ));
        }
        let EngineKind::Stencil(ref e) = *t.engine else {
            return Err(Error::Solver("not a stencil tenant".into()));
        };
        let mut out = vec![0.0; e.grid.len()];
        // SAFETY: tenant idle (checked under the scheduler lock) — the
        // previous command's completion happened-before this read.
        unsafe { e.grid.read(0..out.len(), &mut out) };
        Ok(out)
    }

    /// Install a durable checkpoint's resident state into an idle
    /// stencil tenant — the disk-restore twin of the in-memory
    /// `restore_tenant`: grid, slab pairs, the load flag, and the
    /// lifetime epoch coordinate. Shape mismatches are structured
    /// [`Error::Snapshot`]s (the frame belongs to a different workload),
    /// never a panic.
    fn restore_stencil(&self, tid: usize, ck: &Checkpoint) -> Result<()> {
        let mut g = self.shared.lock();
        if g.shutdown {
            return Err(Error::Solver("solver farm is shut down".into()));
        }
        let Some(t) = g.tenants[tid].as_mut() else {
            return Err(Error::Solver("farm tenant released".into()));
        };
        if t.active {
            return Err(Error::Solver(
                "farm state restore with a command in flight".into(),
            ));
        }
        let engine = t.engine.clone();
        let EngineKind::Stencil(ref e) = *engine else {
            return Err(Error::Solver("not a stencil tenant".into()));
        };
        let CheckpointPayload::Stencil { grid, slabs, residual, loaded, .. } = &ck.payload
        else {
            return Err(Error::Snapshot("checkpoint is not a stencil snapshot".into()));
        };
        if grid.len() != e.grid.len() {
            return Err(Error::Snapshot(format!(
                "snapshot grid has {} cells, tenant expects {}",
                grid.len(),
                e.grid.len()
            )));
        }
        if *loaded {
            if slabs.len() != e.plans.len() {
                return Err(Error::Snapshot(format!(
                    "snapshot has {} slab pairs, tenant expects {}",
                    slabs.len(),
                    e.plans.len()
                )));
            }
            for (i, (plan, (cur, nxt))) in e.plans.iter().zip(slabs).enumerate() {
                if cur.len() != plan.slab.len() || nxt.len() != plan.slab.len() {
                    return Err(Error::Snapshot(format!(
                        "snapshot slab {i} is {}/{} cells, tenant expects {}",
                        cur.len(),
                        nxt.len(),
                        plan.slab.len()
                    )));
                }
            }
        }
        // SAFETY: tenant idle (checked above under the scheduler lock) —
        // exclusive access to the resident buffers, and every length was
        // validated structurally just above.
        unsafe {
            e.grid.write(0, grid);
            if *loaded {
                for (cell, (cur, nxt)) in e.slabs.iter().zip(slabs) {
                    let slab = &mut *cell.0.get();
                    slab.cur.copy_from_slice(cur);
                    slab.nxt.copy_from_slice(nxt);
                }
            }
        }
        t.loaded = *loaded;
        t.residual = *residual;
        t.epoch = ck.epoch;
        Ok(())
    }

    fn release(&self, tid: usize) {
        let sh = &self.shared;
        let mut g = sh.lock();
        release_plane_slots(&mut g, sh, tid);
        let Some(t) = g.tenants[tid].as_mut() else { return };
        if t.active && !t.done_flag {
            // command still in flight (client dropped without waiting):
            // free the slot when it completes; tasks hold their own Arc.
            // Nobody can await a released tenant, so drop any waker too.
            t.zombie = true;
            t.waker = None;
        } else {
            g.tenants[tid] = None;
            g.free.push(tid);
        }
    }

    #[cfg(test)]
    fn tenant_slots(&self) -> usize {
        self.shared.lock().tenants.len()
    }
}

/// Result of one stencil farm command (the farm analog of
/// [`crate::stencil::pool::StencilRun`], plus the queue latency).
#[derive(Clone, Debug)]
pub struct StencilFarmRun {
    /// Time steps actually performed (early tolerance stops land on an
    /// epoch boundary when `bt > 1`, exactly as in the solo pool).
    pub steps: usize,
    /// Last in-loop residual norm, `Some` iff the command tracked one.
    pub residual: Option<f64>,
    /// Bytes moved through the shared ("global") array (same accounting
    /// as the solo pool: slab loads, boundary unions, halos, final store).
    pub global_bytes: u64,
    /// Cell updates including temporal-blocking overlap work.
    pub computed_cells: u64,
    /// Time this command waited from enqueue to first shard dispatch.
    pub queue_wait_seconds: f64,
    /// Supervised recoveries this command performed (0 on a clean run —
    /// the invariant clean benches assert).
    pub recoveries: u64,
    /// Epochs re-executed by those recoveries (checkpoint-to-failure
    /// distance, what the cadence bounds).
    pub replayed_epochs: u64,
    /// Bytes copied into resident-state checkpoints by this command.
    pub checkpoint_bytes: u64,
}

/// Result of one CG farm command (the farm analog of
/// [`crate::cg::pool::PoolRun`], plus the queue latency).
#[derive(Clone, Debug)]
pub struct CgFarmRun {
    pub iters: usize,
    pub rr: f64,
    /// Collective solver error (not positive definite, or a non-finite
    /// reduction that exhausted its retries) — completed iterations are
    /// still valid, as in the serial/pooled paths. A *panicked* shard is
    /// different: it surfaces as `Err(Error::Fault)` from the wait, with
    /// no state copied out (the iteration was torn mid-phase).
    pub error: Option<String>,
    pub queue_wait_seconds: f64,
    /// Supervised recoveries this command performed (0 on a clean run).
    pub recoveries: u64,
    /// Iterations re-executed by those recoveries.
    pub replayed_epochs: u64,
    /// Bytes copied into resident-state checkpoints by this command.
    pub checkpoint_bytes: u64,
}

/// An admitted stencil session: submit/wait (or the blocking `advance`)
/// plus state snapshots. Dropping the handle releases the tenant.
pub struct FarmStencil {
    farm: FarmHandle,
    tid: usize,
}

impl FarmStencil {
    /// Enqueue an advance of up to `steps` steps (grouped into epochs of
    /// the tenant's `bt`); with `tol = Some(t)` the epoch residual is
    /// tracked and the command stops once it drops to `t`.
    pub fn submit(&mut self, steps: usize, tol: Option<f64>) -> Result<()> {
        self.farm.submit_stencil(self.tid, steps, tol)
    }

    /// Block until the submitted command completes.
    pub fn wait(&mut self) -> Result<StencilFarmRun> {
        self.farm.wait_stencil(self.tid)
    }

    /// Blocking advance: submit + wait.
    pub fn advance(&mut self, steps: usize, tol: Option<f64>) -> Result<StencilFarmRun> {
        self.submit(steps, tol)?;
        self.wait()
    }

    /// Enqueue an entire batched [`CommandGraph`] (epoch-chain segments,
    /// tolerance, resubmission policy) under a single scheduler-lock
    /// acquisition. Segment boundaries are chained inside the farm's
    /// completion transition, so the result is bit-identical to one
    /// monolithic `submit` of `graph.total()` steps.
    pub fn submit_graph(&mut self, graph: &CommandGraph) -> Result<()> {
        self.farm.submit_stencil_graph(self.tid, graph)
    }

    /// Blocking graph run: submit_graph + wait.
    pub fn advance_graph(&mut self, graph: &CommandGraph) -> Result<StencilFarmRun> {
        self.submit_graph(graph)?;
        self.wait()
    }

    /// Completion future of the in-flight command (async `wait`).
    /// Resolving harvests the command; dropping unresolved releases the
    /// plane slots but leaves the command running.
    pub fn completion(&mut self) -> StencilCompletion<'_> {
        StencilCompletion::new(self.farm.clone(), self.tid)
    }

    /// Non-blocking submit: enqueue and return the completion future.
    pub fn submit_async(&mut self, steps: usize, tol: Option<f64>) -> Result<StencilCompletion<'_>> {
        self.farm.submit_stencil(self.tid, steps, tol)?;
        Ok(self.completion())
    }

    /// Non-blocking graph submit: enqueue and return the completion future.
    pub fn submit_graph_async(&mut self, graph: &CommandGraph) -> Result<StencilCompletion<'_>> {
        self.farm.submit_stencil_graph(self.tid, graph)?;
        Ok(self.completion())
    }

    /// Async advance: submit + await (the async twin of [`Self::advance`]).
    pub async fn advance_async(&mut self, steps: usize, tol: Option<f64>) -> Result<StencilFarmRun> {
        self.submit_async(steps, tol)?.await
    }

    /// Async graph run: submit_graph + await.
    pub async fn advance_graph_async(&mut self, graph: &CommandGraph) -> Result<StencilFarmRun> {
        self.submit_graph_async(graph)?.await
    }

    /// Snapshot the padded domain data (between commands only).
    pub fn state(&self) -> Result<Vec<f64>> {
        self.farm.stencil_state(self.tid)
    }

    /// Set this tenant's resilience knobs (checkpoint cadence, retry
    /// policy, watchdog deadline — see
    /// [`crate::runtime::resilience::ResilienceConfig`]). Errors with a
    /// command in flight: the knobs feed the completion transition and
    /// must not change under it.
    pub fn configure_resilience(&mut self, cfg: ResilienceConfig) -> Result<()> {
        self.farm.set_resilience(self.tid, cfg)
    }

    /// Restore this tenant's resident state from a durable checkpoint
    /// (between commands only): grid, slab pairs, and the lifetime epoch
    /// coordinate, so the next `advance` resumes the time loop
    /// bit-identically to the uninterrupted run. Pair with
    /// [`crate::runtime::resilience::snapshot::SnapshotStore::restore`]
    /// and [`Checkpoint::progress`] to compute the remaining steps —
    /// the recovery walkthrough lives in `docs/RECOVERY.md`.
    pub fn restore_from(&mut self, ck: &Checkpoint) -> Result<()> {
        self.farm.restore_stencil(self.tid, ck)
    }
}

impl Drop for FarmStencil {
    fn drop(&mut self) {
        self.farm.release(self.tid);
    }
}

/// An admitted CG session. State is copied in at submit and out at wait
/// (command-boundary semantics identical to [`crate::cg::pool::CgPool::run`]);
/// between those boundaries the iteration loop runs resident in the farm.
pub struct FarmCg {
    farm: FarmHandle,
    tid: usize,
}

impl FarmCg {
    /// Enqueue up to `iters` CG iterations from recurrence state `rr`,
    /// stopping early once `rr <= threshold` (0.0 = fixed-iteration mode).
    pub fn submit(
        &mut self,
        x: &[f64],
        r: &[f64],
        p: &[f64],
        rr: f64,
        threshold: f64,
        iters: usize,
    ) -> Result<()> {
        self.farm.submit_cg(self.tid, x, r, p, rr, threshold, iters)
    }

    /// Block until the submitted command completes, copying the advanced
    /// state back out (including on a solver error, whose completed
    /// iterations are still valid).
    pub fn wait(&mut self, x: &mut [f64], r: &mut [f64], p: &mut [f64]) -> Result<CgFarmRun> {
        self.farm.wait_cg(self.tid, x, r, p)
    }

    /// Blocking run: submit + wait (the farm mirror of `CgPool::run`).
    pub fn run(
        &mut self,
        x: &mut [f64],
        r: &mut [f64],
        p: &mut [f64],
        rr: f64,
        threshold: f64,
        iters: usize,
    ) -> Result<CgFarmRun> {
        self.submit(x, r, p, rr, threshold, iters)?;
        self.wait(x, r, p)
    }

    /// Enqueue an entire batched [`CommandGraph`] of CG iteration
    /// segments under a single scheduler-lock acquisition; the graph's
    /// tolerance (if any) is the squared-residual threshold. Bit-identical
    /// to one monolithic `submit` of `graph.total()` iterations.
    pub fn submit_graph(
        &mut self,
        x: &[f64],
        r: &[f64],
        p: &[f64],
        rr: f64,
        graph: &CommandGraph,
    ) -> Result<()> {
        self.farm.submit_cg_graph(self.tid, x, r, p, rr, graph)
    }

    /// Blocking graph run: submit_graph + wait.
    pub fn run_graph(
        &mut self,
        x: &mut [f64],
        r: &mut [f64],
        p: &mut [f64],
        rr: f64,
        graph: &CommandGraph,
    ) -> Result<CgFarmRun> {
        self.submit_graph(x, r, p, rr, graph)?;
        self.wait(x, r, p)
    }

    /// Completion future of the in-flight command (async `wait`). The
    /// borrowed slices receive the advanced state when it resolves.
    pub fn completion<'a>(
        &'a mut self,
        x: &'a mut [f64],
        r: &'a mut [f64],
        p: &'a mut [f64],
    ) -> CgCompletion<'a> {
        CgCompletion::new(self.farm.clone(), self.tid, x, r, p)
    }

    /// Non-blocking run: enqueue up to `iters` iterations from the state
    /// in `x`/`r`/`p` and return the completion future that will copy the
    /// advanced state back into them.
    #[allow(clippy::too_many_arguments)]
    pub fn submit_async<'a>(
        &'a mut self,
        x: &'a mut [f64],
        r: &'a mut [f64],
        p: &'a mut [f64],
        rr: f64,
        threshold: f64,
        iters: usize,
    ) -> Result<CgCompletion<'a>> {
        self.farm.submit_cg(self.tid, x, r, p, rr, threshold, iters)?;
        Ok(self.completion(x, r, p))
    }

    /// Non-blocking graph run: enqueue the batched graph and return the
    /// completion future.
    pub fn submit_graph_async<'a>(
        &'a mut self,
        x: &'a mut [f64],
        r: &'a mut [f64],
        p: &'a mut [f64],
        rr: f64,
        graph: &CommandGraph,
    ) -> Result<CgCompletion<'a>> {
        self.farm.submit_cg_graph(self.tid, x, r, p, rr, graph)?;
        Ok(self.completion(x, r, p))
    }

    /// Async run: submit + await (the async twin of [`Self::run`]).
    pub async fn run_async(
        &mut self,
        x: &mut [f64],
        r: &mut [f64],
        p: &mut [f64],
        rr: f64,
        threshold: f64,
        iters: usize,
    ) -> Result<CgFarmRun> {
        self.submit_async(x, r, p, rr, threshold, iters)?.await
    }

    /// Async graph run: submit_graph + await.
    pub async fn run_graph_async(
        &mut self,
        x: &mut [f64],
        r: &mut [f64],
        p: &mut [f64],
        rr: f64,
        graph: &CommandGraph,
    ) -> Result<CgFarmRun> {
        self.submit_graph_async(x, r, p, rr, graph)?.await
    }

    /// Set this tenant's resilience knobs (see
    /// [`FarmStencil::configure_resilience`]).
    pub fn configure_resilience(&mut self, cfg: ResilienceConfig) -> Result<()> {
        self.farm.set_resilience(self.tid, cfg)
    }
}

impl Drop for FarmCg {
    fn drop(&mut self) {
        self.farm.release(self.tid);
    }
}

/// An admitted *pipelined* CG session ([`crate::cg::pipeline`]). The full
/// nine-vector recurrence state moves in at submit and out at wait;
/// between those boundaries each iteration is ONE scheduled farm phase
/// (`P_PIPE`) where the classic CG tenant schedules four, and the
/// advance is bit-identical to [`crate::cg::pipeline::advance_serial`]
/// over the same partition. Command graphs and resilience are not
/// supported on this path.
pub struct FarmCgPipe {
    farm: FarmHandle,
    tid: usize,
}

impl FarmCgPipe {
    /// Enqueue up to `iters` pipelined iterations from `st`, stopping
    /// early once `rr <= threshold` (0.0 = fixed-iteration mode).
    pub fn submit(&mut self, st: &PipeState, threshold: f64, iters: usize) -> Result<()> {
        self.farm.submit_cg_pipe(self.tid, st, threshold, iters)
    }

    /// Block until the submitted command completes, copying the advanced
    /// recurrence state back into `st` (including on a solver error,
    /// whose completed iterations are still valid).
    pub fn wait(&mut self, st: &mut PipeState) -> Result<CgFarmRun> {
        self.farm.wait_cg_pipe(self.tid, st)
    }

    /// Blocking run: submit + wait (the farm mirror of
    /// [`crate::cg::pipeline::PipePool::run`]).
    pub fn run(&mut self, st: &mut PipeState, threshold: f64, iters: usize) -> Result<CgFarmRun> {
        self.submit(st, threshold, iters)?;
        self.wait(st)
    }

    /// Always errors: checkpoint/replay needs the classic CG farm path
    /// (the pipelined tenant's mid-iteration state spans two `m`
    /// parities and four recurrence scalars that the checkpoint format
    /// does not carry).
    pub fn configure_resilience(&mut self, cfg: ResilienceConfig) -> Result<()> {
        self.farm.set_resilience(self.tid, cfg)
    }
}

impl Drop for FarmCgPipe {
    fn drop(&mut self) {
        self.farm.release(self.tid);
    }
}

// ---------------------------------------------------------------------
// Worker loop + scheduler
// ---------------------------------------------------------------------

fn worker_main(sh: &FarmShared) {
    loop {
        let task = {
            let mut g = sh.lock();
            loop {
                if g.shutdown {
                    return;
                }
                let mut next_due: Option<f64> = None;
                if let Some(t) = claim(&mut g, sh, &mut next_due) {
                    break t;
                }
                g = match next_due {
                    // a restored tenant is backing off: park with a
                    // timeout so its replay resumes even if no other
                    // work arrives to wake us
                    Some(due) => {
                        let wait = (due - sh.now()).max(0.0);
                        sh.work_cv
                            .wait_timeout(g, Duration::from_secs_f64(wait))
                            .unwrap_or_else(|p| p.into_inner())
                            .0
                    }
                    None => sh.work_cv.wait(g).unwrap_or_else(|p| p.into_inner()),
                };
            }
        };
        // injected stall: sleep outside the scheduler lock, before the
        // shard runs — peers keep claiming, only this command slows
        if let Some(FaultKind::Stall(d)) = task.inject {
            std::thread::sleep(d);
        }
        // injected hard kill: a SIGKILL stand-in — the process dies
        // right here, mid-command, no unwinding, no Drop, no flush.
        // In-memory recovery cannot survive this; only a durable
        // snapshot already renamed into place can (docs/RECOVERY.md,
        // the `crash-restart` CI job).
        if matches!(task.inject, Some(FaultKind::Kill)) {
            std::process::abort();
        }
        // A panic in the numeric shard must not leave the countdown short
        // (that would hang the client's wait): surface it as a command
        // failure instead. Unlike the barrier pools, a panicking shard
        // strands nothing — the other shards complete independently.
        // SAFETY: the claim/complete handshake hands this worker exclusive
        // ownership of `task.shard` until `complete` runs, so the raw
        // shard access inside `run_shard` cannot race a peer.
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| unsafe {
            if matches!(task.inject, Some(FaultKind::Panic)) {
                // lint: allow(no-panic) -- deliberate fault injection; caught by the catch_unwind directly above and surfaced as a command failure
                panic!("injected fault");
            }
            let out = task.engine.run_shard(
                task.phase,
                task.shard,
                task.sub,
                task.track,
                task.scalar,
                task.scalar2,
            );
            if matches!(task.inject, Some(FaultKind::Nan)) {
                task.engine.poison_shard(task.shard);
            }
            out
        }))
        .map_err(|_| Failure::Panic { phase: task.phase, shard: task.shard, epoch: task.epoch });
        let (waker, durable_job) = {
            let mut g = sh.lock();
            let waker = complete(&mut g, sh, &task, res);
            // claim this tenant's pending durable frame (if the
            // transition just parked one) under the lock we already
            // hold; the write itself runs after the guard drops
            let job = claim_durable(&mut g, task.tid);
            (waker, job)
        };
        // fire the completion waker outside the scheduler lock — the woken
        // executor immediately re-polls, which needs the lock itself
        if let Some(w) = waker {
            w.wake();
        }
        // durable write-out: fsync + rename latency happens here, with
        // no lock held — peers keep claiming while the disk works
        if let Some((sink, ck)) = durable_job {
            write_durable(sh, task.tid, sink, ck);
        }
    }
}

/// Claim one shard from the front ready session (round-robin with the
/// age bound — see module docs). Returns `None` when nothing is ready;
/// tenants deferred by a recovery backoff report their earliest resume
/// time through `next_due` so the caller can park with a timeout.
fn claim(g: &mut FarmState, sh: &FarmShared, next_due: &mut Option<f64>) -> Option<Task> {
    // hot-path: begin -- runs under the scheduler lock on every worker
    // wake; anything allocating here serializes the whole farm
    // tenants backing off after a restore are stashed aside (order
    // preserved) instead of claimed — one bounded scan, no rotation spin
    // lint: allow(hot-path-alloc) -- empty Vec: no heap touch until a deferral actually occurs, which only happens on the cold recovery-backoff path
    let mut deferred: Vec<usize> = Vec::new();
    let mut out = None;
    while let Some(tid) = g.ready.pop_front() {
        let tick = g.tick;
        let now = sh.now();
        let (mut task, more, aged, sample) = {
            let Some(t) = g.tenants[tid].as_mut() else {
                continue; // released while queued (defensive)
            };
            if t.next_shard >= t.nshards {
                continue; // stale entry (defensive)
            }
            if t.resume_at > now {
                *next_due = Some(next_due.map_or(t.resume_at, |d| d.min(t.resume_at)));
                deferred.push(tid);
                continue;
            }
            let shard = t.next_shard;
            t.next_shard += 1;
            t.outstanding += 1;
            let sample = if t.first_dispatch {
                t.first_dispatch = false;
                let wait = (now - t.enqueued_at).max(0.0);
                t.queue_wait_cmd = wait;
                Some(wait)
            } else {
                None
            };
            let task = Task {
                tid,
                phase: t.phase,
                shard,
                sub: t.sub,
                track: t.tol.is_some(),
                scalar: match (&*t.engine, t.phase) {
                    (EngineKind::Cg(_), P_XR) => t.alpha,
                    (EngineKind::Cg(_), P_PUP) => t.beta,
                    (EngineKind::CgPipe(_), P_PIPE) => t.alpha,
                    _ => 0.0,
                },
                scalar2: match (&*t.engine, t.phase) {
                    (EngineKind::CgPipe(_), P_PIPE) => t.beta,
                    _ => 0.0,
                },
                epoch: t.epoch,
                inject: None,
                // lint: allow(hot-path-alloc) -- Arc refcount bump, not a heap allocation; the engine itself is shared, never copied
                engine: t.engine.clone(),
            };
            let more = t.next_shard < t.nshards;
            let aged = tick.saturating_sub(t.enqueue_tick) > FAIRNESS_BOUND;
            (task, more, aged, sample)
        };
        // fault injection: consult the installed plan under the lock the
        // claim already holds (one branch when no plan is installed)
        if let Some(plan) = g.faults.as_mut() {
            if let Some(k) = plan.claim(tid, task.epoch, task.phase, task.shard) {
                task.inject = Some(k);
                sh.faults_injected.fetch_add(1, Ordering::Relaxed);
                counters::note_faults_injected(1);
            }
        }
        g.tick = tick + 1;
        if let Some(wait) = sample {
            g.queue_max = g.queue_max.max(wait);
            if g.queue_waits.len() < QUEUE_SAMPLE_CAP {
                g.queue_waits.push(wait);
            } else {
                // rolling window: overwrite the oldest sample
                g.queue_waits[g.queue_next] = wait;
                g.queue_next = (g.queue_next + 1) % QUEUE_SAMPLE_CAP;
            }
        }
        if more {
            if aged {
                g.ready.push_front(tid);
            } else {
                g.ready.push_back(tid);
            }
        }
        out = Some(task);
        break;
    }
    // put deferred tenants back at the head, preserving their order
    for tid in deferred.into_iter().rev() {
        g.ready.push_front(tid);
    }
    // hot-path: end
    out
}

/// Retire an in-flight command whose farm has shut down, so the tenant
/// does not stay wedged in the `active` state forever (workers are gone;
/// no completion will ever arrive). Only safe once no claimed task is
/// still draining (`outstanding == 0`) — a worker that observed shutdown
/// mid-task may still be writing tenant buffers until its `complete`
/// runs, and while that is possible the command must stay `active` so
/// state reads keep erroring instead of tearing.
fn abandon_command(g: &mut FarmState, tid: usize) {
    if let Some(t) = g.tenants[tid].as_mut() {
        if t.outstanding == 0 {
            t.active = false;
            t.done_flag = false;
        }
    }
}

/// Account one batch enqueued through the submission plane. Called once
/// per `submit`/`submit_graph` — i.e. once per enqueue-side scheduler
/// lock acquisition, which is exactly the invariant the counters assert:
/// `sched_lock_acquisitions == plane_batches` on the batched path.
fn note_batch_enqueued(sh: &FarmShared) {
    sh.commands.fetch_add(1, Ordering::Relaxed);
    counters::note_farm_commands(1);
    sh.plane_batches.fetch_add(1, Ordering::Relaxed);
    counters::note_plane_batches(1);
    sh.sched_locks.fetch_add(1, Ordering::Relaxed);
    counters::note_sched_lock_acquisitions(1);
}

/// Admission control: charge `need` plane slots (one per graph segment)
/// to tenant `tid`, applying the farm's [`PlaneConfig`] policy when the
/// submission queue is full. Takes and returns the scheduler guard so
/// `Block`/`Timeout` can park on the gate condvar without releasing the
/// caller's critical section on success. Callers must have rejected
/// contract errors (double submit, wrong engine) **before** this: a
/// double submit under the `Block` policy would otherwise park on a gate
/// only its own completion could open.
fn acquire_plane_slots<'a>(
    sh: &'a FarmShared,
    mut g: MutexGuard<'a, FarmState>,
    tid: usize,
    need: usize,
) -> Result<MutexGuard<'a, FarmState>> {
    let cap = sh.plane.queue_cap;
    let per = sh.plane.per_tenant;
    if need > cap || need > per {
        // can never fit, regardless of policy or patience
        sh.plane_sheds.fetch_add(1, Ordering::Relaxed);
        counters::note_plane_sheds(1);
        return Err(Error::Shed(format!(
            "submission of {need} segment(s) exceeds the plane's capacity \
             (queue {cap}, per-tenant {per})"
        )));
    }
    let deadline = match sh.plane.policy {
        AdmissionPolicy::Timeout(d) => Some(Instant::now() + d),
        _ => None,
    };
    loop {
        let held = match g.tenants[tid].as_ref() {
            Some(t) => t.slots_held,
            None => return Err(Error::Solver("farm tenant released".into())),
        };
        if g.plane_inflight.saturating_add(need) <= cap && held.saturating_add(need) <= per {
            g.plane_inflight += need;
            g.plane_peak = g.plane_peak.max(g.plane_inflight);
            // lint: allow(no-panic) -- tenant presence was checked a few lines up under the same uninterrupted lock hold
            g.tenants[tid].as_mut().expect("tenant checked above").slots_held += need;
            return Ok(g);
        }
        match sh.plane.policy {
            AdmissionPolicy::Shed => {
                sh.plane_sheds.fetch_add(1, Ordering::Relaxed);
                counters::note_plane_sheds(1);
                return Err(Error::Shed("submission queue full".into()));
            }
            AdmissionPolicy::Block => {
                g = sh.gate_cv.wait(g).unwrap_or_else(|p| p.into_inner());
            }
            AdmissionPolicy::Timeout(_) => {
                // lint: allow(no-panic) -- `deadline` is Some whenever the policy is Timeout; both are set together at admission entry
                let deadline = deadline.expect("deadline set for Timeout policy");
                let now = Instant::now();
                if now >= deadline {
                    sh.plane_timeouts.fetch_add(1, Ordering::Relaxed);
                    counters::note_plane_timeouts(1);
                    return Err(Error::Timeout(
                        "timed out waiting for a submission-queue slot".into(),
                    ));
                }
                let (guard, _) = sh
                    .gate_cv
                    .wait_timeout(g, deadline - now)
                    .unwrap_or_else(|p| p.into_inner());
                g = guard;
            }
        }
        if g.shutdown {
            return Err(Error::Solver("solver farm is shut down".into()));
        }
    }
}

/// Return all plane slots held by tenant `tid` and wake parked
/// submitters. Idempotent — harvest, future drop, and tenant release can
/// each race to be the one that frees.
fn release_plane_slots(g: &mut FarmState, sh: &FarmShared, tid: usize) {
    let Some(t) = g.tenants.get_mut(tid).and_then(|t| t.as_mut()) else { return };
    if t.slots_held > 0 {
        g.plane_inflight -= t.slots_held;
        t.slots_held = 0;
        sh.gate_cv.notify_all();
    }
}

/// Record a finished task; on phase completion run the transition and
/// either enqueue the next phase or complete the command. Returns the
/// tenant's registered completion waker (if the command finished) for
/// the caller to fire **after** dropping the scheduler lock.
fn complete(
    g: &mut FarmState,
    sh: &FarmShared,
    task: &Task,
    res: std::result::Result<ShardOut, Failure>,
) -> Option<Waker> {
    sh.tasks.fetch_add(1, Ordering::Relaxed);
    counters::note_farm_tasks(1);
    let tick = g.tick;
    let mut requeue = false;
    let mut finished = false;
    let mut freed = false;
    let mut waker = None;
    {
        let Some(t) = g.tenants[task.tid].as_mut() else { return None };
        t.outstanding -= 1;
        match res {
            Ok(o) => {
                t.moved += o.moved;
                t.computed += o.computed;
            }
            Err(f) => {
                if t.failure.is_none() {
                    t.failure = Some(f);
                }
            }
        }
        if t.outstanding > 0 || t.next_shard < t.nshards {
            return None; // phase still in flight
        }
        let mut step = if t.failure.is_some() { Step::Done } else { transition(t, sh) };
        // supervised recovery: classify *after* the transition ran — the
        // transition itself raises failures (non-finite folds), and by
        // this point the phase is fully drained, so the engine buffers
        // are exclusively ours to restore
        if let Some(f) = t.failure.as_ref() {
            if f.retryable()
                && !t.zombie
                && t.attempts < t.res_cfg.retry.max_attempts
                && t.checkpoint.is_some()
            {
                t.attempts += 1;
                step = Step::Phase(restore_tenant(t, sh));
            }
        }
        match step {
            Step::Phase(p) => {
                t.phase = p;
                t.next_shard = 0;
                t.nshards = t.engine.shards();
                t.enqueue_tick = tick;
                requeue = true;
            }
            Step::Done => {
                if t.zombie {
                    freed = true;
                } else {
                    t.done_flag = true;
                    waker = t.waker.take();
                    finished = true;
                }
            }
        }
    }
    if requeue {
        g.ready.push_back(task.tid);
        sh.work_cv.notify_all();
    }
    if freed {
        // nobody will ever harvest a zombie: return its plane slots here
        release_plane_slots(g, sh, task.tid);
        g.tenants[task.tid] = None;
        g.free.push(task.tid);
    }
    if finished {
        sh.done_cv.notify_all();
    }
    waker
}

/// Phase-completion transition: the scalar control flow of the solo
/// pools' resident loops, run once under the scheduler lock (where the
/// pools replicate it per worker between barriers).
fn transition(t: &mut Tenant, sh: &FarmShared) -> Step {
    let engine = t.engine.clone();
    match &*engine {
        EngineKind::Stencil(e) => match t.phase {
            P_LOAD => {
                t.loaded = true;
                stencil_next_epoch(t, e)
            }
            P_COMPUTE => {
                if t.tol.is_some() {
                    // slot-order fold: the solo pool's read_sum, bit for bit
                    t.residual = Some(fold_slots(&e.slots));
                }
                t.done_steps += t.sub;
                t.epoch += 1;
                sh.epochs.fetch_add(1, Ordering::Relaxed);
                // non-finite guard: a poisoned slab (injected, or a
                // genuinely diverged run) fails naming its epoch instead
                // of silently iterating NaN to max_steps
                if let Some(res) = t.residual {
                    if !res.is_finite() {
                        t.failure = Some(Failure::NonFinite {
                            what: "residual",
                            value: res,
                            epoch: t.epoch,
                        });
                        return Step::Done;
                    }
                }
                Step::Phase(P_HALO)
            }
            P_HALO => {
                if let (Some(tol), Some(res)) = (t.tol, t.residual) {
                    if res <= tol {
                        // collective epoch stop: convergence retires the
                        // whole graph, queued segments and resubmits too
                        t.graph_segs.clear();
                        t.graph_resubmits = 0;
                        return Step::Phase(P_FINAL);
                    }
                }
                // cadence checkpoint at the epoch boundary: halos freshly
                // consistent, boundary unions stored — exactly the state
                // an epoch restart needs (taken before the next segment
                // dequeues, so a restore re-dequeues like the clean run)
                maybe_cadence_checkpoint(t, sh);
                stencil_next_epoch(t, e)
            }
            P_FINAL => Step::Done,
            p => unreachable!("bad stencil phase {p}"),
        },
        EngineKind::Cg(e) => match t.phase {
            P_SPMV => Step::Phase(P_FIXUP),
            P_FIXUP => {
                let pap = fold_slots(&e.slots);
                if !pap.is_finite() {
                    // NaN contamination (injected poisoning, or genuine
                    // divergence) — detected before any x/r update of
                    // this iteration, so a restore replays cleanly
                    t.failure = Some(Failure::NonFinite {
                        what: "p·Ap",
                        value: pap,
                        epoch: t.epoch,
                    });
                    return Step::Done;
                }
                if pap <= 0.0 {
                    // detected before any state update of the failing
                    // iteration — the serial/pooled error point
                    t.failure = Some(Failure::Solver(format!(
                        "matrix not positive definite (pAp={pap})"
                    )));
                    return Step::Done;
                }
                t.alpha = t.rr / pap;
                Step::Phase(P_XR)
            }
            P_XR => {
                t.rr_next = fold_slots(&e.slots);
                if !t.rr_next.is_finite() {
                    t.failure = Some(Failure::NonFinite {
                        what: "r·r",
                        value: t.rr_next,
                        epoch: t.epoch,
                    });
                    return Step::Done;
                }
                t.beta = t.rr_next / t.rr;
                Step::Phase(P_PUP)
            }
            P_PUP => {
                t.rr = t.rr_next;
                t.iters_done += 1;
                t.epoch += 1;
                sh.epochs.fetch_add(1, Ordering::Relaxed);
                if t.rr <= t.threshold || t.rr <= 0.0 {
                    // convergence retires the whole graph
                    t.graph_segs.clear();
                    t.graph_resubmits = 0;
                    Step::Done
                } else if t.iters_done >= t.iters_target {
                    // segment boundary: chain the next graph segment
                    // without releasing the (already held) scheduler lock
                    match next_graph_segment(t) {
                        Some(seg) => {
                            t.iters_target += seg;
                            // checkpoint *after* the dequeue: a CG restore
                            // resumes straight at P_SPMV, so the snapshot
                            // must carry the post-dequeue schedule
                            maybe_cadence_checkpoint(t, sh);
                            Step::Phase(P_SPMV)
                        }
                        None => Step::Done,
                    }
                } else {
                    maybe_cadence_checkpoint(t, sh);
                    Step::Phase(P_SPMV)
                }
            }
            p => unreachable!("bad cg phase {p}"),
        },
        EngineKind::CgPipe(e) => match t.phase {
            // one transition per iteration — the farm twin of the solo
            // pipelined pool's single `sync_reduce`: fold the three slot
            // ranges in slot order, rotate the scalar recurrence, flip
            // the m parity, decide, and (usually) re-enqueue P_PIPE
            P_PIPE => {
                let nb = e.blocks.len();
                let g = fold_slots(&e.slots[..nb]);
                let d = fold_slots(&e.slots[nb..2 * nb]);
                let rr = fold_slots(&e.slots[2 * nb..]);
                // the vectors moved even if the fold is bad: flip the
                // parity first so a harvest reads the freshly written m
                t.sub = 1 - t.sub;
                if let Some(msg) = pipeline::check_folds(g, d, rr, t.iters_done + 1) {
                    // same collective message (and uncounted iteration)
                    // as the serial/pooled replication sites
                    t.failure = Some(Failure::Solver(msg));
                    return Step::Done;
                }
                t.pg_gamma_prev = t.pg_gamma;
                t.pg_alpha_prev = t.alpha;
                t.pg_gamma = g;
                t.pg_delta = d;
                t.rr = rr;
                t.iters_done += 1;
                t.epoch += 1;
                sh.epochs.fetch_add(1, Ordering::Relaxed);
                if t.rr <= t.threshold || t.rr <= 0.0 || t.iters_done >= t.iters_target {
                    return Step::Done;
                }
                match pipeline::pipe_coeffs(
                    t.pg_gamma,
                    t.pg_delta,
                    t.pg_gamma_prev,
                    t.pg_alpha_prev,
                ) {
                    Ok((beta, alpha)) => {
                        t.alpha = alpha;
                        t.beta = beta;
                        Step::Phase(P_PIPE)
                    }
                    Err(msg) => {
                        t.failure = Some(Failure::Solver(msg));
                        Step::Done
                    }
                }
            }
            p => unreachable!("bad pipelined cg phase {p}"),
        },
    }
}

fn stencil_next_epoch(t: &mut Tenant, e: &StencilEngine) -> Step {
    if t.done_steps >= t.steps_target {
        // segment boundary: chain the next graph segment under the
        // already-held scheduler lock (no client re-acquire per epoch)
        match next_graph_segment(t) {
            Some(seg) => t.steps_target += seg,
            None => return Step::Phase(P_FINAL),
        }
    }
    // a trailing partial epoch advances fewer sub-steps; the slab's
    // bt*r halo depth covers any sub <= bt
    t.sub = e.bt.min(t.steps_target - t.done_steps);
    Step::Phase(P_COMPUTE)
}

/// Dequeue the next segment of the tenant's command graph, replaying the
/// stored schedule when a resubmission budget remains. `None` ends the
/// command.
fn next_graph_segment(t: &mut Tenant) -> Option<usize> {
    if let Some(seg) = t.graph_segs.pop_front() {
        return Some(seg);
    }
    if t.graph_resubmits > 0 && !t.graph_schedule.is_empty() {
        t.graph_resubmits -= 1;
        let sched: Vec<usize> = t.graph_schedule.clone();
        t.graph_segs.extend(sched);
        return t.graph_segs.pop_front();
    }
    None
}

// ---------------------------------------------------------------------
// Resilience: checkpoint, restore, replay
// ---------------------------------------------------------------------

/// Take a cadence checkpoint when the tenant's lifetime epoch lands on
/// its configured boundary. The `c.epoch < t.epoch` guard makes the
/// cadence idempotent per boundary (a snapshot already at this epoch is
/// never re-copied).
fn maybe_cadence_checkpoint(t: &mut Tenant, sh: &FarmShared) {
    let every = t.res_cfg.checkpoint_every;
    if every > 0
        && t.epoch % every == 0
        && t.checkpoint.as_ref().map_or(true, |c| c.epoch < t.epoch)
    {
        take_checkpoint(t, sh);
    }
}

/// Snapshot the tenant's resident state — numeric buffers, progress
/// counters, traffic accounting, and the remaining command schedule —
/// into its checkpoint slot. Called under the scheduler lock at points
/// where the engine buffers are quiescent: command entry (no command in
/// flight) and phase transitions (`outstanding == 0` with every shard
/// dispatched — the claim/complete handshake ordered all shard writes
/// before this read). No extra barrier or phase is ever added; the copy
/// rides the transition the countdown already runs.
fn take_checkpoint(t: &mut Tenant, sh: &FarmShared) {
    let engine = t.engine.clone();
    let payload = match &*engine {
        EngineKind::Stencil(e) => {
            let mut grid = vec![0.0; e.grid.len()];
            // SAFETY: buffers quiescent (see above).
            unsafe { e.grid.read(0..grid.len(), &mut grid) };
            let slabs = if t.loaded {
                e.slabs
                    .iter()
                    .map(|cell| {
                        // SAFETY: quiescent — no shard owns any slab now.
                        let slab = unsafe { &*cell.0.get() };
                        (slab.cur.clone(), slab.nxt.clone())
                    })
                    .collect()
            } else {
                // pre-load snapshot: the grid alone is the whole state
                Vec::new()
            };
            CheckpointPayload::Stencil {
                grid,
                slabs,
                done_steps: t.done_steps,
                residual: t.residual,
                loaded: t.loaded,
                moved: t.moved,
                computed: t.computed,
                steps_target: t.steps_target,
                segs: t.graph_segs.iter().copied().collect(),
                resubmits: t.graph_resubmits,
            }
        }
        EngineKind::Cg(e) => {
            // SAFETY: buffers quiescent (see above).
            let (x, r, p) = unsafe {
                (e.x.whole().to_vec(), e.r.whole().to_vec(), e.p.whole().to_vec())
            };
            CheckpointPayload::Cg {
                x,
                r,
                p,
                rr: t.rr,
                iters_done: t.iters_done,
                iters_target: t.iters_target,
                segs: t.graph_segs.iter().copied().collect(),
                resubmits: t.graph_resubmits,
            }
        }
        // defensive: pipelined tenants reject every resilience config,
        // so neither the command-entry nor the cadence call sites can
        // reach here with one
        EngineKind::CgPipe(_) => return,
    };
    let ck = Arc::new(Checkpoint::new(t.epoch, payload));
    t.ckpt_bytes_cmd += ck.bytes;
    sh.checkpoint_bytes.fetch_add(ck.bytes, Ordering::Relaxed);
    counters::note_checkpoint_bytes(ck.bytes);
    // durable tenants park the same snapshot (an Arc, not a copy) for
    // the off-lock write-out; overwriting a not-yet-claimed frame is the
    // coalescing policy — only the newest epoch matters on disk
    if t.durable.is_some() {
        t.durable_pending = Some(ck.clone());
    }
    t.checkpoint = Some(ck);
}

/// Restore the tenant's last checkpoint — state bytes, progress and
/// traffic counters, and the remaining segment schedule — clearing the
/// failure and accounting the recovery. Returns the phase to resume at.
/// Called under the scheduler lock with the failed command's phase fully
/// drained (`outstanding == 0`), so the engine buffers are exclusively
/// ours; because every reduction folds fixed slots in slot order, the
/// replay from here is bit-identical to an uninjected run.
fn restore_tenant(t: &mut Tenant, sh: &FarmShared) -> u8 {
    // lint: allow(no-panic) -- callers only reach restore after observing a checkpoint for this tenant under the scheduler lock
    let ck = t.checkpoint.take().expect("restore without a checkpoint");
    let replayed = t.epoch.saturating_sub(ck.epoch);
    t.failure = None;
    t.recoveries_cmd += 1;
    t.replayed_cmd += replayed;
    sh.recoveries.fetch_add(1, Ordering::Relaxed);
    sh.replayed_epochs.fetch_add(replayed, Ordering::Relaxed);
    counters::note_farm_recoveries(1);
    counters::note_replayed_epochs(replayed);
    t.epoch = ck.epoch;
    let backoff = t.res_cfg.retry.backoff;
    if backoff > Duration::ZERO {
        // defer this tenant's *claims*, never a worker: the scheduler
        // skips it (and parks with a timeout) until the farm clock
        // passes resume_at
        t.resume_at = sh.now() + backoff.as_secs_f64();
    }
    let engine = t.engine.clone();
    let resume = match (&*engine, &ck.payload) {
        (
            EngineKind::Stencil(e),
            CheckpointPayload::Stencil {
                grid,
                slabs,
                done_steps,
                residual,
                loaded,
                moved,
                computed,
                steps_target,
                segs,
                resubmits,
            },
        ) => {
            // SAFETY: exclusive access (see above).
            unsafe {
                e.grid.write(0, grid);
                for (cell, (cur, nxt)) in e.slabs.iter().zip(slabs) {
                    let slab = &mut *cell.0.get();
                    slab.cur.copy_from_slice(cur);
                    slab.nxt.copy_from_slice(nxt);
                }
            }
            t.done_steps = *done_steps;
            t.residual = *residual;
            t.loaded = *loaded;
            t.moved = *moved;
            t.computed = *computed;
            t.steps_target = *steps_target;
            t.graph_segs.clear();
            t.graph_segs.extend(segs.iter().copied());
            t.graph_resubmits = *resubmits;
            if !t.loaded {
                // pre-load snapshot: replay the load itself
                P_LOAD
            } else {
                // re-enter the epoch loop exactly where the snapshot was
                // taken (re-dequeuing segments like the clean run did)
                match stencil_next_epoch(t, e) {
                    Step::Phase(p) => p,
                    Step::Done => P_FINAL,
                }
            }
        }
        (
            EngineKind::Cg(e),
            CheckpointPayload::Cg { x, r, p, rr, iters_done, iters_target, segs, resubmits },
        ) => {
            // SAFETY: exclusive access (see above).
            unsafe {
                e.x.whole_mut().copy_from_slice(x);
                e.r.whole_mut().copy_from_slice(r);
                e.p.whole_mut().copy_from_slice(p);
            }
            t.rr = *rr;
            t.iters_done = *iters_done;
            t.iters_target = *iters_target;
            t.graph_segs.clear();
            t.graph_segs.extend(segs.iter().copied());
            t.graph_resubmits = *resubmits;
            P_SPMV
        }
        _ => unreachable!("checkpoint payload kind matches the engine"),
    };
    // the same snapshot serves every remaining attempt
    t.checkpoint = Some(ck);
    resume
}

/// Claim a tenant's pending durable frame for write-out, if one exists
/// and no peer is already writing it (at most one write-out per tenant
/// in flight, so generations land on disk in epoch order). Called under
/// the scheduler lock; the returned sink + frame are persisted by the
/// caller **after** the guard drops.
fn claim_durable(
    g: &mut FarmState,
    tid: usize,
) -> Option<(Arc<DurableSink>, Arc<Checkpoint>)> {
    let t = g.tenants.get_mut(tid).and_then(|t| t.as_mut())?;
    if t.durable_writing {
        return None;
    }
    let ck = t.durable_pending.take()?;
    let sink = t.durable.as_ref()?.clone();
    t.durable_writing = true;
    Some((sink, ck))
}

/// Persist a claimed checkpoint frame, then keep going while newer
/// frames arrive (a slow disk coalesces to the newest epoch instead of
/// building a backlog). Runs on a worker thread with **no** scheduler
/// lock held around the filesystem work; the lock is re-taken only to
/// record the outcome and claim the next frame. A failed write marks
/// the tenant (`Error::Snapshot` on its next submit) — it never tears a
/// frame: the store's tmp + fsync + rename protocol means a partial
/// write is invisible to every restore.
fn write_durable(
    sh: &FarmShared,
    tid: usize,
    mut sink: Arc<DurableSink>,
    mut ck: Arc<Checkpoint>,
) {
    loop {
        let res = sink.store.persist(&sink.name, &sink.meta, &ck);
        let mut g = sh.lock();
        if res.is_ok() {
            sh.durable_frames.fetch_add(1, Ordering::Relaxed);
            sh.durable_bytes.fetch_add(ck.bytes, Ordering::Relaxed);
        }
        // tenant released mid-write: the frame (if written) is already
        // durable; there is simply nobody left to report to
        let Some(t) = g.tenants.get_mut(tid).and_then(|t| t.as_mut()) else { return };
        if let Err(e) = res {
            t.durable_error = Some(format!("durable write-out failed: {e}"));
            t.durable_pending = None;
            t.durable_writing = false;
            return;
        }
        match (t.durable_pending.take(), t.durable.as_ref()) {
            (Some(next), Some(s)) => {
                sink = s.clone();
                ck = next;
            }
            _ => {
                t.durable_writing = false;
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;
    use crate::stencil::gold;
    use crate::stencil::pool::StencilPool;
    use crate::stencil::shape::spec;

    /// The tentpole acceptance bar: a farm tenant's iterates are
    /// bit-identical to its solo-pool run at every farm worker count,
    /// including across resumed advances and at temporal degree bt > 1.
    #[test]
    fn farm_stencil_is_bit_identical_to_solo_pool_across_workers_and_resume() {
        let s = spec("2d9pt").unwrap();
        let mut d = Domain::for_spec(&s, &[18, 18]).unwrap();
        d.randomize(11);
        let want = gold::run(&s, &d, 9).unwrap();
        let mut solo = StencilPool::spawn(&s, &d, 3).unwrap();
        solo.run(4, None).unwrap();
        solo.run(5, None).unwrap();
        assert_eq!(solo.state(), want.data, "solo pool vs gold");
        for workers in [1usize, 2, 3, 8] {
            let farm = SolverFarm::spawn(workers).unwrap();
            let mut t = farm.handle().admit_stencil(&s, &d, 3, 1).unwrap();
            let r1 = t.advance(4, None).unwrap();
            let r2 = t.advance(5, None).unwrap();
            assert_eq!(r1.steps + r2.steps, 9);
            assert_eq!(t.state().unwrap(), want.data, "workers={workers}: farm vs gold");
            // traffic accounting matches the solo pool run for run
            let mut solo2 = StencilPool::spawn(&s, &d, 3).unwrap();
            let s1 = solo2.run(4, None).unwrap();
            let s2 = solo2.run(5, None).unwrap();
            assert_eq!(r1.global_bytes, s1.global_bytes, "workers={workers}: first-run bytes");
            assert_eq!(r2.global_bytes, s2.global_bytes, "workers={workers}: resumed bytes");
            assert_eq!(farm.spawn_count(), workers as u64, "admission spawned threads");
        }
    }

    #[test]
    fn farm_temporal_bt_matches_gold_including_partial_epochs_and_3d() {
        let s = spec("2d5pt").unwrap();
        let mut d = Domain::for_spec(&s, &[16, 16]).unwrap();
        d.randomize(8);
        let want = gold::run(&s, &d, 11).unwrap();
        for workers in [1usize, 3] {
            let farm = SolverFarm::spawn(workers).unwrap();
            for bt in [2usize, 4] {
                let mut t = farm.handle().admit_stencil(&s, &d, 3, bt).unwrap();
                let r1 = t.advance(5, None).unwrap(); // partial epochs at bt=4
                let r2 = t.advance(6, None).unwrap();
                assert_eq!(r1.steps + r2.steps, 11, "bt={bt} workers={workers}");
                assert_eq!(t.state().unwrap(), want.data, "bt={bt} workers={workers}");
                assert!(r1.computed_cells > 0);
            }
        }
        // 3D composition
        let s3 = spec("3d13pt").unwrap();
        let mut d3 = Domain::for_spec(&s3, &[8, 6, 6]).unwrap();
        d3.randomize(9);
        let want3 = gold::run(&s3, &d3, 4).unwrap();
        let farm = SolverFarm::spawn(2).unwrap();
        let mut t = farm.handle().admit_stencil(&s3, &d3, 3, 2).unwrap();
        t.advance(4, None).unwrap();
        assert_eq!(t.state().unwrap(), want3.data, "3D bt=2 vs gold");
    }

    /// Band-shard count is a tenant knob, not the worker count: any
    /// shards x workers combination walks gold's bits (the farm mirror of
    /// the pools' thread-count invariance).
    #[test]
    fn farm_shard_and_worker_counts_are_invisible_to_the_bits() {
        let s = spec("2d5pt").unwrap();
        let mut d = Domain::for_spec(&s, &[14, 14]).unwrap();
        d.randomize(4);
        let want = gold::run(&s, &d, 6).unwrap();
        for shards in [1usize, 2, 5] {
            for workers in [1usize, 4] {
                let farm = SolverFarm::spawn(workers).unwrap();
                let mut t = farm.handle().admit_stencil(&s, &d, shards, 1).unwrap();
                t.advance(6, None).unwrap();
                assert_eq!(
                    t.state().unwrap(),
                    want.data,
                    "shards={shards} workers={workers}"
                );
            }
        }
    }

    #[test]
    fn farm_stencil_advance_until_stops_on_the_solo_pools_epoch() {
        let s = spec("2d5pt").unwrap();
        let mut d = Domain::for_spec(&s, &[8, 8]).unwrap();
        d.randomize(7);
        let (tol, max) = (1e-8, 20_000);
        let mut solo = StencilPool::spawn(&s, &d, 2).unwrap();
        let want = solo.run(max, Some(tol)).unwrap();
        assert!(want.steps < max, "reference did not converge");
        let want_state = solo.state();
        for workers in [1usize, 2, 8] {
            let farm = SolverFarm::spawn(workers).unwrap();
            let mut t = farm.handle().admit_stencil(&s, &d, 2, 1).unwrap();
            let run = t.advance(max, Some(tol)).unwrap();
            assert_eq!(run.steps, want.steps, "workers={workers}: stop step");
            assert_eq!(
                run.residual.unwrap().to_bits(),
                want.residual.unwrap().to_bits(),
                "workers={workers}: residual bits"
            );
            assert_eq!(t.state().unwrap(), want_state, "workers={workers}: state bits");
        }
        // epoch-granular stop at bt > 1, identical at every worker count
        let bt = 4;
        let mut reference: Option<(usize, u64)> = None;
        for workers in [1usize, 3] {
            let farm = SolverFarm::spawn(workers).unwrap();
            let mut t = farm.handle().admit_stencil(&s, &d, 2, bt).unwrap();
            let run = t.advance(max, Some(tol)).unwrap();
            assert_eq!(run.steps % bt, 0, "workers={workers}: epoch-aligned stop");
            let key = (run.steps, run.residual.unwrap().to_bits());
            match &reference {
                None => reference = Some(key),
                Some(want) => assert_eq!(&key, want, "workers={workers}"),
            }
        }
    }

    /// Serial CG reference with the canonical block-ordered reductions
    /// (the same arithmetic as `cg::pool`'s test reference).
    fn serial_cg(a: &Csr, b: &[f64], parts: usize, iters: usize) -> (Vec<f64>, f64) {
        let n = a.n_rows;
        let plan = MergePlan::new(a, parts);
        let blocks = crate::stencil::parallel::partition(n, parts);
        let mut x = vec![0.0; n];
        let mut r = b.to_vec();
        let mut p = b.to_vec();
        let mut ap = vec![0.0; n];
        let mut rr: f64 = b.iter().map(|v| v * v).sum();
        for _ in 0..iters {
            if rr <= 0.0 {
                break;
            }
            merge::spmv(a, &plan, &p, &mut ap);
            let mut pap = 0.0;
            for &(s, l) in &blocks {
                pap += crate::cg::block_partial(s, l, |i| p[i] * ap[i]);
            }
            let alpha = rr / pap;
            let mut rr_new = 0.0;
            for &(s, l) in &blocks {
                rr_new += crate::cg::block_partial(s, l, |i| {
                    x[i] += alpha * p[i];
                    let ri = r[i] - alpha * ap[i];
                    r[i] = ri;
                    ri * ri
                });
            }
            let beta = rr_new / rr;
            for i in 0..n {
                p[i] = r[i] + beta * p[i];
            }
            rr = rr_new;
        }
        (x, rr)
    }

    #[test]
    fn farm_cg_is_bit_identical_to_serial_across_workers_and_resume() {
        let a = gen::poisson2d(16);
        let b = gen::rhs(a.n_rows, 7);
        let (want_x, want_rr) = serial_cg(&a, &b, 8, 22);
        for workers in [1usize, 2, 3, 8] {
            let farm = SolverFarm::spawn(workers).unwrap();
            let plan = MergePlan::new(&a, 8);
            let mut t = farm.handle().admit_cg(Arc::new(a.clone()), plan).unwrap();
            let n = a.n_rows;
            let mut x = vec![0.0; n];
            let mut r = b.clone();
            let mut p = b.clone();
            let mut rr: f64 = b.iter().map(|v| v * v).sum();
            for chunk in [9usize, 13] {
                let run = t.run(&mut x, &mut r, &mut p, rr, 0.0, chunk).unwrap();
                assert!(run.error.is_none());
                rr = run.rr;
            }
            assert_eq!(x, want_x, "workers={workers}");
            assert_eq!(rr.to_bits(), want_rr.to_bits(), "workers={workers}");
        }
    }

    #[test]
    fn farm_cg_threshold_and_error_paths_match_the_pool_semantics() {
        // threshold stop
        let a = gen::poisson2d(10);
        let b = gen::rhs(a.n_rows, 9);
        let rr0: f64 = b.iter().map(|v| v * v).sum();
        let farm = SolverFarm::spawn(2).unwrap();
        let mut t = farm.handle().admit_cg(Arc::new(a.clone()), MergePlan::new(&a, 8)).unwrap();
        let n = a.n_rows;
        let (mut x, mut r, mut p) = (vec![0.0; n], b.clone(), b.clone());
        let run = t.run(&mut x, &mut r, &mut p, rr0, 1e-12 * rr0, 10_000).unwrap();
        assert!(run.iters < 10_000 && run.rr <= 1e-12 * rr0);
        let mut ax = vec![0.0; n];
        a.spmv_gold(&x, &mut ax);
        let err = b.iter().zip(&ax).map(|(bi, ai)| (bi - ai).abs()).fold(0.0, f64::max);
        assert!(err < 1e-5, "true residual {err}");

        // not-positive-definite error before any state update
        let neg = Csr::from_coo(4, 4, (0..4).map(|i| (i, i, -1.0)).collect()).unwrap();
        let bneg = vec![1.0; 4];
        let plan = MergePlan::new(&neg, 2);
        let mut t = farm.handle().admit_cg(Arc::new(neg), plan).unwrap();
        let (mut x, mut r, mut p) = (vec![0.0; 4], bneg.clone(), bneg.clone());
        let run = t.run(&mut x, &mut r, &mut p, 4.0, 0.0, 10).unwrap();
        assert_eq!(run.iters, 0);
        assert!(run.error.as_deref().unwrap_or("").contains("positive definite"));
        assert_eq!(x, vec![0.0; 4], "error fires before any x/r/p update");
        // tenant stays usable after the error
        let again = t.run(&mut x, &mut r, &mut p, 0.0, 0.0, 1).unwrap();
        assert!(again.error.is_none());
        assert_eq!(again.iters, 0);
    }

    /// The pipelined-CG tentpole bar on the farm path: every worker
    /// count and every preconditioner walks the bits of
    /// [`pipeline::advance_serial`] over the same partition, including
    /// across resumed advances.
    #[test]
    fn farm_cg_pipelined_is_bit_identical_to_serial_across_workers_and_resume() {
        let a = gen::poisson2d(14);
        let b = gen::rhs(a.n_rows, 5);
        let parts = 6;
        let blocks = crate::stencil::parallel::partition(a.n_rows, parts);
        for spec in [
            Preconditioner::None,
            Preconditioner::Jacobi,
            Preconditioner::BlockJacobi { block: 5 },
        ] {
            // one-shot serial reference: 22 iterations
            let pc = Precond::build(spec, &a, &blocks).unwrap();
            let mut want = PipeState::prime(&a, &b, None, &pc).unwrap();
            let ser = pipeline::advance_serial(&a, &blocks, &pc, &mut want, 0.0, 22);
            assert_eq!(ser.iters, 22, "{}: serial reference", spec.name());
            for workers in [1usize, 2, 3, 8] {
                let farm = SolverFarm::spawn(workers).unwrap();
                let mut t = farm
                    .handle()
                    .admit_cg_pipelined(Arc::new(a.clone()), parts, spec)
                    .unwrap();
                let mut st = PipeState::prime(&a, &b, None, &pc).unwrap();
                for chunk in [9usize, 13] {
                    let run = t.run(&mut st, 0.0, chunk).unwrap();
                    assert!(run.error.is_none(), "{}: workers={workers}", spec.name());
                    assert_eq!(run.iters, chunk);
                }
                let tag = format!("{} workers={workers}", spec.name());
                assert_eq!(st.x, want.x, "{tag}: x bits");
                assert_eq!(st.r, want.r, "{tag}: r bits");
                assert_eq!(st.p, want.p, "{tag}: p bits");
                assert_eq!(st.rr.to_bits(), want.rr.to_bits(), "{tag}: rr bits");
                assert_eq!(st.gamma.to_bits(), want.gamma.to_bits(), "{tag}: γ bits");
                assert_eq!(st.delta.to_bits(), want.delta.to_bits(), "{tag}: δ bits");
            }
        }
    }

    /// The one-barrier-per-iteration claim, in farm units: a pipelined
    /// iteration is ONE scheduled phase (`P_PIPE`, `shards` tasks) where
    /// classic CG schedules four — counter-asserted on the shared task
    /// and epoch tallies of a fresh farm.
    #[test]
    fn farm_cg_pipelined_schedules_one_phase_per_iteration() {
        let a = gen::poisson2d(12);
        let b = gen::rhs(a.n_rows, 2);
        let (parts, iters) = (5usize, 17usize);
        let blocks = crate::stencil::parallel::partition(a.n_rows, parts);
        let pc = Precond::build(Preconditioner::Jacobi, &a, &blocks).unwrap();
        let farm = SolverFarm::spawn(3).unwrap();
        let mut t = farm
            .handle()
            .admit_cg_pipelined(Arc::new(a.clone()), parts, Preconditioner::Jacobi)
            .unwrap();
        let mut st = PipeState::prime(&a, &b, None, &pc).unwrap();
        let run = t.run(&mut st, 0.0, iters).unwrap();
        assert!(run.error.is_none());
        assert_eq!(run.iters, iters);
        let m = farm.metrics();
        assert_eq!(m.tasks, (parts * iters) as u64, "one phase of `parts` shards per iteration");
        assert_eq!(m.epochs, iters as u64, "one epoch per iteration");
    }

    /// Solver-error and unsupported-feature paths: a non-SPD system is a
    /// collective [`pipeline::check_folds`] error with the serial path's
    /// exact message and zero counted iterations, the tenant stays
    /// usable, and resilience is rejected at configure time.
    #[test]
    fn farm_cg_pipelined_error_paths_match_serial_and_reject_resilience() {
        let neg = Csr::from_coo(6, 6, (0..6).map(|i| (i, i, -1.0)).collect()).unwrap();
        let bneg = vec![1.0; 6];
        let blocks = crate::stencil::parallel::partition(6, 2);
        let pc = Precond::build(Preconditioner::None, &neg, &blocks).unwrap();
        let mut want = PipeState::prime(&neg, &bneg, None, &pc).unwrap();
        let ser = pipeline::advance_serial(&neg, &blocks, &pc, &mut want, 0.0, 10);
        let want_err = ser.error.expect("serial run must error on a non-SPD system");
        assert_eq!(ser.iters, 0);

        let farm = SolverFarm::spawn(2).unwrap();
        let mut t = farm
            .handle()
            .admit_cg_pipelined(Arc::new(neg.clone()), 2, Preconditioner::None)
            .unwrap();
        let mut st = PipeState::prime(&neg, &bneg, None, &pc).unwrap();
        let run = t.run(&mut st, 0.0, 10).unwrap();
        assert_eq!(run.iters, 0, "failing iteration is not counted");
        assert_eq!(run.error.as_deref(), Some(want_err.as_str()), "farm vs serial error text");
        // tenant stays usable after the solver error
        let again = t.run(&mut st, 0.0, 0).unwrap();
        assert!(again.error.is_none());
        assert_eq!(again.iters, 0);
        // resilience is a classic-CG-only feature on the farm
        let err = t.configure_resilience(ResilienceConfig::checkpointed()).unwrap_err();
        assert!(
            format!("{err}").contains("pipelined"),
            "unexpected rejection text: {err}"
        );
        // and a pipelined tenant rejects stencil submissions
        assert!(farm.handle().submit_stencil_cmd(t.tid, 1, &[], None, 0).is_err());
    }

    /// Mixed stencil + CG tenants with interleaved in-flight commands:
    /// every tenant still walks its solo bits, from one worker set.
    #[test]
    fn mixed_tenants_with_concurrent_commands_keep_their_solo_bits() {
        let s = spec("2d5pt").unwrap();
        let mut d1 = Domain::for_spec(&s, &[12, 12]).unwrap();
        d1.randomize(1);
        let mut d2 = Domain::for_spec(&s, &[10, 14]).unwrap();
        d2.randomize(2);
        let a = gen::poisson2d(12);
        let b = gen::rhs(a.n_rows, 3);
        let want1 = gold::run(&s, &d1, 8).unwrap();
        let want2 = gold::run(&s, &d2, 6).unwrap();
        let (want_x, want_rr) = serial_cg(&a, &b, 8, 15);

        let farm = SolverFarm::spawn(3).unwrap();
        let h = farm.handle();
        let mut t1 = h.admit_stencil(&s, &d1, 2, 2).unwrap();
        let mut t2 = h.admit_stencil(&s, &d2, 3, 1).unwrap();
        let mut tc = h.admit_cg(Arc::new(a.clone()), MergePlan::new(&a, 8)).unwrap();
        let n = a.n_rows;
        let (mut x, mut r, mut p) = (vec![0.0; n], b.clone(), b.clone());
        let rr0: f64 = b.iter().map(|v| v * v).sum();
        // all three commands in flight at once on the shared workers
        t1.submit(8, None).unwrap();
        t2.submit(6, None).unwrap();
        tc.submit(&x, &r, &p, rr0, 0.0, 15).unwrap();
        let r1 = t1.wait().unwrap();
        let r2 = t2.wait().unwrap();
        let rc = tc.wait(&mut x, &mut r, &mut p).unwrap();
        assert_eq!(r1.steps, 8);
        assert_eq!(r2.steps, 6);
        assert_eq!(rc.iters, 15);
        assert_eq!(t1.state().unwrap(), want1.data, "tenant 1 vs gold");
        assert_eq!(t2.state().unwrap(), want2.data, "tenant 2 vs gold");
        assert_eq!(x, want_x, "cg tenant vs serial");
        assert_eq!(rc.rr.to_bits(), want_rr.to_bits());
        // the whole mixed workload ran on the startup worker set
        assert_eq!(farm.spawn_count(), 3);
        let m = farm.metrics();
        assert_eq!(m.admissions, 3);
        assert!(m.commands >= 3);
        assert!(m.tasks > 0 && m.epochs > 0);
        assert!(m.queue_wait_p99 >= m.queue_wait_p50);
        assert!(m.fairness() >= 1.0);
    }

    /// Satellite acceptance: admitting sessions and advancing them spawns
    /// zero threads after farm startup.
    #[test]
    fn admissions_and_advances_never_spawn_after_farm_startup() {
        let s = spec("2d5pt").unwrap();
        let mut d = Domain::for_spec(&s, &[10, 10]).unwrap();
        d.randomize(3);
        let farm = SolverFarm::spawn(2).unwrap();
        let after_start = farm.spawn_count();
        assert_eq!(after_start, 2);
        for i in 0..6usize {
            let mut t = farm.handle().admit_stencil(&s, &d, 2, 1 + (i % 2)).unwrap();
            t.advance(3, None).unwrap();
            t.advance(2, None).unwrap();
        }
        assert_eq!(farm.spawn_count(), after_start, "admission/advance must not spawn");
        assert_eq!(farm.metrics().admissions, 6);
    }

    /// Satellite: the shutdown race — 64 rapid create/drop cycles, with
    /// and without commands, some with a command still in flight at drop.
    /// Every join must complete promptly (the test hanging IS the
    /// failure), and a waiter on a shut-down farm gets an error.
    #[test]
    fn rapid_create_drop_cycles_never_hang() {
        let s = spec("2d5pt").unwrap();
        let mut d = Domain::for_spec(&s, &[8, 8]).unwrap();
        d.randomize(5);
        for cycle in 0..64usize {
            let mut farm = SolverFarm::spawn(1 + cycle % 3).unwrap();
            let weak = farm.shared_weak();
            match cycle % 4 {
                0 => {} // drop a farm that never ran anything
                1 => {
                    let mut t = farm.handle().admit_stencil(&s, &d, 2, 1).unwrap();
                    t.advance(2, None).unwrap();
                }
                2 => {
                    // tenant dropped without waiting: zombie-released
                    let mut t = farm.handle().admit_stencil(&s, &d, 2, 1).unwrap();
                    t.submit(2, None).unwrap();
                    drop(t);
                }
                _ => {
                    // explicit shutdown while a command may be in flight,
                    // then wait must error (or return the completed run),
                    // never hang
                    let mut t = farm.handle().admit_stencil(&s, &d, 2, 1).unwrap();
                    t.submit(50, None).unwrap();
                    farm.shutdown();
                    let _ = t.wait(); // Ok (completed before shutdown) or Err
                }
            }
            drop(farm);
            // handles may still be held by FarmStencil Drops above, but a
            // dropped farm keeps no worker alive: only client Arcs remain
            assert!(weak.upgrade().map(|sh| Arc::strong_count(&sh) <= 2).unwrap_or(true));
        }
    }

    #[test]
    fn commands_after_shutdown_error_instead_of_hanging() {
        let s = spec("2d5pt").unwrap();
        let mut d = Domain::for_spec(&s, &[8, 8]).unwrap();
        d.randomize(6);
        let mut farm = SolverFarm::spawn(2).unwrap();
        let h = farm.handle();
        let mut t = h.admit_stencil(&s, &d, 2, 1).unwrap();
        t.advance(2, None).unwrap();
        farm.shutdown();
        let err = t.advance(1, None).unwrap_err();
        assert!(format!("{err}").contains("shut down"), "{err}");
        let err = h.admit_stencil(&s, &d, 2, 1).unwrap_err();
        assert!(format!("{err}").contains("shut down"), "{err}");
        // state stays readable after shutdown (tenant idle, grid intact)
        assert_eq!(t.state().unwrap().len(), d.data.len());
    }

    #[test]
    fn double_submit_and_mid_flight_state_reads_are_errors() {
        let s = spec("2d5pt").unwrap();
        let mut d = Domain::for_spec(&s, &[8, 8]).unwrap();
        d.randomize(2);
        let farm = SolverFarm::spawn(1).unwrap();
        let mut t = farm.handle().admit_stencil(&s, &d, 2, 1).unwrap();
        t.submit(10_000, None).unwrap();
        assert!(t.submit(1, None).is_err(), "double submit must be rejected");
        // state read with a command in flight is an error, not a torn read
        // (the command may legitimately finish first — accept either)
        match t.state() {
            Ok(v) => assert_eq!(v.len(), d.data.len()),
            Err(e) => assert!(format!("{e}").contains("in flight"), "{e}"),
        }
        t.wait().unwrap();
        assert_eq!(t.state().unwrap().len(), d.data.len());
    }

    /// Regression (submission-plane satellite): the double-submit
    /// contract must hold for CG sessions too, not just stencils — and it
    /// must fail loudly *before* admission control, so a `Block`-policy
    /// plane can never park a double submit on a gate only its own
    /// completion could open.
    #[test]
    fn cg_double_submit_is_an_error_not_a_deadlock() {
        let a = gen::poisson2d(12);
        let b = gen::rhs(a.n_rows, 11);
        let rr0: f64 = b.iter().map(|v| v * v).sum();
        // bounded Block-policy plane: the deadlock would be real if the
        // contract check came after the admission gate
        let farm =
            SolverFarm::spawn_with(1, PlaneConfig::bounded(1)).unwrap();
        let plan = MergePlan::new(&a, 4);
        let mut t = farm.handle().admit_cg(Arc::new(a.clone()), plan).unwrap();
        let n = a.n_rows;
        let (mut x, mut r, mut p) = (vec![0.0; n], b.clone(), b.clone());
        t.submit(&x, &r, &p, rr0, 0.0, 10_000).unwrap();
        let err = t.submit(&x, &r, &p, rr0, 0.0, 1).unwrap_err();
        assert!(format!("{err}").contains("in flight"), "{err}");
        let run = t.wait(&mut x, &mut r, &mut p).unwrap();
        assert_eq!(run.iters, 10_000);
        // the rejected submit must not have leaked a plane slot
        assert_eq!(farm.metrics().plane_inflight_peak, 1);
        // tenant stays usable
        let again = t.run(&mut x, &mut r, &mut p, run.rr, 0.0, 1).unwrap();
        assert!(again.error.is_none());
    }

    #[test]
    fn released_tenant_slots_are_reused() {
        let s = spec("2d5pt").unwrap();
        let mut d = Domain::for_spec(&s, &[8, 8]).unwrap();
        d.randomize(1);
        let farm = SolverFarm::spawn(1).unwrap();
        let h = farm.handle();
        for _ in 0..10 {
            let mut t = h.admit_stencil(&s, &d, 2, 1).unwrap();
            t.advance(1, None).unwrap();
        }
        assert!(h.tenant_slots() <= 2, "released slots must be recycled");
        assert_eq!(farm.metrics().admissions, 10);
    }

    #[test]
    fn admission_validates_like_the_solo_substrates() {
        let s = spec("2d5pt").unwrap();
        let mut d = Domain::for_spec(&s, &[8, 8]).unwrap();
        d.randomize(1);
        let farm = SolverFarm::spawn(1).unwrap();
        let h = farm.handle();
        assert!(h.admit_stencil(&s, &d, 0, 1).is_err(), "zero shards");
        assert!(h.admit_stencil(&s, &d, 2, 0).is_err(), "bt = 0");
        let empty = Domain::zeros([1, 0, 8], s.radius, 2);
        assert!(h.admit_stencil(&s, &empty, 2, 1).is_err(), "empty domain");
        assert!(SolverFarm::spawn(0).is_err(), "zero workers");
        let rect = Csr::from_coo(2, 3, vec![(0, 0, 1.0)]).unwrap();
        let plan = MergePlan::new(&rect, 2);
        assert!(h.admit_cg(Arc::new(rect), plan).is_err(), "rectangular matrix");
        let a = gen::poisson2d(4);
        let other = gen::poisson2d(5);
        let plan = MergePlan::new(&other, 2);
        assert!(h.admit_cg(Arc::new(a), plan).is_err(), "plan mismatch");
    }
}
